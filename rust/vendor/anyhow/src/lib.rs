//! Offline stand-in for the `anyhow` crate.
//!
//! The build image has no crates.io access (DESIGN.md §7), so the
//! error-context API the coordinator uses is vendored here: [`Error`],
//! [`Result`], the [`Context`] extension trait, and the `anyhow!` /
//! `bail!` / `ensure!` macros. Behaviour mirrors real `anyhow` for the
//! subset this repository exercises; swap the path dependency for the
//! registry crate to get the full implementation.

use std::fmt;

/// A dynamic error carrying an accumulated context chain.
///
/// Unlike real `anyhow`, the chain is flattened into one message at
/// construction time ("context: cause: cause"); `Display` and the `{:#}`
/// alternate form both print the full chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (the `anyhow!` macro's core).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Wrap with an outer context layer.
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// `Error` intentionally does NOT implement `std::error::Error`: that
// keeps this blanket conversion coherent (same trick as real anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

/// `anyhow::Result<T>` — a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(|| ...)` to
/// `Result` and `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| Error { msg: format!("{ctx}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => { $crate::Error::msg(format!($msg)) };
    ($err:expr $(,)?) => { $crate::Error::msg($err) };
    ($fmt:expr, $($arg:tt)*) => { $crate::Error::msg(format!($fmt, $($arg)*)) };
}

/// Return early with an error built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => { return ::std::result::Result::Err($crate::anyhow!($($t)*)) };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        let r: std::io::Result<()> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"));
        r?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().contains("disk on fire"));
    }

    #[test]
    fn context_layers_accumulate() {
        let e = io_fail()
            .context("reading manifest")
            .unwrap_err();
        assert!(e.to_string().starts_with("reading manifest: "));
        let e2: Result<()> = None::<()>.with_context(|| format!("slot {}", 3)).map(|_| ());
        assert_eq!(e2.unwrap_err().to_string(), "slot 3");
    }

    #[test]
    fn macros_build_errors() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let b = anyhow!("x = {}", 42);
        assert_eq!(b.to_string(), "x = 42");
        let c = anyhow!(String::from("owned"));
        assert_eq!(c.to_string(), "owned");

        fn bails(flag: bool) -> Result<u32> {
            ensure!(!flag, "flag was {flag}");
            bail!("unreachable? no: always bails");
        }
        assert_eq!(bails(true).unwrap_err().to_string(), "flag was true");
        assert!(bails(false).unwrap_err().to_string().contains("always bails"));
    }
}
