//! `ParallelDispatcher` + `run_dispatch_parallel`: partition topology,
//! routing fidelity vs the single-thread dispatcher, and the router's
//! backpressure/rejection semantics. Everything is artifact-free
//! (`EchoExecutor` lanes) — the throughput side of parallel dispatch is
//! gated by `benches/parallel_dispatch.rs`.

mod common;

use std::collections::HashMap;
use std::time::Duration;

use common::seeded_request;
use netfuse::coordinator::mock::EchoExecutor;
use netfuse::coordinator::multi::{GroupSpec, LaneSpec, MultiServer, ParallelDispatcher};
use netfuse::coordinator::server::ServerConfig;
use netfuse::coordinator::StrategyKind;
use netfuse::ingress::{
    run_dispatch, run_dispatch_parallel, Envelope, Frame, FrameQueue, IngressBridge, LaneQos,
    RejectCode,
};
use netfuse::util::rng::Rng;

const FAR: Duration = Duration::from_secs(3600);

fn lane_config() -> ServerConfig {
    ServerConfig {
        strategy: StrategyKind::NetFuse,
        queue_cap: 4096,
        max_wait: Duration::ZERO,
    }
}

/// The standard 5-lane topology: group {0,1} (bert), standalone 2
/// (solo), group {3,4} (gpt). Executors are built by the caller so the
/// dispatcher's borrows have something to point at.
struct Execs {
    lanes: Vec<EchoExecutor>,
    groups: Vec<EchoExecutor>,
}

fn lane_exec(name: &str, m: usize, cost: Duration) -> EchoExecutor {
    common::echo(name, m, cost)
}

fn build_execs(m: usize, cost: Duration) -> Execs {
    Execs {
        lanes: vec![
            lane_exec("bert", m, cost),
            lane_exec("bert", m, cost),
            lane_exec("solo", m, cost),
            lane_exec("gpt", m, cost),
            lane_exec("gpt", m, cost),
        ],
        groups: vec![lane_exec("bert", 2 * m, cost), lane_exec("gpt", 2 * m, cost)],
    }
}

fn build_dispatcher<'f>(e: &'f Execs) -> ParallelDispatcher<'f, EchoExecutor> {
    let lanes = e
        .lanes
        .iter()
        .map(|x| LaneSpec::new(x, lane_config(), LaneQos::new(1, FAR)))
        .collect();
    let groups = vec![
        GroupSpec::new(&e.groups[0], &[0, 1]),
        GroupSpec::new(&e.groups[1], &[3, 4]),
    ];
    ParallelDispatcher::new(lanes, groups).unwrap()
}

/// The equivalent single-thread `MultiServer` (the sequential oracle).
fn build_single<'f>(e: &'f Execs) -> MultiServer<'f, EchoExecutor> {
    let mut multi = MultiServer::new();
    for x in &e.lanes {
        multi.add_lane_qos(x, lane_config(), LaneQos::new(1, FAR));
    }
    multi.add_coalesce_group(&e.groups[0], &[0, 1]).unwrap();
    multi.add_coalesce_group(&e.groups[1], &[3, 4]).unwrap();
    multi
}

#[test]
fn partitions_lanes_into_groups_then_standalones() {
    let e = build_execs(2, Duration::ZERO);
    let d = build_dispatcher(&e);
    assert_eq!(d.parts(), 3, "two groups + one standalone lane");
    assert_eq!(d.lanes(), 5);

    let topo = d.topology();
    assert_eq!(topo.parts(), 3);
    // group partitions first (in registration order), standalone after
    assert_eq!(topo.part_lanes(0), &[0, 1]);
    assert_eq!(topo.part_lanes(1), &[3, 4]);
    assert_eq!(topo.part_lanes(2), &[2]);
    // locate/global are inverses over every lane
    for lane in 0..5 {
        let (p, local) = topo.locate(lane).unwrap();
        assert_eq!(topo.global(p, local), lane);
    }
    assert!(topo.locate(5).is_none());

    // each group partition carries its coalesce group
    assert_eq!(d.part(0).coalesce_groups(), 1);
    assert_eq!(d.part(1).coalesce_groups(), 1);
    assert_eq!(d.part(2).coalesce_groups(), 0);
    assert_eq!(d.part(0).lanes(), 2);
    assert_eq!(d.part(2).lanes(), 1);
}

#[test]
fn rejects_bad_partitions() {
    let e = build_execs(2, Duration::ZERO);
    let lanes = || -> Vec<LaneSpec<'_, EchoExecutor>> {
        e.lanes
            .iter()
            .map(|x| LaneSpec::new(x, lane_config(), LaneQos::new(1, FAR)))
            .collect()
    };
    // out-of-range member
    let err = ParallelDispatcher::new(lanes(), vec![GroupSpec::new(&e.groups[0], &[0, 9])])
        .unwrap_err();
    assert!(err.to_string().contains("no lane 9"), "got: {err}");
    // a lane in two groups
    let err = ParallelDispatcher::new(
        lanes(),
        vec![
            GroupSpec::new(&e.groups[0], &[0, 1]),
            GroupSpec::new(&e.groups[1], &[1, 3]),
        ],
    )
    .unwrap_err();
    assert!(err.to_string().contains("more than one"), "got: {err}");
    // coalesce-key mismatch surfaces from group validation
    let err = ParallelDispatcher::new(lanes(), vec![GroupSpec::new(&e.groups[0], &[0, 3])])
        .unwrap_err();
    assert!(err.to_string().contains("cannot coalesce"), "got: {err}");
    // no lanes at all
    assert!(ParallelDispatcher::<EchoExecutor>::new(Vec::new(), Vec::new()).is_err());
}

#[test]
fn closed_loop_offer_routes_globally_and_drains() {
    let e = build_execs(2, Duration::ZERO);
    let mut d = build_dispatcher(&e);
    // one request per (lane, model)
    let mut id = 0u64;
    for lane in 0..5 {
        for model in 0..2 {
            d.offer(lane, seeded_request(id, model, &[4])).unwrap();
            id += 1;
        }
    }
    assert_eq!(d.pending(), 10);
    assert!(d.offer(7, seeded_request(99, 0, &[4])).is_err());
    let mut buf = Vec::new();
    assert_eq!(d.drain(&mut buf).unwrap(), 10);
    assert_eq!(d.pending(), 0);
    // the group partitions flushed their members as merged rounds
    assert_eq!(d.part(0).group_stats(0).rounds, 1);
    assert_eq!(d.part(1).group_stats(0).rounds, 1);
}

/// Run `arrivals` through the full ingress path and collect per-
/// `(lane, model)` FIFO response streams plus the stats. `parallel`
/// selects run_dispatch_parallel vs the single-thread loop.
type ModelStreams = HashMap<(usize, u32), Vec<(u64, Vec<f32>)>>;

fn serve(
    e: &Execs,
    arrivals: &[(usize, usize, u64)],
    parallel: bool,
) -> (ModelStreams, netfuse::ingress::IngressStats) {
    let bridge = IngressBridge::new(arrivals.len().max(1));
    let replies: Vec<FrameQueue> = (0..e.lanes.len()).map(|_| FrameQueue::new()).collect();
    // submit everything up front, then close: both serving paths see
    // the identical arrival sequence
    for &(lane, model, id) in arrivals {
        let env = Envelope {
            lane,
            client_id: id,
            req: seeded_request(id, model, &[4]),
            reply: replies[lane].clone(),
        };
        assert!(bridge.submit(env).is_ok(), "bridge sized for all arrivals");
    }
    bridge.close();

    let stats = if parallel {
        let mut d = build_dispatcher(e);
        run_dispatch_parallel(&mut d, &bridge, arrivals.len().max(1)).unwrap()
    } else {
        let mut multi = build_single(e);
        run_dispatch(&mut multi, &bridge).unwrap()
    };

    let mut streams: ModelStreams = HashMap::new();
    for (lane, q) in replies.iter().enumerate() {
        q.close();
        while let Some(f) = q.try_pop() {
            match f {
                Frame::Response { id, lane: wire_lane, model_idx, data, .. } => {
                    assert_eq!(wire_lane as usize, lane, "response quotes the wrong lane");
                    streams.entry((lane, model_idx)).or_default().push((id, data));
                }
                other => panic!("unexpected frame on lane {lane}: {other:?}"),
            }
        }
    }
    (streams, stats)
}

#[test]
fn parallel_routing_matches_the_single_thread_dispatcher() {
    // the sequential-oracle parity check: same seeded arrivals through
    // run_dispatch (one thread) and run_dispatch_parallel (router + 3
    // dispatch threads) must yield byte-identical per-(lane, model)
    // FIFO response streams — no misrouting, reordering, or corruption
    // across partition boundaries
    let e = build_execs(2, Duration::ZERO);
    let mut rng = Rng::new(0x9A11E1);
    let arrivals: Vec<(usize, usize, u64)> = (0..600)
        .map(|id| (rng.usize_below(5), rng.usize_below(2), id as u64))
        .collect();

    let (want, seq_stats) = serve(&e, &arrivals, false);
    let (got, par_stats) = serve(&e, &arrivals, true);

    assert_eq!(seq_stats.responses, arrivals.len() as u64);
    assert_eq!(par_stats.responses, arrivals.len() as u64);
    assert_eq!(par_stats.admitted, arrivals.len() as u64);
    assert_eq!(par_stats.no_lane + par_stats.lane_busy + par_stats.group_busy, 0);

    assert_eq!(want.len(), got.len(), "stream key sets diverged");
    for (key, w) in &want {
        let g = got.get(key).unwrap_or_else(|| panic!("missing stream {key:?}"));
        assert_eq!(w, g, "stream {key:?} diverged between sequential and parallel");
    }
    // the grouped partitions actually coalesced while running parallel
    assert!(par_stats.coalesced_rounds > 0, "parallel run never merged a round");
}

#[test]
fn router_answers_unknown_lanes_and_full_groups_in_band() {
    let e = build_execs(2, Duration::from_millis(2));
    let total = 40usize;
    let bridge = IngressBridge::new(total + 1);
    let reply = FrameQueue::new();
    // one envelope to a lane that does not exist...
    assert!(bridge
        .submit(Envelope {
            lane: 9,
            client_id: 1_000_000,
            req: seeded_request(1_000_000, 0, &[4]),
            reply: reply.clone(),
        })
        .is_ok());
    // ...and a burst at one slow partition, with a sub-bridge of
    // capacity 1 so the router must shed load
    for id in 0..total as u64 {
        assert!(bridge
            .submit(Envelope {
                lane: 2,
                client_id: id,
                req: seeded_request(id, 0, &[4]),
                reply: reply.clone(),
            })
            .is_ok());
    }
    bridge.close();
    let mut d = build_dispatcher(&e);
    let stats = run_dispatch_parallel(&mut d, &bridge, 1).unwrap();

    reply.close();
    let (mut responses, mut busy, mut no_lane) = (0u64, 0u64, 0u64);
    while let Some(f) = reply.try_pop() {
        match f {
            Frame::Response { .. } => responses += 1,
            Frame::Reject { code: RejectCode::Busy, .. } => busy += 1,
            Frame::Reject { code: RejectCode::NoLane, id, .. } => {
                assert_eq!(id, 1_000_000);
                no_lane += 1;
            }
            other => panic!("unexpected frame: {other:?}"),
        }
    }
    assert_eq!(no_lane, 1, "unknown lane must get exactly one NoLane frame");
    assert_eq!(
        responses + busy,
        total as u64,
        "every arrival needs exactly one outcome frame (got {responses} + {busy})"
    );
    assert_eq!(stats.no_lane, 1);
    assert_eq!(stats.group_busy, busy);
    assert_eq!(stats.responses, responses);
}
