//! Coordinator integration tests over real artifacts: strategy
//! equivalence, the serving loop (routing, padding, backpressure) and
//! failure handling.

use std::path::Path;

use netfuse::coordinator::server::{Admit, Server, ServerConfig};
use netfuse::coordinator::workload::Workload;
use netfuse::coordinator::{Fleet, Request, StrategyKind};
use netfuse::runtime::Runtime;
use netfuse::tensor::Tensor;
use netfuse::util::rng::Rng;

fn artifacts_dir() -> &'static Path {
    Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
}

fn skip() -> bool {
    if artifacts_dir().join("manifest.json").exists() {
        false
    } else {
        eprintln!("skipping: artifacts/ not built");
        true
    }
}

#[test]
fn all_strategies_agree_on_outputs() {
    if skip() {
        return;
    }
    let rt = Runtime::open(artifacts_dir()).unwrap();
    for model in ["resnet", "bert"] {
        let fleet = Fleet::load(&rt, model, 4, 1).unwrap();
        let mut rng = Rng::new(3);
        let xs: Vec<Tensor> = (0..4)
            .map(|_| Tensor::randn(&fleet.request_shape(), &mut rng))
            .collect();
        let refs: Vec<&Tensor> = xs.iter().collect();
        let want = fleet.run_round(StrategyKind::Sequential, &refs).unwrap();
        for s in [
            StrategyKind::Concurrent,
            StrategyKind::Hybrid { procs: 2 },
            StrategyKind::NetFuse,
        ] {
            let got = fleet.run_round(s, &refs).unwrap();
            for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                assert!(
                    a.allclose(b, 1e-3, 1e-4),
                    "{model}/{s}: instance {i} diverges (max {:?})",
                    a.max_abs_diff(b)
                );
            }
        }
    }
}

#[test]
fn unpack_views_alias_the_merged_output() {
    if skip() {
        return;
    }
    // the zero-copy unpack path: views into the merged output are
    // element-identical to the owned per-instance outputs and alias the
    // merged buffer instead of copying it
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let fleet = Fleet::load(&rt, "bert", 2, 1).unwrap();
    let mut rng = Rng::new(21);
    let xs: Vec<Tensor> = (0..2)
        .map(|_| Tensor::randn(&fleet.request_shape(), &mut rng))
        .collect();
    let refs: Vec<&Tensor> = xs.iter().collect();
    let outs = fleet.run_round(StrategyKind::NetFuse, &refs).unwrap();
    let y = Tensor::stack(&outs.iter().collect::<Vec<_>>()).unwrap();
    let views = fleet.unpack(&y).unwrap();
    assert_eq!(views.len(), 2);
    for (i, v) in views.iter().enumerate() {
        assert!(v.allclose(&outs[i].view(), 0.0, 0.0), "view {i} differs");
        // borrowed, not copied
        assert_eq!(v.data().as_ptr(), y.view0(i).unwrap().data().as_ptr());
    }
}

#[test]
fn fused_outputs_differ_across_instances() {
    if skip() {
        return;
    }
    // different weights => the same input must produce different outputs
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let fleet = Fleet::load(&rt, "bert", 2, 1).unwrap();
    let mut rng = Rng::new(4);
    let x = Tensor::randn(&fleet.request_shape(), &mut rng);
    let outs = fleet
        .run_round(StrategyKind::NetFuse, &[&x, &x])
        .unwrap();
    let diff = outs[0].max_abs_diff(&outs[1]).unwrap();
    assert!(diff > 1e-3, "instances look identical (diff {diff})");
}

#[test]
fn server_serves_full_rounds() {
    if skip() {
        return;
    }
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let fleet = Fleet::load(&rt, "bert", 4, 1).unwrap();
    let mut server = Server::new(
        &fleet,
        ServerConfig { strategy: StrategyKind::NetFuse, ..Default::default() },
    );
    let mut wl = Workload::new(4, &fleet.request_shape(), 100.0, 11);
    let served = server.run_rounds(10, || wl.round()).unwrap();
    assert_eq!(served, 40);
    assert_eq!(server.metrics.completed_requests, 40);
    assert!(server.metrics.round_latency.count() >= 10);
    assert!(server.metrics.request_latency.p99() > 0.0);
}

#[test]
fn server_pads_partial_rounds() {
    if skip() {
        return;
    }
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let fleet = Fleet::load(&rt, "bert", 4, 1).unwrap();
    let mut server = Server::new(
        &fleet,
        ServerConfig {
            strategy: StrategyKind::NetFuse,
            max_wait: std::time::Duration::from_millis(0),
            ..Default::default()
        },
    );
    // only models 1 and 3 have work
    let mut rng = Rng::new(5);
    for idx in [1usize, 3] {
        let x = Tensor::randn(&fleet.request_shape(), &mut rng);
        assert_eq!(server.offer(Request::new(idx as u64, idx, x)), Admit::Queued);
    }
    assert!(server.round_ready());
    let responses = server.dispatch().unwrap();
    // padded slots produce no responses
    assert_eq!(responses.len(), 2);
    let mut idxs: Vec<usize> = responses.iter().map(|r| r.model_idx).collect();
    idxs.sort();
    assert_eq!(idxs, vec![1, 3]);
    assert_eq!(server.pending(), 0);
}

#[test]
fn server_applies_backpressure() {
    if skip() {
        return;
    }
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let fleet = Fleet::load(&rt, "bert", 2, 1).unwrap();
    let mut server = Server::new(
        &fleet,
        ServerConfig {
            strategy: StrategyKind::Sequential,
            queue_cap: 2,
            ..Default::default()
        },
    );
    let mut rng = Rng::new(6);
    let mk = |rng: &mut Rng, id: u64| {
        Request::new(id, 0, Tensor::randn(&fleet.request_shape(), rng))
    };
    assert_eq!(server.offer(mk(&mut rng, 0)), Admit::Queued);
    assert_eq!(server.offer(mk(&mut rng, 1)), Admit::Queued);
    assert_eq!(server.offer(mk(&mut rng, 2)), Admit::Rejected);
}

#[test]
fn server_rejects_malformed_payloads_at_ingress() {
    if skip() {
        return;
    }
    // wrong-shaped payloads fail alone at offer() instead of poisoning
    // a whole round at dispatch time
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let fleet = Fleet::load(&rt, "bert", 2, 1).unwrap();
    let mut server = Server::new(&fleet, ServerConfig::default());
    let bad = Request::new(0, 0, Tensor::zeros(&[1, 2, 3]));
    assert_eq!(server.offer(bad), Admit::Invalid);
    let bad_idx = Request::new(1, 7, Tensor::zeros(&fleet.request_shape()));
    assert_eq!(server.offer(bad_idx), Admit::Invalid);
    assert_eq!(server.pending(), 0);
}

#[test]
fn fleet_rejects_too_many_instances() {
    if skip() {
        return;
    }
    let rt = Runtime::open(artifacts_dir()).unwrap();
    assert!(Fleet::load(&rt, "bert", 1000, 1).is_err());
}

#[test]
fn fleet_rejects_wrong_round_size() {
    if skip() {
        return;
    }
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let fleet = Fleet::load(&rt, "bert", 2, 1).unwrap();
    let mut rng = Rng::new(7);
    let x = Tensor::randn(&fleet.request_shape(), &mut rng);
    assert!(fleet.run_round(StrategyKind::NetFuse, &[&x]).is_err());
}

#[test]
fn bound_rejects_wrong_input_shape() {
    if skip() {
        return;
    }
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let fleet = Fleet::load(&rt, "bert", 2, 1).unwrap();
    let bad = Tensor::zeros(&[1, 2, 3]);
    assert!(fleet.single(0).run(&bad).is_err());
}

#[test]
fn hybrid_procs_variants_all_work() {
    if skip() {
        return;
    }
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let fleet = Fleet::load(&rt, "resnet", 4, 1).unwrap();
    let mut rng = Rng::new(8);
    let xs: Vec<Tensor> = (0..4)
        .map(|_| Tensor::randn(&fleet.request_shape(), &mut rng))
        .collect();
    let refs: Vec<&Tensor> = xs.iter().collect();
    let want = fleet.run_round(StrategyKind::Sequential, &refs).unwrap();
    for procs in [1usize, 2, 3, 4, 9] {
        let got = fleet
            .run_round(StrategyKind::Hybrid { procs }, &refs)
            .unwrap();
        for (a, b) in want.iter().zip(&got) {
            assert!(a.allclose(b, 1e-3, 1e-4), "hybrid:{procs} diverges");
        }
    }
}

#[test]
fn pallas_and_xla_backends_agree() {
    if skip() {
        return;
    }
    // the same fleet through the Pallas-kernel HLO and the plain-XLA HLO
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let a = Fleet::load(&rt, "bert", 4, 1).unwrap();
    let b = Fleet::load_with(&rt, "bert", 4, 1, "_pallas").unwrap();
    let mut rng = Rng::new(9);
    let xs: Vec<Tensor> = (0..4)
        .map(|_| Tensor::randn(&a.request_shape(), &mut rng))
        .collect();
    let refs: Vec<&Tensor> = xs.iter().collect();
    let ya = a.run_round(StrategyKind::NetFuse, &refs).unwrap();
    let yb = b.run_round(StrategyKind::NetFuse, &refs).unwrap();
    for (u, v) in ya.iter().zip(&yb) {
        assert!(u.allclose(v, 1e-3, 1e-3), "backends disagree");
    }
}
