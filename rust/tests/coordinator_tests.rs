//! Coordinator integration tests: strategy equivalence, the serving
//! loop (routing, padding, backpressure), failure handling, and
//! multi-fleet serving.
//!
//! Tests over real artifacts skip when `artifacts/` is absent; the
//! batching/requeue/scheduling tests run everywhere by substituting
//! [`MockFleet`], an artifact-free `RoundExecutor`. Shared scaffolding
//! (payload builders, drain-and-sort helpers) lives in `common/`.

mod common;

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use netfuse::coordinator::multi::MultiServer;
use netfuse::coordinator::pool::WorkerPool;
use netfuse::coordinator::server::{Admit, Server, ServerConfig};
use netfuse::coordinator::service::RoundExecutor;
use netfuse::coordinator::workload::Workload;
use netfuse::coordinator::{Fleet, Request, StrategyKind};
use netfuse::runtime::Runtime;
use netfuse::tensor::Tensor;
use netfuse::util::rng::Rng;

fn artifacts_dir() -> &'static Path {
    Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
}

fn skip() -> bool {
    if artifacts_dir().join("manifest.json").exists() {
        false
    } else {
        eprintln!("skipping: artifacts/ not built");
        true
    }
}

#[test]
fn all_strategies_agree_on_outputs() {
    if skip() {
        return;
    }
    let rt = Runtime::open(artifacts_dir()).unwrap();
    for model in ["resnet", "bert"] {
        let fleet = Fleet::load(&rt, model, 4, 1).unwrap();
        let mut rng = Rng::new(3);
        let xs: Vec<Tensor> = (0..4)
            .map(|_| Tensor::randn(&fleet.request_shape(), &mut rng))
            .collect();
        let refs: Vec<&Tensor> = xs.iter().collect();
        let want = fleet.run_round(StrategyKind::Sequential, &refs).unwrap();
        for s in [
            StrategyKind::Concurrent,
            StrategyKind::Hybrid { procs: 2 },
            StrategyKind::NetFuse,
        ] {
            let got = fleet.run_round(s, &refs).unwrap();
            for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                assert!(
                    a.allclose(b, 1e-3, 1e-4),
                    "{model}/{s}: instance {i} diverges (max {:?})",
                    a.max_abs_diff(b)
                );
            }
        }
    }
}

#[test]
fn unpack_views_alias_the_merged_output() {
    if skip() {
        return;
    }
    // the zero-copy unpack path: views into the merged output are
    // element-identical to the owned per-instance outputs and alias the
    // merged buffer instead of copying it
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let fleet = Fleet::load(&rt, "bert", 2, 1).unwrap();
    let mut rng = Rng::new(21);
    let xs: Vec<Tensor> = (0..2)
        .map(|_| Tensor::randn(&fleet.request_shape(), &mut rng))
        .collect();
    let refs: Vec<&Tensor> = xs.iter().collect();
    let outs = fleet.run_round(StrategyKind::NetFuse, &refs).unwrap();
    let y = Tensor::stack(&outs.iter().collect::<Vec<_>>()).unwrap();
    let views = fleet.unpack(&y).unwrap();
    assert_eq!(views.len(), 2);
    for (i, v) in views.iter().enumerate() {
        assert!(v.allclose(&outs[i].view(), 0.0, 0.0), "view {i} differs");
        // borrowed, not copied
        assert_eq!(v.data().as_ptr(), y.view0(i).unwrap().data().as_ptr());
    }
}

#[test]
fn fused_outputs_differ_across_instances() {
    if skip() {
        return;
    }
    // different weights => the same input must produce different outputs
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let fleet = Fleet::load(&rt, "bert", 2, 1).unwrap();
    let mut rng = Rng::new(4);
    let x = Tensor::randn(&fleet.request_shape(), &mut rng);
    let outs = fleet
        .run_round(StrategyKind::NetFuse, &[&x, &x])
        .unwrap();
    let diff = outs[0].max_abs_diff(&outs[1]).unwrap();
    assert!(diff > 1e-3, "instances look identical (diff {diff})");
}

#[test]
fn server_serves_full_rounds() {
    if skip() {
        return;
    }
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let fleet = Fleet::load(&rt, "bert", 4, 1).unwrap();
    let mut server = Server::new(
        &fleet,
        ServerConfig { strategy: StrategyKind::NetFuse, ..Default::default() },
    );
    let mut wl = Workload::new(4, &fleet.request_shape(), 100.0, 11);
    let served = server.run_rounds(10, || wl.round()).unwrap();
    assert_eq!(served, 40);
    assert_eq!(server.metrics.completed_requests, 40);
    assert!(server.metrics.round_latency.count() >= 10);
    assert!(server.metrics.request_latency.p99() > 0.0);
}

#[test]
fn server_pads_partial_rounds() {
    if skip() {
        return;
    }
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let fleet = Fleet::load(&rt, "bert", 4, 1).unwrap();
    let mut server = Server::new(
        &fleet,
        ServerConfig {
            strategy: StrategyKind::NetFuse,
            max_wait: std::time::Duration::from_millis(0),
            ..Default::default()
        },
    );
    // only models 1 and 3 have work
    let mut rng = Rng::new(5);
    for idx in [1usize, 3] {
        let x = Tensor::randn(&fleet.request_shape(), &mut rng);
        assert_eq!(server.offer(Request::new(idx as u64, idx, x)), Admit::Queued);
    }
    assert!(server.round_ready());
    let responses = server.dispatch().unwrap();
    // padded slots produce no responses
    assert_eq!(responses.len(), 2);
    let mut idxs: Vec<usize> = responses.iter().map(|r| r.model_idx).collect();
    idxs.sort();
    assert_eq!(idxs, vec![1, 3]);
    assert_eq!(server.pending(), 0);
}

#[test]
fn server_applies_backpressure() {
    if skip() {
        return;
    }
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let fleet = Fleet::load(&rt, "bert", 2, 1).unwrap();
    let mut server = Server::new(
        &fleet,
        ServerConfig {
            strategy: StrategyKind::Sequential,
            queue_cap: 2,
            ..Default::default()
        },
    );
    let mut rng = Rng::new(6);
    let mk = |rng: &mut Rng, id: u64| {
        Request::new(id, 0, Tensor::randn(&fleet.request_shape(), rng))
    };
    assert_eq!(server.offer(mk(&mut rng, 0)), Admit::Queued);
    assert_eq!(server.offer(mk(&mut rng, 1)), Admit::Queued);
    assert_eq!(server.offer(mk(&mut rng, 2)), Admit::Rejected);
}

#[test]
fn server_rejects_malformed_payloads_at_ingress() {
    if skip() {
        return;
    }
    // wrong-shaped payloads fail alone at offer() instead of poisoning
    // a whole round at dispatch time
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let fleet = Fleet::load(&rt, "bert", 2, 1).unwrap();
    let mut server = Server::new(&fleet, ServerConfig::default());
    let bad = Request::new(0, 0, Tensor::zeros(&[1, 2, 3]));
    assert_eq!(server.offer(bad), Admit::Invalid);
    let bad_idx = Request::new(1, 7, Tensor::zeros(&fleet.request_shape()));
    assert_eq!(server.offer(bad_idx), Admit::Invalid);
    assert_eq!(server.pending(), 0);
}

#[test]
fn fleet_rejects_too_many_instances() {
    if skip() {
        return;
    }
    let rt = Runtime::open(artifacts_dir()).unwrap();
    assert!(Fleet::load(&rt, "bert", 1000, 1).is_err());
}

#[test]
fn fleet_rejects_wrong_round_size() {
    if skip() {
        return;
    }
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let fleet = Fleet::load(&rt, "bert", 2, 1).unwrap();
    let mut rng = Rng::new(7);
    let x = Tensor::randn(&fleet.request_shape(), &mut rng);
    assert!(fleet.run_round(StrategyKind::NetFuse, &[&x]).is_err());
}

#[test]
fn bound_rejects_wrong_input_shape() {
    if skip() {
        return;
    }
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let fleet = Fleet::load(&rt, "bert", 2, 1).unwrap();
    let bad = Tensor::zeros(&[1, 2, 3]);
    assert!(fleet.single(0).run(&bad).is_err());
}

#[test]
fn hybrid_procs_variants_all_work() {
    if skip() {
        return;
    }
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let fleet = Fleet::load(&rt, "resnet", 4, 1).unwrap();
    let mut rng = Rng::new(8);
    let xs: Vec<Tensor> = (0..4)
        .map(|_| Tensor::randn(&fleet.request_shape(), &mut rng))
        .collect();
    let refs: Vec<&Tensor> = xs.iter().collect();
    let want = fleet.run_round(StrategyKind::Sequential, &refs).unwrap();
    for procs in [1usize, 2, 3, 4, 9] {
        let got = fleet
            .run_round(StrategyKind::Hybrid { procs }, &refs)
            .unwrap();
        for (a, b) in want.iter().zip(&got) {
            assert!(a.allclose(b, 1e-3, 1e-4), "hybrid:{procs} diverges");
        }
    }
}

// ---------------------------------------------------------------------------
// artifact-free serving-loop tests over a mock RoundExecutor
// ---------------------------------------------------------------------------

/// Artifact-free executor: echoes each occupied slot's payload back as
/// its output, dispatching Concurrent/Hybrid chunks on a (possibly
/// shared) [`WorkerPool`] exactly like `Fleet::run_chunked` does.
struct MockFleet {
    name: String,
    m: usize,
    input_shape: Vec<usize>,
    pool: Arc<WorkerPool>,
    /// fail the next N rounds (failure-path tests)
    fail_rounds: AtomicUsize,
}

impl MockFleet {
    fn new(name: &str, m: usize, pool: Arc<WorkerPool>) -> MockFleet {
        MockFleet {
            name: name.to_string(),
            m,
            input_shape: vec![4],
            pool,
            fail_rounds: AtomicUsize::new(0),
        }
    }
}

impl RoundExecutor for MockFleet {
    fn name(&self) -> &str {
        &self.name
    }
    fn m(&self) -> usize {
        self.m
    }
    fn bs(&self) -> usize {
        1
    }
    fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }
    fn run_round_slots<'a>(
        &self,
        strategy: StrategyKind,
        get: &(dyn Fn(usize) -> Option<&'a Tensor> + Sync),
        outs: &mut Vec<Option<Tensor>>,
    ) -> Result<()> {
        strategy.validate()?;
        if self.fail_rounds.load(Ordering::SeqCst) > 0 {
            self.fail_rounds.fetch_sub(1, Ordering::SeqCst);
            anyhow::bail!("injected round failure");
        }
        outs.clear();
        let procs = match strategy {
            StrategyKind::Concurrent => self.m,
            StrategyKind::Hybrid { procs } => procs.min(self.m),
            _ => 1,
        };
        if procs > 1 {
            self.pool.ensure_workers(procs);
            let results = self.pool.run_chunked(self.m, procs, |i| Ok(get(i).cloned()))?;
            outs.extend(results);
        } else {
            for i in 0..self.m {
                outs.push(get(i).cloned());
            }
        }
        Ok(())
    }
}

use common::{payload, sorted_ids};

#[test]
fn batching_clock_tracks_oldest_queued_request() {
    // REGRESSION (max_wait batching-clock bug): the server used to keep
    // a single `oldest_wait_start: Instant` that `dispatch_into`
    // overwrote with `Instant::now()` on every dispatch — a request
    // left queued behind a dispatched one had its wait clock silently
    // restarted each round, so under steady traffic its latency could
    // grow far past `max_wait`. The deadline must derive from the
    // oldest queued request's own `arrived` timestamp.
    let fleet = MockFleet::new("mock", 2, WorkerPool::shared(1));
    let max_wait = Duration::from_millis(40);
    let mut server = Server::new(
        &fleet,
        ServerConfig { strategy: StrategyKind::Sequential, max_wait, ..Default::default() },
    );
    // a lone request on model 0 plus steady traffic on model 1 (two
    // arrivals queued back to back)
    assert_eq!(server.offer(Request::new(0, 0, payload())), Admit::Queued);
    assert_eq!(server.offer(Request::new(1, 1, payload())), Admit::Queued);
    assert_eq!(server.offer(Request::new(2, 1, payload())), Admit::Queued);
    std::thread::sleep(max_wait + Duration::from_millis(20));

    // full round: pops the model-0 request and the FIRST model-1
    // request; request 2 stays queued and has already waited > max_wait
    assert!(server.round_ready());
    let first = server.dispatch().unwrap();
    assert_eq!(first.len(), 2);

    // the old logic reset the clock to the dispatch instant here, so
    // this returned false and request 2 waited another full max_wait
    assert!(
        server.round_ready(),
        "a request queued past max_wait must make the next round due immediately"
    );
    let second = server.dispatch().unwrap();
    assert_eq!(second.len(), 1);
    assert_eq!(second[0].id, 2);
    assert!(
        second[0].latency >= max_wait.as_secs_f64(),
        "latency accounting must reflect the real wait"
    );
}

#[test]
fn failed_round_requeues_fifo_and_next_dispatch_returns_them() {
    let fleet = MockFleet::new("mock", 2, WorkerPool::shared(2));
    let mut server = Server::new(
        &fleet,
        ServerConfig { strategy: StrategyKind::Concurrent, ..Default::default() },
    );
    for (id, model) in [(1u64, 0usize), (2, 0), (3, 1), (4, 1)] {
        assert_eq!(server.offer(Request::new(id, model, payload())), Admit::Queued);
    }
    fleet.fail_rounds.store(1, Ordering::SeqCst);
    let err = server.dispatch().unwrap_err();
    assert!(err.to_string().contains("injected round failure"), "got: {err}");
    assert_eq!(server.pending(), 4, "failed round must not drop requests");

    // FIFO restored per queue: the next successful dispatch returns the
    // ORIGINAL fronts (1 and 3), then the tails (2 and 4)
    let round1 = server.dispatch().unwrap();
    assert_eq!(sorted_ids(&round1), vec![1, 3], "requeue must restore per-queue FIFO order");
    let round2 = server.dispatch().unwrap();
    assert_eq!(sorted_ids(&round2), vec![2, 4]);
    assert_eq!(server.pending(), 0);
}

#[test]
fn hybrid_zero_procs_fails_loudly_and_keeps_requests() {
    // Hybrid { procs: 0 } can be built directly, bypassing
    // StrategyKind::parse — it must fail at dispatch with a clear
    // error instead of being silently clamped, and must not eat the
    // round's requests
    let fleet = MockFleet::new("mock", 2, WorkerPool::shared(1));
    let mut server = Server::new(
        &fleet,
        ServerConfig { strategy: StrategyKind::Hybrid { procs: 0 }, ..Default::default() },
    );
    assert_eq!(server.offer(Request::new(0, 0, payload())), Admit::Queued);
    assert_eq!(server.offer(Request::new(1, 1, payload())), Admit::Queued);
    let err = server.dispatch().unwrap_err();
    assert!(err.to_string().contains(">= 1 proc"), "got: {err}");
    assert_eq!(server.pending(), 2, "misconfigured strategy must not drop requests");
}

#[test]
fn multi_server_shares_one_worker_pool_across_fleets() {
    let pool = WorkerPool::shared(1);
    let wide = MockFleet::new("fleet-wide", 4, pool.clone());
    let narrow = MockFleet::new("fleet-narrow", 3, pool.clone());
    let mut multi = MultiServer::new();
    let a = multi.add_lane(
        &wide,
        ServerConfig { strategy: StrategyKind::Concurrent, ..Default::default() },
    );
    let b = multi.add_lane(
        &narrow,
        ServerConfig { strategy: StrategyKind::Hybrid { procs: 2 }, ..Default::default() },
    );
    for i in 0..4 {
        assert_eq!(multi.offer(a, Request::new(i as u64, i, payload())).unwrap(), Admit::Queued);
    }
    for i in 0..3 {
        assert_eq!(
            multi.offer(b, Request::new(10 + i as u64, i, payload())).unwrap(),
            Admit::Queued
        );
    }
    let mut responses = Vec::new();
    let served = multi.drain(&mut responses).unwrap();
    assert_eq!(served, 7);
    assert!(responses.iter().all(|r| r.output.shape() == &[1, 4]));
    // ONE pool served both fleets: grown to the widest strategy's
    // parallelism (Concurrent over m=4), NOT the 4 + 2 threads a
    // pool-per-fleet design would spawn
    assert_eq!(pool.workers(), 4);
    assert_eq!(multi.lane(a).metrics.completed_requests, 4);
    assert_eq!(multi.lane(b).metrics.completed_requests, 3);
}

#[test]
fn multi_server_fair_dispatch_alternates_ready_lanes() {
    let pool = WorkerPool::shared(1);
    let f1 = MockFleet::new("fleet-a", 2, pool.clone());
    let f2 = MockFleet::new("fleet-b", 2, pool);
    let mut multi = MultiServer::new();
    let a = multi.add_lane(
        &f1,
        ServerConfig { strategy: StrategyKind::Sequential, ..Default::default() },
    );
    let b = multi.add_lane(
        &f2,
        ServerConfig { strategy: StrategyKind::Sequential, ..Default::default() },
    );
    // both lanes loaded with 3 full rounds each: both are permanently
    // "ready", so only fair scheduling decides who goes next
    let mut id = 0u64;
    for _ in 0..3 {
        for model in 0..2 {
            assert_eq!(multi.offer(a, Request::new(id, model, payload())).unwrap(), Admit::Queued);
            id += 1;
            assert_eq!(multi.offer(b, Request::new(id, model, payload())).unwrap(), Admit::Queued);
            id += 1;
        }
    }
    let mut responses = Vec::new();
    let mut order = Vec::new();
    while let Some(d) = multi.dispatch_next(&mut responses).unwrap() {
        assert_eq!(d.responses, 2);
        assert_eq!(d.lanes_served, 1, "no coalesce group registered");
        order.push(d.lane);
    }
    assert_eq!(order, vec![0, 1, 0, 1, 0, 1], "dispatch must alternate ready lanes");
    assert_eq!(multi.pending(), 0);
    assert_eq!(responses.len(), 12);
}

#[test]
fn multi_server_rejects_unknown_lane_and_bad_payloads() {
    let fleet = MockFleet::new("mock", 2, WorkerPool::shared(1));
    let mut multi = MultiServer::new();
    let lane = multi.add_lane(&fleet, ServerConfig::default());
    assert!(multi.offer(lane + 1, Request::new(0, 0, payload())).is_err());
    // per-lane ingress validation still applies
    assert_eq!(
        multi.offer(lane, Request::new(0, 0, Tensor::zeros(&[9, 9]))).unwrap(),
        Admit::Invalid
    );
    assert_eq!(multi.pending(), 0);
}

#[test]
fn multi_server_serves_two_real_fleets_on_one_shared_pool() {
    if skip() {
        return;
    }
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let pool = WorkerPool::shared(2);
    let bert = Fleet::load_with_pool(&rt, "bert", 2, 1, "", pool.clone()).unwrap();
    let resnet = Fleet::load_with_pool(&rt, "resnet", 2, 1, "", pool.clone()).unwrap();
    assert!(
        Arc::ptr_eq(bert.shared_pool().unwrap(), resnet.shared_pool().unwrap()),
        "both fleets must hold the SAME pool"
    );

    let mut multi = MultiServer::new();
    let a = multi.add_lane(
        &bert,
        ServerConfig { strategy: StrategyKind::Concurrent, ..Default::default() },
    );
    let b = multi.add_lane(
        &resnet,
        ServerConfig { strategy: StrategyKind::Hybrid { procs: 2 }, ..Default::default() },
    );
    let mut wa = Workload::new(2, &bert.request_shape(), 100.0, 31);
    let mut wb = Workload::new(2, &resnet.request_shape(), 100.0, 32);
    let mut buf = Vec::new();
    for _ in 0..5 {
        for req in wa.round() {
            assert_eq!(multi.offer(a, req).unwrap(), Admit::Queued);
        }
        for req in wb.round() {
            assert_eq!(multi.offer(b, req).unwrap(), Admit::Queued);
        }
        while multi.dispatch_next(&mut buf).unwrap().is_some() {}
    }
    multi.drain(&mut buf).unwrap();
    assert_eq!(multi.lane(a).metrics.completed_requests, 10);
    assert_eq!(multi.lane(b).metrics.completed_requests, 10);
    // one pool, sized to the widest strategy (2), not one per fleet
    assert_eq!(pool.workers(), 2);
}

#[test]
fn pallas_and_xla_backends_agree() {
    if skip() {
        return;
    }
    // the same fleet through the Pallas-kernel HLO and the plain-XLA HLO
    let rt = Runtime::open(artifacts_dir()).unwrap();
    let a = Fleet::load(&rt, "bert", 4, 1).unwrap();
    let b = Fleet::load_with(&rt, "bert", 4, 1, "_pallas").unwrap();
    let mut rng = Rng::new(9);
    let xs: Vec<Tensor> = (0..4)
        .map(|_| Tensor::randn(&a.request_shape(), &mut rng))
        .collect();
    let refs: Vec<&Tensor> = xs.iter().collect();
    let ya = a.run_round(StrategyKind::NetFuse, &refs).unwrap();
    let yb = b.run_round(StrategyKind::NetFuse, &refs).unwrap();
    for (u, v) in ya.iter().zip(&yb) {
        assert!(u.allclose(v, 1e-3, 1e-3), "backends disagree");
    }
}
