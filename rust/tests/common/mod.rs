//! Shared scaffolding for the integration-test suites
//! (`coordinator_tests`, `ingress_tests`, `coalesce_tests`): mock
//! executor wiring, seeded request builders, and drain-and-sort
//! helpers that used to be copy-pasted per suite.
//!
//! Each test binary compiles this module independently (`mod common;`),
//! so not every helper is used from every suite — hence the blanket
//! `dead_code` allow.

#![allow(dead_code)]

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use netfuse::coordinator::arena::ArenaRing;
use netfuse::coordinator::mock::EchoExecutor;
use netfuse::coordinator::multi::MultiServer;
use netfuse::coordinator::request::{Request, Response};
use netfuse::coordinator::service::RoundExecutor;
use netfuse::coordinator::StrategyKind;
use netfuse::ingress::Frame;
use netfuse::tensor::Tensor;

/// The standard mock lane: an [`EchoExecutor`] over the suite-wide
/// `[4]` input shape (bs = 1).
pub fn echo(name: &str, m: usize, round_cost: Duration) -> EchoExecutor {
    EchoExecutor::new(name, m, &[4], round_cost)
}

/// A zero payload matching [`echo`]'s request shape.
pub fn payload() -> Tensor {
    Tensor::zeros(&[1, 4])
}

/// A **seeded** request: the payload is a deterministic function of
/// `(id, model_idx)`, so two serving paths fed the same ids can be
/// diffed byte-for-byte (the coalesce oracle harness does exactly
/// that). `inner` is the per-request shape EXCLUDING the leading bs=1.
pub fn seeded_request(id: u64, model_idx: usize, inner: &[usize]) -> Request {
    let mut shape = vec![1usize];
    shape.extend_from_slice(inner);
    let n: usize = shape.iter().product();
    let data: Vec<f32> = (0..n)
        .map(|j| id as f32 * 1000.0 + model_idx as f32 * 10.0 + j as f32)
        .collect();
    Request::new(id, model_idx, Tensor::new(shape, data).unwrap())
}

/// A well-formed `Request` wire frame (ingress suites).
pub fn request_frame(id: u64, lane: u32, model_idx: u32, shape: &[usize]) -> Frame {
    let n: usize = shape.iter().product();
    Frame::Request { id, lane, model_idx, shape: shape.to_vec(), data: vec![0.0; n] }
}

/// Dispatch until nothing is due, then flush the remainder; every
/// response is appended to `buf`.
pub fn drain_all<E: RoundExecutor>(
    multi: &mut MultiServer<E>,
    buf: &mut Vec<Response>,
) -> Result<()> {
    while multi.dispatch_next(buf)?.is_some() {}
    multi.drain(buf)?;
    Ok(())
}

/// The ids of a response batch in ascending order (round/drain batches
/// interleave lanes and slots, so assertions compare sorted ids).
pub fn sorted_ids(responses: &[Response]) -> Vec<u64> {
    let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    ids.sort();
    ids
}

/// Per-lane FIFO response streams for oracle diffs: one
/// `(id, model_idx, payload bytes)` entry per response, in the order
/// the lane produced them. Two serving paths fed identical seeded
/// requests must produce identical streams, byte for byte.
pub type Streams = Vec<Vec<(u64, usize, Vec<f32>)>>;

/// Drain a response batch into per-lane streams, attributing each
/// response through the offer-time `id -> lane` map.
pub fn collect_streams(
    buf: &mut Vec<Response>,
    lane_of_id: &HashMap<u64, usize>,
    streams: &mut Streams,
) {
    for r in buf.drain(..) {
        let lane = lane_of_id[&r.id];
        streams[lane].push((r.id, r.model_idx, r.output.data().to_vec()));
    }
}

/// Keep every lane's queues topped up and record which lane each of
/// `rounds` dispatches served — the saturated-drive probe the WDRR
/// fairness suites use (only scheduling decides the order).
pub fn dispatch_saturated(
    multi: &mut MultiServer<EchoExecutor>,
    rounds: usize,
    next_id: &mut u64,
) -> Vec<usize> {
    let mut order = Vec::with_capacity(rounds);
    let mut buf = Vec::new();
    for _ in 0..rounds {
        for lane in 0..multi.lanes() {
            for model in 0..multi.lane(lane).fleet().m() {
                while multi.lane(lane).pending() < 4 {
                    multi.offer(lane, Request::new(*next_id, model, payload())).unwrap();
                    *next_id += 1;
                }
            }
        }
        let d = multi
            .dispatch_next(&mut buf)
            .unwrap()
            .expect("saturated lanes are always dispatchable");
        buf.clear();
        order.push(d.lane);
    }
    order
}

/// Echo executor that stages every round through a shared
/// [`ArenaRing`]: reserve a slot, pack the occupied payloads into its
/// megabatch, hold the reservation across the modeled device time,
/// then read each occupied window back OUT of the staged buffer as
/// the round's outputs. The shared ring makes a round's lifetime
/// *observable* (`ring.in_flight()` counts held reservations), which
/// is what the elastic-topology suite uses to prove a sibling
/// partition's in-flight round is untouched by lane churn.
pub struct RingEcho {
    name: String,
    m: usize,
    input_shape: Vec<usize>,
    ring: Arc<ArenaRing>,
    round_cost: Duration,
}

impl RingEcho {
    pub fn new(name: &str, ring: Arc<ArenaRing>, round_cost: Duration) -> RingEcho {
        RingEcho {
            name: name.to_string(),
            m: ring.m(),
            input_shape: ring.request_shape()[1..].to_vec(),
            ring,
            round_cost,
        }
    }
}

impl RoundExecutor for RingEcho {
    fn name(&self) -> &str {
        &self.name
    }
    fn m(&self) -> usize {
        self.m
    }
    fn bs(&self) -> usize {
        1
    }
    fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }
    fn run_round_slots<'a>(
        &self,
        strategy: StrategyKind,
        get: &(dyn Fn(usize) -> Option<&'a Tensor> + Sync),
        outs: &mut Vec<Option<Tensor>>,
    ) -> Result<()> {
        strategy.validate()?;
        // pack + "execute" + unpack, all under ONE ring reservation
        let mut slot = self.ring.acquire();
        slot.pack_with(get)?;
        if !self.round_cost.is_zero() {
            std::thread::sleep(self.round_cost);
        }
        let inner: usize = self.input_shape.iter().product();
        outs.clear();
        for i in 0..self.m {
            outs.push(match get(i) {
                Some(_) => {
                    let window = &slot.merged_data()[i * inner..(i + 1) * inner];
                    let mut shape = vec![1usize];
                    shape.extend_from_slice(&self.input_shape);
                    Some(Tensor::new(shape, window.to_vec())?)
                }
                None => None,
            });
        }
        Ok(())
    }
}

/// [`EchoExecutor`] with injectable round failures: the next
/// [`FailingEcho::fail_rounds`] executions bail before producing
/// outputs. Shared by the failed-round requeue tests (solo and
/// coalesced) so the failure path is exercised through the same
/// executor shape everywhere.
pub struct FailingEcho {
    inner: EchoExecutor,
    fail_next: AtomicUsize,
}

impl FailingEcho {
    pub fn new(name: &str, m: usize, input_shape: &[usize]) -> FailingEcho {
        FailingEcho {
            inner: EchoExecutor::new(name, m, input_shape, Duration::ZERO),
            fail_next: AtomicUsize::new(0),
        }
    }

    /// Make the next `n` rounds fail (each failure decrements).
    pub fn fail_rounds(&self, n: usize) {
        self.fail_next.store(n, Ordering::SeqCst);
    }
}

impl RoundExecutor for FailingEcho {
    fn name(&self) -> &str {
        self.inner.name()
    }
    fn m(&self) -> usize {
        self.inner.m()
    }
    fn bs(&self) -> usize {
        self.inner.bs()
    }
    fn input_shape(&self) -> &[usize] {
        self.inner.input_shape()
    }
    fn run_round_slots<'a>(
        &self,
        strategy: StrategyKind,
        get: &(dyn Fn(usize) -> Option<&'a Tensor> + Sync),
        outs: &mut Vec<Option<Tensor>>,
    ) -> Result<()> {
        if self.fail_next.load(Ordering::SeqCst) > 0 {
            self.fail_next.fetch_sub(1, Ordering::SeqCst);
            anyhow::bail!("injected round failure");
        }
        self.inner.run_round_slots(strategy, get, outs)
    }
}
