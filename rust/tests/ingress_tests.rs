//! Ingress subsystem integration tests: wire format over real
//! transports, the producer->dispatch bridge (backpressure + rejection
//! frames), QoS scheduling (WDRR fairness, SLO boost), and the
//! admission-boundary arrival re-stamping. Everything here is
//! artifact-free: lanes are mock `RoundExecutor`s, so the suite runs in
//! offline CI.

mod common;

use std::collections::BTreeMap;
use std::net::TcpListener;
use std::time::Duration;

use common::{dispatch_saturated, echo, payload, request_frame};
use netfuse::coordinator::multi::MultiServer;
use netfuse::coordinator::server::{Admit, Server, ServerConfig};
use netfuse::coordinator::{Request, StrategyKind};
use netfuse::ingress::{
    run_dispatch, serve_conn, ChanTransport, Envelope, Frame, FrameQueue, IngressBridge, LaneQos,
    RejectCode, TcpTransport, Transport, TransportRx, TransportTx,
};
use netfuse::prop_assert;
use netfuse::util::prop;

// ---------------------------------------------------------------------------
// transports
// ---------------------------------------------------------------------------

#[test]
fn tcp_transport_roundtrips_frames() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut t = TcpTransport::from_stream(stream).unwrap();
        while let Some(frame) = t.recv().unwrap() {
            if frame == Frame::Eos {
                break;
            }
            t.send(&frame).unwrap(); // echo
        }
    });

    let mut client = TcpTransport::connect(addr).unwrap();
    let f = request_frame(42, 1, 0, &[1, 4]);
    client.send(&f).unwrap();
    assert_eq!(client.recv().unwrap(), Some(f));
    client.send(&Frame::Eos).unwrap();
    server.join().unwrap();
}

#[test]
fn tcp_transport_split_halves_work_from_two_threads() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let t: Box<dyn Transport> = Box::new(TcpTransport::from_stream(stream).unwrap());
        let (mut tx, mut rx) = t.split().unwrap();
        let n = 16u64;
        let pump = std::thread::spawn(move || {
            for id in 0..n {
                tx.send(&request_frame(id, 0, 0, &[1])).unwrap();
            }
            // tx dropped here; the socket stays open until rx drops too
        });
        // count client frames until its in-band end-of-stream marker
        // (dropping one dup'd half of a TcpStream does NOT half-close
        // the socket, so EOF cannot signal "done sending" mid-duplex)
        let mut got = 0;
        loop {
            match rx.recv().unwrap() {
                Some(Frame::Eos) | None => break,
                Some(_f) => got += 1,
            }
        }
        pump.join().unwrap();
        got
    });

    let t: Box<dyn Transport> = Box::new(TcpTransport::connect(addr).unwrap());
    let (mut tx, mut rx) = t.split().unwrap();
    for id in 0..8u64 {
        tx.send(&request_frame(id, 0, 0, &[1])).unwrap();
    }
    tx.send(&Frame::Eos).unwrap();
    let mut received = 0;
    // the server drops its whole transport after Eos -> real EOF here
    while let Some(_f) = rx.recv().unwrap() {
        received += 1;
    }
    assert_eq!(received, 16, "client must see every server frame");
    assert_eq!(server.join().unwrap(), 8, "server must see every client frame");
}

// ---------------------------------------------------------------------------
// bridge + dispatch loop end to end (in-proc transport)
// ---------------------------------------------------------------------------

/// Satellite: `Admit::Invalid` and `Admit::Busy` must come back through
/// the bridge as typed error frames WITHOUT poisoning the connection or
/// dropping requests that were admitted.
#[test]
fn rejection_frames_do_not_poison_the_connection_or_drop_queued_requests() {
    let fleet = echo("mock", 1, Duration::from_millis(30));
    let mut multi = MultiServer::new();
    multi.add_lane(
        &fleet,
        ServerConfig { strategy: StrategyKind::Sequential, queue_cap: 1, ..Default::default() },
    );
    let bridge = IngressBridge::new(64);

    let (client, server_end) = ChanTransport::pair();
    let conn = serve_conn(bridge.clone(), Box::new(server_end)).unwrap();
    let (mut ctx, mut crx) = (Box::new(client) as Box<dyn Transport>).split().unwrap();

    let stats = std::thread::scope(|s| {
        let dispatch = s.spawn(|| run_dispatch(&mut multi, &bridge));

        // one malformed request (wrong payload shape), then a burst of
        // valid ones that overruns the queue_cap=1 lane while its 30ms
        // rounds run
        ctx.send(&request_frame(1000, 0, 0, &[9])).unwrap();
        for id in 0..5u64 {
            ctx.send(&request_frame(id, 0, 0, &[1, 4])).unwrap();
        }

        // every request gets exactly one outcome frame
        let mut outcomes: BTreeMap<u64, &'static str> = BTreeMap::new();
        while outcomes.len() < 6 {
            match crx.recv().unwrap().expect("connection must stay open") {
                Frame::Response { id, .. } => {
                    outcomes.insert(id, "ok");
                }
                Frame::Reject { id, code: RejectCode::Invalid, .. } => {
                    outcomes.insert(id, "invalid");
                }
                Frame::Reject { id, code: RejectCode::Busy, .. } => {
                    outcomes.insert(id, "busy");
                }
                f => panic!("unexpected frame {f:?}"),
            }
        }
        assert_eq!(outcomes.get(&1000), Some(&"invalid"));
        let busy = outcomes.values().filter(|v| **v == "busy").count();
        let ok = outcomes.values().filter(|v| **v == "ok").count();
        assert_eq!(busy + ok + 1, 6);
        assert!(busy >= 1, "queue_cap=1 under a burst must reject some");
        assert!(ok >= 1, "admitted requests must still be served");

        // the connection is NOT poisoned: a fresh request after the
        // storm is admitted and served normally
        ctx.send(&request_frame(99, 0, 0, &[1, 4])).unwrap();
        match crx.recv().unwrap().unwrap() {
            Frame::Response { id, .. } => assert_eq!(id, 99),
            f => panic!("post-storm request must succeed, got {f:?}"),
        }

        ctx.send(&Frame::Eos).unwrap();
        bridge.close();
        dispatch.join().unwrap().unwrap()
    });
    conn.shutdown();

    assert_eq!(stats.invalid, 1);
    assert!(stats.lane_busy >= 1);
    assert_eq!(stats.responses, stats.admitted, "no admitted request may be dropped");
    assert_eq!(stats.no_lane, 0);
}

#[test]
fn unknown_lane_is_rejected_with_no_lane_frame() {
    let fleet = echo("mock", 1, Duration::ZERO);
    let mut multi = MultiServer::new();
    multi.add_lane(
        &fleet,
        ServerConfig { strategy: StrategyKind::Sequential, ..Default::default() },
    );
    let bridge = IngressBridge::new(8);
    let reply = FrameQueue::new();
    bridge
        .submit(Envelope {
            lane: 7,
            client_id: 5,
            req: Request::new(5, 0, payload()),
            reply: reply.clone(),
        })
        .ok()
        .unwrap();
    bridge.close();
    let stats = run_dispatch(&mut multi, &bridge).unwrap();
    assert_eq!(stats.no_lane, 1);
    match reply.try_pop().unwrap() {
        Frame::Reject { id, code, .. } => {
            assert_eq!((id, code), (5, RejectCode::NoLane));
        }
        f => panic!("expected NoLane reject, got {f:?}"),
    }
}

/// Satellite (bugfix): a producer-side `arrived` stamp must not leak
/// into queue-wait math — the bridge re-stamps at admission.
#[test]
fn admission_restamps_stale_producer_arrival_clocks() {
    let fleet = echo("mock", 1, Duration::ZERO);
    let mut multi = MultiServer::new();
    multi.add_lane(
        &fleet,
        ServerConfig {
            strategy: StrategyKind::Sequential,
            max_wait: Duration::ZERO,
            ..Default::default()
        },
    );
    let bridge = IngressBridge::new(8);
    let reply = FrameQueue::new();

    // a request constructed 200ms before it reaches the server (clock
    // reuse by a producer)
    let stale = Request::new(1, 0, payload());
    std::thread::sleep(Duration::from_millis(200));
    bridge
        .submit(Envelope { lane: 0, client_id: 1, req: stale, reply: reply.clone() })
        .ok()
        .unwrap();
    bridge.close();
    run_dispatch(&mut multi, &bridge).unwrap();

    match reply.try_pop().unwrap() {
        Frame::Response { latency, .. } => {
            assert!(
                latency < 0.15,
                "latency {latency:.3}s includes producer-side age: arrival \
                 was not re-stamped at admission"
            );
        }
        f => panic!("expected a response, got {f:?}"),
    }
}

#[test]
fn server_offer_clamps_non_monotone_arrival_stamps() {
    let fleet = echo("mock", 1, Duration::ZERO);
    let mut server = Server::new(
        &fleet,
        ServerConfig { strategy: StrategyKind::Sequential, ..Default::default() },
    );
    let fresh = Request::new(1, 0, payload());
    let mut backdated = Request::new(2, 0, payload());
    backdated.arrived = fresh.arrived - Duration::from_millis(250);
    assert_eq!(server.offer(fresh), Admit::Queued);
    assert_eq!(server.offer(backdated), Admit::Queued);
    // the backdated stamp was clamped to the queue tail: the oldest
    // wait is the FIRST request's, not a fabricated 250ms history
    let wait = server.oldest_wait().unwrap();
    assert!(
        wait < Duration::from_millis(100),
        "oldest wait {wait:?} reflects a backdated arrival stamp"
    );
    let responses = server.dispatch().unwrap();
    assert_eq!(responses.len(), 1);
    let responses = server.dispatch().unwrap();
    assert!(responses[0].latency < 0.1, "clamped request must not report fake latency");

    // the clamp also covers an EMPTY queue: the floor is the server's
    // creation time, so a backdated first request cannot fake history
    let fleet2 = echo("mock2", 1, Duration::ZERO);
    let mut fresh_server = Server::new(
        &fleet2,
        ServerConfig { strategy: StrategyKind::Sequential, ..Default::default() },
    );
    let mut first = Request::new(3, 0, payload());
    first.arrived -= Duration::from_millis(250);
    assert_eq!(fresh_server.offer(first), Admit::Queued);
    let wait = fresh_server.oldest_wait().unwrap();
    assert!(
        wait < Duration::from_millis(100),
        "empty-queue backdating must clamp to the server floor, got {wait:?}"
    );
}

// ---------------------------------------------------------------------------
// QoS: WDRR fairness + SLO boost (satellite test coverage)
// ---------------------------------------------------------------------------

#[test]
fn wdrr_three_to_one_ratio_converges() {
    let a = echo("heavy", 2, Duration::ZERO);
    let b = echo("light", 2, Duration::ZERO);
    let mut multi = MultiServer::new();
    let cfg = ServerConfig {
        strategy: StrategyKind::Sequential,
        max_wait: Duration::ZERO,
        ..Default::default()
    };
    multi.add_lane_qos(&a, cfg.clone(), LaneQos::new(3, Duration::from_secs(3600)));
    multi.add_lane_qos(&b, cfg, LaneQos::new(1, Duration::from_secs(3600)));
    let mut id = 0;
    let order = dispatch_saturated(&mut multi, 400, &mut id);
    let heavy = order.iter().filter(|&&l| l == 0).count();
    let light = order.len() - heavy;
    let ratio = heavy as f64 / light as f64;
    assert!(
        (2.5..=3.5).contains(&ratio),
        "weights 3:1 must dispatch ~3:1 rounds, got {heavy}:{light} ({ratio:.2})"
    );
}

#[test]
fn fairness_property_no_lane_starves_and_shares_track_weights() {
    prop::check(
        "wdrr-shares-track-weights",
        12,
        |rng, _size| (1 + rng.below(4) as u32, 1 + rng.below(4) as u32),
        |&(wa, wb)| {
            let a = echo("a", 2, Duration::ZERO);
            let b = echo("b", 2, Duration::ZERO);
            let mut multi = MultiServer::new();
            let cfg = ServerConfig {
                strategy: StrategyKind::Sequential,
                max_wait: Duration::ZERO,
                ..Default::default()
            };
            multi.add_lane_qos(&a, cfg.clone(), LaneQos::new(wa, Duration::from_secs(3600)));
            multi.add_lane_qos(&b, cfg, LaneQos::new(wb, Duration::from_secs(3600)));
            let rounds = 40 * (wa + wb) as usize;
            let mut id = 0;
            let order = dispatch_saturated(&mut multi, rounds, &mut id);
            let na = order.iter().filter(|&&l| l == 0).count();
            let nb = order.len() - na;
            prop_assert!(na > 0 && nb > 0, "weights {wa}:{wb}: a lane starved ({na}:{nb})");
            let share = na as f64 / order.len() as f64;
            let want = wa as f64 / (wa + wb) as f64;
            prop_assert!(
                (share - want).abs() < 0.1,
                "weights {wa}:{wb}: share {share:.3} should be ~{want:.3}"
            );
            Ok(())
        },
    );
}

#[test]
fn equal_weights_serve_sparse_lane_promptly() {
    // weights {1,1}: a lane with a single request next to a saturated
    // lane is served within two dispatches — no starvation
    let a = echo("busy", 2, Duration::ZERO);
    let b = echo("sparse", 2, Duration::ZERO);
    let mut multi = MultiServer::new();
    let cfg = ServerConfig {
        strategy: StrategyKind::Sequential,
        max_wait: Duration::ZERO,
        ..Default::default()
    };
    multi.add_lane(&a, cfg.clone());
    multi.add_lane(&b, cfg);
    let mut id = 0u64;
    let mut buf = Vec::new();
    for model in 0..2 {
        for _ in 0..4 {
            multi.offer(0, Request::new(id, model, payload())).unwrap();
            id += 1;
        }
    }
    multi.offer(1, Request::new(id, 0, payload())).unwrap();
    let first = multi.dispatch_next(&mut buf).unwrap().unwrap().lane;
    buf.clear();
    let second = multi.dispatch_next(&mut buf).unwrap().unwrap().lane;
    assert!(
        first == 1 || second == 1,
        "sparse lane must be served within two dispatches (got {first}, {second})"
    );
}

#[test]
fn slo_boost_dispatches_padded_round_before_deadline() {
    let bulk = echo("bulk", 2, Duration::ZERO);
    let tight = echo("tight", 2, Duration::ZERO);
    let mut multi = MultiServer::new();
    // bulk: huge weight, no SLO pressure. tight: partial rounds never
    // batching-ready (max_wait 1s), 50ms SLO.
    let cfg = ServerConfig {
        strategy: StrategyKind::Sequential,
        max_wait: Duration::from_secs(1),
        ..Default::default()
    };
    let slow_lane = multi.add_lane_qos(
        &bulk,
        ServerConfig { max_wait: Duration::ZERO, ..cfg.clone() },
        LaneQos::new(8, Duration::from_secs(3600)),
    );
    let tight_lane = multi.add_lane_qos(&tight, cfg, LaneQos::new(1, Duration::from_millis(50)));

    let mut id = 0u64;
    let mut buf = Vec::new();
    // tight lane: ONE request on model 0 (a partial round)
    multi.offer(tight_lane, Request::new(900, 0, payload())).unwrap();
    // bulk lane saturated: WDRR alone would keep picking it
    for _ in 0..6 {
        for model in 0..2 {
            multi.offer(slow_lane, Request::new(id, model, payload())).unwrap();
            id += 1;
        }
    }
    // before the deadline window, dispatches go to the bulk lane
    for _ in 0..3 {
        let d = multi.dispatch_next(&mut buf).unwrap().unwrap();
        assert_eq!(d.lane, slow_lane, "no SLO pressure yet");
        buf.clear();
    }
    // cross into the boost window (50ms SLO - 1ms margin)
    std::thread::sleep(Duration::from_millis(60));
    let d = multi.dispatch_next(&mut buf).unwrap().unwrap();
    assert_eq!(d.lane, tight_lane, "SLO-urgent lane must preempt WDRR");
    assert!(d.urgent, "the pick must be SLO-boosted");
    assert_eq!(d.responses, 1, "the padded round serves the one queued request");
    assert_eq!(buf[0].id, 900);
    assert!(buf[0].latency >= 0.05, "it really waited into the boost window");
    assert_eq!(
        multi.lane(tight_lane).metrics.slo_violations,
        1,
        "a 50ms SLO served at ~60ms is one violation"
    );
}

/// Satellite (bugfix): the SLO boost margin ε used to be fixed for all
/// lanes at `MultiServer` construction; it is now plumbed per lane
/// through every `add_lane_qos` path, and the deadline math
/// (`next_due_in`) must honor the per-lane value — a widened margin
/// brings the lane's due time FORWARD so the dispatch thread wakes in
/// time to pad early, and a zero margin never pads early (see
/// `qos::tests::zero_boost_margin_never_pads_early` for the scheduler-
/// level regression).
#[test]
fn per_lane_boost_margin_drives_next_due_in() {
    let wide = echo("wide", 2, Duration::ZERO);
    let zero = echo("zero", 2, Duration::ZERO);
    let mut multi = MultiServer::new();
    let slo = Duration::from_millis(100);
    let cfg = ServerConfig {
        strategy: StrategyKind::Sequential,
        max_wait: Duration::from_secs(3600),
        ..Default::default()
    };
    let wide_lane = multi.add_lane_qos(
        &wide,
        cfg.clone(),
        LaneQos::new(1, slo).with_boost_margin(Duration::from_millis(60)),
    );
    multi.add_lane_qos(&zero, cfg, LaneQos::new(1, slo).with_boost_margin(Duration::ZERO));
    assert_eq!(multi.qos(wide_lane).boost_margin, Some(Duration::from_millis(60)));

    // one partial round on each lane: neither is batching-ready, so the
    // only clocks are the SLO boosts
    multi.offer(0, Request::new(0, 0, payload())).unwrap();
    multi.offer(1, Request::new(1, 0, payload())).unwrap();
    let due = multi.next_due_in().expect("queued work implies a due time");
    // the 60ms-margin lane is due at ~slo - 60ms = 40ms; the zero-margin
    // lane not before ~100ms. A scheduler still using one global 1ms ε
    // would report ~99ms here and sleep through the boost window.
    assert!(
        due <= Duration::from_millis(45),
        "next_due_in {due:?} ignores the widened per-lane margin"
    );
    assert!(
        due >= Duration::from_millis(10),
        "next_due_in {due:?} is earlier than any lane's boost window"
    );
}
