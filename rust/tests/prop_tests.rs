//! Property-based tests (via `util::prop`, the offline proptest stand-in)
//! for the coordinator-side invariants: Algorithm 1 merge properties over
//! randomly generated graphs, tensor algebra round-trips, the zero-copy
//! round pipeline (arena packing vs the concat/stack reference, view
//! unpacking vs index0/split), the worker pool, and JSON round-trip
//! fuzzing.

use std::collections::BTreeMap;

use netfuse::coordinator::arena::{ArenaRing, Layout, RoundArena};
use netfuse::coordinator::pool::WorkerPool;
use netfuse::fuse;
use netfuse::graph::{Attr, Graph, MergeDim, Node};
use netfuse::tensor::Tensor;
use netfuse::util::json::Json;
use netfuse::util::prop::check;
use netfuse::util::rng::Rng;

// ---------------------------------------------------------------------------
// random graph generator: a layered mix of the mergeable op vocabulary
// ---------------------------------------------------------------------------

fn gen_seq_graph(rng: &mut Rng, size: usize) -> Graph {
    // sequence-model graphs: dense / layernorm / gelu / add chains
    let hidden = 4 * (1 + rng.usize_below(4));
    let mut nodes: Vec<Node> = Vec::new();
    let mut prev = "input".to_string();
    let mut fork: Option<String> = None;
    let n_ops = 1 + size.min(12);
    for i in 0..n_ops {
        let id = format!("n{i}");
        let choice = rng.usize_below(5);
        let node = match choice {
            0 => Node {
                id: id.clone(),
                kind: "dense".into(),
                inputs: vec![prev.clone()],
                attrs: BTreeMap::from([
                    ("fin".to_string(), Attr::Int(hidden as i64)),
                    ("fout".to_string(), Attr::Int(hidden as i64)),
                ]),
                weights: BTreeMap::from([
                    ("w".to_string(), vec![hidden, hidden]),
                    ("b".to_string(), vec![hidden]),
                ]),
                mergeable: true,
            },
            1 => Node {
                id: id.clone(),
                kind: "layernorm".into(),
                inputs: vec![prev.clone()],
                attrs: BTreeMap::from([("dim".to_string(), Attr::Int(hidden as i64))]),
                weights: BTreeMap::from([
                    ("gamma".to_string(), vec![hidden]),
                    ("beta".to_string(), vec![hidden]),
                ]),
                mergeable: true,
            },
            2 => Node {
                id: id.clone(),
                kind: "gelu".into(),
                inputs: vec![prev.clone()],
                attrs: BTreeMap::new(),
                weights: BTreeMap::new(),
                mergeable: true,
            },
            3 if fork.is_some() => Node {
                id: id.clone(),
                kind: "add".into(),
                inputs: vec![prev.clone(), fork.clone().unwrap()],
                attrs: BTreeMap::new(),
                weights: BTreeMap::new(),
                mergeable: true,
            },
            _ => Node {
                id: id.clone(),
                kind: "relu".into(),
                inputs: vec![prev.clone()],
                attrs: BTreeMap::new(),
                weights: BTreeMap::new(),
                mergeable: true,
            },
        };
        if rng.below(3) == 0 {
            fork = Some(prev.clone());
        }
        nodes.push(node);
        prev = id;
    }
    let g = Graph {
        name: "gen".into(),
        input_shape: vec![hidden],
        nodes,
        output: prev,
        merged_m: 1,
        layout: "single".into(),
    };
    g.validate().expect("generator must produce valid graphs");
    g
}

#[test]
fn prop_merge_preserves_mergeable_node_ids() {
    check("merge-preserves-ids", 60, gen_seq_graph, |g| {
        let m = 1 + (g.nodes.len() % 4);
        let merged = fuse::merge(g, m).map_err(|e| e.to_string())?;
        for n in &g.nodes {
            if merged.node(&n.id).is_err() {
                return Err(format!("node {} lost in merge", n.id));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_merge_only_adds_fixups() {
    check("merge-only-adds-fixups", 60, gen_seq_graph, |g| {
        let merged = fuse::merge(g, 3).map_err(|e| e.to_string())?;
        let orig: std::collections::HashSet<&str> =
            g.nodes.iter().map(|n| n.id.as_str()).collect();
        for n in &merged.nodes {
            if !orig.contains(n.id.as_str()) && n.kind != "refmt" {
                return Err(format!("unexpected new node {} ({})", n.id, n.kind));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_merge_is_valid_and_layernorm_free() {
    check("merge-valid-no-ln", 60, gen_seq_graph, |g| {
        let merged = fuse::merge(g, 4).map_err(|e| e.to_string())?;
        merged.validate().map_err(|e| e.to_string())?;
        if merged.nodes.iter().any(|n| n.kind == "layernorm") {
            return Err("layernorm survived the merge".into());
        }
        Ok(())
    });
}

#[test]
fn prop_refmt_endpoints_consistent() {
    check("refmt-endpoints", 60, gen_seq_graph, |g| {
        let merged = fuse::merge(g, 2).map_err(|e| e.to_string())?;
        for n in &merged.nodes {
            if n.kind == "refmt" {
                let src = n.attrs["src"].as_str().unwrap_or("");
                let dst = n.attrs["dst"].as_str().unwrap_or("");
                if src == dst {
                    return Err(format!("no-op refmt {}", n.id));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_merged_weight_shapes_scale_with_m() {
    check("weights-scale", 40, gen_seq_graph, |g| {
        for m in [2usize, 5] {
            let merged = fuse::merge(g, m).map_err(|e| e.to_string())?;
            for n in &g.nodes {
                let mn = merged.node(&n.id).unwrap();
                for (wname, shape) in &n.weights {
                    let got: usize = mn.weights[wname].iter().product();
                    let want: usize = shape.iter().product::<usize>() * m;
                    if got != want {
                        return Err(format!(
                            "{}.{}: {} elements, want {}",
                            n.id, wname, got, want
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_input_dim_rule() {
    check("input-dim", 20, gen_seq_graph, |g| {
        // sequence graphs pack on Batch; CNN graphs (rank-3 input) on Channel
        if fuse::input_dim(g) != MergeDim::Batch {
            return Err("sequence graph should pack on batch".into());
        }
        let mut cnn = g.clone();
        cnn.input_shape = vec![3, 8, 8];
        if fuse::input_dim(&cnn) != MergeDim::Channel {
            return Err("CNN graph should pack on channel".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// tensor properties
// ---------------------------------------------------------------------------

fn gen_tensor_parts(rng: &mut Rng, size: usize) -> (Vec<Tensor>, usize) {
    let rank = 2 + rng.usize_below(3);
    let shape: Vec<usize> = (0..rank).map(|_| 1 + rng.usize_below(4)).collect();
    let n = 1 + size.min(6);
    let parts = (0..n).map(|_| Tensor::randn(&shape, rng)).collect();
    let axis = rng.usize_below(rank);
    (parts, axis)
}

#[test]
fn prop_concat_split_roundtrip() {
    check("concat-split", 80, gen_tensor_parts, |(parts, axis)| {
        let refs: Vec<&Tensor> = parts.iter().collect();
        let cat = Tensor::concat(&refs, *axis).map_err(|e| e.to_string())?;
        let back = cat.split(parts.len(), *axis).map_err(|e| e.to_string())?;
        for (a, b) in parts.iter().zip(&back) {
            if a != b {
                return Err("split(concat(x)) != x".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_stack_index_roundtrip() {
    check("stack-index", 80, gen_tensor_parts, |(parts, _)| {
        let refs: Vec<&Tensor> = parts.iter().collect();
        let st = Tensor::stack(&refs).map_err(|e| e.to_string())?;
        for (i, p) in parts.iter().enumerate() {
            if &st.index0(i).map_err(|e| e.to_string())? != p {
                return Err(format!("stack[{i}] != part"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_swap01_involutive() {
    check("swap01", 60, gen_tensor_parts, |(parts, _)| {
        let t = &parts[0];
        if t.rank() < 2 {
            return Ok(());
        }
        let tt = t
            .swap01()
            .and_then(|x| x.swap01())
            .map_err(|e| e.to_string())?;
        if &tt != t {
            return Err("swap01 not involutive".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// zero-copy round pipeline: arena pack vs concat/stack, views vs index0
// ---------------------------------------------------------------------------

/// A random round: layout, request shape, payloads, and an occupancy
/// mask (None = padded slot).
#[derive(Debug)]
struct RoundCase {
    layout: Layout,
    shape: Vec<usize>,
    xs: Vec<Tensor>,
    occupied: Vec<bool>,
}

fn gen_round(rng: &mut Rng, size: usize) -> RoundCase {
    let layout = if rng.bool() { Layout::Channel } else { Layout::Batch };
    let shape: Vec<usize> = match layout {
        // [bs, C, spatial...]: channel packing needs rank >= 2
        Layout::Channel => {
            let rank = 2 + rng.usize_below(3);
            (0..rank).map(|_| 1 + rng.usize_below(4)).collect()
        }
        Layout::Batch => {
            let rank = 1 + rng.usize_below(3);
            (0..rank).map(|_| 1 + rng.usize_below(5)).collect()
        }
    };
    let m = 1 + size.min(7);
    let xs = (0..m).map(|_| Tensor::randn(&shape, rng)).collect();
    let occupied = (0..m).map(|_| rng.below(4) > 0).collect();
    RoundCase { layout, shape, xs, occupied }
}

#[test]
fn prop_pack_with_matches_concat_stack_reference() {
    check("arena-pack-reference", 120, gen_round, |c| {
        let m = c.xs.len();
        let pad = Tensor::zeros(&c.shape);
        // reference: the seed's copying pack over pad-substituted slots
        let slots: Vec<&Tensor> = (0..m)
            .map(|i| if c.occupied[i] { &c.xs[i] } else { &pad })
            .collect();
        let want = match c.layout {
            Layout::Channel => Tensor::concat(&slots, 1),
            Layout::Batch => Tensor::stack(&slots),
        }
        .map_err(|e| e.to_string())?;

        let mut arena =
            RoundArena::new(c.layout, m, &c.shape).map_err(|e| e.to_string())?;
        // dirty the buffer first: pack_with must fully overwrite
        arena.pack_with(&|i| Some(&c.xs[i])).map_err(|e| e.to_string())?;
        arena
            .pack_with(&|i| if c.occupied[i] { Some(&c.xs[i]) } else { None })
            .map_err(|e| e.to_string())?;

        if arena.merged_shape() != want.shape() {
            return Err(format!(
                "merged shape {:?} != reference {:?}",
                arena.merged_shape(),
                want.shape()
            ));
        }
        if arena.merged_data() != want.data() {
            return Err("megabatch bytes differ from concat/stack reference".into());
        }
        Ok(())
    });
}

#[test]
fn prop_pad_skip_matches_reference_across_rounds() {
    // the arena skips re-zeroing windows that stayed absent since the
    // previous round; over any sequence of occupancy patterns the
    // megabatch must stay byte-identical to the copying
    // concat/stack-with-zero-pads reference
    check("arena-pad-skip", 80, gen_round, |c| {
        let m = c.xs.len();
        let pad = Tensor::zeros(&c.shape);
        let mut arena =
            RoundArena::new(c.layout, m, &c.shape).map_err(|e| e.to_string())?;
        for round in 0..4usize {
            // rotate the occupancy mask so slots transition through
            // every (occupied, absent) -> (occupied, absent) pair
            let occ: Vec<bool> = (0..m).map(|i| c.occupied[(i + round) % m]).collect();
            let slots: Vec<&Tensor> = (0..m)
                .map(|i| if occ[i] { &c.xs[i] } else { &pad })
                .collect();
            let want = match c.layout {
                Layout::Channel => Tensor::concat(&slots, 1),
                Layout::Batch => Tensor::stack(&slots),
            }
            .map_err(|e| e.to_string())?;
            arena
                .pack_with(&|i| if occ[i] { Some(&c.xs[i]) } else { None })
                .map_err(|e| e.to_string())?;
            if arena.merged_data() != want.data() {
                return Err(format!(
                    "round {round}: pad-skip megabatch diverges from reference"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pack_later_rounds_never_corrupt_inflight_round() {
    // the ring soundness property: packing rounds N+1..N+depth-1 (other
    // threads, other ring slots) while round N's slot is still reserved
    // must leave round N's staged megabatch byte-identical, for every
    // overlap distance k < depth
    check("arena-ring-overlap", 60, gen_round, |c| {
        let m = c.xs.len();
        // depth varies per case so the property covers rings beyond the
        // old double-buffered pair
        let depth = 2 + (m % 3); // 2..=4
        let ring =
            ArenaRing::new(c.layout, m, &c.shape, depth).map_err(|e| e.to_string())?;

        // round N: reserve a slot, pack it, snapshot the staged bytes
        let mut inflight = ring.acquire();
        inflight
            .pack_with(&|i| if c.occupied[i] { Some(&c.xs[i]) } else { None })
            .map_err(|e| e.to_string())?;
        let staged: Vec<f32> = inflight.merged_data().to_vec();

        // rounds N+1..N+depth-1 pack concurrently from other threads
        // while round N is still "executing" (its slot stays locked);
        // each later round HOLDS its slot too, so all depth-1 free
        // slots end up reserved at once
        std::thread::scope(|s| {
            s.spawn(|| {
                let mut held = Vec::new();
                for k in 1..depth {
                    let mut next = ring.try_acquire().expect("k < depth slots reserved");
                    next.pack_with(&|i| Some(&c.xs[(i + k) % m])).unwrap();
                    held.push(next);
                }
                // with round N's slot also held the ring must be full
                assert!(ring.try_acquire().is_none(), "ring over-committed a slot");
            })
            .join()
            .unwrap();
        });

        if inflight.merged_data() != staged.as_slice() {
            return Err("overlapped pack corrupted the in-flight round".into());
        }
        Ok(())
    });
}

#[test]
fn prop_concurrent_ring_reservations_never_alias() {
    // R threads acquiring from an ArenaRing(depth = R) at the same time
    // must each get a distinct slot (distinct megabatch buffers); the
    // rendezvous barrier guarantees all R reservations are live at once
    check("arena-ring-no-alias", 40, gen_round, |c| {
        let m = c.xs.len();
        let depth = 2 + (m % 3); // 2..=4 concurrent reservations
        let ring =
            ArenaRing::new(c.layout, m, &c.shape, depth).map_err(|e| e.to_string())?;
        let barrier = std::sync::Barrier::new(depth);
        let ptrs = std::sync::Mutex::new(Vec::new());

        std::thread::scope(|s| {
            for t in 0..depth {
                let (ring, barrier, ptrs, xs) = (&ring, &barrier, &ptrs, &c.xs);
                s.spawn(move || {
                    let mut slot = ring.acquire();
                    slot.pack_with(&|i| Some(&xs[(i + t) % m])).unwrap();
                    ptrs.lock().unwrap().push(slot.merged_data().as_ptr() as usize);
                    // hold the reservation until every thread has one
                    barrier.wait();
                });
            }
        });

        let mut ptrs = ptrs.into_inner().unwrap();
        ptrs.sort_unstable();
        ptrs.dedup();
        if ptrs.len() != depth {
            return Err(format!(
                "{depth} concurrent reservations shared a slot ({} distinct buffers)",
                ptrs.len()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_view0_matches_index0_and_split() {
    check("view-unpack-reference", 120, gen_round, |c| {
        // merged outputs are always batch-packed [M, ...]
        let refs: Vec<&Tensor> = c.xs.iter().collect();
        let y = Tensor::stack(&refs).map_err(|e| e.to_string())?;
        let split = y.split(c.xs.len(), 0).map_err(|e| e.to_string())?;
        for (i, part) in c.xs.iter().enumerate() {
            let v = y.view0(i).map_err(|e| e.to_string())?;
            if v != *part {
                return Err(format!("view0({i}) differs from packed part"));
            }
            if v.to_owned() != y.index0(i).map_err(|e| e.to_string())? {
                return Err(format!("view0({i}).to_owned() != index0({i})"));
            }
            if v.to_owned() != split[i] {
                return Err(format!("view0({i}) != split[{i}]"));
            }
            if !v.allclose(&part.view(), 0.0, 0.0) {
                return Err(format!("view0({i}) allclose self failed"));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// worker pool: index alignment under arbitrary procs
// ---------------------------------------------------------------------------

#[test]
fn prop_worker_pool_results_index_aligned() {
    let pool = WorkerPool::new(4);
    check(
        "pool-index-aligned",
        60,
        |rng: &mut Rng, size| {
            let n = 1 + rng.usize_below(4 * (1 + size));
            let procs = 1 + rng.usize_below(2 * n + 2);
            let items: Vec<u64> = (0..n as u64).map(|i| i ^ rng.below(1 << 20)).collect();
            (items, procs)
        },
        |(items, procs)| {
            let got = pool
                .run_chunked(items.len(), *procs, |i| Ok(items[i].wrapping_mul(2654435761)))
                .map_err(|e| e.to_string())?;
            let want: Vec<u64> =
                items.iter().map(|v| v.wrapping_mul(2654435761)).collect();
            if got != want {
                return Err(format!("procs={procs}: results out of order"));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// json round-trip fuzz
// ---------------------------------------------------------------------------

fn gen_json(rng: &mut Rng, size: usize) -> Json {
    fn value(rng: &mut Rng, depth: usize) -> Json {
        match if depth > 3 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bool()),
            2 => Json::Num((rng.below(2_000_001) as f64 - 1e6) / 8.0),
            3 => {
                let n = rng.usize_below(8);
                let s: String = (0..n)
                    .map(|_| {
                        let c = rng.below(128) as u8;
                        if c.is_ascii_graphic() || c == b' ' {
                            c as char
                        } else {
                            '\\'
                        }
                    })
                    .collect();
                Json::Str(s)
            }
            4 => Json::Arr((0..rng.usize_below(4)).map(|_| value(rng, depth + 1)).collect()),
            _ => Json::Obj(
                (0..rng.usize_below(4))
                    .map(|i| (format!("k{i}"), value(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    let _ = size;
    value(rng, 0)
}

#[test]
fn prop_json_roundtrip() {
    check("json-roundtrip", 200, gen_json, |v| {
        let text = v.dump();
        let back = Json::parse(&text).map_err(|e| format!("{e} in {text:?}"))?;
        if &back != v {
            return Err(format!("roundtrip mismatch: {text}"));
        }
        Ok(())
    });
}

#[test]
fn prop_json_parser_never_panics_on_garbage() {
    check("json-no-panic", 300, |rng: &mut Rng, size| {
        let n = size * 4;
        (0..n)
            .map(|_| rng.below(128) as u8 as char)
            .collect::<String>()
    }, |s| {
        let _ = Json::parse(s); // must return, never panic
        Ok(())
    });
}
