//! Observability plane (ADR-006) integration tests: stage tracing
//! through the full `run_dispatch_elastic` stack (per-lane stage
//! histograms telescoping to the reported end-to-end latencies, and a
//! byte-identity diff against an instrumentation-off oracle run),
//! `ObsQuery`/`ObsReport` over a real TCP connection with counters
//! matching the final `IngressStats` exactly, the flight recorder's
//! merge-exactness property under concurrent recording, and the
//! automatic dump on persistent round failure.
//!
//! Everything is artifact-free (`EchoExecutor` / `RingEcho` /
//! `FailingEcho` lanes); the overhead side of observability is gated by
//! `benches/observe.rs`.

mod common;

use std::collections::HashMap;
use std::net::TcpListener;
use std::sync::Arc;
use std::time::{Duration, Instant};

use common::{echo, request_frame, seeded_request, FailingEcho, RingEcho};
use netfuse::coordinator::arena::{ArenaRing, Layout};
use netfuse::coordinator::control::{ControlPlane, TopologyController};
use netfuse::coordinator::metrics::MetricsHub;
use netfuse::coordinator::mock::SWAP_SCALE;
use netfuse::coordinator::multi::{GroupSpec, LaneSpec, MultiServer, ParallelDispatcher};
use netfuse::coordinator::obs::{
    CtrlKind, EventKind, FlightRecorder, ObsHub, RecHandle, Stage, DEFAULT_EVENT_CAP,
};
use netfuse::coordinator::server::ServerConfig;
use netfuse::coordinator::StrategyKind;
use netfuse::ingress::{
    run_dispatch, run_dispatch_elastic, serve_conn, ChanTransport, Envelope, Frame, FrameQueue,
    IngressBridge, IngressStats, LaneQos, RejectCode, TcpTransport, Transport,
};
use netfuse::util::json::Json;
use netfuse::util::shard::Sharded;

const FAR: Duration = Duration::from_secs(3600);
const WAIT: Duration = Duration::from_secs(10);

fn cfg() -> ServerConfig {
    ServerConfig {
        strategy: StrategyKind::NetFuse,
        queue_cap: 4096,
        max_wait: Duration::ZERO,
    }
}

fn qos1() -> LaneQos {
    LaneQos::new(1, FAR)
}

/// The seeded payload element `j` of request `(id, model)` — what an
/// unswapped echo lane must return byte-for-byte.
fn seeded_at(id: u64, model: usize, j: usize) -> f32 {
    id as f32 * 1000.0 + model as f32 * 10.0 + j as f32
}

fn await_frames(reply: &FrameQueue, n: usize, sink: &mut Vec<Frame>) {
    let deadline = Instant::now() + WAIT;
    let mut got = 0;
    while got < n {
        if let Some(f) = reply.try_pop() {
            sink.push(f);
            got += 1;
            continue;
        }
        assert!(Instant::now() < deadline, "timed out waiting for {n} outcome frames");
        std::thread::sleep(Duration::from_millis(1));
    }
}

/// Every counter in the report's `stats` object must equal the final
/// merged [`IngressStats`] exactly — the report was taken after traffic
/// quiesced, so nothing may tick between the snapshot and shutdown.
fn assert_stats_eq(report: &Json, stats: &IngressStats) {
    let pairs: [(&str, u64); 12] = [
        ("admitted", stats.admitted),
        ("lane_busy", stats.lane_busy),
        ("group_busy", stats.group_busy),
        ("invalid", stats.invalid),
        ("no_lane", stats.no_lane),
        ("shed", stats.shed),
        ("responses", stats.responses),
        ("rounds", stats.rounds),
        ("coalesced_rounds", stats.coalesced_rounds),
        ("round_errors", stats.round_errors),
        ("idle_naps_avoided", stats.idle_naps_avoided),
        ("ctrl_ops", stats.ctrl_ops),
    ];
    for (key, want) in pairs {
        assert_eq!(
            report.get("stats").get(key).as_usize(),
            Some(want as usize),
            "report stats.{key} must match the final counters"
        );
    }
}

// ---------------------------------------------------------------------------
// full stack: stage tracing + live report over elastic churn
// ---------------------------------------------------------------------------

/// One full churn scenario's outcome, for diffing obs-on vs obs-off.
struct ChurnRun {
    /// `(client_id, lane, model_idx, payload)` sorted by id — byte-exact
    responses: Vec<(u64, u32, u32, Vec<f32>)>,
    /// `(client_id, lane)` of every NoLane reject, sorted
    rejects: Vec<(u64, u32)>,
    /// per global lane: (response count, summed reported latency s)
    lane_latency: HashMap<u32, (u64, f64)>,
    stats: IngressStats,
    epoch: u64,
    report: Option<String>,
}

/// Drive identical seeded traffic + topology churn over
/// `run_dispatch_elastic`: 36 requests over the three construction
/// lanes (0,1 coalesce-grouped; 2 solo), add lane 3 and send 12, swap
/// it to version 7 and send 12 more, remove lane 1 and bounce 6 off
/// its dead global id. With a hub the run also issues one `ObsQuery`
/// while the server is still live (traffic quiesced, loops polling).
fn run_churn(hub: Option<&Arc<ObsHub>>) -> ChurnRun {
    let bert0 = echo("bert", 2, Duration::ZERO);
    let bert1 = echo("bert", 2, Duration::ZERO);
    let group = echo("bert", 4, Duration::ZERO);
    let solo = echo("solo", 2, Duration::ZERO);
    let added = echo("fresh", 2, Duration::ZERO);

    let mut d = ParallelDispatcher::new(
        vec![
            LaneSpec::new(&bert0, cfg(), qos1()),
            LaneSpec::new(&bert1, cfg(), qos1()),
            LaneSpec::new(&solo, cfg(), qos1()),
        ],
        vec![GroupSpec::new(&group, &[0, 1])],
    )
    .unwrap(); // p0 = group {0,1}, p1 = solo
    d.add_spare_part(); // p2, for the runtime add
    let metrics = Arc::new(MetricsHub::new(d.parts()));
    let plane = Arc::new(ControlPlane::for_dispatcher(&d));
    let ctl = TopologyController::new(d.topology_handle(), Arc::clone(&plane));
    let stats: Arc<Sharded<IngressStats>> = Arc::new(Sharded::new(d.parts() + 1));
    let bridge = IngressBridge::new(4096);
    if let Some(h) = hub {
        d.attach_metrics_hub(&metrics);
        h.attach_metrics(Arc::clone(&metrics));
        bridge.attach_obs(Arc::clone(h));
    }
    let reply = FrameQueue::new();
    let mut frames: Vec<Frame> = Vec::new();
    let mut want: HashMap<u64, (usize, usize, f32)> = HashMap::new();
    let mut report: Option<String> = None;

    std::thread::scope(|s| {
        let runner = s.spawn(|| run_dispatch_elastic(&mut d, &bridge, 1024, &stats, &plane));
        let submit = |id: u64, lane: usize, model: usize| {
            let env = Envelope {
                lane,
                client_id: id,
                req: seeded_request(id, model, &[4]),
                reply: reply.clone(),
            };
            assert!(bridge.submit(env).is_ok(), "bridge sized for the test");
        };
        let mut id = 0u64;

        // phase 1: steady traffic over the construction-time lanes
        for i in 0..36 {
            let (lane, model) = (i % 3, i % 2);
            submit(id, lane, model);
            want.insert(id, (lane, model, 0.0));
            id += 1;
        }
        await_frames(&reply, 36, &mut frames);

        // phase 2: grow under traffic
        let (g_new, ticket) = ctl.add_lane(LaneSpec::new(&added, cfg(), qos1())).unwrap();
        assert_eq!(g_new, 3, "global ids are monotone");
        ticket.wait(WAIT).unwrap();
        for i in 0..12 {
            let model = i % 2;
            submit(id, g_new, model);
            want.insert(id, (g_new, model, 0.0));
            id += 1;
        }
        await_frames(&reply, 12, &mut frames);

        // phase 3: hot-swap the new lane; post-ack traffic serves v7
        ctl.swap_model(g_new, 7).unwrap().wait(WAIT).unwrap();
        for i in 0..12 {
            let model = i % 2;
            submit(id, g_new, model);
            want.insert(id, (g_new, model, 7.0 * SWAP_SCALE));
            id += 1;
        }
        await_frames(&reply, 12, &mut frames);

        // phase 4: shrink; the removed global id answers NoLane
        ctl.remove_lane(1).unwrap().wait(WAIT).unwrap();
        for _ in 0..6 {
            submit(id, 1, 0);
            id += 1;
        }
        await_frames(&reply, 6, &mut frames);

        // the introspection moment: the server is live (all dispatch
        // loops polling) but traffic has quiesced, so every counter in
        // the report must equal the final merged stats exactly
        if let Some(h) = hub {
            // lane gauges refresh at the idle-poll cadence per
            // partition; give every thread a few cycles so the removed
            // lane's gauge is dropped before the snapshot
            std::thread::sleep(Duration::from_millis(50));
            let q = FrameQueue::new();
            h.enqueue_query(42, q.clone());
            let deadline = Instant::now() + WAIT;
            loop {
                if let Some(Frame::ObsReport { id, json }) = q.try_pop() {
                    assert_eq!(id, 42);
                    report = Some(json);
                    break;
                }
                assert!(Instant::now() < deadline, "ObsQuery went unanswered");
                std::thread::sleep(Duration::from_millis(1));
            }
        }

        bridge.close();
        runner.join().expect("dispatch runner panicked").expect("elastic dispatch failed");
    });

    // classify + byte-verify every outcome against the seeded oracle
    let mut responses = Vec::new();
    let mut rejects = Vec::new();
    let mut lane_latency: HashMap<u32, (u64, f64)> = HashMap::new();
    for f in frames {
        match f {
            Frame::Response { id, lane, model_idx, latency, data, .. } => {
                let (wl, wm, offset) =
                    want.remove(&id).unwrap_or_else(|| panic!("unexpected response id {id}"));
                assert_eq!(lane as usize, wl, "id {id} quoted the wrong lane");
                assert_eq!(model_idx as usize, wm);
                for (j, &x) in data.iter().enumerate() {
                    assert_eq!(x, seeded_at(id, wm, j) + offset, "id {id} byte {j}");
                }
                let e = lane_latency.entry(lane).or_insert((0, 0.0));
                e.0 += 1;
                e.1 += latency;
                responses.push((id, lane, model_idx, data));
            }
            Frame::Reject { id, lane, code, .. } => {
                assert_eq!(code, RejectCode::NoLane, "only the removed lane may reject");
                assert_eq!(lane, 1);
                rejects.push((id, lane));
            }
            other => panic!("unexpected frame: {other:?}"),
        }
    }
    assert!(want.is_empty(), "submissions without a response: {want:?}");
    responses.sort_by_key(|r| r.0);
    rejects.sort_unstable();
    ChurnRun { responses, rejects, lane_latency, stats: stats.read(), epoch: ctl.epoch(), report }
}

/// Tentpole acceptance: run the churn scenario instrumented and
/// uninstrumented, diff the outcome streams byte-for-byte, check the
/// stage histograms telescope to the reported end-to-end latencies,
/// and validate the live `ObsReport` against the final merged state.
#[test]
fn stage_histograms_and_live_report_match_the_oracle_over_churn() {
    let hub = Arc::new(ObsHub::new(4)); // three partitions + the router
    let on = run_churn(Some(&hub));
    let off = run_churn(None);

    // instrumentation transparency: byte-identical outcome streams and
    // identical deterministic counters
    assert_eq!(on.responses, off.responses, "observability must not change a single byte");
    assert_eq!(on.rejects, off.rejects);
    assert_eq!(on.responses.len(), 60);
    assert_eq!(on.rejects.len(), 6);
    for run in [&on, &off] {
        assert_eq!(run.stats.admitted, 60);
        assert_eq!(run.stats.responses, 60);
        assert_eq!(run.stats.no_lane, 6);
        assert_eq!(run.stats.ctrl_ops, 3, "add + swap + remove");
        assert_eq!(
            run.stats.lane_busy
                + run.stats.group_busy
                + run.stats.invalid
                + run.stats.round_errors,
            0
        );
    }

    // stage histograms: every response folded exactly once per stage,
    // per lane, and the first four stages telescope to the summed
    // reported latency (sum_ns is exact; only the f64 conversion of
    // the wire latency separates the two)
    let stages = hub.stages();
    let lane_counts: Vec<u64> =
        stages.lanes().iter().map(|l| l.stage(Stage::Queue).count()).collect();
    assert_eq!(lane_counts, vec![12, 12, 12, 24], "per-lane stage coverage");
    for (g, lane) in stages.lanes().iter().enumerate() {
        let n = lane.stage(Stage::Queue).count();
        let mut telescoped = 0.0f64;
        for st in Stage::ALL {
            assert_eq!(lane.stage(st).count(), n, "lane {g}: stage {} count", st.name());
            if st != Stage::Write {
                telescoped += lane.stage(st).sum_ns() as f64 / 1e9;
            }
        }
        let (rn, rsum) = on.lane_latency[&(g as u32)];
        assert_eq!(rn, n, "lane {g}: histogram covers every response");
        assert!(
            (telescoped - rsum).abs() < 1e-6,
            "lane {g}: stages sum to {telescoped}s but responses reported {rsum}s"
        );
    }

    // the live report: topology + gauges + exact counters
    let r = Json::parse(on.report.as_ref().unwrap()).unwrap();
    assert_eq!(r.get("epoch").as_usize(), Some(on.epoch as usize));
    assert_eq!(r.get("parts").as_usize(), Some(3));
    assert_stats_eq(&r, &on.stats);
    let lanes = r.get("lanes").as_arr().unwrap();
    let globals: Vec<usize> =
        lanes.iter().map(|l| l.get("global").as_usize().unwrap()).collect();
    assert_eq!(globals, vec![0, 2, 3], "removed lane's gauge gone; survivors + the add remain");
    assert_eq!(r.get("unmapped").as_arr().unwrap().len(), 1);
    assert_eq!(r.get("unmapped").idx(0).as_usize(), Some(1));
    for l in lanes {
        assert_eq!(l.get("life").as_str(), Some("live"));
        assert_eq!(l.get("pending").as_usize(), Some(0), "traffic quiesced before the query");
        assert!(l.get("round_p99_s").as_f64().unwrap() > 0.0, "every live lane served rounds");
    }
    // the added lane's wire-visible stage view equals the in-process one
    let l3 = &lanes[2];
    assert_eq!(l3.get("stages").get("queue").get("count").as_usize(), Some(24));
    assert_eq!(
        l3.get("stages").get("execute").get("sum_ns").as_usize(),
        Some(stages.lane(3).unwrap().stage(Stage::Execute).sum_ns() as usize)
    );

    // aggregate metrics rode along
    let m = r.get("metrics");
    assert_eq!(m.get("completed_requests").as_usize(), Some(60));
    assert!(m.get("rounds").as_usize().unwrap() >= 1);
    assert!(m.get("request_p99_s").as_f64().unwrap() > 0.0);

    // the flight recorder saw the whole story, in global order, and a
    // clean (if churny) run must not trigger a dump
    assert!(hub.recorder.last_dump().is_none(), "no false-alarm dumps");
    let evs = hub.recorder.snapshot();
    assert!(evs.windows(2).all(|w| w[0].seq < w[1].seq), "snapshot is in global seq order");
    let ctrl: Vec<(CtrlKind, usize, u64)> = evs
        .iter()
        .filter_map(|e| match e.kind {
            EventKind::CtrlOp { op, global, epoch } => Some((op, global, epoch)),
            _ => None,
        })
        .collect();
    assert_eq!(ctrl.len(), 3);
    assert_eq!((ctrl[0].0, ctrl[0].1), (CtrlKind::Add, 3));
    assert_eq!((ctrl[1].0, ctrl[1].1), (CtrlKind::Swap, 3));
    assert_eq!((ctrl[2].0, ctrl[2].1), (CtrlKind::Remove, 1));
    assert!(
        ctrl[0].2 < ctrl[1].2 && ctrl[1].2 < ctrl[2].2,
        "ctrl-op epochs must advance: {ctrl:?}"
    );
    let no_lane = evs
        .iter()
        .filter(|e| matches!(e.kind, EventKind::Reject { code: RejectCode::NoLane, lane: 1 }))
        .count();
    assert_eq!(no_lane, 6, "every bounced envelope leaves a reject event");
    let served: usize = evs
        .iter()
        .filter_map(|e| match e.kind {
            EventKind::RoundEnd { responses, .. } => Some(responses),
            _ => None,
        })
        .sum();
    assert_eq!(served, 60, "round-end events account for every response");
    assert!(evs.iter().any(|e| matches!(e.kind, EventKind::QosPick { .. })));
}

// ---------------------------------------------------------------------------
// introspection over a real TCP connection
// ---------------------------------------------------------------------------

/// `ObsQuery` rides the same socket as traffic: after ten echoed
/// requests the client asks for a snapshot and the report's counters
/// must equal the final `IngressStats` of the whole run, field by
/// field — plus the tracked `ArenaRing` gauge and the lane's stage
/// histograms, all over the wire.
#[test]
fn obs_query_over_tcp_matches_the_final_stats_exactly() {
    let ring = Arc::new(ArenaRing::new(Layout::Batch, 2, &[1, 4], 2).unwrap());
    let fleet = RingEcho::new("ringed", Arc::clone(&ring), Duration::ZERO);
    let mut multi: MultiServer<RingEcho> = MultiServer::new();
    multi.add_lane(&fleet, cfg());
    let metrics = Arc::new(MetricsHub::new(1));
    multi.attach_metrics_sink(&metrics.register());
    let hub = Arc::new(ObsHub::new(1));
    hub.track_ring("fleet-ring", Arc::clone(&ring));
    hub.attach_metrics(Arc::clone(&metrics));
    let bridge = IngressBridge::new(256);
    bridge.attach_obs(Arc::clone(&hub));

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();

    let (json, stats) = std::thread::scope(|s| {
        let dispatch = s.spawn(|| run_dispatch(&mut multi, &bridge));
        let b2 = bridge.clone();
        let server = s.spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let t: Box<dyn Transport> = Box::new(TcpTransport::from_stream(stream).unwrap());
            serve_conn(b2, t).unwrap()
        });

        let t: Box<dyn Transport> = Box::new(TcpTransport::connect(addr).unwrap());
        let (mut tx, mut rx) = t.split().unwrap();
        for id in 0..10u64 {
            tx.send(&request_frame(id, 0, (id % 2) as u32, &[1, 4])).unwrap();
        }
        let mut got = 0;
        while got < 10 {
            match rx.recv().unwrap() {
                Some(Frame::Response { .. }) => got += 1,
                other => panic!("expected ten responses first, got {other:?}"),
            }
        }
        // traffic done; the introspection query rides the same socket
        tx.send(&Frame::ObsQuery { id: 777 }).unwrap();
        let json = match rx.recv().unwrap() {
            Some(Frame::ObsReport { id, json }) => {
                assert_eq!(id, 777, "report echoes the query id");
                json
            }
            other => panic!("expected an ObsReport, got {other:?}"),
        };
        tx.send(&Frame::Eos).unwrap();
        let conn = server.join().unwrap();
        bridge.close();
        let stats = dispatch.join().unwrap().unwrap();
        conn.shutdown();
        while rx.recv().unwrap().is_some() {}
        (json, stats)
    });

    assert_eq!(stats.admitted, 10);
    assert_eq!(stats.responses, 10);
    let r = Json::parse(&json).unwrap();
    assert_stats_eq(&r, &stats);
    assert_eq!(r.get("epoch").as_usize(), Some(0), "unpartitioned run has no topology");
    assert_eq!(r.get("parts").as_usize(), Some(1));
    // the tracked ring gauge: idle at query time, depth as constructed
    let rj = r.get("rings").idx(0);
    assert_eq!(rj.get("label").as_str(), Some("fleet-ring"));
    assert_eq!(rj.get("depth").as_usize(), Some(2));
    assert_eq!(rj.get("in_flight").as_usize(), Some(0));
    // one live lane, all ten responses staged through every seam
    let lane = r.get("lanes").idx(0);
    assert_eq!(lane.get("global").as_usize(), Some(0));
    assert_eq!(lane.get("life").as_str(), Some("live"));
    assert_eq!(lane.get("stages").get("queue").get("count").as_usize(), Some(10));
    assert_eq!(lane.get("stages").get("write").get("count").as_usize(), Some(10));
    assert!(lane.get("stages").get("execute").get("sum_ns").as_f64().unwrap() > 0.0);
    assert_eq!(r.get("metrics").get("completed_requests").as_usize(), Some(10));
    assert!(r.get("recorder").get("recorded").as_usize().unwrap() > 0);
}

/// Without an attached hub the query is refused in-band — typed, on
/// the same connection, without poisoning it.
#[test]
fn obs_query_without_a_hub_is_rejected_in_band() {
    let bridge = IngressBridge::new(8);
    let (client, server_end) = ChanTransport::pair();
    let conn = serve_conn(bridge.clone(), Box::new(server_end)).unwrap();
    let (mut tx, mut rx) = (Box::new(client) as Box<dyn Transport>).split().unwrap();
    tx.send(&Frame::ObsQuery { id: 9 }).unwrap();
    tx.send(&Frame::Eos).unwrap();
    match rx.recv().unwrap() {
        Some(Frame::Reject { id, code, msg, .. }) => {
            assert_eq!(id, 9);
            assert_eq!(code, RejectCode::Invalid);
            assert!(msg.contains("observability not enabled"), "{msg}");
        }
        other => panic!("expected an in-band reject, got {other:?}"),
    }
    conn.shutdown();
    assert!(rx.recv().unwrap().is_none(), "connection closes cleanly after the reject");
}

// ---------------------------------------------------------------------------
// flight recorder: merge exactness under concurrency + failure dumps
// ---------------------------------------------------------------------------

/// Property (satellite): with one global sequence counter, the merged
/// snapshot of per-thread wrapped rings is EXACTLY the newest
/// `DEFAULT_EVENT_CAP` events across all threads, in order — an event
/// in the global tail has fewer than `cap` successors globally, hence
/// fewer on its own shard, hence was never overwritten. This must hold
/// under any interleaving, so the recording threads run concurrently.
#[test]
fn concurrent_recorder_retains_exactly_the_global_last_cap() {
    const THREADS: usize = 4;
    const PER_THREAD: u64 = 600; // 2400 total >> 512 retained
    let rec = FlightRecorder::new(THREADS);
    let handles: Vec<RecHandle> = (0..THREADS).map(|_| rec.handle()).collect();
    std::thread::scope(|s| {
        for (t, h) in handles.into_iter().enumerate() {
            s.spawn(move || {
                for _ in 0..PER_THREAD {
                    h.record(EventKind::RoundStart { part: t });
                }
            });
        }
    });
    let total = THREADS as u64 * PER_THREAD;
    assert_eq!(rec.recorded(), total);
    let evs = rec.snapshot();
    assert_eq!(evs.len(), DEFAULT_EVENT_CAP);
    let seqs: Vec<u64> = evs.iter().map(|e| e.seq).collect();
    let want: Vec<u64> = (total - DEFAULT_EVENT_CAP as u64..total).collect();
    assert_eq!(seqs, want, "merged rings must be exactly the newest cap events, in order");
}

/// A persistently failing fleet dumps the flight recorder before the
/// loop dies, and the dump contains the failing rounds (the full error
/// streak), while the client still gets its one typed outcome frame.
#[test]
fn persistent_round_failure_dumps_the_flight_recorder() {
    let fleet = FailingEcho::new("flaky", 1, &[4]);
    fleet.fail_rounds(3); // == the loop's consecutive-failure budget
    let mut multi: MultiServer<FailingEcho> = MultiServer::new();
    multi.add_lane(&fleet, cfg());
    let hub = Arc::new(ObsHub::new(1));
    let bridge = IngressBridge::new(8);
    bridge.attach_obs(Arc::clone(&hub));
    let reply = FrameQueue::new();

    let result = std::thread::scope(|s| {
        let runner = s.spawn(|| run_dispatch(&mut multi, &bridge));
        bridge
            .submit(Envelope {
                lane: 0,
                client_id: 1,
                req: seeded_request(1, 0, &[4]),
                reply: reply.clone(),
            })
            .unwrap();
        runner.join().expect("dispatch thread panicked")
    });
    assert!(result.is_err(), "three consecutive round failures must surface");

    // the admitted request still got exactly one outcome frame
    match reply.try_pop() {
        Some(Frame::Reject { code, .. }) => assert_eq!(code, RejectCode::Shutdown),
        other => panic!("expected a Shutdown reject, got {other:?}"),
    }

    let dump = hub.recorder.last_dump().expect("persistent failure must auto-dump");
    assert!(dump.reason.contains("consecutive round failures"), "{}", dump.reason);
    let streaks: Vec<u32> = dump
        .events
        .iter()
        .filter_map(|e| match e.kind {
            EventKind::RoundError { consecutive } => Some(consecutive),
            _ => None,
        })
        .collect();
    assert_eq!(streaks, vec![1, 2, 3], "the dump must contain the whole failing streak");
}
