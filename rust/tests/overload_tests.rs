//! Overload robustness (ADR-007): admission-control projection,
//! adaptive ε, failure cooldown, gauge freshness under saturation, and
//! the 120-seed exactly-one-outcome property with an unshedded oracle.

mod common;

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use common::{drain_all, echo, payload, seeded_request, FailingEcho};
use netfuse::coordinator::multi::MultiServer;
use netfuse::coordinator::server::ServerConfig;
use netfuse::coordinator::{ObsHub, Request, StrategyKind};
use netfuse::ingress::{
    run_dispatch, Envelope, Frame, FrameQueue, IngressBridge, LaneQos, RejectCode, SubmitError,
};
use netfuse::util::rng::Rng;

/// The dispatch loop's gauge/ε refresh cadence (`IDLE_POLL` in
/// bridge.rs — private, mirrored here so the freshness test states its
/// contract explicitly).
const CADENCE: Duration = Duration::from_millis(5);

fn cfg(queue_cap: usize) -> ServerConfig {
    ServerConfig { strategy: StrategyKind::Sequential, queue_cap, ..Default::default() }
}

/// Non-blocking frame wait with a hard deadline, so a broken dispatch
/// path fails the test with a message instead of hanging it.
fn pop_within(q: &FrameQueue, deadline: Duration, what: &str) -> Frame {
    let t0 = Instant::now();
    loop {
        if let Some(f) = q.try_pop() {
            return f;
        }
        assert!(t0.elapsed() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_micros(200));
    }
}

// ---------------------------------------------------------------------------
// admission projection (tentpole b)
// ---------------------------------------------------------------------------

/// The projection is evidence-gated: no observed rounds or no backlog
/// means no shed, and once both exist the decision is
/// `ceil(pending / m) * round_p99 > slo`.
#[test]
fn shed_decision_requires_evidence_then_tracks_backlog() {
    let fleet = echo("slow", 2, Duration::from_millis(2));
    let mut multi = MultiServer::new();
    multi.add_lane_qos(&fleet, cfg(64), LaneQos::new(1, Duration::from_millis(1)));

    // cold and empty: nothing to project from
    assert_eq!(multi.projected_wait(0), None);
    assert!(!multi.should_shed(0));
    // unknown lane: never sheds (the bridge answers NoLane instead)
    assert!(!multi.should_shed(7));

    // backlogged but COLD — no completed round, no p99, no shedding:
    // admission control must not guess on a lane it has no evidence for
    for id in 0..4 {
        multi.offer(0, Request::new(id, (id % 2) as usize, payload())).unwrap();
    }
    assert_eq!(multi.projected_wait(0), None);
    assert!(!multi.should_shed(0));

    // serve the backlog: p99 is now ~2ms (the round cost)
    let mut out = Vec::new();
    drain_all(&mut multi, &mut out).unwrap();
    assert_eq!(out.len(), 4);

    // warm but EMPTY: an idle lane never sheds
    assert_eq!(multi.projected_wait(0), None);
    assert!(!multi.should_shed(0));

    // warm and backlogged: 4 pending / m=2 -> 2 rounds x ~2ms = ~4ms,
    // far past the 1ms SLO
    for id in 10..14 {
        multi.offer(0, Request::new(id, (id % 2) as usize, payload())).unwrap();
    }
    let wait = multi.projected_wait(0).expect("backlog + observed rounds must project");
    assert!(wait >= Duration::from_millis(3), "projection {wait:?} lost the round cost");
    assert!(multi.should_shed(0));
}

// ---------------------------------------------------------------------------
// adaptive ε (tentpole a)
// ---------------------------------------------------------------------------

/// The ε control loop derives each lane's boost margin from its own
/// observed tail, clamps it to `[min_eps, slo/2]`, and never overrides
/// an operator pin.
#[test]
fn adaptive_eps_tracks_round_tail_clamps_and_respects_pins() {
    let floor = Duration::from_micros(200);
    let fleet = echo("slow", 2, Duration::from_millis(2));
    let mut multi = MultiServer::new();
    // lane 0: tight SLO -> the 2ms tail clamps to slo/2
    multi.add_lane_qos(&fleet, cfg(64), LaneQos::new(1, Duration::from_millis(1)));
    // lane 1: same SLO, operator-pinned ε -> adaptation must not win
    multi.add_lane_qos(
        &fleet,
        cfg(64),
        LaneQos::new(1, Duration::from_millis(1)).with_boost_margin(Duration::from_micros(123)),
    );
    // lane 2: huge SLO -> the estimate passes through unclamped
    multi.add_lane_qos(&fleet, cfg(64), LaneQos::default());

    // no completed rounds: the refresh is a no-op, lanes keep resolving
    // to their static margins
    multi.refresh_adaptive_eps(floor);
    for lane in 0..3 {
        assert_eq!(multi.lane_adaptive_margin(lane), None);
    }

    // one round per lane establishes each tail
    let mut out = Vec::new();
    for lane in 0..3 {
        multi.offer(lane, Request::new(100 + lane as u64, 0, payload())).unwrap();
        multi.offer(lane, Request::new(200 + lane as u64, 1, payload())).unwrap();
    }
    drain_all(&mut multi, &mut out).unwrap();
    assert_eq!(out.len(), 6);

    multi.refresh_adaptive_eps(floor);

    // lane 0: tail ~2ms, ceiling slo/2 = 500us -> clamped exactly there
    assert_eq!(multi.lane_adaptive_margin(0), Some(Duration::from_micros(500)));
    assert_eq!(multi.lane_boost_margin(0), Duration::from_micros(500));

    // lane 1: adaptation runs, but the pin stays the effective ε
    assert!(multi.lane_adaptive_margin(1).is_some());
    assert_eq!(multi.lane_boost_margin(1), Duration::from_micros(123));

    // lane 2: unclamped tracking — ε is the observed ~2ms tail itself
    let eps2 = multi.lane_adaptive_margin(2).expect("lane 2 completed a round");
    assert!(eps2 >= Duration::from_millis(2), "ε {eps2:?} below the observed tail");
    assert!(eps2 < Duration::from_millis(500), "ε {eps2:?} not a plausible tail");
    assert_eq!(multi.lane_boost_margin(2), eps2);

    // steady state: with an unchanged tail the EWMA is a fixed point
    multi.refresh_adaptive_eps(floor);
    assert_eq!(multi.lane_adaptive_margin(0), Some(Duration::from_micros(500)));
}

// ---------------------------------------------------------------------------
// failure cooldown (satellite 1)
// ---------------------------------------------------------------------------

/// A cooling lane disappears from QoS selection AND the deadline scan;
/// siblings keep flowing; expiry is purely time-based.
#[test]
fn cooldown_masks_lane_from_selection_and_deadline_scan() {
    let fleet = echo("mock", 2, Duration::ZERO);
    let mut multi = MultiServer::new();
    multi.add_lane(&fleet, cfg(16));
    multi.add_lane(&fleet, cfg(16));

    multi.offer(0, Request::new(1, 0, payload())).unwrap();
    multi.offer(0, Request::new(2, 1, payload())).unwrap();
    assert_eq!(multi.ready_lane(), Some(0));

    // cool lane 0: it must vanish from selection and the deadline scan
    multi.set_lane_cooldown(0, Instant::now() + Duration::from_secs(60));
    assert!(multi.lane_cooling(0));
    assert_eq!(multi.ready_lane(), None, "a cooling lane must not be selectable");
    assert_eq!(multi.next_due_in(), None, "a cooling lane must not drive the nap deadline");

    // a healthy sibling is unaffected
    multi.offer(1, Request::new(3, 0, payload())).unwrap();
    multi.offer(1, Request::new(4, 1, payload())).unwrap();
    assert_eq!(multi.ready_lane(), Some(1));
    let mut out = Vec::new();
    let d = multi.dispatch_next(&mut out).unwrap().expect("sibling round is due");
    assert_eq!(d.lane, 1);

    // expiry is time-based: re-arm with an already-past deadline
    multi.set_lane_cooldown(0, Instant::now());
    std::thread::sleep(Duration::from_micros(50));
    assert!(!multi.lane_cooling(0));
    assert_eq!(multi.ready_lane(), Some(0));
    drain_all(&mut multi, &mut out).unwrap();
    assert_eq!(multi.pending(), 0);
    assert_eq!(out.len(), 4);
}

/// `take_failed_lane` is one-shot, and a failed round requeues its
/// requests so a later attempt serves them.
#[test]
fn failed_lane_attribution_is_one_shot_and_requests_survive() {
    let flaky = FailingEcho::new("flaky", 2, &[4]);
    flaky.fail_rounds(1);
    let mut multi = MultiServer::new();
    multi.add_lane(&flaky, cfg(16));
    multi.offer(0, Request::new(1, 0, payload())).unwrap();
    multi.offer(0, Request::new(2, 1, payload())).unwrap();

    let mut out = Vec::new();
    assert!(multi.dispatch_next(&mut out).is_err());
    assert_eq!(multi.take_failed_lane(), Some(0));
    assert_eq!(multi.take_failed_lane(), None, "attribution must be consumed exactly once");

    // the failed round's requests were requeued in order
    assert_eq!(multi.pending(), 2);
    multi.dispatch_next(&mut out).unwrap().expect("recovered round");
    assert_eq!(out.len(), 2);
}

/// The regression the cooldown fixes (satellite 1): a lane whose fleet
/// fails 6 rounds in a row — twice the loop's consecutive-error budget
/// of 3 — must neither kill the dispatch loop nor starve its healthy
/// sibling, because each failure cools the lane long enough for
/// sibling rounds to interleave and reset the error streak. Before the
/// fix, the failed lane was re-picked immediately: three failures
/// burned in microseconds and the loop died.
#[test]
fn persistently_failing_lane_neither_kills_loop_nor_starves_sibling() {
    let flaky = FailingEcho::new("flaky", 2, &[4]);
    flaky.fail_rounds(6);
    // a MultiServer's lanes share one executor type, so the healthy
    // sibling is a FailingEcho that simply never has failures armed
    let steady = FailingEcho::new("steady", 2, &[4]);

    let mut multi = MultiServer::new();
    multi.add_lane(&flaky, cfg(16));
    multi.add_lane(&steady, cfg(64));
    let bridge = IngressBridge::new(256);

    let flaky_reply = FrameQueue::new();
    let steady_reply = FrameQueue::new();
    let stop = AtomicBool::new(false);

    let stats = std::thread::scope(|s| {
        let dispatch = s.spawn(|| run_dispatch(&mut multi, &bridge));

        // the doomed backlog: one full round on lane 0
        for id in [1000u64, 1001] {
            let env = Envelope {
                lane: 0,
                client_id: id,
                req: Request::new(id, (id % 2) as usize, payload()),
                reply: flaky_reply.clone(),
            };
            assert!(bridge.submit(env).is_ok());
        }

        // sibling traffic: keep lane 1 topped up (one pair per 200us —
        // many pairs per 2ms cooldown window) until the flaky lane
        // finally serves, so every failure has a healthy round after it
        let producer = s.spawn(|| {
            let mut sent = 0u64;
            let mut id = 0u64;
            while !stop.load(Ordering::Acquire) && sent < 50_000 {
                for _ in 0..2 {
                    let env = Envelope {
                        lane: 1,
                        client_id: id,
                        req: Request::new(id, (id % 2) as usize, payload()),
                        reply: steady_reply.clone(),
                    };
                    match bridge.submit(env) {
                        Ok(()) => sent += 1,
                        Err(SubmitError::Busy(_)) => {}
                        Err(SubmitError::Closed(_)) => return sent,
                    }
                    id += 1;
                }
                std::thread::sleep(Duration::from_micros(200));
            }
            sent
        });

        // both doomed requests must eventually be SERVED — 6 failures
        // (requeue + cooldown each time), then the recovered round
        for _ in 0..2 {
            match pop_within(&flaky_reply, Duration::from_secs(10), "flaky lane responses") {
                Frame::Response { id, .. } => assert!(id == 1000 || id == 1001),
                f => panic!("flaky lane request must be served after recovery, got {f:?}"),
            }
        }
        stop.store(true, Ordering::Release);
        let sent = producer.join().unwrap();
        bridge.close();
        let stats = dispatch
            .join()
            .unwrap()
            .expect("6 failures with cooldown must not kill the dispatch loop");

        // the sibling was never starved: every submission got exactly
        // one outcome, and virtually all of them were served (a Busy
        // from a transiently full queue is backpressure, not starvation)
        let (mut steady_served, mut steady_busy) = (0u64, 0u64);
        while let Some(f) = steady_reply.try_pop() {
            match f {
                Frame::Response { lane: 1, .. } => steady_served += 1,
                Frame::Reject { code: RejectCode::Busy, .. } => steady_busy += 1,
                f => panic!("healthy sibling got an unexpected outcome: {f:?}"),
            }
        }
        assert_eq!(steady_served + steady_busy, sent, "healthy sibling lost outcomes");
        assert!(
            steady_served >= sent - sent / 10,
            "sibling starved: only {steady_served}/{sent} served"
        );
        stats
    });

    assert_eq!(stats.round_errors, 6, "all six injected failures must surface as retries");
    assert_eq!(stats.shed, 0);
}

// ---------------------------------------------------------------------------
// gauge freshness under saturation (satellite 2)
// ---------------------------------------------------------------------------

/// A saturated loop — always a round due, never reaching the idle
/// poll — still republishes gauges within 2x the refresh cadence,
/// because the time budget is also checked on the round path.
#[test]
fn saturated_loop_refreshes_gauges_within_twice_cadence() {
    let fleet = echo("busy", 2, Duration::from_micros(500));
    let mut multi = MultiServer::new();
    multi.add_lane_qos(&fleet, cfg(8192), LaneQos::default());
    let bridge = IngressBridge::new(8192);
    let hub = Arc::new(ObsHub::new(1));
    bridge.attach_obs(Arc::clone(&hub));

    let reply = FrameQueue::new();
    let stop = AtomicBool::new(false);

    std::thread::scope(|s| {
        let dispatch = s.spawn(|| run_dispatch(&mut multi, &bridge));

        // oversubscribe ~5x: the backlog grows monotonically, so every
        // gauge publish carries a new `pending` value
        let producer = s.spawn(|| {
            let mut id = 0u64;
            while !stop.load(Ordering::Acquire) && id < 50_000 {
                for _ in 0..2 {
                    let env = Envelope {
                        lane: 0,
                        client_id: id,
                        req: Request::new(id, (id % 2) as usize, payload()),
                        reply: reply.clone(),
                    };
                    let _ = bridge.submit(env);
                    id += 1;
                }
                std::thread::sleep(Duration::from_micros(100));
            }
        });

        // wait for the first publish, then time gaps between observed
        // gauge changes; the loop is saturated the whole time
        let t0 = Instant::now();
        let mut last = loop {
            if let Some(g) = hub.gauges().first() {
                break g.pending;
            }
            assert!(t0.elapsed() < Duration::from_secs(2), "gauges never appeared");
            std::thread::sleep(Duration::from_micros(100));
        };
        let mut gaps = Vec::new();
        let mut mark = Instant::now();
        while gaps.len() < 6 {
            assert!(t0.elapsed() < Duration::from_secs(5), "gauges went stale under load");
            let now = hub.gauges().first().map(|g| g.pending).unwrap_or(last);
            if now != last {
                gaps.push(mark.elapsed());
                mark = Instant::now();
                last = now;
            }
            std::thread::sleep(Duration::from_micros(100));
        }
        stop.store(true, Ordering::Release);
        producer.join().unwrap();
        bridge.close();
        dispatch.join().unwrap().unwrap();

        // the contract is the cadence bound; the min over six intervals
        // tolerates individual scheduler hiccups without weakening it
        let fastest = gaps.iter().min().unwrap();
        assert!(
            *fastest <= CADENCE * 2,
            "saturated loop republished gauges every {fastest:?} at best — \
             budget is 2x the {CADENCE:?} cadence"
        );
    });
}

// ---------------------------------------------------------------------------
// the overload property (satellite 4)
// ---------------------------------------------------------------------------

/// 120 seeded overload trials: with admission control active, every
/// submission gets EXACTLY one outcome frame (served xor a typed
/// reject), the shed counters match the frames bit-for-bit, and the
/// served stream is byte-identical to an unshedded oracle restricted
/// to the served set.
#[test]
fn overload_property_every_submission_one_outcome_and_serves_match_oracle() {
    let mut total_shed = 0u64;
    for seed in 0..120u64 {
        let mut rng = Rng::new(0x51ED5 + seed);
        let fleet = echo("prop", 2, Duration::from_micros(300));
        let mut multi = MultiServer::new();
        // SLO 500us against a 300us round: backlogs of >= 4 project past
        // the deadline, so bursts shed their tails once the lane is warm
        multi.add_lane_qos(&fleet, cfg(64), LaneQos::new(1, Duration::from_micros(500)));
        let bridge = IngressBridge::new(256);
        let reply = FrameQueue::new();
        let mut submitted: Vec<(u64, usize)> = Vec::new();

        let stats = std::thread::scope(|s| {
            let dispatch = s.spawn(|| run_dispatch(&mut multi, &bridge));
            let mut id = 0u64;
            for _ in 0..6 {
                for _ in 0..4 + rng.usize_below(9) {
                    let model = rng.usize_below(2);
                    let env = Envelope {
                        lane: 0,
                        client_id: id,
                        req: seeded_request(id, model, &[4]),
                        reply: reply.clone(),
                    };
                    match bridge.submit(env) {
                        Ok(()) => submitted.push((id, model)),
                        Err(_) => panic!("bridge cap 256 cannot fill at this volume"),
                    }
                    id += 1;
                }
                std::thread::sleep(Duration::from_micros(800));
            }
            bridge.close();
            dispatch.join().unwrap().unwrap()
        });

        // exactly one outcome per submission, no spurious extras
        let mut served: HashMap<u64, (u32, Vec<f32>)> = HashMap::new();
        let mut rejected: HashMap<u64, RejectCode> = HashMap::new();
        while let Some(f) = reply.try_pop() {
            match f {
                Frame::Response { id, model_idx, data, .. } => {
                    assert!(
                        served.insert(id, (model_idx, data)).is_none(),
                        "seed {seed}: duplicate response for {id}"
                    );
                }
                Frame::Reject { id, code, .. } => {
                    assert!(
                        matches!(
                            code,
                            RejectCode::Shed | RejectCode::Busy | RejectCode::Shutdown
                        ),
                        "seed {seed}: untyped overload reject {code:?} for {id}"
                    );
                    assert!(
                        rejected.insert(id, code).is_none(),
                        "seed {seed}: duplicate reject for {id}"
                    );
                }
                f => panic!("seed {seed}: unexpected outcome frame {f:?}"),
            }
        }
        assert_eq!(
            served.len() + rejected.len(),
            submitted.len(),
            "seed {seed}: outcome count drifted from submissions"
        );
        for (id, _) in &submitted {
            assert_ne!(
                served.contains_key(id),
                rejected.contains_key(id),
                "seed {seed}: submission {id} must be served XOR rejected"
            );
        }

        // counter exactness: scalar, per-lane row, and frames all agree
        let shed_frames =
            rejected.values().filter(|&&c| c == RejectCode::Shed).count() as u64;
        let busy_frames =
            rejected.values().filter(|&&c| c == RejectCode::Busy).count() as u64;
        assert_eq!(stats.shed, shed_frames, "seed {seed}: shed counter != shed frames");
        assert_eq!(stats.lane_busy, busy_frames, "seed {seed}: busy counter != busy frames");
        let row = stats.lane_rejects.get(&0).copied().unwrap_or_default();
        assert_eq!(row.shed, shed_frames, "seed {seed}: per-lane shed row drifted");
        assert_eq!(row.busy, busy_frames, "seed {seed}: per-lane busy row drifted");
        assert_eq!(stats.admitted, served.len() as u64, "seed {seed}: admitted != served");
        assert_eq!(stats.responses, served.len() as u64);

        // unshedded oracle: the same arrivals through a plain MultiServer
        // with headroom — served ids must match byte-for-byte
        let oracle_fleet = echo("prop", 2, Duration::ZERO);
        let mut oracle = MultiServer::new();
        oracle.add_lane(&oracle_fleet, cfg(4096));
        let mut oresp = Vec::new();
        for &(id, model) in &submitted {
            oracle.offer(0, seeded_request(id, model, &[4])).unwrap();
        }
        drain_all(&mut oracle, &mut oresp).unwrap();
        let odata: HashMap<u64, Vec<f32>> =
            oresp.into_iter().map(|r| (r.id, r.output.data().to_vec())).collect();
        for (id, (_, data)) in &served {
            assert_eq!(
                Some(data.as_slice()),
                odata.get(id).map(|v| v.as_slice()),
                "seed {seed}: served stream diverged from the unshedded oracle at {id}"
            );
        }

        total_shed += shed_frames;
    }
    assert!(total_shed > 0, "120 overload trials never shed — the property is vacuous");
}
