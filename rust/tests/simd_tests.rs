//! Property tests for `util::simd`: every dispatched kernel must be
//! byte-identical (f32 compared via `to_bits`, so NaN payloads and
//! signed zeros count) to the strict scalar reference in
//! `util::simd::reference`, across random lengths, ragged tails,
//! alignments (offset prefixes) and overlap-free window layouts. The CI
//! matrix runs this suite twice — once on the detected backend and once
//! under `RUST_PALLAS_FORCE_SCALAR=1` — so both sides of the dispatch
//! stay proven.

use netfuse::prop_assert;
use netfuse::util::prop::check;
use netfuse::util::rng::Rng;
use netfuse::util::simd::{self, reference, Backend, Windows};

/// Random f32 payloads that exercise odd bit patterns, not just ramps:
/// normals, negative zero, infinities and quiet NaNs all survive a
/// byte copy and must survive the SIMD one identically.
fn gen_values(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n)
        .map(|_| match rng.usize_below(16) {
            0 => -0.0,
            1 => f32::INFINITY,
            2 => f32::NEG_INFINITY,
            3 => f32::NAN,
            _ => rng.f32_range(-1e6, 1e6),
        })
        .collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn forced_scalar_pins_the_backend() {
    // under RUST_PALLAS_FORCE_SCALAR=1 (the CI fallback leg) detection
    // must never win over the pin
    if simd::scalar_forced() {
        assert_eq!(simd::backend(), Backend::Scalar);
    }
}

#[test]
fn copy_matches_reference_across_lengths_and_alignments() {
    check(
        "simd-copy-parity",
        300,
        |rng, size| {
            // lengths sweep the ragged tails around every lane width;
            // offset shifts the slice start to exercise misalignment
            let n = rng.usize_below(size * 8 + 65);
            let offset = rng.usize_below(8);
            (gen_values(rng, offset + n), offset)
        },
        |(buf, offset)| {
            let src = &buf[*offset..];
            let mut got = vec![0.0f32; src.len()];
            let mut want = vec![0.0f32; src.len()];
            simd::copy(&mut got, src);
            reference::copy(&mut want, src);
            prop_assert!(bits(&got) == bits(&want), "copy diverged at len {}", src.len());

            let via_vec = simd::to_vec(src);
            prop_assert!(bits(&via_vec) == bits(&want), "to_vec diverged at len {}", src.len());

            simd::fill_zero(&mut got);
            reference::fill_zero(&mut want);
            prop_assert!(bits(&got) == bits(&want), "fill diverged at len {}", src.len());
            Ok(())
        },
    );
}

#[test]
fn copy_windows_matches_reference_and_leaves_gaps_untouched() {
    check(
        "simd-windows-parity",
        200,
        |rng, size| {
            let rows = 1 + rng.usize_below(size.min(6) + 2);
            let row_len = rng.usize_below(size * 4 + 40);
            // strides >= row_len keep windows overlap-free (the only
            // layout the production paths produce)
            let dst_stride = row_len + rng.usize_below(17);
            let src_stride = row_len + rng.usize_below(17);
            let dst_offset = rng.usize_below(9);
            let src_offset = rng.usize_below(9);
            let w = Windows { rows, row_len, dst_offset, dst_stride, src_offset, src_stride };
            let need = |offset: usize, stride: usize| offset + (rows - 1) * stride + row_len;
            let src = gen_values(rng, need(src_offset, src_stride));
            let dst_len = need(dst_offset, dst_stride) + rng.usize_below(8);
            (w, src, dst_len)
        },
        |(w, src, dst_len)| {
            // prefill with a sentinel pattern: the full-buffer bitwise
            // compare below then also proves the gaps were not written
            let canvas: Vec<f32> = (0..*dst_len).map(|i| i as f32 - 7.5).collect();
            let mut got = canvas.clone();
            let mut want = canvas;
            simd::copy_windows(&mut got, src, *w);
            reference::copy_windows(&mut want, src, *w);
            prop_assert!(bits(&got) == bits(&want), "copy_windows diverged for {w:?}");

            simd::fill_rows_zero(&mut got, w.dst_offset, w.dst_stride, w.rows, w.row_len);
            reference::fill_rows_zero(&mut want, w.dst_offset, w.dst_stride, w.rows, w.row_len);
            prop_assert!(bits(&got) == bits(&want), "fill_rows_zero diverged for {w:?}");
            Ok(())
        },
    );
}

#[test]
fn scatter_then_gather_is_identity() {
    check(
        "simd-scatter-gather-roundtrip",
        200,
        |rng, size| {
            let rows = 1 + rng.usize_below(size.min(6) + 2);
            let row_len = 1 + rng.usize_below(size * 4 + 40);
            let stride = row_len + rng.usize_below(13);
            let offset = rng.usize_below(7);
            let src = gen_values(rng, rows * row_len);
            (src, rows, row_len, stride, offset)
        },
        |(src, rows, row_len, stride, offset)| {
            let mut mega = vec![f32::MIN; offset + (rows - 1) * stride + row_len];
            simd::scatter_rows(&mut mega, *offset, *stride, src, *rows, *row_len);
            let mut back = vec![0.0f32; rows * row_len];
            simd::gather_rows(&mut back, &mega, *offset, *stride, *rows, *row_len);
            prop_assert!(
                bits(&back) == bits(src),
                "scatter/gather not an identity (rows={rows} row_len={row_len} stride={stride})"
            );
            Ok(())
        },
    );
}

#[test]
fn le_byte_codec_matches_reference_and_roundtrips() {
    check(
        "simd-le-codec-parity",
        300,
        |rng, size| {
            let n = rng.usize_below(size * 8 + 65);
            // a random-length prefix misaligns both the byte output and
            // the later decode input
            let prefix = rng.usize_below(5);
            (gen_values(rng, n), prefix)
        },
        |(src, prefix)| {
            let mut got: Vec<u8> = vec![0xA5; *prefix];
            let mut want = got.clone();
            simd::extend_f32_le(&mut got, src);
            reference::extend_f32_le(&mut want, src);
            prop_assert!(got == want, "encode diverged at len {}", src.len());

            let mut back = vec![1.25f32];
            let mut back_ref = back.clone();
            simd::extend_le_f32(&mut back, &got[*prefix..]);
            reference::extend_le_f32(&mut back_ref, &want[*prefix..]);
            prop_assert!(bits(&back) == bits(&back_ref), "decode diverged at len {}", src.len());
            prop_assert!(
                bits(&back[1..]) == bits(src),
                "encode/decode not a roundtrip at len {}",
                src.len()
            );
            Ok(())
        },
    );
}
