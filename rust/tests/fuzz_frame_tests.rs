//! Adversarial frame fuzzing (ADR-007): registry-free structured
//! fuzzing of the ingress wire format, plus cross-connection blast
//! containment.
//!
//! Three layers:
//! 1. **Seeded mutation fuzz** — a corpus of valid frames is run
//!    through seeded byte mutations (flips, truncation, extension) and
//!    grammar-aware header mutations (length prefix, tag, rank/dims,
//!    message-length fields). Every mutated buffer must decode to
//!    `Ok` or `Err` — never a panic — and no single decode may cost an
//!    unbounded allocation. Iteration count defaults to 10k and scales
//!    with `RUST_PALLAS_FUZZ_ITERS` (CI sets it explicitly).
//! 2. **Hostile length claims** — inflated length prefixes over short
//!    frames must be rejected from the `HEADER_MAX` window alone: the
//!    payload buffer allocation is bounded by the header window, not
//!    the claimed length (a 64MiB claim costs 64 bytes, not 64MiB).
//! 3. **Blast containment** — over real TCP: one connection spraying
//!    raw garbage and another violating the protocol with well-formed
//!    server-only frames must not poison a sibling connection, the
//!    bridge, or the dispatch thread.
//!
//! The allocation assertions share one global counting allocator, so
//! every measuring test serializes on [`ALLOC_GATE`] — test threads
//! otherwise pollute each other's deltas.

mod common;

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::Mutex;
use std::time::Duration;

use common::{echo, request_frame};
use netfuse::coordinator::multi::MultiServer;
use netfuse::coordinator::server::ServerConfig;
use netfuse::coordinator::StrategyKind;
use netfuse::ingress::frame::{HEADER_MAX, MAX_FRAME, MAX_RANK};
use netfuse::ingress::{
    run_dispatch, serve_conn, Frame, IngressBridge, RejectCode, TcpTransport, TransportRx,
    TransportTx,
};
use netfuse::util::bench::counting_alloc::{self, CountingAlloc};
use netfuse::util::rng::Rng;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Serializes allocation-measuring regions across test threads.
static ALLOC_GATE: Mutex<()> = Mutex::new(());

/// `RUST_PALLAS_FUZZ_ITERS` env knob (default 10k mutated frames).
fn fuzz_iters() -> usize {
    std::env::var("RUST_PALLAS_FUZZ_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(10_000)
}

/// Valid frames covering every tag, both sides of the `HEADER_MAX`
/// window, rank 0 (scalar) through multi-dim tensors, and every reject
/// code — the seeds the mutators perturb.
fn corpus() -> Vec<Frame> {
    vec![
        Frame::Eos,
        Frame::ObsQuery { id: 7 },
        Frame::ObsQuery { id: u64::MAX },
        Frame::ObsReport { id: 1, json: "{}".to_string() },
        Frame::ObsReport { id: 2, json: format!("{{\"k\":[{}]}}", "0,".repeat(80) + "0") },
        Frame::reject(3, 1, RejectCode::Busy, "lane queue full"),
        Frame::reject(9, 0, RejectCode::Shed, "projected queue wait exceeds lane SLO"),
        Frame::reject(11, 2, RejectCode::Invalid, &"m".repeat(100)),
        Frame::reject(12, 3, RejectCode::NoLane, ""),
        Frame::reject(13, 4, RejectCode::Shutdown, "bye"),
        Frame::Request { id: 1, lane: 0, model_idx: 0, shape: vec![], data: vec![0.5] },
        Frame::Request {
            id: 2,
            lane: 1,
            model_idx: 3,
            shape: vec![1, 4],
            data: vec![1.0, -2.0, 3.5, f32::MIN_POSITIVE],
        },
        Frame::Request {
            id: u64::MAX,
            lane: u32::MAX,
            model_idx: u32::MAX,
            shape: vec![2, 3, 4],
            data: (0..24).map(|i| i as f32).collect(),
        },
        Frame::Response {
            id: 4,
            lane: 2,
            model_idx: 1,
            latency: 0.0123,
            shape: vec![1, 64],
            data: (0..64).map(|i| i as f32 * 0.25).collect(),
        },
    ]
}

fn encode(f: &Frame) -> Vec<u8> {
    let mut buf = Vec::new();
    f.encode_into(&mut buf);
    buf
}

/// One seeded mutation: byte-level (flip / truncate / extend) or
/// grammar-aware (length prefix, tag, a header field). Returns a short
/// label for failure messages.
fn mutate(rng: &mut Rng, buf: &mut Vec<u8>) -> &'static str {
    match rng.below(6) {
        0 => {
            // flip 1..=8 bytes anywhere (length prefix included)
            for _ in 0..=rng.below(8) {
                let i = rng.usize_below(buf.len());
                buf[i] ^= 1 << rng.below(8);
            }
            "byte-flip"
        }
        1 => {
            // truncate: mid-prefix, mid-header, or mid-payload
            buf.truncate(rng.usize_below(buf.len()));
            "truncate"
        }
        2 => {
            // trailing garbage past the declared length
            for _ in 0..1 + rng.below(32) {
                buf.push(rng.next_u64() as u8);
            }
            "extend"
        }
        3 => {
            // length prefix rewrite, biased toward hostile claims
            let claim: u32 = match rng.below(4) {
                0 => rng.next_u64() as u32,
                1 => (MAX_FRAME - 1 - rng.usize_below(64)) as u32,
                2 => (MAX_FRAME + rng.usize_below(1 << 20)) as u32,
                _ => rng.below(HEADER_MAX as u64 * 2) as u32,
            };
            buf[..4].copy_from_slice(&claim.to_le_bytes());
            "length-claim"
        }
        4 => {
            if buf.len() > 4 {
                buf[4] = rng.next_u64() as u8; // tag byte
            }
            "tag"
        }
        _ => {
            // smash one aligned 4-byte field inside the header window
            // (hits lane/model ids, rank+dims, msg_len/json_len)
            let window = buf.len().min(4 + HEADER_MAX);
            if window > 9 {
                let at = 5 + 4 * rng.usize_below((window - 5 - 4) / 4 + 1);
                let v = (rng.next_u64() as u32).to_le_bytes();
                let end = (at + 4).min(buf.len());
                buf[at..end].copy_from_slice(&v[..end - at]);
            }
            "header-field"
        }
    }
}

/// Tentpole: 10k+ seeded mutations across the corpus — every decode is
/// `Ok` xor `Err` (a panic fails the test), no decode allocates
/// unbounded memory, and any frame the decoder ACCEPTS re-encodes to
/// bytes the decoder accepts again (no parse-only frames that the
/// server could not echo back onto the wire).
#[test]
fn mutated_frames_never_panic_or_overallocate() {
    let _gate = ALLOC_GATE.lock().unwrap();
    let seeds = corpus().iter().map(encode).collect::<Vec<_>>();
    let mut rng = Rng::new(0xF0220_1);
    let iters = fuzz_iters();
    let (mut oks, mut errs) = (0u64, 0u64);
    for i in 0..iters {
        let mut buf = seeds[i % seeds.len()].clone();
        let kind = mutate(&mut rng, &mut buf);
        // a SELF-CONSISTENT header (prefix == header-implied length) may
        // legitimately allocate its declared payload before the body
        // read fails — that's the protocol's own frame budget, capped by
        // MAX_FRAME. The bound scales with the declared prefix; the
        // strict header-window bound for INCONSISTENT claims is pinned
        // by the dedicated hostile-length test below.
        let declared = if buf.len() >= 4 {
            u32::from_le_bytes(buf[..4].try_into().unwrap()) as u64
        } else {
            0
        };
        let bound = declared.saturating_add(1 << 20);
        let before = counting_alloc::bytes_allocated();
        let res = Frame::read_from(&mut &buf[..]);
        let delta = counting_alloc::bytes_allocated() - before;
        assert!(
            delta < bound,
            "{kind} mutation #{i} cost a {delta}-byte decode against a \
             {declared}-byte claim: hostile input must never drive \
             allocations beyond the declared frame budget"
        );
        match res {
            Ok(Some(f)) => {
                oks += 1;
                let reenc = encode(&f);
                assert!(
                    Frame::read_from(&mut &reenc[..]).is_ok(),
                    "{kind} mutation #{i}: accepted frame failed to re-encode losslessly"
                );
            }
            Ok(None) | Err(_) => errs += 1,
        }
    }
    // the mutator must exercise both sides of the validator
    assert!(oks > 0, "no mutation survived decoding — the fuzzer only tests rejection");
    assert!(errs > iters as u64 / 10, "only {errs}/{iters} rejections — mutations too tame");
}

/// A hostile length claim on a short frame is rejected from the
/// `HEADER_MAX` window alone: the decode allocates the 64-byte header
/// buffer (plus the error object), never the claimed megabytes.
#[test]
fn hostile_length_claims_cost_header_window_not_claimed_bytes() {
    let _gate = ALLOC_GATE.lock().unwrap();
    let mut rng = Rng::new(0xF0220_2);
    for f in corpus() {
        for _ in 0..64 {
            let mut buf = encode(&f);
            let true_len = buf.len() - 4;
            // claim far beyond the real payload, within the MAX_FRAME cap
            // so the length check alone cannot save us; a draw equal to
            // the frame's true length would be a no-op, skip it
            let claim = (HEADER_MAX + 1 + rng.usize_below(MAX_FRAME - HEADER_MAX - 1)) as u32;
            if claim as usize == true_len {
                continue;
            }
            buf[..4].copy_from_slice(&claim.to_le_bytes());
            // pad so the header read itself succeeds
            if buf.len() < 4 + HEADER_MAX {
                buf.resize(4 + HEADER_MAX, 0);
            }
            let before = counting_alloc::bytes_allocated();
            let res = Frame::read_from(&mut &buf[..]);
            let delta = counting_alloc::bytes_allocated() - before;
            assert!(res.is_err(), "a {claim}-byte claim over a short frame must be rejected");
            assert!(
                delta <= 4096,
                "a {claim}-byte length claim allocated {delta} bytes — the payload \
                 buffer must be bounded by the {HEADER_MAX}-byte header window"
            );
        }
    }
}

/// Grammar corner: every rank the header can claim (0..=255) over an
/// otherwise valid request — ranks past [`MAX_RANK`] must reject, and
/// none may panic on the dim-read path.
#[test]
fn every_claimed_rank_is_handled() {
    let f = Frame::Request { id: 5, lane: 0, model_idx: 0, shape: vec![1, 4], data: vec![0.0; 4] };
    let rank_at = 4 + 1 + 8 + 4 + 4; // prefix + tag + id + lane + model_idx
    for rank in 0..=255u8 {
        let mut buf = encode(&f);
        buf[rank_at] = rank;
        let res = Frame::read_from(&mut &buf[..]);
        if rank as usize > MAX_RANK {
            assert!(res.is_err(), "rank {rank} exceeds the cap and must be rejected");
        }
        // ranks <= MAX_RANK reinterpret the remaining bytes as dims and
        // then fail the length cross-check (or, for rank 2, succeed) —
        // either way no panic, which reaching here proves
    }
}

/// Blast containment over real TCP: a raw-garbage connection and a
/// protocol-violating connection run concurrently with a well-behaved
/// one. The victim's requests are all served, the dispatch loop
/// survives, and each hostile connection's damage stays on that
/// connection.
#[test]
fn hostile_connection_never_poisons_siblings_or_the_dispatch_thread() {
    let fleet = echo("mock", 2, Duration::ZERO);
    let mut multi = MultiServer::new();
    multi.add_lane(
        &fleet,
        ServerConfig { strategy: StrategyKind::Sequential, queue_cap: 64, ..Default::default() },
    );
    let bridge = IngressBridge::new(64);

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let accept_bridge = bridge.clone();
    let acceptor = std::thread::spawn(move || {
        (0..3)
            .map(|_| {
                let (stream, _) = listener.accept().unwrap();
                let t = TcpTransport::from_stream(stream).unwrap();
                serve_conn(accept_bridge.clone(), Box::new(t)).unwrap()
            })
            .collect::<Vec<_>>()
    });

    let stats = std::thread::scope(|s| {
        let dispatch = s.spawn(|| run_dispatch(&mut multi, &bridge));

        // conn 1: the victim — valid requests, expects every response
        let victim = s.spawn(move || {
            let mut t = TcpTransport::connect(addr).unwrap();
            let mut served = 0;
            for id in 0..20u64 {
                t.send(&request_frame(id, 0, (id % 2) as u32, &[1, 4])).unwrap();
                match t.recv().unwrap() {
                    Some(Frame::Response { id: got, .. }) => {
                        assert_eq!(got, id);
                        served += 1;
                    }
                    f => panic!("victim expected a response for {id}, got {f:?}"),
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            t.send(&Frame::Eos).unwrap();
            served
        });

        // conn 2: raw garbage — seeded byte spray and hostile length
        // claims straight onto the socket; its reader dies alone
        let garbage = s.spawn(move || {
            let mut sock = TcpStream::connect(addr).unwrap();
            let mut rng = Rng::new(0xF0220_3);
            // a hostile 64MiB claim over a 1-byte payload...
            let mut claim = Vec::new();
            claim.extend_from_slice(&(MAX_FRAME as u32).to_le_bytes());
            claim.push(4); // Eos tag
            claim.resize(4 + HEADER_MAX, 0);
            let _ = sock.write_all(&claim);
            // ...then random byte spray until the server hangs up
            for _ in 0..64 {
                let junk: Vec<u8> = (0..64).map(|_| rng.next_u64() as u8).collect();
                if sock.write_all(&junk).is_err() {
                    break;
                }
            }
        });

        // conn 3: protocol violation — well-formed frames a client must
        // never send; answered with in-band Invalid rejects, and the
        // connection still serves a valid request afterwards
        let violator = s.spawn(move || {
            let mut t = TcpTransport::connect(addr).unwrap();
            t.send(&Frame::ObsReport { id: 1, json: "{}".to_string() }).unwrap();
            match t.recv().unwrap() {
                Some(Frame::Reject { code: RejectCode::Invalid, .. }) => {}
                f => panic!("server-only frame must draw an Invalid reject, got {f:?}"),
            }
            t.send(&request_frame(500, 0, 0, &[1, 4])).unwrap();
            match t.recv().unwrap() {
                Some(Frame::Response { id, .. }) => assert_eq!(id, 500),
                f => panic!("the violating connection must still serve, got {f:?}"),
            }
            t.send(&Frame::Eos).unwrap();
        });

        let served = victim.join().unwrap();
        garbage.join().unwrap();
        violator.join().unwrap();
        let conns = acceptor.join().unwrap();
        bridge.close();
        let stats = dispatch.join().unwrap().expect("hostile peers must not kill dispatch");
        for c in conns {
            c.shutdown();
        }
        assert_eq!(served, 20, "the victim connection lost responses");
        stats
    });

    // 20 victim + 1 violator request admitted and served; the garbage
    // connection never produced a single admissible envelope
    assert_eq!(stats.admitted, 21);
    assert_eq!(stats.responses, 21);
    assert_eq!(stats.shed, 0);
    assert_eq!(stats.round_errors, 0, "hostile bytes reached the executor");
}
