//! Cross-fleet round coalescing: the randomized/property harness and
//! the deterministic positive/negative paths.
//!
//! The harness is the correctness story for slot routing: for random
//! lane counts, WDRR weights, queue depths, and partial occupancy, a
//! coalesced `MultiServer` must produce **byte-identical responses, per
//! lane, in FIFO order** to an uncoalesced oracle fed the same seeded
//! requests — including through injected round failures (merged-round
//! requeue) in the coalesced run. Everything is artifact-free
//! (`EchoExecutor` / `FailingEcho` lanes) and sleep-free (`max_wait`
//! zero, zero round cost), so the 120-case property suite stays well
//! inside the test wall-clock budget.

mod common;

use std::collections::HashMap;
use std::time::Duration;

use common::{collect_streams, echo, seeded_request, FailingEcho, Streams};
use netfuse::coordinator::mock::EchoExecutor;
use netfuse::coordinator::multi::MultiServer;
use netfuse::coordinator::server::{Admit, ServerConfig};
use netfuse::coordinator::StrategyKind;
use netfuse::ingress::LaneQos;
use netfuse::prop_assert;
use netfuse::util::prop;
use netfuse::util::rng::Rng;

const FAR: Duration = Duration::from_secs(3600);

fn lane_config() -> ServerConfig {
    ServerConfig {
        strategy: StrategyKind::NetFuse,
        queue_cap: 1024,
        max_wait: Duration::ZERO,
    }
}

// ---------------------------------------------------------------------------
// the property harness: coalesced vs uncoalesced oracle
// ---------------------------------------------------------------------------

/// One randomized serving scenario. `steps[k]` is the batch of
/// `(lane, model, id)` arrivals offered before the k-th dispatch-to-
/// empty; `fail_at_step[k]` injects that many merged-round failures
/// (and one solo-lane failure) into the coalesced run at step k.
#[derive(Debug, Clone)]
struct Scenario {
    lanes: usize,
    lane_m: usize,
    weights: Vec<u32>,
    steps: Vec<Vec<(usize, usize, u64)>>,
    fail_at_step: Vec<usize>,
}

fn gen_scenario(rng: &mut Rng, size: usize) -> Scenario {
    let lanes = 2 + rng.usize_below(3); // 2..=4
    let lane_m = 1 + rng.usize_below(3); // 1..=3
    let weights = (0..lanes).map(|_| 1 + rng.below(4) as u32).collect();
    let nsteps = 1 + size.min(6);
    let mut id = 0u64;
    let mut steps = Vec::new();
    let mut fail_at_step = Vec::new();
    for _ in 0..nsteps {
        let mut step = Vec::new();
        for lane in 0..lanes {
            for model in 0..lane_m {
                // partial occupancy: ~40% empty, ~40% one queued, ~20%
                // a depth-2 queue (exercises multi-round steps)
                let depth = match rng.below(10) {
                    0..=3 => 0,
                    4..=7 => 1,
                    _ => 2,
                };
                for _ in 0..depth {
                    step.push((lane, model, id));
                    id += 1;
                }
            }
        }
        steps.push(step);
        fail_at_step.push(if rng.below(5) == 0 { 1 + rng.usize_below(2) } else { 0 });
    }
    Scenario { lanes, lane_m, weights, steps, fail_at_step }
}

/// Run one scenario. `coalesced` registers all lanes as ONE group on a
/// group executor sized to their total; `inject` arms the scenario's
/// failure schedule (merged-round failures on the group executor plus a
/// solo failure on a rotating lane executor). Returns the per-lane
/// response streams and the number of successful merged rounds.
fn run_case(sc: &Scenario, coalesced: bool, inject: bool) -> (Streams, u64) {
    let lane_execs: Vec<FailingEcho> =
        (0..sc.lanes).map(|_| FailingEcho::new("family", sc.lane_m, &[4])).collect();
    let group_exec = FailingEcho::new("family", sc.lanes * sc.lane_m, &[4]);
    let mut multi: MultiServer<FailingEcho> = MultiServer::new();
    for (i, e) in lane_execs.iter().enumerate() {
        multi.add_lane_qos(e, lane_config(), LaneQos::new(sc.weights[i], FAR));
    }
    let group = if coalesced {
        let members: Vec<usize> = (0..sc.lanes).collect();
        Some(multi.add_coalesce_group(&group_exec, &members).unwrap())
    } else {
        None
    };

    let mut lane_of_id: HashMap<u64, usize> = HashMap::new();
    let mut streams: Streams = vec![Vec::new(); sc.lanes];
    let mut buf = Vec::new();
    for (k, step) in sc.steps.iter().enumerate() {
        for &(lane, model, id) in step {
            lane_of_id.insert(id, lane);
            assert_eq!(
                multi.offer(lane, seeded_request(id, model, &[4])).unwrap(),
                Admit::Queued
            );
        }
        if inject && sc.fail_at_step[k] > 0 {
            group_exec.fail_rounds(sc.fail_at_step[k]);
            lane_execs[k % sc.lanes].fail_rounds(1);
        }
        // dispatch to empty; injected failures requeue and are retried
        loop {
            match multi.dispatch_next(&mut buf) {
                Ok(Some(_)) => {}
                Ok(None) => break,
                Err(e) => assert!(
                    e.to_string().contains("injected round failure"),
                    "unexpected round error: {e}"
                ),
            }
        }
        collect_streams(&mut buf, &lane_of_id, &mut streams);
    }
    assert_eq!(multi.pending(), 0, "every offered request must be served");
    (streams, group.map_or(0, |g| multi.group_stats(g).rounds))
}

/// Satellite: the coalesce property. For random lane counts, weights,
/// and partial occupancy — with merged-round failures injected — the
/// coalesced server's responses are byte-identical, per lane and in
/// FIFO order, to the same requests dispatched lane-by-lane.
#[test]
fn coalesced_rounds_match_the_uncoalesced_oracle() {
    prop::check("coalesce-oracle", 120, gen_scenario, |sc| {
        let (oracle, _) = run_case(sc, false, false);
        let (subject, merged_rounds) = run_case(sc, true, true);
        // scenarios where some step loads >= 2 lanes MUST coalesce at
        // least once, or the property is vacuously comparing solo runs
        let concurrent = sc.steps.iter().any(|step| {
            let mut ls: Vec<usize> = step.iter().map(|&(l, _, _)| l).collect();
            ls.sort();
            ls.dedup();
            ls.len() >= 2
        });
        prop_assert!(
            !concurrent || merged_rounds > 0,
            "no merged round despite concurrent work on >= 2 lanes"
        );
        for lane in 0..sc.lanes {
            prop_assert!(
                subject[lane] == oracle[lane],
                "lane {lane} diverges from the uncoalesced oracle:\n  \
                 coalesced: {:?}\n  oracle: {:?}",
                subject[lane].iter().map(|(id, m, _)| (*id, *m)).collect::<Vec<_>>(),
                oracle[lane].iter().map(|(id, m, _)| (*id, *m)).collect::<Vec<_>>()
            );
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// deterministic positive paths
// ---------------------------------------------------------------------------

#[test]
fn one_merged_execution_serves_every_member_lane() {
    let a = echo("bert", 2, Duration::ZERO);
    let b = echo("bert", 2, Duration::ZERO);
    let g = echo("bert", 4, Duration::ZERO);
    let mut multi = MultiServer::new();
    let la = multi.add_lane(&a, lane_config());
    let lb = multi.add_lane(&b, lane_config());
    let group = multi.add_coalesce_group(&g, &[la, lb]).unwrap();
    assert_eq!(multi.group_members(group), &[la, lb]);
    assert_eq!(multi.lane_group(la), Some(group));

    for (lane, base) in [(la, 0u64), (lb, 10u64)] {
        for model in 0..2 {
            assert_eq!(
                multi.offer(lane, seeded_request(base + model as u64, model, &[4])).unwrap(),
                Admit::Queued
            );
        }
    }
    let mut buf = Vec::new();
    let d = multi.dispatch_next(&mut buf).unwrap().unwrap();
    assert_eq!(d.lanes_served, 2, "both lanes must ride ONE merged round");
    assert_eq!(d.responses, 4);
    assert!(!d.urgent);
    assert_eq!(buf.len(), 4);
    // responses echo their own payloads through the slot remap
    for r in &buf {
        let want = seeded_request(r.id, r.model_idx, &[4]);
        assert_eq!(r.output.data(), want.input.data(), "id {} routed wrong", r.id);
    }
    // metrics attribution is per lane
    let stats = multi.group_stats(group);
    assert_eq!((stats.rounds, stats.responses), (1, 4));
    assert_eq!(multi.lane(la).metrics.completed_requests, 2);
    assert_eq!(multi.lane(lb).metrics.completed_requests, 2);
    assert_eq!(multi.lane(la).metrics.round_latency.count(), 1);
    assert_eq!(multi.pending(), 0);
    assert!(multi.dispatch_next(&mut buf).unwrap().is_none());
}

#[test]
fn partial_lane_piggybacks_on_a_ready_member() {
    // lane B's round is NOT batching-ready (1 of 2 slots, huge
    // max_wait), but lane A's is: the merged round serves B's front
    // early — its window would otherwise run as pad
    let a = echo("bert", 2, Duration::ZERO);
    let b = echo("bert", 2, Duration::ZERO);
    let g = echo("bert", 4, Duration::ZERO);
    let mut multi = MultiServer::new();
    let la = multi.add_lane(&a, lane_config());
    let lb = multi.add_lane(&b, ServerConfig { max_wait: FAR, ..lane_config() });
    multi.add_coalesce_group(&g, &[la, lb]).unwrap();

    for model in 0..2 {
        multi.offer(la, seeded_request(model as u64, model, &[4])).unwrap();
    }
    multi.offer(lb, seeded_request(9, 0, &[4])).unwrap();
    let mut buf = Vec::new();
    let d = multi.dispatch_next(&mut buf).unwrap().unwrap();
    assert_eq!(d.lane, la, "only lane A was round-ready");
    assert_eq!(d.lanes_served, 2);
    assert_eq!(d.responses, 3, "B's partial round rides along");
    assert_eq!(multi.lane(lb).pending(), 0);
}

#[test]
fn failed_merged_round_requeues_every_member_in_fifo_order() {
    let a = FailingEcho::new("bert", 2, &[4]);
    let b = FailingEcho::new("bert", 2, &[4]);
    let g = FailingEcho::new("bert", 4, &[4]);
    let mut multi: MultiServer<FailingEcho> = MultiServer::new();
    let la = multi.add_lane(&a, lane_config());
    let lb = multi.add_lane(&b, lane_config());
    let group = multi.add_coalesce_group(&g, &[la, lb]).unwrap();

    // two requests deep on every model queue of both lanes
    let mut id = 0u64;
    for lane in [la, lb] {
        for model in 0..2 {
            for _ in 0..2 {
                multi.offer(lane, seeded_request(id, model, &[4])).unwrap();
                id += 1;
            }
        }
    }
    g.fail_rounds(1);
    let mut buf = Vec::new();
    let err = multi.dispatch_next(&mut buf).unwrap_err();
    assert!(err.to_string().contains("injected round failure"), "got: {err}");
    assert_eq!(multi.pending(), 8, "failed merged round must not drop requests");
    assert_eq!(multi.group_stats(group).rounds, 0);

    // retry: round 1 returns the ORIGINAL fronts of both lanes, round 2
    // the tails — per-lane FIFO survived the remap
    let d = multi.dispatch_next(&mut buf).unwrap().unwrap();
    assert_eq!((d.lanes_served, d.responses), (2, 4));
    assert_eq!(common::sorted_ids(&buf), vec![0, 2, 4, 6]);
    buf.clear();
    let d = multi.dispatch_next(&mut buf).unwrap().unwrap();
    assert_eq!((d.lanes_served, d.responses), (2, 4));
    assert_eq!(common::sorted_ids(&buf), vec![1, 3, 5, 7]);
    assert_eq!(multi.pending(), 0);
}

// ---------------------------------------------------------------------------
// negative paths: what must NOT coalesce
// ---------------------------------------------------------------------------

/// Satellite: lanes with mismatched request shapes or slot counts must
/// never coalesce — group formation rejects them.
#[test]
fn mismatched_lanes_never_coalesce() {
    let base = echo("bert", 2, Duration::ZERO);
    let wide = EchoExecutor::new("bert", 2, &[8], Duration::ZERO);
    let tall = echo("bert", 3, Duration::ZERO);
    let other = echo("resnet", 2, Duration::ZERO);
    let g4 = echo("bert", 4, Duration::ZERO);

    // mismatched request shape
    let mut multi = MultiServer::new();
    let l0 = multi.add_lane(&base, lane_config());
    let l1 = multi.add_lane(&wide, lane_config());
    let err = multi.add_coalesce_group(&g4, &[l0, l1]).unwrap_err();
    assert!(err.to_string().contains("cannot coalesce"), "got: {err}");
    assert!(multi.lane_group(l0).is_none(), "rejected group must not claim lanes");

    // mismatched slot count
    let mut multi = MultiServer::new();
    let l0 = multi.add_lane(&base, lane_config());
    let l1 = multi.add_lane(&tall, lane_config());
    assert!(multi.add_coalesce_group(&g4, &[l0, l1]).is_err());

    // mismatched family
    let mut multi = MultiServer::new();
    let l0 = multi.add_lane(&base, lane_config());
    let l1 = multi.add_lane(&other, lane_config());
    assert!(multi.add_coalesce_group(&g4, &[l0, l1]).is_err());

    // a lane cannot join two groups; unknown/duplicate lanes rejected
    let base2 = echo("bert", 2, Duration::ZERO);
    let mut multi = MultiServer::new();
    let l0 = multi.add_lane(&base, lane_config());
    let l1 = multi.add_lane(&base2, lane_config());
    assert!(multi.add_coalesce_group(&g4, &[l0, l0]).is_err());
    assert!(multi.add_coalesce_group(&g4, &[l0, 7]).is_err());
    multi.add_coalesce_group(&g4, &[l0, l1]).unwrap();
    assert!(multi.add_coalesce_group(&g4, &[l0, l1]).is_err());
}

#[test]
fn auto_coalesce_groups_only_matching_lanes() {
    let a = echo("bert", 2, Duration::ZERO);
    let wide = EchoExecutor::new("bert", 2, &[8], Duration::ZERO);
    let b = echo("bert", 2, Duration::ZERO);
    let g4 = echo("bert", 4, Duration::ZERO);
    let mut multi = MultiServer::new();
    let l0 = multi.add_lane(&a, lane_config());
    let l1 = multi.add_lane(&wide, lane_config());
    let l2 = multi.add_lane(&b, lane_config());
    let group = multi.auto_coalesce(&g4).unwrap().expect("two matching lanes");
    assert_eq!(multi.group_members(group), &[l0, l2], "mismatched lane skipped");
    assert!(multi.lane_group(l1).is_none());

    // fewer than two matching lanes -> no group
    let lonely = echo("gpt", 2, Duration::ZERO);
    let g_lonely = echo("gpt", 4, Duration::ZERO);
    let mut multi = MultiServer::new();
    multi.add_lane(&lonely, lane_config());
    assert!(multi.auto_coalesce(&g_lonely).unwrap().is_none());
}

/// Satellite: an SLO-boosted lane dispatches solo rather than waiting
/// on (or padding out) group fill.
#[test]
fn slo_boosted_lane_dispatches_solo() {
    let tight = echo("bert", 2, Duration::ZERO);
    let bulk = echo("bert", 2, Duration::ZERO);
    let g = echo("bert", 4, Duration::ZERO);
    let mut multi = MultiServer::new();
    // tight: partial rounds never batching-ready, 40ms SLO
    let lt = multi.add_lane_qos(
        &tight,
        ServerConfig { max_wait: FAR, ..lane_config() },
        LaneQos::new(1, Duration::from_millis(40)),
    );
    let lb = multi.add_lane_qos(&bulk, lane_config(), LaneQos::new(8, FAR));
    let group = multi.add_coalesce_group(&g, &[lt, lb]).unwrap();

    multi.offer(lt, seeded_request(0, 0, &[4])).unwrap();
    for model in 0..2 {
        multi.offer(lb, seeded_request(10 + model as u64, model, &[4])).unwrap();
    }
    // cross into the boost window
    std::thread::sleep(Duration::from_millis(50));
    let mut buf = Vec::new();
    let d = multi.dispatch_next(&mut buf).unwrap().unwrap();
    assert_eq!(d.lane, lt, "SLO-urgent lane preempts");
    assert!(d.urgent);
    assert_eq!(d.lanes_served, 1, "urgent pick must NOT wait on group fill");
    assert_eq!(d.responses, 1);
    assert_eq!(multi.lane(lb).pending(), 2, "bulk lane untouched by the solo round");
    assert_eq!(multi.group_stats(group).rounds, 0);

    // with the urgency served, the next pick coalesces... but only one
    // lane still holds work, so it stays solo on the lane's own executor
    let d = multi.dispatch_next(&mut buf).unwrap().unwrap();
    assert_eq!((d.lane, d.lanes_served), (lb, 1));
}

// ---------------------------------------------------------------------------
// drain + offer interleaving sanity under a coalescing config
// ---------------------------------------------------------------------------

#[test]
fn drain_flushes_grouped_lanes_with_merged_rounds() {
    // REGRESSION (group-aware drain): the shutdown flush bypasses
    // batching readiness but NOT coalescing — live group members flush
    // together as ONE merged round, so even the final partial rounds
    // amortize the merged program's launch (the old drain dispatched
    // solo per lane, paying one launch per member)
    let a = echo("bert", 2, Duration::ZERO);
    let b = echo("bert", 2, Duration::ZERO);
    let g = echo("bert", 4, Duration::ZERO);
    let mut multi = MultiServer::new();
    let la = multi.add_lane(&a, ServerConfig { max_wait: FAR, ..lane_config() });
    let lb = multi.add_lane(&b, ServerConfig { max_wait: FAR, ..lane_config() });
    let group = multi.add_coalesce_group(&g, &[la, lb]).unwrap();
    multi.offer(la, seeded_request(1, 0, &[4])).unwrap();
    multi.offer(lb, seeded_request(2, 1, &[4])).unwrap();
    let mut buf = Vec::new();
    let n = multi.drain(&mut buf).unwrap();
    assert_eq!(n, 2);
    assert_eq!(multi.pending(), 0);
    assert_eq!(
        multi.group_stats(group).rounds,
        1,
        "shutdown flush must coalesce live group members"
    );
    // responses are intact: both seeded payloads came back
    let mut ids: Vec<u64> = buf.iter().map(|r| r.id).collect();
    ids.sort();
    assert_eq!(ids, vec![1, 2]);

    // a group with a single live member still flushes solo (merging a
    // one-lane round would only pad the other members' windows)
    multi.offer(la, seeded_request(3, 0, &[4])).unwrap();
    buf.clear();
    assert_eq!(multi.drain(&mut buf).unwrap(), 1);
    assert_eq!(
        multi.group_stats(group).rounds,
        1,
        "a lone live member must not dispatch a merged round"
    );

    // an empty multi drains to Ok(0) — the scan simply finds no work
    buf.clear();
    assert_eq!(multi.drain(&mut buf).unwrap(), 0);
}

// ---------------------------------------------------------------------------
// rider deficit charging: weighted shares under full coalescing
// ---------------------------------------------------------------------------

#[test]
fn rider_charging_holds_weighted_shares_under_full_coalescing() {
    // REGRESSION (merged-round fairness): 8 lanes — two standalone
    // (weights 3 and 1) next to three coalesce groups of two (weight 1
    // per lane). Saturated, with zero max_wait, every group dispatch is
    // a merged round ("full coalescing"): the pick's group mate is
    // always served as a rider. Before riders were charged, each
    // grouped lane was served on BOTH members' credits — double its
    // weighted share (measured shares came out ~3:1:2:2:2:2:2:2).
    // With `commit_served` charging every served lane for the slots it
    // consumed, per-lane served-request shares must track
    // 3:1:1:1:1:1:1:1 within 5%. Lanes are single-model (m = 1) so a
    // round serves exactly one slot per live lane and the accounting
    // below is exact.
    let standalone: Vec<EchoExecutor> =
        (0..2).map(|_| echo("solo", 1, Duration::ZERO)).collect();
    let grouped: Vec<EchoExecutor> =
        (0..6).map(|_| echo("bert", 1, Duration::ZERO)).collect();
    let gexecs: Vec<EchoExecutor> = (0..3).map(|_| echo("bert", 2, Duration::ZERO)).collect();

    let mut multi = MultiServer::new();
    let weights: Vec<u32> = vec![3, 1, 1, 1, 1, 1, 1, 1];
    multi.add_lane_qos(&standalone[0], lane_config(), LaneQos::new(weights[0], FAR));
    multi.add_lane_qos(&standalone[1], lane_config(), LaneQos::new(weights[1], FAR));
    for (k, exec) in grouped.iter().enumerate() {
        let l = multi.add_lane_qos(exec, lane_config(), LaneQos::new(weights[2 + k], FAR));
        assert_eq!(l, 2 + k);
    }
    for (gi, gexec) in gexecs.iter().enumerate() {
        multi.add_coalesce_group(gexec, &[2 + 2 * gi, 3 + 2 * gi]).unwrap();
    }

    // saturated drive: every lane's queue stays topped up, so
    // scheduling alone decides who is served
    let mut id = 0u64;
    let mut buf = Vec::new();
    let mut served = vec![0u64; 8];
    let mut merged_rounds = 0u64;
    for _ in 0..2000 {
        for lane in 0..8 {
            while multi.lane(lane).pending() < 2 {
                multi.offer(lane, seeded_request(id, 0, &[4])).unwrap();
                id += 1;
            }
        }
        let d = multi.dispatch_next(&mut buf).unwrap().expect("saturated lanes dispatch");
        buf.clear();
        // solo round: one slot on the picked lane; merged round: one
        // slot per member (every lane is saturated, so all members are
        // live and fully occupied)
        if d.lanes_served == 1 {
            assert_eq!(d.responses, 1);
            served[d.lane] += 1;
        } else {
            merged_rounds += 1;
            let g = multi.lane_group(d.lane).expect("merged pick is grouped");
            assert_eq!(d.responses, multi.group_members(g).len());
            for &l in multi.group_members(g) {
                served[l] += 1;
            }
        }
    }
    assert!(
        merged_rounds > 500,
        "saturated grouped lanes must dispatch merged rounds, got {merged_rounds}"
    );

    let total: f64 = served.iter().sum::<u64>() as f64;
    let weight_sum: f64 = weights.iter().sum::<u32>() as f64;
    for lane in 0..8 {
        let got = served[lane] as f64 / total;
        let want = weights[lane] as f64 / weight_sum;
        assert!(
            (got - want).abs() / want <= 0.05,
            "lane {lane}: share {got:.4}, want {want:.4} (weights {weights:?}, served {served:?})"
        );
    }
}
