//! Elastic topology (ADR-005): lane lifecycle guards, the churn-storm
//! property harness, group-aware drain under member excision, sibling
//! in-flight non-disruption (via the `ArenaRing` gauge), WDRR share
//! re-convergence after removal, and the full control-plane integration
//! over `run_dispatch_elastic` with live traffic.
//!
//! Everything is artifact-free (`EchoExecutor` / ring-staged `RingEcho`
//! lanes); the throughput/latency side of elastic churn is gated by
//! `benches/elastic_churn.rs`.

mod common;

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use common::{drain_all, echo, payload, seeded_request, RingEcho};
use netfuse::coordinator::arena::{ArenaRing, Layout};
use netfuse::coordinator::control::{ControlPlane, TopologyController};
use netfuse::coordinator::mock::{EchoExecutor, SWAP_SCALE};
use netfuse::coordinator::multi::{
    GroupSpec, LaneLife, LaneSpec, MultiServer, ParallelDispatcher,
};
use netfuse::coordinator::request::{Request, Response};
use netfuse::coordinator::server::{Admit, ServerConfig};
use netfuse::coordinator::StrategyKind;
use netfuse::ingress::{
    run_dispatch_elastic, Envelope, Frame, FrameQueue, IngressBridge, IngressStats, LaneQos,
    RejectCode,
};
use netfuse::util::rng::Rng;
use netfuse::util::shard::Sharded;

const FAR: Duration = Duration::from_secs(3600);

fn cfg() -> ServerConfig {
    ServerConfig {
        strategy: StrategyKind::NetFuse,
        queue_cap: 4096,
        max_wait: Duration::ZERO,
    }
}

fn qos1() -> LaneQos {
    LaneQos::new(1, FAR)
}

/// The seeded payload element `j` of request `(id, model)` — what an
/// unswapped echo lane must return byte-for-byte.
fn seeded_at(id: u64, model: usize, j: usize) -> f32 {
    id as f32 * 1000.0 + model as f32 * 10.0 + j as f32
}

// ---------------------------------------------------------------------------
// lifecycle guards + retired-slot reuse (deterministic)
// ---------------------------------------------------------------------------

#[test]
fn lane_lifecycle_guards_and_slot_reuse() {
    let a = echo("a", 2, Duration::ZERO);
    let b = echo("b", 2, Duration::ZERO);
    let c = echo("c", 2, Duration::ZERO);
    let mut multi: MultiServer<EchoExecutor> = MultiServer::new();
    multi.add_lane(&a, cfg());
    let (slot_b, attached) = multi.install_lane(&b, cfg(), qos1(), 0).unwrap();
    assert_eq!(slot_b, 1);
    assert!(attached.is_none());
    assert_eq!(multi.live_lanes(), 2);
    multi.offer(slot_b, seeded_request(0, 0, &[4])).unwrap();

    // draining: no admission, not ready while pending, cannot finish
    // early, cannot retire twice
    multi.begin_retire(slot_b).unwrap();
    assert_eq!(multi.lane_life(slot_b), LaneLife::Draining);
    assert!(multi.offer(slot_b, seeded_request(1, 0, &[4])).is_err());
    assert!(!multi.retire_ready(slot_b));
    assert!(multi.finish_retire(slot_b).is_err());
    assert!(multi.begin_retire(slot_b).is_err());

    let mut buf: Vec<Response> = Vec::new();
    drain_all(&mut multi, &mut buf).unwrap();
    assert_eq!(buf.len(), 1, "queued request drains through normal dispatch");
    assert!(multi.retire_ready(slot_b));
    multi.finish_retire(slot_b).unwrap();
    assert_eq!(multi.lane_life(slot_b), LaneLife::Retired);
    assert!(multi.offer(slot_b, seeded_request(2, 0, &[4])).is_err());
    assert!(multi.swap_lane_model(slot_b, 1).is_err(), "retired lane cannot swap");
    assert_eq!(multi.live_lanes(), 1);

    // reuse: the SAME slot comes back with a fresh life and no stale
    // swap offset from the previous tenant
    let (slot_c, attached) = multi.install_lane(&c, cfg(), LaneQos::new(2, FAR), 0).unwrap();
    assert_eq!(slot_c, slot_b, "retired slot must be reused");
    assert!(attached.is_none());
    assert_eq!(multi.lane_life(slot_c), LaneLife::Live);
    assert_eq!(multi.lanes(), 2, "reuse must not grow the slot table");
    multi.offer(slot_c, seeded_request(3, 1, &[4])).unwrap();
    buf.clear();
    drain_all(&mut multi, &mut buf).unwrap();
    assert_eq!(buf.len(), 1);
    assert_eq!(buf[0].output.data()[0], seeded_at(3, 1, 0));
}

// ---------------------------------------------------------------------------
// hot-swap semantics: versions follow the LANE across membership churn
// ---------------------------------------------------------------------------

#[test]
fn grouped_swap_follows_the_lane_across_membership_churn() {
    let a = echo("bert", 2, Duration::ZERO);
    let b = echo("bert", 2, Duration::ZERO);
    let c = echo("bert", 2, Duration::ZERO);
    let g = echo("bert", 4, Duration::ZERO);
    let mut multi: MultiServer<EchoExecutor> = MultiServer::new();
    multi.add_lane(&a, cfg());
    multi.add_lane(&b, cfg());
    multi.add_coalesce_group(&g, &[0, 1]).unwrap();

    // swap lane 1 only: its own executor AND its megabatch window
    let pause = multi.swap_lane_model(1, 5).unwrap();
    assert!(pause < Duration::from_secs(1));

    let mut buf: Vec<Response> = Vec::new();
    for model in 0..2 {
        multi.offer(0, seeded_request(model as u64, model, &[4])).unwrap();
        multi.offer(1, seeded_request(10 + model as u64, model, &[4])).unwrap();
    }
    let d = multi.dispatch_next(&mut buf).unwrap().unwrap();
    assert_eq!(d.lanes_served, 2, "both members merged");
    assert_eq!(buf.len(), 4);
    for r in buf.drain(..) {
        let offset = if r.id >= 10 { 5.0 * SWAP_SCALE } else { 0.0 };
        let base = if r.id >= 10 { r.id - 10 } else { r.id } as usize; // model
        for (j, &x) in r.output.data().iter().enumerate() {
            assert_eq!(
                x,
                seeded_at(r.id, base, j) + offset,
                "id {} served by the wrong weight version",
                r.id
            );
        }
    }

    // excise lane 0: lane 1's window shifts left and must carry its
    // version with it
    multi.begin_retire(0).unwrap();
    assert!(multi.retire_ready(0), "lane 0 is already empty");
    multi.finish_retire(0).unwrap();
    assert_eq!(multi.group_members(0), &[1]);

    // install a third bert lane: it reuses the retired slot, attaches to
    // the group, and its window — previously stamped with lane 1's tag —
    // must be re-stamped back to factory weights
    let (slot, attached) = multi.install_lane(&c, cfg(), qos1(), 0).unwrap();
    assert_eq!(slot, 0);
    assert_eq!(attached, Some(0));
    assert_eq!(multi.group_members(0), &[1, 0]);

    for model in 0..2 {
        multi.offer(1, seeded_request(20 + model as u64, model, &[4])).unwrap();
        multi.offer(slot, seeded_request(30 + model as u64, model, &[4])).unwrap();
    }
    let d = multi.dispatch_next(&mut buf).unwrap().unwrap();
    assert_eq!(d.lanes_served, 2, "survivor + newcomer merge");
    assert_eq!(buf.len(), 4);
    for r in buf.drain(..) {
        let (offset, model) = if r.id >= 30 {
            (0.0, (r.id - 30) as usize) // newcomer: factory weights
        } else {
            (5.0 * SWAP_SCALE, (r.id - 20) as usize) // survivor: version 5
        };
        for (j, &x) in r.output.data().iter().enumerate() {
            assert_eq!(
                x,
                seeded_at(r.id, model, j) + offset,
                "id {} lost its lane's weight version across churn",
                r.id
            );
        }
    }
}

// ---------------------------------------------------------------------------
// churn storm: randomized add/remove/swap against a churn-free oracle
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Target {
    /// lane present for the whole run (0,1 = coalesced bert pair, 2 = solo)
    Whole(usize),
    /// churny pool slot `k` — installed/retired/swapped at random
    Churn(usize),
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    Offer { target: Target, model: usize, id: u64 },
    Dispatch,
    Install(usize),
    Retire(usize),
    Swap { k: usize, tag: u64 },
}

/// A deterministic event schedule: ids and swap tags are assigned at
/// generation time so the storm run and the churn-free oracle run see
/// IDENTICAL arrivals for the whole-run lanes.
fn schedule(rng: &mut Rng, events: usize) -> Vec<Ev> {
    let mut id = 0u64;
    let mut tag = 0u64;
    let mut evs = Vec::with_capacity(events);
    for _ in 0..events {
        let r = rng.below(100);
        if r < 50 {
            let target = if rng.below(100) < 60 {
                Target::Whole(rng.usize_below(3))
            } else {
                Target::Churn(rng.usize_below(3))
            };
            evs.push(Ev::Offer { target, model: rng.usize_below(2), id });
            id += 1;
        } else if r < 80 {
            evs.push(Ev::Dispatch);
        } else {
            let k = rng.usize_below(3);
            match rng.below(3) {
                0 => evs.push(Ev::Install(k)),
                1 => evs.push(Ev::Retire(k)),
                _ => {
                    tag += 1;
                    evs.push(Ev::Swap { k, tag });
                }
            }
        }
    }
    evs
}

/// Fresh executors per run: churny `EchoExecutor`s carry per-slot weight
/// versions, so they must not leak state across runs or seeds.
struct Pool {
    whole: Vec<EchoExecutor>,
    group: EchoExecutor,
    churn: Vec<EchoExecutor>,
}

fn pool() -> Pool {
    Pool {
        whole: vec![
            echo("bert", 2, Duration::ZERO),
            echo("bert", 2, Duration::ZERO),
            echo("solo", 2, Duration::ZERO),
        ],
        group: echo("bert", 4, Duration::ZERO),
        // distinct families so churny lanes never join the bert group
        churn: (0..3).map(|k| echo(&format!("churn{k}"), 2, Duration::ZERO)).collect(),
    }
}

/// Per-(whole-run lane, model) FIFO response streams — the byte-level
/// oracle surface.
type WholeStreams = HashMap<(usize, usize), Vec<(u64, Vec<f32>)>>;

/// Consume a response batch: every response must match exactly one
/// still-pending admission (no drops, no double-serves), carry the
/// seeded payload (no misroutes/corruption), and — for churny lanes —
/// a weight-version offset that is an exact, monotone multiple of
/// [`SWAP_SCALE`].
fn absorb(
    buf: &mut Vec<Response>,
    pending: &mut HashMap<u64, (Target, usize)>,
    streams: &mut WholeStreams,
    last_v: &mut [u64; 3],
) {
    for r in buf.drain(..) {
        let (target, model) = pending
            .remove(&r.id)
            .expect("response for an id never admitted, or served twice");
        assert_eq!(r.model_idx, model, "id {} answered under the wrong model", r.id);
        let out = r.output.data();
        assert_eq!(out.len(), 4);
        match target {
            Target::Whole(l) => {
                for (j, &x) in out.iter().enumerate() {
                    assert_eq!(
                        x,
                        seeded_at(r.id, model, j),
                        "corrupted payload for id {} on whole-run lane {l}",
                        r.id
                    );
                }
                streams.entry((l, model)).or_default().push((r.id, out.to_vec()));
            }
            Target::Churn(k) => {
                let delta = out[0] - seeded_at(r.id, model, 0);
                for (j, &x) in out.iter().enumerate() {
                    assert_eq!(
                        x - seeded_at(r.id, model, j),
                        delta,
                        "inconsistent swap offset within id {}",
                        r.id
                    );
                }
                let v = delta / SWAP_SCALE;
                assert!(
                    v >= 0.0 && v.fract() == 0.0,
                    "offset {delta} is not a whole weight version"
                );
                let v = v as u64;
                assert!(
                    v >= last_v[k],
                    "weight version went backwards on churn slot {k}: {v} < {}",
                    last_v[k]
                );
                last_v[k] = v;
            }
        }
    }
}

/// Excise every draining churny lane that has fully drained.
fn finish_ready(
    multi: &mut MultiServer<'_, EchoExecutor>,
    churn_lane: &mut [Option<usize>; 3],
    draining: &mut [bool; 3],
) {
    for k in 0..3 {
        if !draining[k] {
            continue;
        }
        let slot = churn_lane[k].expect("draining implies installed");
        if multi.retire_ready(slot) {
            multi.finish_retire(slot).unwrap();
            assert_eq!(multi.lane_life(slot), LaneLife::Retired);
            churn_lane[k] = None;
            draining[k] = false;
        }
    }
}

/// Run one schedule. `churn = false` is the oracle: churn events (and
/// offers to churny lanes) are skipped, whole-run arrivals are
/// identical. Returns the whole-run lanes' FIFO streams.
fn run_storm(pool: &Pool, evs: &[Ev], churn: bool) -> WholeStreams {
    let mut multi: MultiServer<'_, EchoExecutor> = MultiServer::new();
    for x in &pool.whole {
        multi.add_lane_qos(x, cfg(), qos1());
    }
    multi.add_coalesce_group(&pool.group, &[0, 1]).unwrap();

    let mut churn_lane: [Option<usize>; 3] = [None; 3];
    let mut draining: [bool; 3] = [false; 3];
    let mut last_v: [u64; 3] = [0; 3];
    let mut pending: HashMap<u64, (Target, usize)> = HashMap::new();
    let mut streams: WholeStreams = HashMap::new();
    let mut buf: Vec<Response> = Vec::new();

    for ev in evs {
        match *ev {
            Ev::Offer { target, model, id } => {
                let slot = match target {
                    Target::Whole(l) => Some(l),
                    Target::Churn(k) if churn => {
                        churn_lane[k].filter(|&s| multi.lane_life(s) == LaneLife::Live)
                    }
                    Target::Churn(_) => None, // oracle has no churny lanes
                };
                if let Some(slot) = slot {
                    let admit = multi.offer(slot, seeded_request(id, model, &[4])).unwrap();
                    assert!(matches!(admit, Admit::Queued));
                    pending.insert(id, (target, model));
                }
            }
            Ev::Dispatch => {
                multi.dispatch_next(&mut buf).unwrap();
                absorb(&mut buf, &mut pending, &mut streams, &mut last_v);
                if churn {
                    finish_ready(&mut multi, &mut churn_lane, &mut draining);
                }
            }
            Ev::Install(k) if churn => {
                if churn_lane[k].is_none() {
                    let (slot, attached) =
                        multi.install_lane(&pool.churn[k], cfg(), qos1(), 0).unwrap();
                    assert!(attached.is_none(), "churn lane joined the bert group");
                    assert_eq!(multi.lane_life(slot), LaneLife::Live);
                    churn_lane[k] = Some(slot);
                }
            }
            Ev::Retire(k) if churn => {
                if let Some(slot) = churn_lane[k] {
                    if multi.lane_life(slot) == LaneLife::Live {
                        multi.begin_retire(slot).unwrap();
                        draining[k] = true;
                    }
                }
            }
            Ev::Swap { k, tag } if churn => {
                if let Some(slot) = churn_lane[k] {
                    multi.swap_lane_model(slot, tag).unwrap();
                }
            }
            _ => {} // churn event skipped by the oracle run
        }
    }

    drain_all(&mut multi, &mut buf).unwrap();
    absorb(&mut buf, &mut pending, &mut streams, &mut last_v);
    if churn {
        finish_ready(&mut multi, &mut churn_lane, &mut draining);
        assert!(draining.iter().all(|&d| !d), "a drained lane failed to excise");
        let installed = churn_lane.iter().filter(|s| s.is_some()).count();
        assert_eq!(multi.live_lanes(), 3 + installed, "lifecycle accounting drifted");
    }
    assert_eq!(multi.pending(), 0);
    assert!(
        pending.is_empty(),
        "admitted requests were dropped: {:?}",
        pending.keys().collect::<Vec<_>>()
    );
    streams
}

#[test]
fn churn_storm_matches_churn_free_oracle() {
    // 120 seeds x 160 events: random install/retire/swap interleaved
    // with seeded traffic. The whole-run lanes' per-(lane, model) FIFO
    // streams must be byte-identical to a run with NO churn at all;
    // every admitted request (churny lanes included) gets exactly one
    // correctly-attributed response.
    for seed in 0..120u64 {
        let mut rng = Rng::new(0xE1A5_7100 + seed);
        let evs = schedule(&mut rng, 160);
        let storm_pool = pool();
        let got = run_storm(&storm_pool, &evs, true);
        let oracle_pool = pool();
        let want = run_storm(&oracle_pool, &evs, false);
        assert_eq!(
            want, got,
            "whole-run lane streams diverged under churn (seed {seed})"
        );
    }
}

// ---------------------------------------------------------------------------
// group-aware drain under churn (satellite 3)
// ---------------------------------------------------------------------------

#[test]
fn merged_flush_continues_across_member_excision() {
    let lanes: Vec<EchoExecutor> = (0..3).map(|_| echo("bert", 2, Duration::ZERO)).collect();
    let g = echo("bert", 6, Duration::ZERO);
    let mut multi: MultiServer<'_, EchoExecutor> = MultiServer::new();
    for x in &lanes {
        multi.add_lane_qos(x, cfg(), qos1());
    }
    multi.add_coalesce_group(&g, &[0, 1, 2]).unwrap();

    let mut pending: HashMap<u64, (Target, usize)> = HashMap::new();
    let mut streams: WholeStreams = HashMap::new();
    let mut last_v = [0u64; 3];
    let mut buf: Vec<Response> = Vec::new();
    let mut id = 0u64;
    for lane in 0..3usize {
        for model in 0..2 {
            for _ in 0..3 {
                multi.offer(lane, seeded_request(id, model, &[4])).unwrap();
                pending.insert(id, (Target::Whole(lane), model));
                id += 1;
            }
        }
    }

    // quiesce lane 1 mid-backlog: merged rounds must keep flushing all
    // three members (the drainer rides along) and the group counters
    // must stay monotone — no underflow when membership shrinks
    multi.begin_retire(1).unwrap();
    let mut prev = multi.group_stats(0);
    while !multi.retire_ready(1) {
        let d = multi.dispatch_next(&mut buf).unwrap().expect("backlog pending");
        assert!(d.lanes_served >= 2, "backlogged group members must merge");
        absorb(&mut buf, &mut pending, &mut streams, &mut last_v);
        let now = multi.group_stats(0);
        assert!(
            now.rounds >= prev.rounds && now.responses >= prev.responses,
            "group counters went backwards: {now:?} after {prev:?}"
        );
        prev = now;
    }
    assert!(prev.rounds >= 3, "draining a 6-deep backlog takes >= 3 merged rounds");
    multi.finish_retire(1).unwrap();
    assert_eq!(multi.group_members(0), &[0, 2]);
    assert_eq!(multi.lane_life(1), LaneLife::Retired);

    // survivors keep merging after the excision
    for lane in [0usize, 2] {
        for model in 0..2 {
            multi.offer(lane, seeded_request(id, model, &[4])).unwrap();
            pending.insert(id, (Target::Whole(lane), model));
            id += 1;
        }
    }
    let before = multi.group_stats(0).rounds;
    let d = multi.dispatch_next(&mut buf).unwrap().unwrap();
    assert_eq!(d.lanes_served, 2, "survivors stopped merging after excision");
    assert_eq!(multi.group_stats(0).rounds, before + 1);
    absorb(&mut buf, &mut pending, &mut streams, &mut last_v);

    drain_all(&mut multi, &mut buf).unwrap();
    absorb(&mut buf, &mut pending, &mut streams, &mut last_v);
    assert!(pending.is_empty(), "requests dropped during group churn");
    let stats = multi.group_stats(0);
    assert_eq!(stats.responses, 22, "every request flushed through merged rounds");
}

// ---------------------------------------------------------------------------
// sibling non-disruption: churn next to an in-flight ring round
// ---------------------------------------------------------------------------

#[test]
fn sibling_in_flight_round_survives_churn() {
    // partition A stages its round through a shared ArenaRing with a
    // long modeled device time; partition B churns (retire + reinstall)
    // while A's reservation is held. The ring gauge proves A's round is
    // never disturbed: its reservation survives the churn and its
    // outputs come back intact.
    let ring = Arc::new(ArenaRing::new(Layout::Batch, 2, &[1, 4], 2).unwrap());
    let slow = RingEcho::new("sib", Arc::clone(&ring), Duration::from_millis(200));
    let mut a: MultiServer<'_, RingEcho> = MultiServer::new();
    a.add_lane(&slow, cfg());
    a.offer(0, seeded_request(0, 0, &[4])).unwrap();
    a.offer(0, seeded_request(1, 1, &[4])).unwrap();

    let b0 = echo("b0", 2, Duration::ZERO);
    let fresh = echo("fresh", 2, Duration::ZERO);
    let mut b: MultiServer<'_, EchoExecutor> = MultiServer::new();
    b.add_lane(&b0, cfg());
    for model in 0..2u64 {
        b.offer(0, seeded_request(10 + model, model as usize, &[4])).unwrap();
    }

    std::thread::scope(|s| {
        let t = s.spawn(|| {
            let mut buf = Vec::new();
            let d = a.dispatch_next(&mut buf).unwrap().unwrap();
            (d, buf)
        });

        // wait for A's round to take its ring reservation
        let deadline = Instant::now() + Duration::from_secs(5);
        while ring.in_flight() == 0 {
            assert!(Instant::now() < deadline, "round never reached the ring");
            std::thread::yield_now();
        }

        // full churn cycle on partition B while A's round is in flight
        let mut buf = Vec::new();
        b.begin_retire(0).unwrap();
        while !b.retire_ready(0) {
            b.dispatch_next(&mut buf).unwrap();
        }
        b.finish_retire(0).unwrap();
        let (slot, attached) = b.install_lane(&fresh, cfg(), qos1(), 0).unwrap();
        assert_eq!(slot, 0, "retired slot is reused");
        assert!(attached.is_none());
        assert_eq!(buf.len(), 2, "partition B drained its own lane");

        assert_eq!(
            ring.in_flight(),
            1,
            "sibling churn disturbed the in-flight round's reservation"
        );

        let (d, buf_a) = t.join().unwrap();
        assert_eq!(d.lanes_served, 1);
        assert_eq!(buf_a.len(), 2);
        for r in &buf_a {
            for (j, &x) in r.output.data().iter().enumerate() {
                assert_eq!(x, seeded_at(r.id, r.model_idx, j), "staged round corrupted");
            }
        }
    });
    assert_eq!(ring.in_flight(), 0, "reservation leaked");
}

// ---------------------------------------------------------------------------
// WDRR share re-convergence after removal
// ---------------------------------------------------------------------------

/// Like `common::dispatch_saturated`, but only tops up Live lanes so it
/// keeps working across retirement.
fn saturate_live(
    multi: &mut MultiServer<'_, EchoExecutor>,
    rounds: usize,
    next_id: &mut u64,
) -> Vec<usize> {
    let mut order = Vec::with_capacity(rounds);
    let mut buf = Vec::new();
    for _ in 0..rounds {
        for lane in 0..multi.lanes() {
            if multi.lane_life(lane) != LaneLife::Live {
                continue;
            }
            for model in 0..multi.lane(lane).fleet().m() {
                while multi.lane(lane).pending() < 4 {
                    multi.offer(lane, Request::new(*next_id, model, payload())).unwrap();
                    *next_id += 1;
                }
            }
        }
        let d = multi
            .dispatch_next(&mut buf)
            .unwrap()
            .expect("saturated lanes are always dispatchable");
        buf.clear();
        order.push(d.lane);
    }
    order
}

#[test]
fn surviving_shares_reconverge_after_removal() {
    // weights 3:1:1 over three standalone lanes; retire the heavy lane
    // and the survivors must re-converge to 1:1 within 5%
    let execs: Vec<EchoExecutor> =
        (0..3).map(|k| echo(&format!("w{k}"), 2, Duration::ZERO)).collect();
    let weights = [3u64, 1, 1];
    let mut multi: MultiServer<'_, EchoExecutor> = MultiServer::new();
    for (x, &w) in execs.iter().zip(&weights) {
        multi.add_lane_qos(x, cfg(), LaneQos::new(w, FAR));
    }

    let mut id = 0u64;
    let warm = saturate_live(&mut multi, 250, &mut id);
    let heavy = warm.iter().filter(|&&l| l == 0).count() as f64 / 250.0;
    assert!(
        (heavy - 0.6).abs() <= 0.05,
        "weight-3 lane took {heavy} of rounds, want ~0.6"
    );

    multi.begin_retire(0).unwrap();
    let mut buf = Vec::new();
    while !multi.retire_ready(0) {
        multi.dispatch_next(&mut buf).unwrap().expect("backlog pending");
        buf.clear();
    }
    multi.finish_retire(0).unwrap();

    let after = saturate_live(&mut multi, 400, &mut id);
    assert!(after.iter().all(|&l| l != 0), "retired lane was dispatched");
    for lane in [1usize, 2] {
        let share = after.iter().filter(|&&l| l == lane).count() as f64 / 400.0;
        assert!(
            (share - 0.5).abs() <= 0.05,
            "surviving lane {lane} share {share} did not re-converge to 0.5"
        );
    }
}

// ---------------------------------------------------------------------------
// full control plane over live parallel dispatch
// ---------------------------------------------------------------------------

/// What one submitted request must come back as.
#[derive(Debug, Clone, Copy)]
enum Want {
    Echo { lane: usize, model: usize, offset: f32 },
    NoLane { lane: usize },
}

fn await_frames(reply: &FrameQueue, n: usize, sink: &mut Vec<Frame>) {
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut got = 0;
    while got < n {
        if let Some(f) = reply.try_pop() {
            sink.push(f);
            got += 1;
            continue;
        }
        assert!(Instant::now() < deadline, "timed out waiting for {n} outcome frames");
        std::thread::sleep(Duration::from_millis(1));
    }
}

#[test]
fn elastic_control_plane_over_live_traffic() {
    const WAIT: Duration = Duration::from_secs(10);
    let bert0 = echo("bert", 2, Duration::ZERO);
    let bert1 = echo("bert", 2, Duration::ZERO);
    let group = echo("bert", 4, Duration::ZERO);
    let solo = echo("solo", 2, Duration::ZERO);
    let added = echo("fresh", 2, Duration::ZERO);

    let mut d = ParallelDispatcher::new(
        vec![
            LaneSpec::new(&bert0, cfg(), qos1()),
            LaneSpec::new(&bert1, cfg(), qos1()),
            LaneSpec::new(&solo, cfg(), qos1()),
        ],
        vec![GroupSpec::new(&group, &[0, 1])],
    )
    .unwrap(); // p0 = group {0,1}, p1 = solo
    let spare = d.add_spare_part(); // p2, laneless until the control plane fills it
    assert_eq!(spare, 2);
    let plane = Arc::new(ControlPlane::for_dispatcher(&d));
    let ctl = TopologyController::new(d.topology_handle(), Arc::clone(&plane));
    let stats: Arc<Sharded<IngressStats>> = Arc::new(Sharded::new(d.parts() + 1));
    let bridge = IngressBridge::new(4096);
    let reply = FrameQueue::new();

    let mut want: HashMap<u64, Want> = HashMap::new();
    let mut frames: Vec<Frame> = Vec::new();
    let mut epochs: Vec<u64> = Vec::new();

    std::thread::scope(|s| {
        let runner = s.spawn(|| run_dispatch_elastic(&mut d, &bridge, 1024, &stats, &plane));
        let submit = |id: u64, lane: usize, model: usize| {
            let env = Envelope {
                lane,
                client_id: id,
                req: seeded_request(id, model, &[4]),
                reply: reply.clone(),
            };
            assert!(bridge.submit(env).is_ok(), "bridge sized for the test");
        };
        let mut id = 0u64;
        epochs.push(ctl.epoch());

        // phase 1: steady traffic over the construction-time lanes
        for i in 0..40 {
            let lane = i % 3;
            let model = i % 2;
            submit(id, lane, model);
            want.insert(id, Want::Echo { lane, model, offset: 0.0 });
            id += 1;
        }
        await_frames(&reply, 40, &mut frames);

        // phase 2: add a lane under traffic — the balance heuristic must
        // pick the empty spare partition
        let (g_new, ticket) = ctl.add_lane(LaneSpec::new(&added, cfg(), qos1())).unwrap();
        assert_eq!(g_new, 3, "global ids are monotone");
        let out = ticket.wait(WAIT).unwrap();
        assert_eq!((out.global, out.local), (3, 0));
        assert!(out.group.is_none());
        let snap = ctl.snapshot();
        assert_eq!(snap.lanes[3], Some((spare, 0)));
        epochs.push(ctl.epoch());
        for i in 0..10 {
            let model = i % 2;
            submit(id, g_new, model);
            want.insert(id, Want::Echo { lane: g_new, model, offset: 0.0 });
            id += 1;
        }
        await_frames(&reply, 10, &mut frames);

        // phase 3: hot-swap the new lane; traffic submitted after the
        // ack must be served entirely by the new weights
        let pause = ctl.swap_model(g_new, 7).unwrap().wait(WAIT).unwrap();
        assert!(pause < Duration::from_secs(1));
        epochs.push(ctl.epoch());
        for i in 0..10 {
            let model = i % 2;
            submit(id, g_new, model);
            want.insert(id, Want::Echo { lane: g_new, model, offset: 7.0 * SWAP_SCALE });
            id += 1;
        }
        await_frames(&reply, 10, &mut frames);

        // phase 4: remove a coalesce-group member; its global id answers
        // NoLane from then on
        let removed = ctl.remove_lane(1).unwrap().wait(WAIT).unwrap();
        assert!(removed.epoch > epochs[0]);
        assert!(ctl.snapshot().lanes[1].is_none());
        epochs.push(ctl.epoch());
        for _ in 0..5 {
            submit(id, 1, 0);
            want.insert(id, Want::NoLane { lane: 1 });
            id += 1;
        }
        await_frames(&reply, 5, &mut frames);

        // phase 5: migrate the solo lane into partition 0 — it gets a
        // fresh global id, reuses p0's retired local slot, carries its
        // WDRR deficit, and does NOT join the bert group
        let out = ctl
            .migrate_lane(2, 0, LaneSpec::new(&solo, cfg(), qos1()), WAIT)
            .unwrap();
        assert_eq!((out.global, out.local), (4, 1), "migrant must reuse the retired slot");
        assert!(out.group.is_none(), "solo lane must not join the bert group");
        epochs.push(ctl.epoch());
        for i in 0..10 {
            let model = i % 2;
            submit(id, out.global, model);
            want.insert(id, Want::Echo { lane: out.global, model, offset: 0.0 });
            id += 1;
        }
        for _ in 0..3 {
            submit(id, 2, 0); // the old global id is gone forever
            want.insert(id, Want::NoLane { lane: 2 });
            id += 1;
        }
        await_frames(&reply, 13, &mut frames);

        bridge.close();
        runner
            .join()
            .expect("dispatch runner panicked")
            .expect("elastic dispatch failed");
    });

    // every submission got exactly one outcome frame, correctly typed,
    // correctly laned, and byte-exact
    for f in &frames {
        match f {
            Frame::Response { id, lane, model_idx, data, .. } => {
                match want.remove(id) {
                    Some(Want::Echo { lane: wl, model, offset }) => {
                        assert_eq!(*lane as usize, wl, "id {id} quoted the wrong lane");
                        assert_eq!(*model_idx as usize, model);
                        for (j, &x) in data.iter().enumerate() {
                            assert_eq!(x, seeded_at(*id, model, j) + offset);
                        }
                    }
                    other => panic!("unexpected Response for id {id} (want {other:?})"),
                }
            }
            Frame::Reject { id, lane, code, .. } => match want.remove(id) {
                Some(Want::NoLane { lane: wl }) => {
                    assert_eq!(*code, RejectCode::NoLane, "id {id}: wrong reject type");
                    assert_eq!(*lane as usize, wl);
                }
                other => panic!("unexpected Reject for id {id} (want {other:?})"),
            },
            other => panic!("unexpected frame: {other:?}"),
        }
    }
    assert!(want.is_empty(), "submissions without an outcome: {want:?}");

    // epochs advanced at every control-plane phase
    for w in epochs.windows(2) {
        assert!(w[0] < w[1], "epoch did not advance: {epochs:?}");
    }

    let st = stats.read();
    assert_eq!(st.admitted, 70);
    assert_eq!(st.responses, 70);
    assert_eq!(st.no_lane, 8);
    assert_eq!(st.ctrl_ops, 5, "add + swap + remove + migrate(remove, add)");
    assert_eq!(st.lane_busy + st.group_busy + st.invalid + st.round_errors, 0);
    assert!(st.rounds > 0);

    // post-run structure: retired slots where lanes left, reuse where
    // the migrant landed
    assert_eq!(d.part(0).lane_life(1), LaneLife::Live, "slot reused by the migrant");
    assert_eq!(d.part(1).lane_life(0), LaneLife::Retired, "migrated-away lane retired");
    assert_eq!(d.part(spare).live_lanes(), 1);
    let snap = ctl.snapshot();
    assert_eq!(snap.lanes.len(), 5);
    assert!(snap.lanes[1].is_none() && snap.lanes[2].is_none());
    assert_eq!(snap.lanes[4], Some((0, 1)));
}
