//! Golden-path integration: the Rust runtime executes the AOT'd HLO and
//! reproduces the Python interpreter's outputs bit-for-bit-ish.
//!
//! Requires `make artifacts`. Covers, per model family:
//!   1. single-model executable + per-instance bank  == golden y_i
//!   2. merged executable + Rust-stacked weights     == golden y_fused
//!   3. the NETFUSE invariant end-to-end in Rust: slicing the merged
//!      output reproduces each single-model output.

use std::path::Path;

use netfuse::fuse;
use netfuse::runtime::{Manifest, Runtime};
use netfuse::tensor::{io::read_nft, Tensor};

const MODELS: [&str; 4] = ["resnet", "resnext", "bert", "xlnet"];

fn artifacts_dir() -> &'static Path {
    Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
}

fn skip_if_missing() -> bool {
    if artifacts_dir().join("manifest.json").exists() {
        false
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        true
    }
}

/// Split a weight bank file keyed `m{i}/node.weight` into per-instance banks.
fn instance_banks(
    all: &std::collections::BTreeMap<String, Tensor>,
    m: usize,
) -> Vec<fuse::weights::Bank> {
    let mut banks = vec![fuse::weights::Bank::new(); m];
    for (k, v) in all {
        let (prefix, rest) = k.split_once('/').expect("bank key format");
        let idx: usize = prefix.strip_prefix('m').unwrap().parse().unwrap();
        if idx < m {
            banks[idx].insert(rest.to_string(), v.clone());
        }
    }
    banks
}

#[test]
fn single_model_outputs_match_golden() {
    if skip_if_missing() {
        return;
    }
    let rt = Runtime::open(artifacts_dir()).unwrap();
    for model in MODELS {
        let entry = rt.manifest.model(model).unwrap().clone();
        let all = read_nft(&artifacts_dir().join(&entry.weights)).unwrap();
        let banks = instance_banks(&all, 2);
        let golden = read_nft(&artifacts_dir().join(format!("golden/{model}.nft"))).unwrap();

        let exe = rt.compile(&Manifest::single_name(model, 1)).unwrap();
        for i in 0..2 {
            let params =
                fuse::weights::params_in_order(&entry.graph, &banks[i]).unwrap();
            let bound = exe.bind(&params).unwrap();
            let y = bound.run(&golden[&format!("x{i}")]).unwrap();
            let want = &golden[&format!("y{i}")];
            let err = y.max_abs_diff(want).unwrap();
            assert!(err < 1e-4, "{model} instance {i}: max err {err}");
        }
    }
}

#[test]
fn fused_outputs_match_golden_with_rust_stacked_weights() {
    if skip_if_missing() {
        return;
    }
    let rt = Runtime::open(artifacts_dir()).unwrap();
    for model in MODELS {
        let entry = rt.manifest.model(model).unwrap().clone();
        let all = read_nft(&artifacts_dir().join(&entry.weights)).unwrap();
        let banks = instance_banks(&all, 2);
        let golden = read_nft(&artifacts_dir().join(format!("golden/{model}.nft"))).unwrap();

        // Rust-side Algorithm 1 + weight stacking (not Python's!)
        let merged = fuse::merge(&entry.graph, 2).unwrap();
        let bank = fuse::weights::merge_weights(&merged, &banks).unwrap();
        let params = fuse::weights::params_in_order(&merged, &bank).unwrap();

        let bound = rt.load(&Manifest::fused_name(model, 2, 1), &params).unwrap();
        let y = bound.run(&golden["x_fused"]).unwrap();
        let err = y.max_abs_diff(&golden["y_fused"]).unwrap();
        assert!(err < 1e-4, "{model} fused: max err {err}");
    }
}

#[test]
fn netfuse_invariant_fused_equals_singles() {
    if skip_if_missing() {
        return;
    }
    let rt = Runtime::open(artifacts_dir()).unwrap();
    for model in MODELS {
        let golden = read_nft(&artifacts_dir().join(format!("golden/{model}.nft"))).unwrap();
        // golden y_fused is batch-packed [M, bs, ...]: slice per instance
        let fused = &golden["y_fused"];
        for i in 0..2 {
            let got = fused.index0(i).unwrap();
            let want = &golden[&format!("y{i}")];
            let err = got.max_abs_diff(want).unwrap();
            assert!(err < 1e-4, "{model}: fused[{i}] vs single: {err}");
        }
    }
}

#[test]
fn rust_merge_planner_matches_python_merged_graph() {
    if skip_if_missing() {
        return;
    }
    // the manifest's fused artifacts embed the Python-merged graph; the
    // Rust planner must produce an identical structure.
    let rt = Runtime::open(artifacts_dir()).unwrap();
    for model in MODELS {
        let single = rt.manifest.model(model).unwrap().graph.clone();
        for m in [2usize, 4] {
            let name = Manifest::fused_name(model, m, 1);
            let art = match rt.manifest.artifact(&name) {
                Ok(a) => a.clone(),
                Err(_) => continue,
            };
            // the artifact's positional param list is derived from the
            // Python-merged graph; identical param order across every
            // weight of every node pins the two planners to isomorphic
            // merged graphs (ids, kinds and weight shapes all agree).
            let rust_merged = fuse::merge(&single, m).unwrap();
            assert_eq!(
                rust_merged.param_order(),
                art.params,
                "{name}: param order"
            );
        }
    }
}
