// GOOD fixture: hot-path code that either avoids panicking constructs
// or carries a reasoned, fn-scoped LINT-ALLOW.

pub fn head_or_zero(xs: &[u32]) -> u32 {
    xs.first().copied().unwrap_or(0)
}

// LINT-ALLOW(index is bounds-checked at entry)
pub fn checked_pick(xs: &[u32], i: usize) -> u32 {
    if i >= xs.len() {
        return 0;
    }
    xs[i]
}
