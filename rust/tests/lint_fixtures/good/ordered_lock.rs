// GOOD fixture: the rank-checked wrappers are the sanctioned way to
// lock; their names do not collide with the banned raw identifiers.

use crate::util::lock::{LockRank, OrderedMutex};

pub struct Counter {
    inner: OrderedMutex<u64>,
}

impl Counter {
    pub fn new() -> Counter {
        Counter { inner: OrderedMutex::new(LockRank::StatsShard, 0) }
    }

    pub fn bump(&self) -> u64 {
        let mut v = self.inner.lock();
        *v += 1;
        *v
    }
}
