// GOOD fixture: the same kernel + call shape is fine when it lives in
// util/simd.rs (the tests lint this file under that logical path),
// because that module owns the runtime CPU-feature dispatch.

/// SAFETY: `dst` must be valid for `n` writes.
#[target_feature(enable = "avx2")]
unsafe fn fill_fast(dst: *mut f32, n: usize) {
    let _ = (dst, n);
}

pub fn fill(dst: &mut [f32]) {
    if is_x86_feature_detected!("avx2") {
        // SAFETY: avx2 presence checked above; pointer/len from the slice.
        unsafe { fill_fast(dst.as_mut_ptr(), dst.len()) }
    }
}
