// GOOD fixture: every `unsafe` carries a SAFETY note in the contiguous
// comment/attribute block directly above it (doc comments count).

/// Copies `n` floats.
///
/// SAFETY: caller guarantees `dst` and `src` are valid for `n`
/// elements and do not overlap.
#[inline]
pub unsafe fn copy(dst: *mut f32, src: *const f32, n: usize) {
    // SAFETY: forwarded caller contract.
    unsafe { std::ptr::copy_nonoverlapping(src, dst, n) }
}
