// BAD fixture: raw std::sync lock named outside util/lock.rs — this
// bypasses the lockdep rank tracker entirely.

use std::sync::Mutex;

pub struct Counter {
    inner: Mutex<u64>,
}
