// BAD fixture: a `#[target_feature]` kernel called directly from a
// module that is not util/simd.rs — no feature dispatch in sight.

/// SAFETY: `dst` must be valid for `n` writes.
#[target_feature(enable = "avx2")]
unsafe fn fill_fast(dst: *mut f32, n: usize) {
    let _ = (dst, n);
}

pub fn fill(dst: &mut [f32]) {
    // SAFETY: pointer/len come from the slice.
    unsafe { fill_fast(dst.as_mut_ptr(), dst.len()) }
}
