// BAD fixture: linted under a hot-path logical path
// (coordinator/multi.rs) — unwrap, indexing, and expect each flag.

pub fn head(xs: &[u32]) -> u32 {
    *xs.first().unwrap()
}

pub fn pick(xs: &[u32], i: usize) -> u32 {
    xs[i]
}

pub fn must(xs: &[u32]) -> u32 {
    xs.iter().copied().max().expect("nonempty")
}
