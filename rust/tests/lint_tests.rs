//! Self-tests for the in-repo invariant lint (ADR-008): every rule has
//! at least one failing and one passing fixture under
//! `tests/lint_fixtures/` (cargo never compiles those — only the lint
//! reads them), and the real `src/` tree must come back clean, which is
//! the same gate CI enforces via the `pallas-lint` binary.

use std::fs;
use std::path::Path;

use netfuse::util::lint::{
    self, Finding, RULE_HOT_PANIC, RULE_KERNEL, RULE_RAW_LOCK, RULE_SAFETY,
};

fn fixture(rel: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/lint_fixtures").join(rel);
    fs::read_to_string(&p).unwrap_or_else(|e| panic!("reading {}: {e}", p.display()))
}

/// Lint one fixture under a chosen logical path — the path-sensitive
/// rules (hot-path set, util/simd.rs, util/lock.rs) key off suffixes,
/// so the test decides which regime each fixture is judged under.
fn lint_as(logical: &str, src: String) -> Vec<Finding> {
    lint::lint_sources(&[(logical.to_string(), src)])
}

#[test]
fn every_bad_fixture_is_flagged_with_its_rule() {
    let cases = [
        ("bad/safety_missing.rs", "src/x.rs", RULE_SAFETY, 1),
        ("bad/kernel_direct_call.rs", "src/coordinator/other.rs", RULE_KERNEL, 1),
        ("bad/raw_mutex.rs", "src/x.rs", RULE_RAW_LOCK, 2),
        ("bad/hot_path_panic.rs", "src/coordinator/multi.rs", RULE_HOT_PANIC, 3),
    ];
    for (file, logical, rule, want) in cases {
        let findings = lint_as(logical, fixture(file));
        assert_eq!(findings.len(), want, "{file}: {findings:?}");
        assert!(findings.iter().all(|f| f.rule == rule), "{file}: {findings:?}");
        assert!(findings.iter().all(|f| f.line > 0), "{file}: {findings:?}");
    }
}

#[test]
fn every_good_fixture_passes_clean() {
    let cases = [
        ("good/safety_comment.rs", "src/x.rs"),
        ("good/kernel_dispatch.rs", "src/util/simd.rs"),
        ("good/ordered_lock.rs", "src/x.rs"),
        ("good/hot_path_clean.rs", "src/ingress/qos.rs"),
    ];
    for (file, logical) in cases {
        let findings = lint_as(logical, fixture(file));
        assert!(findings.is_empty(), "{file}: {findings:?}");
    }
}

#[test]
fn hot_path_fixture_is_clean_outside_the_hot_set() {
    // The same panicking constructs are fine in a non-hot module.
    let findings = lint_as("src/merge/mod.rs", fixture("bad/hot_path_panic.rs"));
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn kernel_fixture_is_clean_inside_simd_home() {
    let findings = lint_as("src/util/simd.rs", fixture("bad/kernel_direct_call.rs"));
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn raw_lock_fixture_is_clean_inside_lock_home() {
    let findings = lint_as("src/util/lock.rs", fixture("bad/raw_mutex.rs"));
    assert!(findings.is_empty(), "{findings:?}");
}

#[test]
fn findings_render_with_path_line_and_rule() {
    let findings = lint_as("src/x.rs", "fn f() {\n    unsafe { g() }\n}\n".to_string());
    assert_eq!(findings.len(), 1, "{findings:?}");
    let s = findings[0].render();
    assert!(s.contains("src/x.rs:2") && s.contains(RULE_SAFETY), "{s}");
}

/// The acceptance gate: `pallas-lint` must be clean on the real tree.
/// CI runs the binary before the build; this test keeps `cargo test`
/// equivalent to that gate.
#[test]
fn the_real_src_tree_is_clean() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let findings = lint::lint_tree(&src).expect("lint walks src");
    assert!(
        findings.is_empty(),
        "pallas-lint findings:\n{}",
        findings.iter().map(Finding::render).collect::<Vec<_>>().join("\n")
    );
}
