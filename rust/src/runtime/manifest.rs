//! `artifacts/manifest.json` loader — the contract with `aot.py`.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::graph::Graph;
use crate::util::json::Json;

/// One AOT-compiled executable's metadata.
#[derive(Debug, Clone)]
pub struct Artifact {
    pub name: String,
    /// model family: resnet | resnext | bert | xlnet
    pub model: String,
    /// number of merged instances (1 = single-model executable)
    pub m: usize,
    pub bs: usize,
    /// kernel backend the HLO was lowered with: "xla" | "pallas"
    pub backend: String,
    /// HLO text file, relative to the artifact dir
    pub hlo: String,
    /// "single" | "channel" | "batch"
    pub layout: String,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    /// positional parameter keys ("node.weight"), excluding the input
    pub params: Vec<String>,
    pub weights_bytes: u64,
    pub act_bytes: u64,
}

/// One model family's source-of-truth.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub graph: Graph,
    pub instances: usize,
    /// weight bank file, relative to the artifact dir
    pub weights: String,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifacts: Vec<Artifact>,
    pub models: BTreeMap<String, ModelEntry>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text)?;
        let mut artifacts = Vec::new();
        for a in v.get("artifacts").as_arr().context("manifest.artifacts")? {
            artifacts.push(Artifact {
                name: a.get("name").as_str().context("artifact.name")?.into(),
                model: a.get("model").as_str().context("artifact.model")?.into(),
                m: a.get("m").as_usize().context("artifact.m")?,
                bs: a.get("bs").as_usize().context("artifact.bs")?,
                backend: a.get("backend").as_str().unwrap_or("xla").into(),
                hlo: a.get("hlo").as_str().context("artifact.hlo")?.into(),
                layout: a.get("layout").as_str().unwrap_or("single").into(),
                input_shape: usizes(a.get("input").get("shape"))?,
                output_shape: usizes(a.get("output").get("shape"))?,
                params: a
                    .get("params")
                    .as_arr()
                    .context("artifact.params")?
                    .iter()
                    .map(|p| {
                        p.get("key")
                            .as_str()
                            .map(str::to_string)
                            .context("param.key")
                    })
                    .collect::<Result<_>>()?,
                weights_bytes: a.get("mem").get("weights_bytes").as_usize().unwrap_or(0)
                    as u64,
                act_bytes: a.get("mem").get("act_bytes").as_usize().unwrap_or(0) as u64,
            });
        }
        let mut models = BTreeMap::new();
        if let Some(o) = v.get("models").as_obj() {
            for (name, mv) in o {
                models.insert(
                    name.clone(),
                    ModelEntry {
                        graph: Graph::from_json(mv.get("graph"))
                            .with_context(|| format!("model {name}: graph"))?,
                        instances: mv.get("instances").as_usize().unwrap_or(1),
                        weights: mv
                            .get("weights")
                            .as_str()
                            .context("model.weights")?
                            .into(),
                    },
                );
            }
        }
        Ok(Manifest { artifacts, models })
    }

    pub fn artifact(&self, name: &str) -> Result<&Artifact> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .with_context(|| format!("no artifact {name:?} in manifest"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .with_context(|| format!("no model {name:?} in manifest"))
    }

    /// Artifact-name conventions shared with aot.py.
    pub fn single_name(model: &str, bs: usize) -> String {
        format!("{model}_single_bs{bs}")
    }

    pub fn fused_name(model: &str, m: usize, bs: usize) -> String {
        format!("{model}_fused_m{m}_bs{bs}")
    }
}

fn usizes(v: &Json) -> Result<Vec<usize>> {
    v.as_arr()
        .context("expected shape array")?
        .iter()
        .map(|x| x.as_usize().context("shape dim"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": [
        {"name": "m_single_bs1", "model": "m", "m": 1, "bs": 1,
         "backend": "xla", "hlo": "m.hlo.txt", "layout": "single",
         "input": {"shape": [1, 4], "dtype": "f32"},
         "output": {"shape": [1, 2], "dtype": "f32"},
         "params": [{"key": "d.b"}, {"key": "d.w"}],
         "mem": {"weights_bytes": 40, "act_bytes": 8},
         "graph": {}}
      ],
      "models": {
        "m": {
          "instances": 2,
          "weights": "weights/m.nft",
          "graph": {"name": "m", "input_shape": [4], "output": "d",
            "nodes": [{"id": "d", "kind": "dense", "inputs": ["input"],
              "attrs": {"fin": 4, "fout": 2},
              "weights": {"w": [4, 2], "b": [2]}}]}
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.artifact("m_single_bs1").unwrap();
        assert_eq!(a.params, vec!["d.b", "d.w"]);
        assert_eq!(a.input_shape, vec![1, 4]);
        assert_eq!(m.model("m").unwrap().instances, 2);
    }

    #[test]
    fn missing_artifact_is_error() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.artifact("nope").is_err());
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn name_conventions() {
        assert_eq!(Manifest::single_name("bert", 2), "bert_single_bs2");
        assert_eq!(Manifest::fused_name("bert", 8, 1), "bert_fused_m8_bs1");
    }
}
