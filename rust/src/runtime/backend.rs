//! Offline stub of the `xla` PJRT bindings (default build).
//!
//! The build image does not carry the `xla` crate, so `runtime/` is
//! compiled against this API-compatible stub unless the `xla` feature is
//! enabled (which expects the real crate as a dependency — see
//! `Cargo.toml`). The stub keeps the whole coordinator, every
//! artifact-gated test, and the host-side benches compiling and running;
//! only actual device execution is unavailable: [`PjRtClient::cpu`]
//! returns an error, so `Runtime::open` fails fast and the artifact
//! tests skip, exactly as they do when `artifacts/` is missing.
//!
//! [`Literal`] is implemented for real (host-side shape + f32 payload)
//! so the `to_literal`/`from_literal` converters stay functional.

use std::fmt;

/// Stub error type; converts into `anyhow::Error` via `?` like the real
/// crate's error does.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error(
        "PJRT backend unavailable: built with the offline xla stub \
         (enable the `xla` feature with the real dependency to execute HLO)"
            .to_string(),
    )
}

/// Uninhabited marker: device-side stub types can never be constructed,
/// which lets their methods compile as `match self.0 {}`.
#[derive(Debug)]
enum Void {}

/// Host literal: dims + row-major f32 payload (functional in the stub).
#[derive(Debug, Clone)]
pub struct Literal {
    dims: Vec<i64>,
    data: Vec<f32>,
}

/// Array shape descriptor returned by [`Literal::array_shape`].
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Sealed-ish element trait for [`Literal::to_vec`] (f32-only pipeline).
pub trait Element: Sized {
    fn from_f32(v: f32) -> Self;
}

impl Element for f32 {
    fn from_f32(v: f32) -> f32 {
        v
    }
}

impl Literal {
    /// Rank-1 literal over a host slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: data.to_vec() }
    }

    /// Reinterpret with new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error(format!(
                "cannot reshape literal of {} elems to {:?}",
                self.data.len(),
                dims
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>, Error> {
        Ok(self.data.iter().map(|v| T::from_f32(*v)).collect())
    }

    /// Extract the sole element of a 1-tuple output (identity here: the
    /// stub never produces real tuple literals).
    pub fn to_tuple1(self) -> Result<Literal, Error> {
        Ok(self)
    }
}

/// Stub PJRT device handle (never constructed).
#[allow(dead_code)]
pub struct PjRtDevice(Void);

/// Stub PJRT client: construction fails, everything else is unreachable.
pub struct PjRtClient(Void);

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        match self.0 {}
    }

    /// Real-crate contract mirrored here: the host buffer may be read
    /// LAZILY (the H2D copy can be deferred until execution), so
    /// callers must keep `data` live and unmodified until the returned
    /// buffer has been executed. `Bound::stage` encodes that as a
    /// borrowed `StagedInput<'a>`, and the coordinator's `ArenaRing`
    /// keeps the packed slot locked for the same span.
    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer, Error> {
        match self.0 {}
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        match self.0 {}
    }
}

/// Stub device buffer (never constructed).
pub struct PjRtBuffer(Void);

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        match self.0 {}
    }
}

/// Stub compiled executable (never constructed).
pub struct PjRtLoadedExecutable(Void);

impl PjRtLoadedExecutable {
    pub fn client(&self) -> PjRtClient {
        match self.0 {}
    }

    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        match self.0 {}
    }
}

/// Stub HLO module proto: text loading fails (no parser offline).
pub struct HloModuleProto(Void);

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

/// Stub computation wrapper (never constructed: protos cannot load).
pub struct XlaComputation(Void);

impl XlaComputation {
    pub fn from_proto(p: &HloModuleProto) -> XlaComputation {
        match p.0 {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_reports_stub() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("offline xla stub"));
    }

    #[test]
    fn literal_roundtrip_is_functional() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(Literal::vec1(&[1.0]).reshape(&[3]).is_err());
    }
}
