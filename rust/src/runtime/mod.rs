//! PJRT runtime: load AOT-compiled HLO text, compile once, execute many.
//!
//! The interchange format is HLO **text** (not serialized protos): jax
//! ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see `/opt/xla-example/README.md`).
//!
//! Python never appears here — `artifacts/` is produced once by
//! `make artifacts`, and this module is everything the request path needs.
//!
//! Layering:
//! - [`Runtime`] — PJRT CPU client + compiled-module cache.
//! - [`Module`]  — one compiled executable (compile once per artifact).
//! - [`Bound`]   — a module bound to device-resident parameter buffers.
//!   `Sequential` binds M weight banks to ONE module (the paper's
//!   baseline keeps every model's weights resident); `NetFuse` binds the
//!   stacked merged bank to the merged module.
//!
//! Backend selection: the default build compiles against the offline
//! stub in [`backend`] (the image has no `xla` crate); enabling the
//! `xla` cargo feature switches these paths to the real PJRT bindings.

pub mod manifest;

#[cfg(not(feature = "xla"))]
pub mod backend;
#[cfg(not(feature = "xla"))]
use self::backend as xla;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;
use crate::util::lock::{LockRank, OrderedMutex};
pub use manifest::{Artifact, Manifest, ModelEntry};

/// Tensor -> host literal.
pub fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|d| *d as i64).collect();
    Ok(xla::Literal::vec1(t.data()).reshape(&dims)?)
}

/// Device literal -> tensor (f32 arrays only).
pub fn from_literal(l: &xla::Literal) -> Result<Tensor> {
    let shape = l.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
    let data = l.to_vec::<f32>()?;
    Tensor::new(dims, data)
}

/// One compiled artifact (shared, immutable after compile).
pub struct Module {
    pub art: Artifact,
    exe: xla::PjRtLoadedExecutable,
}

// SAFETY: `Module` is immutable after compile (the executable is only
// read), and PJRT CPU executions are internally synchronized; the
// wrapper types are plain pointers into runtime-owned memory that lives
// as long as the client. Concurrency across threads mirrors the paper's
// process-per-model Concurrent baseline.
unsafe impl Send for Module {}
// SAFETY: see the Send impl above — `&Module` only exposes execute
// paths PJRT already serializes internally.
unsafe impl Sync for Module {}

impl Module {
    /// Upload a parameter set; returns a runnable binding. Parameters are
    /// borrowed — `params_in_order` hands out bank references, so binding
    /// no longer clones every weight tensor on the way in.
    pub fn bind(self: &Arc<Self>, params: &[&Tensor]) -> Result<Bound> {
        if params.len() != self.art.params.len() {
            bail!(
                "{}: got {} params, manifest wants {}",
                self.art.name, params.len(), self.art.params.len()
            );
        }
        let client = self.exe.client();
        let mut bufs = Vec::with_capacity(params.len());
        for p in params {
            bufs.push(client.buffer_from_host_buffer(p.data(), p.shape(), None)?);
        }
        Ok(Bound { module: self.clone(), params: bufs })
    }
}

/// A compiled module + device-resident weights: the runnable unit.
pub struct Bound {
    module: Arc<Module>,
    params: Vec<xla::PjRtBuffer>,
}

// SAFETY: `Bound` is an `Arc<Module>` plus device buffers that are
// never mutated after bind; PJRT device buffers are plain handles whose
// use (execute argument lists) is internally synchronized by PJRT.
unsafe impl Send for Bound {}
// SAFETY: see the Send impl above — shared access only reads the
// immutable binding.
unsafe impl Sync for Bound {}

/// A device-resident input buffer produced by [`Bound::stage`]. The
/// lifetime ties it to the host staging slice, so the compiler enforces
/// that the host memory outlives any pending (possibly deferred) upload.
/// When the slice comes from a `coordinator::arena::ArenaRing` slot,
/// the borrow runs through that slot's guard, which is exactly the
/// reservation that keeps round N's staged megabatch intact while
/// later rounds pack the other ring slots.
pub struct StagedInput<'a> {
    buf: xla::PjRtBuffer,
    _host: std::marker::PhantomData<&'a [f32]>,
}

// SAFETY: the staged buffer is a PJRT handle safe to move across
// threads; the `PhantomData<&'a [f32]>` borrow keeps the host staging
// memory pinned for exactly as long as the handle exists, so the
// deferred host→device copy can run from any thread.
unsafe impl Send for StagedInput<'_> {}

impl Bound {
    pub fn art(&self) -> &Artifact {
        &self.module.art
    }

    /// Execute with the bound weights; `x` is the only per-call upload.
    pub fn run(&self, x: &Tensor) -> Result<Tensor> {
        self.run_raw(x.shape(), x.data())
    }

    /// Execute straight from a raw staging buffer — the zero-copy fast
    /// path: the coordinator's `RoundArena` megabatch is uploaded to the
    /// device as-is, with no intermediate `Tensor` materialization
    /// between pack and PJRT.
    pub fn run_raw(&self, shape: &[usize], data: &[f32]) -> Result<Tensor> {
        let staged = self.stage(shape, data)?;
        self.run_staged(&staged)
    }

    /// Upload a staging buffer to the device without executing.
    ///
    /// The returned handle borrows `data`: PJRT host-buffer semantics
    /// may defer the host→device copy, so the staging memory must stay
    /// live and unmodified until the staged input has been executed
    /// ([`Bound::run_staged`]) — the borrow makes the compiler enforce
    /// liveness, and the NETFUSE path additionally holds the lock of
    /// the `ArenaRing` slot it packed across stage + execute, so that
    /// buffer cannot be *repacked* either. (xla-rs's CPU path copies
    /// synchronously — this is defense-in-depth for other PJRT
    /// backends.) This stage/run split is what lets the ring overlap
    /// rounds: while one slot's `StagedInput` is in flight, the other
    /// slots are free to pack the next rounds.
    pub fn stage<'a>(&self, shape: &[usize], data: &'a [f32]) -> Result<StagedInput<'a>> {
        let art = &self.module.art;
        if shape != art.input_shape.as_slice() {
            bail!(
                "{}: input shape {:?}, expected {:?}",
                art.name, shape, art.input_shape
            );
        }
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("{}: staging buffer has {} elems, shape wants {}", art.name, data.len(), n);
        }
        let client = self.module.exe.client();
        Ok(StagedInput {
            buf: client.buffer_from_host_buffer(data, shape, None)?,
            _host: std::marker::PhantomData,
        })
    }

    /// Execute with a previously staged input (see [`Bound::stage`]).
    pub fn run_staged(&self, staged: &StagedInput<'_>) -> Result<Tensor> {
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.params.len());
        args.push(&staged.buf);
        args.extend(self.params.iter());
        let res = self.module.exe.execute_b(&args)?;
        // aot.py lowers with return_tuple=True -> 1-tuple output
        let lit = res[0][0].to_literal_sync()?.to_tuple1()?;
        from_literal(&lit)
    }
}

/// Runtime: a PJRT client + compiled-module cache over an artifact dir.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: OrderedMutex<HashMap<String, Arc<Module>>>,
}

// SAFETY: the PJRT client is thread-safe per the PJRT C API contract
// (the stub backend is trivially so); the only interior mutability is
// the compile cache, which is behind its own mutex.
unsafe impl Send for Runtime {}
// SAFETY: see the Send impl above.
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Open the artifact directory (the output of `make artifacts`).
    pub fn open(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: OrderedMutex::new(LockRank::RuntimeCache, HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an artifact (cached by name — each artifact is compiled at
    /// most once per Runtime, amortized like the paper's offline merge).
    pub fn compile(&self, name: &str) -> Result<Arc<Module>> {
        {
            let cache = self.cache.lock();
            if let Some(m) = cache.get(name) {
                return Ok(m.clone());
            }
        }
        let art = self.manifest.artifact(name)?.clone();
        let hlo_path = self.dir.join(&art.hlo);
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().context("non-utf8 path")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let m = Arc::new(Module { art, exe });
        self.cache.lock().insert(name.to_string(), m.clone());
        Ok(m)
    }

    /// Convenience: compile + bind in one step.
    pub fn load(&self, name: &str, params: &[&Tensor]) -> Result<Bound> {
        self.compile(name)?.bind(params)
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }
}
