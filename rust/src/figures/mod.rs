//! Figure/table regeneration engine — one function per paper exhibit.
//!
//! Every timing figure is produced in two modes (DESIGN.md §3):
//! - **measured** — real wall-clock of the mini models on the CPU PJRT
//!   backend, all four strategies executing real HLO.
//! - **device-model** — the analytical V100 / TITAN Xp simulator at the
//!   paper's full model scale.
//!
//! The benches (`benches/fig*.rs`) and the CLI (`netfuse bench-figure`)
//! both call into here; EXPERIMENTS.md records the outputs.

use anyhow::Result;

use crate::coordinator::memory::{self, ModelFootprint};
use crate::coordinator::strategy::StrategyKind;
use crate::coordinator::Fleet;
use crate::devmodel::{sim, GpuProfile, V100};
use crate::fuse;
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use crate::util::bench::{time_once, Bench, Config};
use crate::util::rng::Rng;
use crate::util::stats::{fmt_bytes, fmt_secs};

pub const MODELS: [&str; 4] = ["resnet", "resnext", "bert", "xlnet"];

/// Sweep sizes; benches can shrink them for quick runs.
#[derive(Debug, Clone)]
pub struct FigOpts {
    pub models: Vec<String>,
    pub m_sweep: Vec<usize>,
    pub samples: usize,
    pub measured: bool,
    pub device: GpuProfile,
}

impl Default for FigOpts {
    fn default() -> Self {
        FigOpts {
            models: MODELS.iter().map(|s| s.to_string()).collect(),
            m_sweep: vec![1, 2, 4, 8, 16, 32],
            samples: 10,
            measured: true,
            device: V100,
        }
    }
}

impl FigOpts {
    pub fn quick() -> Self {
        FigOpts {
            m_sweep: vec![2, 4],
            samples: 3,
            ..Default::default()
        }
    }
}

fn bench_cfg(samples: usize) -> Config {
    Config { warmup_s: 0.2, samples, min_sample_s: 0.005 }
}

const STRATEGIES: [StrategyKind; 3] = [
    StrategyKind::Sequential,
    StrategyKind::Concurrent,
    StrategyKind::NetFuse,
];

/// One measured cell: mean seconds per round.
fn measure_round(fleet: &Fleet, strategy: StrategyKind, samples: usize) -> Result<f64> {
    let mut rng = Rng::new(0xF1C5);
    let xs: Vec<Tensor> = (0..fleet.m)
        .map(|_| Tensor::randn(&fleet.request_shape(), &mut rng))
        .collect();
    let refs: Vec<&Tensor> = xs.iter().collect();
    // correctness guard: every strategy must agree before we time it
    let want = fleet.run_round(StrategyKind::Sequential, &refs)?;
    let got = fleet.run_round(strategy, &refs)?;
    for (a, b) in want.iter().zip(&got) {
        anyhow::ensure!(
            a.allclose(b, 1e-3, 1e-4),
            "strategy {strategy} diverges from sequential"
        );
    }
    let mut bench = Bench::new().quiet();
    bench.config = bench_cfg(samples);
    let m = bench.run(&format!("{strategy}"), || {
        fleet.run_round(strategy, &refs).expect("round failed");
    });
    Ok(m.mean)
}

// ---------------------------------------------------------------------------
// Figure 5 / Figure 9: inference time vs number of models, bs=1
// ---------------------------------------------------------------------------

/// Figure 5 (V100) / Figure 9 (TITAN Xp): mean inference time of the
/// strategies for a varying number of models, bs=1.
pub fn fig5(rt: Option<&Runtime>, opts: &FigOpts) -> Result<String> {
    let mut out = String::new();
    let dev = &opts.device;
    out.push_str(&format!(
        "# Figure {}: inference time vs #models (bs=1, {})\n",
        if dev.name == "V100" { "5" } else { "9" },
        dev.name
    ));
    out.push_str(
        "# model      M    mode      sequential   concurrent      netfuse  speedup(best-fitting)\n",
    );
    for model in &opts.models {
        for &m in &opts.m_sweep {
            if m < 2 {
                continue;
            }
            // device-model row (paper scale)
            let mut row = vec![f64::NAN; 3];
            for (i, s) in STRATEGIES.iter().enumerate() {
                row[i] = sim::predict(dev, model, m, 1, *s)?;
            }
            let conc_fits =
                sim::predict_memory(model, m, 1, StrategyKind::Concurrent).fits(dev.capacity);
            let best = if conc_fits { row[0].min(row[1]) } else { row[0] };
            out.push_str(&format!(
                "{:<10} {:>3}   sim     {:>12} {:>12} {:>12}  {:>6.2}x{}\n",
                model,
                m,
                fmt_secs(row[0]),
                if conc_fits { fmt_secs(row[1]) } else { "OOM".into() },
                fmt_secs(row[2]),
                best / row[2],
                if conc_fits { "" } else { "  (concurrent OOM)" },
            ));
            // measured row (mini models, CPU PJRT)
            if opts.measured {
                if let Some(rt) = rt {
                    let fleet = Fleet::load(rt, model, m, 1)?;
                    let mut times = vec![f64::NAN; 3];
                    for (i, s) in STRATEGIES.iter().enumerate() {
                        times[i] = measure_round(&fleet, *s, opts.samples)?;
                    }
                    out.push_str(&format!(
                        "{:<10} {:>3}   cpu     {:>12} {:>12} {:>12}  {:>6.2}x\n",
                        model,
                        m,
                        fmt_secs(times[0]),
                        fmt_secs(times[1]),
                        fmt_secs(times[2]),
                        times[0].min(times[1]) / times[2],
                    ));
                }
            }
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Figure 6: BERT, normalized inference time vs batch size
// ---------------------------------------------------------------------------

pub fn fig6(rt: Option<&Runtime>, opts: &FigOpts) -> Result<String> {
    let mut out = String::new();
    out.push_str("# Figure 6: BERT inference time normalized to NETFUSE, by batch size (V100)\n");
    out.push_str("# bs   M    mode   sequential/nf  concurrent/nf\n");
    for &bs in &[1usize, 2, 4, 8] {
        for &m in &opts.m_sweep {
            if m < 2 {
                continue;
            }
            let nf = sim::predict(&V100, "bert", m, bs, StrategyKind::NetFuse)?;
            let seq = sim::predict(&V100, "bert", m, bs, StrategyKind::Sequential)?;
            let conc = sim::predict(&V100, "bert", m, bs, StrategyKind::Concurrent)?;
            out.push_str(&format!(
                "{:>4} {:>3}   sim    {:>12.2} {:>14.2}\n",
                bs, m, seq / nf, conc / nf
            ));
            if opts.measured {
                if let Some(rt) = rt {
                    let fleet = Fleet::load(rt, "bert", m, bs)?;
                    let nf = measure_round(&fleet, StrategyKind::NetFuse, opts.samples)?;
                    let seq = measure_round(&fleet, StrategyKind::Sequential, opts.samples)?;
                    let conc = measure_round(&fleet, StrategyKind::Concurrent, opts.samples)?;
                    out.push_str(&format!(
                        "{:>4} {:>3}   cpu    {:>12.2} {:>14.2}\n",
                        bs, m, seq / nf, conc / nf
                    ));
                }
            }
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Figure 7 / Figure 10: peak memory
// ---------------------------------------------------------------------------

pub fn fig7(opts: &FigOpts) -> Result<String> {
    let dev = &opts.device;
    let mut out = String::new();
    out.push_str(&format!(
        "# Figure {}: peak memory (workspace + base), {}, capacity {}\n",
        if dev.name == "V100" { "7" } else { "10" },
        dev.name,
        fmt_bytes(dev.capacity)
    ));
    out.push_str("# model      M  strategy     workspace        base       total  fits\n");
    for model in &opts.models {
        for &m in &opts.m_sweep {
            if m < 2 {
                continue;
            }
            for s in [
                StrategyKind::Sequential,
                StrategyKind::Concurrent,
                StrategyKind::NetFuse,
            ] {
                let e = sim::predict_memory(model, m, 1, s);
                out.push_str(&format!(
                    "{:<10} {:>3}  {:<10} {:>11} {:>11} {:>11}  {}\n",
                    model,
                    m,
                    s.to_string(),
                    fmt_bytes(e.workspace),
                    fmt_bytes(e.base),
                    fmt_bytes(e.total),
                    if e.fits(dev.capacity) { "yes" } else { "OOM" },
                ));
            }
        }
    }
    Ok(out)
}

/// Measured-mode memory table from the manifest's real byte counts
/// (mini models; the solid/hatched decomposition is the same).
pub fn fig7_measured(rt: &Runtime, opts: &FigOpts) -> Result<String> {
    let mut out = String::new();
    out.push_str("# Figure 7 (measured bytes, mini models, host memory model)\n");
    for model in &opts.models {
        for &m in &opts.m_sweep {
            if m < 2 {
                continue;
            }
            let single = rt.manifest.artifact(&crate::runtime::Manifest::single_name(model, 1))?;
            let fused =
                rt.manifest.artifact(&crate::runtime::Manifest::fused_name(model, m, 1))?;
            let fp = ModelFootprint {
                weights_bytes: single.weights_bytes,
                act_bytes: single.act_bytes,
                fused_weights_bytes: fused.weights_bytes,
                fused_act_bytes: fused.act_bytes,
            };
            for s in [
                StrategyKind::Sequential,
                StrategyKind::Concurrent,
                StrategyKind::NetFuse,
            ] {
                let e = memory::estimate(s, m, &fp);
                out.push_str(&format!(
                    "{:<10} {:>3}  {:<10} workspace={:>10} total={:>10}\n",
                    model,
                    m,
                    s.to_string(),
                    fmt_bytes(e.workspace),
                    fmt_bytes(e.total),
                ));
            }
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Figure 8: hybrid configurations at 32 models
// ---------------------------------------------------------------------------

pub fn fig8(rt: Option<&Runtime>, opts: &FigOpts) -> Result<String> {
    let dev = &opts.device;
    let m = *opts.m_sweep.iter().max().unwrap_or(&32);
    let mut out = String::new();
    out.push_str(&format!(
        "# Figure 8: hybrid (Ap, Bm) configurations, {} models, bs=1, {}\n",
        m, dev.name
    ));
    out.push_str("# config        mode        time    memory   fits\n");
    let mut configs = vec![StrategyKind::Sequential];
    let mut p = 2;
    while p < m {
        configs.push(StrategyKind::Hybrid { procs: p });
        p *= 2;
    }
    configs.push(StrategyKind::Concurrent);
    configs.push(StrategyKind::NetFuse);
    for model in &opts.models {
        out.push_str(&format!("## {model}\n"));
        for s in &configs {
            let t = sim::predict(dev, model, m, 1, *s)?;
            let e = sim::predict_memory(model, m, 1, *s);
            out.push_str(&format!(
                "{:<13} sim   {:>10} {:>9}   {}\n",
                label(*s, m),
                fmt_secs(t),
                fmt_bytes(e.total),
                if e.fits(dev.capacity) { "yes" } else { "OOM" },
            ));
        }
        if opts.measured {
            if let Some(rt) = rt {
                let fleet = Fleet::load(rt, model, m, 1)?;
                for s in &configs {
                    let t = measure_round(&fleet, *s, opts.samples)?;
                    out.push_str(&format!(
                        "{:<13} cpu   {:>10}\n",
                        label(*s, m),
                        fmt_secs(t)
                    ));
                }
            }
        }
    }
    Ok(out)
}

fn label(s: StrategyKind, m: usize) -> String {
    match s {
        StrategyKind::Sequential => format!("(1p,{m}m)"),
        StrategyKind::Concurrent => format!("({m}p,1m)"),
        StrategyKind::Hybrid { procs } => format!("({}p,{}m)", procs, m / procs),
        StrategyKind::NetFuse => "netfuse".to_string(),
    }
}

// ---------------------------------------------------------------------------
// Figure 2 + §2.2: rewriter baseline
// ---------------------------------------------------------------------------

pub fn fig2() -> Result<String> {
    use crate::graph::Graph;
    use crate::rewriter;

    let mut out = String::new();
    out.push_str("# Figure 2 / §2.2: greedy graph rewriting vs NETFUSE\n");

    // two disjoint conv models (Figure 2a)
    let two_convs = Graph::parse(
        r#"{
          "name": "two_models", "input_shape": [8, 16, 16], "output": "add",
          "nodes": [
            {"id": "conv_a", "kind": "conv2d", "inputs": ["input"],
             "attrs": {"cin": 8, "cout": 8, "k": 3, "stride": 1,
                       "padding": 1, "groups": 1},
             "weights": {"w": [8, 8, 3, 3], "b": [8]}},
            {"id": "conv_b", "kind": "conv2d", "inputs": ["input"],
             "attrs": {"cin": 8, "cout": 8, "k": 3, "stride": 1,
                       "padding": 1, "groups": 1},
             "weights": {"w": [8, 8, 3, 3], "b": [8]}},
            {"id": "add", "kind": "add", "inputs": ["conv_a", "conv_b"]}
          ]
        }"#,
    )?;
    // NOTE: conv_a/conv_b share `input` here only to satisfy single-graph
    // form; the rewriter rule requires *different* inputs, so this graph
    // is the adversarial case where the cross-model rule does not apply.

    let p = V100;
    let res = rewriter::greedy_optimize(&p, &two_convs, &rewriter::default_rules(), 1);
    out.push_str(&format!(
        "greedy (default rules): {} applications {:?}, cost {:.2}us -> {:.2}us\n",
        res.applied.len(),
        res.applied,
        res.initial_cost * 1e6,
        res.final_cost * 1e6
    ));
    out.push_str(
        "  -> no cross-model merge found (the rule is not in the default set,\n",
    );
    out.push_str("     and greedy search cannot pass through the concat overhead)\n");

    // NETFUSE on the same pair via Algorithm 1 directly
    let single = Graph::parse(
        r#"{
          "name": "one_conv", "input_shape": [8, 16, 16], "output": "conv",
          "nodes": [
            {"id": "conv", "kind": "conv2d", "inputs": ["input"],
             "attrs": {"cin": 8, "cout": 8, "k": 3, "stride": 1,
                       "padding": 1, "groups": 1},
             "weights": {"w": [8, 8, 3, 3], "b": [8]}}
          ]
        }"#,
    )?;
    let merged = fuse::merge(&single, 2)?;
    let mc = rewriter::graph_cost(&p, &merged, 1);
    let sc = 2.0 * rewriter::graph_cost(&p, &single, 1);
    out.push_str(&format!(
        "netfuse (Algorithm 1): grouped conv of {} groups, cost {:.2}us vs {:.2}us separate ({:.2}x)\n",
        merged.node("conv")?.attr_i64("groups")?,
        mc * 1e6,
        sc * 1e6,
        sc / mc
    ));

    // §2.2 scalability: search space explosion with model count
    out.push_str("\n# §2.2 scalability: rewrite search space vs #models (TASO: 30h at 4, OOM at 8)\n");
    for n in [1usize, 2, 4, 8] {
        out.push_str(&format!(
            "{} models: ~2^{} candidate substitution states\n",
            n,
            10 * n
        ));
        let _ = rewriter::search_space_size(10, n);
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// §4: merge overhead
// ---------------------------------------------------------------------------

/// Merge (Algorithm 1 + weight stacking) wall time vs M — the paper
/// reports <= 600 ms for 32 ResNeXt-50 instances, amortized offline.
pub fn merge_overhead(rt: &Runtime, opts: &FigOpts) -> Result<String> {
    let mut out = String::new();
    out.push_str("# §4 merge overhead: Algorithm 1 + weight stacking wall time\n");
    for model in &opts.models {
        let entry = rt.manifest.model(model)?.clone();
        let max_m = *opts.m_sweep.iter().max().unwrap_or(&32);
        let banks = crate::coordinator::service::load_banks(rt, model, max_m)?;
        for &m in &opts.m_sweep {
            if m < 2 {
                continue;
            }
            let (plan, t_plan) = time_once(|| fuse::merge(&entry.graph, m).unwrap());
            let (_bank, t_weights) =
                time_once(|| fuse::weights::merge_weights(&plan, &banks[..m]).unwrap());
            out.push_str(&format!(
                "{:<10} m={:>3}: plan {:>10}  weights {:>10}  total {:>10}\n",
                model,
                m,
                fmt_secs(t_plan),
                fmt_secs(t_weights),
                fmt_secs(t_plan + t_weights)
            ));
        }
    }
    Ok(out)
}
