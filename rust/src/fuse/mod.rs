//! NETFUSE Algorithm 1 — the serving-side merge planner.
//!
//! Re-implements `python/compile/netfuse.py` over the shared graph IR:
//! given a single-instance graph and M, produce the merged graph (op
//! counterparts, merge-dimension propagation, refmt fix-up insertion,
//! per-instance head expansion). The Python implementation drives the
//! AOT lowering; this one drives the coordinator (weight-bank stacking,
//! memory estimation, artifact validation) and is cross-checked against
//! Python output in `tests/fuse_vs_python.rs`.

pub mod weights;

use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};

use anyhow::{bail, Result};

use crate::graph::{merge_dim, Attr, Graph, MergeDim, Node};

/// Packing of the merged graph input: CNNs concat on channel, sequence
/// models stack on batch.
pub fn input_dim(g: &Graph) -> MergeDim {
    if g.input_shape.len() == 3 {
        MergeDim::Channel
    } else {
        MergeDim::Batch
    }
}

/// Merge one op into its input-weight-local counterpart (paper §3.1).
/// Returns the merged node and its required concat dimension.
pub fn merge_node(n: &Node, m: usize) -> Result<(Node, MergeDim)> {
    let mut out = n.clone();
    let mi = m as i64;
    match n.kind.as_str() {
        "conv2d" => {
            // conv -> grouped conv with M x G groups (Appendix A)
            let cin = n.attr_i64("cin")?;
            let cout = n.attr_i64("cout")?;
            let groups = n.attr_i64("groups")?;
            let k = n.attr_i64("k")? as usize;
            out.attrs.insert("cin".into(), Attr::Int(cin * mi));
            out.attrs.insert("cout".into(), Attr::Int(cout * mi));
            out.attrs.insert("groups".into(), Attr::Int(groups * mi));
            out.weights.insert(
                "w".into(),
                vec![(cout * mi) as usize, (cin / groups) as usize, k, k],
            );
            out.weights.insert("b".into(), vec![(cout * mi) as usize]);
            Ok((out, MergeDim::Channel))
        }
        "dense" => {
            // matmul -> batch matmul, weights stacked on new leading axis
            let fin = n.attr_usize("fin")?;
            let fout = n.attr_usize("fout")?;
            out.attrs.insert("merged_m".into(), Attr::Int(mi));
            out.weights.insert("w".into(), vec![m, fin, fout]);
            out.weights.insert("b".into(), vec![m, fout]);
            Ok((out, MergeDim::Batch))
        }
        "layernorm" => {
            // layer norm -> group norm with M groups
            let dim = n.attr_usize("dim")?;
            out.kind = "groupnorm".into();
            out.attrs.clear();
            out.attrs.insert("c".into(), Attr::Int((dim * m) as i64));
            out.attrs.insert("groups".into(), Attr::Int(mi));
            out.weights = BTreeMap::from([
                ("gamma".to_string(), vec![dim * m]),
                ("beta".to_string(), vec![dim * m]),
            ]);
            Ok((out, MergeDim::Channel))
        }
        "groupnorm" => {
            let c = n.attr_usize("c")?;
            let groups = n.attr_i64("groups")?;
            out.attrs.insert("c".into(), Attr::Int((c * m) as i64));
            out.attrs.insert("groups".into(), Attr::Int(groups * mi));
            out.weights = BTreeMap::from([
                ("gamma".to_string(), vec![c * m]),
                ("beta".to_string(), vec![c * m]),
            ]);
            Ok((out, MergeDim::Channel))
        }
        "batchnorm" => {
            // per-channel math: concat weights, same op type
            let c = n.attr_usize("c")?;
            out.attrs.insert("c".into(), Attr::Int((c * m) as i64));
            for shape in out.weights.values_mut() {
                *shape = vec![c * m];
            }
            Ok((out, MergeDim::Channel))
        }
        "attention" | "xl_attention" => {
            // composition of matmuls -> composition of batch matmuls
            out.attrs.insert("merged_m".into(), Attr::Int(mi));
            for shape in out.weights.values_mut() {
                let mut s = vec![m];
                s.extend_from_slice(shape);
                *shape = s;
            }
            Ok((out, MergeDim::Batch))
        }
        k => match merge_dim(k) {
            // non-trainable ops merge seamlessly (paper §3.1)
            Some(MergeDim::DontCare) => Ok((out, MergeDim::DontCare)),
            _ => bail!("cannot merge op kind {k:?}"),
        },
    }
}

/// Algorithm 1: BFS merge of M instances of `g` into one graph.
pub fn merge(g: &Graph, m: usize) -> Result<Graph> {
    if m < 1 {
        bail!("m must be >= 1");
    }
    g.validate()?;
    if g.merged_m != 1 {
        bail!("graph is already merged");
    }

    let in_dim = input_dim(g);
    let mut merged: Vec<Node> = Vec::with_capacity(g.nodes.len() + 8);
    let mut dim_of: HashMap<String, MergeDim> = HashMap::new();
    dim_of.insert("input".into(), in_dim);
    // original node id -> id of the node carrying its merged output
    let mut out_id: HashMap<String, String> = HashMap::new();
    out_id.insert("input".into(), "input".into());
    // (parent output id, wanted dim) -> refmt id, shared across diamonds
    let mut refmt_cache: HashMap<(String, MergeDim), String> = HashMap::new();
    let mut refmt_count = 0usize;

    let mut indeg: HashMap<&str, usize> = HashMap::new();
    for n in &g.nodes {
        indeg.insert(
            &n.id,
            n.inputs.iter().filter(|s| s.as_str() != "input").count(),
        );
    }
    let mut q: VecDeque<&Node> = g
        .nodes
        .iter()
        .filter(|n| indeg[n.id.as_str()] == 0)
        .collect();
    let mut visited: HashSet<&str> = HashSet::new();

    // helper: route `parent`'s merged output into packing `want`
    macro_rules! connect {
        ($merged:ident, $dim_of:ident, $refmt_cache:ident, $refmt_count:ident,
         $out_id:ident, $parent:expr, $want:expr) => {{
            let pid = $out_id[$parent].clone();
            let have = $dim_of[&pid];
            if $want == MergeDim::DontCare || have == $want {
                pid
            } else {
                let key = (pid.clone(), $want);
                if !$refmt_cache.contains_key(&key) {
                    $refmt_count += 1;
                    let rid = format!("refmt_{}", $refmt_count);
                    let mut attrs = BTreeMap::new();
                    attrs.insert(
                        "src".to_string(),
                        Attr::Str(dim_name(have).to_string()),
                    );
                    attrs.insert(
                        "dst".to_string(),
                        Attr::Str(dim_name($want).to_string()),
                    );
                    $merged.push(Node {
                        id: rid.clone(),
                        kind: "refmt".into(),
                        inputs: vec![pid.clone()],
                        attrs,
                        weights: BTreeMap::new(),
                        mergeable: true,
                    });
                    $dim_of.insert(rid.clone(), $want);
                    $refmt_cache.insert(key.clone(), rid);
                }
                $refmt_cache[&key].clone()
            }
        }};
    }

    while let Some(op) = q.pop_front() {
        if !visited.insert(&op.id) {
            continue;
        }

        if !op.mergeable {
            // §6: task-specific head kept per-instance
            if op.kind != "dense" {
                bail!(
                    "unmergeable op {:?} of kind {:?}: only dense heads \
                     are supported per-instance",
                    op.id, op.kind
                );
            }
            let src = connect!(merged, dim_of, refmt_cache, refmt_count,
                               out_id, &op.inputs[0], MergeDim::Batch);
            let mut parts = Vec::with_capacity(m);
            for i in 0..m {
                let sid = format!("{}__slice{}", op.id, i);
                merged.push(Node {
                    id: sid.clone(),
                    kind: "slice_m".into(),
                    inputs: vec![src.clone()],
                    attrs: BTreeMap::from([
                        ("index".to_string(), Attr::Int(i as i64)),
                    ]),
                    weights: BTreeMap::new(),
                    mergeable: true,
                });
                dim_of.insert(sid.clone(), MergeDim::Batch);
                let did = format!("{}__m{}", op.id, i);
                let mut attrs = op.attrs.clone();
                attrs.insert("merged_m".into(), Attr::Int(1));
                merged.push(Node {
                    id: did.clone(),
                    kind: "dense".into(),
                    inputs: vec![sid],
                    attrs,
                    weights: op.weights.clone(),
                    mergeable: false,
                });
                dim_of.insert(did.clone(), MergeDim::Batch);
                parts.push(did);
            }
            let stid = format!("{}__stack", op.id);
            merged.push(Node {
                id: stid.clone(),
                kind: "stack_m".into(),
                inputs: parts,
                attrs: BTreeMap::new(),
                weights: BTreeMap::new(),
                mergeable: true,
            });
            dim_of.insert(stid.clone(), MergeDim::Batch);
            out_id.insert(op.id.clone(), stid);
        } else {
            let (mut mi, mut di) = merge_node(op, m)?;
            if di == MergeDim::DontCare {
                // follow the majority of the parents (Algorithm 1 l.23-27)
                let mut batch = 0usize;
                let mut channel = 0usize;
                for s in &op.inputs {
                    match dim_of[&out_id[s]] {
                        MergeDim::Batch => batch += 1,
                        MergeDim::Channel => channel += 1,
                        MergeDim::DontCare => {}
                    }
                }
                di = if batch == 0 && channel == 0 {
                    in_dim
                } else if channel > batch {
                    MergeDim::Channel
                } else {
                    MergeDim::Batch
                };
            }
            mi.inputs = op
                .inputs
                .iter()
                .map(|s| connect!(merged, dim_of, refmt_cache, refmt_count,
                                  out_id, s, di))
                .collect();
            dim_of.insert(mi.id.clone(), di);
            out_id.insert(op.id.clone(), mi.id.clone());
            merged.push(mi);
        }

        for child in g.consumers(&op.id) {
            let e = indeg.get_mut(child.id.as_str()).unwrap();
            *e -= 1;
            if *e == 0 {
                q.push_back(child);
            }
        }
    }

    if visited.len() != g.nodes.len() {
        bail!("graph has a cycle or unreachable nodes");
    }

    let out = Graph {
        name: format!("{}_x{}", g.name, m),
        input_shape: g.input_shape.clone(),
        output: out_id[&g.output].clone(),
        nodes: merged,
        merged_m: m,
        layout: match in_dim {
            MergeDim::Channel => "channel".into(),
            _ => "batch".into(),
        },
    };
    out.validate()?;
    Ok(out)
}

fn dim_name(d: MergeDim) -> &'static str {
    match d {
        MergeDim::Batch => "batch",
        MergeDim::Channel => "channel",
        MergeDim::DontCare => "dontcare",
    }
}

/// Graph-level optimization pass: cancel adjacent inverse refmts
/// (`batch->channel` directly feeding `channel->batch`, and vice versa).
/// The Python merge inserts fix-ups edge-by-edge exactly as Algorithm 1
/// dictates; this pass removes the provably-redundant pairs. Ablated in
/// `benches/ablation_refmt.rs`.
pub fn elide_refmt_pairs(g: &Graph) -> Graph {
    let mut alias: HashMap<String, String> = HashMap::new();
    let by_id: HashMap<&str, &Node> =
        g.nodes.iter().map(|n| (n.id.as_str(), n)).collect();
    for n in &g.nodes {
        if n.kind != "refmt" {
            continue;
        }
        if let Some(parent) = by_id.get(n.inputs[0].as_str()) {
            if parent.kind == "refmt" {
                let (src, dst) = (
                    n.attrs["src"].as_str().unwrap(),
                    n.attrs["dst"].as_str().unwrap(),
                );
                let (psrc, pdst) = (
                    parent.attrs["src"].as_str().unwrap(),
                    parent.attrs["dst"].as_str().unwrap(),
                );
                if src == pdst && dst == psrc {
                    // n(parent(x)) == x
                    alias.insert(n.id.clone(), parent.inputs[0].clone());
                }
            }
        }
    }
    if alias.is_empty() {
        return g.clone();
    }
    let resolve = |id: &String| -> String {
        let mut cur = id.clone();
        while let Some(next) = alias.get(&cur) {
            cur = next.clone();
        }
        cur
    };
    let mut nodes: Vec<Node> = Vec::new();
    for n in &g.nodes {
        if alias.contains_key(&n.id) {
            continue;
        }
        let mut n2 = n.clone();
        n2.inputs = n.inputs.iter().map(&resolve).collect();
        nodes.push(n2);
    }
    // drop now-unconsumed refmts (dead code) except the output
    let used: HashSet<String> = nodes
        .iter()
        .flat_map(|n| n.inputs.iter().cloned())
        .chain(std::iter::once(resolve(&g.output)))
        .collect();
    let nodes: Vec<Node> = nodes
        .into_iter()
        .filter(|n| n.kind != "refmt" || used.contains(&n.id))
        .collect();
    Graph {
        name: g.name.clone(),
        input_shape: g.input_shape.clone(),
        output: resolve(&g.output),
        nodes,
        merged_m: g.merged_m,
        layout: g.layout.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ffnn() -> Graph {
        Graph::parse(
            r#"{
              "name": "ffnn", "input_shape": [8], "output": "ln",
              "nodes": [
                {"id": "d", "kind": "dense", "inputs": ["input"],
                 "attrs": {"fin": 8, "fout": 8},
                 "weights": {"w": [8, 8], "b": [8]}},
                {"id": "ln", "kind": "layernorm", "inputs": ["d"],
                 "attrs": {"dim": 8},
                 "weights": {"gamma": [8], "beta": [8]}}
              ]
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn figure4_ffnn_merge() {
        // paper Figure 4: bmm (Batch) -> refmt -> group norm (Channel)
        let mg = merge(&ffnn(), 2).unwrap();
        let kinds: Vec<&str> = mg.nodes.iter().map(|n| n.kind.as_str()).collect();
        assert_eq!(kinds, vec!["dense", "refmt", "groupnorm"]);
        let gn = mg.node("ln").unwrap();
        assert_eq!(gn.attr_i64("groups").unwrap(), 2);
        let r = mg.node(&gn.inputs[0]).unwrap();
        assert_eq!(r.attrs["src"].as_str(), Some("batch"));
        assert_eq!(r.attrs["dst"].as_str(), Some("channel"));
    }

    #[test]
    fn conv_groups_multiply() {
        let g = Graph::parse(
            r#"{
              "name": "c", "input_shape": [4, 8, 8], "output": "cv",
              "nodes": [
                {"id": "cv", "kind": "conv2d", "inputs": ["input"],
                 "attrs": {"cin": 4, "cout": 6, "k": 3, "stride": 1,
                           "padding": 1, "groups": 2},
                 "weights": {"w": [6, 2, 3, 3], "b": [6]}}
              ]
            }"#,
        )
        .unwrap();
        let mg = merge(&g, 4).unwrap();
        let cv = mg.node("cv").unwrap();
        assert_eq!(cv.attr_i64("groups").unwrap(), 8); // M x G
        assert_eq!(cv.attr_i64("cout").unwrap(), 24);
        assert_eq!(cv.weights["w"], vec![24, 2, 3, 3]);
    }

    #[test]
    fn rejects_double_merge_and_bad_m() {
        let g = ffnn();
        let mg = merge(&g, 2).unwrap();
        assert!(merge(&mg, 2).is_err());
        assert!(merge(&g, 0).is_err());
    }

    #[test]
    fn elide_cancels_inverse_pair() {
        // dense -> LN -> dense: merge inserts b->c then c->b
        let g = Graph::parse(
            r#"{
              "name": "f2", "input_shape": [8], "output": "d2",
              "nodes": [
                {"id": "d1", "kind": "dense", "inputs": ["input"],
                 "attrs": {"fin": 8, "fout": 8},
                 "weights": {"w": [8, 8], "b": [8]}},
                {"id": "ln", "kind": "layernorm", "inputs": ["d1"],
                 "attrs": {"dim": 8},
                 "weights": {"gamma": [8], "beta": [8]}},
                {"id": "d2", "kind": "dense", "inputs": ["ln"],
                 "attrs": {"fin": 8, "fout": 8},
                 "weights": {"w": [8, 8], "b": [8]}}
              ]
            }"#,
        )
        .unwrap();
        let mg = merge(&g, 2).unwrap();
        let n_refmt = mg.nodes.iter().filter(|n| n.kind == "refmt").count();
        assert_eq!(n_refmt, 2);
        let opt = elide_refmt_pairs(&mg);
        opt.validate().unwrap();
        // an inverse pair cannot be fully removed here (ln still needs its
        // channel view), but no *chain* of two refmts should survive
        for n in &opt.nodes {
            if n.kind == "refmt" {
                let p = opt.node(&n.inputs[0]);
                if let Ok(p) = p {
                    assert_ne!(p.kind, "refmt", "refmt chain survived");
                }
            }
        }
    }
}
