//! Merged-weight construction: stack M per-instance weight banks into the
//! merged graph's parameter tensors (the Rust mirror of
//! `netfuse.merge_weights`).
//!
//! Per-op rules (paper §3.1):
//! - Channel-merged ops (grouped conv, norms): concat on axis 0.
//! - Batch-merged ops (batch matmul, attention): stack on a new leading
//!   axis.
//! - Per-instance heads (`{orig}__m{i}`): instance i's tensor unchanged.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::graph::Graph;
use crate::tensor::Tensor;

/// One model instance's weights: `"node.weight" -> tensor`.
pub type Bank = BTreeMap<String, Tensor>;

/// Build the merged graph's parameters from M per-instance banks.
pub fn merge_weights(merged: &Graph, banks: &[Bank]) -> Result<Bank> {
    let m = merged.merged_m;
    if banks.len() != m {
        bail!("expected {} weight banks, got {}", m, banks.len());
    }
    let mut out = Bank::new();
    for node in &merged.nodes {
        if node.weights.is_empty() {
            continue;
        }
        // per-instance head: "{orig}__m{i}"
        if let Some((orig, idx)) = split_head_id(&node.id) {
            let bank = banks
                .get(idx)
                .with_context(|| format!("head {} wants bank {}", node.id, idx))?;
            for wname in node.weights.keys() {
                let t = bank
                    .get(&format!("{orig}.{wname}"))
                    .with_context(|| format!("missing weight {orig}.{wname}"))?;
                out.insert(format!("{}.{}", node.id, wname), t.clone());
            }
            continue;
        }
        for (wname, want_shape) in &node.weights {
            let key = format!("{}.{}", node.id, wname);
            let parts: Vec<&Tensor> = banks
                .iter()
                .map(|b| {
                    b.get(&key)
                        .with_context(|| format!("missing weight {key}"))
                })
                .collect::<Result<_>>()?;
            let single_rank = parts[0].rank();
            let t = if want_shape.len() > single_rank {
                Tensor::stack(&parts)? // Batch-merged: new leading axis
            } else {
                Tensor::concat(&parts, 0)? // Channel-merged: concat axis 0
            };
            if t.shape() != want_shape.as_slice() {
                bail!(
                    "merged weight {key}: got {:?}, expected {:?}",
                    t.shape(), want_shape
                );
            }
            out.insert(key, t);
        }
    }
    Ok(out)
}

/// `"{orig}__m{i}" -> (orig, i)` for per-instance head nodes.
fn split_head_id(id: &str) -> Option<(&str, usize)> {
    let pos = id.rfind("__m")?;
    let idx: usize = id[pos + 3..].parse().ok()?;
    Some((&id[..pos], idx))
}

/// Parameter tensors in the executable's positional order, **borrowed**
/// from the bank — binding a module uploads straight from these
/// references, so the load path no longer clones every weight tensor.
pub fn params_in_order<'b>(g: &Graph, bank: &'b Bank) -> Result<Vec<&'b Tensor>> {
    g.param_order()
        .iter()
        .map(|key| {
            bank.get(key)
                .with_context(|| format!("missing param {key}"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fuse::merge;

    fn ffnn() -> Graph {
        Graph::parse(
            r#"{
              "name": "ffnn", "input_shape": [4], "output": "ln",
              "nodes": [
                {"id": "d", "kind": "dense", "inputs": ["input"],
                 "attrs": {"fin": 4, "fout": 4},
                 "weights": {"w": [4, 4], "b": [4]}},
                {"id": "ln", "kind": "layernorm", "inputs": ["d"],
                 "attrs": {"dim": 4},
                 "weights": {"gamma": [4], "beta": [4]}}
              ]
            }"#,
        )
        .unwrap()
    }

    fn bank(fill: f32) -> Bank {
        let mut b = Bank::new();
        b.insert("d.w".into(), Tensor::new(vec![4, 4], vec![fill; 16]).unwrap());
        b.insert("d.b".into(), Tensor::new(vec![4], vec![fill; 4]).unwrap());
        b.insert("ln.gamma".into(), Tensor::new(vec![4], vec![fill; 4]).unwrap());
        b.insert("ln.beta".into(), Tensor::new(vec![4], vec![fill; 4]).unwrap());
        b
    }

    #[test]
    fn stacks_and_concats() {
        let g = ffnn();
        let mg = merge(&g, 2).unwrap();
        let merged = merge_weights(&mg, &[bank(1.0), bank(2.0)]).unwrap();
        // dense stacked on new axis
        assert_eq!(merged["d.w"].shape(), &[2, 4, 4]);
        assert_eq!(merged["d.w"].data()[0], 1.0);
        assert_eq!(merged["d.w"].data()[16], 2.0);
        // layernorm -> groupnorm concat
        assert_eq!(merged["ln.gamma"].shape(), &[8]);
        assert_eq!(merged["ln.gamma"].data()[4], 2.0);
    }

    #[test]
    fn wrong_bank_count_rejected() {
        let g = ffnn();
        let mg = merge(&g, 2).unwrap();
        assert!(merge_weights(&mg, &[bank(1.0)]).is_err());
    }

    #[test]
    fn missing_weight_rejected() {
        let g = ffnn();
        let mg = merge(&g, 2).unwrap();
        let mut b2 = bank(2.0);
        b2.remove("ln.beta");
        assert!(merge_weights(&mg, &[bank(1.0), b2]).is_err());
    }

    #[test]
    fn head_id_parsing() {
        assert_eq!(split_head_id("dense_3__m12"), Some(("dense_3", 12)));
        assert_eq!(split_head_id("dense_3"), None);
        assert_eq!(split_head_id("x__mzz"), None);
    }

    #[test]
    fn params_in_order_matches_param_order() {
        let g = ffnn();
        let b = bank(1.0);
        let ps = params_in_order(&g, &b).unwrap();
        assert_eq!(ps.len(), 4); // d.b, d.w, ln.beta, ln.gamma
        assert_eq!(ps[0].shape(), &[4]); // d.b first (sorted)
        // borrowed, not cloned: the refs alias the bank's storage
        assert_eq!(ps[0].data().as_ptr(), b["d.b"].data().as_ptr());
    }
}
