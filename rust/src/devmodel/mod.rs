//! Analytical GPU device model — the hardware substitute (DESIGN.md §5).
//!
//! We have no V100 / TITAN Xp; this module reproduces the paper's
//! GPU-shaped results from first principles. Per-op cost on a profile:
//!
//! ```text
//! t_op = launch_us + max( flops / (peak_flops * occupancy),
//!                         bytes / mem_bw )
//! occupancy = min(1, parallel_elems / (sms * wave))
//! ```
//!
//! The two mechanisms the paper's speedups hinge on are both explicit
//! here: (i) per-kernel *launch overhead*, paid M times by the baselines
//! and once by NETFUSE; (ii) *occupancy*, low for one small-batch model
//! and restored by the M-fold wider merged kernels. At large batch sizes
//! single-model occupancy is already ~1, so merging stops helping —
//! Figure 6's crossover falls out of the model rather than being
//! hand-tuned in.

pub mod fullscale;
pub mod sim;

/// A GPU hardware profile.
#[derive(Debug, Clone, Copy)]
pub struct GpuProfile {
    pub name: &'static str,
    /// streaming multiprocessors
    pub sms: f64,
    /// peak f32 FLOP/s
    pub peak_flops: f64,
    /// HBM/GDDR bandwidth, bytes/s
    pub mem_bw: f64,
    /// per-kernel launch + dispatch overhead, seconds
    pub launch_s: f64,
    /// inter-process context-switch cost per kernel when time-slicing
    /// without MPS (the Concurrent baseline), seconds
    pub switch_s: f64,
    /// minimum effective kernel duration under time-slicing (scheduling
    /// quantum floor), seconds
    pub slice_q: f64,
    /// max cross-process kernel co-residency (Volta supports a handful
    /// of contexts co-scheduled when occupancy is low)
    pub overlap_cap: f64,
    /// device memory, bytes
    pub capacity: u64,
    /// resident threads per SM (occupancy denominator)
    pub wave: f64,
}

/// NVIDIA V100 (AWS p3.2xlarge, the paper's §5.1 primary testbed).
pub const V100: GpuProfile = GpuProfile {
    name: "V100",
    sms: 80.0,
    peak_flops: 15.7e12,
    mem_bw: 900.0e9,
    launch_s: 5.0e-6,
    switch_s: 2.0e-6,
    slice_q: 3.0e-6,
    overlap_cap: 4.0,
    capacity: 16 * 1024 * 1024 * 1024,
    wave: 2048.0,
};

/// NVIDIA TITAN Xp (the paper's Appendix B testbed). Fewer SMs => less
/// parallel headroom => smaller NETFUSE gains (Appendix B observation).
pub const TITAN_XP: GpuProfile = GpuProfile {
    name: "TITANXp",
    sms: 30.0,
    peak_flops: 12.1e12,
    mem_bw: 547.6e9,
    launch_s: 5.0e-6,
    switch_s: 2.0e-6,
    slice_q: 4.0e-6,
    overlap_cap: 2.0,
    capacity: 12 * 1024 * 1024 * 1024,
    wave: 2048.0,
};

pub fn profile(name: &str) -> Option<GpuProfile> {
    match name.to_ascii_lowercase().as_str() {
        "v100" => Some(V100),
        "titanxp" | "titan_xp" | "xp" => Some(TITAN_XP),
        _ => None,
    }
}

/// One kernel's abstract cost.
#[derive(Debug, Clone, Copy)]
pub struct OpCost {
    /// floating point operations
    pub flops: f64,
    /// bytes moved (inputs + outputs + weights)
    pub bytes: f64,
    /// independent output elements (occupancy proxy)
    pub parallel: f64,
    /// extra serialization cost (seconds) this op pays *per execution*
    /// under process-level time-slicing (the Concurrent baseline).
    /// Zero for ordinary kernels; the Transformer-XL relative-position
    /// stream is flagged with a positive penalty — the modeled
    /// instantiation of the paper's §5.2 conjecture that XLNet's "extra
    /// computations render concurrent executions more ineffective".
    pub slice_penalty: f64,
}

/// Convenience constructor for ordinary (penalty-free) kernels.
pub fn op(flops: f64, bytes: f64, parallel: f64) -> OpCost {
    OpCost { flops, bytes, parallel, slice_penalty: 0.0 }
}

impl OpCost {
    /// Execution time of this kernel alone on `p` (excluding launch).
    pub fn compute_s(&self, p: &GpuProfile) -> f64 {
        let occ = (self.parallel / (p.sms * p.wave)).clamp(1.0 / 512.0, 1.0);
        let t_flops = self.flops / (p.peak_flops * occ);
        let t_bytes = self.bytes / p.mem_bw;
        t_flops.max(t_bytes)
    }

    /// The same op with M instances merged into one kernel: M x work,
    /// M x parallelism, ONE launch (applied by the caller). The merged
    /// kernel runs in one process: no slicing penalty.
    pub fn merged(&self, m: usize) -> OpCost {
        OpCost {
            flops: self.flops * m as f64,
            bytes: self.bytes * m as f64,
            parallel: self.parallel * m as f64,
            slice_penalty: 0.0,
        }
    }

    /// Execution time under process-level time-slicing with `streams`
    /// co-resident processes: low-occupancy kernels gain cross-process
    /// overlap (up to `overlap_cap` contexts), but every kernel pays the
    /// scheduling-quantum floor and its slicing penalty.
    pub fn sliced_s(&self, p: &GpuProfile, streams: usize) -> f64 {
        let boost = (streams as f64).min(p.overlap_cap);
        let occ = (self.parallel / (p.sms * p.wave)).clamp(1.0 / 512.0, 1.0);
        let eff_occ = (occ * boost).min(1.0);
        let t_flops = self.flops / (p.peak_flops * eff_occ);
        let t_bytes = self.bytes / p.mem_bw;
        t_flops.max(t_bytes).max(p.slice_q) + self.slice_penalty
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn low_occupancy_hurts() {
        let small = op(1e9, 1e6, 10_000.0);
        let wide = op(1e9, 1e6, 10_000_000.0);
        assert!(small.compute_s(&V100) > wide.compute_s(&V100));
    }

    #[test]
    fn merging_improves_efficiency_at_low_occupancy() {
        let op = op(1e9, 1e6, 20_000.0);
        let m = 8;
        // 8 separate executions vs one 8-wide execution
        let separate = m as f64 * op.compute_s(&V100);
        let merged = op.merged(m).compute_s(&V100);
        assert!(merged < separate * 0.5, "{merged} vs {separate}");
    }

    #[test]
    fn merging_is_neutral_at_full_occupancy() {
        let op = op(1e10, 1e6, 1e9);
        let separate = 4.0 * op.compute_s(&V100);
        let merged = op.merged(4).compute_s(&V100);
        assert!((merged - separate).abs() / separate < 0.01);
    }

    #[test]
    fn bandwidth_bound_ops() {
        let op = op(1e3, 1e9, 1e9);
        let t = op.compute_s(&V100);
        assert!((t - 1e9 / 900.0e9).abs() / t < 1e-6);
    }

    #[test]
    fn profiles_resolve() {
        assert_eq!(profile("v100").unwrap().name, "V100");
        assert_eq!(profile("TitanXp").unwrap().name, "TITANXp");
        assert!(profile("a100").is_none());
    }
}
