//! Full-scale architecture cost tables: ResNet-50, ResNeXt-50 (32x4d),
//! BERT-base and XLNet-base — the models the paper evaluates (§5.1).
//!
//! The measured path runs op-faithful *mini* models on CPU PJRT; this
//! module carries the real architectures' per-kernel FLOP/byte/width
//! counts so the device model reproduces the paper's absolute-scale
//! behaviour (launch-bound at bs=1, saturation at bs=8, memory bars in
//! GB). Derived from the published architectures, not fitted to the
//! paper's plots.

use super::{op, OpCost};
use crate::coordinator::memory::ModelFootprint;

const F32: f64 = 4.0;

fn conv(bs: usize, cin: f64, cout: f64, k: f64, hw_out: f64, groups: f64) -> OpCost {
    let b = bs as f64;
    let out_elems = b * cout * hw_out * hw_out;
    // Grouped convolutions tile per group: each group's GEMM is small,
    // so achievable parallelism degrades with the group count (this is
    // why ResNeXt-50 is the most launch/occupancy-bound single model and
    // why it shows the paper's largest CNN speedup, 3.4x). The merged
    // conv has M x more groups but also M x more total work, so its
    // *per-group* efficiency matches — modeled by the same penalty.
    op(
        2.0 * out_elems * (cin / groups) * k * k,
        F32 * (b * cin * (hw_out * hw_out) + out_elems + cout * cin / groups * k * k),
        out_elems / groups.sqrt(),
    )
}

/// bandwidth-bound elementwise kernel (BN / ReLU / residual add)
fn eltwise(bs: usize, c: f64, hw: f64, reads: f64) -> OpCost {
    let elems = bs as f64 * c * hw * hw;
    op(2.0 * elems, F32 * elems * (reads + 1.0), elems)
}

fn matmul(bs_rows: f64, k: f64, n: f64) -> OpCost {
    op(
        2.0 * bs_rows * k * n,
        F32 * (bs_rows * k + k * n + bs_rows * n),
        bs_rows * n,
    )
}

fn rowwise(rows: f64, width: f64) -> OpCost {
    // LN / softmax / gelu: 2 passes over the tensor
    op(8.0 * rows * width, F32 * rows * width * 2.0, rows * width)
}

// ---------------------------------------------------------------------------
// CNNs
// ---------------------------------------------------------------------------

fn bottleneck(
    ops: &mut Vec<OpCost>,
    bs: usize,
    cin: f64,
    cmid: f64,
    cout: f64,
    hw: f64,
    stride: f64,
    groups: f64,
    downsample: bool,
) {
    let hw_out = hw / stride;
    ops.push(conv(bs, cin, cmid, 1.0, hw, 1.0)); // 1x1 reduce (pre-stride)
    ops.push(eltwise(bs, cmid, hw, 1.0)); // bn+relu (fused)
    ops.push(conv(bs, cmid, cmid, 3.0, hw_out, groups)); // 3x3 (grouped for resnext)
    ops.push(eltwise(bs, cmid, hw_out, 1.0));
    ops.push(conv(bs, cmid, cout, 1.0, hw_out, 1.0)); // 1x1 expand
    ops.push(eltwise(bs, cout, hw_out, 1.0));
    if downsample {
        ops.push(conv(bs, cin, cout, 1.0, hw_out, 1.0));
        ops.push(eltwise(bs, cout, hw_out, 1.0));
    }
    ops.push(eltwise(bs, cout, hw_out, 2.0)); // residual add + relu
}

fn resnet_like(bs: usize, cardinality: f64, width_mult: f64) -> Vec<OpCost> {
    let mut ops = Vec::new();
    // stem: 7x7/2 conv to 64ch @112, bn+relu, 3x3/2 maxpool -> 56
    ops.push(conv(bs, 3.0, 64.0, 7.0, 112.0, 1.0));
    ops.push(eltwise(bs, 64.0, 112.0, 1.0));
    ops.push(eltwise(bs, 64.0, 56.0, 1.0)); // maxpool
    // stages: (cout, base cmid, blocks, hw_in)
    let stages: [(f64, f64, usize, f64); 4] = [
        (256.0, 64.0, 3, 56.0),
        (512.0, 128.0, 4, 56.0),
        (1024.0, 256.0, 6, 28.0),
        (2048.0, 512.0, 3, 14.0),
    ];
    let mut cin = 64.0;
    for (si, (cout, cmid_base, blocks, hw_in)) in stages.iter().enumerate() {
        let cmid = cmid_base * width_mult;
        let mut hw = *hw_in;
        for b in 0..*blocks {
            let stride = if si > 0 && b == 0 { 2.0 } else { 1.0 };
            bottleneck(&mut ops, bs, cin, cmid, *cout, hw, stride, cardinality, b == 0);
            hw /= stride;
            cin = *cout;
        }
    }
    ops.push(eltwise(bs, 2048.0, 7.0, 1.0)); // global average pool
    ops.push(matmul(bs as f64, 2048.0, 1000.0)); // classifier head
    ops
}

/// ResNet-50 @224 (25.6M params, ~4.1 GFLOPs at bs=1).
pub fn resnet50(bs: usize) -> Vec<OpCost> {
    resnet_like(bs, 1.0, 1.0)
}

/// ResNeXt-50 32x4d @224 (25.0M params, ~4.2 GFLOPs at bs=1).
pub fn resnext50(bs: usize) -> Vec<OpCost> {
    resnet_like(bs, 32.0, 2.0)
}

// ---------------------------------------------------------------------------
// Transformers
// ---------------------------------------------------------------------------

fn encoder_layer(ops: &mut Vec<OpCost>, bs: usize, s: f64, h: f64, ffn: f64, xl: bool) {
    let rows = bs as f64 * s;
    // q, k, v projections
    for _ in 0..3 {
        ops.push(matmul(rows, h, h));
    }
    if xl {
        // the Transformer-XL relative-position stream: projection, the
        // b·d attention term, and the u/v bias adds. Flagged with a
        // time-slicing penalty: this chain is what makes Concurrent the
        // *slowest* baseline for XLNet in the paper (§5.2) — see
        // OpCost::slice_penalty.
        const XL_SLICE_PENALTY: f64 = 110.0e-6;
        let mut r_proj = matmul(s, h, h); // relative-position projection r*Wr
        r_proj.slice_penalty = XL_SLICE_PENALTY;
        ops.push(r_proj);
        let mut bd = matmul(rows, h, s); // position attention stream (b*d)
        bd.slice_penalty = XL_SLICE_PENALTY;
        ops.push(bd);
        ops.push(eltwise(bs, 1.0, (s * s).sqrt(), 2.0)); // bias adds
    }
    ops.push(matmul(rows, h, s)); // content scores qk^T
    ops.push(rowwise(rows, s)); // softmax
    ops.push(matmul(rows, s, h)); // attn * v
    ops.push(matmul(rows, h, h)); // output projection
    ops.push(eltwise(bs, 1.0, (s * h).sqrt(), 2.0)); // residual add
    ops.push(rowwise(rows, h)); // layer norm
    ops.push(matmul(rows, h, ffn)); // FFN up
    ops.push(rowwise(rows, ffn)); // gelu
    ops.push(matmul(rows, ffn, h)); // FFN down
    ops.push(eltwise(bs, 1.0, (s * h).sqrt(), 2.0)); // residual add
    ops.push(rowwise(rows, h)); // layer norm
}

/// BERT-base, seq 128 (110M params): 12 x (h=768, ffn=3072).
pub fn bert_base(bs: usize) -> Vec<OpCost> {
    let mut ops = Vec::new();
    for _ in 0..12 {
        encoder_layer(&mut ops, bs, 128.0, 768.0, 3072.0, false);
    }
    ops.push(matmul(bs as f64 * 128.0, 768.0, 768.0)); // task head
    ops
}

/// XLNet-base, seq 128 (117M params): Transformer-XL layers — more
/// kernels and more FLOPs per layer than BERT (the §5.2 observation).
pub fn xlnet_base(bs: usize) -> Vec<OpCost> {
    let mut ops = Vec::new();
    for _ in 0..12 {
        encoder_layer(&mut ops, bs, 128.0, 768.0, 3072.0, true);
    }
    ops.push(matmul(bs as f64 * 128.0, 768.0, 768.0));
    ops
}

/// Per-kernel op list for a paper model at batch size `bs`.
pub fn model_ops(name: &str, bs: usize) -> Option<Vec<OpCost>> {
    Some(match name {
        "resnet" => resnet50(bs),
        "resnext" => resnext50(bs),
        "bert" => bert_base(bs),
        "xlnet" => xlnet_base(bs),
        _ => return None,
    })
}

/// Parameter bytes of the full-scale models.
pub fn weight_bytes(name: &str) -> u64 {
    match name {
        "resnet" => 25_600_000 * 4,
        "resnext" => 25_000_000 * 4,
        "bert" => 110_000_000 * 4,
        "xlnet" => 117_000_000 * 4,
        _ => 0,
    }
}

/// Activation workspace: inference frameworks free intermediates as
/// soon as their consumer runs, so the live set is a few tensors, not
/// the whole graph — we charge 3x the largest kernel output (double
/// buffering + residual skip), which reproduces the paper's "weights
/// dominate the workspace" memory bars.
pub fn act_bytes(name: &str, bs: usize) -> u64 {
    let ops = model_ops(name, bs).unwrap_or_default();
    let max_out = ops
        .iter()
        .map(|o| (o.parallel * F32) as u64)
        .max()
        .unwrap_or(0);
    3 * max_out
}

/// Full-scale memory footprint for the memory model (Figures 7/10).
pub fn footprint(name: &str, bs: usize, m: usize) -> ModelFootprint {
    let w = weight_bytes(name);
    let a = act_bytes(name, bs);
    ModelFootprint {
        weights_bytes: w,
        act_bytes: a,
        fused_weights_bytes: w * m as u64,
        fused_act_bytes: a * m as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet50_flops_about_4gf() {
        let total: f64 = resnet50(1).iter().map(|o| o.flops).sum();
        // 4.1 GMACs in the literature == ~8.2 GFLOPs (2 flops/MAC)
        assert!(
            (7.0e9..10.0e9).contains(&total),
            "resnet50 flops {total:.2e} out of expected band"
        );
    }

    #[test]
    fn bert_flops_about_22gf() {
        // 2 * 110M params * 128 tokens ~ 22 GFLOPs (plus attention)
        let total: f64 = bert_base(1).iter().map(|o| o.flops).sum();
        assert!(
            (15e9..40e9).contains(&total),
            "bert flops {total:.2e} out of expected band"
        );
    }

    #[test]
    fn xlnet_heavier_than_bert() {
        let b: f64 = bert_base(1).iter().map(|o| o.flops).sum();
        let x: f64 = xlnet_base(1).iter().map(|o| o.flops).sum();
        assert!(x > b);
        assert!(xlnet_base(1).len() > bert_base(1).len());
    }

    #[test]
    fn flops_scale_with_batch() {
        let f1: f64 = resnet50(1).iter().map(|o| o.flops).sum();
        let f8: f64 = resnet50(8).iter().map(|o| o.flops).sum();
        assert!((f8 / f1 - 8.0).abs() < 0.2);
    }

    #[test]
    fn footprints_are_gb_scale() {
        let fp = footprint("bert", 1, 16);
        assert!(fp.weights_bytes > 400 << 20);
        assert!(fp.fused_weights_bytes == 16 * fp.weights_bytes);
    }
}
