//! Strategy simulation on a GPU profile: predicts the paper's inference
//! times per (model, M, bs, strategy) — the engine behind Figures 5, 6,
//! 8 and 9.

use anyhow::{bail, Result};

use crate::coordinator::memory::{self, MemoryEstimate};
use crate::coordinator::strategy::StrategyKind;

use super::{fullscale, GpuProfile, OpCost};

/// Predicted inference time (seconds) for one round of M models.
pub fn predict(
    p: &GpuProfile,
    model: &str,
    m: usize,
    bs: usize,
    strategy: StrategyKind,
) -> Result<f64> {
    let Some(ops) = fullscale::model_ops(model, bs) else {
        bail!("unknown model {model:?}");
    };
    Ok(match strategy {
        StrategyKind::Sequential => {
            // M full passes, launches and compute both serialized
            let one: f64 = ops
                .iter()
                .map(|o| p.launch_s + o.compute_s(p))
                .sum();
            one * m as f64
        }
        StrategyKind::Concurrent => concurrent_time(p, &ops, m),
        StrategyKind::Hybrid { procs } => {
            // A concurrent workers, each a sequential chain of B models.
            let procs = procs.min(m);
            let per_worker = m.div_ceil(procs);
            // each worker behaves like `Concurrent` with `procs` streams,
            // repeated `per_worker` times
            concurrent_time(p, &ops, procs) * per_worker as f64
        }
        StrategyKind::NetFuse => {
            // one launch per op, M x wider kernels
            ops.iter()
                .map(|o| p.launch_s + o.merged(m).compute_s(p))
                .sum()
        }
    })
}

/// M unsynchronized processes sharing the device (no MPS): compute
/// serializes at the device, launches overlap across processes, but each
/// kernel boundary pays a context-switch cost — with enough processes
/// and enough kernels this overtakes the launch savings, which is why
/// the paper sees Concurrent *lose* to Sequential on XLNet (§5.2).
fn concurrent_time(p: &GpuProfile, ops: &[OpCost], m: usize) -> f64 {
    if m == 1 {
        // one process: identical to Sequential with M=1
        return ops.iter().map(|o| p.launch_s + o.compute_s(p)).sum();
    }
    // GPU compute serializes across processes, but (i) low-occupancy
    // kernels co-schedule across up to `overlap_cap` contexts, (ii) CPU
    // launch streams overlap (only one stream's worth stays exposed),
    // while (iii) every kernel pays the time-slicing quantum + context
    // switch, and penalty-flagged ops (Transformer-XL) pay extra.
    let compute: f64 = ops.iter().map(|o| o.sliced_s(p, m)).sum::<f64>() * m as f64;
    let launches: f64 = ops.len() as f64 * p.launch_s;
    let switches = ops.len() as f64 * m as f64 * p.switch_s;
    compute + launches + switches
}

/// Memory estimate at full scale (Figures 7 / 10).
pub fn predict_memory(
    model: &str,
    m: usize,
    bs: usize,
    strategy: StrategyKind,
) -> MemoryEstimate {
    let fp = fullscale::footprint(model, bs, m);
    memory::estimate(strategy, m, &fp)
}

/// Convenience: the NETFUSE speedup over the best baseline *that fits
/// device memory* — in the paper the Concurrent baseline OOMs at 16-32
/// models (Figure 7), so the reported speedups there are vs Sequential.
pub fn speedup_vs_best_baseline(
    p: &GpuProfile,
    model: &str,
    m: usize,
    bs: usize,
) -> Result<f64> {
    let nf = predict(p, model, m, bs, StrategyKind::NetFuse)?;
    let seq = predict(p, model, m, bs, StrategyKind::Sequential)?;
    let mut best = seq;
    if predict_memory(model, m, bs, StrategyKind::Concurrent).fits(p.capacity) {
        best = best.min(predict(p, model, m, bs, StrategyKind::Concurrent)?);
    }
    Ok(best / nf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::devmodel::{TITAN_XP, V100};

    #[test]
    fn sequential_linear_in_m() {
        let t8 = predict(&V100, "resnet", 8, 1, StrategyKind::Sequential).unwrap();
        let t16 = predict(&V100, "resnet", 16, 1, StrategyKind::Sequential).unwrap();
        assert!((t16 / t8 - 2.0).abs() < 0.01);
    }

    #[test]
    fn netfuse_wins_at_bs1_m32() {
        // paper §5.2: up to 2.6x / 3.4x / 2.7x / 3.6x on V100
        for model in ["resnet", "resnext", "bert", "xlnet"] {
            let s = speedup_vs_best_baseline(&V100, model, 32, 1).unwrap();
            assert!(s > 1.5, "{model}: speedup {s:.2} too small");
            assert!(s < 8.0, "{model}: speedup {s:.2} implausibly large");
        }
    }

    #[test]
    fn gap_narrows_with_batch_size() {
        // paper Figure 6: merging helps less as bs grows
        let s1 = speedup_vs_best_baseline(&V100, "bert", 16, 1).unwrap();
        let s8 = speedup_vs_best_baseline(&V100, "bert", 16, 8).unwrap();
        assert!(s8 < s1, "bs=8 speedup {s8:.2} !< bs=1 speedup {s1:.2}");
    }

    #[test]
    fn titan_xp_gains_smaller_than_v100() {
        // paper Appendix B: fewer SMs => smaller relative gains
        let v = speedup_vs_best_baseline(&V100, "resnext", 32, 1).unwrap();
        let x = speedup_vs_best_baseline(&TITAN_XP, "resnext", 32, 1).unwrap();
        assert!(x < v, "TITANXp {x:.2} !< V100 {v:.2}");
    }

    #[test]
    fn concurrent_slowest_for_xlnet() {
        // paper §5.2: XLNet's extra kernels make Concurrent the worst
        let seq = predict(&V100, "xlnet", 32, 1, StrategyKind::Sequential).unwrap();
        let conc = predict(&V100, "xlnet", 32, 1, StrategyKind::Concurrent).unwrap();
        assert!(conc > seq, "concurrent {conc:.4} !> sequential {seq:.4}");
    }

    #[test]
    fn concurrent_beats_sequential_for_resnet() {
        let seq = predict(&V100, "resnet", 16, 1, StrategyKind::Sequential).unwrap();
        let conc = predict(&V100, "resnet", 16, 1, StrategyKind::Concurrent).unwrap();
        assert!(conc < seq, "concurrent {conc:.4} !< sequential {seq:.4}");
    }

    #[test]
    fn hybrid_between_extremes() {
        let seq = predict(&V100, "resnext", 32, 1, StrategyKind::Sequential).unwrap();
        let h4 = predict(&V100, "resnext", 32, 1, StrategyKind::Hybrid { procs: 4 }).unwrap();
        let nf = predict(&V100, "resnext", 32, 1, StrategyKind::NetFuse).unwrap();
        assert!(h4 < seq);
        assert!(nf < h4);
    }

    #[test]
    fn concurrent_oom_at_16_models_v100() {
        // paper Figure 7: concurrent runs out of the 16 GB V100
        let e = predict_memory("resnet", 16, 1, StrategyKind::Concurrent);
        assert!(!e.fits(V100.capacity), "expected OOM, got {} bytes", e.total);
        let s = predict_memory("resnet", 16, 1, StrategyKind::Sequential);
        assert!(s.fits(V100.capacity));
    }

    #[test]
    fn netfuse_memory_small_extra() {
        let seq = predict_memory("bert", 8, 1, StrategyKind::Sequential);
        let nf = predict_memory("bert", 8, 1, StrategyKind::NetFuse);
        assert!(nf.total < seq.total * 2);
        assert!(nf.fits(V100.capacity));
    }
}
