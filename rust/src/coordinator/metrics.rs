//! Serving metrics: per-strategy latency/throughput collection and the
//! table-formatted reports the benches print.

use std::time::{Duration, Instant};

use crate::util::stats::{fmt_secs, Latencies};

use super::strategy::StrategyKind;

/// Rolling metrics for one (strategy, configuration) run.
#[derive(Debug)]
pub struct Metrics {
    pub strategy: StrategyKind,
    pub model: String,
    pub m: usize,
    pub bs: usize,
    /// end-to-end request latency (arrival -> response)
    pub request_latency: Latencies,
    /// wall time per fleet round (the paper's "inference time")
    pub round_latency: Latencies,
    /// throughput clock: the EARLIEST recorded request arrival (each
    /// completion instant minus its latency), not construction time —
    /// `Metrics::new` runs at fleet load, and counting load/idle time
    /// understated steady-state requests/sec. Anchoring at arrival
    /// (rather than first completion) keeps request service and queue
    /// time in the denominator, so a 1-request run reports 1/latency
    /// instead of a near-infinite rate.
    first_arrival: Option<Instant>,
    pub completed_requests: u64,
    /// end-to-end latency target (seconds) this lane was registered
    /// with (`MultiServer::add_lane_qos`); `None` = no SLO accounting
    pub slo: Option<f64>,
    /// completed requests whose end-to-end latency exceeded `slo`
    pub slo_violations: u64,
}

impl Metrics {
    pub fn new(strategy: StrategyKind, model: &str, m: usize, bs: usize) -> Metrics {
        Metrics {
            strategy,
            model: model.to_string(),
            m,
            bs,
            request_latency: Latencies::new(),
            round_latency: Latencies::new(),
            first_arrival: None,
            completed_requests: 0,
            slo: None,
            slo_violations: 0,
        }
    }

    pub fn record_round(&mut self, seconds: f64) {
        self.round_latency.record(seconds);
    }

    pub fn record_request(&mut self, latency: f64) {
        // reconstruct this request's arrival from its end-to-end
        // latency and keep the EARLIEST one seen: recording order is
        // slot order, not arrival order, so a long-queued request may
        // be recorded after a fresh one in the same round — the
        // throughput span must still start at the oldest arrival
        let now = Instant::now();
        let arrived = now
            .checked_sub(Duration::from_secs_f64(latency.max(0.0)))
            .unwrap_or(now);
        self.first_arrival = Some(match self.first_arrival {
            Some(first) => first.min(arrived),
            None => arrived,
        });
        self.request_latency.record(latency);
        self.completed_requests += 1;
        if let Some(slo) = self.slo {
            if latency > slo {
                self.slo_violations += 1;
            }
        }
    }

    /// Requests per second since the first recorded request ARRIVED
    /// (0.0 until a measurable span exists). Fleet-load and pre-traffic
    /// idle time are excluded so the number reflects steady-state
    /// serving rate.
    pub fn throughput(&self) -> f64 {
        let Some(first) = self.first_arrival else {
            return 0.0;
        };
        let secs = first.elapsed().as_secs_f64();
        if secs > 0.0 {
            self.completed_requests as f64 / secs
        } else {
            0.0
        }
    }

    pub fn report_line(&self) -> String {
        let r = &self.round_latency;
        let q = &self.request_latency;
        format!(
            "{:<10} {:<8} m={:<3} bs={:<2} rounds={:<5} round: {:>10} ± {:>9} \
             p50={:>10} p99={:>10} | req p50={:>10} p95={:>10} p99={:>10} slo_viol={}",
            self.strategy.to_string(),
            self.model,
            self.m,
            self.bs,
            r.count(),
            fmt_secs(r.summary().mean()),
            fmt_secs(r.summary().std()),
            fmt_secs(r.p50()),
            fmt_secs(r.p99()),
            fmt_secs(q.p50()),
            fmt_secs(q.p95()),
            fmt_secs(q.p99()),
            self.slo_violations,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut m = Metrics::new(StrategyKind::NetFuse, "bert", 4, 1);
        m.record_round(0.010);
        m.record_round(0.012);
        m.record_request(0.011);
        assert_eq!(m.round_latency.count(), 2);
        assert_eq!(m.completed_requests, 1);
        let line = m.report_line();
        assert!(line.contains("netfuse") && line.contains("bert"));
    }

    #[test]
    fn slo_violations_counted_and_reported() {
        let mut m = Metrics::new(StrategyKind::NetFuse, "bert", 4, 1);
        m.slo = Some(0.010);
        m.record_request(0.005);
        m.record_request(0.011); // violation
        m.record_request(0.200); // violation
        assert_eq!(m.slo_violations, 2);
        assert!(m.report_line().contains("slo_viol=2"));

        // without an SLO, nothing is ever counted
        let mut free = Metrics::new(StrategyKind::NetFuse, "bert", 4, 1);
        free.record_request(10.0);
        assert_eq!(free.slo_violations, 0);
    }

    #[test]
    fn report_line_includes_request_percentiles() {
        let mut m = Metrics::new(StrategyKind::NetFuse, "bert", 2, 1);
        for i in 1..=100 {
            m.record_request(i as f64 / 1000.0);
        }
        let line = m.report_line();
        assert!(line.contains("req p50="), "got: {line}");
        assert!(line.contains("p95="), "got: {line}");
    }

    #[test]
    fn throughput_excludes_preload_idle_time() {
        let mut m = Metrics::new(StrategyKind::NetFuse, "bert", 4, 1);
        assert_eq!(m.throughput(), 0.0, "no requests yet");

        // construction-to-first-request idle (fleet load, warm-up):
        // must NOT dilute the reported rate
        std::thread::sleep(std::time::Duration::from_millis(500));
        m.record_request(0.001); // clock starts here
        std::thread::sleep(std::time::Duration::from_millis(5));
        for _ in 0..9 {
            m.record_request(0.001);
        }
        let tp = m.throughput();
        // 10 requests over a ~5ms active span: the construction-stamped
        // clock this guards against reported at most 10 / 0.505s ≈ 20
        // rps here. The 30-rps bound only fails if the active span
        // stretches past ~330ms — a wide margin for a loaded 2-core CI
        // runner executing the suite in parallel.
        assert!(tp > 30.0, "throughput {tp} counts pre-traffic idle time");
    }

    #[test]
    fn throughput_spans_back_to_the_oldest_recorded_arrival() {
        // recording order is slot order, not arrival order: a fresh
        // request recorded before a long-queued one must not shrink
        // the span to the fresh request's arrival
        let mut m = Metrics::new(StrategyKind::NetFuse, "bert", 2, 1);
        m.record_request(0.001); // fresh arrival, recorded first
        m.record_request(0.250); // arrived 250ms ago, recorded second
        let tp = m.throughput();
        // the span covers the 250ms-old arrival: 2 requests / >=0.25s
        assert!(
            tp > 0.0 && tp <= 9.0,
            "throughput {tp} must span the oldest arrival (~8 rps)"
        );
    }

    #[test]
    fn single_request_throughput_is_one_over_latency() {
        // the clock anchors at the first request's ARRIVAL, so a
        // 1-request run reports ~1/latency, not a near-infinite rate
        let mut m = Metrics::new(StrategyKind::NetFuse, "bert", 1, 1);
        m.record_request(0.050);
        let tp = m.throughput();
        assert!(
            tp > 0.0 && tp <= 21.0,
            "single-request throughput {tp} should be ~1/latency (<= 20 rps)"
        );
    }
}
