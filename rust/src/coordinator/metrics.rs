//! Serving metrics: per-strategy latency/throughput collection and the
//! table-formatted reports the benches print.

use std::time::Instant;

use crate::util::stats::{fmt_secs, Latencies};

use super::strategy::StrategyKind;

/// Rolling metrics for one (strategy, configuration) run.
#[derive(Debug)]
pub struct Metrics {
    pub strategy: StrategyKind,
    pub model: String,
    pub m: usize,
    pub bs: usize,
    /// end-to-end request latency (arrival -> response)
    pub request_latency: Latencies,
    /// wall time per fleet round (the paper's "inference time")
    pub round_latency: Latencies,
    started: Instant,
    pub completed_requests: u64,
}

impl Metrics {
    pub fn new(strategy: StrategyKind, model: &str, m: usize, bs: usize) -> Metrics {
        Metrics {
            strategy,
            model: model.to_string(),
            m,
            bs,
            request_latency: Latencies::new(),
            round_latency: Latencies::new(),
            started: Instant::now(),
            completed_requests: 0,
        }
    }

    pub fn record_round(&mut self, seconds: f64) {
        self.round_latency.record(seconds);
    }

    pub fn record_request(&mut self, latency: f64) {
        self.request_latency.record(latency);
        self.completed_requests += 1;
    }

    /// Requests per second since construction.
    pub fn throughput(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64();
        if secs > 0.0 {
            self.completed_requests as f64 / secs
        } else {
            0.0
        }
    }

    pub fn report_line(&self) -> String {
        let r = &self.round_latency;
        format!(
            "{:<10} {:<8} m={:<3} bs={:<2} rounds={:<5} round: {:>10} ± {:>9} \
             p50={:>10} p99={:>10}",
            self.strategy.to_string(),
            self.model,
            self.m,
            self.bs,
            r.count(),
            fmt_secs(r.summary().mean()),
            fmt_secs(r.summary().std()),
            fmt_secs(r.p50()),
            fmt_secs(r.p99()),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut m = Metrics::new(StrategyKind::NetFuse, "bert", 4, 1);
        m.record_round(0.010);
        m.record_round(0.012);
        m.record_request(0.011);
        assert_eq!(m.round_latency.count(), 2);
        assert_eq!(m.completed_requests, 1);
        let line = m.report_line();
        assert!(line.contains("netfuse") && line.contains("bert"));
    }
}
