//! Serving metrics: per-strategy latency/throughput collection and the
//! table-formatted reports the benches print.
//!
//! Two recording surfaces share the arithmetic:
//!
//! - [`Metrics`] — the per-lane recorder `Server` owns, with the
//!   strategy/model labels the report tables print. Its fields stay
//!   public (tests, benches and examples read them directly).
//! - [`MetricsCore`] — the label-free accumulator a [`MetricsHub`]
//!   shards per dispatch thread. A lane whose `Metrics` has a sink
//!   attached ([`Metrics::attach_sink`]) mirrors every record into its
//!   thread's shard, so cross-lane aggregate metrics never take a
//!   shared lock on the dispatch path; readers merge the shards on
//!   demand ([`MetricsHub::read`]). Percentiles merge exactly — see
//!   `Latencies::merge_from`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::util::lock::LockRank;
use crate::util::shard::{ShardHandle, Shardable, Sharded};
use crate::util::stats::{fmt_secs, Latencies};

use super::strategy::StrategyKind;

/// Reconstruct a request's arrival from its end-to-end latency and
/// keep the EARLIEST arrival seen: recording order is slot order, not
/// arrival order, so a long-queued request may be recorded after a
/// fresh one in the same round — the throughput span must still start
/// at the oldest arrival.
fn fold_first_arrival(first: &mut Option<Instant>, latency: f64) {
    let now = Instant::now();
    let arrived = now.checked_sub(Duration::from_secs_f64(latency.max(0.0))).unwrap_or(now);
    *first = Some(match *first {
        Some(f) => f.min(arrived),
        None => arrived,
    });
}

/// Label-free serving counters: the shardable core of [`Metrics`].
/// One of these per dispatch thread (behind a [`MetricsHub`]) absorbs
/// the records of every lane that thread serves.
#[derive(Debug, Clone, Default)]
pub struct MetricsCore {
    pub request_latency: Latencies,
    pub round_latency: Latencies,
    pub completed_requests: u64,
    pub slo_violations: u64,
    first_arrival: Option<Instant>,
}

impl MetricsCore {
    pub fn record_round(&mut self, seconds: f64) {
        self.round_latency.record(seconds);
    }

    pub fn record_request(&mut self, latency: f64, slo: Option<f64>) {
        fold_first_arrival(&mut self.first_arrival, latency);
        self.request_latency.record(latency);
        self.completed_requests += 1;
        if let Some(slo) = slo {
            if latency > slo {
                self.slo_violations += 1;
            }
        }
    }

    /// Requests per second since the oldest recorded arrival (0.0
    /// until a measurable span exists) — same clock as
    /// [`Metrics::throughput`].
    pub fn throughput(&self) -> f64 {
        let Some(first) = self.first_arrival else {
            return 0.0;
        };
        let secs = first.elapsed().as_secs_f64();
        if secs > 0.0 {
            self.completed_requests as f64 / secs
        } else {
            0.0
        }
    }

    /// Observed round-time p99 in seconds, `None` until a round has
    /// been recorded (ADR-006: the aggregate gauge `ObsReport` quotes).
    /// Nearest-rank, so the merged hub value equals what one recorder
    /// over every shard's rounds would report.
    pub fn round_p99(&self) -> Option<f64> {
        (self.round_latency.count() > 0).then(|| self.round_latency.p99())
    }

    /// Aggregate one-line report (nearest-rank percentiles, exactly as
    /// a single recorder over all merged streams would print them).
    pub fn report_line(&self) -> String {
        let r = &self.round_latency;
        let q = &self.request_latency;
        format!(
            "aggregate rounds={:<5} round: {:>10} ± {:>9} p50={:>10} p99={:>10} \
             | req p50={:>10} p95={:>10} p99={:>10} completed={} slo_viol={}",
            r.count(),
            fmt_secs(r.summary().mean()),
            fmt_secs(r.summary().std()),
            fmt_secs(r.p50()),
            fmt_secs(r.p99()),
            fmt_secs(q.p50()),
            fmt_secs(q.p95()),
            fmt_secs(q.p99()),
            self.completed_requests,
            self.slo_violations,
        )
    }
}

impl Shardable for MetricsCore {
    // read by `ObsHub::report` while the hub's `metrics` registration
    // slot (ObsMeta) is held, so metrics shards rank above it (ADR-008)
    const RANK: LockRank = LockRank::MetricsShard;

    fn merge_from(&mut self, other: &Self) {
        self.request_latency.merge_from(&other.request_latency);
        self.round_latency.merge_from(&other.round_latency);
        self.completed_requests += other.completed_requests;
        self.slo_violations += other.slo_violations;
        self.first_arrival = match (self.first_arrival, other.first_arrival) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
    }
}

/// Per-thread sharded aggregate metrics for an N-thread dispatcher:
/// construct with the thread count, [`register`] one handle per
/// dispatch thread (`ParallelDispatcher::attach_metrics_hub` does this
/// per partition), and [`read`] the exact merged view at any time —
/// including while dispatch threads are still recording.
///
/// [`register`]: MetricsHub::register
/// [`read`]: MetricsHub::read
pub struct MetricsHub {
    shards: Arc<Sharded<MetricsCore>>,
}

impl MetricsHub {
    pub fn new(threads: usize) -> MetricsHub {
        MetricsHub { shards: Arc::new(Sharded::new(threads)) }
    }

    /// Claim the next shard (round-robin; wraps if over-registered).
    pub fn register(&self) -> ShardHandle<MetricsCore> {
        Sharded::register(&self.shards)
    }

    /// Merge every shard into one exact aggregate view.
    pub fn read(&self) -> MetricsCore {
        self.shards.read()
    }

    /// Merged round-time p99 in seconds (`None` before any round) —
    /// ADR-006 satellite: the one-number health gauge operators poll,
    /// exact across shards because nearest-rank depends only on the
    /// merged sample multiset.
    pub fn round_p99(&self) -> Option<f64> {
        self.read().round_p99()
    }

    pub fn shards(&self) -> usize {
        self.shards.shards()
    }

    pub fn report_line(&self) -> String {
        self.read().report_line()
    }
}

/// Rolling metrics for one (strategy, configuration) run.
#[derive(Debug)]
pub struct Metrics {
    pub strategy: StrategyKind,
    pub model: String,
    pub m: usize,
    pub bs: usize,
    /// end-to-end request latency (arrival -> response)
    pub request_latency: Latencies,
    /// wall time per fleet round (the paper's "inference time")
    pub round_latency: Latencies,
    /// throughput clock: the EARLIEST recorded request arrival (each
    /// completion instant minus its latency), not construction time —
    /// `Metrics::new` runs at fleet load, and counting load/idle time
    /// understated steady-state requests/sec. Anchoring at arrival
    /// (rather than first completion) keeps request service and queue
    /// time in the denominator, so a 1-request run reports 1/latency
    /// instead of a near-infinite rate.
    first_arrival: Option<Instant>,
    pub completed_requests: u64,
    /// end-to-end latency target (seconds) this lane was registered
    /// with (`MultiServer::add_lane_qos`); `None` = no SLO accounting
    pub slo: Option<f64>,
    /// completed requests whose end-to-end latency exceeded `slo`
    pub slo_violations: u64,
    /// optional per-thread aggregate shard every record is mirrored
    /// into (see [`MetricsHub`]); `None` = lane-local recording only
    sink: Option<ShardHandle<MetricsCore>>,
}

impl Metrics {
    pub fn new(strategy: StrategyKind, model: &str, m: usize, bs: usize) -> Metrics {
        Metrics {
            strategy,
            model: model.to_string(),
            m,
            bs,
            request_latency: Latencies::new(),
            round_latency: Latencies::new(),
            first_arrival: None,
            completed_requests: 0,
            slo: None,
            slo_violations: 0,
            sink: None,
        }
    }

    /// Mirror every subsequent record into the given aggregate shard.
    /// The shard is this dispatch thread's own (uncontended), so the
    /// mirror adds no cross-thread traffic to the recording path.
    pub fn attach_sink(&mut self, sink: ShardHandle<MetricsCore>) {
        self.sink = Some(sink);
    }

    pub fn record_round(&mut self, seconds: f64) {
        self.round_latency.record(seconds);
        if let Some(s) = &self.sink {
            s.lock().record_round(seconds);
        }
    }

    pub fn record_request(&mut self, latency: f64) {
        fold_first_arrival(&mut self.first_arrival, latency);
        self.request_latency.record(latency);
        self.completed_requests += 1;
        if let Some(slo) = self.slo {
            if latency > slo {
                self.slo_violations += 1;
            }
        }
        if let Some(s) = &self.sink {
            s.lock().record_request(latency, self.slo);
        }
    }

    /// Requests per second since the first recorded request ARRIVED
    /// (0.0 until a measurable span exists). Fleet-load and pre-traffic
    /// idle time are excluded so the number reflects steady-state
    /// serving rate.
    pub fn throughput(&self) -> f64 {
        let Some(first) = self.first_arrival else {
            return 0.0;
        };
        let secs = first.elapsed().as_secs_f64();
        if secs > 0.0 {
            self.completed_requests as f64 / secs
        } else {
            0.0
        }
    }

    /// This lane's observed round-time p99 in seconds, `None` until a
    /// round has been recorded — the per-lane gauge the dispatch loop
    /// publishes to the observability hub (ADR-006).
    pub fn round_p99(&self) -> Option<f64> {
        (self.round_latency.count() > 0).then(|| self.round_latency.p99())
    }

    /// One-line report. The p50/p95/p99 columns are **nearest-rank**
    /// percentiles (`Latencies::percentile`: 1-indexed `ceil(q * n)`
    /// over the sorted raw samples, no interpolation) — pinned here
    /// because sharded aggregation relies on it: nearest-rank depends
    /// only on the sample multiset, so a merged-on-read report is
    /// bit-identical to a single-recorder one.
    pub fn report_line(&self) -> String {
        let r = &self.round_latency;
        let q = &self.request_latency;
        format!(
            "{:<10} {:<8} m={:<3} bs={:<2} rounds={:<5} round: {:>10} ± {:>9} \
             p50={:>10} p99={:>10} | req p50={:>10} p95={:>10} p99={:>10} slo_viol={}",
            self.strategy.to_string(),
            self.model,
            self.m,
            self.bs,
            r.count(),
            fmt_secs(r.summary().mean()),
            fmt_secs(r.summary().std()),
            fmt_secs(r.p50()),
            fmt_secs(r.p99()),
            fmt_secs(q.p50()),
            fmt_secs(q.p95()),
            fmt_secs(q.p99()),
            self.slo_violations,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let mut m = Metrics::new(StrategyKind::NetFuse, "bert", 4, 1);
        m.record_round(0.010);
        m.record_round(0.012);
        m.record_request(0.011);
        assert_eq!(m.round_latency.count(), 2);
        assert_eq!(m.completed_requests, 1);
        let line = m.report_line();
        assert!(line.contains("netfuse") && line.contains("bert"));
    }

    #[test]
    fn slo_violations_counted_and_reported() {
        let mut m = Metrics::new(StrategyKind::NetFuse, "bert", 4, 1);
        m.slo = Some(0.010);
        m.record_request(0.005);
        m.record_request(0.011); // violation
        m.record_request(0.200); // violation
        assert_eq!(m.slo_violations, 2);
        assert!(m.report_line().contains("slo_viol=2"));

        // without an SLO, nothing is ever counted
        let mut free = Metrics::new(StrategyKind::NetFuse, "bert", 4, 1);
        free.record_request(10.0);
        assert_eq!(free.slo_violations, 0);
    }

    #[test]
    fn report_line_includes_request_percentiles() {
        let mut m = Metrics::new(StrategyKind::NetFuse, "bert", 2, 1);
        for i in 1..=100 {
            m.record_request(i as f64 / 1000.0);
        }
        let line = m.report_line();
        assert!(line.contains("req p50="), "got: {line}");
        assert!(line.contains("p95="), "got: {line}");
    }

    #[test]
    fn throughput_excludes_preload_idle_time() {
        let mut m = Metrics::new(StrategyKind::NetFuse, "bert", 4, 1);
        assert_eq!(m.throughput(), 0.0, "no requests yet");

        // construction-to-first-request idle (fleet load, warm-up):
        // must NOT dilute the reported rate
        std::thread::sleep(std::time::Duration::from_millis(500));
        m.record_request(0.001); // clock starts here
        std::thread::sleep(std::time::Duration::from_millis(5));
        for _ in 0..9 {
            m.record_request(0.001);
        }
        let tp = m.throughput();
        // 10 requests over a ~5ms active span: the construction-stamped
        // clock this guards against reported at most 10 / 0.505s ≈ 20
        // rps here. The 30-rps bound only fails if the active span
        // stretches past ~330ms — a wide margin for a loaded 2-core CI
        // runner executing the suite in parallel.
        assert!(tp > 30.0, "throughput {tp} counts pre-traffic idle time");
    }

    #[test]
    fn throughput_spans_back_to_the_oldest_recorded_arrival() {
        // recording order is slot order, not arrival order: a fresh
        // request recorded before a long-queued one must not shrink
        // the span to the fresh request's arrival
        let mut m = Metrics::new(StrategyKind::NetFuse, "bert", 2, 1);
        m.record_request(0.001); // fresh arrival, recorded first
        m.record_request(0.250); // arrived 250ms ago, recorded second
        let tp = m.throughput();
        // the span covers the 250ms-old arrival: 2 requests / >=0.25s
        assert!(
            tp > 0.0 && tp <= 9.0,
            "throughput {tp} must span the oldest arrival (~8 rps)"
        );
    }

    #[test]
    fn single_request_throughput_is_one_over_latency() {
        // the clock anchors at the first request's ARRIVAL, so a
        // 1-request run reports ~1/latency, not a near-infinite rate
        let mut m = Metrics::new(StrategyKind::NetFuse, "bert", 1, 1);
        m.record_request(0.050);
        let tp = m.throughput();
        assert!(
            tp > 0.0 && tp <= 21.0,
            "single-request throughput {tp} should be ~1/latency (<= 20 rps)"
        );
    }

    /// A fixed sample set recorded through 3 shards must report the
    /// exact same nearest-rank percentiles (and counters) as one
    /// recorder that saw every sample — the satellite regression for
    /// sharded merge-on-read.
    #[test]
    fn sharded_merge_matches_single_shard_percentiles() {
        let slo = Some(0.080);
        // fixed, deliberately unsorted sample set with duplicates
        let samples: Vec<f64> =
            (0..100).map(|i| ((i * 37 + 11) % 100) as f64 / 1000.0 + 0.001).collect();

        let mut single = MetricsCore::default();
        for &s in &samples {
            single.record_request(s, slo);
            single.record_round(s * 2.0);
        }

        let hub = MetricsHub::new(3);
        let handles: Vec<_> = (0..3).map(|_| hub.register()).collect();
        for (i, &s) in samples.iter().enumerate() {
            let mut shard = handles[i % 3].lock();
            shard.record_request(s, slo);
            shard.record_round(s * 2.0);
        }

        let merged = hub.read();
        assert_eq!(merged.completed_requests, single.completed_requests);
        assert_eq!(merged.slo_violations, single.slo_violations);
        // exact f64 equality: nearest-rank selects an observed sample,
        // so merged and single-shard must agree to the bit
        assert_eq!(merged.request_latency.p50(), single.request_latency.p50());
        assert_eq!(merged.request_latency.p95(), single.request_latency.p95());
        assert_eq!(merged.request_latency.p99(), single.request_latency.p99());
        assert_eq!(merged.round_latency.p50(), single.round_latency.p50());
        assert_eq!(merged.round_latency.p99(), single.round_latency.p99());
        assert_eq!(merged.report_line(), single.report_line());
    }

    /// ADR-006 satellite: `round_p99` across a 2-shard hub is pinned to
    /// the exact nearest-rank value — rank `ceil(0.99 * 100)` = sample
    /// #100 of the merged multiset 0.001..=0.100.
    #[test]
    fn hub_round_p99_is_exact_across_shards() {
        let hub = MetricsHub::new(2);
        assert_eq!(hub.round_p99(), None, "no rounds yet");
        let handles: Vec<_> = (0..2).map(|_| hub.register()).collect();
        // 100 known round times, split alternately across the shards
        for i in 1..=100u32 {
            handles[(i % 2) as usize].lock().record_round(i as f64 / 1000.0);
        }
        // nearest-rank p99 of 100 samples is rank 99 -> 0.099
        assert_eq!(hub.round_p99(), Some(0.099));
        // and the per-lane accessor agrees with its own samples
        let mut m = Metrics::new(StrategyKind::NetFuse, "bert", 2, 1);
        assert_eq!(m.round_p99(), None);
        for i in 1..=100u32 {
            m.record_round(i as f64 / 1000.0);
        }
        assert_eq!(m.round_p99(), Some(0.099));
        assert_eq!(MetricsCore::default().round_p99(), None);
    }

    /// ADR-006 satellite: merged throughput must span back to the
    /// OLDEST arrival across shards — the cross-shard analogue of
    /// `throughput_spans_back_to_the_oldest_recorded_arrival`. Shard 0
    /// records a fresh arrival FIRST; shard 1 then records a request
    /// that arrived 250ms ago. A first-wins (or last-wins) merge of
    /// `first_arrival` would anchor the span at the fresh arrival and
    /// report ~2000 rps; the min-merge reports ~8.
    #[test]
    fn merged_throughput_spans_the_oldest_arrival_across_staggered_shards() {
        let hub = MetricsHub::new(2);
        let h0 = hub.register();
        let h1 = hub.register();
        h0.lock().record_request(0.001, None); // fresh, recorded first
        h1.lock().record_request(0.250, None); // arrived 250ms ago
        let tp = hub.read().throughput();
        assert!(
            tp > 0.0 && tp <= 9.0,
            "merged throughput {tp} must anchor at the oldest shard arrival (~8 rps)"
        );
        // merge the other direction too (fold order must not matter)
        let mut rev = MetricsCore::default();
        rev.merge_from(&h1.lock());
        rev.merge_from(&h0.lock());
        let tp = rev.throughput();
        assert!(tp > 0.0 && tp <= 9.0, "reverse-order merge reports {tp}");
    }

    #[test]
    fn attached_sink_mirrors_lane_records() {
        let hub = MetricsHub::new(2);
        let mut a = Metrics::new(StrategyKind::NetFuse, "bert", 2, 1);
        let mut b = Metrics::new(StrategyKind::NetFuse, "gpt", 2, 1);
        a.slo = Some(0.010);
        a.attach_sink(hub.register());
        b.attach_sink(hub.register());

        a.record_round(0.004);
        a.record_request(0.003);
        a.record_request(0.020); // violation on lane a
        b.record_round(0.006);
        b.record_request(0.005);

        let agg = hub.read();
        assert_eq!(agg.completed_requests, 3);
        assert_eq!(agg.slo_violations, 1);
        assert_eq!(agg.round_latency.count(), 2);
        // lane-local views are untouched by the mirror
        assert_eq!(a.completed_requests, 2);
        assert_eq!(b.completed_requests, 1);
        assert_eq!(b.slo_violations, 0);
    }
}
