//! The elastic-topology control plane (ADR-005): add, remove, and
//! hot-swap lanes on a LIVE [`ParallelDispatcher`] under open-loop
//! traffic.
//!
//! Ownership is the whole design. Each partition's lanes — queues, QoS
//! deficits, coalesce-group `SlotMap` — are owned by exactly one
//! dispatch thread and are mutated lock-free. The control plane never
//! touches them directly: a [`TopologyController`] (any thread) enqueues
//! a [`LaneCmd`] on the owning partition's [`PartControl`] queue, and
//! the partition's dispatch loop applies it **strictly between rounds**
//! (the loop polls its queue once per iteration, and one iteration
//! dispatches at most one round). That gives every mutation the same
//! safety argument the data plane already has: no round is in flight on
//! the structures being changed, and sibling partitions — whose rounds
//! may be mid-execution on their own `ArenaRing` slots — are never
//! touched at all (ring slots are independently reserved; see ADR-003).
//! Command latency is bounded by one round plus the loop's idle poll.
//!
//! The only shared-mutable state is the [`Topology`] routing table,
//! which ADR-005 moved behind a lock with an epoch stamp. Ordering
//! makes the quiesce race-free:
//!
//! - **add**: reserve a fresh global id (router answers `NoLane` — the
//!   id exists but is unmapped) → the owning thread installs the lane
//!   (reusing a retired slot when one exists) → `map_lane` publishes
//!   it. A client racing the install sees a clean typed reject, never a
//!   misroute.
//! - **remove**: `unmap_lane` FIRST — from that instant the router
//!   rejects new arrivals with `NoLane` — then the owning thread marks
//!   the lane `Draining` and its already-admitted requests flow out
//!   through normal dispatch (merged rounds included). Once empty, the
//!   thread excises it from the group `SlotMap` and the QoS table and
//!   acks with the lane's carried WDRR deficit.
//! - **swap**: applied between rounds by the owning thread on both the
//!   lane executor and the lane's group-megabatch window; the ack
//!   carries the measured pause (FusedInf's bounded-pause contract).
//!
//! Every command is acknowledged exactly once through a
//! [`Ticket`]/[`Ack`] pair — including on dispatch-loop shutdown or
//! failure, where outstanding commands fail with an error instead of
//! hanging their waiters. Results cross threads as `Result<T, String>`
//! because `anyhow::Error` is not `Clone` and the dispatch thread must
//! not die with a controller's error.
//!
//! [`Topology`]: super::multi::Topology

use std::collections::VecDeque;
use std::sync::{Arc, Condvar};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::ingress::qos::LaneQos;
use crate::util::lock::{LockRank, OrderedMutex};

use super::multi::{LaneSpec, ParallelDispatcher, Topology, TopologySnapshot};
use super::server::ServerConfig;
use super::service::{Fleet, RoundExecutor};

// ---------------------------------------------------------------------------
// one-shot completion: Ticket (waiter) / Ack (resolver)
// ---------------------------------------------------------------------------

struct Cell<T> {
    slot: OrderedMutex<Option<std::result::Result<T, String>>>,
    done: Condvar,
}

/// The waiting half of a one-shot completion: blocks until the paired
/// [`Ack`] resolves, or the timeout expires.
pub struct Ticket<T>(Arc<Cell<T>>);

/// The resolving half: the dispatch thread completes it exactly once.
/// Dropping an `Ack` unresolved fails the ticket (a "command dropped"
/// error) rather than hanging the waiter forever.
pub struct Ack<T>(Option<Arc<Cell<T>>>);

/// A fresh, unresolved completion pair.
pub fn ticket<T>() -> (Ticket<T>, Ack<T>) {
    let cell = Arc::new(Cell {
        slot: OrderedMutex::new(LockRank::Ticket, None),
        done: Condvar::new(),
    });
    (Ticket(Arc::clone(&cell)), Ack(Some(cell)))
}

impl<T> Ticket<T> {
    /// Block until the command is acknowledged. Times out with an error
    /// after `timeout` (the command may still complete later; its
    /// result is then discarded).
    pub fn wait(self, timeout: Duration) -> Result<T> {
        let deadline = Instant::now() + timeout;
        let mut slot = self.0.slot.lock();
        loop {
            if let Some(res) = slot.take() {
                return res.map_err(|e| anyhow!(e)).context("control command failed");
            }
            let now = Instant::now();
            if now >= deadline {
                bail!("control command not acknowledged within {timeout:?}");
            }
            let (next, _) = slot.wait_timeout(&self.0.done, deadline - now);
            slot = next;
        }
    }

    /// Non-blocking probe: the result if the command has completed.
    pub fn try_take(&self) -> Option<Result<T>> {
        self.0
            .slot
            .lock()
            .take()
            .map(|res| res.map_err(|e| anyhow!(e).context("control command failed")))
    }
}

impl<T> Ack<T> {
    /// Resolve the paired ticket (exactly once; later calls are no-ops
    /// because `complete` consumes the ack).
    pub fn complete(mut self, res: std::result::Result<T, String>) {
        if let Some(cell) = self.0.take() {
            *cell.slot.lock() = Some(res);
            cell.done.notify_all();
        }
    }
}

impl<T> Drop for Ack<T> {
    fn drop(&mut self) {
        if let Some(cell) = self.0.take() {
            *cell.slot.lock() =
                Some(Err("control command dropped without acknowledgement".to_string()));
            cell.done.notify_all();
        }
    }
}

// ---------------------------------------------------------------------------
// commands and their outcomes
// ---------------------------------------------------------------------------

/// What a completed add reports back.
#[derive(Debug, Clone, Copy)]
pub struct AddOutcome {
    /// the global lane id clients address (reserved before install)
    pub global: usize,
    /// the partition-local lane slot (possibly a reused retired slot)
    pub local: usize,
    /// the coalesce group the lane auto-attached to, if any
    pub group: Option<usize>,
    /// topology epoch after the lane was published
    pub epoch: u64,
}

/// What a completed remove reports back.
#[derive(Debug, Clone, Copy)]
pub struct RemoveOutcome {
    /// the lane's carried WDRR deficit at excision — feed it to the
    /// add side of a migration so weighted shares hold across the move
    pub deficit: i64,
    /// topology epoch after the lane was excised
    pub epoch: u64,
}

/// One mutation for a partition's dispatch thread to apply between
/// rounds.
pub enum LaneCmd<'f, E: RoundExecutor = Fleet> {
    /// Install a lane and publish `global -> (part, local)`.
    Add {
        global: usize,
        spec: LaneSpec<'f, E>,
        /// carried WDRR deficit (0 for a fresh tenant)
        deficit: i64,
        ack: Ack<AddOutcome>,
    },
    /// Quiesce local lane `local` (already unmapped by the controller):
    /// drain through normal dispatch, then excise. Acked when excised.
    Remove {
        local: usize,
        /// the unmapped global id (for diagnostics/logging only — the
        /// routing table no longer knows it)
        global: usize,
        ack: Ack<RemoveOutcome>,
    },
    /// Hot-swap local lane `local`'s weights to version `tag` between
    /// rounds; acked with the measured pause.
    Swap { local: usize, tag: u64, ack: Ack<Duration> },
}

impl<'f, E: RoundExecutor> LaneCmd<'f, E> {
    /// Fail this command's waiter with `reason` (shutdown/error paths).
    pub fn fail(self, reason: &str) {
        match self {
            LaneCmd::Add { ack, .. } => ack.complete(Err(reason.to_string())),
            LaneCmd::Remove { ack, .. } => ack.complete(Err(reason.to_string())),
            LaneCmd::Swap { ack, .. } => ack.complete(Err(reason.to_string())),
        }
    }
}

/// One partition's command queue: controller threads push, the
/// partition's dispatch thread pops between rounds.
pub struct PartControl<'f, E: RoundExecutor = Fleet> {
    q: OrderedMutex<VecDeque<LaneCmd<'f, E>>>,
}

impl<'f, E: RoundExecutor> Default for PartControl<'f, E> {
    fn default() -> Self {
        PartControl { q: OrderedMutex::new(LockRank::ControlQueue, VecDeque::new()) }
    }
}

impl<'f, E: RoundExecutor> PartControl<'f, E> {
    pub(crate) fn push(&self, cmd: LaneCmd<'f, E>) {
        self.q.lock().push_back(cmd);
    }

    /// Pop the next pending command (dispatch-thread side).
    pub fn pop(&self) -> Option<LaneCmd<'f, E>> {
        self.q.lock().pop_front()
    }

    /// Commands waiting to be applied.
    pub fn len(&self) -> usize {
        self.q.lock().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Command queues for every partition of one dispatcher. Created once,
/// shared (`Arc`) between the controller and the dispatch run.
pub struct ControlPlane<'f, E: RoundExecutor = Fleet> {
    parts: Vec<PartControl<'f, E>>,
}

impl<'f, E: RoundExecutor> ControlPlane<'f, E> {
    /// One queue per partition — size with
    /// [`ParallelDispatcher::parts`] AFTER pre-provisioning spares
    /// ([`ParallelDispatcher::add_spare_part`]): partitions cannot be
    /// added once the run starts.
    pub fn new(parts: usize) -> ControlPlane<'f, E> {
        ControlPlane { parts: (0..parts).map(|_| PartControl::default()).collect() }
    }

    /// For a dispatcher, sized to its current partitions.
    pub fn for_dispatcher(d: &ParallelDispatcher<'f, E>) -> ControlPlane<'f, E> {
        Self::new(d.parts())
    }

    pub fn parts(&self) -> usize {
        self.parts.len()
    }

    /// Partition `p`'s command queue.
    pub fn part(&self, p: usize) -> &PartControl<'f, E> {
        &self.parts[p]
    }
}

// ---------------------------------------------------------------------------
// the controller
// ---------------------------------------------------------------------------

/// The operator's handle on a live dispatcher: issues add / remove /
/// swap / migrate against the shared [`Topology`] and the per-partition
/// command queues, from ANY thread, while the dispatch threads own the
/// data plane. Every method returns a [`Ticket`] (or acts through one)
/// so callers choose between fire-and-forget and bounded waits.
pub struct TopologyController<'f, E: RoundExecutor = Fleet> {
    topo: Arc<Topology>,
    plane: Arc<ControlPlane<'f, E>>,
}

impl<'f, E: RoundExecutor> TopologyController<'f, E> {
    /// `topo` from [`ParallelDispatcher::topology_handle`], `plane`
    /// shared with the `run_dispatch_elastic` call driving the same
    /// dispatcher. The plane must have one queue per partition.
    pub fn new(topo: Arc<Topology>, plane: Arc<ControlPlane<'f, E>>) -> TopologyController<'f, E> {
        TopologyController { topo, plane }
    }

    /// Current topology epoch (bumped by every mutation).
    pub fn epoch(&self) -> u64 {
        self.topo.epoch()
    }

    /// One coherent copy of the routing table with its epoch.
    pub fn snapshot(&self) -> TopologySnapshot {
        self.topo.snapshot()
    }

    /// Add a lane to the partition currently mapping the fewest lanes
    /// (the simple balance heuristic; use
    /// [`TopologyController::add_lane_to`] to choose explicitly).
    /// Returns the reserved global id — valid for addressing the lane
    /// as soon as the ticket resolves — and the install ticket.
    pub fn add_lane(&self, spec: LaneSpec<'f, E>) -> Result<(usize, Ticket<AddOutcome>)> {
        let snap = self.topo.snapshot();
        let parts = snap.parts.min(self.plane.parts());
        if parts == 0 {
            bail!("no partitions to add a lane to");
        }
        let mut load = vec![0usize; parts];
        for slot in snap.lanes.iter().flatten() {
            if slot.0 < parts {
                load[slot.0] += 1;
            }
        }
        let part = (0..parts).min_by_key(|&p| load[p]).expect("parts > 0");
        self.add_lane_to(spec, part, 0)
    }

    /// Add a lane to partition `part`, carrying `deficit` WDRR credit
    /// (0 for a fresh tenant; a migration passes the removed lane's
    /// carried deficit). The global id is reserved — and permanently
    /// owned by this tenant — before the command is queued, so a racing
    /// client sees `NoLane`, never another tenant's lane.
    pub fn add_lane_to(
        &self,
        spec: LaneSpec<'f, E>,
        part: usize,
        deficit: i64,
    ) -> Result<(usize, Ticket<AddOutcome>)> {
        if part >= self.plane.parts() {
            bail!("no partition {part} (have {})", self.plane.parts());
        }
        let global = self.topo.reserve_lane();
        let (t, ack) = ticket();
        self.plane.part(part).push(LaneCmd::Add { global, spec, deficit, ack });
        Ok((global, t))
    }

    /// Remove global lane `global`: unmap it NOW (the router starts
    /// answering `NoLane` before this returns) and queue the quiesce on
    /// the owning partition. The ticket resolves once the lane has
    /// drained through normal dispatch and been excised, carrying its
    /// WDRR deficit.
    pub fn remove_lane(&self, global: usize) -> Result<Ticket<RemoveOutcome>> {
        let Some((part, local)) = self.topo.unmap_lane(global) else {
            bail!("no such lane {global} (not mapped)");
        };
        let (t, ack) = ticket();
        self.plane.part(part).push(LaneCmd::Remove { local, global, ack });
        Ok(t)
    }

    /// Hot-swap global lane `global`'s weights to version `tag`. The
    /// owning dispatch thread applies it between rounds; the ticket
    /// resolves with the measured pause. The epoch bumps on completion
    /// so watchers observe the change.
    pub fn swap_model(&self, global: usize, tag: u64) -> Result<Ticket<Duration>> {
        let Some((part, local)) = self.topo.locate(global) else {
            bail!("no such lane {global} (not mapped)");
        };
        let (t, ack) = ticket();
        self.plane.part(part).push(LaneCmd::Swap { local, tag, ack });
        Ok(t)
    }

    /// Migrate a lane to `to_part`: remove it (quiesce + excise), then
    /// re-add it on the target partition **carrying its WDRR deficit**,
    /// so its earned weighted share survives the rebalance (the ADR-003
    /// "weights meter within a partition only" caveat would otherwise
    /// let a migration reset a lane's credit). Blocks up to `timeout`
    /// for EACH phase. The lane gets a fresh global id (ids are
    /// monotone; the old id answers `NoLane` forever) — returned in the
    /// outcome.
    pub fn migrate_lane(
        &self,
        global: usize,
        to_part: usize,
        spec: LaneSpec<'f, E>,
        timeout: Duration,
    ) -> Result<AddOutcome> {
        let removed = self
            .remove_lane(global)?
            .wait(timeout)
            .with_context(|| format!("migrating lane {global}: remove phase"))?;
        let (_, t) = self.add_lane_to(spec, to_part, removed.deficit)?;
        t.wait(timeout)
            .with_context(|| format!("migrating lane {global}: add phase"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ticket_resolves_and_times_out() {
        let (t, ack) = ticket::<u32>();
        ack.complete(Ok(7));
        assert_eq!(t.wait(Duration::from_millis(1)).unwrap(), 7);

        let (t, _ack) = {
            let (t, ack) = ticket::<u32>();
            (t, Box::new(ack)) // keep the ack alive past the wait
        };
        let err = t.wait(Duration::from_millis(5)).unwrap_err();
        assert!(err.to_string().contains("not acknowledged"), "got: {err}");
    }

    #[test]
    fn dropped_ack_fails_the_ticket_instead_of_hanging() {
        let (t, ack) = ticket::<u32>();
        drop(ack);
        let err = t.wait(Duration::from_secs(1)).unwrap_err();
        assert!(err.to_string().contains("dropped"), "got: {err}");
    }

    #[test]
    fn error_results_cross_as_context() {
        let (t, ack) = ticket::<u32>();
        ack.complete(Err("lane 3 is not live".to_string()));
        let err = t.wait(Duration::from_millis(1)).unwrap_err();
        let chain = format!("{err:#}");
        assert!(chain.contains("lane 3 is not live"), "got: {chain}");
    }
}
