//! `Fleet`: M fine-tuned instances of one model family, runnable under
//! any of the four strategies. This is the heart of the reproduction —
//! the same weight banks flow through the single-model executables
//! (baselines) and through the merged executable (NETFUSE), and a round
//! of M requests produces identical outputs either way.
//!
//! Round data plane (zero-copy pipeline):
//! - an [`ArenaRing`] (multi-buffered [`RoundArena`], default depth 2)
//!   allocated once at [`Fleet::load`] holds the merged megabatches and
//!   pad blocks; [`Fleet::pack_into`] writes request payloads straight
//!   into their windows (no concat/stack allocation). A NETFUSE round
//!   reserves one ring slot for pack + stage + execute, so up to
//!   `depth` threads pack later rounds into the other slots while round
//!   N is still in flight. [`Fleet::with_arena_depth`] widens the ring
//!   for N-thread dispatch ([`ParallelDispatcher`]), and
//!   [`Fleet::set_arena_ring`] lets identically shaped fleets share one
//!   ring (one staging footprint for a whole coalesce family);
//! - the megabatch is handed to PJRT via `Bound::stage`/`run_staged`
//!   without an intermediate `Tensor`;
//! - [`Fleet::unpack`] returns borrowed [`TensorView`]s into the merged
//!   output; only occupied slots are promoted to owned tensors;
//! - `Concurrent`/`Hybrid` rounds run on a persistent [`WorkerPool`].
//!   The pool is a shared `Arc` handle: by default it is spawned lazily
//!   per fleet on the first round that needs it, but
//!   [`Fleet::load_with_pool`] accepts one machine-sized pool that any
//!   number of fleets (a `MultiServer` tenancy) dispatch onto.
//!
//! [`RoundExecutor`] abstracts the slot-level round contract the
//! serving loop needs, so `Server`/`MultiServer` batching logic is
//! testable without AOT artifacts or a PJRT backend.
//!
//! [`ParallelDispatcher`]: super::multi::ParallelDispatcher

use std::collections::BTreeMap;
use std::sync::{Arc, OnceLock};

use anyhow::{bail, Context, Result};

use crate::fuse::{self, weights::Bank};
use crate::graph::Graph;
use crate::runtime::{Bound, Manifest, Runtime};
use crate::tensor::{io::read_nft, Tensor, TensorView};

use super::arena::{ArenaRing, Layout, RoundArena};
use super::pool::WorkerPool;
use super::strategy::StrategyKind;

/// The slot-level round contract the serving loop dispatches against:
/// everything `Server`/`MultiServer` need from a fleet. `Fleet` is the
/// production implementation; tests substitute artifact-free mocks so
/// the router/batcher logic runs everywhere (including offline CI).
pub trait RoundExecutor: Sync {
    /// Display name (metrics/reporting).
    fn name(&self) -> &str;
    /// Number of model instances (one queue slot each per round).
    fn m(&self) -> usize;
    /// Per-request batch size (leading payload dimension).
    fn bs(&self) -> usize;
    /// Per-request input shape EXCLUDING the leading batch dimension.
    fn input_shape(&self) -> &[usize];
    /// Execute one (possibly padded) round; the contract of
    /// [`Fleet::run_round_slots`].
    fn run_round_slots<'a>(
        &self,
        strategy: StrategyKind,
        get: &(dyn Fn(usize) -> Option<&'a Tensor> + Sync),
        outs: &mut Vec<Option<Tensor>>,
    ) -> Result<()>;

    /// Hot-swap the model weights backing instance `slots` to version
    /// `tag`, **between rounds** — the FusedInf-style on-demand swap
    /// (PAPERS.md, arxiv 2410.21120). For a standalone executor `slots`
    /// is the full `0..m()`; for a coalesce-group executor it is one
    /// member lane's megabatch window, so one tenant's weights swap
    /// without touching its siblings' windows. Returns the pause the
    /// swap cost (the bounded hot-swap pause ADR-005 budgets).
    ///
    /// The control plane calls this only from the thread that dispatches
    /// this executor, strictly between its rounds, so implementations
    /// may re-stage weight banks without guarding against an in-flight
    /// round of their own; rounds of OTHER executors (other `ArenaRing`
    /// slots, other partitions) stay untouched by construction.
    ///
    /// Default: unsupported — executors that cannot swap (today:
    /// [`Fleet`], whose merged-bank re-stage needs the real PJRT
    /// backend; see ROADMAP open item 1) refuse with a typed error the
    /// controller surfaces, rather than silently serving stale weights.
    fn swap_model(&self, slots: std::ops::Range<usize>, tag: u64) -> Result<std::time::Duration> {
        bail!(
            "{}: model hot-swap unsupported (slots {slots:?}, tag {tag})",
            self.name()
        )
    }
}

/// A fleet of M instances of one model family at a fixed batch size.
pub struct Fleet {
    pub model: String,
    pub m: usize,
    pub bs: usize,
    /// merged-input packing: "channel" (CNN) | "batch" (sequence)
    pub layout: String,
    /// parsed form of `layout` (validated once at load)
    packing: Layout,
    /// single-model graph (planning/memory estimation)
    pub graph: Graph,
    /// M bindings of the single-model module (one per weight bank)
    singles: Vec<Bound>,
    /// the NETFUSE executable with Rust-stacked merged weights
    fused: Bound,
    /// ring of round-lifetime staging buffers, reused every round;
    /// `depth` slots so rounds from different threads overlap. Behind
    /// an `Arc` so identically shaped fleets can share one ring.
    arenas: Arc<ArenaRing>,
    /// persistent strategy workers. Either a machine-wide pool shared
    /// across fleets (installed by [`Fleet::load_with_pool`]) or a
    /// fleet-private one spawned lazily on the first Concurrent/Hybrid
    /// round (Sequential/NetFuse fleets never pay the M thread spawns).
    pool: OnceLock<Arc<WorkerPool>>,
    /// manifest memory numbers for the memory model
    pub single_weights_bytes: u64,
    pub single_act_bytes: u64,
    pub fused_weights_bytes: u64,
    pub fused_act_bytes: u64,
}

impl Fleet {
    /// Load a fleet from artifacts: compile the single + merged modules,
    /// read the per-instance banks, stack the merged weights (Rust-side
    /// Algorithm 1 + weight merge).
    pub fn load(rt: &Runtime, model: &str, m: usize, bs: usize) -> Result<Fleet> {
        Self::load_with(rt, model, m, bs, "")
    }

    /// Like [`Fleet::load_with`], but dispatches Concurrent/Hybrid
    /// rounds onto `pool` instead of spawning a fleet-private one —
    /// the multi-tenant form: every fleet a [`MultiServer`] serves
    /// shares ONE machine-sized [`WorkerPool`]
    /// ([`WorkerPool::machine_sized`]).
    ///
    /// [`MultiServer`]: super::multi::MultiServer
    pub fn load_with_pool(
        rt: &Runtime,
        model: &str,
        m: usize,
        bs: usize,
        suffix: &str,
        pool: Arc<WorkerPool>,
    ) -> Result<Fleet> {
        let fleet = Self::load_with(rt, model, m, bs, suffix)?;
        fleet
            .pool
            .set(pool)
            .map_err(|_| anyhow::anyhow!("fleet pool already initialized"))?;
        Ok(fleet)
    }

    /// `suffix` selects artifact variants (e.g. "_pallas" for the
    /// Pallas-kernel lowering the quickstart exercises).
    pub fn load_with(
        rt: &Runtime,
        model: &str,
        m: usize,
        bs: usize,
        suffix: &str,
    ) -> Result<Fleet> {
        let entry = rt.manifest.model(model)?.clone();
        if m > entry.instances {
            bail!(
                "{model}: fleet wants {m} instances, bank has {}",
                entry.instances
            );
        }
        let banks = load_banks(rt, model, m)?;

        // single-model executables: ONE compile, M weight bindings
        let single_name = format!("{}{}", Manifest::single_name(model, bs), suffix);
        let single_mod = rt.compile(&single_name)?;
        let mut singles = Vec::with_capacity(m);
        for bank in &banks {
            let params = fuse::weights::params_in_order(&entry.graph, bank)?;
            singles.push(single_mod.bind(&params)?);
        }

        // merged executable: Rust-side merge plan + stacked weights
        let merged_graph = fuse::merge(&entry.graph, m)?;
        let merged_bank = fuse::weights::merge_weights(&merged_graph, &banks)?;
        let fused_name = format!("{}{}", Manifest::fused_name(model, m, bs), suffix);
        let fused_art = rt.manifest.artifact(&fused_name)?;
        // cross-check the plan against the artifact the Python side lowered
        if merged_graph.param_order() != fused_art.params {
            bail!("{fused_name}: Rust merge plan disagrees with artifact");
        }
        let params = fuse::weights::params_in_order(&merged_graph, &merged_bank)?;
        let fused = rt.load(&fused_name, &params)?;

        let layout = fused.art().layout.clone();
        let packing = Layout::parse(&layout)?;
        let mut request_shape = vec![bs];
        request_shape.extend_from_slice(&entry.graph.input_shape);
        let arenas = Arc::new(ArenaRing::pair(packing, m, &request_shape)?);
        // the arenas' derived megabatch shape must agree with what the
        // AOT side lowered, or packing would feed the wrong windows
        if arenas.merged_shape() != fused.art().input_shape {
            bail!(
                "{fused_name}: arena packs {:?}, artifact expects {:?}",
                arenas.merged_shape(),
                fused.art().input_shape
            );
        }

        let single_art = rt.manifest.artifact(&single_name)?;
        Ok(Fleet {
            model: model.to_string(),
            m,
            bs,
            layout,
            packing,
            graph: entry.graph,
            single_weights_bytes: single_art.weights_bytes,
            single_act_bytes: single_art.act_bytes,
            fused_weights_bytes: fused.art().weights_bytes,
            fused_act_bytes: fused.art().act_bytes,
            singles,
            fused,
            arenas,
            pool: OnceLock::new(),
        })
    }

    /// The worker pool handle this fleet dispatches Concurrent/Hybrid
    /// rounds onto, if one has been installed or lazily spawned yet.
    pub fn shared_pool(&self) -> Option<&Arc<WorkerPool>> {
        self.pool.get()
    }

    /// The staging ring NETFUSE rounds reserve slots from. Clone the
    /// `Arc` into [`Fleet::set_arena_ring`] of an identically shaped
    /// fleet to share one staging footprint across fleets.
    pub fn arena_ring(&self) -> &Arc<ArenaRing> {
        &self.arenas
    }

    /// Replace this fleet's staging ring — the sharing hook: several
    /// fleets with the same packing configuration (layout, instance
    /// count, request shape) can reserve slots from ONE ring, and a
    /// ring deeper than 2 lets that many dispatch threads overlap
    /// rounds. Rejects a ring whose configuration does not match what
    /// this fleet packs. Requires `&mut self`, so it can only happen
    /// before the fleet is registered with a server (servers hold `&`).
    pub fn set_arena_ring(&mut self, ring: Arc<ArenaRing>) -> Result<()> {
        if ring.layout() != self.packing
            || ring.m() != self.m
            || ring.request_shape() != self.request_shape().as_slice()
        {
            bail!(
                "ring packs {:?} {}x{:?}, fleet serves {:?} {}x{:?}",
                ring.layout(),
                ring.m(),
                ring.request_shape(),
                self.packing,
                self.m,
                self.request_shape()
            );
        }
        self.arenas = ring;
        Ok(())
    }

    /// Rebuild the staging ring at `depth` slots (builder form, applied
    /// after load and before serving): one slot per dispatch thread
    /// that should be able to hold a round in flight concurrently.
    pub fn with_arena_depth(mut self, depth: usize) -> Result<Fleet> {
        let ring = ArenaRing::new(self.packing, self.m, &self.request_shape(), depth)?;
        self.arenas = Arc::new(ring);
        Ok(self)
    }

    /// Pack one round of slot payloads into `arena`'s megabatch
    /// (paper §3.1: concat on channel for conv nets, stack on batch for
    /// matmul nets; absent slots take the arena's zero pad block).
    pub fn pack_into<'a>(
        &self,
        arena: &mut RoundArena,
        get: &(dyn Fn(usize) -> Option<&'a Tensor> + Sync),
    ) -> Result<()> {
        // allocation-free validation: this runs on the round hot path
        let rs = arena.request_shape();
        if arena.layout() != self.packing
            || arena.m() != self.m
            || rs.first() != Some(&self.bs)
            || rs[1..] != self.graph.input_shape[..]
        {
            bail!(
                "arena packs {:?} {}x{:?}, fleet serves {:?} {}x{:?}",
                arena.layout(),
                arena.m(),
                arena.request_shape(),
                self.packing,
                self.m,
                self.request_shape()
            );
        }
        arena.pack_with(get)
    }

    /// Split the merged output into per-instance **borrowed views**
    /// (zero-copy). Merged outputs are always batch-packed `[M, bs, ...]`
    /// (the per-instance heads are re-stacked by `stack_m`), so each view
    /// is a contiguous window. Promote with `to_owned` where needed.
    pub fn unpack<'y>(&self, y: &'y Tensor) -> Result<Vec<TensorView<'y>>> {
        (0..self.m).map(|i| y.view0(i)).collect()
    }

    /// Run one round (one request per instance) under `strategy`.
    /// Returns per-instance outputs, index-aligned with `xs`.
    pub fn run_round(
        &self,
        strategy: StrategyKind,
        xs: &[&Tensor],
    ) -> Result<Vec<Tensor>> {
        if xs.len() != self.m {
            bail!("round wants {} inputs, got {}", self.m, xs.len());
        }
        let mut outs = Vec::with_capacity(self.m);
        self.run_round_slots(strategy, &|i| Some(xs[i]), &mut outs)?;
        outs.into_iter()
            .enumerate()
            .map(|(i, t)| t.with_context(|| format!("model {i} produced no output")))
            .collect()
    }

    /// Slot-level round executor — the server's hot path. `get(i)` is
    /// instance `i`'s payload (`None` = empty queue slot). Results are
    /// appended to `outs` index-aligned (`None` for absent slots, which
    /// single-model strategies skip entirely and NETFUSE pads). `outs` is
    /// caller-owned scratch so the steady state reuses its capacity.
    pub fn run_round_slots<'a>(
        &self,
        strategy: StrategyKind,
        get: &(dyn Fn(usize) -> Option<&'a Tensor> + Sync),
        outs: &mut Vec<Option<Tensor>>,
    ) -> Result<()> {
        // catch strategies built directly (bypassing StrategyKind::parse)
        // before any queue slot is consumed
        strategy.validate()?;
        outs.clear();
        match strategy {
            StrategyKind::Sequential => {
                for i in 0..self.m {
                    outs.push(match get(i) {
                        Some(x) => Some(self.singles[i].run(x)?),
                        None => None,
                    });
                }
                Ok(())
            }
            StrategyKind::Concurrent => self.run_chunked(get, self.m, outs),
            StrategyKind::Hybrid { procs } => {
                self.run_chunked(get, procs.min(self.m), outs)
            }
            StrategyKind::NetFuse => {
                let y = {
                    // reserve ONE ring slot for this round: the guard
                    // spans pack + stage + execute because PJRT
                    // host-buffer semantics may defer the H2D copy, so
                    // the staged megabatch must not be repacked until
                    // the round completes (`StagedInput` borrows the
                    // slot through the guard). The other `depth - 1`
                    // slots stay free, so concurrent rounds — one per
                    // dispatch thread, up to the ring depth — pack and
                    // stage while this one is still in flight.
                    let mut arena = self.arenas.acquire();
                    self.pack_into(&mut arena, get)?;
                    let staged =
                        self.fused.stage(arena.merged_shape(), arena.merged_data())?;
                    self.fused.run_staged(&staged)?
                };
                for i in 0..self.m {
                    outs.push(match get(i) {
                        Some(_) => Some(y.view0(i)?.to_owned()),
                        None => None,
                    });
                }
                Ok(())
            }
        }
    }

    /// `procs` unsynchronized workers, each draining a contiguous chunk
    /// of models sequentially on the persistent pool. procs == M is the
    /// Concurrent baseline.
    fn run_chunked<'a>(
        &self,
        get: &(dyn Fn(usize) -> Option<&'a Tensor> + Sync),
        procs: usize,
        outs: &mut Vec<Option<Tensor>>,
    ) -> Result<()> {
        // size the pool to what this strategy actually uses; a later
        // wider strategy (e.g. Concurrent after Hybrid) grows it. A
        // pool installed by load_with_pool is shared across fleets and
        // never duplicated here.
        let pool = self.pool.get_or_init(|| WorkerPool::shared(procs));
        pool.ensure_workers(procs);
        let results = pool.run_chunked(self.m, procs, |i| match get(i) {
            Some(x) => self.singles[i].run(x).map(Some),
            None => Ok(None),
        })?;
        outs.extend(results);
        Ok(())
    }

    /// Access a single instance's executable (serving loop fast path for
    /// strategies that dispatch per request).
    pub fn single(&self, i: usize) -> &Bound {
        &self.singles[i]
    }

    pub fn fused(&self) -> &Bound {
        &self.fused
    }

    /// Per-request input shape `[bs, ...]`.
    pub fn request_shape(&self) -> Vec<usize> {
        let mut s = vec![self.bs];
        s.extend_from_slice(&self.graph.input_shape);
        s
    }
}

impl RoundExecutor for Fleet {
    fn name(&self) -> &str {
        &self.model
    }
    fn m(&self) -> usize {
        self.m
    }
    fn bs(&self) -> usize {
        self.bs
    }
    fn input_shape(&self) -> &[usize] {
        &self.graph.input_shape
    }
    fn run_round_slots<'a>(
        &self,
        strategy: StrategyKind,
        get: &(dyn Fn(usize) -> Option<&'a Tensor> + Sync),
        outs: &mut Vec<Option<Tensor>>,
    ) -> Result<()> {
        Fleet::run_round_slots(self, strategy, get, outs)
    }
}

/// Read `weights/<model>.nft` and split into per-instance banks
/// (keys are `m{i}/node.weight`), keeping the first `m`. The weight
/// file itself is the source of truth for how many instances it
/// carries; the manifest's `instances` field only gates fleet
/// admission (checked in `Fleet::load`).
pub fn load_banks(rt: &Runtime, model: &str, m: usize) -> Result<Vec<Bank>> {
    let entry = rt.manifest.model(model)?;
    let all = read_nft(&rt.artifact_dir().join(&entry.weights))?;
    let mut count = 0usize;
    for k in all.keys() {
        count = count.max(bank_key_index(k)?.0 + 1);
    }
    let mut banks = split_banks(all, count)?;
    if m > banks.len() {
        bail!(
            "{model}: wanted {m} instance banks, weight file has {}",
            banks.len()
        );
    }
    banks.truncate(m);
    Ok(banks)
}

/// `"m{i}/node.weight" -> (i, "node.weight")`.
fn bank_key_index(k: &str) -> Result<(usize, &str)> {
    let (prefix, rest) = k
        .split_once('/')
        .with_context(|| format!("bad bank key {k:?}"))?;
    let idx: usize = prefix
        .strip_prefix('m')
        .and_then(|s| s.parse().ok())
        .with_context(|| format!("bad bank key {k:?}"))?;
    Ok((idx, rest))
}

/// Split a flat `m{i}/key` map into exactly `m` per-instance banks.
/// Takes the map by value and **moves** each tensor into its bank — the
/// fleet-load path reads multi-gigabyte weight files, and the seed's
/// per-tensor clone doubled that traffic. A key addressing an instance
/// `>= m` fails loudly (the seed silently dropped such tensors);
/// callers that want "first m of a larger file" split by the file's own
/// instance count and truncate, as `load_banks` does.
pub fn split_banks(all: BTreeMap<String, Tensor>, m: usize) -> Result<Vec<Bank>> {
    let mut banks = vec![Bank::new(); m];
    for (k, v) in all {
        let (idx, rest) = bank_key_index(&k)?;
        if idx >= m {
            bail!("bank key {k:?} addresses instance {idx}, but only {m} banks were requested");
        }
        let rest = rest.to_string();
        banks[idx].insert(rest, v);
    }
    for (i, b) in banks.iter().enumerate() {
        if b.is_empty() {
            bail!("weight bank has no tensors for instance {i}");
        }
    }
    Ok(banks)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flat(m: usize) -> BTreeMap<String, Tensor> {
        let mut all = BTreeMap::new();
        for i in 0..m {
            all.insert(format!("m{i}/d.w"), Tensor::zeros(&[2, 2]));
            all.insert(format!("m{i}/d.b"), Tensor::scalar(i as f32));
        }
        all
    }

    #[test]
    fn split_banks_moves_tensors_per_instance() {
        let banks = split_banks(flat(3), 3).unwrap();
        assert_eq!(banks.len(), 3);
        for (i, b) in banks.iter().enumerate() {
            assert_eq!(b.len(), 2);
            assert_eq!(b["d.b"].data(), &[i as f32]);
        }
    }

    #[test]
    fn split_banks_rejects_out_of_range_instances() {
        // the seed silently dropped m{i} keys with idx >= m; now loud
        let err = split_banks(flat(3), 2).unwrap_err();
        assert!(err.to_string().contains("instance 2"));
    }

    #[test]
    fn split_banks_rejects_malformed_keys_and_gaps() {
        let mut all = flat(1);
        all.insert("nodelimiter".into(), Tensor::scalar(0.0));
        assert!(split_banks(all, 1).is_err());

        let mut all = flat(1);
        all.insert("q7/x".into(), Tensor::scalar(0.0));
        assert!(split_banks(all, 1).is_err());

        // declared m=2 but no m1/* keys at all -> empty bank
        assert!(split_banks(flat(1), 2).is_err());
    }
}
