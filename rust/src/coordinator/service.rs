//! `Fleet`: M fine-tuned instances of one model family, runnable under
//! any of the four strategies. This is the heart of the reproduction —
//! the same weight banks flow through the single-model executables
//! (baselines) and through the merged executable (NETFUSE), and a round
//! of M requests produces identical outputs either way.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::fuse::{self, weights::Bank};
use crate::graph::Graph;
use crate::runtime::{Bound, Manifest, Runtime};
use crate::tensor::{io::read_nft, Tensor};

use super::strategy::StrategyKind;

/// A fleet of M instances of one model family at a fixed batch size.
pub struct Fleet {
    pub model: String,
    pub m: usize,
    pub bs: usize,
    /// merged-input packing: "channel" (CNN) | "batch" (sequence)
    pub layout: String,
    /// single-model graph (planning/memory estimation)
    pub graph: Graph,
    /// M bindings of the single-model module (one per weight bank)
    singles: Vec<Bound>,
    /// the NETFUSE executable with Rust-stacked merged weights
    fused: Bound,
    /// manifest memory numbers for the memory model
    pub single_weights_bytes: u64,
    pub single_act_bytes: u64,
    pub fused_weights_bytes: u64,
    pub fused_act_bytes: u64,
}

impl Fleet {
    /// Load a fleet from artifacts: compile the single + merged modules,
    /// read the per-instance banks, stack the merged weights (Rust-side
    /// Algorithm 1 + weight merge).
    pub fn load(rt: &Runtime, model: &str, m: usize, bs: usize) -> Result<Fleet> {
        Self::load_with(rt, model, m, bs, "")
    }

    /// `suffix` selects artifact variants (e.g. "_pallas" for the
    /// Pallas-kernel lowering the quickstart exercises).
    pub fn load_with(
        rt: &Runtime,
        model: &str,
        m: usize,
        bs: usize,
        suffix: &str,
    ) -> Result<Fleet> {
        let entry = rt.manifest.model(model)?.clone();
        if m > entry.instances {
            bail!(
                "{model}: fleet wants {m} instances, bank has {}",
                entry.instances
            );
        }
        let banks = load_banks(rt, model, m)?;

        // single-model executables: ONE compile, M weight bindings
        let single_name = format!("{}{}", Manifest::single_name(model, bs), suffix);
        let single_mod = rt.compile(&single_name)?;
        let mut singles = Vec::with_capacity(m);
        for bank in &banks {
            let params = fuse::weights::params_in_order(&entry.graph, bank)?;
            singles.push(single_mod.bind(&params)?);
        }

        // merged executable: Rust-side merge plan + stacked weights
        let merged_graph = fuse::merge(&entry.graph, m)?;
        let merged_bank = fuse::weights::merge_weights(&merged_graph, &banks)?;
        let fused_name = format!("{}{}", Manifest::fused_name(model, m, bs), suffix);
        let fused_art = rt.manifest.artifact(&fused_name)?;
        // cross-check the plan against the artifact the Python side lowered
        if merged_graph.param_order() != fused_art.params {
            bail!("{fused_name}: Rust merge plan disagrees with artifact");
        }
        let params = fuse::weights::params_in_order(&merged_graph, &merged_bank)?;
        let fused = rt.load(&fused_name, &params)?;

        let single_art = rt.manifest.artifact(&single_name)?;
        Ok(Fleet {
            model: model.to_string(),
            m,
            bs,
            layout: fused.art().layout.clone(),
            graph: entry.graph,
            single_weights_bytes: single_art.weights_bytes,
            single_act_bytes: single_art.act_bytes,
            fused_weights_bytes: fused.art().weights_bytes,
            fused_act_bytes: fused.art().act_bytes,
            singles,
            fused,
        })
    }

    /// Pack M per-instance inputs into the merged input tensor
    /// (paper §3.1: concat on channel for conv nets, stack on batch for
    /// matmul nets).
    pub fn pack(&self, xs: &[&Tensor]) -> Result<Tensor> {
        if xs.len() != self.m {
            bail!("pack wants {} inputs, got {}", self.m, xs.len());
        }
        match self.layout.as_str() {
            "channel" => Tensor::concat(xs, 1),
            "batch" => Tensor::stack(xs),
            other => bail!("bad fleet layout {other:?}"),
        }
    }

    /// Split the merged output back into per-instance outputs. Merged
    /// outputs are always batch-packed `[M, bs, ...]` (the per-instance
    /// heads are re-stacked by `stack_m`).
    pub fn unpack(&self, y: &Tensor) -> Result<Vec<Tensor>> {
        (0..self.m).map(|i| y.index0(i)).collect()
    }

    /// Run one round (one request per instance) under `strategy`.
    /// Returns per-instance outputs, index-aligned with `xs`.
    pub fn run_round(
        &self,
        strategy: StrategyKind,
        xs: &[&Tensor],
    ) -> Result<Vec<Tensor>> {
        if xs.len() != self.m {
            bail!("round wants {} inputs, got {}", self.m, xs.len());
        }
        match strategy {
            StrategyKind::Sequential => {
                let mut out = Vec::with_capacity(self.m);
                for (i, x) in xs.iter().enumerate() {
                    out.push(self.singles[i].run(x)?);
                }
                Ok(out)
            }
            StrategyKind::Concurrent => self.run_chunked(xs, self.m),
            StrategyKind::Hybrid { procs } => self.run_chunked(xs, procs.min(self.m)),
            StrategyKind::NetFuse => {
                let y = self.fused.run(&self.pack(xs)?)?;
                self.unpack(&y)
            }
        }
    }

    /// `procs` unsynchronized workers, each draining a contiguous chunk
    /// of models sequentially. procs == M is the Concurrent baseline.
    fn run_chunked(&self, xs: &[&Tensor], procs: usize) -> Result<Vec<Tensor>> {
        let chunk = self.m.div_ceil(procs);
        let mut out: Vec<Option<Tensor>> = (0..self.m).map(|_| None).collect();
        let results: Vec<Result<Vec<(usize, Tensor)>>> = std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for p in 0..procs {
                let lo = p * chunk;
                let hi = ((p + 1) * chunk).min(self.m);
                if lo >= hi {
                    continue;
                }
                let singles = &self.singles;
                handles.push(scope.spawn(move || {
                    let mut part = Vec::with_capacity(hi - lo);
                    for i in lo..hi {
                        part.push((i, singles[i].run(xs[i])?));
                    }
                    Ok(part)
                }));
            }
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for r in results {
            for (i, t) in r? {
                out[i] = Some(t);
            }
        }
        out.into_iter()
            .enumerate()
            .map(|(i, t)| t.with_context(|| format!("model {i} produced no output")))
            .collect()
    }

    /// Access a single instance's executable (serving loop fast path for
    /// strategies that dispatch per request).
    pub fn single(&self, i: usize) -> &Bound {
        &self.singles[i]
    }

    pub fn fused(&self) -> &Bound {
        &self.fused
    }

    /// Per-request input shape `[bs, ...]`.
    pub fn request_shape(&self) -> Vec<usize> {
        let mut s = vec![self.bs];
        s.extend_from_slice(&self.graph.input_shape);
        s
    }
}

/// Read `weights/<model>.nft` and split into per-instance banks
/// (keys are `m{i}/node.weight`).
pub fn load_banks(rt: &Runtime, model: &str, m: usize) -> Result<Vec<Bank>> {
    let entry = rt.manifest.model(model)?;
    let all = read_nft(&rt.artifact_dir().join(&entry.weights))?;
    split_banks(&all, m)
}

/// Split a flat `m{i}/key` map into per-instance banks.
pub fn split_banks(all: &BTreeMap<String, Tensor>, m: usize) -> Result<Vec<Bank>> {
    let mut banks = vec![Bank::new(); m];
    for (k, v) in all {
        let (prefix, rest) = k
            .split_once('/')
            .with_context(|| format!("bad bank key {k:?}"))?;
        let idx: usize = prefix
            .strip_prefix('m')
            .and_then(|s| s.parse().ok())
            .with_context(|| format!("bad bank key {k:?}"))?;
        if idx < m {
            banks[idx].insert(rest.to_string(), v.clone());
        }
    }
    for (i, b) in banks.iter().enumerate() {
        if b.is_empty() {
            bail!("weight bank has no tensors for instance {i}");
        }
    }
    Ok(banks)
}
