//! Memory accounting — drives Figures 7 and 10.
//!
//! The paper decomposes peak GPU memory into the *inference workspace*
//! (weights + intermediate activations) and the *base memory* the
//! framework reserves per process (~500 MB for PyTorch on GPU). The
//! Concurrent baseline OOMs not because of workspace but because M
//! processes × base memory exhausts the card (§5.3). This module
//! reproduces that decomposition for any strategy.

use super::strategy::StrategyKind;

/// Framework base memory per process (the paper's PyTorch constant).
pub const BASE_PER_PROCESS: u64 = 500 * 1024 * 1024;

/// Per-process cuDNN workspace + caching-allocator slack. Charged to the
/// *workspace* portion for every live process: this is what pushes the
/// Concurrent baseline over the 16 GB V100 at 16 models (§5.3) even
/// though weights alone would fit.
pub const SLACK_PER_PROCESS: u64 = 448 * 1024 * 1024;

/// Per-configuration memory inputs (from the manifest for measured mode,
/// or from `devmodel::fullscale` for paper-scale mode).
#[derive(Debug, Clone, Copy)]
pub struct ModelFootprint {
    /// one instance's parameters
    pub weights_bytes: u64,
    /// one instance's activation workspace at the given batch size
    pub act_bytes: u64,
    /// merged (M-instance) parameters — == m * weights_bytes
    pub fused_weights_bytes: u64,
    /// merged activation workspace
    pub fused_act_bytes: u64,
}

/// Peak memory estimate for one configuration.
#[derive(Debug, Clone, Copy)]
pub struct MemoryEstimate {
    /// weights + activations (the hatched bar portion)
    pub workspace: u64,
    /// framework base (the solid bar portion)
    pub base: u64,
    pub total: u64,
    /// processes the strategy spawns
    pub processes: usize,
}

impl MemoryEstimate {
    pub fn fits(&self, capacity: u64) -> bool {
        self.total <= capacity
    }
}

/// Estimate peak memory for running M instances under `strategy`
/// (paper §5.3):
///
/// - Sequential: one process; all M weight sets stay resident (the
///   paper's baseline keeps every model loaded), one activation set.
/// - Concurrent: M processes, each with its own weights + activations
///   and its own framework base.
/// - Hybrid(A): A processes; all weights resident, A live activation
///   sets.
/// - NetFuse: one process holding the merged weights + merged
///   activations.
pub fn estimate(
    strategy: StrategyKind,
    m: usize,
    fp: &ModelFootprint,
) -> MemoryEstimate {
    let procs = strategy.processes(m);
    let base = BASE_PER_PROCESS * procs as u64;
    let workspace = match strategy {
        StrategyKind::Sequential => fp.weights_bytes * m as u64 + fp.act_bytes,
        StrategyKind::Concurrent => (fp.weights_bytes + fp.act_bytes) * m as u64,
        StrategyKind::Hybrid { .. } => {
            fp.weights_bytes * m as u64 + fp.act_bytes * procs as u64
        }
        StrategyKind::NetFuse => fp.fused_weights_bytes + fp.fused_act_bytes,
    } + SLACK_PER_PROCESS * procs as u64;
    MemoryEstimate { workspace, base, total: workspace + base, processes: procs }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FP: ModelFootprint = ModelFootprint {
        weights_bytes: 100 << 20,       // 100 MB
        act_bytes: 30 << 20,            // 30 MB
        fused_weights_bytes: 16 * (100 << 20),
        fused_act_bytes: 16 * (30 << 20),
    };

    #[test]
    fn concurrent_base_dominates() {
        // the paper's §5.3 observation: 16 processes ~ 8 GB of base alone
        let e = estimate(StrategyKind::Concurrent, 16, &FP);
        assert_eq!(e.base, 16 * BASE_PER_PROCESS);
        assert!(e.base > e.workspace / 2);
        assert!(!e.fits(10 << 30)); // 10 GB card: OOM
    }

    #[test]
    fn sequential_is_smallest_workspace() {
        let seq = estimate(StrategyKind::Sequential, 16, &FP);
        let conc = estimate(StrategyKind::Concurrent, 16, &FP);
        let fused = estimate(StrategyKind::NetFuse, 16, &FP);
        assert!(seq.workspace < conc.workspace);
        assert!(seq.workspace <= fused.workspace);
        assert!(seq.total < conc.total);
    }

    #[test]
    fn netfuse_close_to_sequential_plus_acts() {
        // NETFUSE holds M x activations but only 1 process of base:
        // "a small additional amount of GPU memory" (abstract)
        let seq = estimate(StrategyKind::Sequential, 8, &FP);
        let nf = estimate(StrategyKind::NetFuse, 8, &FP);
        assert!(nf.total < seq.total * 2);
        assert!(nf.base == BASE_PER_PROCESS);
    }

    #[test]
    fn hybrid_interpolates() {
        let h4 = estimate(StrategyKind::Hybrid { procs: 4 }, 32, &FP);
        let seq = estimate(StrategyKind::Sequential, 32, &FP);
        let conc = estimate(StrategyKind::Concurrent, 32, &FP);
        assert!(h4.total > seq.total);
        assert!(h4.total < conc.total);
        assert_eq!(h4.processes, 4);
    }
}
