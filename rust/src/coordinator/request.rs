//! Inference request/response types.

use std::time::Instant;

use crate::tensor::Tensor;

/// A single inference request targeting one model instance.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// which of the M fine-tuned instances this request is for
    pub model_idx: usize,
    /// [bs, ...input_shape]
    pub input: Tensor,
    /// arrival time (set by the workload generator / ingress)
    pub arrived: Instant,
}

impl Request {
    pub fn new(id: u64, model_idx: usize, input: Tensor) -> Request {
        Request { id, model_idx, input, arrived: Instant::now() }
    }

    /// Re-stamp `arrived` to now — the **admission-boundary** stamp.
    ///
    /// `Request` is `Clone` and producers may build (or clone) requests
    /// long before the server sees them; queue-wait math keyed off a
    /// producer-side construction time would inflate latencies and
    /// trip `max_wait`/SLO deadlines that never really elapsed. Ingress
    /// paths (`ingress::bridge`) call this at admission; `Server::offer`
    /// additionally clamps stragglers to a server-wide arrival floor so
    /// admission order IS arrival order.
    pub fn arrived_now(mut self) -> Request {
        self.arrived = Instant::now();
        self
    }
}

/// The corresponding completion.
#[derive(Debug)]
pub struct Response {
    pub id: u64,
    pub model_idx: usize,
    pub output: Tensor,
    /// end-to-end seconds (arrival -> completion)
    pub latency: f64,
}
