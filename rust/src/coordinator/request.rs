//! Inference request/response types.

use std::time::Instant;

use crate::tensor::Tensor;

/// Monotonic stage timestamps stamped at the existing dispatch seams
/// (ADR-006). Stamping is unconditional and costs one `Instant` copy
/// per seam — the seams reuse one `Instant::now()` per ROUND — so
/// observability never changes routing or payloads; the stamps are only
/// *folded* into stage histograms when an `ObsHub` is attached.
///
/// Stage segments telescope: with `arrived` from admission,
/// `queue = picked - arrived`, `pack = exec_start - picked`,
/// `execute = exec_end - exec_start`, `scatter = completed - exec_end`,
/// and the first four sum exactly to `completed - arrived` — the same
/// interval `Response::latency` measures (nanoseconds apart). The
/// response-write stage is measured at the routing seam
/// (`ingress::bridge::route_responses`) against `completed`.
#[derive(Debug, Clone, Copy, Default)]
pub struct Stamps {
    /// admission boundary (copied from `Request::arrived` at completion)
    pub arrived: Option<Instant>,
    /// QoS pick: the round-take that claimed this request
    pub picked: Option<Instant>,
    /// megabatch execution began (arena pack happens at its start)
    pub exec_start: Option<Instant>,
    /// megabatch execution returned
    pub exec_end: Option<Instant>,
    /// response materialized (verify + scatter done)
    pub completed: Option<Instant>,
}

/// A single inference request targeting one model instance.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// which of the M fine-tuned instances this request is for
    pub model_idx: usize,
    /// [bs, ...input_shape]
    pub input: Tensor,
    /// arrival time (set by the workload generator / ingress)
    pub arrived: Instant,
    /// stage timestamps (ADR-006); re-stamped as the request moves
    pub stamps: Stamps,
}

impl Request {
    pub fn new(id: u64, model_idx: usize, input: Tensor) -> Request {
        Request {
            id,
            model_idx,
            input,
            arrived: Instant::now(),
            stamps: Stamps::default(),
        }
    }

    /// Re-stamp `arrived` to now — the **admission-boundary** stamp.
    ///
    /// `Request` is `Clone` and producers may build (or clone) requests
    /// long before the server sees them; queue-wait math keyed off a
    /// producer-side construction time would inflate latencies and
    /// trip `max_wait`/SLO deadlines that never really elapsed. Ingress
    /// paths (`ingress::bridge`) call this at admission; `Server::offer`
    /// additionally clamps stragglers to a server-wide arrival floor so
    /// admission order IS arrival order.
    pub fn arrived_now(mut self) -> Request {
        self.arrived = Instant::now();
        self
    }
}

/// The corresponding completion.
#[derive(Debug)]
pub struct Response {
    pub id: u64,
    pub model_idx: usize,
    pub output: Tensor,
    /// end-to-end seconds (arrival -> completion)
    pub latency: f64,
    /// stage timestamps carried from the request (ADR-006)
    pub stamps: Stamps,
}
