//! Inference request/response types.

use std::time::Instant;

use crate::tensor::Tensor;

/// A single inference request targeting one model instance.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// which of the M fine-tuned instances this request is for
    pub model_idx: usize,
    /// [bs, ...input_shape]
    pub input: Tensor,
    /// arrival time (set by the workload generator / ingress)
    pub arrived: Instant,
}

impl Request {
    pub fn new(id: u64, model_idx: usize, input: Tensor) -> Request {
        Request { id, model_idx, input, arrived: Instant::now() }
    }
}

/// The corresponding completion.
#[derive(Debug)]
pub struct Response {
    pub id: u64,
    pub model_idx: usize,
    pub output: Tensor,
    /// end-to-end seconds (arrival -> completion)
    pub latency: f64,
}
