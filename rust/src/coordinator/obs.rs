//! Observability plane (ADR-006): stage tracing, the flight recorder,
//! and the live introspection hub behind `ObsQuery`/`ObsReport`.
//!
//! Three surfaces, all built on the `util::shard` merge-on-read idiom
//! so the dispatch hot path never takes a contended lock:
//!
//! - **Stage tracing** — every `Request` is stamped with monotonic
//!   [`Stamps`](super::request::Stamps) at the existing dispatch seams;
//!   at response-routing time a [`StageTracer`] folds the telescoping
//!   segments (queue → pack → execute → scatter → write) into per-lane
//!   fixed-log-bucket histograms ([`crate::util::hist::Hist`]). The
//!   bucketization is a pure function applied before sharding, so the
//!   merged view is **exactly** what one histogram fed every stream
//!   would hold — the ADR-004 exactness contract extended to stages.
//! - **Flight recorder** — each dispatch thread holds a [`RecHandle`]
//!   onto its own fixed-capacity overwriting [`EventRing`] of compact
//!   [`Event`]s (round start/end, coalesce decisions, QoS picks with
//!   deficits, control ops with epochs, rejects, round errors). The
//!   merged ring — the newest events across all threads in global
//!   sequence order — is dumped automatically on round failure and on
//!   unresolved control tickets, and on demand.
//! - **Introspection hub** — [`ObsHub`] collects per-lane gauges,
//!   tracked [`ArenaRing`]s, an optional [`MetricsHub`], and the
//!   pending `ObsQuery` replies; a dispatch loop answers every waiting
//!   query with one JSON [`ObsHub::report`] built from the exactly
//!   merged state.
//!
//! The hub is attached to an `IngressBridge`
//! (`IngressBridge::attach_obs`) *before* dispatch starts; with no hub
//! attached, the only per-request cost is the unconditional stamp
//! copies (one `Instant::now()` per round per seam).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::ingress::bridge::IngressStats;
use crate::ingress::frame::{Frame, RejectCode};
use crate::ingress::transport::FrameQueue;
use crate::util::hist::Hist;
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::lock::{LockRank, OrderedMutex};
use crate::util::shard::{ShardHandle, Shardable, Sharded};

use super::arena::ArenaRing;
use super::metrics::MetricsHub;
use super::multi::TopologySnapshot;
use super::request::Stamps;

// ---------------------------------------------------------------------------
// stage tracing
// ---------------------------------------------------------------------------

/// The five request stages the seams stamp. The first four telescope
/// exactly to the end-to-end latency (`completed - arrived`); `Write`
/// is the routing seam's own segment, measured against `completed`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(usize)]
pub enum Stage {
    /// admission → QoS pick (`picked - arrived`)
    Queue = 0,
    /// QoS pick → megabatch execution start (`exec_start - picked`)
    Pack = 1,
    /// megabatch execution (`exec_end - exec_start`)
    Execute = 2,
    /// execution end → response materialized (`completed - exec_end`)
    Scatter = 3,
    /// response materialized → handed to the reply queue
    Write = 4,
}

impl Stage {
    pub const ALL: [Stage; 5] =
        [Stage::Queue, Stage::Pack, Stage::Execute, Stage::Scatter, Stage::Write];

    pub fn name(self) -> &'static str {
        match self {
            Stage::Queue => "queue",
            Stage::Pack => "pack",
            Stage::Execute => "execute",
            Stage::Scatter => "scatter",
            Stage::Write => "write",
        }
    }
}

/// One lane's per-stage histograms.
#[derive(Clone, Debug)]
pub struct LaneStages {
    pub stages: [Hist; 5],
}

impl Default for LaneStages {
    fn default() -> Self {
        LaneStages { stages: std::array::from_fn(|_| Hist::new()) }
    }
}

impl LaneStages {
    pub fn stage(&self, stage: Stage) -> &Hist {
        &self.stages[stage as usize]
    }
}

/// The shardable per-lane stage-histogram accumulator: lanes are
/// indexed by **global** lane id (the vec grows on demand — global ids
/// are monotone, so the index is stable across topology churn).
#[derive(Clone, Debug, Default)]
pub struct ObsCore {
    lanes: Vec<LaneStages>,
}

impl ObsCore {
    pub fn fold(&mut self, lane: usize, stage: Stage, ns: u64) {
        if lane >= self.lanes.len() {
            self.lanes.resize_with(lane + 1, LaneStages::default);
        }
        self.lanes[lane].stages[stage as usize].record_ns(ns);
    }

    pub fn lanes(&self) -> &[LaneStages] {
        &self.lanes
    }

    pub fn lane(&self, lane: usize) -> Option<&LaneStages> {
        self.lanes.get(lane)
    }
}

impl Shardable for ObsCore {
    // tracer shards are folded while the admit path's stats-shard
    // guard is held, so they rank above StatsShard (ADR-008)
    const RANK: LockRank = LockRank::ObsShard;

    fn merge_from(&mut self, other: &Self) {
        if other.lanes.len() > self.lanes.len() {
            self.lanes.resize_with(other.lanes.len(), LaneStages::default);
        }
        for (a, b) in self.lanes.iter_mut().zip(&other.lanes) {
            for (ha, hb) in a.stages.iter_mut().zip(&b.stages) {
                ha.merge_from(hb);
            }
        }
    }
}

fn dur_ns(from: Instant, to: Instant) -> u64 {
    u64::try_from(to.saturating_duration_since(from).as_nanos()).unwrap_or(u64::MAX)
}

/// One dispatch thread's claim on a stage-histogram shard. Folding is
/// an uncontended lock (the shard is private to the thread) plus five
/// bucket increments per response.
#[derive(Clone, Debug)]
pub struct StageTracer {
    shard: ShardHandle<ObsCore>,
}

impl StageTracer {
    /// Fold one response's stamps into lane `lane`'s stage histograms.
    /// A response missing any stamp (a foreign-offered request that
    /// never crossed the admission seam) folds nothing.
    pub fn fold_stamps(&self, lane: usize, st: &Stamps, write_end: Instant) {
        let (Some(arrived), Some(picked), Some(es), Some(ee), Some(done)) =
            (st.arrived, st.picked, st.exec_start, st.exec_end, st.completed)
        else {
            return;
        };
        let mut core = self.shard.lock();
        core.fold(lane, Stage::Queue, dur_ns(arrived, picked));
        core.fold(lane, Stage::Pack, dur_ns(picked, es));
        core.fold(lane, Stage::Execute, dur_ns(es, ee));
        core.fold(lane, Stage::Scatter, dur_ns(ee, done));
        core.fold(lane, Stage::Write, dur_ns(done, write_end));
    }

    /// The exactly merged view across every tracer shard.
    pub fn merged(&self) -> ObsCore {
        self.shard.merged()
    }
}

// ---------------------------------------------------------------------------
// flight recorder
// ---------------------------------------------------------------------------

/// Per-shard event capacity of the operating configuration.
/// `EventRing::default()` — what `Sharded::new` constructs shards with —
/// MUST carry this cap; explicit caps are for direct test construction.
pub const DEFAULT_EVENT_CAP: usize = 512;

/// What kind of lane-lifecycle control op an event records.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CtrlKind {
    Add,
    Remove,
    Swap,
}

/// One compact flight-recorder event. All variants are `Copy`-sized:
/// the ring is a flat overwrite buffer, never an allocation per event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventKind {
    /// a round is about to dispatch on partition `part`
    RoundStart { part: usize },
    /// a round completed: the QoS-picked global lane, how many lanes
    /// the round served, and the responses it produced
    RoundEnd { lane: usize, lanes_served: usize, responses: usize },
    /// the round coalesced: `members` lanes merged into one megabatch
    Coalesce { lane: usize, members: usize },
    /// the QoS pick, with the picked lane's post-charge deficit
    /// ([`crate::ingress::qos::CHARGE_UNIT`] fixed point) and whether
    /// the SLO boost preempted WDRR
    QosPick { lane: usize, deficit: i64, urgent: bool },
    /// a control-plane command applied, with the topology epoch
    /// observed after it
    CtrlOp { op: CtrlKind, global: usize, epoch: u64 },
    /// an envelope refused in-band
    Reject { code: RejectCode, lane: usize },
    /// a failed round (requests requeued); `consecutive` counts the
    /// current failure streak
    RoundError { consecutive: u32 },
}

/// One recorded event: a globally ordered sequence number, nanoseconds
/// since the recorder's epoch, and the payload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    /// global order across every shard (one `AtomicU64` per recorder)
    pub seq: u64,
    /// nanoseconds since [`FlightRecorder`] construction
    pub t_ns: u64,
    pub kind: EventKind,
}

/// A fixed-capacity overwriting ring of [`Event`]s — one per dispatch
/// thread, behind the [`Sharded`] idiom. Pushing is O(1) with no
/// allocation once the ring is full; [`EventRing::events`] returns the
/// retained events oldest→newest.
///
/// **Merge exactness:** `seq` is issued by one global counter, so the
/// merged ring — union of all shards, sorted by `seq`, truncated to the
/// newest `cap` — contains exactly the last `cap` events recorded
/// across all shards: an event within the global last-`cap` has fewer
/// than `cap` successors globally, hence fewer on its own shard, hence
/// was not yet overwritten there. Intermediate fold truncation is safe
/// for the same reason — each partial merge keeps the newest `cap` of
/// what it has seen, and anything it drops has `cap` successors in that
/// partial view already.
#[derive(Clone, Debug)]
pub struct EventRing {
    cap: usize,
    buf: Vec<Event>,
    /// oldest element when the ring is full (`buf.len() == cap`)
    head: usize,
}

impl Default for EventRing {
    fn default() -> Self {
        EventRing::with_cap(DEFAULT_EVENT_CAP)
    }
}

impl EventRing {
    /// `cap` is clamped to at least 1.
    pub fn with_cap(cap: usize) -> EventRing {
        EventRing { cap: cap.max(1), buf: Vec::new(), head: 0 }
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Append, overwriting the oldest event once full.
    pub fn push(&mut self, e: Event) {
        if self.buf.len() < self.cap {
            self.buf.push(e);
        } else {
            self.buf[self.head] = e;
            self.head = (self.head + 1) % self.cap;
        }
    }

    /// Retained events, oldest first.
    pub fn events(&self) -> Vec<Event> {
        if self.buf.len() < self.cap {
            self.buf.clone()
        } else {
            let mut out = Vec::with_capacity(self.cap);
            out.extend_from_slice(&self.buf[self.head..]);
            out.extend_from_slice(&self.buf[..self.head]);
            out
        }
    }
}

impl Shardable for EventRing {
    // recorder rings are pushed to under the same held stats-shard
    // guard as the tracer shards (ADR-008)
    const RANK: LockRank = LockRank::ObsShard;

    fn merge_from(&mut self, other: &Self) {
        let cap = self.cap.max(other.cap);
        let mut all = self.events();
        all.extend(other.events());
        all.sort_by_key(|e| e.seq);
        if all.len() > cap {
            let cut = all.len() - cap;
            all.drain(..cut);
        }
        *self = EventRing { cap, buf: all, head: 0 };
    }
}

/// A stored flight-recorder dump: why it was taken and the merged
/// events at that moment (oldest first).
#[derive(Debug, Clone)]
pub struct Dump {
    pub reason: String,
    pub events: Vec<Event>,
}

/// The per-thread-ringed flight recorder. Construct sized to the
/// dispatch thread count; each thread takes a [`FlightRecorder::handle`]
/// and records through it lock-contention-free.
pub struct FlightRecorder {
    epoch: Instant,
    seq: Arc<AtomicU64>,
    rings: Arc<Sharded<EventRing>>,
    last: OrderedMutex<Option<Dump>>,
}

impl FlightRecorder {
    pub fn new(threads: usize) -> FlightRecorder {
        FlightRecorder {
            epoch: Instant::now(),
            seq: Arc::new(AtomicU64::new(0)),
            rings: Arc::new(Sharded::new(threads)),
            last: OrderedMutex::new(LockRank::ObsMeta, None),
        }
    }

    /// Claim the next ring shard (round-robin, wraps) for one recording
    /// thread. The handle is self-contained (`'static`).
    pub fn handle(&self) -> RecHandle {
        RecHandle {
            ring: Sharded::register(&self.rings),
            seq: Arc::clone(&self.seq),
            epoch: self.epoch,
        }
    }

    /// Events recorded so far (global counter — may exceed what the
    /// rings retain).
    pub fn recorded(&self) -> u64 {
        self.seq.load(Ordering::Relaxed)
    }

    /// The merged retained events across every shard, oldest first.
    pub fn snapshot(&self) -> Vec<Event> {
        self.rings.read().events()
    }

    /// Take a dump now: store it as the last dump (readable via
    /// [`FlightRecorder::last_dump`]) and print a one-line summary to
    /// stderr so an operator tailing logs sees the trigger.
    pub fn dump_now(&self, reason: &str) {
        let events = self.snapshot();
        eprintln!(
            "[flight-recorder] dump ({reason}): {} events retained, newest seq {}",
            events.len(),
            events.last().map(|e| e.seq).map(|s| s.to_string()).unwrap_or_else(|| "-".into()),
        );
        *self.last.lock() = Some(Dump { reason: reason.to_string(), events });
    }

    /// The most recent dump, if any was taken.
    pub fn last_dump(&self) -> Option<Dump> {
        self.last.lock().clone()
    }
}

/// One thread's recording claim: its ring shard plus the shared
/// sequence counter and epoch.
#[derive(Clone)]
pub struct RecHandle {
    ring: ShardHandle<EventRing>,
    seq: Arc<AtomicU64>,
    epoch: Instant,
}

impl RecHandle {
    /// Record one event: one atomic increment, one `Instant` read, one
    /// uncontended ring push.
    pub fn record(&self, kind: EventKind) {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let t_ns = u64::try_from(self.epoch.elapsed().as_nanos()).unwrap_or(u64::MAX);
        self.ring.lock().push(Event { seq, t_ns, kind });
    }

    /// The merged retained events across every shard (oldest first) —
    /// readable from a thread that only holds a handle.
    pub fn merged(&self) -> Vec<Event> {
        self.ring.merged().events()
    }
}

// ---------------------------------------------------------------------------
// the introspection hub
// ---------------------------------------------------------------------------

/// A point-in-time gauge for one lane, published by the dispatch thread
/// that owns it (between rounds, so every field is coherent).
#[derive(Debug, Clone, Copy)]
pub struct LaneGauge {
    /// global (wire) lane id
    pub global: usize,
    /// owning partition
    pub part: usize,
    /// partition-local lane slot
    pub local: usize,
    /// "live" | "draining" (retired lanes drop their gauge)
    pub life: &'static str,
    /// WDRR weight
    pub weight: u32,
    /// current WDRR deficit (CHARGE_UNIT fixed point; negative = debt)
    pub deficit: i64,
    /// effective SLO boost margin ε, nanoseconds
    pub boost_ns: u64,
    /// queued requests
    pub pending: usize,
    /// the lane's observed round-time p99, seconds (`None` until a
    /// round completes)
    pub round_p99_s: Option<f64>,
}

/// The live introspection plane: per-lane stage histograms, the flight
/// recorder, lane gauges, tracked arena rings, optional aggregate
/// metrics, and the pending `ObsQuery` reply queues.
///
/// Attach one hub to the `IngressBridge` before dispatch starts
/// (`IngressBridge::attach_obs`); connection readers enqueue queries,
/// and whichever dispatch loop polls next answers every pending one
/// with a single [`ObsHub::report`].
pub struct ObsHub {
    stages: Arc<Sharded<ObsCore>>,
    pub recorder: FlightRecorder,
    // all four registries share the ObsMeta rank: none is ever held
    // while another is acquired (each accessor's guard is transient),
    // and the one nested acquisition — `report` reading the MetricsHub
    // shards under the `metrics` guard — goes UP to MetricsShard
    gauges: OrderedMutex<HashMap<usize, LaneGauge>>,
    queries: OrderedMutex<VecDeque<(u64, FrameQueue)>>,
    rings: OrderedMutex<Vec<(String, Arc<ArenaRing>)>>,
    metrics: OrderedMutex<Option<Arc<MetricsHub>>>,
}

impl ObsHub {
    /// Size to the number of recording threads (dispatch threads; the
    /// parallel router counts as one more).
    pub fn new(threads: usize) -> ObsHub {
        ObsHub {
            stages: Arc::new(Sharded::new(threads)),
            recorder: FlightRecorder::new(threads),
            gauges: OrderedMutex::new(LockRank::ObsMeta, HashMap::new()),
            queries: OrderedMutex::new(LockRank::ObsMeta, VecDeque::new()),
            rings: OrderedMutex::new(LockRank::ObsMeta, Vec::new()),
            metrics: OrderedMutex::new(LockRank::ObsMeta, None),
        }
    }

    /// Claim a stage-histogram shard for one dispatch thread.
    pub fn tracer(&self) -> StageTracer {
        StageTracer { shard: Sharded::register(&self.stages) }
    }

    /// Claim a flight-recorder ring for one dispatch thread.
    pub fn rec_handle(&self) -> RecHandle {
        self.recorder.handle()
    }

    /// The exactly merged per-lane stage histograms.
    pub fn stages(&self) -> ObsCore {
        self.stages.read()
    }

    /// Publish (or refresh) one lane's gauge, keyed by global lane id.
    pub fn publish_gauge(&self, g: LaneGauge) {
        self.gauges.lock().insert(g.global, g);
    }

    /// Drop a retired lane's gauge.
    pub fn drop_gauge(&self, global: usize) {
        self.gauges.lock().remove(&global);
    }

    pub fn gauges(&self) -> Vec<LaneGauge> {
        let mut v: Vec<LaneGauge> = self.gauges.lock().values().copied().collect();
        v.sort_by_key(|g| g.global);
        v
    }

    /// Track an [`ArenaRing`]'s in-flight gauge in reports.
    pub fn track_ring(&self, label: &str, ring: Arc<ArenaRing>) {
        self.rings.lock().push((label.to_string(), ring));
    }

    /// Include a [`MetricsHub`]'s merged aggregates in reports.
    pub fn attach_metrics(&self, hub: Arc<MetricsHub>) {
        *self.metrics.lock() = Some(hub);
    }

    /// Queue one `ObsQuery` for the next dispatch-loop poll; the answer
    /// goes to `reply` as a `Frame::ObsReport` with the same `id`.
    pub fn enqueue_query(&self, id: u64, reply: FrameQueue) {
        self.queries.lock().push_back((id, reply));
    }

    pub fn has_queries(&self) -> bool {
        !self.queries.lock().is_empty()
    }

    /// Answer every pending query with one report built from `stats`
    /// (the caller's exactly merged counters) and the topology snapshot.
    /// Returns how many queries were answered. Queries are popped under
    /// the lock, so concurrent answering threads never double-answer.
    pub fn answer(&self, stats: &IngressStats, topo: Option<&TopologySnapshot>) -> usize {
        let waiting: Vec<(u64, FrameQueue)> = {
            let mut q = self.queries.lock();
            if q.is_empty() {
                return 0;
            }
            q.drain(..).collect()
        };
        let json = self.report(stats, topo).dump();
        let n = waiting.len();
        for (id, reply) in waiting {
            // a closed reply queue (client gone) drops the report,
            // matching response-delivery semantics
            reply.push(Frame::ObsReport { id, json: json.clone() });
        }
        n
    }

    /// Build the full introspection report.
    pub fn report(&self, stats: &IngressStats, topo: Option<&TopologySnapshot>) -> Json {
        let stages = self.stages.read();
        let lanes = arr(self.gauges().into_iter().map(|g| {
            let hists = stages.lane(g.global);
            obj(vec![
                ("global", num(g.global as f64)),
                ("part", num(g.part as f64)),
                ("local", num(g.local as f64)),
                ("life", s(g.life)),
                ("weight", num(g.weight as f64)),
                ("deficit", num(g.deficit as f64)),
                ("boost_ns", num(g.boost_ns as f64)),
                ("pending", num(g.pending as f64)),
                (
                    "round_p99_s",
                    g.round_p99_s.map(num).unwrap_or(Json::Null),
                ),
                (
                    "stages",
                    obj(Stage::ALL
                        .iter()
                        .map(|&st| {
                            let h = hists.map(|l| l.stage(st));
                            (st.name(), stage_json(h))
                        })
                        .collect()),
                ),
            ])
        }));
        let unmapped = arr(topo.iter().flat_map(|t| {
            t.lanes
                .iter()
                .enumerate()
                .filter(|(_, m)| m.is_none())
                .map(|(i, _)| num(i as f64))
        }));
        let rings = arr(self.rings.lock().iter().map(|(label, ring)| {
            obj(vec![
                ("label", s(label)),
                ("depth", num(ring.depth() as f64)),
                ("in_flight", num(ring.in_flight() as f64)),
            ])
        }));
        let stats_json = obj(vec![
            ("admitted", num(stats.admitted as f64)),
            ("lane_busy", num(stats.lane_busy as f64)),
            ("group_busy", num(stats.group_busy as f64)),
            ("invalid", num(stats.invalid as f64)),
            ("no_lane", num(stats.no_lane as f64)),
            ("shed", num(stats.shed as f64)),
            ("responses", num(stats.responses as f64)),
            ("rounds", num(stats.rounds as f64)),
            ("coalesced_rounds", num(stats.coalesced_rounds as f64)),
            ("round_errors", num(stats.round_errors as f64)),
            ("idle_naps_avoided", num(stats.idle_naps_avoided as f64)),
            ("ctrl_ops", num(stats.ctrl_ops as f64)),
        ]);
        let lane_rejects = arr(stats.lane_reject_rows().into_iter().map(|(lane, r)| {
            obj(vec![
                ("lane", num(lane as f64)),
                ("busy", num(r.busy as f64)),
                ("shed", num(r.shed as f64)),
            ])
        }));
        let metrics = self.metrics.lock().as_ref().map(|hub| {
            let m = hub.read();
            obj(vec![
                ("completed_requests", num(m.completed_requests as f64)),
                ("slo_violations", num(m.slo_violations as f64)),
                ("rounds", num(m.round_latency.count() as f64)),
                (
                    "round_p99_s",
                    m.round_p99().map(num).unwrap_or(Json::Null),
                ),
                (
                    "request_p50_s",
                    finite(m.request_latency.p50()),
                ),
                (
                    "request_p99_s",
                    finite(m.request_latency.p99()),
                ),
            ])
        });
        let recorder = obj(vec![
            ("recorded", num(self.recorder.recorded() as f64)),
            ("retained", num(self.recorder.snapshot().len() as f64)),
            (
                "last_dump",
                self.recorder
                    .last_dump()
                    .map(|d| s(&d.reason))
                    .unwrap_or(Json::Null),
            ),
        ]);
        obj(vec![
            ("epoch", num(topo.map(|t| t.epoch as f64).unwrap_or(0.0))),
            ("parts", num(topo.map(|t| t.parts as f64).unwrap_or(1.0))),
            ("lanes", lanes),
            ("unmapped", unmapped),
            ("rings", rings),
            ("stats", stats_json),
            ("lane_rejects", lane_rejects),
            ("metrics", metrics.unwrap_or(Json::Null)),
            ("recorder", recorder),
        ])
    }
}

/// One stage histogram as JSON (`null` percentiles while empty; a lane
/// with no folded responses yet reports zero counts).
fn stage_json(h: Option<&Hist>) -> Json {
    let Some(h) = h else {
        return obj(vec![("count", num(0.0)), ("sum_ns", num(0.0))]);
    };
    obj(vec![
        ("count", num(h.count() as f64)),
        ("sum_ns", num(h.sum_ns() as f64)),
        ("mean_ns", h.mean_ns().map(num).unwrap_or(Json::Null)),
        ("p50_ns", h.p50_ns().map(|v| num(v as f64)).unwrap_or(Json::Null)),
        ("p95_ns", h.p95_ns().map(|v| num(v as f64)).unwrap_or(Json::Null)),
        ("p99_ns", h.p99_ns().map(|v| num(v as f64)).unwrap_or(Json::Null)),
    ])
}

/// NaN-safe number (empty `Latencies` percentiles are NaN, which JSON
/// cannot carry).
fn finite(v: f64) -> Json {
    if v.is_finite() {
        num(v)
    } else {
        Json::Null
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(seq: u64) -> Event {
        Event { seq, t_ns: seq * 10, kind: EventKind::RoundStart { part: 0 } }
    }

    #[test]
    fn ring_keeps_exactly_the_last_cap_events_in_order() {
        let mut r = EventRing::with_cap(4);
        assert!(r.is_empty());
        for i in 0..10 {
            r.push(ev(i));
        }
        assert_eq!(r.len(), 4);
        let seqs: Vec<u64> = r.events().iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9], "wrapped ring must hold the newest, oldest first");
        // below capacity: everything retained
        let mut small = EventRing::with_cap(8);
        for i in 0..3 {
            small.push(ev(i));
        }
        assert_eq!(small.events().len(), 3);
    }

    #[test]
    fn default_ring_carries_the_operating_cap() {
        // Sharded::new builds shards via Default — the operating cap
        // MUST live there, or production rings would be cap-1
        assert_eq!(EventRing::default().cap(), DEFAULT_EVENT_CAP);
        assert_eq!(EventRing::with_cap(0).cap(), 1, "cap clamps to 1");
    }

    #[test]
    fn merged_rings_equal_the_global_last_cap() {
        // interleave one global seq stream across two shards, merge:
        // the result must be exactly the newest `cap` of the union
        let mut a = EventRing::with_cap(6);
        let mut b = EventRing::with_cap(6);
        for i in 0..40u64 {
            if i % 3 == 0 { &mut a } else { &mut b }.push(ev(i));
        }
        let mut merged = EventRing::with_cap(6);
        Shardable::merge_from(&mut merged, &a);
        Shardable::merge_from(&mut merged, &b);
        let seqs: Vec<u64> = merged.events().iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![34, 35, 36, 37, 38, 39]);
    }

    #[test]
    fn recorder_orders_events_across_handles_and_dumps() {
        let rec = FlightRecorder::new(2);
        let (h1, h2) = (rec.handle(), rec.handle());
        h1.record(EventKind::RoundStart { part: 0 });
        h2.record(EventKind::Reject { code: RejectCode::Busy, lane: 3 });
        h1.record(EventKind::RoundEnd { lane: 1, lanes_served: 2, responses: 8 });
        let evs = rec.snapshot();
        assert_eq!(evs.len(), 3);
        let seqs: Vec<u64> = evs.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![0, 1, 2], "merged snapshot is in global order");
        assert!(rec.last_dump().is_none());
        rec.dump_now("test trigger");
        let d = rec.last_dump().expect("dump stored");
        assert_eq!(d.reason, "test trigger");
        assert_eq!(d.events.len(), 3);
    }

    #[test]
    fn tracer_folds_telescoping_stamps_exactly() {
        use std::time::Duration;
        let hub = ObsHub::new(1);
        let t = hub.tracer();
        let t0 = Instant::now();
        let st = Stamps {
            arrived: Some(t0),
            picked: Some(t0 + Duration::from_nanos(100)),
            exec_start: Some(t0 + Duration::from_nanos(250)),
            exec_end: Some(t0 + Duration::from_nanos(1_250)),
            completed: Some(t0 + Duration::from_nanos(1_400)),
        };
        t.fold_stamps(2, &st, t0 + Duration::from_nanos(1_500));
        let core = hub.stages();
        let lane = core.lane(2).expect("lane 2 folded");
        assert_eq!(lane.stage(Stage::Queue).sum_ns(), 100);
        assert_eq!(lane.stage(Stage::Pack).sum_ns(), 150);
        assert_eq!(lane.stage(Stage::Execute).sum_ns(), 1_000);
        assert_eq!(lane.stage(Stage::Scatter).sum_ns(), 150);
        assert_eq!(lane.stage(Stage::Write).sum_ns(), 100);
        // the first four stages telescope to completed - arrived
        let e2e: u64 =
            [Stage::Queue, Stage::Pack, Stage::Execute, Stage::Scatter]
                .iter()
                .map(|&s| lane.stage(s).sum_ns())
                .sum();
        assert_eq!(e2e, 1_400);
        // lanes below 2 exist but are empty; a missing stamp folds nothing
        assert!(core.lane(0).unwrap().stage(Stage::Queue).is_empty());
        t.fold_stamps(0, &Stamps::default(), Instant::now());
        assert!(hub.stages().lane(0).unwrap().stage(Stage::Queue).is_empty());
    }

    #[test]
    fn hub_answers_every_pending_query_once() {
        let hub = ObsHub::new(1);
        let stats = IngressStats { admitted: 7, responses: 7, rounds: 3, ..Default::default() };
        assert_eq!(hub.answer(&stats, None), 0, "no queries, no work");
        let (q1, q2) = (FrameQueue::new(), FrameQueue::new());
        hub.enqueue_query(11, q1.clone());
        hub.enqueue_query(12, q2.clone());
        assert!(hub.has_queries());
        assert_eq!(hub.answer(&stats, None), 2);
        assert!(!hub.has_queries());
        let Some(Frame::ObsReport { id, json }) = q1.try_pop() else {
            panic!("query 11 must be answered with a report");
        };
        assert_eq!(id, 11);
        let v = Json::parse(&json).expect("report is valid JSON");
        assert_eq!(v.get("stats").get("admitted").as_usize(), Some(7));
        assert_eq!(v.get("stats").get("rounds").as_usize(), Some(3));
        let Some(Frame::ObsReport { id, .. }) = q2.try_pop() else {
            panic!("query 12 must be answered too");
        };
        assert_eq!(id, 12);
    }

    #[test]
    fn report_includes_gauges_rings_and_recorder_state() {
        use crate::coordinator::arena::Layout;
        let hub = ObsHub::new(1);
        hub.publish_gauge(LaneGauge {
            global: 4,
            part: 1,
            local: 0,
            life: "live",
            weight: 3,
            deficit: -65536,
            boost_ns: 1_000_000,
            pending: 2,
            round_p99_s: Some(0.004),
        });
        let ring = Arc::new(ArenaRing::pair(Layout::Batch, 2, &[4]).unwrap());
        hub.track_ring("fleet-a", Arc::clone(&ring));
        hub.rec_handle().record(EventKind::RoundStart { part: 0 });
        let r = hub.report(&IngressStats::default(), None);
        let lane = r.get("lanes").idx(0);
        assert_eq!(lane.get("global").as_usize(), Some(4));
        assert_eq!(lane.get("deficit").as_i64(), Some(-65536));
        assert_eq!(lane.get("stages").get("queue").get("count").as_usize(), Some(0));
        let rj = r.get("rings").idx(0);
        assert_eq!(rj.get("label").as_str(), Some("fleet-a"));
        assert_eq!(rj.get("in_flight").as_usize(), Some(0));
        assert_eq!(r.get("recorder").get("recorded").as_usize(), Some(1));
        assert_eq!(r.get("metrics"), &Json::Null, "no metrics hub attached");
        // dropping the gauge removes the lane from the next report
        hub.drop_gauge(4);
        let empty = hub.report(&IngressStats::default(), None);
        assert_eq!(empty.get("lanes").as_arr().unwrap().len(), 0);
    }
}
