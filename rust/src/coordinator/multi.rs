//! `MultiServer`: several fleets served as tenants of one machine.
//!
//! The paper evaluates many merged fleets per GPU (§5), but PR 1's
//! serving loop was single-tenant: one [`Server`] per fleet, and —
//! because every fleet lazily spawned its own [`WorkerPool`] — one
//! thread set per fleet, so an M1-fleet plus an M2-fleet cost M1+M2
//! workers on a machine with far fewer cores.
//!
//! `MultiServer` fixes both:
//! - **per-fleet lanes** — each fleet keeps its own router/batcher
//!   ([`Server`]) with independent queues, strategy, and metrics;
//! - **QoS scheduling across fleets** — lane selection is delegated to
//!   an [`QosScheduler`]: weighted deficit round-robin over round-ready
//!   lanes plus an SLO-deadline boost (a lane whose oldest queued
//!   request is within ε of its [`LaneQos::slo`] preempts the WDRR
//!   order, dispatching a padded round early rather than missing the
//!   deadline). Lanes registered with [`MultiServer::add_lane`] get
//!   `LaneQos::default()` — weight 1 and a far-away SLO — which
//!   degenerates to exactly the old fair round-robin;
//! - **one shared `WorkerPool`** — load every fleet with
//!   [`Fleet::load_with_pool`] and a single
//!   [`WorkerPool::machine_sized`] handle, and all Concurrent/Hybrid
//!   rounds dispatch onto one thread set sized to the machine instead
//!   of one pool per fleet;
//! - **cross-fleet round coalescing** — lanes with the same coalesce
//!   key (model family, request shape, slot count — see
//!   [`super::coalesce`]) can be registered as a *coalesce group*
//!   ([`MultiServer::add_coalesce_group`] /
//!   [`MultiServer::auto_coalesce`]): whenever the QoS pick lands on a
//!   member and at least two members hold queued work, ONE merged round
//!   packs every member's queue fronts into the group executor's
//!   megabatch (`arena::SlotMap` remaps lane-local slots to group
//!   slots) and the outputs scatter back through each lane's own
//!   response routing and metrics. An SLO-**urgent** pick always
//!   dispatches solo on the lane's own executor — a padded group-sized
//!   megabatch would spend the deadline slack on lanes that have
//!   plenty. A failed merged round requeues every member's requests in
//!   their original FIFO positions, exactly like a failed solo round.
//!
//! **Elastic topology (ADR-005):** lanes are no longer fixed at
//! startup. Every lane has a [`LaneLife`] — `Live` lanes admit and
//! dispatch; [`MultiServer::begin_retire`] turns one `Draining` (stops
//! admitting, keeps dispatching until its queues empty through the
//! normal QoS path); [`MultiServer::finish_retire`] excises a drained
//! lane from its coalesce group's `SlotMap` and the QoS table, leaving
//! a `Retired` slot that [`MultiServer::install_lane`] may reuse for a
//! future tenant (with fresh QoS credit — retired deficit/debt never
//! leaks to the reuser). Coalesce-group membership is **elastic**: the
//! group executor keeps its compiled width while the `SlotMap` grows
//! and shrinks with the members, so merged rounds of the survivors
//! continue across churn (unused megabatch windows pad).
//! [`MultiServer::swap_lane_model`] hot-swaps one lane's weights
//! between rounds — the FusedInf on-demand pattern — on both the
//! lane's own executor and its group-megabatch window. The live
//! control plane driving these from outside the dispatch thread is
//! [`super::control`].
//!
//! Note on round overlap: one `MultiServer` dispatches lanes one at a
//! time (`dispatch_next` is `&mut self`), so it does NOT overlap
//! NETFUSE rounds by itself. Overlap comes from **sharding dispatch**:
//! [`ParallelDispatcher`] partitions the lanes into *lane groups* (a
//! coalesce group, or a standalone lane) and gives each group its own
//! `MultiServer` — its own queues and [`QosScheduler`] — so one
//! dispatch thread per group packs/stages/executes concurrently, all
//! sharing ONE [`WorkerPool`] and reserving megabatch slots from the
//! fleet [`ArenaRing`]s (ring depth bounds the overlap).
//! `benches/multi_fleet.rs` measures the two-deep arena win and
//! `benches/parallel_dispatch.rs` the N-thread dispatch win. The async
//! ingress feeding these types from outside the dispatch thread lives
//! in [`crate::ingress`] (`IngressBridge` + `run_dispatch`, or
//! `run_dispatch_parallel` for the sharded form;
//! `run_dispatch_elastic` adds the control plane).
//!
//! Like [`Server`], the types are generic over [`RoundExecutor`] so the
//! scheduling logic is testable without artifacts.
//!
//! [`Fleet::load_with_pool`]: super::service::Fleet::load_with_pool
//! [`WorkerPool`]: super::pool::WorkerPool
//! [`WorkerPool::machine_sized`]: super::pool::WorkerPool::machine_sized
//! [`ArenaRing`]: super::arena::ArenaRing

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::ingress::qos::{LaneCharge, LaneQos, LaneSnapshot, QosScheduler};
use crate::tensor::Tensor;
use crate::util::lock::{LockRank, OrderedRwLock};
use crate::util::shard::ShardHandle;

use super::arena::SlotMap;
use super::coalesce::{plan_group, CoalesceKey};
use super::metrics::{MetricsCore, MetricsHub};
use super::request::{Request, Response};
use super::server::{Admit, Server, ServerConfig};
use super::service::{Fleet, RoundExecutor};
use super::strategy::StrategyKind;

/// One registered coalesce group: the group-level executor (for real
/// fleets, the fused program compiled at its construction-time total
/// slot count), the member lanes in megabatch-window order, and the
/// slot remap between the two. Membership is elastic: `members` and
/// `map` shrink/grow under churn while `exec` keeps its compiled
/// width — `map.total() <= exec.m()`, and megabatch slots beyond the
/// current members pad.
struct Group<'f, E: RoundExecutor> {
    exec: &'f E,
    members: Vec<usize>,
    map: SlotMap,
    /// uniform member window width (slots per member) — fixed for the
    /// group's whole life even as membership churns, so window
    /// arithmetic never depends on which members remain
    member_m: usize,
    rounds: u64,
    responses: u64,
}

/// Cumulative accounting for one coalesce group.
#[derive(Debug, Clone, Copy, Default)]
pub struct GroupStats {
    /// merged rounds dispatched through the group executor
    pub rounds: u64,
    /// responses those merged rounds produced (across all members)
    pub responses: u64,
}

/// Lifecycle of one lane slot (ADR-005).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LaneLife {
    /// admitting and dispatching
    Live,
    /// quiescing: no longer admitting, still dispatching until its
    /// queues drain through the normal QoS path
    Draining,
    /// excised from its group and the QoS table; the slot is inert and
    /// reusable by a future [`MultiServer::install_lane`]
    Retired,
}

/// What one [`MultiServer::dispatch_next`] did.
#[derive(Debug, Clone, Copy)]
pub struct Dispatched {
    /// the lane the QoS scheduler picked (and charged)
    pub lane: usize,
    /// responses appended — for a coalesced round these span every
    /// served member lane, not just `lane`
    pub responses: usize,
    /// lanes whose requests this round served: 1 for a solo round,
    /// >= 2 for a coalesced group round
    pub lanes_served: usize,
    /// the pick came from the SLO boost (solo, possibly padded round)
    pub urgent: bool,
}

/// Multi-tenant serving front end: one [`Server`] lane per fleet,
/// QoS-scheduled (WDRR + SLO boost) round dispatch across lanes, with
/// optional cross-fleet round coalescing and runtime lane churn.
pub struct MultiServer<'f, E: RoundExecutor = Fleet> {
    lanes: Vec<Server<'f, E>>,
    sched: QosScheduler,
    /// registered coalesce groups (disjoint member sets)
    groups: Vec<Group<'f, E>>,
    /// lane -> its group, parallel to `lanes`
    group_of: Vec<Option<usize>>,
    /// lane lifecycle, parallel to `lanes`
    life: Vec<LaneLife>,
    /// last weight version swapped onto each lane (0 = factory
    /// weights), parallel to `lanes`. Needed because a lane's group
    /// megabatch window MOVES when membership churns — the window's
    /// version must be re-stamped wherever the lane lands.
    swap_tag: Vec<u64>,
    /// cached metrics sink so lanes installed at runtime mirror into
    /// the same shard the construction-time lanes were attached to
    metrics_sink: Option<ShardHandle<MetricsCore>>,
    /// merged-round output scratch, reused across coalesced rounds
    group_outs: Vec<Option<Tensor>>,
    /// per-round served-lane charge scratch, reused across dispatches
    charges: Vec<LaneCharge>,
    /// the lane whose round most recently failed (set by
    /// [`MultiServer::dispatch_next`] on its error paths, consumed by
    /// [`MultiServer::take_failed_lane`]) — the dispatch loop's failure
    /// cooldown needs to know WHICH lane to back off from, and the
    /// `Result` error type carries no lane
    last_failed_lane: Option<usize>,
    /// per-lane failure cooldown deadline (ADR-007), parallel to
    /// `lanes`: while in the future, the lane is invisible to QoS
    /// selection and the deadline scan — its requeued work waits out
    /// the cooldown instead of busy-spinning the dispatch loop
    cooldown: Vec<Option<Instant>>,
}

impl<'f, E: RoundExecutor> Default for MultiServer<'f, E> {
    fn default() -> Self {
        Self::new()
    }
}

fn snapshot<E: RoundExecutor>(lane: &Server<'_, E>) -> LaneSnapshot {
    LaneSnapshot {
        ready: lane.round_ready(),
        pending: lane.pending(),
        oldest_wait: lane.oldest_wait(),
    }
}

/// [`snapshot`] with the failure cooldown applied (ADR-007): a lane
/// cooling until after `now` reads as neither round-ready nor
/// boost-eligible — selection skips it and the deadline scan does not
/// pin `next_due_in` to zero on its requeued work — while its real
/// `pending` stays visible so WDRR replenish bookkeeping never mistakes
/// it for an idle (credit-resetting) lane.
fn snapshot_gated<E: RoundExecutor>(
    lane: &Server<'_, E>,
    cooling_until: Option<Instant>,
    now: Instant,
) -> LaneSnapshot {
    let mut s = snapshot(lane);
    if cooling_until.is_some_and(|t| t > now) {
        s.ready = false;
        s.oldest_wait = None;
    }
    s
}

impl<'f, E: RoundExecutor> MultiServer<'f, E> {
    pub fn new() -> MultiServer<'f, E> {
        Self::with_boost_margin(QosScheduler::DEFAULT_BOOST_MARGIN)
    }

    /// `boost_margin` is the scheduler's default ε: how close to its
    /// SLO a lane's oldest wait may get before the lane preempts WDRR.
    /// Individual lanes can override it per lane via
    /// [`LaneQos::with_boost_margin`] at `add_lane_qos` time.
    pub fn with_boost_margin(eps: Duration) -> MultiServer<'f, E> {
        MultiServer {
            lanes: Vec::new(),
            sched: QosScheduler::new(eps),
            groups: Vec::new(),
            group_of: Vec::new(),
            life: Vec::new(),
            swap_tag: Vec::new(),
            metrics_sink: None,
            group_outs: Vec::new(),
            charges: Vec::new(),
            last_failed_lane: None,
            cooldown: Vec::new(),
        }
    }

    /// Register one fleet as a tenant with default QoS (weight 1, no
    /// effective SLO — plain fair round-robin); returns its lane index
    /// (the handle used by [`MultiServer::offer`]).
    pub fn add_lane(&mut self, fleet: &'f E, cfg: ServerConfig) -> usize {
        self.add_lane_qos(fleet, cfg, LaneQos::default())
    }

    /// Register one fleet as a tenant with an explicit [`LaneQos`]
    /// (WDRR weight + SLO). The lane's metrics count violations of
    /// `qos.slo` from here on.
    pub fn add_lane_qos(&mut self, fleet: &'f E, cfg: ServerConfig, qos: LaneQos) -> usize {
        let mut server = Server::new(fleet, cfg);
        server.metrics.slo = Some(qos.slo.as_secs_f64());
        if let Some(sink) = &self.metrics_sink {
            server.attach_metrics_sink(sink.clone());
        }
        self.lanes.push(server);
        self.group_of.push(None);
        self.life.push(LaneLife::Live);
        self.swap_tag.push(0);
        self.cooldown.push(None);
        self.sched.add_lane(qos)
    }

    /// Mirror every lane's metrics into one [`MetricsHub`] shard — the
    /// shard of the (single) thread dispatching this `MultiServer`.
    /// Lane-local [`Server::metrics`] views are unaffected. The sink is
    /// remembered, so lanes installed later ([`MultiServer::install_lane`])
    /// mirror into the same shard.
    ///
    /// [`MetricsHub`]: super::metrics::MetricsHub
    pub fn attach_metrics_sink(&mut self, sink: &ShardHandle<MetricsCore>) {
        for lane in &mut self.lanes {
            lane.attach_metrics_sink(sink.clone());
        }
        self.metrics_sink = Some(sink.clone());
    }

    /// Register `members` as a coalesce group executing merged rounds
    /// on `exec`. Validation (same model family, request shape, and
    /// slot count across members; `exec` sized to exactly the members'
    /// total — see [`super::coalesce::plan_group`]) rejects any lane
    /// set that could not share a megabatch; a lane can belong to at
    /// most one group. Returns the group handle.
    ///
    /// Construction-time validation is strict (`exec` exactly full);
    /// afterwards membership is elastic — removals shrink the
    /// `SlotMap` below `exec`'s width and installs may grow it back.
    // LINT-ALLOW(member indices are validated against the lane table at entry)
    pub fn add_coalesce_group(&mut self, exec: &'f E, members: &[usize]) -> Result<usize> {
        for (a, &l) in members.iter().enumerate() {
            if l >= self.lanes.len() {
                bail!("no lane {l} (have {})", self.lanes.len());
            }
            if self.life[l] != LaneLife::Live {
                bail!("lane {l} is not live ({:?})", self.life[l]);
            }
            if self.group_of[l].is_some() {
                bail!("lane {l} already belongs to a coalesce group");
            }
            if members[..a].contains(&l) {
                bail!("lane {l} listed twice in one coalesce group");
            }
        }
        let execs: Vec<&E> = members.iter().map(|&l| self.lanes[l].fleet()).collect();
        let map = plan_group(exec, &execs)?;
        let member_m = self.lanes[members[0]].fleet().m();
        let g = self.groups.len();
        for &l in members {
            self.group_of[l] = Some(g);
        }
        self.groups.push(Group {
            exec,
            members: members.to_vec(),
            map,
            member_m,
            rounds: 0,
            responses: 0,
        });
        Ok(g)
    }

    /// Form a coalesce group automatically: scan registered lanes (in
    /// lane order) for ungrouped live ones whose coalesce key — (model
    /// family, request shape, slot count) — matches `exec`'s family and
    /// shape, taking the first matching lane's slot count as the
    /// group's, until `exec`'s capacity is filled. Lanes with a
    /// mismatched key are skipped, never coalesced. Returns `Ok(None)`
    /// when fewer than two matching lanes exist or their total does not
    /// fill `exec` exactly.
    // LINT-ALLOW(candidate lanes are enumerated from the lane table itself)
    pub fn auto_coalesce(&mut self, exec: &'f E) -> Result<Option<usize>> {
        let want = CoalesceKey::of(exec);
        let mut members: Vec<usize> = Vec::new();
        let mut lane_m: Option<usize> = None;
        for (l, lane) in self.lanes.iter().enumerate() {
            if self.group_of[l].is_some() || self.life[l] != LaneLife::Live {
                continue;
            }
            let k = CoalesceKey::of(lane.fleet());
            if k.family != want.family || k.request_shape != want.request_shape {
                continue;
            }
            match lane_m {
                None => lane_m = Some(k.slots),
                Some(m) if m != k.slots => continue,
                Some(_) => {}
            }
            if (members.len() + 1) * lane_m.unwrap() > want.slots {
                break; // group executor full
            }
            members.push(l);
        }
        match lane_m {
            Some(m) if members.len() >= 2 && members.len() * m == want.slots => {
                Ok(Some(self.add_coalesce_group(exec, &members)?))
            }
            _ => Ok(None),
        }
    }

    /// Number of registered coalesce groups.
    pub fn coalesce_groups(&self) -> usize {
        self.groups.len()
    }

    /// Member lanes of group `g`, in megabatch-window order.
    // LINT-ALLOW(group ids are handed out by add_coalesce_group and never removed)
    pub fn group_members(&self, g: usize) -> &[usize] {
        &self.groups[g].members
    }

    /// Cumulative merged-round accounting for group `g`.
    // LINT-ALLOW(group ids are handed out by add_coalesce_group and never removed)
    pub fn group_stats(&self, g: usize) -> GroupStats {
        GroupStats { rounds: self.groups[g].rounds, responses: self.groups[g].responses }
    }

    /// The coalesce group `lane` belongs to, if any.
    // LINT-ALLOW(lane ids are issued by add_lane; callers pass back what we issued)
    pub fn lane_group(&self, lane: usize) -> Option<usize> {
        self.group_of[lane]
    }

    /// Number of lane SLOTS (live, draining, and retired — retired
    /// slots stay addressable so ids remain stable under churn).
    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Lanes currently in [`LaneLife::Live`].
    pub fn live_lanes(&self) -> usize {
        self.life.iter().filter(|&&l| l == LaneLife::Live).count()
    }

    /// Lifecycle state of lane slot `lane`.
    // LINT-ALLOW(lane ids are issued by add_lane; callers pass back what we issued)
    pub fn lane_life(&self, lane: usize) -> LaneLife {
        self.life[lane]
    }

    /// Per-lane router/batcher (queue state, metrics).
    // LINT-ALLOW(lane ids are issued by add_lane; callers pass back what we issued)
    pub fn lane(&self, lane: usize) -> &Server<'f, E> {
        &self.lanes[lane]
    }

    /// The scheduling contract `lane` was registered with.
    pub fn qos(&self, lane: usize) -> LaneQos {
        self.sched.qos(lane)
    }

    /// `lane`'s current WDRR deficit (scheduler credit). Observability
    /// read (ADR-006): the gauge a dispatch thread publishes between
    /// rounds, and the value the flight recorder stamps on QoS-pick
    /// events.
    pub fn lane_deficit(&self, lane: usize) -> i64 {
        self.sched.deficit(lane)
    }

    /// The effective SLO boost margin ε for `lane` (operator pin,
    /// adaptive estimate, or the scheduler default, in that order) —
    /// published as a gauge (ADR-006).
    pub fn lane_boost_margin(&self, lane: usize) -> Duration {
        self.sched.lane_boost_margin(lane)
    }

    /// The adaptive ε currently derived for `lane` from its observed
    /// round tails (ADR-007), `None` until the lane has completed a
    /// round. A pinned [`LaneQos::boost_margin`] overrides it.
    pub fn lane_adaptive_margin(&self, lane: usize) -> Option<Duration> {
        self.sched.adaptive_margin(lane)
    }

    /// Close the ε control loop (ADR-007): derive each live lane's SLO
    /// boost margin from its observed round-time p99, EWMA-smoothed
    /// (α = 1/4: a shift in the tail settles within a handful of
    /// refreshes without one outlier round yanking the margin) and
    /// clamped to `[min_eps, slo/2]` — the floor keeps a fast lane from
    /// shrinking its window below scheduling resolution, the ceiling
    /// keeps a slow lane from going permanently "urgent" and starving
    /// WDRR. Lanes with no completed round yet keep resolving to the
    /// static default; operator pins (`LaneQos::with_boost_margin`)
    /// always win regardless of what this installs. Called by the
    /// dispatch loops between rounds (same cadence as gauge refresh).
    // LINT-ALLOW(iterates 0..lanes.len() over the scheduler's own tables)
    pub fn refresh_adaptive_eps(&mut self, min_eps: Duration) {
        for lane in 0..self.lanes.len() {
            if self.life[lane] == LaneLife::Retired {
                continue;
            }
            let Some(p99) = self.lanes[lane].metrics.round_p99() else {
                continue;
            };
            let slo = self.sched.qos(lane).slo;
            let ceil = slo / 2;
            let floor = min_eps.min(ceil); // keep floor <= ceiling for tiny SLOs
            let target = Duration::from_secs_f64(p99.max(0.0)).clamp(floor, ceil);
            let next = match self.sched.adaptive_margin(lane) {
                Some(prev) => Duration::from_secs_f64(
                    prev.as_secs_f64() * 0.75 + target.as_secs_f64() * 0.25,
                )
                .clamp(floor, ceil),
                None => target,
            };
            self.sched.set_adaptive_margin(lane, Some(next));
        }
    }

    /// Queue-wait projection for one more request on `lane` (ADR-007):
    /// the rounds the current backlog needs (`ceil(pending / m)`) times
    /// the lane's observed round-time p99. `None` while the lane has no
    /// observed rounds or no backlog — admission control never sheds on
    /// a cold or empty lane (it has no evidence the wait is doomed).
    // LINT-ALLOW(guarded by the explicit lane bounds check at entry)
    pub fn projected_wait(&self, lane: usize) -> Option<Duration> {
        if lane >= self.lanes.len() || self.life[lane] != LaneLife::Live {
            return None;
        }
        let pending = self.lanes[lane].pending();
        if pending == 0 {
            return None;
        }
        let p99 = self.lanes[lane].metrics.round_p99()?;
        let m = self.lanes[lane].fleet().m().max(1);
        let rounds_ahead = pending.div_ceil(m);
        Some(Duration::from_secs_f64(p99.max(0.0) * rounds_ahead as f64))
    }

    /// Admission-control decision for `lane` (ADR-007): `true` when the
    /// projected queue wait already exceeds the lane's SLO, i.e. a
    /// request admitted now is doomed to miss its deadline before its
    /// round even starts — the bridge sheds it with a typed
    /// `Reject{Shed}` instead of letting it consume a slot and QoS
    /// credit.
    pub fn should_shed(&self, lane: usize) -> bool {
        match self.projected_wait(lane) {
            Some(wait) => wait > self.sched.qos(lane).slo,
            None => false,
        }
    }

    /// The lane whose round most recently failed, consumed (one-shot:
    /// the next call answers `None` until another round fails). The
    /// dispatch loop reads this right after a
    /// [`MultiServer::dispatch_next`] error to know which lane to place
    /// in failure cooldown.
    pub fn take_failed_lane(&mut self) -> Option<usize> {
        self.last_failed_lane.take()
    }

    /// Place `lane` in failure cooldown until `until` (ADR-007): it is
    /// skipped by QoS selection and the deadline scan until then — its
    /// requeued work waits out the cooldown instead of being re-picked
    /// the very next iteration — while admission and its queues are
    /// untouched. Bounded by construction: the caller passes a short
    /// deadline, and expiry is purely time-based (no reset required).
    // LINT-ALLOW(lane ids are issued by add_lane; callers pass back what we issued)
    pub fn set_lane_cooldown(&mut self, lane: usize, until: Instant) {
        self.cooldown[lane] = Some(until);
    }

    /// Whether `lane` is currently in failure cooldown.
    // LINT-ALLOW(lane ids are issued by add_lane; callers pass back what we issued)
    pub fn lane_cooling(&self, lane: usize) -> bool {
        self.cooldown[lane].is_some_and(|t| t > Instant::now())
    }

    // -----------------------------------------------------------------
    // elastic lane lifecycle (ADR-005)
    // -----------------------------------------------------------------

    /// Install a tenant at runtime: reuse the first [`LaneLife::Retired`]
    /// slot if one exists (its QoS state was fully torn down at
    /// retirement, so the reuser starts from exactly the carried
    /// `deficit` — use 0 for a fresh tenant, or a migrated lane's
    /// carried deficit so weighted shares hold across a rebalance),
    /// else append a new slot. Then try to attach the lane to the first
    /// existing coalesce group with a matching key and free megabatch
    /// capacity, so future rounds merge. Returns
    /// `(lane, attached group)`.
    ///
    /// Call strictly between rounds (the control plane's dispatch-thread
    /// command path guarantees this); sibling lanes' queues, deficits,
    /// and in-flight state are untouched.
    // LINT-ALLOW(the reused slot index is found by scanning the lane table itself)
    pub fn install_lane(
        &mut self,
        exec: &'f E,
        cfg: ServerConfig,
        qos: LaneQos,
        deficit: i64,
    ) -> Result<(usize, Option<usize>)> {
        let mut server = Server::new(exec, cfg);
        server.metrics.slo = Some(qos.slo.as_secs_f64());
        if let Some(sink) = &self.metrics_sink {
            server.attach_metrics_sink(sink.clone());
        }
        let local = match self.life.iter().position(|&l| l == LaneLife::Retired) {
            Some(i) => {
                debug_assert!(self.group_of[i].is_none(), "retired lane left grouped");
                self.lanes[i] = server;
                self.sched.restore_lane(i, qos, deficit);
                self.life[i] = LaneLife::Live;
                self.swap_tag[i] = 0;
                self.cooldown[i] = None;
                i
            }
            None => {
                self.lanes.push(server);
                self.group_of.push(None);
                self.life.push(LaneLife::Live);
                self.swap_tag.push(0);
                self.cooldown.push(None);
                let i = self.sched.add_lane_carrying(qos, deficit);
                debug_assert_eq!(i + 1, self.lanes.len(), "scheduler/lane slot drift");
                i
            }
        };

        // auto-attach: first key-compatible group with free capacity
        // (same family + request shape, same member width, and the
        // group executor has at least one more member window to give)
        let key = CoalesceKey::of(exec);
        let mut attached = None;
        {
            let groups = &mut self.groups;
            let group_of = &mut self.group_of;
            for (g, group) in groups.iter_mut().enumerate() {
                let gk = CoalesceKey::of(group.exec);
                if gk.family != key.family
                    || gk.request_shape != key.request_shape
                    || key.slots != group.member_m
                    || (group.members.len() + 1) * group.member_m > group.exec.m()
                {
                    continue;
                }
                group.members.push(local);
                group.map = SlotMap::uniform(group.members.len(), group.member_m)?;
                group_of[local] = Some(g);
                attached = Some(g);
                break;
            }
        }
        if let Some(g) = attached {
            // membership changed every member's window start is stable
            // (append-only), but the NEW member's window may hold a
            // previously-retired member's swapped weights — re-stamp
            self.restamp_group_versions(g)?;
        }
        Ok((local, attached))
    }

    /// Begin quiescing `lane`: it stops admitting ([`MultiServer::offer`]
    /// now refuses) but keeps dispatching through the normal QoS path —
    /// including merged group rounds — until its queues empty. Siblings
    /// are untouched.
    // LINT-ALLOW(guarded by the explicit lane bounds check at entry)
    pub fn begin_retire(&mut self, lane: usize) -> Result<()> {
        if lane >= self.lanes.len() || self.life[lane] != LaneLife::Live {
            bail!(
                "lane {lane} is not live (have {} slots)",
                self.lanes.len()
            );
        }
        self.life[lane] = LaneLife::Draining;
        Ok(())
    }

    /// True when a [`LaneLife::Draining`] lane has fully drained and
    /// [`MultiServer::finish_retire`] may excise it. Safe to act on
    /// between rounds: dispatch is synchronous on this thread, so a
    /// lane with `pending() == 0` here has no in-flight round either
    /// (a failed round's requeue restores `pending` before this can be
    /// observed).
    // LINT-ALLOW(guarded by the explicit lane bounds check at entry)
    pub fn retire_ready(&self, lane: usize) -> bool {
        lane < self.lanes.len()
            && self.life[lane] == LaneLife::Draining
            && self.lanes[lane].pending() == 0
    }

    /// Excise a drained lane: remove it from its coalesce group (the
    /// group's `SlotMap` shrinks; surviving members keep merging) and
    /// retire its QoS slot — deficit/debt/boost state is fully torn
    /// down, returned as the lane's **carried deficit** so a rebalance
    /// can hand it to the lane's next home
    /// ([`MultiServer::install_lane`] with the same value). The slot
    /// becomes [`LaneLife::Retired`] and reusable.
    // LINT-ALLOW(guarded by the explicit lane bounds check at entry)
    pub fn finish_retire(&mut self, lane: usize) -> Result<i64> {
        if lane >= self.lanes.len() || self.life[lane] != LaneLife::Draining {
            bail!("lane {lane} is not draining");
        }
        let pending = self.lanes[lane].pending();
        if pending > 0 {
            bail!("lane {lane} still holds {pending} queued requests");
        }
        if let Some(g) = self.group_of[lane].take() {
            let group = &mut self.groups[g];
            group.members.retain(|&l| l != lane);
            // an emptied group keeps a 1-member-shaped placeholder map
            // (SlotMap rejects zero lanes); dispatch never uses it —
            // merged rounds need >= 2 members with work
            let n = group.members.len().max(1);
            group.map = SlotMap::uniform(n, group.member_m)?;
            // surviving members' windows shifted: re-stamp their weight
            // versions onto the group executor's new window layout
            self.restamp_group_versions(g)?;
        }
        self.life[lane] = LaneLife::Retired;
        self.swap_tag[lane] = 0;
        self.cooldown[lane] = None;
        Ok(self.sched.remove_lane(lane))
    }

    /// Hot-swap `lane`'s model weights to version `tag`, between rounds
    /// (FusedInf-style; see [`RoundExecutor::swap_model`]). Swaps BOTH
    /// the lane's own executor (full range — solo and urgent rounds)
    /// and, for a grouped lane, its megabatch window on the group
    /// executor — sibling windows are untouched. Returns the total
    /// bounded pause spent swapping.
    // LINT-ALLOW(guarded by the explicit lane bounds check at entry)
    pub fn swap_lane_model(&mut self, lane: usize, tag: u64) -> Result<Duration> {
        if lane >= self.lanes.len() || self.life[lane] == LaneLife::Retired {
            bail!("no live lane {lane} (have {} slots)", self.lanes.len());
        }
        let m = self.lanes[lane].fleet().m();
        let mut pause = self.lanes[lane].fleet().swap_model(0..m, tag)?;
        if let Some(g) = self.group_of[lane] {
            let group = &self.groups[g];
            let k = group
                .members
                .iter()
                .position(|&l| l == lane)
                .expect("grouped lane is one of its group's members");
            pause += group.exec.swap_model(group.map.slots_of(k), tag)?;
        }
        self.swap_tag[lane] = tag;
        Ok(pause)
    }

    /// Re-apply every member's weight version to its CURRENT megabatch
    /// window on the group executor. Membership churn moves windows
    /// (removal shifts survivors left; install may reuse a departed
    /// member's window), so versions must follow the lanes, not the
    /// slots. Skipped entirely while no member has ever swapped — so
    /// executors without swap support still churn membership freely.
    // LINT-ALLOW(group members are lane-table indices maintained by grouping)
    fn restamp_group_versions(&self, g: usize) -> Result<()> {
        let group = &self.groups[g];
        if group.members.iter().all(|&l| self.swap_tag[l] == 0) {
            return Ok(());
        }
        for (k, &l) in group.members.iter().enumerate() {
            group.exec.swap_model(group.map.slots_of(k), self.swap_tag[l])?;
        }
        Ok(())
    }

    /// Route one request to `lane`'s per-model queues. Only
    /// [`LaneLife::Live`] lanes admit — a draining or retired lane
    /// refuses (the ingress router maps this to a typed
    /// `Reject{NoLane}` frame).
    // LINT-ALLOW(guarded by the explicit lane bounds check at entry)
    pub fn offer(&mut self, lane: usize, req: Request) -> Result<Admit> {
        if lane >= self.lanes.len() || self.life[lane] != LaneLife::Live {
            bail!("no live lane {lane} (have {} slots)", self.lanes.len());
        }
        Ok(self.lanes[lane].offer(req))
    }

    /// Total queued requests across all lanes.
    pub fn pending(&self) -> usize {
        self.lanes.iter().map(|l| l.pending()).sum()
    }

    /// The lane the QoS scheduler would dispatch next: an SLO-urgent
    /// lane first, otherwise the WDRR pick among round-ready lanes.
    /// `None` when nothing is due. Pure — deficits are only charged by
    /// an actual [`MultiServer::dispatch_next`].
    // LINT-ALLOW(snapshot closures index 0..lanes.len())
    pub fn ready_lane(&self) -> Option<usize> {
        let lanes = &self.lanes;
        let cd = &self.cooldown;
        let now = Instant::now();
        self.sched.select(&|i| snapshot_gated(&lanes[i], cd[i], now)).map(|p| p.lane)
    }

    /// How long until some lane becomes due (batching deadline or SLO
    /// boost), `Duration::ZERO` if one already is, `None` when every
    /// queue is empty. This is the longest an ingress loop may block
    /// without risking an idle dispatch thread next to a due round.
    /// Delegates to [`QosScheduler::next_due_in`], whose scan covers
    /// every backlogged lane — including lanes a coalesced round would
    /// serve only as riders, whose boost windows are dispatch triggers
    /// of their own.
    // LINT-ALLOW(snapshot closures index 0..lanes.len())
    pub fn next_due_in(&self) -> Option<Duration> {
        let lanes = &self.lanes;
        let cd = &self.cooldown;
        let now = Instant::now();
        self.sched.next_due_in(
            &|i| snapshot_gated(&lanes[i], cd[i], now),
            &|i| lanes[i].config().max_wait,
        )
    }

    /// Dispatch the next due round (QoS pick), appending its responses
    /// to `responses`. Returns `Some(`[`Dispatched`]`)`, or `None` when
    /// no lane is due yet. An SLO-urgent pick dispatches even if the
    /// lane's round is not batching-ready — the round pads, and it
    /// always runs **solo** on the lane's own executor. A non-urgent
    /// pick on a coalesce-group member with at least one other member
    /// holding work dispatches a **merged** group round instead: every
    /// member's queue fronts pack into one megabatch (members that are
    /// not yet batching-ready ride along — their windows would
    /// otherwise pad), and responses scatter back per lane.
    ///
    /// Deficit charging happens AFTER the round, against what it
    /// actually served: a solo round charges the picked lane one whole
    /// credit (one launch = one round, padded or not — unchanged), and
    /// a merged round charges **every served member** — rider lanes
    /// included — proportionally to the slots each consumed of its own
    /// round capacity ([`QosScheduler::commit_served`]). Before this,
    /// only the picked lane was charged and riders accumulated service
    /// for free, so strict weighted shares drifted at high lane counts.
    ///
    /// A failed round — solo or merged — requeues its requests inside
    /// the owning lane(s) (original FIFO order and wait clocks) and
    /// surfaces the error; the picked lane is still charged a whole
    /// round and the cursor advances past it, so a persistently failing
    /// fleet cannot starve the others.
    // LINT-ALLOW(pick.lane comes from the scheduler, which only yields live table indices)
    pub fn dispatch_next(
        &mut self,
        responses: &mut Vec<Response>,
    ) -> Result<Option<Dispatched>> {
        let pick = {
            let lanes = &self.lanes;
            let cd = &self.cooldown;
            let now = Instant::now();
            match self.sched.select(&|i| snapshot_gated(&lanes[i], cd[i], now)) {
                Some(p) => p,
                None => return Ok(None),
            }
        };
        if !pick.urgent {
            if let Some(g) = self.group_of[pick.lane] {
                let live = self.groups[g]
                    .members
                    .iter()
                    .filter(|&&l| self.lanes[l].pending() > 0)
                    .count();
                if live >= 2 {
                    match self.dispatch_group(g, responses) {
                        Ok((lanes_served, n)) => {
                            let (lanes, sched) = (&self.lanes, &mut self.sched);
                            sched.commit_served(&pick, &self.charges, &|i| {
                                snapshot(&lanes[i])
                            });
                            return Ok(Some(Dispatched {
                                lane: pick.lane,
                                responses: n,
                                lanes_served,
                                urgent: false,
                            }));
                        }
                        Err(e) => {
                            let (lanes, sched) = (&self.lanes, &mut self.sched);
                            sched.commit(&pick, &|i| snapshot(&lanes[i]));
                            self.last_failed_lane = Some(pick.lane);
                            return Err(e);
                        }
                    }
                }
            }
        }
        // solo round: success or failure, the pick costs one whole
        // credit (one launch) and the cursor moves on
        let result = self.lanes[pick.lane].dispatch_into(responses);
        let (lanes, sched) = (&self.lanes, &mut self.sched);
        sched.commit(&pick, &|i| snapshot(&lanes[i]));
        let n = match result {
            Ok(n) => n,
            Err(e) => {
                self.last_failed_lane = Some(pick.lane);
                return Err(e);
            }
        };
        Ok(Some(Dispatched {
            lane: pick.lane,
            responses: n,
            lanes_served: 1,
            urgent: pick.urgent,
        }))
    }

    /// One merged round over group `g`: take every member's queue
    /// fronts, execute the group's megabatch once, scatter the outputs
    /// back through each member's response path. Returns
    /// `(lanes_served, responses)`; the per-member slot consumption is
    /// left in `self.charges` so the caller can charge every served
    /// lane (rider fairness — riders must pay for the service they
    /// receive).
    // LINT-ALLOW(member indices and window offsets are constructed in-bounds by SlotMap)
    fn dispatch_group(
        &mut self,
        g: usize,
        responses: &mut Vec<Response>,
    ) -> Result<(usize, usize)> {
        // field-level borrow split: `groups` is only read while `lanes`
        // and the output scratch are driven through the round phases
        let groups = &self.groups;
        let lanes = &mut self.lanes;
        let outs = &mut self.group_outs;
        let charges = &mut self.charges;
        let group = &groups[g];

        // take: pop each member's fronts into its round scratch. Members
        // with nothing queued still "take" (an empty round) so their
        // megabatch windows pad; they are not counted as served and are
        // not charged.
        let mut lanes_served = 0usize;
        charges.clear();
        for &l in &group.members {
            let taken = lanes[l].take_round();
            if taken > 0 {
                lanes_served += 1;
                charges.push(LaneCharge {
                    lane: l,
                    slots: taken,
                    round_slots: lanes[l].fleet().m(),
                });
            }
        }

        // execute: ONE merged round through the group executor; the
        // `get` closure is the SlotMap remap (group slot -> member
        // lane's local slot). Megabatch slots at or beyond the current
        // membership's total pad (elastic membership may leave the map
        // narrower than the executor's compiled width). Coalescing
        // exists to amortize the merged program's launch, so the group
        // round is always NETFUSE.
        let t0 = Instant::now();
        let run = {
            let lanes = &*lanes;
            let get = |gs: usize| {
                if gs >= group.map.total() {
                    return None; // beyond current members: pad window
                }
                let (k, local) = group.map.locate(gs);
                lanes[group.members[k]].slot_input(local)
            };
            group.exec.run_round_slots(StrategyKind::NetFuse, &get, outs)
        };
        if let Err(e) = run {
            // merged-round failure: every member requeues its own
            // fronts — per-queue FIFO order and wait clocks survive the
            // remap, exactly like a failed solo round
            for &l in &group.members {
                lanes[l].requeue_taken();
            }
            return Err(e);
        }

        // verify the WHOLE merged output before any lane consumes a
        // slot: a short or hole-y result from a misbehaving group
        // executor must requeue every member, not answer some lanes
        // and drop the rest mid-scatter. (`outs` may legitimately be
        // LONGER than the map — the executor answers its compiled
        // width; slots beyond the members' total are padding.)
        let bad = if outs.len() < group.map.total() {
            Some(format!(
                "executor returned {} outputs for {} group slots",
                outs.len(),
                group.map.total()
            ))
        } else {
            (0..group.map.total())
                .find(|&gs| {
                    let (k, local) = group.map.locate(gs);
                    lanes[group.members[k]].slot_input(local).is_some() && outs[gs].is_none()
                })
                .map(|gs| format!("group slot {gs} produced no output for an occupied slot"))
        };
        if let Some(msg) = bad {
            for &l in &group.members {
                lanes[l].requeue_taken();
            }
            bail!("coalesced round: {msg}");
        }

        // scatter: each member completes against its lane-relative
        // window of the merged output. Round time is the merged round's
        // wall time, attributed to every lane that actually held work.
        let t1 = Instant::now();
        let mut n = 0usize;
        for (k, &l) in group.members.iter().enumerate() {
            let window = group.map.slots_of(k);
            let occupied = (0..window.len()).any(|local| lanes[l].slot_input(local).is_some());
            if !occupied {
                continue;
            }
            match lanes[l].complete_round(t0, t1, &mut outs[window], responses) {
                Ok(c) => n += c,
                Err(e) => {
                    // mid-scatter failure (unreachable after the group
                    // verification above, kept as defense): the failing
                    // lane requeued its own round inside complete_round;
                    // members not yet scattered must requeue too or
                    // their taken requests would leak
                    for &rest in &group.members[k + 1..] {
                        lanes[rest].requeue_taken();
                    }
                    return Err(e);
                }
            }
        }
        let group = &mut self.groups[g];
        group.rounds += 1;
        group.responses += n as u64;
        Ok((lanes_served, n))
    }

    /// Dispatch (padded) rounds until every queue on every lane is
    /// empty, appending all responses. Returns the number of responses.
    /// Unlike [`MultiServer::dispatch_next`], this drains lanes whose
    /// rounds are not yet due — it is the shutdown/flush path.
    ///
    /// The flush is **group-aware**: when the round-robin scan lands on
    /// a coalesce-group member and at least one other member still
    /// holds work, the members flush together as ONE merged round, so
    /// even the final partial rounds amortize the merged program's
    /// launch instead of dispatching solo per lane. Draining lanes
    /// flush like any other; retired lanes hold nothing by definition.
    // LINT-ALLOW(iterates 0..lanes.len())
    pub fn drain(&mut self, responses: &mut Vec<Response>) -> Result<usize> {
        let mut total = 0;
        loop {
            // round-robin over lanes with work so the flush stays fair;
            // when no lane holds work (including a lane that emptied
            // between scans) the flush is complete
            let n = self.lanes.len();
            let lane = (0..n)
                .map(|k| (self.sched.cursor() + k) % n)
                .find(|&i| self.lanes[i].pending() > 0);
            let Some(lane) = lane else {
                return Ok(total);
            };
            self.sched.rotate_after(lane);
            if let Some(g) = self.group_of[lane] {
                let live = self.groups[g]
                    .members
                    .iter()
                    .filter(|&&l| self.lanes[l].pending() > 0)
                    .count();
                if live >= 2 {
                    total += self.dispatch_group(g, responses)?.1;
                    continue;
                }
            }
            total += self.lanes[lane].dispatch_into(responses)?;
        }
    }
}

// ---------------------------------------------------------------------------
// parallel dispatch: one thread per lane group
// ---------------------------------------------------------------------------

/// One lane's registration for a [`ParallelDispatcher`]: the executor
/// it dispatches onto, its batching config, and its QoS contract.
pub struct LaneSpec<'f, E: RoundExecutor = Fleet> {
    pub exec: &'f E,
    pub cfg: ServerConfig,
    pub qos: LaneQos,
}

impl<'f, E: RoundExecutor> LaneSpec<'f, E> {
    pub fn new(exec: &'f E, cfg: ServerConfig, qos: LaneQos) -> LaneSpec<'f, E> {
        LaneSpec { exec, cfg, qos }
    }
}

/// One coalesce group's registration for a [`ParallelDispatcher`]:
/// the group-level executor and the member lanes (global lane ids, in
/// megabatch-window order). Validation is [`super::coalesce`]'s, via
/// [`MultiServer::add_coalesce_group`] on the group's partition.
pub struct GroupSpec<'f, E: RoundExecutor = Fleet> {
    pub exec: &'f E,
    pub members: Vec<usize>,
}

impl<'f, E: RoundExecutor> GroupSpec<'f, E> {
    pub fn new(exec: &'f E, members: &[usize]) -> GroupSpec<'f, E> {
        GroupSpec { exec, members: members.to_vec() }
    }
}

/// The routing tables behind [`Topology`], behind one lock.
struct TopoState {
    /// global lane -> owning `(partition, local lane)`; `None` = not
    /// (or no longer) mapped — the router's typed NoLane case. Global
    /// ids are **monotone**: a removed lane's id is never reissued, so
    /// a stale client keeps getting NoLane instead of someone else's
    /// lane.
    local_of: Vec<Option<(usize, usize)>>,
    /// partition -> local lane -> last mapped global id. Grow-only and
    /// kept after unmap: a quiescing lane's drained responses must
    /// still quote the client's wire lane id. A reused local slot gets
    /// overwritten only at its next `map_lane` — after the old lane has
    /// fully drained (the dispatch thread is sequential).
    global_of: Vec<Vec<usize>>,
}

/// One coherent read of the live topology (ADR-005): the routing table
/// as of `epoch`. Epochs advance on every mutation (map, unmap, new
/// partition), so two snapshots with equal epochs are identical.
#[derive(Debug, Clone)]
pub struct TopologySnapshot {
    pub epoch: u64,
    /// global lane -> `Some((partition, local))` while mapped
    pub lanes: Vec<Option<(usize, usize)>>,
    /// number of partitions
    pub parts: usize,
}

/// The lane partition of a [`ParallelDispatcher`]: which partition owns
/// each global lane, and the global id of every partition-local lane.
/// Shared by the router and every dispatch thread — and, since ADR-005,
/// **live**: the tables sit behind a lock with an epoch stamp
/// ([`Topology::epoch`]) bumped on every change, so the control plane
/// can map/unmap lanes under traffic. Readers see each change atomically
/// (a lane is mapped or it is not — never half-routed); the router's
/// per-envelope [`Topology::locate`] is the single admission gate, so an
/// unmapped lane yields a typed NoLane the instant `unmap_lane` returns.
pub struct Topology {
    state: OrderedRwLock<TopoState>,
    epoch: AtomicU64,
}

impl Topology {
    fn new(local_of: Vec<Option<(usize, usize)>>, global_of: Vec<Vec<usize>>) -> Topology {
        Topology {
            state: OrderedRwLock::new(LockRank::Topology, TopoState { local_of, global_of }),
            epoch: AtomicU64::new(0),
        }
    }

    /// Number of partitions (= dispatch threads).
    pub fn parts(&self) -> usize {
        self.state.read().global_of.len()
    }

    /// Number of global lane ids ever issued (mapped or not — ids are
    /// monotone and never reissued).
    pub fn lanes(&self) -> usize {
        self.state.read().local_of.len()
    }

    /// The `(partition, local lane)` owning global lane `lane`, or
    /// `None` for an unknown or unmapped lane id (the router's NoLane
    /// case — removed lanes land here forever).
    pub fn locate(&self, lane: usize) -> Option<(usize, usize)> {
        self.state.read().local_of.get(lane).copied().flatten()
    }

    /// Global id of partition `part`'s local lane `local`. For a local
    /// slot whose lane was removed, this keeps answering the REMOVED
    /// lane's global id until the slot is remapped — exactly what
    /// response routing needs while that lane drains.
    // LINT-ALLOW(routing tables are kept consistent by map/unmap under one lock)
    pub fn global(&self, part: usize, local: usize) -> usize {
        self.state.read().global_of[part][local]
    }

    /// Global lane ids currently mapped to partition `part`, in
    /// local-lane order.
    // LINT-ALLOW(routing tables are kept consistent by map/unmap under one lock)
    pub fn part_lanes(&self, part: usize) -> Vec<usize> {
        let st = self.state.read();
        st.global_of[part]
            .iter()
            .enumerate()
            .filter(|(local, &g)| st.local_of.get(g).copied().flatten() == Some((part, *local)))
            .map(|(_, &g)| g)
            .collect()
    }

    /// The current topology epoch: bumped on every mutation. Two equal
    /// epochs bracket an unchanged routing table.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// One coherent copy of the routing table with its epoch.
    pub fn snapshot(&self) -> TopologySnapshot {
        let st = self.state.read();
        TopologySnapshot {
            epoch: self.epoch.load(Ordering::Acquire),
            lanes: st.local_of.clone(),
            parts: st.global_of.len(),
        }
    }

    fn bump(&self) {
        self.epoch.fetch_add(1, Ordering::AcqRel);
    }

    /// Issue a fresh global lane id, unmapped (NoLane) until
    /// [`Topology::map_lane`] binds it. Reserving BEFORE the owning
    /// partition installs the lane means a client racing the install
    /// gets a clean NoLane, never a misroute.
    pub(crate) fn reserve_lane(&self) -> usize {
        let mut st = self.state.write();
        st.local_of.push(None);
        let g = st.local_of.len() - 1;
        drop(st);
        self.bump();
        g
    }

    /// Bind global lane `global` to `(part, local)` and bump the epoch.
    // LINT-ALLOW(reserve_lane/add_part sized both tables before any mapping)
    pub(crate) fn map_lane(&self, global: usize, part: usize, local: usize) {
        let mut st = self.state.write();
        if global >= st.local_of.len() {
            st.local_of.resize(global + 1, None);
        }
        st.local_of[global] = Some((part, local));
        let row = &mut st.global_of[part];
        if local >= row.len() {
            row.resize(local + 1, usize::MAX);
        }
        row[local] = global;
        drop(st);
        self.bump();
    }

    /// Unbind global lane `global`: from this call on, the router
    /// answers NoLane for it. Returns the `(partition, local)` it was
    /// mapped to (the quiesce path needs it to address the drain), or
    /// `None` if it was not mapped. The reverse record
    /// ([`Topology::global`]) intentionally survives — see its doc.
    pub(crate) fn unmap_lane(&self, global: usize) -> Option<(usize, usize)> {
        let mut st = self.state.write();
        let old = st.local_of.get_mut(global)?.take();
        drop(st);
        if old.is_some() {
            self.bump();
        }
        old
    }

    /// Register one more (initially empty) partition; returns its id.
    pub(crate) fn add_part(&self) -> usize {
        let mut st = self.state.write();
        st.global_of.push(Vec::new());
        let p = st.global_of.len() - 1;
        drop(st);
        self.bump();
        p
    }

    /// Record a topology-relevant change that the tables themselves do
    /// not encode (e.g. a completed in-place model swap), so epoch
    /// watchers observe it.
    pub(crate) fn note_change(&self) {
        self.bump();
    }
}

/// Sharded dispatch over one lane set: the lanes are partitioned into
/// **lane groups** — each registered coalesce group is one partition,
/// each remaining standalone lane its own — and every partition gets an
/// independent [`MultiServer`] (its own queues and [`QosScheduler`]),
/// so one dispatch thread per partition runs pack/stage/execute
/// concurrently with the others. All partitions share whatever the
/// executors share: ONE [`WorkerPool`] (via `Fleet::load_with_pool`)
/// and the fleet [`ArenaRing`]s, whose depth bounds how many of those
/// rounds can be staged at once.
///
/// Partitioning by group keeps every cross-lane interaction inside one
/// thread: coalesced rounds only ever merge lanes of the same
/// partition, so no lock is needed around queues or scheduling state,
/// and per-lane FIFO response order is preserved exactly as in
/// single-thread dispatch. Requests are routed to the owning
/// partition's queue by global lane id ([`Topology::locate`]); the
/// ingress form of that router is
/// [`run_dispatch_parallel`](crate::ingress::run_dispatch_parallel),
/// and [`run_dispatch_elastic`](crate::ingress::run_dispatch_elastic)
/// adds the runtime add/remove/swap command path
/// ([`super::control::TopologyController`]).
///
/// What cross-partition dispatch gives up is cross-partition WDRR:
/// weights meter shares *within* a partition (where lanes contend for
/// one dispatch thread); partitions themselves run concurrently and
/// contend only for device/pool capacity. A controller-driven
/// **migration** carries the lane's WDRR deficit to its new partition
/// ([`MultiServer::finish_retire`] → [`MultiServer::install_lane`]),
/// so a rebalance does not reset earned shares.
///
/// [`WorkerPool`]: super::pool::WorkerPool
/// [`ArenaRing`]: super::arena::ArenaRing
pub struct ParallelDispatcher<'f, E: RoundExecutor = Fleet> {
    parts: Vec<MultiServer<'f, E>>,
    topo: Arc<Topology>,
}

impl<'f, E: RoundExecutor> ParallelDispatcher<'f, E> {
    /// Partition `lanes` (indexed by their position = global lane id)
    /// into one dispatch group per [`GroupSpec`] plus one per remaining
    /// standalone lane. Group partitions come first, in `groups` order;
    /// standalone partitions follow in lane order. Rejects out-of-range
    /// or multiply grouped members and anything
    /// [`MultiServer::add_coalesce_group`] rejects.
    // LINT-ALLOW(spec lane ids are validated by GroupSpec construction against the lane count)
    pub fn new(
        lanes: Vec<LaneSpec<'f, E>>,
        groups: Vec<GroupSpec<'f, E>>,
    ) -> Result<ParallelDispatcher<'f, E>> {
        let n = lanes.len();
        if n == 0 {
            bail!("parallel dispatcher needs at least one lane");
        }
        let mut grouped: Vec<bool> = vec![false; n];
        for (g, spec) in groups.iter().enumerate() {
            for &l in &spec.members {
                if l >= n {
                    bail!("group {g}: no lane {l} (have {n})");
                }
                if grouped[l] {
                    bail!("lane {l} listed in more than one dispatch group");
                }
                grouped[l] = true;
            }
        }
        let mut specs: Vec<Option<LaneSpec<'f, E>>> = lanes.into_iter().map(Some).collect();
        let mut parts: Vec<MultiServer<'f, E>> = Vec::new();
        let mut local_of: Vec<Option<(usize, usize)>> = vec![None; n];
        let mut global_of: Vec<Vec<usize>> = Vec::new();
        for spec in &groups {
            let p = parts.len();
            let mut ms = MultiServer::new();
            let mut locals = Vec::with_capacity(spec.members.len());
            for &l in &spec.members {
                let LaneSpec { exec, cfg, qos } =
                    specs[l].take().expect("group disjointness checked above");
                let local = ms.add_lane_qos(exec, cfg, qos);
                local_of[l] = Some((p, local));
                locals.push(local);
            }
            ms.add_coalesce_group(spec.exec, &locals)?;
            parts.push(ms);
            global_of.push(spec.members.clone());
        }
        for (l, spec) in specs.iter_mut().enumerate() {
            let Some(LaneSpec { exec, cfg, qos }) = spec.take() else {
                continue; // grouped above
            };
            let p = parts.len();
            let mut ms = MultiServer::new();
            let local = ms.add_lane_qos(exec, cfg, qos);
            local_of[l] = Some((p, local));
            parts.push(ms);
            global_of.push(vec![l]);
        }
        Ok(ParallelDispatcher {
            parts,
            topo: Arc::new(Topology::new(local_of, global_of)),
        })
    }

    /// Number of partitions (= dispatch threads a parallel run spawns).
    pub fn parts(&self) -> usize {
        self.parts.len()
    }

    /// Pre-provision one more (initially laneless) partition and its
    /// dispatch thread slot, for the control plane to install lanes
    /// into at runtime. Partitions are pinned to dispatch threads at
    /// run start (`std::thread::scope` spawns one per partition), so
    /// spares must be added BEFORE the run; an idle spare costs one
    /// parked thread (the idle-poll nap). Returns the partition id.
    pub fn add_spare_part(&mut self) -> usize {
        self.parts.push(MultiServer::new());
        let p = self.topo.add_part();
        debug_assert_eq!(p + 1, self.parts.len(), "topology/partition drift");
        p
    }

    /// Register one [`MetricsHub`] shard per partition and mirror every
    /// lane's metrics into its partition's shard, so each dispatch
    /// thread records aggregate metrics without cross-thread locking.
    /// Size the hub with [`ParallelDispatcher::parts`] for one private
    /// shard per thread (a smaller hub shares shards, which is merely
    /// slower, not wrong). Lanes installed at runtime inherit their
    /// partition's shard.
    ///
    /// [`MetricsHub`]: super::metrics::MetricsHub
    pub fn attach_metrics_hub(&mut self, hub: &MetricsHub) {
        for part in &mut self.parts {
            part.attach_metrics_sink(&hub.register());
        }
    }

    /// Number of global lanes.
    pub fn lanes(&self) -> usize {
        self.topo.lanes()
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// A shared handle to the live topology — what a
    /// [`TopologyController`](super::control::TopologyController)
    /// holds while the dispatcher itself is mutably borrowed by the
    /// running dispatch threads.
    pub fn topology_handle(&self) -> Arc<Topology> {
        Arc::clone(&self.topo)
    }

    /// Partition `p`'s `MultiServer` (its lanes are local — translate
    /// ids through [`ParallelDispatcher::topology`]).
    // LINT-ALLOW(partition ids are issued by the dispatcher constructor)
    pub fn part(&self, p: usize) -> &MultiServer<'f, E> {
        &self.parts[p]
    }

    // LINT-ALLOW(partition ids are issued by the dispatcher constructor)
    pub fn part_mut(&mut self, p: usize) -> &mut MultiServer<'f, E> {
        &mut self.parts[p]
    }

    /// The partitioned servers plus the routing tables, borrowed
    /// disjointly — what a parallel runner needs to hand each dispatch
    /// thread its own `&mut MultiServer` while every thread shares the
    /// topology.
    pub fn split_mut(&mut self) -> (&mut [MultiServer<'f, E>], &Topology) {
        (&mut self.parts, &self.topo)
    }

    /// Route one request to a **global** lane's queues.
    // LINT-ALLOW(locate() gated the global id before partition indexing)
    pub fn offer(&mut self, lane: usize, req: Request) -> Result<Admit> {
        let Some((p, local)) = self.topo.locate(lane) else {
            bail!("no lane {lane} (have {})", self.topo.lanes());
        };
        self.parts[p].offer(local, req)
    }

    /// Total queued requests across every partition.
    pub fn pending(&self) -> usize {
        self.parts.iter().map(|p| p.pending()).sum()
    }

    /// Flush every partition to empty, sequentially (single-thread
    /// shutdown path; the parallel runner drains each partition on its
    /// own thread instead). Returns the number of responses appended.
    pub fn drain(&mut self, responses: &mut Vec<Response>) -> Result<usize> {
        let mut total = 0;
        for part in &mut self.parts {
            total += part.drain(responses)?;
        }
        Ok(total)
    }
}
