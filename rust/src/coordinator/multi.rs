//! `MultiServer`: several fleets served as tenants of one machine.
//!
//! The paper evaluates many merged fleets per GPU (§5), but PR 1's
//! serving loop was single-tenant: one [`Server`] per fleet, and —
//! because every fleet lazily spawned its own [`WorkerPool`] — one
//! thread set per fleet, so an M1-fleet plus an M2-fleet cost M1+M2
//! workers on a machine with far fewer cores.
//!
//! `MultiServer` fixes both:
//! - **per-fleet lanes** — each fleet keeps its own router/batcher
//!   ([`Server`]) with independent queues, strategy, and metrics;
//! - **QoS scheduling across fleets** — lane selection is delegated to
//!   an [`QosScheduler`]: weighted deficit round-robin over round-ready
//!   lanes plus an SLO-deadline boost (a lane whose oldest queued
//!   request is within ε of its [`LaneQos::slo`] preempts the WDRR
//!   order, dispatching a padded round early rather than missing the
//!   deadline). Lanes registered with [`MultiServer::add_lane`] get
//!   `LaneQos::default()` — weight 1 and a far-away SLO — which
//!   degenerates to exactly the old fair round-robin;
//! - **one shared `WorkerPool`** — load every fleet with
//!   [`Fleet::load_with_pool`] and a single
//!   [`WorkerPool::machine_sized`] handle, and all Concurrent/Hybrid
//!   rounds dispatch onto one thread set sized to the machine instead
//!   of one pool per fleet;
//! - **cross-fleet round coalescing** — lanes with the same coalesce
//!   key (model family, request shape, slot count — see
//!   [`super::coalesce`]) can be registered as a *coalesce group*
//!   ([`MultiServer::add_coalesce_group`] /
//!   [`MultiServer::auto_coalesce`]): whenever the QoS pick lands on a
//!   member and at least two members hold queued work, ONE merged round
//!   packs every member's queue fronts into the group executor's
//!   megabatch (`arena::SlotMap` remaps lane-local slots to group
//!   slots) and the outputs scatter back through each lane's own
//!   response routing and metrics. An SLO-**urgent** pick always
//!   dispatches solo on the lane's own executor — a padded group-sized
//!   megabatch would spend the deadline slack on lanes that have
//!   plenty. A failed merged round requeues every member's requests in
//!   their original FIFO positions, exactly like a failed solo round.
//!
//! Note on round overlap: one `MultiServer` dispatches lanes one at a
//! time (`dispatch_next` is `&mut self`), so it does NOT overlap
//! NETFUSE rounds by itself. Overlap comes from **sharding dispatch**:
//! [`ParallelDispatcher`] partitions the lanes into *lane groups* (a
//! coalesce group, or a standalone lane) and gives each group its own
//! `MultiServer` — its own queues and [`QosScheduler`] — so one
//! dispatch thread per group packs/stages/executes concurrently, all
//! sharing ONE [`WorkerPool`] and reserving megabatch slots from the
//! fleet [`ArenaRing`]s (ring depth bounds the overlap).
//! `benches/multi_fleet.rs` measures the two-deep arena win and
//! `benches/parallel_dispatch.rs` the N-thread dispatch win. The async
//! ingress feeding these types from outside the dispatch thread lives
//! in [`crate::ingress`] (`IngressBridge` + `run_dispatch`, or
//! `run_dispatch_parallel` for the sharded form).
//!
//! Like [`Server`], the types are generic over [`RoundExecutor`] so the
//! scheduling logic is testable without artifacts.
//!
//! [`Fleet::load_with_pool`]: super::service::Fleet::load_with_pool
//! [`WorkerPool`]: super::pool::WorkerPool
//! [`WorkerPool::machine_sized`]: super::pool::WorkerPool::machine_sized
//! [`ArenaRing`]: super::arena::ArenaRing

use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::ingress::qos::{LaneCharge, LaneQos, LaneSnapshot, QosScheduler};
use crate::tensor::Tensor;
use crate::util::shard::ShardHandle;

use super::arena::SlotMap;
use super::coalesce::{plan_group, CoalesceKey};
use super::metrics::{MetricsCore, MetricsHub};
use super::request::{Request, Response};
use super::server::{Admit, Server, ServerConfig};
use super::service::{Fleet, RoundExecutor};
use super::strategy::StrategyKind;

/// One registered coalesce group: the group-level executor (for real
/// fleets, the fused program compiled at the members' total slot
/// count), the member lanes in megabatch-window order, and the slot
/// remap between the two.
struct Group<'f, E: RoundExecutor> {
    exec: &'f E,
    members: Vec<usize>,
    map: SlotMap,
    rounds: u64,
    responses: u64,
}

/// Cumulative accounting for one coalesce group.
#[derive(Debug, Clone, Copy, Default)]
pub struct GroupStats {
    /// merged rounds dispatched through the group executor
    pub rounds: u64,
    /// responses those merged rounds produced (across all members)
    pub responses: u64,
}

/// What one [`MultiServer::dispatch_next`] did.
#[derive(Debug, Clone, Copy)]
pub struct Dispatched {
    /// the lane the QoS scheduler picked (and charged)
    pub lane: usize,
    /// responses appended — for a coalesced round these span every
    /// served member lane, not just `lane`
    pub responses: usize,
    /// lanes whose requests this round served: 1 for a solo round,
    /// >= 2 for a coalesced group round
    pub lanes_served: usize,
    /// the pick came from the SLO boost (solo, possibly padded round)
    pub urgent: bool,
}

/// Multi-tenant serving front end: one [`Server`] lane per fleet,
/// QoS-scheduled (WDRR + SLO boost) round dispatch across lanes, with
/// optional cross-fleet round coalescing.
pub struct MultiServer<'f, E: RoundExecutor = Fleet> {
    lanes: Vec<Server<'f, E>>,
    sched: QosScheduler,
    /// registered coalesce groups (disjoint member sets)
    groups: Vec<Group<'f, E>>,
    /// lane -> its group, parallel to `lanes`
    group_of: Vec<Option<usize>>,
    /// merged-round output scratch, reused across coalesced rounds
    group_outs: Vec<Option<Tensor>>,
    /// per-round served-lane charge scratch, reused across dispatches
    charges: Vec<LaneCharge>,
}

impl<'f, E: RoundExecutor> Default for MultiServer<'f, E> {
    fn default() -> Self {
        Self::new()
    }
}

fn snapshot<E: RoundExecutor>(lane: &Server<'_, E>) -> LaneSnapshot {
    LaneSnapshot {
        ready: lane.round_ready(),
        pending: lane.pending(),
        oldest_wait: lane.oldest_wait(),
    }
}

impl<'f, E: RoundExecutor> MultiServer<'f, E> {
    pub fn new() -> MultiServer<'f, E> {
        Self::with_boost_margin(QosScheduler::DEFAULT_BOOST_MARGIN)
    }

    /// `boost_margin` is the scheduler's default ε: how close to its
    /// SLO a lane's oldest wait may get before the lane preempts WDRR.
    /// Individual lanes can override it per lane via
    /// [`LaneQos::with_boost_margin`] at `add_lane_qos` time.
    pub fn with_boost_margin(eps: Duration) -> MultiServer<'f, E> {
        MultiServer {
            lanes: Vec::new(),
            sched: QosScheduler::new(eps),
            groups: Vec::new(),
            group_of: Vec::new(),
            group_outs: Vec::new(),
            charges: Vec::new(),
        }
    }

    /// Register one fleet as a tenant with default QoS (weight 1, no
    /// effective SLO — plain fair round-robin); returns its lane index
    /// (the handle used by [`MultiServer::offer`]).
    pub fn add_lane(&mut self, fleet: &'f E, cfg: ServerConfig) -> usize {
        self.add_lane_qos(fleet, cfg, LaneQos::default())
    }

    /// Register one fleet as a tenant with an explicit [`LaneQos`]
    /// (WDRR weight + SLO). The lane's metrics count violations of
    /// `qos.slo` from here on.
    pub fn add_lane_qos(&mut self, fleet: &'f E, cfg: ServerConfig, qos: LaneQos) -> usize {
        let mut server = Server::new(fleet, cfg);
        server.metrics.slo = Some(qos.slo.as_secs_f64());
        self.lanes.push(server);
        self.group_of.push(None);
        self.sched.add_lane(qos)
    }

    /// Mirror every lane's metrics into one [`MetricsHub`] shard — the
    /// shard of the (single) thread dispatching this `MultiServer`.
    /// Lane-local [`Server::metrics`] views are unaffected.
    ///
    /// [`MetricsHub`]: super::metrics::MetricsHub
    pub fn attach_metrics_sink(&mut self, sink: &ShardHandle<MetricsCore>) {
        for lane in &mut self.lanes {
            lane.attach_metrics_sink(sink.clone());
        }
    }

    /// Register `members` as a coalesce group executing merged rounds
    /// on `exec`. Validation (same model family, request shape, and
    /// slot count across members; `exec` sized to exactly the members'
    /// total — see [`super::coalesce::plan_group`]) rejects any lane
    /// set that could not share a megabatch; a lane can belong to at
    /// most one group. Returns the group handle.
    pub fn add_coalesce_group(&mut self, exec: &'f E, members: &[usize]) -> Result<usize> {
        for (a, &l) in members.iter().enumerate() {
            if l >= self.lanes.len() {
                bail!("no lane {l} (have {})", self.lanes.len());
            }
            if self.group_of[l].is_some() {
                bail!("lane {l} already belongs to a coalesce group");
            }
            if members[..a].contains(&l) {
                bail!("lane {l} listed twice in one coalesce group");
            }
        }
        let execs: Vec<&E> = members.iter().map(|&l| self.lanes[l].fleet()).collect();
        let map = plan_group(exec, &execs)?;
        let g = self.groups.len();
        for &l in members {
            self.group_of[l] = Some(g);
        }
        self.groups.push(Group {
            exec,
            members: members.to_vec(),
            map,
            rounds: 0,
            responses: 0,
        });
        Ok(g)
    }

    /// Form a coalesce group automatically: scan registered lanes (in
    /// lane order) for ungrouped ones whose coalesce key — (model
    /// family, request shape, slot count) — matches `exec`'s family and
    /// shape, taking the first matching lane's slot count as the
    /// group's, until `exec`'s capacity is filled. Lanes with a
    /// mismatched key are skipped, never coalesced. Returns `Ok(None)`
    /// when fewer than two matching lanes exist or their total does not
    /// fill `exec` exactly.
    pub fn auto_coalesce(&mut self, exec: &'f E) -> Result<Option<usize>> {
        let want = CoalesceKey::of(exec);
        let mut members: Vec<usize> = Vec::new();
        let mut lane_m: Option<usize> = None;
        for (l, lane) in self.lanes.iter().enumerate() {
            if self.group_of[l].is_some() {
                continue;
            }
            let k = CoalesceKey::of(lane.fleet());
            if k.family != want.family || k.request_shape != want.request_shape {
                continue;
            }
            match lane_m {
                None => lane_m = Some(k.slots),
                Some(m) if m != k.slots => continue,
                Some(_) => {}
            }
            if (members.len() + 1) * lane_m.unwrap() > want.slots {
                break; // group executor full
            }
            members.push(l);
        }
        match lane_m {
            Some(m) if members.len() >= 2 && members.len() * m == want.slots => {
                Ok(Some(self.add_coalesce_group(exec, &members)?))
            }
            _ => Ok(None),
        }
    }

    /// Number of registered coalesce groups.
    pub fn coalesce_groups(&self) -> usize {
        self.groups.len()
    }

    /// Member lanes of group `g`, in megabatch-window order.
    pub fn group_members(&self, g: usize) -> &[usize] {
        &self.groups[g].members
    }

    /// Cumulative merged-round accounting for group `g`.
    pub fn group_stats(&self, g: usize) -> GroupStats {
        GroupStats { rounds: self.groups[g].rounds, responses: self.groups[g].responses }
    }

    /// The coalesce group `lane` belongs to, if any.
    pub fn lane_group(&self, lane: usize) -> Option<usize> {
        self.group_of[lane]
    }

    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Per-lane router/batcher (queue state, metrics).
    pub fn lane(&self, lane: usize) -> &Server<'f, E> {
        &self.lanes[lane]
    }

    /// The scheduling contract `lane` was registered with.
    pub fn qos(&self, lane: usize) -> LaneQos {
        self.sched.qos(lane)
    }

    /// Route one request to `lane`'s per-model queues.
    pub fn offer(&mut self, lane: usize, req: Request) -> Result<Admit> {
        if lane >= self.lanes.len() {
            bail!("no lane {lane} (have {})", self.lanes.len());
        }
        Ok(self.lanes[lane].offer(req))
    }

    /// Total queued requests across all lanes.
    pub fn pending(&self) -> usize {
        self.lanes.iter().map(|l| l.pending()).sum()
    }

    /// The lane the QoS scheduler would dispatch next: an SLO-urgent
    /// lane first, otherwise the WDRR pick among round-ready lanes.
    /// `None` when nothing is due. Pure — deficits are only charged by
    /// an actual [`MultiServer::dispatch_next`].
    pub fn ready_lane(&self) -> Option<usize> {
        let lanes = &self.lanes;
        self.sched.select(&|i| snapshot(&lanes[i])).map(|p| p.lane)
    }

    /// How long until some lane becomes due (batching deadline or SLO
    /// boost), `Duration::ZERO` if one already is, `None` when every
    /// queue is empty. This is the longest an ingress loop may block
    /// without risking an idle dispatch thread next to a due round.
    /// Delegates to [`QosScheduler::next_due_in`], whose scan covers
    /// every backlogged lane — including lanes a coalesced round would
    /// serve only as riders, whose boost windows are dispatch triggers
    /// of their own.
    pub fn next_due_in(&self) -> Option<Duration> {
        let lanes = &self.lanes;
        self.sched.next_due_in(
            &|i| snapshot(&lanes[i]),
            &|i| lanes[i].config().max_wait,
        )
    }

    /// Dispatch the next due round (QoS pick), appending its responses
    /// to `responses`. Returns `Some(`[`Dispatched`]`)`, or `None` when
    /// no lane is due yet. An SLO-urgent pick dispatches even if the
    /// lane's round is not batching-ready — the round pads, and it
    /// always runs **solo** on the lane's own executor. A non-urgent
    /// pick on a coalesce-group member with at least one other member
    /// holding work dispatches a **merged** group round instead: every
    /// member's queue fronts pack into one megabatch (members that are
    /// not yet batching-ready ride along — their windows would
    /// otherwise pad), and responses scatter back per lane.
    ///
    /// Deficit charging happens AFTER the round, against what it
    /// actually served: a solo round charges the picked lane one whole
    /// credit (one launch = one round, padded or not — unchanged), and
    /// a merged round charges **every served member** — rider lanes
    /// included — proportionally to the slots each consumed of its own
    /// round capacity ([`QosScheduler::commit_served`]). Before this,
    /// only the picked lane was charged and riders accumulated service
    /// for free, so strict weighted shares drifted at high lane counts.
    ///
    /// A failed round — solo or merged — requeues its requests inside
    /// the owning lane(s) (original FIFO order and wait clocks) and
    /// surfaces the error; the picked lane is still charged a whole
    /// round and the cursor advances past it, so a persistently failing
    /// fleet cannot starve the others.
    pub fn dispatch_next(
        &mut self,
        responses: &mut Vec<Response>,
    ) -> Result<Option<Dispatched>> {
        let pick = {
            let lanes = &self.lanes;
            match self.sched.select(&|i| snapshot(&lanes[i])) {
                Some(p) => p,
                None => return Ok(None),
            }
        };
        if !pick.urgent {
            if let Some(g) = self.group_of[pick.lane] {
                let live = self.groups[g]
                    .members
                    .iter()
                    .filter(|&&l| self.lanes[l].pending() > 0)
                    .count();
                if live >= 2 {
                    match self.dispatch_group(g, responses) {
                        Ok((lanes_served, n)) => {
                            let (lanes, sched) = (&self.lanes, &mut self.sched);
                            sched.commit_served(&pick, &self.charges, &|i| {
                                snapshot(&lanes[i])
                            });
                            return Ok(Some(Dispatched {
                                lane: pick.lane,
                                responses: n,
                                lanes_served,
                                urgent: false,
                            }));
                        }
                        Err(e) => {
                            let (lanes, sched) = (&self.lanes, &mut self.sched);
                            sched.commit(&pick, &|i| snapshot(&lanes[i]));
                            return Err(e);
                        }
                    }
                }
            }
        }
        // solo round: success or failure, the pick costs one whole
        // credit (one launch) and the cursor moves on
        let result = self.lanes[pick.lane].dispatch_into(responses);
        let (lanes, sched) = (&self.lanes, &mut self.sched);
        sched.commit(&pick, &|i| snapshot(&lanes[i]));
        Ok(Some(Dispatched {
            lane: pick.lane,
            responses: result?,
            lanes_served: 1,
            urgent: pick.urgent,
        }))
    }

    /// One merged round over group `g`: take every member's queue
    /// fronts, execute the group's megabatch once, scatter the outputs
    /// back through each member's response path. Returns
    /// `(lanes_served, responses)`; the per-member slot consumption is
    /// left in `self.charges` so the caller can charge every served
    /// lane (rider fairness — riders must pay for the service they
    /// receive).
    fn dispatch_group(
        &mut self,
        g: usize,
        responses: &mut Vec<Response>,
    ) -> Result<(usize, usize)> {
        // field-level borrow split: `groups` is only read while `lanes`
        // and the output scratch are driven through the round phases
        let groups = &self.groups;
        let lanes = &mut self.lanes;
        let outs = &mut self.group_outs;
        let charges = &mut self.charges;
        let group = &groups[g];

        // take: pop each member's fronts into its round scratch. Members
        // with nothing queued still "take" (an empty round) so their
        // megabatch windows pad; they are not counted as served and are
        // not charged.
        let mut lanes_served = 0usize;
        charges.clear();
        for &l in &group.members {
            let taken = lanes[l].take_round();
            if taken > 0 {
                lanes_served += 1;
                charges.push(LaneCharge {
                    lane: l,
                    slots: taken,
                    round_slots: lanes[l].fleet().m(),
                });
            }
        }

        // execute: ONE merged round through the group executor; the
        // `get` closure is the SlotMap remap (group slot -> member
        // lane's local slot). Coalescing exists to amortize the merged
        // program's launch, so the group round is always NETFUSE.
        let t0 = Instant::now();
        let run = {
            let lanes = &*lanes;
            let get = |gs: usize| {
                let (k, local) = group.map.locate(gs);
                lanes[group.members[k]].slot_input(local)
            };
            group.exec.run_round_slots(StrategyKind::NetFuse, &get, outs)
        };
        if let Err(e) = run {
            // merged-round failure: every member requeues its own
            // fronts — per-queue FIFO order and wait clocks survive the
            // remap, exactly like a failed solo round
            for &l in &group.members {
                lanes[l].requeue_taken();
            }
            return Err(e);
        }

        // verify the WHOLE merged output before any lane consumes a
        // slot: a short or hole-y result from a misbehaving group
        // executor must requeue every member, not answer some lanes
        // and drop the rest mid-scatter
        let bad = if outs.len() != group.map.total() {
            Some(format!(
                "executor returned {} outputs for {} group slots",
                outs.len(),
                group.map.total()
            ))
        } else {
            (0..group.map.total())
                .find(|&gs| {
                    let (k, local) = group.map.locate(gs);
                    lanes[group.members[k]].slot_input(local).is_some() && outs[gs].is_none()
                })
                .map(|gs| format!("group slot {gs} produced no output for an occupied slot"))
        };
        if let Some(msg) = bad {
            for &l in &group.members {
                lanes[l].requeue_taken();
            }
            bail!("coalesced round: {msg}");
        }

        // scatter: each member completes against its lane-relative
        // window of the merged output. Round time is the merged round's
        // wall time, attributed to every lane that actually held work.
        let secs = t0.elapsed().as_secs_f64();
        let mut n = 0usize;
        for (k, &l) in group.members.iter().enumerate() {
            let window = group.map.slots_of(k);
            let occupied = (0..window.len()).any(|local| lanes[l].slot_input(local).is_some());
            if !occupied {
                continue;
            }
            match lanes[l].complete_round(secs, &mut outs[window], responses) {
                Ok(c) => n += c,
                Err(e) => {
                    // mid-scatter failure (unreachable after the group
                    // verification above, kept as defense): the failing
                    // lane requeued its own round inside complete_round;
                    // members not yet scattered must requeue too or
                    // their taken requests would leak
                    for &rest in &group.members[k + 1..] {
                        lanes[rest].requeue_taken();
                    }
                    return Err(e);
                }
            }
        }
        let group = &mut self.groups[g];
        group.rounds += 1;
        group.responses += n as u64;
        Ok((lanes_served, n))
    }

    /// Dispatch (padded) rounds until every queue on every lane is
    /// empty, appending all responses. Returns the number of responses.
    /// Unlike [`MultiServer::dispatch_next`], this drains lanes whose
    /// rounds are not yet due — it is the shutdown/flush path.
    ///
    /// The flush is **group-aware**: when the round-robin scan lands on
    /// a coalesce-group member and at least one other member still
    /// holds work, the members flush together as ONE merged round, so
    /// even the final partial rounds amortize the merged program's
    /// launch instead of dispatching solo per lane.
    pub fn drain(&mut self, responses: &mut Vec<Response>) -> Result<usize> {
        let mut total = 0;
        loop {
            // round-robin over lanes with work so the flush stays fair;
            // when no lane holds work (including a lane that emptied
            // between scans) the flush is complete
            let n = self.lanes.len();
            let lane = (0..n)
                .map(|k| (self.sched.cursor() + k) % n)
                .find(|&i| self.lanes[i].pending() > 0);
            let Some(lane) = lane else {
                return Ok(total);
            };
            self.sched.rotate_after(lane);
            if let Some(g) = self.group_of[lane] {
                let live = self.groups[g]
                    .members
                    .iter()
                    .filter(|&&l| self.lanes[l].pending() > 0)
                    .count();
                if live >= 2 {
                    total += self.dispatch_group(g, responses)?.1;
                    continue;
                }
            }
            total += self.lanes[lane].dispatch_into(responses)?;
        }
    }
}

// ---------------------------------------------------------------------------
// parallel dispatch: one thread per lane group
// ---------------------------------------------------------------------------

/// One lane's registration for a [`ParallelDispatcher`]: the executor
/// it dispatches onto, its batching config, and its QoS contract.
pub struct LaneSpec<'f, E: RoundExecutor = Fleet> {
    pub exec: &'f E,
    pub cfg: ServerConfig,
    pub qos: LaneQos,
}

impl<'f, E: RoundExecutor> LaneSpec<'f, E> {
    pub fn new(exec: &'f E, cfg: ServerConfig, qos: LaneQos) -> LaneSpec<'f, E> {
        LaneSpec { exec, cfg, qos }
    }
}

/// One coalesce group's registration for a [`ParallelDispatcher`]:
/// the group-level executor and the member lanes (global lane ids, in
/// megabatch-window order). Validation is [`super::coalesce`]'s, via
/// [`MultiServer::add_coalesce_group`] on the group's partition.
pub struct GroupSpec<'f, E: RoundExecutor = Fleet> {
    pub exec: &'f E,
    pub members: Vec<usize>,
}

impl<'f, E: RoundExecutor> GroupSpec<'f, E> {
    pub fn new(exec: &'f E, members: &[usize]) -> GroupSpec<'f, E> {
        GroupSpec { exec, members: members.to_vec() }
    }
}

/// The lane partition of a [`ParallelDispatcher`]: which partition owns
/// each global lane, and the global id of every partition-local lane.
/// Routing tables only — immutable after construction, shared by the
/// router and every dispatch thread.
pub struct Topology {
    /// global lane -> (partition, partition-local lane)
    local_of: Vec<(usize, usize)>,
    /// partition -> local lane -> global lane
    global_of: Vec<Vec<usize>>,
}

impl Topology {
    /// Number of partitions (= dispatch threads).
    pub fn parts(&self) -> usize {
        self.global_of.len()
    }

    /// Number of global lanes.
    pub fn lanes(&self) -> usize {
        self.local_of.len()
    }

    /// The `(partition, local lane)` owning global lane `lane`, or
    /// `None` for an unknown lane id (the router's NoLane case).
    pub fn locate(&self, lane: usize) -> Option<(usize, usize)> {
        self.local_of.get(lane).copied()
    }

    /// Global id of partition `part`'s local lane `local`.
    pub fn global(&self, part: usize, local: usize) -> usize {
        self.global_of[part][local]
    }

    /// Global lane ids owned by partition `part`, in local-lane order.
    pub fn part_lanes(&self, part: usize) -> &[usize] {
        &self.global_of[part]
    }
}

/// Sharded dispatch over one lane set: the lanes are partitioned into
/// **lane groups** — each registered coalesce group is one partition,
/// each remaining standalone lane its own — and every partition gets an
/// independent [`MultiServer`] (its own queues and [`QosScheduler`]),
/// so one dispatch thread per partition runs pack/stage/execute
/// concurrently with the others. All partitions share whatever the
/// executors share: ONE [`WorkerPool`] (via `Fleet::load_with_pool`)
/// and the fleet [`ArenaRing`]s, whose depth bounds how many of those
/// rounds can be staged at once.
///
/// Partitioning by group keeps every cross-lane interaction inside one
/// thread: coalesced rounds only ever merge lanes of the same
/// partition, so no lock is needed around queues or scheduling state,
/// and per-lane FIFO response order is preserved exactly as in
/// single-thread dispatch. Requests are routed to the owning
/// partition's queue by global lane id ([`Topology::locate`]); the
/// ingress form of that router is
/// [`run_dispatch_parallel`](crate::ingress::run_dispatch_parallel).
///
/// What cross-partition dispatch gives up is cross-partition WDRR:
/// weights meter shares *within* a partition (where lanes contend for
/// one dispatch thread); partitions themselves run concurrently and
/// contend only for device/pool capacity.
///
/// [`WorkerPool`]: super::pool::WorkerPool
/// [`ArenaRing`]: super::arena::ArenaRing
pub struct ParallelDispatcher<'f, E: RoundExecutor = Fleet> {
    parts: Vec<MultiServer<'f, E>>,
    topo: Topology,
}

impl<'f, E: RoundExecutor> ParallelDispatcher<'f, E> {
    /// Partition `lanes` (indexed by their position = global lane id)
    /// into one dispatch group per [`GroupSpec`] plus one per remaining
    /// standalone lane. Group partitions come first, in `groups` order;
    /// standalone partitions follow in lane order. Rejects out-of-range
    /// or multiply grouped members and anything
    /// [`MultiServer::add_coalesce_group`] rejects.
    pub fn new(
        lanes: Vec<LaneSpec<'f, E>>,
        groups: Vec<GroupSpec<'f, E>>,
    ) -> Result<ParallelDispatcher<'f, E>> {
        let n = lanes.len();
        if n == 0 {
            bail!("parallel dispatcher needs at least one lane");
        }
        let mut grouped: Vec<bool> = vec![false; n];
        for (g, spec) in groups.iter().enumerate() {
            for &l in &spec.members {
                if l >= n {
                    bail!("group {g}: no lane {l} (have {n})");
                }
                if grouped[l] {
                    bail!("lane {l} listed in more than one dispatch group");
                }
                grouped[l] = true;
            }
        }
        let mut specs: Vec<Option<LaneSpec<'f, E>>> = lanes.into_iter().map(Some).collect();
        let mut parts: Vec<MultiServer<'f, E>> = Vec::new();
        let mut local_of: Vec<(usize, usize)> = vec![(usize::MAX, usize::MAX); n];
        let mut global_of: Vec<Vec<usize>> = Vec::new();
        for spec in &groups {
            let p = parts.len();
            let mut ms = MultiServer::new();
            let mut locals = Vec::with_capacity(spec.members.len());
            for &l in &spec.members {
                let LaneSpec { exec, cfg, qos } =
                    specs[l].take().expect("group disjointness checked above");
                let local = ms.add_lane_qos(exec, cfg, qos);
                local_of[l] = (p, local);
                locals.push(local);
            }
            ms.add_coalesce_group(spec.exec, &locals)?;
            parts.push(ms);
            global_of.push(spec.members.clone());
        }
        for (l, spec) in specs.iter_mut().enumerate() {
            let Some(LaneSpec { exec, cfg, qos }) = spec.take() else {
                continue; // grouped above
            };
            let p = parts.len();
            let mut ms = MultiServer::new();
            let local = ms.add_lane_qos(exec, cfg, qos);
            local_of[l] = (p, local);
            parts.push(ms);
            global_of.push(vec![l]);
        }
        Ok(ParallelDispatcher { parts, topo: Topology { local_of, global_of } })
    }

    /// Number of partitions (= dispatch threads a parallel run spawns).
    pub fn parts(&self) -> usize {
        self.parts.len()
    }

    /// Register one [`MetricsHub`] shard per partition and mirror every
    /// lane's metrics into its partition's shard, so each dispatch
    /// thread records aggregate metrics without cross-thread locking.
    /// Size the hub with [`ParallelDispatcher::parts`] for one private
    /// shard per thread (a smaller hub shares shards, which is merely
    /// slower, not wrong).
    ///
    /// [`MetricsHub`]: super::metrics::MetricsHub
    pub fn attach_metrics_hub(&mut self, hub: &MetricsHub) {
        for part in &mut self.parts {
            part.attach_metrics_sink(&hub.register());
        }
    }

    /// Number of global lanes.
    pub fn lanes(&self) -> usize {
        self.topo.lanes()
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Partition `p`'s `MultiServer` (its lanes are local — translate
    /// ids through [`ParallelDispatcher::topology`]).
    pub fn part(&self, p: usize) -> &MultiServer<'f, E> {
        &self.parts[p]
    }

    pub fn part_mut(&mut self, p: usize) -> &mut MultiServer<'f, E> {
        &mut self.parts[p]
    }

    /// The partitioned servers plus the routing tables, borrowed
    /// disjointly — what a parallel runner needs to hand each dispatch
    /// thread its own `&mut MultiServer` while every thread shares the
    /// topology.
    pub fn split_mut(&mut self) -> (&mut [MultiServer<'f, E>], &Topology) {
        (&mut self.parts, &self.topo)
    }

    /// Route one request to a **global** lane's queues.
    pub fn offer(&mut self, lane: usize, req: Request) -> Result<Admit> {
        let Some((p, local)) = self.topo.locate(lane) else {
            bail!("no lane {lane} (have {})", self.topo.lanes());
        };
        self.parts[p].offer(local, req)
    }

    /// Total queued requests across every partition.
    pub fn pending(&self) -> usize {
        self.parts.iter().map(|p| p.pending()).sum()
    }

    /// Flush every partition to empty, sequentially (single-thread
    /// shutdown path; the parallel runner drains each partition on its
    /// own thread instead). Returns the number of responses appended.
    pub fn drain(&mut self, responses: &mut Vec<Response>) -> Result<usize> {
        let mut total = 0;
        for part in &mut self.parts {
            total += part.drain(responses)?;
        }
        Ok(total)
    }
}
