//! `MultiServer`: several fleets served as tenants of one machine.
//!
//! The paper evaluates many merged fleets per GPU (§5), but PR 1's
//! serving loop was single-tenant: one [`Server`] per fleet, and —
//! because every fleet lazily spawned its own [`WorkerPool`] — one
//! thread set per fleet, so an M1-fleet plus an M2-fleet cost M1+M2
//! workers on a machine with far fewer cores.
//!
//! `MultiServer` fixes both:
//! - **per-fleet lanes** — each fleet keeps its own router/batcher
//!   ([`Server`]) with independent queues, strategy, and metrics;
//! - **QoS scheduling across fleets** — lane selection is delegated to
//!   an [`QosScheduler`]: weighted deficit round-robin over round-ready
//!   lanes plus an SLO-deadline boost (a lane whose oldest queued
//!   request is within ε of its [`LaneQos::slo`] preempts the WDRR
//!   order, dispatching a padded round early rather than missing the
//!   deadline). Lanes registered with [`MultiServer::add_lane`] get
//!   `LaneQos::default()` — weight 1 and a far-away SLO — which
//!   degenerates to exactly the old fair round-robin;
//! - **one shared `WorkerPool`** — load every fleet with
//!   [`Fleet::load_with_pool`] and a single
//!   [`WorkerPool::machine_sized`] handle, and all Concurrent/Hybrid
//!   rounds dispatch onto one thread set sized to the machine instead
//!   of one pool per fleet.
//!
//! Note on round overlap: `MultiServer` itself dispatches lanes one at
//! a time (`dispatch_next` is `&mut self`), so it does NOT overlap
//! NETFUSE rounds. The fleet's [`ArenaPair`] enables overlap for
//! *concurrent* callers of `Fleet::run_round_slots` — e.g. one driver
//! thread per lane — `benches/multi_fleet.rs` measures that win
//! directly. The async ingress feeding this type from outside the
//! dispatch thread lives in [`crate::ingress`] (`IngressBridge` +
//! `run_dispatch`).
//!
//! Like [`Server`], the type is generic over [`RoundExecutor`] so the
//! scheduling logic is testable without artifacts.
//!
//! [`Fleet::load_with_pool`]: super::service::Fleet::load_with_pool
//! [`WorkerPool`]: super::pool::WorkerPool
//! [`WorkerPool::machine_sized`]: super::pool::WorkerPool::machine_sized
//! [`ArenaPair`]: super::arena::ArenaPair

use std::time::Duration;

use anyhow::{bail, Result};

use crate::ingress::qos::{LaneQos, LaneSnapshot, QosScheduler};

use super::request::{Request, Response};
use super::server::{Admit, Server, ServerConfig};
use super::service::{Fleet, RoundExecutor};

/// Multi-tenant serving front end: one [`Server`] lane per fleet,
/// QoS-scheduled (WDRR + SLO boost) round dispatch across lanes.
pub struct MultiServer<'f, E: RoundExecutor = Fleet> {
    lanes: Vec<Server<'f, E>>,
    sched: QosScheduler,
}

impl<'f, E: RoundExecutor> Default for MultiServer<'f, E> {
    fn default() -> Self {
        Self::new()
    }
}

fn snapshot<E: RoundExecutor>(lane: &Server<'_, E>) -> LaneSnapshot {
    LaneSnapshot {
        ready: lane.round_ready(),
        pending: lane.pending(),
        oldest_wait: lane.oldest_wait(),
    }
}

impl<'f, E: RoundExecutor> MultiServer<'f, E> {
    pub fn new() -> MultiServer<'f, E> {
        Self::with_boost_margin(QosScheduler::DEFAULT_BOOST_MARGIN)
    }

    /// `boost_margin` is the scheduler's ε: how close to its SLO a
    /// lane's oldest wait may get before the lane preempts WDRR.
    pub fn with_boost_margin(eps: Duration) -> MultiServer<'f, E> {
        MultiServer { lanes: Vec::new(), sched: QosScheduler::new(eps) }
    }

    /// Register one fleet as a tenant with default QoS (weight 1, no
    /// effective SLO — plain fair round-robin); returns its lane index
    /// (the handle used by [`MultiServer::offer`]).
    pub fn add_lane(&mut self, fleet: &'f E, cfg: ServerConfig) -> usize {
        self.add_lane_qos(fleet, cfg, LaneQos::default())
    }

    /// Register one fleet as a tenant with an explicit [`LaneQos`]
    /// (WDRR weight + SLO). The lane's metrics count violations of
    /// `qos.slo` from here on.
    pub fn add_lane_qos(&mut self, fleet: &'f E, cfg: ServerConfig, qos: LaneQos) -> usize {
        let mut server = Server::new(fleet, cfg);
        server.metrics.slo = Some(qos.slo.as_secs_f64());
        self.lanes.push(server);
        self.sched.add_lane(qos)
    }

    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Per-lane router/batcher (queue state, metrics).
    pub fn lane(&self, lane: usize) -> &Server<'f, E> {
        &self.lanes[lane]
    }

    /// The scheduling contract `lane` was registered with.
    pub fn qos(&self, lane: usize) -> LaneQos {
        self.sched.qos(lane)
    }

    /// Route one request to `lane`'s per-model queues.
    pub fn offer(&mut self, lane: usize, req: Request) -> Result<Admit> {
        if lane >= self.lanes.len() {
            bail!("no lane {lane} (have {})", self.lanes.len());
        }
        Ok(self.lanes[lane].offer(req))
    }

    /// Total queued requests across all lanes.
    pub fn pending(&self) -> usize {
        self.lanes.iter().map(|l| l.pending()).sum()
    }

    /// The lane the QoS scheduler would dispatch next: an SLO-urgent
    /// lane first, otherwise the WDRR pick among round-ready lanes.
    /// `None` when nothing is due. Pure — deficits are only charged by
    /// an actual [`MultiServer::dispatch_next`].
    pub fn ready_lane(&self) -> Option<usize> {
        let lanes = &self.lanes;
        self.sched.select(&|i| snapshot(&lanes[i])).map(|p| p.lane)
    }

    /// How long until some lane becomes due (batching deadline or SLO
    /// boost), `Duration::ZERO` if one already is, `None` when every
    /// queue is empty. This is the longest an ingress loop may block
    /// without risking an idle dispatch thread next to a due round.
    pub fn next_due_in(&self) -> Option<Duration> {
        if self.ready_lane().is_some() {
            return Some(Duration::ZERO);
        }
        let mut best: Option<Duration> = None;
        for (i, lane) in self.lanes.iter().enumerate() {
            let Some(wait) = lane.oldest_wait() else { continue };
            let qos = self.sched.qos(i);
            let batch_due = lane.config().max_wait.saturating_sub(wait);
            let slo_due = qos
                .slo
                .saturating_sub(self.sched.boost_margin())
                .saturating_sub(wait);
            let due = batch_due.min(slo_due);
            best = Some(match best {
                Some(b) => b.min(due),
                None => due,
            });
        }
        best
    }

    /// Dispatch the next due lane (QoS pick), appending its responses
    /// to `responses`. Returns `Some((lane, responses_appended))`, or
    /// `None` when no lane is due yet. An SLO-urgent pick dispatches
    /// even if the lane's round is not batching-ready — the round pads.
    /// A failed round requeues its requests inside the lane (original
    /// FIFO order and wait clocks) and surfaces the error; the cursor
    /// and deficit still advance past the lane so a persistently
    /// failing fleet cannot starve the others.
    pub fn dispatch_next(
        &mut self,
        responses: &mut Vec<Response>,
    ) -> Result<Option<(usize, usize)>> {
        let pick = {
            let lanes = &self.lanes;
            match self.sched.select(&|i| snapshot(&lanes[i])) {
                Some(p) => p,
                None => return Ok(None),
            }
        };
        {
            let lanes = &self.lanes;
            self.sched.commit(&pick, &|i| snapshot(&lanes[i]));
        }
        let n = self.lanes[pick.lane].dispatch_into(responses)?;
        Ok(Some((pick.lane, n)))
    }

    /// Dispatch (padded) rounds until every queue on every lane is
    /// empty, appending all responses. Returns the number of responses.
    /// Unlike [`MultiServer::dispatch_next`], this drains lanes whose
    /// rounds are not yet due — it is the shutdown/flush path.
    pub fn drain(&mut self, responses: &mut Vec<Response>) -> Result<usize> {
        let mut total = 0;
        while self.pending() > 0 {
            // round-robin over lanes with work so the flush stays fair
            let n = self.lanes.len();
            let lane = (0..n)
                .map(|k| (self.sched.cursor() + k) % n)
                .find(|&i| self.lanes[i].pending() > 0)
                .expect("pending() > 0 implies some lane has work");
            self.sched.rotate_after(lane);
            total += self.lanes[lane].dispatch_into(responses)?;
        }
        Ok(total)
    }
}
