//! `MultiServer`: several fleets served as tenants of one machine.
//!
//! The paper evaluates many merged fleets per GPU (§5), but PR 1's
//! serving loop was single-tenant: one [`Server`] per fleet, and —
//! because every fleet lazily spawned its own [`WorkerPool`] — one
//! thread set per fleet, so an M1-fleet plus an M2-fleet cost M1+M2
//! workers on a machine with far fewer cores.
//!
//! `MultiServer` fixes both:
//! - **per-fleet lanes** — each fleet keeps its own router/batcher
//!   ([`Server`]) with independent queues, strategy, and metrics;
//! - **QoS scheduling across fleets** — lane selection is delegated to
//!   an [`QosScheduler`]: weighted deficit round-robin over round-ready
//!   lanes plus an SLO-deadline boost (a lane whose oldest queued
//!   request is within ε of its [`LaneQos::slo`] preempts the WDRR
//!   order, dispatching a padded round early rather than missing the
//!   deadline). Lanes registered with [`MultiServer::add_lane`] get
//!   `LaneQos::default()` — weight 1 and a far-away SLO — which
//!   degenerates to exactly the old fair round-robin;
//! - **one shared `WorkerPool`** — load every fleet with
//!   [`Fleet::load_with_pool`] and a single
//!   [`WorkerPool::machine_sized`] handle, and all Concurrent/Hybrid
//!   rounds dispatch onto one thread set sized to the machine instead
//!   of one pool per fleet;
//! - **cross-fleet round coalescing** — lanes with the same coalesce
//!   key (model family, request shape, slot count — see
//!   [`super::coalesce`]) can be registered as a *coalesce group*
//!   ([`MultiServer::add_coalesce_group`] /
//!   [`MultiServer::auto_coalesce`]): whenever the QoS pick lands on a
//!   member and at least two members hold queued work, ONE merged round
//!   packs every member's queue fronts into the group executor's
//!   megabatch (`arena::SlotMap` remaps lane-local slots to group
//!   slots) and the outputs scatter back through each lane's own
//!   response routing and metrics. An SLO-**urgent** pick always
//!   dispatches solo on the lane's own executor — a padded group-sized
//!   megabatch would spend the deadline slack on lanes that have
//!   plenty. A failed merged round requeues every member's requests in
//!   their original FIFO positions, exactly like a failed solo round.
//!
//! Note on round overlap: `MultiServer` itself dispatches lanes one at
//! a time (`dispatch_next` is `&mut self`), so it does NOT overlap
//! NETFUSE rounds. The fleet's [`ArenaPair`] enables overlap for
//! *concurrent* callers of `Fleet::run_round_slots` — e.g. one driver
//! thread per lane — `benches/multi_fleet.rs` measures that win
//! directly. The async ingress feeding this type from outside the
//! dispatch thread lives in [`crate::ingress`] (`IngressBridge` +
//! `run_dispatch`).
//!
//! Like [`Server`], the type is generic over [`RoundExecutor`] so the
//! scheduling logic is testable without artifacts.
//!
//! [`Fleet::load_with_pool`]: super::service::Fleet::load_with_pool
//! [`WorkerPool`]: super::pool::WorkerPool
//! [`WorkerPool::machine_sized`]: super::pool::WorkerPool::machine_sized
//! [`ArenaPair`]: super::arena::ArenaPair

use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::ingress::qos::{LaneQos, LaneSnapshot, QosScheduler};
use crate::tensor::Tensor;

use super::arena::SlotMap;
use super::coalesce::{plan_group, CoalesceKey};
use super::request::{Request, Response};
use super::server::{Admit, Server, ServerConfig};
use super::service::{Fleet, RoundExecutor};
use super::strategy::StrategyKind;

/// One registered coalesce group: the group-level executor (for real
/// fleets, the fused program compiled at the members' total slot
/// count), the member lanes in megabatch-window order, and the slot
/// remap between the two.
struct Group<'f, E: RoundExecutor> {
    exec: &'f E,
    members: Vec<usize>,
    map: SlotMap,
    rounds: u64,
    responses: u64,
}

/// Cumulative accounting for one coalesce group.
#[derive(Debug, Clone, Copy, Default)]
pub struct GroupStats {
    /// merged rounds dispatched through the group executor
    pub rounds: u64,
    /// responses those merged rounds produced (across all members)
    pub responses: u64,
}

/// What one [`MultiServer::dispatch_next`] did.
#[derive(Debug, Clone, Copy)]
pub struct Dispatched {
    /// the lane the QoS scheduler picked (and charged)
    pub lane: usize,
    /// responses appended — for a coalesced round these span every
    /// served member lane, not just `lane`
    pub responses: usize,
    /// lanes whose requests this round served: 1 for a solo round,
    /// >= 2 for a coalesced group round
    pub lanes_served: usize,
    /// the pick came from the SLO boost (solo, possibly padded round)
    pub urgent: bool,
}

/// Multi-tenant serving front end: one [`Server`] lane per fleet,
/// QoS-scheduled (WDRR + SLO boost) round dispatch across lanes, with
/// optional cross-fleet round coalescing.
pub struct MultiServer<'f, E: RoundExecutor = Fleet> {
    lanes: Vec<Server<'f, E>>,
    sched: QosScheduler,
    /// registered coalesce groups (disjoint member sets)
    groups: Vec<Group<'f, E>>,
    /// lane -> its group, parallel to `lanes`
    group_of: Vec<Option<usize>>,
    /// merged-round output scratch, reused across coalesced rounds
    group_outs: Vec<Option<Tensor>>,
}

impl<'f, E: RoundExecutor> Default for MultiServer<'f, E> {
    fn default() -> Self {
        Self::new()
    }
}

fn snapshot<E: RoundExecutor>(lane: &Server<'_, E>) -> LaneSnapshot {
    LaneSnapshot {
        ready: lane.round_ready(),
        pending: lane.pending(),
        oldest_wait: lane.oldest_wait(),
    }
}

impl<'f, E: RoundExecutor> MultiServer<'f, E> {
    pub fn new() -> MultiServer<'f, E> {
        Self::with_boost_margin(QosScheduler::DEFAULT_BOOST_MARGIN)
    }

    /// `boost_margin` is the scheduler's default ε: how close to its
    /// SLO a lane's oldest wait may get before the lane preempts WDRR.
    /// Individual lanes can override it per lane via
    /// [`LaneQos::with_boost_margin`] at `add_lane_qos` time.
    pub fn with_boost_margin(eps: Duration) -> MultiServer<'f, E> {
        MultiServer {
            lanes: Vec::new(),
            sched: QosScheduler::new(eps),
            groups: Vec::new(),
            group_of: Vec::new(),
            group_outs: Vec::new(),
        }
    }

    /// Register one fleet as a tenant with default QoS (weight 1, no
    /// effective SLO — plain fair round-robin); returns its lane index
    /// (the handle used by [`MultiServer::offer`]).
    pub fn add_lane(&mut self, fleet: &'f E, cfg: ServerConfig) -> usize {
        self.add_lane_qos(fleet, cfg, LaneQos::default())
    }

    /// Register one fleet as a tenant with an explicit [`LaneQos`]
    /// (WDRR weight + SLO). The lane's metrics count violations of
    /// `qos.slo` from here on.
    pub fn add_lane_qos(&mut self, fleet: &'f E, cfg: ServerConfig, qos: LaneQos) -> usize {
        let mut server = Server::new(fleet, cfg);
        server.metrics.slo = Some(qos.slo.as_secs_f64());
        self.lanes.push(server);
        self.group_of.push(None);
        self.sched.add_lane(qos)
    }

    /// Register `members` as a coalesce group executing merged rounds
    /// on `exec`. Validation (same model family, request shape, and
    /// slot count across members; `exec` sized to exactly the members'
    /// total — see [`super::coalesce::plan_group`]) rejects any lane
    /// set that could not share a megabatch; a lane can belong to at
    /// most one group. Returns the group handle.
    pub fn add_coalesce_group(&mut self, exec: &'f E, members: &[usize]) -> Result<usize> {
        for (a, &l) in members.iter().enumerate() {
            if l >= self.lanes.len() {
                bail!("no lane {l} (have {})", self.lanes.len());
            }
            if self.group_of[l].is_some() {
                bail!("lane {l} already belongs to a coalesce group");
            }
            if members[..a].contains(&l) {
                bail!("lane {l} listed twice in one coalesce group");
            }
        }
        let execs: Vec<&E> = members.iter().map(|&l| self.lanes[l].fleet()).collect();
        let map = plan_group(exec, &execs)?;
        let g = self.groups.len();
        for &l in members {
            self.group_of[l] = Some(g);
        }
        self.groups.push(Group {
            exec,
            members: members.to_vec(),
            map,
            rounds: 0,
            responses: 0,
        });
        Ok(g)
    }

    /// Form a coalesce group automatically: scan registered lanes (in
    /// lane order) for ungrouped ones whose coalesce key — (model
    /// family, request shape, slot count) — matches `exec`'s family and
    /// shape, taking the first matching lane's slot count as the
    /// group's, until `exec`'s capacity is filled. Lanes with a
    /// mismatched key are skipped, never coalesced. Returns `Ok(None)`
    /// when fewer than two matching lanes exist or their total does not
    /// fill `exec` exactly.
    pub fn auto_coalesce(&mut self, exec: &'f E) -> Result<Option<usize>> {
        let want = CoalesceKey::of(exec);
        let mut members: Vec<usize> = Vec::new();
        let mut lane_m: Option<usize> = None;
        for (l, lane) in self.lanes.iter().enumerate() {
            if self.group_of[l].is_some() {
                continue;
            }
            let k = CoalesceKey::of(lane.fleet());
            if k.family != want.family || k.request_shape != want.request_shape {
                continue;
            }
            match lane_m {
                None => lane_m = Some(k.slots),
                Some(m) if m != k.slots => continue,
                Some(_) => {}
            }
            if (members.len() + 1) * lane_m.unwrap() > want.slots {
                break; // group executor full
            }
            members.push(l);
        }
        match lane_m {
            Some(m) if members.len() >= 2 && members.len() * m == want.slots => {
                Ok(Some(self.add_coalesce_group(exec, &members)?))
            }
            _ => Ok(None),
        }
    }

    /// Number of registered coalesce groups.
    pub fn coalesce_groups(&self) -> usize {
        self.groups.len()
    }

    /// Member lanes of group `g`, in megabatch-window order.
    pub fn group_members(&self, g: usize) -> &[usize] {
        &self.groups[g].members
    }

    /// Cumulative merged-round accounting for group `g`.
    pub fn group_stats(&self, g: usize) -> GroupStats {
        GroupStats { rounds: self.groups[g].rounds, responses: self.groups[g].responses }
    }

    /// The coalesce group `lane` belongs to, if any.
    pub fn lane_group(&self, lane: usize) -> Option<usize> {
        self.group_of[lane]
    }

    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Per-lane router/batcher (queue state, metrics).
    pub fn lane(&self, lane: usize) -> &Server<'f, E> {
        &self.lanes[lane]
    }

    /// The scheduling contract `lane` was registered with.
    pub fn qos(&self, lane: usize) -> LaneQos {
        self.sched.qos(lane)
    }

    /// Route one request to `lane`'s per-model queues.
    pub fn offer(&mut self, lane: usize, req: Request) -> Result<Admit> {
        if lane >= self.lanes.len() {
            bail!("no lane {lane} (have {})", self.lanes.len());
        }
        Ok(self.lanes[lane].offer(req))
    }

    /// Total queued requests across all lanes.
    pub fn pending(&self) -> usize {
        self.lanes.iter().map(|l| l.pending()).sum()
    }

    /// The lane the QoS scheduler would dispatch next: an SLO-urgent
    /// lane first, otherwise the WDRR pick among round-ready lanes.
    /// `None` when nothing is due. Pure — deficits are only charged by
    /// an actual [`MultiServer::dispatch_next`].
    pub fn ready_lane(&self) -> Option<usize> {
        let lanes = &self.lanes;
        self.sched.select(&|i| snapshot(&lanes[i])).map(|p| p.lane)
    }

    /// How long until some lane becomes due (batching deadline or SLO
    /// boost), `Duration::ZERO` if one already is, `None` when every
    /// queue is empty. This is the longest an ingress loop may block
    /// without risking an idle dispatch thread next to a due round.
    pub fn next_due_in(&self) -> Option<Duration> {
        if self.ready_lane().is_some() {
            return Some(Duration::ZERO);
        }
        let mut best: Option<Duration> = None;
        for (i, lane) in self.lanes.iter().enumerate() {
            let Some(wait) = lane.oldest_wait() else { continue };
            let qos = self.sched.qos(i);
            let batch_due = lane.config().max_wait.saturating_sub(wait);
            let slo_due = qos
                .slo
                .saturating_sub(self.sched.lane_boost_margin(i))
                .saturating_sub(wait);
            let due = batch_due.min(slo_due);
            best = Some(match best {
                Some(b) => b.min(due),
                None => due,
            });
        }
        best
    }

    /// Dispatch the next due round (QoS pick), appending its responses
    /// to `responses`. Returns `Some(`[`Dispatched`]`)`, or `None` when
    /// no lane is due yet. An SLO-urgent pick dispatches even if the
    /// lane's round is not batching-ready — the round pads, and it
    /// always runs **solo** on the lane's own executor. A non-urgent
    /// pick on a coalesce-group member with at least one other member
    /// holding work dispatches a **merged** group round instead: every
    /// member's queue fronts pack into one megabatch (members that are
    /// not yet batching-ready ride along — their windows would
    /// otherwise pad), and responses scatter back per lane. A failed
    /// round — solo or merged — requeues its requests inside the
    /// owning lane(s) (original FIFO order and wait clocks) and
    /// surfaces the error; the cursor and deficit still advance past
    /// the picked lane so a persistently failing fleet cannot starve
    /// the others.
    pub fn dispatch_next(
        &mut self,
        responses: &mut Vec<Response>,
    ) -> Result<Option<Dispatched>> {
        let pick = {
            let lanes = &self.lanes;
            match self.sched.select(&|i| snapshot(&lanes[i])) {
                Some(p) => p,
                None => return Ok(None),
            }
        };
        {
            let lanes = &self.lanes;
            self.sched.commit(&pick, &|i| snapshot(&lanes[i]));
        }
        if !pick.urgent {
            if let Some(g) = self.group_of[pick.lane] {
                let live = self.groups[g]
                    .members
                    .iter()
                    .filter(|&&l| self.lanes[l].pending() > 0)
                    .count();
                if live >= 2 {
                    let (lanes_served, n) = self.dispatch_group(g, responses)?;
                    return Ok(Some(Dispatched {
                        lane: pick.lane,
                        responses: n,
                        lanes_served,
                        urgent: false,
                    }));
                }
            }
        }
        let n = self.lanes[pick.lane].dispatch_into(responses)?;
        Ok(Some(Dispatched {
            lane: pick.lane,
            responses: n,
            lanes_served: 1,
            urgent: pick.urgent,
        }))
    }

    /// One merged round over group `g`: take every member's queue
    /// fronts, execute the group's megabatch once, scatter the outputs
    /// back through each member's response path. Returns
    /// `(lanes_served, responses)`.
    fn dispatch_group(
        &mut self,
        g: usize,
        responses: &mut Vec<Response>,
    ) -> Result<(usize, usize)> {
        // field-level borrow split: `groups` is only read while `lanes`
        // and the output scratch are driven through the round phases
        let groups = &self.groups;
        let lanes = &mut self.lanes;
        let outs = &mut self.group_outs;
        let group = &groups[g];

        // take: pop each member's fronts into its round scratch. Members
        // with nothing queued still "take" (an empty round) so their
        // megabatch windows pad; they are not counted as served.
        let mut lanes_served = 0usize;
        for &l in &group.members {
            if lanes[l].take_round() > 0 {
                lanes_served += 1;
            }
        }

        // execute: ONE merged round through the group executor; the
        // `get` closure is the SlotMap remap (group slot -> member
        // lane's local slot). Coalescing exists to amortize the merged
        // program's launch, so the group round is always NETFUSE.
        let t0 = Instant::now();
        let run = {
            let lanes = &*lanes;
            let get = |gs: usize| {
                let (k, local) = group.map.locate(gs);
                lanes[group.members[k]].slot_input(local)
            };
            group.exec.run_round_slots(StrategyKind::NetFuse, &get, outs)
        };
        if let Err(e) = run {
            // merged-round failure: every member requeues its own
            // fronts — per-queue FIFO order and wait clocks survive the
            // remap, exactly like a failed solo round
            for &l in &group.members {
                lanes[l].requeue_taken();
            }
            return Err(e);
        }

        // verify the WHOLE merged output before any lane consumes a
        // slot: a short or hole-y result from a misbehaving group
        // executor must requeue every member, not answer some lanes
        // and drop the rest mid-scatter
        let bad = if outs.len() != group.map.total() {
            Some(format!(
                "executor returned {} outputs for {} group slots",
                outs.len(),
                group.map.total()
            ))
        } else {
            (0..group.map.total())
                .find(|&gs| {
                    let (k, local) = group.map.locate(gs);
                    lanes[group.members[k]].slot_input(local).is_some() && outs[gs].is_none()
                })
                .map(|gs| format!("group slot {gs} produced no output for an occupied slot"))
        };
        if let Some(msg) = bad {
            for &l in &group.members {
                lanes[l].requeue_taken();
            }
            bail!("coalesced round: {msg}");
        }

        // scatter: each member completes against its lane-relative
        // window of the merged output. Round time is the merged round's
        // wall time, attributed to every lane that actually held work.
        let secs = t0.elapsed().as_secs_f64();
        let mut n = 0usize;
        for (k, &l) in group.members.iter().enumerate() {
            let window = group.map.slots_of(k);
            let occupied = (0..window.len()).any(|local| lanes[l].slot_input(local).is_some());
            if !occupied {
                continue;
            }
            match lanes[l].complete_round(secs, &mut outs[window], responses) {
                Ok(c) => n += c,
                Err(e) => {
                    // mid-scatter failure (unreachable after the group
                    // verification above, kept as defense): the failing
                    // lane requeued its own round inside complete_round;
                    // members not yet scattered must requeue too or
                    // their taken requests would leak
                    for &rest in &group.members[k + 1..] {
                        lanes[rest].requeue_taken();
                    }
                    return Err(e);
                }
            }
        }
        let group = &mut self.groups[g];
        group.rounds += 1;
        group.responses += n as u64;
        Ok((lanes_served, n))
    }

    /// Dispatch (padded) rounds until every queue on every lane is
    /// empty, appending all responses. Returns the number of responses.
    /// Unlike [`MultiServer::dispatch_next`], this drains lanes whose
    /// rounds are not yet due — it is the shutdown/flush path.
    pub fn drain(&mut self, responses: &mut Vec<Response>) -> Result<usize> {
        let mut total = 0;
        while self.pending() > 0 {
            // round-robin over lanes with work so the flush stays fair
            let n = self.lanes.len();
            let lane = (0..n)
                .map(|k| (self.sched.cursor() + k) % n)
                .find(|&i| self.lanes[i].pending() > 0)
                .expect("pending() > 0 implies some lane has work");
            self.sched.rotate_after(lane);
            total += self.lanes[lane].dispatch_into(responses)?;
        }
        Ok(total)
    }
}
