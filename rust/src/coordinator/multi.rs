//! `MultiServer`: several fleets served as tenants of one machine.
//!
//! The paper evaluates many merged fleets per GPU (§5), but PR 1's
//! serving loop was single-tenant: one [`Server`] per fleet, and —
//! because every fleet lazily spawned its own [`WorkerPool`] — one
//! thread set per fleet, so an M1-fleet plus an M2-fleet cost M1+M2
//! workers on a machine with far fewer cores.
//!
//! `MultiServer` fixes both:
//! - **per-fleet lanes** — each fleet keeps its own router/batcher
//!   ([`Server`]) with independent queues, strategy, and metrics;
//! - **round-ready scheduling across fleets** — [`MultiServer::ready_lane`]
//!   scans lanes for one whose round is due (full, or past its oldest
//!   request's `max_wait` deadline);
//! - **fair dispatch** — the scan starts after the last dispatched lane
//!   (round-robin), so a lane with steady traffic cannot starve one
//!   with sparse traffic;
//! - **one shared `WorkerPool`** — load every fleet with
//!   [`Fleet::load_with_pool`] and a single
//!   [`WorkerPool::machine_sized`] handle, and all Concurrent/Hybrid
//!   rounds dispatch onto one thread set sized to the machine instead
//!   of one pool per fleet.
//!
//! Note on round overlap: `MultiServer` itself dispatches lanes one at
//! a time (`dispatch_next` is `&mut self`), so it does NOT overlap
//! NETFUSE rounds. The fleet's [`ArenaPair`] enables overlap for
//! *concurrent* callers of `Fleet::run_round_slots` — e.g. one driver
//! thread per lane, or the async ingress the ROADMAP lists —
//! `benches/multi_fleet.rs` measures that win directly.
//!
//! Like [`Server`], the type is generic over [`RoundExecutor`] so the
//! scheduling logic is testable without artifacts.
//!
//! [`Fleet::load_with_pool`]: super::service::Fleet::load_with_pool
//! [`WorkerPool::machine_sized`]: super::pool::WorkerPool::machine_sized
//! [`ArenaPair`]: super::arena::ArenaPair

use anyhow::{bail, Result};

use super::request::{Request, Response};
use super::server::{Admit, Server, ServerConfig};
use super::service::{Fleet, RoundExecutor};

/// Multi-tenant serving front end: one [`Server`] lane per fleet, fair
/// round-ready dispatch across lanes.
pub struct MultiServer<'f, E: RoundExecutor = Fleet> {
    lanes: Vec<Server<'f, E>>,
    /// fair-dispatch cursor: the lane AFTER the last one dispatched is
    /// scanned first
    cursor: usize,
}

impl<'f, E: RoundExecutor> Default for MultiServer<'f, E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<'f, E: RoundExecutor> MultiServer<'f, E> {
    pub fn new() -> MultiServer<'f, E> {
        MultiServer { lanes: Vec::new(), cursor: 0 }
    }

    /// Register one fleet as a tenant; returns its lane index (the
    /// handle used by [`MultiServer::offer`]).
    pub fn add_lane(&mut self, fleet: &'f E, cfg: ServerConfig) -> usize {
        self.lanes.push(Server::new(fleet, cfg));
        self.lanes.len() - 1
    }

    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Per-lane router/batcher (queue state, metrics).
    pub fn lane(&self, lane: usize) -> &Server<'f, E> {
        &self.lanes[lane]
    }

    /// Route one request to `lane`'s per-model queues.
    pub fn offer(&mut self, lane: usize, req: Request) -> Result<Admit> {
        if lane >= self.lanes.len() {
            bail!("no lane {lane} (have {})", self.lanes.len());
        }
        Ok(self.lanes[lane].offer(req))
    }

    /// Total queued requests across all lanes.
    pub fn pending(&self) -> usize {
        self.lanes.iter().map(|l| l.pending()).sum()
    }

    /// The next lane whose round is due, scanning fairly from the
    /// cursor: a lane is due when every model has work or its oldest
    /// queued request has waited past that lane's `max_wait`.
    pub fn ready_lane(&self) -> Option<usize> {
        let n = self.lanes.len();
        (0..n)
            .map(|k| (self.cursor + k) % n)
            .find(|&i| self.lanes[i].round_ready())
    }

    /// Dispatch the next due lane, appending its responses to
    /// `responses`. Returns `Some((lane, responses_appended))`, or
    /// `None` when no lane is due yet. A failed round requeues its
    /// requests inside the lane (original FIFO order and wait clocks)
    /// and surfaces the error; the cursor still advances past the lane
    /// so a persistently failing fleet cannot starve the others.
    pub fn dispatch_next(
        &mut self,
        responses: &mut Vec<Response>,
    ) -> Result<Option<(usize, usize)>> {
        let Some(lane) = self.ready_lane() else {
            return Ok(None);
        };
        self.cursor = (lane + 1) % self.lanes.len();
        let n = self.lanes[lane].dispatch_into(responses)?;
        Ok(Some((lane, n)))
    }

    /// Dispatch (padded) rounds until every queue on every lane is
    /// empty, appending all responses. Returns the number of responses.
    /// Unlike [`MultiServer::dispatch_next`], this drains lanes whose
    /// rounds are not yet due — it is the shutdown/flush path.
    pub fn drain(&mut self, responses: &mut Vec<Response>) -> Result<usize> {
        let mut total = 0;
        while self.pending() > 0 {
            // round-robin over lanes with work so the flush stays fair
            let n = self.lanes.len();
            let lane = (0..n)
                .map(|k| (self.cursor + k) % n)
                .find(|&i| self.lanes[i].pending() > 0)
                .expect("pending() > 0 implies some lane has work");
            self.cursor = (lane + 1) % n;
            total += self.lanes[lane].dispatch_into(responses)?;
        }
        Ok(total)
    }
}
