//! The four execution strategies (paper §5.1).

use std::fmt;

use anyhow::{bail, Result};

/// How the fleet executes one round of M per-model requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StrategyKind {
    /// Round-robin, one model at a time — the paper's `Sequential`.
    Sequential,
    /// One unsynchronized worker per model — the paper's `Concurrent`
    /// (process-per-model; threads here, with per-process base memory
    /// charged by the memory model).
    Concurrent,
    /// `procs` workers, each running M/procs models sequentially — the
    /// paper's `Hybrid` "(Ap, Bm)" configurations (§5.3).
    Hybrid { procs: usize },
    /// One merged executable for all M models — NETFUSE.
    NetFuse,
}

impl StrategyKind {
    /// Parse CLI spellings: `sequential`, `concurrent`, `hybrid:4`,
    /// `netfuse`.
    pub fn parse(s: &str) -> Result<StrategyKind> {
        let s = s.trim().to_ascii_lowercase();
        Ok(match s.as_str() {
            "sequential" | "seq" => StrategyKind::Sequential,
            "concurrent" | "conc" => StrategyKind::Concurrent,
            "netfuse" | "fused" => StrategyKind::NetFuse,
            _ => {
                if let Some(p) = s.strip_prefix("hybrid:") {
                    let procs: usize = p
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad hybrid procs {p:?}"))?;
                    if procs == 0 {
                        bail!("hybrid needs >= 1 proc");
                    }
                    StrategyKind::Hybrid { procs }
                } else {
                    bail!(
                        "unknown strategy {s:?} (want sequential | concurrent \
                         | hybrid:<procs> | netfuse)"
                    );
                }
            }
        })
    }

    /// Reject configurations that only `parse` used to catch:
    /// `Hybrid { procs: 0 }` can be built directly (bypassing
    /// [`StrategyKind::parse`]) and would otherwise be silently clamped
    /// to one worker deep in the dispatch path. Round executors call
    /// this at their entry so the misconfiguration fails loudly instead.
    pub fn validate(&self) -> Result<()> {
        if let StrategyKind::Hybrid { procs: 0 } = self {
            bail!("hybrid strategy needs >= 1 proc (got procs: 0)");
        }
        Ok(())
    }

    /// Number of "processes" the memory model charges base memory for.
    pub fn processes(&self, m: usize) -> usize {
        match self {
            StrategyKind::Sequential | StrategyKind::NetFuse => 1,
            StrategyKind::Concurrent => m,
            StrategyKind::Hybrid { procs } => (*procs).min(m),
        }
    }
}

impl fmt::Display for StrategyKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StrategyKind::Sequential => write!(f, "sequential"),
            StrategyKind::Concurrent => write!(f, "concurrent"),
            StrategyKind::Hybrid { procs } => write!(f, "hybrid:{procs}"),
            StrategyKind::NetFuse => write!(f, "netfuse"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all() {
        assert_eq!(StrategyKind::parse("seq").unwrap(), StrategyKind::Sequential);
        assert_eq!(
            StrategyKind::parse("hybrid:4").unwrap(),
            StrategyKind::Hybrid { procs: 4 }
        );
        assert_eq!(StrategyKind::parse("NetFuse").unwrap(), StrategyKind::NetFuse);
        assert!(StrategyKind::parse("hybrid:0").is_err());
        assert!(StrategyKind::parse("warp").is_err());
    }

    #[test]
    fn display_roundtrip() {
        for s in [
            StrategyKind::Sequential,
            StrategyKind::Concurrent,
            StrategyKind::Hybrid { procs: 8 },
            StrategyKind::NetFuse,
        ] {
            assert_eq!(StrategyKind::parse(&s.to_string()).unwrap(), s);
        }
    }

    #[test]
    fn validate_rejects_directly_built_zero_procs() {
        // `hybrid:0` is unparseable, but the literal can be constructed
        let err = StrategyKind::Hybrid { procs: 0 }.validate().unwrap_err();
        assert!(err.to_string().contains(">= 1 proc"), "got: {err}");
        for ok in [
            StrategyKind::Sequential,
            StrategyKind::Concurrent,
            StrategyKind::Hybrid { procs: 1 },
            StrategyKind::NetFuse,
        ] {
            ok.validate().unwrap();
        }
    }

    #[test]
    fn process_counts() {
        assert_eq!(StrategyKind::Sequential.processes(32), 1);
        assert_eq!(StrategyKind::Concurrent.processes(32), 32);
        assert_eq!(StrategyKind::Hybrid { procs: 4 }.processes(32), 4);
        assert_eq!(StrategyKind::Hybrid { procs: 64 }.processes(32), 32);
        assert_eq!(StrategyKind::NetFuse.processes(32), 1);
    }
}
