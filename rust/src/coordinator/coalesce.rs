//! Cross-fleet round coalescing: group formation and validation.
//!
//! NETFUSE's win is that one merged execution amortizes per-model
//! overhead (paper §3); `MultiServer` still paid that overhead once per
//! *lane*, even when two lanes serve the same model family at the same
//! batch size. A **coalesce group** closes that gap at the serving
//! level: member lanes keep their own queues, QoS contracts, and
//! metrics, but their rounds pack into ONE shared megabatch executed by
//! a single group-level executor (for real fleets: the fused artifact
//! compiled at the group's total instance count), and the outputs
//! scatter back through each lane's own response routing.
//!
//! Groups are keyed by [`CoalesceKey`] — **(model family, request
//! shape, slot count)**. All three must match for lanes to share a
//! megabatch:
//! - *family* (`RoundExecutor::name`): different families have
//!   different merged programs — nothing to share;
//! - *request shape* (`[bs, ...input]`): the megabatch windows are
//!   fixed-shape; a mismatched payload cannot occupy a window;
//! - *slot count* (`m`): uniform windows keep the [`SlotMap`] a pure
//!   offset table and the group executor's instance count an exact
//!   multiple of the lane's.
//!
//! This module owns the *pure* half of the feature (keys, validation,
//! slot-map planning) so it is unit-testable without a `MultiServer`;
//! the dispatch half (group-ready selection, megabatch execution,
//! response scatter) lives in [`super::multi`]. See
//! `docs/ADR-002-coalescing.md` for the full design, including why an
//! SLO-boosted lane always dispatches solo instead of riding a group.
//!
//! [`plan_group`]'s validation is **construction-time strict**: the
//! group executor must be exactly full. After formation, membership is
//! elastic (ADR-005) — `MultiServer` shrinks/grows the `SlotMap`
//! between rounds as lanes retire or install, with the executor's
//! compiled width as the ceiling and unused windows padding.

use anyhow::{bail, Result};

use super::arena::SlotMap;
use super::service::RoundExecutor;

/// What must match for lanes to coalesce: (model family, request shape,
/// slot count).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CoalesceKey {
    /// model family (`RoundExecutor::name`)
    pub family: String,
    /// per-request payload shape `[bs, ...input_shape]`
    pub request_shape: Vec<usize>,
    /// instance slots per round (`RoundExecutor::m`)
    pub slots: usize,
}

impl CoalesceKey {
    /// The coalesce key of one executor.
    pub fn of<E: RoundExecutor + ?Sized>(e: &E) -> CoalesceKey {
        let mut request_shape = vec![e.bs()];
        request_shape.extend_from_slice(e.input_shape());
        CoalesceKey { family: e.name().to_string(), request_shape, slots: e.m() }
    }
}

/// Whether two executors could share a megabatch (same coalesce key).
pub fn compatible<E: RoundExecutor + ?Sized>(a: &E, b: &E) -> bool {
    CoalesceKey::of(a) == CoalesceKey::of(b)
}

/// Validate a proposed group and plan its slot remap.
///
/// `exec` is the group-level executor that will run the merged rounds
/// (for real fleets, the fused program compiled at `members.len() * m`
/// instances); `members` are the member lanes' executors in window
/// order. Rejects — with the reason — any of:
/// - fewer than two members (a 1-lane "group" is just the lane);
/// - members whose key (family, request shape, slot count) differs;
/// - a group executor whose family or request shape differs from the
///   members', or whose slot count is not exactly the members' total.
///
/// On success returns the [`SlotMap`] that remaps each member's local
/// slots into the shared megabatch.
pub fn plan_group<E: RoundExecutor + ?Sized>(exec: &E, members: &[&E]) -> Result<SlotMap> {
    if members.len() < 2 {
        bail!(
            "coalesce group needs >= 2 member lanes, got {}",
            members.len()
        );
    }
    let key = CoalesceKey::of(members[0]);
    for (k, m) in members.iter().enumerate().skip(1) {
        let mk = CoalesceKey::of(*m);
        if mk != key {
            bail!(
                "member {k} cannot coalesce: key {:?} != {:?} \
                 (family, request shape, and slot count must all match)",
                mk,
                key
            );
        }
    }
    let ek = CoalesceKey::of(exec);
    if ek.family != key.family || ek.request_shape != key.request_shape {
        bail!(
            "group executor {:?} serves {:?}, members are {:?} {:?}",
            ek.family,
            ek.request_shape,
            key.family,
            key.request_shape
        );
    }
    let total = members.len() * key.slots;
    if ek.slots != total {
        bail!(
            "group executor has {} slots, {} members x {} slots need exactly {total}",
            ek.slots,
            members.len(),
            key.slots
        );
    }
    SlotMap::uniform(members.len(), key.slots)
}

#[cfg(test)]
mod tests {
    use std::time::Duration;

    use super::*;
    use crate::coordinator::mock::EchoExecutor;

    fn echo(name: &str, m: usize, shape: &[usize]) -> EchoExecutor {
        EchoExecutor::new(name, m, shape, Duration::ZERO)
    }

    #[test]
    fn key_covers_family_shape_and_slots() {
        let a = echo("bert", 2, &[4]);
        assert_eq!(
            CoalesceKey::of(&a),
            CoalesceKey {
                family: "bert".into(),
                request_shape: vec![1, 4],
                slots: 2
            }
        );
        assert!(compatible(&a, &echo("bert", 2, &[4])));
        assert!(!compatible(&a, &echo("resnet", 2, &[4]))); // family
        assert!(!compatible(&a, &echo("bert", 2, &[8]))); // request shape
        assert!(!compatible(&a, &echo("bert", 3, &[4]))); // slot count
    }

    #[test]
    fn plan_group_builds_the_slot_map() {
        let a = echo("bert", 2, &[4]);
        let b = echo("bert", 2, &[4]);
        let g = echo("bert", 4, &[4]);
        let map = plan_group(&g, &[&a, &b]).unwrap();
        assert_eq!(map.lanes(), 2);
        assert_eq!(map.total(), 4);
        assert_eq!(map.slots_of(1), 2..4);
    }

    #[test]
    fn plan_group_rejects_mismatched_members_and_executors() {
        let a = echo("bert", 2, &[4]);
        let g = echo("bert", 4, &[4]);
        // too few members
        assert!(plan_group(&g, &[&a]).is_err());
        // mismatched request shape
        let wide = echo("bert", 2, &[8]);
        let err = plan_group(&g, &[&a, &wide]).unwrap_err();
        assert!(err.to_string().contains("cannot coalesce"), "got: {err}");
        // mismatched slot count
        let tall = echo("bert", 3, &[4]);
        assert!(plan_group(&g, &[&a, &tall]).is_err());
        // mismatched family
        let other = echo("resnet", 2, &[4]);
        assert!(plan_group(&g, &[&a, &other]).is_err());
        // group executor family / shape / capacity mismatches
        let b = echo("bert", 2, &[4]);
        assert!(plan_group(&echo("resnet", 4, &[4]), &[&a, &b]).is_err());
        assert!(plan_group(&echo("bert", 4, &[8]), &[&a, &b]).is_err());
        let err = plan_group(&echo("bert", 6, &[4]), &[&a, &b]).unwrap_err();
        assert!(err.to_string().contains("need exactly 4"), "got: {err}");
    }
}
