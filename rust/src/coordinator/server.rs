//! The serving loop: ingress -> per-model queues -> batcher -> strategy
//! -> responses. Used by `examples/serve_multimodel.rs` (the end-to-end
//! driver) and by the integration tests.
//!
//! Routing and batching mirror a production multi-model router
//! (vLLM-router-style): each fine-tuned instance has its own FIFO; the
//! batcher assembles one *round* — up to one request per instance — and
//! hands it to the configured strategy. Instances with an empty queue at
//! dispatch time are padded from the fleet arena's zero block (NETFUSE
//! executes a fixed merged program; padded slots are computed and
//! discarded, which is exactly what the paper's fixed merged graph
//! implies). Bounded queues provide backpressure.
//!
//! The `max_wait` batching deadline derives from the **oldest queued
//! request's `arrived` timestamp**, recomputed from the queue fronts on
//! every [`Server::round_ready`] check. (An earlier version kept a
//! single `oldest_wait_start: Instant` that was overwritten with
//! `Instant::now()` on every dispatch — a request left queued behind a
//! dispatched one had its wait clock silently restarted each round,
//! violating the latency SLO under steady traffic.)
//!
//! The server is generic over [`RoundExecutor`] (default: [`Fleet`]) so
//! the batching/requeue logic is testable without artifacts.
//!
//! Dispatch scratch (`slots`, `outs`, and the response buffer used by
//! [`Server::run_rounds`]) lives on the server and is cleared, not
//! reallocated, each round. On the NETFUSE strategy the host-side
//! pack/unpack path is then allocation-free in steady state (the bench
//! gates this); response payloads always allocate (they leave the
//! server), and Concurrent/Hybrid rounds additionally allocate their
//! per-round job scaffolding inside `WorkerPool::run_chunked`.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::tensor::Tensor;
use crate::util::shard::ShardHandle;

use super::metrics::{Metrics, MetricsCore};
use super::request::{Request, Response};
use super::service::{Fleet, RoundExecutor};
use super::strategy::StrategyKind;

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub strategy: StrategyKind,
    /// per-model queue capacity; arrivals beyond this are rejected
    /// (backpressure signal to the client). Clamped to >= 1 by
    /// `Server::new` — a capacity of zero would make every request
    /// inadmissible.
    pub queue_cap: usize,
    /// dispatch a partial (padded) round after this long
    pub max_wait: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            strategy: StrategyKind::NetFuse,
            queue_cap: 64,
            max_wait: Duration::from_millis(5),
        }
    }
}

/// Outcome of offering a request to the router.
#[derive(Debug, PartialEq, Eq)]
pub enum Admit {
    Queued,
    /// queue full — caller should retry later (backpressure)
    Rejected,
    /// payload shape does not match the fleet — never admissible.
    /// Validated at ingress so a malformed request can fail alone
    /// instead of poisoning whole rounds at dispatch time.
    Invalid,
}

/// Single-tenant-fleet server: router + batcher + strategy executor.
pub struct Server<'f, E: RoundExecutor = Fleet> {
    fleet: &'f E,
    cfg: ServerConfig,
    queues: Vec<VecDeque<Request>>,
    /// per-round slot scratch (one popped request per instance), reused
    slots: Vec<Option<Request>>,
    /// per-round output scratch, reused
    outs: Vec<Option<Tensor>>,
    /// arrival-stamp floor: starts at server creation and advances with
    /// every admission, so no `offer` can fake queue-wait history with
    /// a backdated `arrived` (even into an empty queue)
    arrival_floor: Instant,
    pub metrics: Metrics,
}

impl<'f, E: RoundExecutor> Server<'f, E> {
    pub fn new(fleet: &'f E, cfg: ServerConfig) -> Server<'f, E> {
        let cfg = ServerConfig { queue_cap: cfg.queue_cap.max(1), ..cfg };
        let metrics = Metrics::new(cfg.strategy, fleet.name(), fleet.m(), fleet.bs());
        Server {
            fleet,
            cfg,
            queues: (0..fleet.m()).map(|_| VecDeque::new()).collect(),
            slots: Vec::with_capacity(fleet.m()),
            outs: Vec::with_capacity(fleet.m()),
            arrival_floor: Instant::now(),
            metrics,
        }
    }

    /// The executor this server dispatches onto.
    pub fn fleet(&self) -> &'f E {
        self.fleet
    }

    /// Hot-swap this server's model weights to version `tag` (full
    /// instance range), between rounds — see
    /// [`RoundExecutor::swap_model`]. Call strictly between
    /// [`Server::dispatch`] calls; queued requests are untouched and
    /// the next round serves the new weights.
    pub fn swap_model(&self, tag: u64) -> Result<Duration> {
        self.fleet.swap_model(0..self.fleet.m(), tag)
    }

    pub fn config(&self) -> &ServerConfig {
        &self.cfg
    }

    /// Mirror this lane's metrics into a [`MetricsHub`] shard (the
    /// dispatch thread's own) — see [`Metrics::attach_sink`].
    ///
    /// [`MetricsHub`]: super::metrics::MetricsHub
    pub fn attach_metrics_sink(&mut self, sink: ShardHandle<MetricsCore>) {
        self.metrics.attach_sink(sink);
    }

    /// Route one request to its model queue.
    pub fn offer(&mut self, req: Request) -> Admit {
        // ingress validation (allocation-free): a malformed request —
        // out-of-range routing index or wrong-shaped payload — is
        // rejected here, per request, rather than failing (and being
        // requeued with) an entire round at dispatch
        let mut req = req;
        let shape = req.input.shape();
        let bs = self.fleet.bs();
        if req.model_idx >= self.fleet.m()
            || shape.first() != Some(&bs)
            || shape[1..] != self.fleet.input_shape()[..]
        {
            return Admit::Invalid;
        }
        let q = &mut self.queues[req.model_idx];
        if q.len() >= self.cfg.queue_cap {
            return Admit::Rejected;
        }
        // arrival monotonicity: the queue fronts drive the max_wait and
        // SLO clocks, so a producer that reuses a stale `arrived` stamp
        // (e.g. cloning one Request for a whole batch) must not fake
        // queue-wait history. Clamp to the server-wide floor — creation
        // time, then every prior admission — so admission order IS
        // arrival order, including into an empty queue. Ingress paths
        // re-stamp via `Request::arrived_now` before offering, so the
        // clamp only fires for misbehaving direct callers.
        if req.arrived < self.arrival_floor {
            req.arrived = self.arrival_floor;
        }
        self.arrival_floor = req.arrived;
        q.push_back(req);
        Admit::Queued
    }

    pub fn pending(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Arrival time of the oldest queued request, derived from the
    /// queue fronts (each queue is FIFO, so its front is its oldest;
    /// failed-round requeues push_front, restoring the original order).
    /// This is the `max_wait` clock — per request, never reset by a
    /// dispatch.
    fn oldest_arrival(&self) -> Option<Instant> {
        self.queues.iter().filter_map(|q| q.front()).map(|r| r.arrived).min()
    }

    /// How long the oldest queued request has been waiting (the value
    /// the batching deadline and the QoS scheduler's SLO boost compare
    /// against). `None` when every queue is empty.
    pub fn oldest_wait(&self) -> Option<Duration> {
        self.oldest_arrival().map(|t| t.elapsed())
    }

    /// True when a round should dispatch: either every model has work, or
    /// the oldest queued request has waited past `max_wait` since it
    /// ARRIVED (not since the last dispatch — a request left queued
    /// behind a dispatched one keeps its original deadline).
    pub fn round_ready(&self) -> bool {
        // nothing queued -> never ready (also keeps a degenerate
        // m() == 0 executor from making the all-non-empty check
        // vacuously true and spinning dispatch loops forever)
        if self.pending() == 0 {
            return false;
        }
        if self.queues.iter().all(|q| !q.is_empty()) {
            return true;
        }
        match self.oldest_arrival() {
            Some(t) => t.elapsed() >= self.cfg.max_wait,
            None => false,
        }
    }

    /// Assemble a (possibly padded) round, execute it, emit responses.
    pub fn dispatch(&mut self) -> Result<Vec<Response>> {
        let mut responses = Vec::new();
        self.dispatch_into(&mut responses)?;
        Ok(responses)
    }

    /// Like [`Server::dispatch`], but appends into a caller-owned buffer
    /// (the allocation-free steady-state entry point). Returns the number
    /// of responses appended.
    pub fn dispatch_into(&mut self, responses: &mut Vec<Response>) -> Result<usize> {
        self.take_round();
        let slots = &self.slots;
        let get = |i: usize| slots[i].as_ref().map(|r| &r.input);
        let t0 = Instant::now();
        let round = self
            .fleet
            .run_round_slots(self.cfg.strategy, &get, &mut self.outs);
        if let Err(e) = round {
            // a failed round must not destroy its requests: put them
            // back at the head of their queues. Payload shapes were
            // validated at ingress (`offer`), so an error here is
            // fleet/runtime-level, not attributable to one request —
            // the caller decides whether to retry or tear down.
            self.requeue_taken();
            return Err(e);
        }
        let exec_end = Instant::now();
        // hand the output scratch to the shared completion path without
        // aliasing `self` (the Vec swap moves no elements)
        let mut outs = std::mem::take(&mut self.outs);
        let res = self.complete_round(t0, exec_end, &mut outs, responses);
        self.outs = outs;
        res
    }

    /// Pop one request per model queue into the round scratch — the
    /// **take** phase of a round, split out so a coalesced dispatch
    /// (`MultiServer` group rounds) can pop several lanes before one
    /// merged execution. Returns the number of occupied slots. Every
    /// taken round should be finished with [`Server::complete_round`]
    /// or [`Server::requeue_taken`] before the next take; a round left
    /// unfinished is requeued here rather than leaked. `offer` remains
    /// safe in between (it appends to the queues, not the scratch).
    pub fn take_round(&mut self) -> usize {
        // self-healing: a round left neither completed nor requeued (a
        // caller bug or an abandoned error path) must not leak its
        // requests when the scratch is cleared — restore them to their
        // queue fronts first. A no-op for the well-behaved steady state
        // (every slot is None between rounds).
        self.requeue_taken();
        self.slots.clear();
        let mut taken = 0;
        // one pick stamp per round (ADR-006 queue-stage boundary)
        let picked = Instant::now();
        for q in self.queues.iter_mut() {
            let mut r = q.pop_front();
            if let Some(req) = r.as_mut() {
                req.stamps.picked = Some(picked);
            }
            taken += r.is_some() as usize;
            self.slots.push(r);
        }
        // NOTE: no batching-clock bookkeeping here — the `max_wait`
        // deadline is derived per request from `arrived` in
        // `round_ready`, so requests left queued (or requeued by a
        // failed round) keep their original wait clocks.
        taken
    }

    /// The payload taken for local slot `i`, if any (the lane-relative
    /// lookup a coalesced pack remaps through `arena::SlotMap`).
    pub fn slot_input(&self, i: usize) -> Option<&Tensor> {
        self.slots.get(i).and_then(|s| s.as_ref()).map(|r| &r.input)
    }

    /// The **complete** phase of a round: validate that every occupied
    /// slot produced an output, then record metrics and emit responses.
    /// `outs` is index-aligned with this lane's local slots — for a solo
    /// round the server's own scratch, for a coalesced round the lane's
    /// window of the group output. `exec_start`/`exec_end` bracket the
    /// round's execution (for a coalesced round the MERGED execution,
    /// attributed to every participating lane): the round wall time
    /// recorded into metrics is their difference, and both instants are
    /// stamped onto every emitted response (ADR-006 stage tracing).
    /// Validation failure requeues the whole taken round (original FIFO
    /// order) before surfacing, exactly like a failed execution.
    pub fn complete_round(
        &mut self,
        exec_start: Instant,
        exec_end: Instant,
        outs: &mut [Option<Tensor>],
        responses: &mut Vec<Response>,
    ) -> Result<usize> {
        // verify every occupied slot has an output BEFORE consuming any,
        // so a violated strategy invariant (a missing or short `outs`,
        // e.g. from a custom RoundExecutor) requeues the whole round
        // instead of dropping the requests taken so far — or panicking
        // on an out-of-bounds index
        if let Some(i) = (0..self.slots.len())
            .find(|&i| self.slots[i].is_some() && !matches!(outs.get(i), Some(Some(_))))
        {
            self.requeue_taken();
            bail!("model {i} produced no output for an occupied slot");
        }
        self.metrics
            .record_round(exec_end.saturating_duration_since(exec_start).as_secs_f64());

        // one completion stamp per round: latency and the stage stamps
        // are derived from the SAME instant, so the ADR-006 stage
        // segments telescope exactly to the reported latency
        let completed = Instant::now();
        let mut n = 0;
        for (i, slot) in self.slots.iter_mut().enumerate() {
            if let Some(req) = slot.take() {
                let output = outs[i]
                    .take()
                    .expect("verified above: occupied slots have outputs");
                let latency = completed.saturating_duration_since(req.arrived).as_secs_f64();
                self.metrics.record_request(latency);
                let mut stamps = req.stamps;
                stamps.arrived = Some(req.arrived);
                stamps.exec_start = Some(exec_start);
                stamps.exec_end = Some(exec_end);
                stamps.completed = Some(completed);
                responses.push(Response {
                    id: req.id,
                    model_idx: i,
                    output,
                    latency,
                    stamps,
                });
                n += 1;
            }
        }
        Ok(n)
    }

    /// Return every request popped into the round scratch to the head
    /// of its queue (failed-round recovery — each queue gets back its
    /// own front, so per-queue FIFO order and wait clocks survive).
    pub fn requeue_taken(&mut self) {
        for (i, slot) in self.slots.iter_mut().enumerate() {
            if let Some(req) = slot.take() {
                self.queues[i].push_front(req);
            }
        }
    }

    /// Closed-loop driver: feed `rounds` full rounds from `make_round`
    /// and dispatch each. Returns total responses.
    pub fn run_rounds<F>(&mut self, rounds: usize, mut make_round: F) -> Result<usize>
    where
        F: FnMut() -> Vec<Request>,
    {
        let mut total = 0;
        let mut buf = Vec::with_capacity(self.fleet.m());
        for _ in 0..rounds {
            for req in make_round() {
                // backpressure: a full target queue forces (padded)
                // rounds out until a slot frees, so the closed loop
                // never drops an offered request (queue_cap >= 1 is a
                // Server::new invariant, so this always terminates into
                // an admissible state)
                while self.queues[req.model_idx].len() >= self.cfg.queue_cap {
                    total += self.dispatch_into(&mut buf)?;
                    buf.clear();
                }
                match self.offer(req) {
                    Admit::Queued => {}
                    Admit::Invalid => {
                        bail!("run_rounds: request payload shape does not match the fleet")
                    }
                    Admit::Rejected => {
                        bail!("run_rounds: queue still full after drain (invariant violated)")
                    }
                }
            }
            while self.round_ready() {
                total += self.dispatch_into(&mut buf)?;
                buf.clear();
            }
        }
        // drain any padded leftovers
        while self.pending() > 0 {
            total += self.dispatch_into(&mut buf)?;
            buf.clear();
        }
        Ok(total)
    }
}
