//! The serving loop: ingress -> per-model queues -> batcher -> strategy
//! -> responses. Used by `examples/serve_multimodel.rs` (the end-to-end
//! driver) and by the integration tests.
//!
//! Routing and batching mirror a production multi-model router
//! (vLLM-router-style): each fine-tuned instance has its own FIFO; the
//! batcher assembles one *round* — up to one request per instance — and
//! hands it to the configured strategy. Instances with an empty queue at
//! dispatch time are padded with zeros (NETFUSE executes a fixed merged
//! program; padded slots are computed and discarded, which is exactly
//! what the paper's fixed merged graph implies). Bounded queues provide
//! backpressure.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::tensor::Tensor;

use super::metrics::Metrics;
use super::request::{Request, Response};
use super::service::Fleet;
use super::strategy::StrategyKind;

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub strategy: StrategyKind,
    /// per-model queue capacity; arrivals beyond this are rejected
    /// (backpressure signal to the client)
    pub queue_cap: usize,
    /// dispatch a partial (padded) round after this long
    pub max_wait: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            strategy: StrategyKind::NetFuse,
            queue_cap: 64,
            max_wait: Duration::from_millis(5),
        }
    }
}

/// Outcome of offering a request to the router.
#[derive(Debug, PartialEq, Eq)]
pub enum Admit {
    Queued,
    /// queue full — caller should retry later (backpressure)
    Rejected,
}

/// Single-tenant-fleet server: router + batcher + strategy executor.
pub struct Server<'f> {
    fleet: &'f Fleet,
    cfg: ServerConfig,
    queues: Vec<VecDeque<Request>>,
    /// zero tensor used to pad absent slots in a partial round
    pad: Tensor,
    oldest_wait_start: Option<Instant>,
    pub metrics: Metrics,
}

impl<'f> Server<'f> {
    pub fn new(fleet: &'f Fleet, cfg: ServerConfig) -> Server<'f> {
        let pad = Tensor::zeros(&fleet.request_shape());
        let metrics = Metrics::new(cfg.strategy, &fleet.model, fleet.m, fleet.bs);
        Server {
            fleet,
            cfg,
            queues: (0..fleet.m).map(|_| VecDeque::new()).collect(),
            pad,
            oldest_wait_start: None,
            metrics,
        }
    }

    /// Route one request to its model queue.
    pub fn offer(&mut self, req: Request) -> Admit {
        let q = &mut self.queues[req.model_idx];
        if q.len() >= self.cfg.queue_cap {
            return Admit::Rejected;
        }
        q.push_back(req);
        if self.oldest_wait_start.is_none() {
            self.oldest_wait_start = Some(Instant::now());
        }
        Admit::Queued
    }

    pub fn pending(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// True when a round should dispatch: either every model has work, or
    /// the oldest queued request has waited past `max_wait`.
    pub fn round_ready(&self) -> bool {
        if self.pending() == 0 {
            return false;
        }
        if self.queues.iter().all(|q| !q.is_empty()) {
            return true;
        }
        match self.oldest_wait_start {
            Some(t) => t.elapsed() >= self.cfg.max_wait,
            None => false,
        }
    }

    /// Assemble a (possibly padded) round, execute it, emit responses.
    pub fn dispatch(&mut self) -> Result<Vec<Response>> {
        let mut slot: Vec<Option<Request>> = (0..self.fleet.m).map(|_| None).collect();
        for (i, q) in self.queues.iter_mut().enumerate() {
            slot[i] = q.pop_front();
        }
        self.oldest_wait_start = if self.pending() > 0 {
            Some(Instant::now())
        } else {
            None
        };

        let inputs: Vec<&Tensor> = slot
            .iter()
            .map(|s| s.as_ref().map(|r| &r.input).unwrap_or(&self.pad))
            .collect();
        let t0 = Instant::now();
        let outs = self.fleet.run_round(self.cfg.strategy, &inputs)?;
        self.metrics.record_round(t0.elapsed().as_secs_f64());

        let mut responses = Vec::new();
        for (i, (req, out)) in slot.into_iter().zip(outs).enumerate() {
            if let Some(req) = req {
                let latency = req.arrived.elapsed().as_secs_f64();
                self.metrics.record_request(latency);
                responses.push(Response {
                    id: req.id,
                    model_idx: i,
                    output: out,
                    latency,
                });
            }
        }
        Ok(responses)
    }

    /// Closed-loop driver: feed `rounds` full rounds from `make_round`
    /// and dispatch each. Returns total responses.
    pub fn run_rounds<F>(&mut self, rounds: usize, mut make_round: F) -> Result<usize>
    where
        F: FnMut() -> Vec<Request>,
    {
        let mut total = 0;
        for _ in 0..rounds {
            for req in make_round() {
                match self.offer(req) {
                    Admit::Queued => {}
                    Admit::Rejected => {
                        // drain before re-offering (simple backpressure)
                        while self.round_ready() {
                            total += self.dispatch()?.len();
                        }
                    }
                }
            }
            while self.round_ready() {
                total += self.dispatch()?.len();
            }
        }
        // drain any padded leftovers
        while self.pending() > 0 {
            total += self.dispatch()?.len();
        }
        Ok(total)
    }
}
