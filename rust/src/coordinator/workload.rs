//! Synthetic workload generation: per-model request streams.
//!
//! The paper serves "different input streams" per fine-tuned instance
//! (§2.1). We model each instance's stream as Poisson arrivals with a
//! configurable per-model rate; payloads are seeded standard-normal
//! tensors shaped `[bs, ...input_shape]`.

use crate::tensor::Tensor;
use crate::util::rng::Rng;

use super::request::Request;

/// Open-loop Poisson workload across M model streams.
pub struct Workload {
    m: usize,
    shape: Vec<usize>,
    /// per-model arrival rate (requests/sec)
    rate: f64,
    rng: Rng,
    next_id: u64,
    /// virtual clock per stream (seconds from start)
    next_arrival: Vec<f64>,
}

impl Workload {
    pub fn new(m: usize, request_shape: &[usize], rate: f64, seed: u64) -> Workload {
        let mut rng = Rng::new(seed);
        let next_arrival = (0..m).map(|_| rng.exp(rate)).collect();
        Workload {
            m,
            shape: request_shape.to_vec(),
            rate,
            rng,
            next_id: 0,
            next_arrival,
        }
    }

    /// The next (arrival_time, request) in global time order.
    pub fn next(&mut self) -> (f64, Request) {
        // earliest stream
        let (idx, _) = self
            .next_arrival
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        let at = self.next_arrival[idx];
        self.next_arrival[idx] += self.rng.exp(self.rate);
        let input = Tensor::randn(&self.shape, &mut self.rng);
        let id = self.next_id;
        self.next_id += 1;
        (at, Request::new(id, idx, input))
    }

    /// One full round: exactly one request per model (closed-loop benches).
    pub fn round(&mut self) -> Vec<Request> {
        (0..self.m)
            .map(|i| {
                let input = Tensor::randn(&self.shape, &mut self.rng);
                let id = self.next_id;
                self.next_id += 1;
                Request::new(id, i, input)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_ordered_and_cover_models() {
        let mut w = Workload::new(4, &[1, 3], 100.0, 7);
        let mut last = 0.0;
        let mut seen = [false; 4];
        for _ in 0..200 {
            let (at, req) = w.next();
            assert!(at >= last, "arrivals must be non-decreasing");
            last = at;
            seen[req.model_idx] = true;
            assert_eq!(req.input.shape(), &[1, 3]);
        }
        assert!(seen.iter().all(|s| *s));
    }

    #[test]
    fn round_is_one_per_model() {
        let mut w = Workload::new(3, &[1, 2], 10.0, 1);
        let r = w.round();
        assert_eq!(r.len(), 3);
        let idxs: Vec<_> = r.iter().map(|q| q.model_idx).collect();
        assert_eq!(idxs, vec![0, 1, 2]);
    }

    #[test]
    fn deterministic_for_seed() {
        let mut a = Workload::new(2, &[1], 5.0, 42);
        let mut b = Workload::new(2, &[1], 5.0, 42);
        for _ in 0..20 {
            let (ta, ra) = a.next();
            let (tb, rb) = b.next();
            assert_eq!(ta, tb);
            assert_eq!(ra.model_idx, rb.model_idx);
            assert_eq!(ra.input, rb.input);
        }
    }
}
