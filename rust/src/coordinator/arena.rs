//! `RoundArena`: the reusable megabatch staging buffer of the round
//! pipeline.
//!
//! The paper's merged program amortizes per-model overhead on the
//! device; the arena does the same for the host side of every round.
//! All round-lifetime storage — the merged input tensor and the zero pad
//! block — is allocated once (at `Fleet::load`) and reused forever:
//! [`RoundArena::pack_with`] writes each instance's payload directly
//! into its channel/batch window of the megabatch, so the steady-state
//! request path performs exactly one host copy (queue slot → megabatch)
//! and zero heap allocations. `benches/round_pipeline.rs` asserts the
//! zero-allocation property with a counting allocator.

use anyhow::{bail, Result};

use crate::tensor::Tensor;

/// How M per-instance inputs pack into the merged input (paper §3.1):
/// conv nets concatenate on the channel axis, matmul/sequence nets stack
/// on a new leading batch axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Layout {
    Channel,
    Batch,
}

impl Layout {
    /// Parse the manifest spelling (`"channel"` | `"batch"`).
    pub fn parse(s: &str) -> Result<Layout> {
        match s {
            "channel" => Ok(Layout::Channel),
            "batch" => Ok(Layout::Batch),
            other => bail!("bad fleet layout {other:?} (want channel | batch)"),
        }
    }
}

/// Preallocated round-lifetime buffers for one fleet configuration.
pub struct RoundArena {
    layout: Layout,
    m: usize,
    /// per-request block shape `[bs, ...]`
    request_shape: Vec<usize>,
    /// the megabatch: merged input tensor, written in place every round
    merged: Tensor,
    /// zero block substituted for absent slots in a padded round
    pad: Vec<f32>,
    /// number of outer blocks (`bs` for channel packing, 1 for batch)
    outer: usize,
    /// contiguous run per (outer block, instance)
    inner: usize,
}

impl RoundArena {
    /// Allocate every buffer the round pipeline needs for `m` instances
    /// with per-request shape `request_shape` (`[bs, ...]`).
    pub fn new(layout: Layout, m: usize, request_shape: &[usize]) -> Result<RoundArena> {
        if m == 0 {
            bail!("arena needs at least one instance");
        }
        let request_len: usize = request_shape.iter().product();
        let (merged_shape, outer, inner) = match layout {
            Layout::Channel => {
                // concat on axis 1: [bs, C, ...] x M -> [bs, M*C, ...]
                if request_shape.len() < 2 {
                    bail!(
                        "channel layout needs request rank >= 2, got {:?}",
                        request_shape
                    );
                }
                let mut s = request_shape.to_vec();
                s[1] *= m;
                let outer = request_shape[0];
                let inner: usize = request_shape[1..].iter().product();
                (s, outer, inner)
            }
            Layout::Batch => {
                // stack on a new leading axis: [bs, ...] x M -> [M, bs, ...]
                let mut s = Vec::with_capacity(request_shape.len() + 1);
                s.push(m);
                s.extend_from_slice(request_shape);
                (s, 1, request_len)
            }
        };
        Ok(RoundArena {
            layout,
            m,
            request_shape: request_shape.to_vec(),
            merged: Tensor::zeros(&merged_shape),
            pad: vec![0.0; request_len],
            outer,
            inner,
        })
    }

    pub fn layout(&self) -> Layout {
        self.layout
    }
    pub fn m(&self) -> usize {
        self.m
    }
    pub fn request_shape(&self) -> &[usize] {
        &self.request_shape
    }
    /// The megabatch in its current state (valid after `pack_with`).
    pub fn merged(&self) -> &Tensor {
        &self.merged
    }
    pub fn merged_shape(&self) -> &[usize] {
        self.merged.shape()
    }
    /// Raw staging slice for `Bound::run_raw` (no Tensor round-trip).
    pub fn merged_data(&self) -> &[f32] {
        self.merged.data()
    }

    /// Pack one round. `get(i)` returns instance `i`'s payload, or `None`
    /// for an absent slot, which is filled from the arena's pad block
    /// (the merged program is fixed-shape; padded slots are computed and
    /// discarded, exactly as the paper's merged graph implies).
    ///
    /// Steady-state cost: one `copy_from_slice` per (outer block,
    /// instance) window — no allocation, no intermediate concat/stack.
    pub fn pack_with<'a>(
        &mut self,
        get: &(dyn Fn(usize) -> Option<&'a Tensor> + Sync),
    ) -> Result<()> {
        let (m, outer, inner) = (self.m, self.outer, self.inner);
        for i in 0..m {
            let src: &[f32] = match get(i) {
                Some(x) => {
                    if x.shape() != self.request_shape.as_slice() {
                        bail!(
                            "instance {i}: payload shape {:?}, fleet packs {:?}",
                            x.shape(),
                            self.request_shape
                        );
                    }
                    x.data()
                }
                None => &self.pad,
            };
            let dst = self.merged.data_mut();
            for o in 0..outer {
                let at = (o * m + i) * inner;
                dst[at..at + inner].copy_from_slice(&src[o * inner..(o + 1) * inner]);
            }
        }
        Ok(())
    }

    /// Pack a full round given one payload per instance (bench/test
    /// convenience around [`RoundArena::pack_with`]).
    pub fn pack_full(&mut self, xs: &[&Tensor]) -> Result<()> {
        if xs.len() != self.m {
            bail!("pack wants {} inputs, got {}", self.m, xs.len());
        }
        self.pack_with(&|i| Some(xs[i]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn channel_pack_matches_concat() {
        let mut rng = Rng::new(1);
        let shape = [2usize, 3, 4, 4];
        let xs: Vec<Tensor> = (0..5).map(|_| Tensor::randn(&shape, &mut rng)).collect();
        let refs: Vec<&Tensor> = xs.iter().collect();
        let want = Tensor::concat(&refs, 1).unwrap();

        let mut arena = RoundArena::new(Layout::Channel, 5, &shape).unwrap();
        arena.pack_full(&refs).unwrap();
        assert_eq!(arena.merged_shape(), want.shape());
        assert_eq!(arena.merged_data(), want.data());
    }

    #[test]
    fn batch_pack_matches_stack() {
        let mut rng = Rng::new(2);
        let shape = [1usize, 8];
        let xs: Vec<Tensor> = (0..3).map(|_| Tensor::randn(&shape, &mut rng)).collect();
        let refs: Vec<&Tensor> = xs.iter().collect();
        let want = Tensor::stack(&refs).unwrap();

        let mut arena = RoundArena::new(Layout::Batch, 3, &shape).unwrap();
        arena.pack_full(&refs).unwrap();
        assert_eq!(arena.merged_shape(), want.shape());
        assert_eq!(arena.merged_data(), want.data());
    }

    #[test]
    fn absent_slots_pad_with_zeros_and_overwrite_stale_data() {
        let mut rng = Rng::new(3);
        let shape = [1usize, 4];
        let a = Tensor::randn(&shape, &mut rng);
        let b = Tensor::randn(&shape, &mut rng);
        let mut arena = RoundArena::new(Layout::Batch, 2, &shape).unwrap();
        // round 1: both slots live
        arena.pack_with(&|i| Some(if i == 0 { &a } else { &b })).unwrap();
        // round 2: slot 1 absent — its window must be zeroed, not stale
        arena.pack_with(&|i| if i == 0 { Some(&a) } else { None }).unwrap();
        assert_eq!(&arena.merged_data()[..4], a.data());
        assert_eq!(&arena.merged_data()[4..], &[0.0; 4]);
    }

    #[test]
    fn rejects_bad_shapes() {
        let mut arena = RoundArena::new(Layout::Batch, 2, &[1, 4]).unwrap();
        let wrong = Tensor::zeros(&[1, 5]);
        assert!(arena.pack_with(&|_| Some(&wrong)).is_err());
        assert!(arena.pack_full(&[&wrong]).is_err()); // wrong count
        assert!(RoundArena::new(Layout::Channel, 2, &[4]).is_err());
        assert!(RoundArena::new(Layout::Batch, 0, &[1, 4]).is_err());
        assert!(Layout::parse("diagonal").is_err());
        assert_eq!(Layout::parse("channel").unwrap(), Layout::Channel);
    }
}
