//! `RoundArena`: the reusable megabatch staging buffer of the round
//! pipeline, and [`ArenaRing`], its multi-buffered (depth >= 2) form.
//!
//! The paper's merged program amortizes per-model overhead on the
//! device; the arena does the same for the host side of every round.
//! All round-lifetime storage — the merged input tensor — is allocated
//! once (at `Fleet::load`) and reused forever: [`RoundArena::pack_with`]
//! writes each instance's payload directly into its channel/batch
//! window of the megabatch through the feature-detected wide kernels
//! (`util::simd::scatter_rows`; absent slots re-zero their windows in
//! place with `fill_rows_zero`, no pad source block needed), so the
//! steady-state request path performs exactly one host copy (queue slot
//! → megabatch) and zero heap allocations. `benches/round_pipeline.rs`
//! asserts the zero-allocation property with a counting allocator and
//! `benches/hot_paths.rs` the per-slot pack cost.
//!
//! The arena also tracks per-slot occupancy across rounds: an absent
//! slot whose window is already zero from a previous padded round skips
//! the pad copy entirely (the first step of letting padded slots skip
//! upload bandwidth).
//!
//! [`ArenaRing`] holds `depth` independently locked arenas so that up
//! to `depth` rounds overlap: one thread packs round N+k while round
//! N's staged megabatch is still in flight on the device. A round
//! acquires one ring slot and holds it for pack + stage + execute
//! (PJRT host-buffer semantics may defer the H2D copy, so the slot
//! must stay reserved until execution completes); the remaining slots
//! stay free, which is what makes cross-thread round overlap possible —
//! `benches/multi_fleet.rs` measures the two-deep win and
//! `benches/parallel_dispatch.rs` drives N dispatch threads over one
//! shared ring. [`ArenaRing::pair`] is the depth-2 form that used to be
//! a dedicated `ArenaPair` type.
//!
//! [`SlotMap`] extends the arena to *cross-fleet* rounds
//! (`coordinator::coalesce`): several serving lanes of the same model
//! family contribute contiguous windows of local slots to ONE shared
//! megabatch. The map is the remap between a lane's local slot space
//! and the group slot space, and it drives both directions of every
//! coalesced dispatch (`MultiServer::dispatch_group`): gather (which
//! lane's taken request fills a group slot) and scatter (which lane's
//! response routing owns a merged output window).
//! [`RoundArena::pack_with_map`] and the per-lane occupancy accessors
//! ([`RoundArena::lane_occupied`]) are the arena-level form of that
//! contract for a group executor that packs its own megabatch — today
//! that is the mock-level path plus this module's tests; wiring a real
//! `Fleet` group executor (the fused artifact at the members' total
//! instance count) through them is a ROADMAP follow-up.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Condvar;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::tensor::Tensor;
use crate::util::lock::{LockGuard, LockRank, OrderedMutex};
use crate::util::simd;

/// How M per-instance inputs pack into the merged input (paper §3.1):
/// conv nets concatenate on the channel axis, matmul/sequence nets stack
/// on a new leading batch axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Layout {
    Channel,
    Batch,
}

impl Layout {
    /// Parse the manifest spelling (`"channel"` | `"batch"`).
    pub fn parse(s: &str) -> Result<Layout> {
        match s {
            "channel" => Ok(Layout::Channel),
            "batch" => Ok(Layout::Batch),
            other => bail!("bad fleet layout {other:?} (want channel | batch)"),
        }
    }
}

/// The slot remap of a coalesced (cross-lane) round: lane `k`'s local
/// slot `j` owns group slot `offset(k) + j` of the shared megabatch.
///
/// Lanes contribute *contiguous* windows in registration order, so the
/// map is just the prefix sums of the per-lane slot counts — `locate`
/// is a partition-point search, `group_slot` an add. The map is built
/// at group formation (`coordinator::coalesce`) and read on every
/// coalesced round, so it allocates nothing after construction. Under
/// elastic topology (ADR-005) group membership churns at runtime: the
/// owning dispatch thread REPLACES the map between rounds (`uniform`
/// over the surviving members) rather than mutating it, so a map in
/// use by a round is immutable for that round's whole life — the same
/// argument that makes `ArenaRing` slot independence safe lets sibling
/// partitions' in-flight rounds ignore the churn entirely.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SlotMap {
    /// `offsets[k]` = first group slot of lane `k`; `offsets[len]` = total
    offsets: Vec<usize>,
}

impl SlotMap {
    /// Build from one slot count per member lane (each must be >= 1).
    pub fn new(slot_counts: &[usize]) -> Result<SlotMap> {
        if slot_counts.is_empty() {
            bail!("slot map needs at least one lane");
        }
        let mut offsets = Vec::with_capacity(slot_counts.len() + 1);
        let mut at = 0usize;
        offsets.push(0);
        for (k, &n) in slot_counts.iter().enumerate() {
            if n == 0 {
                bail!("lane {k}: a coalesce member needs at least one slot");
            }
            at += n;
            offsets.push(at);
        }
        Ok(SlotMap { offsets })
    }

    /// `lanes` members with `per_lane` slots each (the coalesce-group
    /// shape: the key includes the slot count, so members are uniform).
    pub fn uniform(lanes: usize, per_lane: usize) -> Result<SlotMap> {
        SlotMap::new(&vec![per_lane; lanes])
    }

    /// Number of member lanes.
    pub fn lanes(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Total group slots (the merged megabatch's instance count).
    // LINT-ALLOW(offsets always holds lanes+1 entries, so last() exists)
    pub fn total(&self) -> usize {
        *self.offsets.last().unwrap()
    }

    /// First group slot of lane `k`.
    // LINT-ALLOW(lane ids are validated against the map by callers; offsets holds lanes+1 entries)
    pub fn offset(&self, lane: usize) -> usize {
        self.offsets[lane]
    }

    /// Lane `k`'s window of group slots.
    // LINT-ALLOW(lane ids are validated against the map by callers; offsets holds lanes+1 entries)
    pub fn slots_of(&self, lane: usize) -> std::ops::Range<usize> {
        self.offsets[lane]..self.offsets[lane + 1]
    }

    /// Lane `k`'s local slot `local` in group-slot space.
    // LINT-ALLOW(lane ids are validated against the map by callers; offsets holds lanes+1 entries)
    pub fn group_slot(&self, lane: usize, local: usize) -> usize {
        debug_assert!(local < self.slots_of(lane).len(), "local slot out of lane window");
        self.offsets[lane] + local
    }

    /// `(lane, local_slot)` owning group slot `g` — the scatter
    /// direction: which lane's response routing a merged output window
    /// belongs to.
    // LINT-ALLOW(partition_point over offsets yields an index below offsets.len())
    pub fn locate(&self, group_slot: usize) -> (usize, usize) {
        debug_assert!(group_slot < self.total(), "group slot out of range");
        // offsets is strictly increasing; find the last offset <= g
        let lane = self.offsets.partition_point(|&o| o <= group_slot) - 1;
        (lane, group_slot - self.offsets[lane])
    }
}

/// Preallocated round-lifetime buffers for one fleet configuration.
pub struct RoundArena {
    layout: Layout,
    m: usize,
    /// per-request block shape `[bs, ...]`
    request_shape: Vec<usize>,
    /// the megabatch: merged input tensor, written in place every round
    merged: Tensor,
    /// number of outer blocks (`bs` for channel packing, 1 for batch)
    outer: usize,
    /// contiguous run per (outer block, instance)
    inner: usize,
    /// whether slot `i`'s window currently holds payload data (vs
    /// zeros). A slot that stays absent across rounds keeps its
    /// already-zero window, so the re-zero pass is skipped.
    occupied: Vec<bool>,
    /// pad zero-fills actually performed (absent slots whose window
    /// held stale payload data); rounds where the window was already
    /// zero don't count. Observability for the skip-redundant-pad
    /// optimization.
    pad_writes: u64,
}

impl RoundArena {
    /// Allocate every buffer the round pipeline needs for `m` instances
    /// with per-request shape `request_shape` (`[bs, ...]`).
    // LINT-ALLOW(shape vectors are length-validated right above the adjustment)
    pub fn new(layout: Layout, m: usize, request_shape: &[usize]) -> Result<RoundArena> {
        if m == 0 {
            bail!("arena needs at least one instance");
        }
        let request_len: usize = request_shape.iter().product();
        let (merged_shape, outer, inner) = match layout {
            Layout::Channel => {
                // concat on axis 1: [bs, C, ...] x M -> [bs, M*C, ...]
                if request_shape.len() < 2 {
                    bail!(
                        "channel layout needs request rank >= 2, got {:?}",
                        request_shape
                    );
                }
                let mut s = request_shape.to_vec();
                s[1] *= m;
                let outer = request_shape[0];
                let inner: usize = request_shape[1..].iter().product();
                (s, outer, inner)
            }
            Layout::Batch => {
                // stack on a new leading axis: [bs, ...] x M -> [M, bs, ...]
                let mut s = Vec::with_capacity(request_shape.len() + 1);
                s.push(m);
                s.extend_from_slice(request_shape);
                (s, 1, request_len)
            }
        };
        Ok(RoundArena {
            layout,
            m,
            request_shape: request_shape.to_vec(),
            merged: Tensor::zeros(&merged_shape),
            outer,
            inner,
            // the megabatch starts zeroed, so every window is
            // pad-equivalent until its first payload lands
            occupied: vec![false; m],
            pad_writes: 0,
        })
    }

    pub fn layout(&self) -> Layout {
        self.layout
    }
    pub fn m(&self) -> usize {
        self.m
    }
    pub fn request_shape(&self) -> &[usize] {
        &self.request_shape
    }
    /// The megabatch in its current state (valid after `pack_with`).
    pub fn merged(&self) -> &Tensor {
        &self.merged
    }
    pub fn merged_shape(&self) -> &[usize] {
        self.merged.shape()
    }
    /// Raw staging slice for `Bound::run_raw` (no Tensor round-trip).
    pub fn merged_data(&self) -> &[f32] {
        self.merged.data()
    }
    /// Pad zero-fills performed so far (absent slots over stale
    /// payload windows; already-zero windows are skipped and not
    /// counted).
    pub fn pad_writes(&self) -> u64 {
        self.pad_writes
    }

    /// Pack one round. `get(i)` returns instance `i`'s payload, or `None`
    /// for an absent slot, whose windows are re-zeroed in place (the
    /// merged program is fixed-shape; padded slots are computed and
    /// discarded, exactly as the paper's merged graph implies).
    ///
    /// Steady-state cost: one wide strided copy
    /// (`util::simd::scatter_rows`) per instance, writing its (outer
    /// block, instance) windows — no allocation, no intermediate
    /// concat/stack. A slot that was already padded in the previous
    /// round keeps its zero window and skips even the zero-fill.
    // LINT-ALLOW(pack iterates 0..m over the arena's own occupancy table)
    pub fn pack_with<'a>(
        &mut self,
        get: &(dyn Fn(usize) -> Option<&'a Tensor> + Sync),
    ) -> Result<()> {
        let (m, outer, inner) = (self.m, self.outer, self.inner);
        for i in 0..m {
            match get(i) {
                Some(x) => {
                    if x.shape() != self.request_shape.as_slice() {
                        bail!(
                            "instance {i}: payload shape {:?}, fleet packs {:?}",
                            x.shape(),
                            self.request_shape
                        );
                    }
                    self.occupied[i] = true;
                    simd::scatter_rows(
                        self.merged.data_mut(),
                        i * inner,
                        m * inner,
                        x.data(),
                        outer,
                        inner,
                    );
                }
                None => {
                    if !self.occupied[i] {
                        // window is still zero from the last padded
                        // round (or from construction): nothing to do
                        continue;
                    }
                    self.occupied[i] = false;
                    self.pad_writes += 1;
                    simd::fill_rows_zero(self.merged.data_mut(), i * inner, m * inner, outer, inner);
                }
            }
        }
        Ok(())
    }

    /// Pack a full round given one payload per instance (bench/test
    /// convenience around [`RoundArena::pack_with`]).
    // LINT-ALLOW(xs length equals m, checked before delegation to pack_with)
    pub fn pack_full(&mut self, xs: &[&Tensor]) -> Result<()> {
        if xs.len() != self.m {
            bail!("pack wants {} inputs, got {}", self.m, xs.len());
        }
        self.pack_with(&|i| Some(xs[i]))
    }

    /// Pack one **coalesced** round: `get(lane, local)` is member lane
    /// `lane`'s payload for its local slot `local`, remapped into this
    /// arena's group slot space through `map`. The arena must be sized
    /// for the whole group (`map.total()` instances); everything else —
    /// pad blocks for absent slots, skip-already-zero windows, shape
    /// validation — is exactly [`RoundArena::pack_with`].
    pub fn pack_with_map<'a>(
        &mut self,
        map: &SlotMap,
        get: &(dyn Fn(usize, usize) -> Option<&'a Tensor> + Sync),
    ) -> Result<()> {
        if map.total() != self.m {
            bail!(
                "slot map spans {} group slots, arena packs {}",
                map.total(),
                self.m
            );
        }
        self.pack_with(&|g| {
            let (lane, local) = map.locate(g);
            get(lane, local)
        })
    }

    /// Per-slot occupancy after the last pack (`true` = payload window,
    /// `false` = pad/zero window).
    pub fn occupancy(&self) -> &[bool] {
        &self.occupied
    }

    /// How many of member lane `lane`'s slots held payload in the last
    /// pack — the per-lane share of a coalesced megabatch (metrics
    /// attribution and pad-skip observability).
    // LINT-ALLOW(slots_of yields in-range group slots by SlotMap construction)
    pub fn lane_occupied(&self, map: &SlotMap, lane: usize) -> usize {
        map.slots_of(lane).filter(|&g| self.occupied[g]).count()
    }
}

/// Multi-buffered [`RoundArena`]: `depth` identically configured ring
/// slots, each behind its own lock, each independently reservable.
///
/// One NETFUSE round acquires a slot and holds it for the whole
/// pack → stage → execute span (PJRT host-buffer semantics may defer
/// the H2D copy, so the staged megabatch must not be repacked until the
/// round completes — the [`RingSlot`] guard *is* that reservation, and
/// `Bound::stage`'s borrowed [`StagedInput`] ties the staged buffer's
/// lifetime to the guard). The other slots stay free, so up to `depth`
/// rounds — N dispatch threads' worth — pack/stage/execute while round
/// N is still in flight; with the single-arena lock of PR 1 all rounds
/// serialized end to end, and with the fixed pair of PR 2 overlap
/// stopped at two.
///
/// [`StagedInput`]: crate::runtime::StagedInput
pub struct ArenaRing {
    slots: Vec<OrderedMutex<RoundArena>>,
    /// round-robin hint so concurrent rounds start on different slots
    next: AtomicUsize,
    /// rounds currently holding a reservation (observability: a gauge
    /// at `depth` means the ring is the bottleneck, not the device)
    in_flight: AtomicUsize,
    /// oversubscribed acquirers park here until ANY reservation drops —
    /// not on one arbitrary slot's mutex, which could be the longest-
    /// lived in-flight round while a neighboring slot frees first
    released: Condvar,
    release_lock: OrderedMutex<()>,
    /// configuration cached outside the locks so load-time cross-checks
    /// and sharing validation never contend with in-flight rounds
    layout: Layout,
    m: usize,
    request_shape: Vec<usize>,
    merged_shape: Vec<usize>,
}

/// One reserved ring slot: derefs to its [`RoundArena`] and releases
/// the reservation (and the in-flight gauge) on drop.
pub struct RingSlot<'a> {
    guard: LockGuard<'a, RoundArena>,
    index: usize,
    ring: &'a ArenaRing,
}

impl RingSlot<'_> {
    /// Which ring slot this reservation holds (stable for its lifetime).
    pub fn index(&self) -> usize {
        self.index
    }
}

impl std::ops::Deref for RingSlot<'_> {
    type Target = RoundArena;
    fn deref(&self) -> &RoundArena {
        &self.guard
    }
}

impl std::ops::DerefMut for RingSlot<'_> {
    fn deref_mut(&mut self) -> &mut RoundArena {
        &mut self.guard
    }
}

impl Drop for RingSlot<'_> {
    fn drop(&mut self) {
        self.ring.in_flight.fetch_sub(1, Ordering::Relaxed);
        // pair the notify with the lock so an acquirer that failed its
        // sweep and is about to park cannot miss this release. The slot
        // guard is still held here, which is why ArenaSlot < ArenaRelease
        // in the declared hierarchy (ADR-008).
        let _g = self.ring.release_lock.lock();
        self.ring.released.notify_one();
    }
}

impl ArenaRing {
    /// Allocate `depth` ring slots for `m` instances with per-request
    /// shape `request_shape` (`[bs, ...]`). `depth >= 2` — a one-deep
    /// "ring" is the PR 1 lock-spanning arena, which serializes rounds
    /// end to end and defeats the type's purpose.
    // LINT-ALLOW(depth >= 2 is validated, so slots[0] exists)
    pub fn new(
        layout: Layout,
        m: usize,
        request_shape: &[usize],
        depth: usize,
    ) -> Result<ArenaRing> {
        if depth < 2 {
            bail!("arena ring needs depth >= 2, got {depth} (depth 1 cannot overlap rounds)");
        }
        let slots = (0..depth)
            .map(|_| {
                RoundArena::new(layout, m, request_shape)
                    .map(|a| OrderedMutex::new(LockRank::ArenaSlot, a))
            })
            .collect::<Result<Vec<_>>>()?;
        let merged_shape = slots[0].lock().merged_shape().to_vec();
        Ok(ArenaRing {
            slots,
            next: AtomicUsize::new(0),
            in_flight: AtomicUsize::new(0),
            released: Condvar::new(),
            release_lock: OrderedMutex::new(LockRank::ArenaRelease, ()),
            layout,
            m,
            request_shape: request_shape.to_vec(),
            merged_shape,
        })
    }

    /// The double-buffered configuration (formerly `ArenaPair`): the
    /// right default for one dispatch thread overlapping with one
    /// in-flight device round.
    pub fn pair(layout: Layout, m: usize, request_shape: &[usize]) -> Result<ArenaRing> {
        ArenaRing::new(layout, m, request_shape, 2)
    }

    /// Number of independently reservable slots.
    pub fn depth(&self) -> usize {
        self.slots.len()
    }

    /// Rounds currently holding a reservation (0..=depth).
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::Relaxed)
    }

    pub fn layout(&self) -> Layout {
        self.layout
    }

    pub fn m(&self) -> usize {
        self.m
    }

    pub fn request_shape(&self) -> &[usize] {
        &self.request_shape
    }

    /// Acquire a free slot for one round, preferring the one least
    /// recently handed out. Blocks only when *all* slots have rounds in
    /// flight (i.e. more than `depth` concurrent rounds) — and then
    /// parks until ANY reservation drops, taking the first slot to
    /// free rather than gambling on one arbitrary slot's lock.
    pub fn acquire(&self) -> RingSlot<'_> {
        loop {
            if let Some(slot) = self.try_acquire() {
                return slot;
            }
            // all slots in flight: park until a reservation drops. The
            // in_flight recheck under the lock catches a release that
            // landed between the failed sweep and the park (the drop
            // decrements BEFORE taking the lock); the 1ms timeout is a
            // backstop against notify_one going to a thread that then
            // loses the re-acquire race.
            let g = self.release_lock.lock();
            if self.in_flight.load(Ordering::Relaxed) >= self.slots.len() {
                let _ = g.wait_timeout(&self.released, Duration::from_millis(1));
            }
        }
    }

    /// Acquire a free slot without blocking, or `None` when every slot
    /// has a round in flight (lets a dispatch thread choose other work
    /// over waiting on the ring).
    // LINT-ALLOW(scan iterates 0..depth over the slot vec)
    pub fn try_acquire(&self) -> Option<RingSlot<'_>> {
        let depth = self.slots.len();
        let start = self.next.fetch_add(1, Ordering::Relaxed);
        for k in 0..depth {
            let i = (start + k) % depth;
            if let Some(guard) = self.slots[i].try_lock() {
                self.in_flight.fetch_add(1, Ordering::Relaxed);
                return Some(RingSlot { guard, index: i, ring: self });
            }
        }
        None
    }

    /// The merged megabatch shape every slot packs (for load-time
    /// cross-checks against the AOT artifact). Lock-free: cached at
    /// construction.
    pub fn merged_shape(&self) -> &[usize] {
        &self.merged_shape
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn channel_pack_matches_concat() {
        let mut rng = Rng::new(1);
        let shape = [2usize, 3, 4, 4];
        let xs: Vec<Tensor> = (0..5).map(|_| Tensor::randn(&shape, &mut rng)).collect();
        let refs: Vec<&Tensor> = xs.iter().collect();
        let want = Tensor::concat(&refs, 1).unwrap();

        let mut arena = RoundArena::new(Layout::Channel, 5, &shape).unwrap();
        arena.pack_full(&refs).unwrap();
        assert_eq!(arena.merged_shape(), want.shape());
        assert_eq!(arena.merged_data(), want.data());
    }

    #[test]
    fn batch_pack_matches_stack() {
        let mut rng = Rng::new(2);
        let shape = [1usize, 8];
        let xs: Vec<Tensor> = (0..3).map(|_| Tensor::randn(&shape, &mut rng)).collect();
        let refs: Vec<&Tensor> = xs.iter().collect();
        let want = Tensor::stack(&refs).unwrap();

        let mut arena = RoundArena::new(Layout::Batch, 3, &shape).unwrap();
        arena.pack_full(&refs).unwrap();
        assert_eq!(arena.merged_shape(), want.shape());
        assert_eq!(arena.merged_data(), want.data());
    }

    #[test]
    fn absent_slots_pad_with_zeros_and_overwrite_stale_data() {
        let mut rng = Rng::new(3);
        let shape = [1usize, 4];
        let a = Tensor::randn(&shape, &mut rng);
        let b = Tensor::randn(&shape, &mut rng);
        let mut arena = RoundArena::new(Layout::Batch, 2, &shape).unwrap();
        // round 1: both slots live
        arena.pack_with(&|i| Some(if i == 0 { &a } else { &b })).unwrap();
        // round 2: slot 1 absent — its window must be zeroed, not stale
        arena.pack_with(&|i| if i == 0 { Some(&a) } else { None }).unwrap();
        assert_eq!(&arena.merged_data()[..4], a.data());
        assert_eq!(&arena.merged_data()[4..], &[0.0; 4]);
    }

    #[test]
    fn absent_slots_skip_redundant_pad_copies() {
        let mut rng = Rng::new(7);
        let shape = [1usize, 4];
        let x = Tensor::randn(&shape, &mut rng);
        let mut arena = RoundArena::new(Layout::Batch, 2, &shape).unwrap();

        // round 1: slot 1 absent, but its window is zero from
        // construction — no pad copy needed
        arena.pack_with(&|i| if i == 0 { Some(&x) } else { None }).unwrap();
        assert_eq!(arena.pad_writes(), 0);
        assert_eq!(&arena.merged_data()[4..], &[0.0; 4]);

        // round 2: slot 1 occupied; round 3: absent again -> ONE pad copy
        arena.pack_with(&|_| Some(&x)).unwrap();
        arena.pack_with(&|i| if i == 0 { Some(&x) } else { None }).unwrap();
        assert_eq!(arena.pad_writes(), 1);
        assert_eq!(&arena.merged_data()[4..], &[0.0; 4]);

        // round 4: still absent -> window already zero, copy skipped
        arena.pack_with(&|i| if i == 0 { Some(&x) } else { None }).unwrap();
        assert_eq!(arena.pad_writes(), 1);
        assert_eq!(&arena.merged_data()[..4], x.data());
        assert_eq!(&arena.merged_data()[4..], &[0.0; 4]);
    }

    #[test]
    fn arena_ring_hands_out_independent_slots() {
        let ring = ArenaRing::pair(Layout::Batch, 2, &[1, 4]).unwrap();
        assert_eq!(ring.merged_shape(), &[2, 1, 4]);
        assert_eq!(ring.depth(), 2);
        assert_eq!(ring.in_flight(), 0);

        let mut rng = Rng::new(8);
        let a = Tensor::randn(&[1, 4], &mut rng);
        let b = Tensor::randn(&[1, 4], &mut rng);

        // round N holds one slot...
        let mut first = ring.acquire();
        first.pack_with(&|_| Some(&a)).unwrap();
        // ...and round N+1 still packs without blocking (other slot)
        let mut second = ring.acquire();
        second.pack_with(&|_| Some(&b)).unwrap();
        assert_ne!(
            first.merged_data().as_ptr(),
            second.merged_data().as_ptr(),
            "concurrent rounds must get distinct buffers"
        );
        assert_ne!(first.index(), second.index());
        assert_eq!(ring.in_flight(), 2);
        assert_eq!(&first.merged_data()[..4], a.data());
        assert_eq!(&second.merged_data()[..4], b.data());

        // the ring is exhausted: a third round must not get a buffer
        // that aliases an in-flight one
        assert!(ring.try_acquire().is_none(), "depth-2 ring held a third reservation");
        drop(first);
        drop(second);
        assert_eq!(ring.in_flight(), 0);

        // released slots are reacquirable
        let third = ring.acquire();
        assert_eq!(third.m(), 2);
    }

    #[test]
    fn arena_ring_depth_n_overlaps_n_rounds() {
        let ring = ArenaRing::new(Layout::Batch, 1, &[1, 2], 4).unwrap();
        assert_eq!(ring.depth(), 4);
        let x = Tensor::zeros(&[1, 2]);
        let mut held = Vec::new();
        for _ in 0..4 {
            let mut slot = ring.try_acquire().expect("free slot while ring not full");
            slot.pack_with(&|_| Some(&x)).unwrap();
            held.push(slot);
        }
        // all four reservations are live and distinct
        let mut ptrs: Vec<_> = held.iter().map(|s| s.merged_data().as_ptr()).collect();
        ptrs.sort();
        ptrs.dedup();
        assert_eq!(ptrs.len(), 4, "ring slots aliased a buffer");
        assert_eq!(ring.in_flight(), 4);
        assert!(ring.try_acquire().is_none());

        assert!(ArenaRing::new(Layout::Batch, 1, &[1, 2], 1).is_err());
        assert!(ArenaRing::new(Layout::Batch, 1, &[1, 2], 0).is_err());
    }

    #[test]
    fn oversubscribed_acquire_takes_the_first_freed_slot() {
        // more acquirers than depth: a parked acquirer must obtain the
        // slot that actually frees (whichever it is), not gamble on one
        // arbitrary slot's lock while another releases first
        let ring = ArenaRing::pair(Layout::Batch, 1, &[1, 2]).unwrap();
        let a = ring.acquire();
        let b = ring.acquire();
        assert_eq!(ring.in_flight(), 2);
        std::thread::scope(|s| {
            let t = s.spawn(|| ring.acquire().index());
            // give the third acquirer time to park on the full ring
            std::thread::sleep(std::time::Duration::from_millis(5));
            let freed = b.index();
            drop(b);
            assert_eq!(
                t.join().unwrap(),
                freed,
                "parked acquirer must take the freed slot"
            );
            drop(a);
        });
        assert_eq!(ring.in_flight(), 0);
    }

    #[test]
    fn slot_map_remaps_both_directions() {
        let map = SlotMap::new(&[2, 3, 1]).unwrap();
        assert_eq!(map.lanes(), 3);
        assert_eq!(map.total(), 6);
        assert_eq!(map.offset(0), 0);
        assert_eq!(map.offset(2), 5);
        assert_eq!(map.slots_of(1), 2..5);
        assert_eq!(map.group_slot(1, 2), 4);
        // locate is the exact inverse of group_slot over every slot
        for lane in 0..3 {
            for local in 0..map.slots_of(lane).len() {
                assert_eq!(map.locate(map.group_slot(lane, local)), (lane, local));
            }
        }
        assert_eq!(SlotMap::uniform(2, 4).unwrap(), SlotMap::new(&[4, 4]).unwrap());
        assert!(SlotMap::new(&[]).is_err());
        assert!(SlotMap::new(&[2, 0]).is_err());
    }

    #[test]
    fn pack_with_map_matches_flat_pack_and_tracks_lane_occupancy() {
        let mut rng = Rng::new(11);
        let shape = [1usize, 4];
        let xs: Vec<Tensor> = (0..4).map(|_| Tensor::randn(&shape, &mut rng)).collect();
        let map = SlotMap::uniform(2, 2).unwrap();

        // lane 0 fully occupied, lane 1 only local slot 1
        let mut coalesced = RoundArena::new(Layout::Batch, 4, &shape).unwrap();
        coalesced
            .pack_with_map(&map, &|lane, local| match (lane, local) {
                (0, l) => Some(&xs[l]),
                (1, 1) => Some(&xs[3]),
                _ => None,
            })
            .unwrap();

        // oracle: the same slots through the flat single-lane pack
        let mut flat = RoundArena::new(Layout::Batch, 4, &shape).unwrap();
        flat.pack_with(&|g| match g {
            0 => Some(&xs[0]),
            1 => Some(&xs[1]),
            3 => Some(&xs[3]),
            _ => None,
        })
        .unwrap();
        assert_eq!(coalesced.merged_data(), flat.merged_data());

        assert_eq!(coalesced.lane_occupied(&map, 0), 2);
        assert_eq!(coalesced.lane_occupied(&map, 1), 1);
        assert_eq!(coalesced.occupancy(), &[true, true, false, true]);

        // a map sized for a different group must be rejected
        let wrong = SlotMap::uniform(3, 2).unwrap();
        assert!(coalesced.pack_with_map(&wrong, &|_, _| None).is_err());
    }

    #[test]
    fn rejects_bad_shapes() {
        let mut arena = RoundArena::new(Layout::Batch, 2, &[1, 4]).unwrap();
        let wrong = Tensor::zeros(&[1, 5]);
        assert!(arena.pack_with(&|_| Some(&wrong)).is_err());
        assert!(arena.pack_full(&[&wrong]).is_err()); // wrong count
        assert!(RoundArena::new(Layout::Channel, 2, &[4]).is_err());
        assert!(RoundArena::new(Layout::Batch, 0, &[1, 4]).is_err());
        assert!(Layout::parse("diagonal").is_err());
        assert_eq!(Layout::parse("channel").unwrap(), Layout::Channel);
    }
}
