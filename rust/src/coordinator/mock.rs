//! Artifact-free mock executor — test/bench/example support.
//!
//! The serving front ends (`Server`, `MultiServer`, the ingress
//! dispatch loop) are generic over [`RoundExecutor`] precisely so their
//! logic runs without AOT artifacts or a PJRT backend. [`EchoExecutor`]
//! is the shared stand-in: it echoes each occupied slot's payload back
//! as its output after an optional fixed "device time", which is enough
//! to exercise batching, padding, QoS scheduling, and queue-wait
//! behavior. It lives in the library (not under `#[cfg(test)]`) because
//! benches and examples need it too; it is NOT part of the serving
//! data plane.
//!
//! Failure-injection and worker-pool-dispatching mocks stay local to
//! the tests that need them (see `rust/tests/coordinator_tests.rs`).

use std::time::Duration;

use anyhow::Result;

use crate::tensor::Tensor;

use super::service::RoundExecutor;
use super::strategy::StrategyKind;

/// Echo-the-payload executor with a modeled per-round device latency.
/// Batch size is fixed at 1 (every serving mock in the repo uses bs=1).
pub struct EchoExecutor {
    name: String,
    m: usize,
    input_shape: Vec<usize>,
    round_cost: Duration,
}

impl EchoExecutor {
    pub fn new(name: &str, m: usize, input_shape: &[usize], round_cost: Duration) -> EchoExecutor {
        EchoExecutor {
            name: name.to_string(),
            m,
            input_shape: input_shape.to_vec(),
            round_cost,
        }
    }
}

impl RoundExecutor for EchoExecutor {
    fn name(&self) -> &str {
        &self.name
    }
    fn m(&self) -> usize {
        self.m
    }
    fn bs(&self) -> usize {
        1
    }
    fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }
    fn run_round_slots<'a>(
        &self,
        strategy: StrategyKind,
        get: &(dyn Fn(usize) -> Option<&'a Tensor> + Sync),
        outs: &mut Vec<Option<Tensor>>,
    ) -> Result<()> {
        strategy.validate()?;
        if !self.round_cost.is_zero() {
            std::thread::sleep(self.round_cost);
        }
        outs.clear();
        for i in 0..self.m {
            outs.push(get(i).cloned());
        }
        Ok(())
    }
}
