//! Artifact-free mock executor — test/bench/example support.
//!
//! The serving front ends (`Server`, `MultiServer`, the ingress
//! dispatch loop) are generic over [`RoundExecutor`] precisely so their
//! logic runs without AOT artifacts or a PJRT backend. [`EchoExecutor`]
//! is the shared stand-in: it echoes each occupied slot's payload back
//! as its output after an optional fixed "device time", which is enough
//! to exercise batching, padding, QoS scheduling, and queue-wait
//! behavior. It lives in the library (not under `#[cfg(test)]`) because
//! benches and examples need it too; it is NOT part of the serving
//! data plane.
//!
//! For the elastic-topology work (ADR-005) the mock also models
//! FusedInf-style weight hot-swap: each slot carries a version tag, and
//! outputs are offset by `version * SWAP_SCALE` so tests can tell from
//! a response's bytes exactly which weight version served it.
//!
//! Failure-injection and worker-pool-dispatching mocks stay local to
//! the tests that need them (see `rust/tests/coordinator_tests.rs`).

use std::ops::Range;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::tensor::Tensor;
use crate::util::lock::{LockRank, OrderedMutex};

use super::service::RoundExecutor;
use super::strategy::StrategyKind;

/// Per-version payload offset applied by [`EchoExecutor`] after a
/// [`RoundExecutor::swap_model`]: a slot at version `v` echoes
/// `input + v * SWAP_SCALE`. Large enough to never collide with the
/// seeded test payloads (`id*1000 + model*10 + j`).
pub const SWAP_SCALE: f32 = 100_000.0;

/// Echo-the-payload executor with a modeled per-round device latency
/// and per-slot weight versions for hot-swap tests.
/// Batch size is fixed at 1 (every serving mock in the repo uses bs=1).
pub struct EchoExecutor {
    name: String,
    m: usize,
    input_shape: Vec<usize>,
    round_cost: Duration,
    swap_cost: Duration,
    versions: OrderedMutex<Vec<u64>>,
}

impl EchoExecutor {
    pub fn new(name: &str, m: usize, input_shape: &[usize], round_cost: Duration) -> EchoExecutor {
        EchoExecutor {
            name: name.to_string(),
            m,
            input_shape: input_shape.to_vec(),
            round_cost,
            swap_cost: Duration::ZERO,
            versions: OrderedMutex::new(LockRank::ModelState, vec![0; m]),
        }
    }

    /// Model a fixed weight-staging pause per swap (the "bounded pause"
    /// ADR-005 budgets); `Duration::ZERO` (the default) swaps instantly.
    pub fn with_swap_cost(mut self, swap_cost: Duration) -> EchoExecutor {
        self.swap_cost = swap_cost;
        self
    }

    /// Current weight version of slot `i` (0 = never swapped).
    pub fn version(&self, i: usize) -> u64 {
        self.versions.lock()[i]
    }
}

impl RoundExecutor for EchoExecutor {
    fn name(&self) -> &str {
        &self.name
    }
    fn m(&self) -> usize {
        self.m
    }
    fn bs(&self) -> usize {
        1
    }
    fn input_shape(&self) -> &[usize] {
        &self.input_shape
    }
    fn run_round_slots<'a>(
        &self,
        strategy: StrategyKind,
        get: &(dyn Fn(usize) -> Option<&'a Tensor> + Sync),
        outs: &mut Vec<Option<Tensor>>,
    ) -> Result<()> {
        strategy.validate()?;
        if !self.round_cost.is_zero() {
            std::thread::sleep(self.round_cost);
        }
        let versions = self.versions.lock();
        outs.clear();
        for i in 0..self.m {
            let mut out = get(i).cloned();
            let v = versions[i];
            if v != 0 {
                if let Some(t) = out.as_mut() {
                    for x in t.data_mut() {
                        *x += v as f32 * SWAP_SCALE;
                    }
                }
            }
            outs.push(out);
        }
        Ok(())
    }

    fn swap_model(&self, slots: Range<usize>, tag: u64) -> Result<Duration> {
        if slots.start >= slots.end || slots.end > self.m {
            bail!(
                "{}: swap window {slots:?} out of bounds (m={})",
                self.name,
                self.m
            );
        }
        let started = Instant::now();
        if !self.swap_cost.is_zero() {
            std::thread::sleep(self.swap_cost);
        }
        let mut versions = self.versions.lock();
        for v in &mut versions[slots] {
            *v = tag;
        }
        Ok(started.elapsed())
    }
}
