//! Persistent worker pool for the `Concurrent` / `Hybrid` strategies.
//!
//! The seed spawned fresh OS threads inside every round
//! (`std::thread::scope` in `run_chunked`), so those baselines measured
//! thread-creation cost as much as strategy cost. The pool spawns its
//! workers once and feeds them jobs over a shared queue; a round is a
//! [`WorkerPool::scope`] call that blocks until every job of the round
//! has completed, which is what makes handing *borrowed* jobs to
//! long-lived threads sound (same contract as `std::thread::scope`,
//! without the per-round spawn/join).
//!
//! Ownership is an `Arc` handle so ONE pool can back many fleets: pass
//! [`WorkerPool::shared`] (or [`WorkerPool::machine_sized`]) to
//! `Fleet::load_with_pool` for every fleet a `MultiServer` serves, and
//! the machine pays for one thread set sized to its cores instead of
//! one per fleet. `run_chunked` is `&self` and each job runs to
//! completion independently (no job ever re-enters the pool), so
//! concurrent rounds from different fleets interleave safely on the
//! same workers — including rounds submitted by N parallel dispatch
//! threads (`coordinator::multi::ParallelDispatcher`): submission
//! wakes one worker per queued job, not the whole pool, so frequent
//! small rounds from many dispatchers don't stampede a machine-sized
//! worker set on every submit.

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar};
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Result};

use crate::util::lock::{LockRank, OrderedMutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    /// (pending jobs, shutdown flag)
    queue: OrderedMutex<(VecDeque<Job>, bool)>,
    ready: Condvar,
}

/// Count-down latch: one round's completion barrier.
struct Latch {
    remaining: OrderedMutex<usize>,
    done: Condvar,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch { remaining: OrderedMutex::new(LockRank::PoolLatch, n), done: Condvar::new() }
    }

    fn count_down(&self) {
        let mut g = self.remaining.lock();
        *g -= 1;
        if *g == 0 {
            self.done.notify_all();
        }
    }

    fn wait(&self) {
        let mut g = self.remaining.lock();
        while *g > 0 {
            g = g.wait(&self.done);
        }
    }
}

/// Decrements its latch when dropped — including during unwinding, so a
/// panicking job can never leave [`WorkerPool::scope`] blocked.
struct LatchGuard(Arc<Latch>);

impl Drop for LatchGuard {
    fn drop(&mut self) {
        self.0.count_down();
    }
}

/// A set of long-lived worker threads fed over a channel-style queue.
/// Created once (per `Fleet`), reused for every round; grows on demand
/// via [`WorkerPool::ensure_workers`] so a fleet only ever pays for as
/// many threads as its strategies actually request.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: OrderedMutex<Vec<JoinHandle<()>>>,
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock();
            loop {
                if let Some(j) = q.0.pop_front() {
                    break j;
                }
                if q.1 {
                    return;
                }
                q = q.wait(&shared.ready);
            }
        };
        // A panicking job must not kill the worker: the panic is caught
        // here as a backstop (run_chunked converts panics to per-slot
        // errors before they get this far; the job's latch guard fires
        // during unwinding either way).
        let _ = catch_unwind(AssertUnwindSafe(job));
    }
}

/// Best-effort extraction of a panic payload's message.
fn panic_message(p: &(dyn Any + Send)) -> &str {
    if let Some(s) = p.downcast_ref::<&str>() {
        *s
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.as_str()
    } else {
        "non-string panic payload"
    }
}

impl WorkerPool {
    /// Spawn `workers` threads (at least one).
    pub fn new(workers: usize) -> WorkerPool {
        let pool = WorkerPool {
            shared: Arc::new(Shared {
                queue: OrderedMutex::new(LockRank::PoolQueue, (VecDeque::new(), false)),
                ready: Condvar::new(),
            }),
            handles: OrderedMutex::new(LockRank::PoolHandles, Vec::new()),
        };
        pool.ensure_workers(workers);
        pool
    }

    /// Spawn `workers` threads behind a shareable handle — the form
    /// multi-fleet serving wants: clone the `Arc` into each
    /// `Fleet::load_with_pool` so every fleet dispatches onto the same
    /// thread set.
    pub fn shared(workers: usize) -> Arc<WorkerPool> {
        Arc::new(WorkerPool::new(workers))
    }

    /// A shared pool initially sized to the machine (one worker per
    /// available hardware thread) — the right default for a
    /// `MultiServer` hosting several fleets on one box. Note the size
    /// is a starting point, not a cap: a `Concurrent` fleet with
    /// m > cores still grows the pool to m via `ensure_workers`,
    /// because that strategy's contract is one unsynchronized worker
    /// per model (the paper's process-per-model baseline). Use
    /// `Hybrid { procs }` to bound a fleet's parallelism to the
    /// machine.
    pub fn machine_sized() -> Arc<WorkerPool> {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        WorkerPool::shared(n)
    }

    /// Grow the pool to at least `n` workers (never shrinks). Lets a
    /// fleet size the pool to the parallelism a strategy actually asks
    /// for — `Hybrid {procs: 2}` costs 2 threads, not M — while a later
    /// `Concurrent` round can still widen it.
    pub fn ensure_workers(&self, n: usize) {
        let mut handles = self.handles.lock();
        while handles.len() < n.max(1) {
            let shared = self.shared.clone();
            handles.push(std::thread::spawn(move || worker_loop(shared)));
        }
    }

    pub fn workers(&self) -> usize {
        self.handles.lock().len()
    }

    /// Run a batch of borrowed jobs to completion on the pool.
    ///
    /// Blocks until every job has finished (or unwound). That barrier is
    /// the soundness argument for the lifetime erasure below: no job —
    /// queued, running, or panicking — can outlive this call, so the
    /// `'scope` borrows its closures capture remain valid for as long as
    /// any worker can touch them.
    pub fn scope<'scope>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
        let latch = Arc::new(Latch::new(jobs.len()));
        let n_jobs = jobs.len();
        {
            let mut q = self.shared.queue.lock();
            for job in jobs {
                // SAFETY: `job` only needs to live for 'scope; the latch
                // wait below keeps this stack frame alive until every
                // wrapper (and therefore every erased `job`) has been
                // dropped, on the normal and the panic path alike.
                let job: Box<dyn FnOnce() + Send + 'static> = unsafe {
                    std::mem::transmute::<
                        Box<dyn FnOnce() + Send + 'scope>,
                        Box<dyn FnOnce() + Send + 'static>,
                    >(job)
                };
                let guard = LatchGuard(latch.clone());
                q.0.push_back(Box::new(move || {
                    let _guard = guard;
                    job();
                }));
            }
            // wake one worker per queued job rather than the whole
            // pool: with several dispatch threads submitting small
            // rounds concurrently, notify_all would stampede every
            // idle worker (on a machine-sized pool, dozens) through
            // the queue lock for each round
            for _ in 0..n_jobs {
                self.shared.ready.notify_one();
            }
        }
        latch.wait();
    }

    /// Partition `0..n` into `procs` contiguous chunks, run `work(i)` for
    /// every index on the pool, and return the results index-aligned.
    /// A chunk stops at its first error (matching the sequential
    /// semantics of one worker draining its models in order); the first
    /// failure in index order is reported.
    pub fn run_chunked<T, F>(&self, n: usize, procs: usize, work: F) -> Result<Vec<T>>
    where
        T: Send,
        F: Fn(usize) -> Result<T> + Sync,
    {
        if n == 0 {
            return Ok(Vec::new());
        }
        let procs = procs.max(1).min(n);
        let chunk = n.div_ceil(procs);
        let slots: Vec<OrderedMutex<Option<Result<T>>>> =
            (0..n).map(|_| OrderedMutex::new(LockRank::PoolResult, None)).collect();
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(procs);
        for p in 0..procs {
            let lo = p * chunk;
            let hi = ((p + 1) * chunk).min(n);
            if lo >= hi {
                continue;
            }
            let slots = &slots;
            let work = &work;
            jobs.push(Box::new(move || {
                for i in lo..hi {
                    // convert a panicking work item into that slot's
                    // error so the real fault message reaches the
                    // caller instead of a generic missing-result error
                    let r = catch_unwind(AssertUnwindSafe(|| work(i))).unwrap_or_else(
                        |p| Err(anyhow!("worker job {i} panicked: {}", panic_message(&*p))),
                    );
                    let failed = r.is_err();
                    *slots[i].lock() = Some(r);
                    if failed {
                        break;
                    }
                }
            }));
        }
        self.scope(jobs);
        let mut out = Vec::with_capacity(n);
        for (i, slot) in slots.into_iter().enumerate() {
            match slot.into_inner() {
                Some(Ok(t)) => out.push(t),
                Some(Err(e)) => return Err(e),
                None => bail!("worker produced no output for item {i}"),
            }
        }
        Ok(out)
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock();
            q.1 = true;
            self.shared.ready.notify_all();
        }
        for h in self.handles.get_mut().drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn results_are_index_aligned() {
        let pool = WorkerPool::new(4);
        for procs in [1usize, 2, 3, 4, 9] {
            let got = pool.run_chunked(10, procs, |i| Ok(i * i)).unwrap();
            assert_eq!(got, (0..10).map(|i| i * i).collect::<Vec<_>>(), "procs={procs}");
        }
    }

    #[test]
    fn scope_sees_borrowed_state() {
        let pool = WorkerPool::new(3);
        let hits = AtomicUsize::new(0);
        // many rounds over the same pool: no thread churn, borrows local
        // to each round
        for round in 0..50 {
            let local = round; // borrowed by every job this round
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
                .map(|_| {
                    let hits = &hits;
                    let local = &local;
                    Box::new(move || {
                        hits.fetch_add(*local, Ordering::Relaxed);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.scope(jobs);
        }
        assert_eq!(hits.load(Ordering::Relaxed), 8 * (0..50).sum::<usize>());
    }

    #[test]
    fn chunk_errors_propagate() {
        let pool = WorkerPool::new(2);
        let err = pool
            .run_chunked(6, 2, |i| {
                if i == 4 {
                    Err(anyhow::anyhow!("boom at {i}"))
                } else {
                    Ok(i)
                }
            })
            .unwrap_err();
        assert!(err.to_string().contains("boom at 4"));
    }

    #[test]
    fn panicking_job_does_not_hang_or_kill_the_pool() {
        let pool = WorkerPool::new(2);
        let r = pool.run_chunked(3, 3, |i| {
            if i == 1 {
                panic!("job panic");
            }
            Ok(i)
        });
        // the panicked item surfaces as that slot's error with the real
        // panic message, not a hang and not a generic missing result
        let msg = r.unwrap_err().to_string();
        assert!(msg.contains("panicked") && msg.contains("job panic"), "got: {msg}");
        // and the pool still works afterwards
        let ok = pool.run_chunked(4, 2, |i| Ok(i + 1)).unwrap();
        assert_eq!(ok, vec![1, 2, 3, 4]);
    }

    #[test]
    fn ensure_workers_grows_but_never_shrinks() {
        let pool = WorkerPool::new(2);
        assert_eq!(pool.workers(), 2);
        pool.ensure_workers(5);
        assert_eq!(pool.workers(), 5);
        pool.ensure_workers(1);
        assert_eq!(pool.workers(), 5);
        // the widened pool still runs rounds correctly
        let got = pool.run_chunked(12, 5, |i| Ok(i)).unwrap();
        assert_eq!(got, (0..12).collect::<Vec<_>>());
    }

    #[test]
    fn empty_round_is_a_noop() {
        let pool = WorkerPool::new(1);
        pool.scope(Vec::new());
        assert_eq!(pool.run_chunked::<usize, _>(0, 3, |_| Ok(0)).unwrap(), Vec::<usize>::new());
        assert_eq!(pool.workers(), 1);
    }

    #[test]
    fn concurrent_dispatchers_share_one_pool() {
        // the parallel-dispatch shape: N threads each driving rounds
        // through run_chunked on ONE shared pool, concurrently. Every
        // round must complete with index-aligned results and no lost
        // wakeups (each submit wakes exactly as many workers as jobs).
        let pool = WorkerPool::shared(4);
        std::thread::scope(|s| {
            for d in 0..4usize {
                let pool = pool.clone();
                s.spawn(move || {
                    for round in 0..50usize {
                        let got = pool
                            .run_chunked(6, 2, |i| Ok(d * 1000 + round * 10 + i))
                            .unwrap();
                        let want: Vec<usize> =
                            (0..6).map(|i| d * 1000 + round * 10 + i).collect();
                        assert_eq!(got, want, "dispatcher {d} round {round}");
                    }
                });
            }
        });
        assert_eq!(pool.workers(), 4);
    }
}
