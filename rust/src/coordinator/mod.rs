//! The serving coordinator — Layer 3.
//!
//! Owns the multi-model fleet: per-instance weight banks, the merged
//! NETFUSE executable, the paper's three baselines, request routing,
//! batching, memory accounting and metrics (paper §5.1 "Baselines"):
//!
//! - `Sequential` — round-robin, one model at a time.
//! - `Concurrent` — one worker per model, no synchronization.
//! - `Hybrid`     — A workers x B models each (§5.3).
//! - `NetFuse`    — one merged executable for all M models.
//!
//! The round data plane is zero-copy in steady state: [`arena`] owns the
//! reusable megabatch + pad buffers, [`pool`] owns the persistent
//! strategy workers, and `service::Fleet` wires both into the four
//! strategies.

pub mod arena;
pub mod memory;
pub mod metrics;
pub mod pool;
pub mod request;
pub mod service;
pub mod strategy;
pub mod server;
pub mod workload;

pub use arena::{Layout, RoundArena};
pub use pool::WorkerPool;
pub use request::{Request, Response};
pub use service::Fleet;
pub use strategy::StrategyKind;
