//! The serving coordinator — Layer 3.
//!
//! Owns the multi-model fleet: per-instance weight banks, the merged
//! NETFUSE executable, the paper's three baselines, request routing,
//! batching, memory accounting and metrics (paper §5.1 "Baselines"):
//!
//! - `Sequential` — round-robin, one model at a time.
//! - `Concurrent` — one worker per model, no synchronization.
//! - `Hybrid`     — A workers x B models each (§5.3).
//! - `NetFuse`    — one merged executable for all M models.
//!
//! The round data plane is zero-copy in steady state: [`arena`] owns the
//! reusable megabatch + pad buffers (an `arena::ArenaRing` of `depth`
//! independently reservable slots, so up to `depth` NETFUSE rounds
//! overlap across threads), [`pool`] owns the persistent strategy
//! workers (shareable across fleets), and `service::Fleet` wires both
//! into the four strategies.
//!
//! Serving front ends: `server::Server` is the single-fleet router +
//! batcher; [`multi`]'s `MultiServer` hosts several fleets as tenants
//! of one machine — per-fleet lanes, QoS-scheduled round dispatch
//! (weighted deficit round-robin + SLO-deadline boost via
//! `crate::ingress::qos`), one shared `WorkerPool` sized to the box,
//! and cross-fleet round coalescing ([`coalesce`]): lanes serving the
//! same model family at the same shape merge their rounds into ONE
//! megabatch execution (`arena::SlotMap` remaps lane-local slots), so
//! the merged program's launch is amortized across tenants, not just
//! across the instances of one lane. Both front ends are generic over
//! `service::RoundExecutor`, the slot-level round contract `Fleet`
//! implements. Open-loop traffic reaches `MultiServer` through
//! `crate::ingress` (frames -> transports -> bounded bridge -> the
//! dispatch thread), or — sharded — through `multi::ParallelDispatcher`
//! (one dispatch thread per lane group over one shared ring and pool,
//! `crate::ingress::run_dispatch_parallel`).
//!
//! Since ADR-005 the topology is **elastic**: [`control`]'s
//! `TopologyController` adds, removes, and hot-swaps lanes on a live
//! dispatcher (`crate::ingress::run_dispatch_elastic`) — the routing
//! tables are epoch-stamped state behind `multi::Topology`, lane slots
//! carry a `multi::LaneLife` lifecycle, and per-partition command
//! queues apply every mutation strictly between rounds.
//!
//! Since ADR-006 the whole plane is **observable**: requests carry
//! monotonic stage stamps ([`request::Stamps`]) folded into per-lane
//! stage histograms, each dispatch thread keeps a flight-recorder ring
//! of recent decisions, and a live [`obs::ObsHub`] answers
//! `ObsQuery`/`ObsReport` introspection frames over the same wire that
//! carries traffic.

pub mod arena;
pub mod coalesce;
pub mod control;
pub mod memory;
pub mod metrics;
pub mod mock;
pub mod multi;
pub mod obs;
pub mod pool;
pub mod request;
pub mod service;
pub mod strategy;
pub mod server;
pub mod workload;

pub use arena::{ArenaRing, Layout, RingSlot, RoundArena, SlotMap};
pub use coalesce::CoalesceKey;
pub use control::{
    AddOutcome, ControlPlane, LaneCmd, PartControl, RemoveOutcome, Ticket, TopologyController,
};
pub use multi::{
    Dispatched, GroupSpec, GroupStats, LaneLife, LaneSpec, MultiServer, ParallelDispatcher,
    Topology, TopologySnapshot,
};
pub use obs::{
    CtrlKind, Dump, Event, EventKind, EventRing, FlightRecorder, LaneGauge, ObsCore, ObsHub,
    RecHandle, Stage, StageTracer,
};
pub use pool::WorkerPool;
pub use request::{Request, Response, Stamps};
pub use service::{Fleet, RoundExecutor};
pub use strategy::StrategyKind;
