//! `.nft` tensor container IO — byte-compatible with
//! `python/compile/weights.py` (see that module for the layout spec).

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Tensor;

const MAGIC: &[u8; 4] = b"NFT1";

/// Read an entire `.nft` container into name -> tensor.
pub fn read_nft(path: &Path) -> Result<BTreeMap<String, Tensor>> {
    let mut buf = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("open {}", path.display()))?
        .read_to_end(&mut buf)?;
    parse_nft(&buf).with_context(|| format!("parse {}", path.display()))
}

pub fn parse_nft(buf: &[u8]) -> Result<BTreeMap<String, Tensor>> {
    if buf.len() < 8 || &buf[..4] != MAGIC {
        bail!("bad magic (not an NFT1 container)");
    }
    let mut off = 4usize;
    let count = read_u32(buf, &mut off)? as usize;
    let mut out = BTreeMap::new();
    for _ in 0..count {
        let nlen = read_u16(buf, &mut off)? as usize;
        let name = std::str::from_utf8(slice(buf, &mut off, nlen)?)
            .context("tensor name not utf-8")?
            .to_string();
        let dtype = read_u8(buf, &mut off)?;
        if dtype != 0 {
            bail!("tensor {name}: unsupported dtype {dtype}");
        }
        let ndim = read_u8(buf, &mut off)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u32(buf, &mut off)? as usize);
        }
        let n: usize = shape.iter().product();
        let raw = slice(buf, &mut off, 4 * n)?;
        let mut data = Vec::with_capacity(n);
        for c in raw.chunks_exact(4) {
            data.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        out.insert(name, Tensor::new(shape, data)?);
    }
    if off != buf.len() {
        bail!("trailing bytes after {count} tensors");
    }
    Ok(out)
}

/// Write tensors to a `.nft` container (ordering = map order).
pub fn write_nft(path: &Path, tensors: &BTreeMap<String, Tensor>) -> Result<()> {
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("create {}", path.display()))?;
    f.write_all(MAGIC)?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        let nb = name.as_bytes();
        if nb.len() > u16::MAX as usize {
            bail!("tensor name too long");
        }
        f.write_all(&(nb.len() as u16).to_le_bytes())?;
        f.write_all(nb)?;
        f.write_all(&[0u8, t.rank() as u8])?;
        for d in t.shape() {
            f.write_all(&(*d as u32).to_le_bytes())?;
        }
        let mut raw = Vec::with_capacity(4 * t.len());
        for v in t.data() {
            raw.extend_from_slice(&v.to_le_bytes());
        }
        f.write_all(&raw)?;
    }
    Ok(())
}

fn slice<'a>(buf: &'a [u8], off: &mut usize, n: usize) -> Result<&'a [u8]> {
    if *off + n > buf.len() {
        bail!("truncated container at byte {}", off);
    }
    let s = &buf[*off..*off + n];
    *off += n;
    Ok(s)
}

fn read_u8(buf: &[u8], off: &mut usize) -> Result<u8> {
    Ok(slice(buf, off, 1)?[0])
}

fn read_u16(buf: &[u8], off: &mut usize) -> Result<u16> {
    let s = slice(buf, off, 2)?;
    Ok(u16::from_le_bytes([s[0], s[1]]))
}

fn read_u32(buf: &[u8], off: &mut usize) -> Result<u32> {
    let s = slice(buf, off, 4)?;
    Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("netfuse_nft_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.nft");
        let mut m = BTreeMap::new();
        m.insert(
            "a/b.w".to_string(),
            Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap(),
        );
        m.insert("scalar".to_string(), Tensor::scalar(7.5));
        write_nft(&path, &m).unwrap();
        let back = read_nft(&path).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(parse_nft(b"XXXX\x00\x00\x00\x00").is_err());
    }

    #[test]
    fn rejects_truncation() {
        let mut m = BTreeMap::new();
        m.insert("x".to_string(), Tensor::zeros(&[4]));
        let dir = std::env::temp_dir().join("netfuse_nft_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.nft");
        write_nft(&path, &m).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert!(parse_nft(&bytes[..bytes.len() - 3]).is_err());
        // and trailing garbage
        let mut extended = bytes.clone();
        extended.push(0);
        assert!(parse_nft(&extended).is_err());
    }
}
