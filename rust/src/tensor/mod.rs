//! Dense tensor library: the coordinator's host-side data plane.
//!
//! Holds request payloads, weight banks and megabatch buffers; implements
//! the concat/stack/slice operations the NETFUSE batcher and weight
//! merger need (paper §3.1: inputs concat on batch or channel, weights
//! concat or stack per op kind). f32-only — everything the AOT pipeline
//! emits is f32.

pub mod io;

use anyhow::{bail, Result};

/// A dense, row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

/// A borrowed, zero-copy window into a [`Tensor`] (shape + contiguous
/// data slice). This is the steady-state currency of the round pipeline:
/// `Fleet::unpack` hands out one view per instance into the merged
/// output instead of materializing M copies; callers promote to an owned
/// tensor with [`TensorView::to_owned`] only where a response actually
/// leaves the server.
#[derive(Debug, Clone, Copy)]
pub struct TensorView<'a> {
    shape: &'a [usize],
    data: &'a [f32],
}

impl<'a> TensorView<'a> {
    /// View over externally managed storage (shape must match the slice).
    pub fn new(shape: &'a [usize], data: &'a [f32]) -> Result<TensorView<'a>> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("view shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(TensorView { shape, data })
    }

    pub fn shape(&self) -> &[usize] {
        self.shape
    }
    pub fn rank(&self) -> usize {
        self.shape.len()
    }
    pub fn len(&self) -> usize {
        self.data.len()
    }
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
    pub fn data(&self) -> &'a [f32] {
        self.data
    }

    /// Promote to an owned tensor (the only copying step on the unpack
    /// path, paid per occupied slot rather than per round — so it goes
    /// through the feature-detected wide copy).
    pub fn to_owned(&self) -> Tensor {
        Tensor { shape: self.shape.to_vec(), data: crate::util::simd::to_vec(self.data) }
    }

    /// Max |a - b| over all elements.
    pub fn max_abs_diff(&self, other: &TensorView<'_>) -> Result<f64> {
        if self.shape != other.shape {
            bail!("shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        Ok(self
            .data
            .iter()
            .zip(other.data)
            .map(|(a, b)| (a - b).abs() as f64)
            .fold(0.0, f64::max))
    }

    /// Relative-tolerance comparison mirroring numpy.allclose.
    pub fn allclose(&self, other: &TensorView<'_>, rtol: f64, atol: f64) -> bool {
        self.shape == other.shape
            && self.data.iter().zip(other.data).all(|(a, b)| {
                let (a, b) = (*a as f64, *b as f64);
                (a - b).abs() <= atol + rtol * b.abs()
            })
    }
}

impl PartialEq for TensorView<'_> {
    fn eq(&self, other: &TensorView<'_>) -> bool {
        self.shape == other.shape && self.data == other.data
    }
}

impl PartialEq<Tensor> for TensorView<'_> {
    fn eq(&self, other: &Tensor) -> bool {
        self.shape == other.shape.as_slice() && self.data == other.data.as_slice()
    }
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Deterministic standard-normal tensor (synthetic request payloads).
    pub fn randn(shape: &[usize], rng: &mut crate::util::rng::Rng) -> Tensor {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| rng.normal() as f32).collect();
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }
    pub fn rank(&self) -> usize {
        self.shape.len()
    }
    pub fn len(&self) -> usize {
        self.data.len()
    }
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
    pub fn data(&self) -> &[f32] {
        &self.data
    }
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }
    pub fn into_data(self) -> Vec<f32> {
        self.data
    }
    /// Decompose into `(shape, data)` without copying (wire encoding).
    pub fn into_parts(self) -> (Vec<usize>, Vec<f32>) {
        (self.shape, self.data)
    }
    pub fn size_bytes(&self) -> u64 {
        4 * self.data.len() as u64
    }

    /// Reshape without copying (element count must match).
    pub fn reshape(mut self, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("cannot reshape {:?} -> {:?}", self.shape, shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// Row-major strides (exposed for layout-aware consumers/tests).
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1; self.shape.len()];
        for i in (0..self.shape.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.shape[i + 1];
        }
        s
    }

    /// Concatenate along `axis`. All other dims must agree.
    pub fn concat(parts: &[&Tensor], axis: usize) -> Result<Tensor> {
        if parts.is_empty() {
            bail!("concat of zero tensors");
        }
        let rank = parts[0].rank();
        if axis >= rank {
            bail!("concat axis {} out of range for rank {}", axis, rank);
        }
        let mut out_shape = parts[0].shape.clone();
        let mut axis_total = 0;
        for p in parts {
            if p.rank() != rank {
                bail!("concat rank mismatch: {:?} vs {:?}", parts[0].shape, p.shape);
            }
            for d in 0..rank {
                if d != axis && p.shape[d] != parts[0].shape[d] {
                    bail!(
                        "concat dim {} mismatch: {:?} vs {:?}",
                        d, parts[0].shape, p.shape
                    );
                }
            }
            axis_total += p.shape[axis];
        }
        out_shape[axis] = axis_total;

        // copy per outer-block: outer = prod(dims < axis); the per-part
        // inner run lengths are invariant across outer blocks, so compute
        // them once instead of re-reducing the shape every iteration
        let outer: usize = parts[0].shape[..axis].iter().product();
        let inners: Vec<usize> = parts
            .iter()
            .map(|p| p.shape[axis..].iter().product())
            .collect();
        let mut data = Vec::with_capacity(out_shape.iter().product());
        for o in 0..outer {
            for (p, &inner) in parts.iter().zip(&inners) {
                let off = o * inner;
                data.extend_from_slice(&p.data[off..off + inner]);
            }
        }
        Tensor::new(out_shape, data)
    }

    /// Stack along a new leading axis.
    pub fn stack(parts: &[&Tensor]) -> Result<Tensor> {
        if parts.is_empty() {
            bail!("stack of zero tensors");
        }
        for p in parts {
            if p.shape != parts[0].shape {
                bail!("stack shape mismatch: {:?} vs {:?}", parts[0].shape, p.shape);
            }
        }
        let mut shape = vec![parts.len()];
        shape.extend_from_slice(&parts[0].shape);
        let mut data = Vec::with_capacity(parts.len() * parts[0].len());
        for p in parts {
            data.extend_from_slice(&p.data);
        }
        Tensor::new(shape, data)
    }

    /// Split into `n` equal chunks along `axis` (inverse of concat).
    pub fn split(&self, n: usize, axis: usize) -> Result<Vec<Tensor>> {
        if axis >= self.rank() {
            bail!("split axis {} out of range", axis);
        }
        if n == 0 || self.shape[axis] % n != 0 {
            bail!("cannot split dim {} into {} parts", self.shape[axis], n);
        }
        let chunk = self.shape[axis] / n;
        let outer: usize = self.shape[..axis].iter().product();
        let inner: usize = self.shape[axis + 1..].iter().product();
        let mut out_shape = self.shape.clone();
        out_shape[axis] = chunk;
        let mut outs = vec![Vec::with_capacity(outer * chunk * inner); n];
        for o in 0..outer {
            for (i, out) in outs.iter_mut().enumerate() {
                let off = (o * self.shape[axis] + i * chunk) * inner;
                out.extend_from_slice(&self.data[off..off + chunk * inner]);
            }
        }
        outs.into_iter()
            .map(|d| Tensor::new(out_shape.clone(), d))
            .collect()
    }

    /// Whole-tensor borrowed view.
    pub fn view(&self) -> TensorView<'_> {
        TensorView { shape: &self.shape, data: &self.data }
    }

    /// Zero-copy index of the leading axis: `[M, ...] -> view of [...]`.
    /// This is the unpack fast path — the merged output is always
    /// batch-packed `[M, bs, ...]`, so every per-instance output is a
    /// contiguous window.
    pub fn view0(&self, i: usize) -> Result<TensorView<'_>> {
        if self.rank() == 0 || i >= self.shape[0] {
            bail!("view0 {} out of range for {:?}", i, self.shape);
        }
        let inner: usize = self.shape[1..].iter().product();
        Ok(TensorView {
            shape: &self.shape[1..],
            data: &self.data[i * inner..(i + 1) * inner],
        })
    }

    /// Index the leading axis, materialized: `[M, ...] -> [...]`.
    /// Delegates to [`Tensor::view0`]; prefer the view when the copy is
    /// not needed.
    pub fn index0(&self, i: usize) -> Result<Tensor> {
        Ok(self.view0(i)?.to_owned())
    }

    /// Max |a - b| over all elements.
    pub fn max_abs_diff(&self, other: &Tensor) -> Result<f64> {
        self.view().max_abs_diff(&other.view())
    }

    /// Relative-tolerance comparison mirroring numpy.allclose.
    pub fn allclose(&self, other: &Tensor, rtol: f64, atol: f64) -> bool {
        self.view().allclose(&other.view(), rtol, atol)
    }

    /// Transpose the first axis with the second for rank >= 2 tensors
    /// (the batcher's channel<->batch repack helper).
    pub fn swap01(&self) -> Result<Tensor> {
        if self.rank() < 2 {
            bail!("swap01 needs rank >= 2, got {:?}", self.shape);
        }
        let (a, b) = (self.shape[0], self.shape[1]);
        let inner: usize = self.shape[2..].iter().product();
        let mut data = vec![0.0f32; self.data.len()];
        for i in 0..a {
            for j in 0..b {
                let src = (i * b + j) * inner;
                let dst = (j * a + i) * inner;
                crate::util::simd::copy(&mut data[dst..dst + inner], &self.data[src..src + inner]);
            }
        }
        let mut shape = self.shape.clone();
        shape.swap(0, 1);
        Tensor::new(shape, data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: &[usize], data: &[f32]) -> Tensor {
        Tensor::new(shape.to_vec(), data.to_vec()).unwrap()
    }

    #[test]
    fn new_checks_len() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn concat_axis0() {
        let a = t(&[2, 2], &[1., 2., 3., 4.]);
        let b = t(&[1, 2], &[5., 6.]);
        let c = Tensor::concat(&[&a, &b], 0).unwrap();
        assert_eq!(c.shape(), &[3, 2]);
        assert_eq!(c.data(), &[1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn concat_axis1_interleaves() {
        let a = t(&[2, 1], &[1., 2.]);
        let b = t(&[2, 2], &[10., 11., 20., 21.]);
        let c = Tensor::concat(&[&a, &b], 1).unwrap();
        assert_eq!(c.shape(), &[2, 3]);
        assert_eq!(c.data(), &[1., 10., 11., 2., 20., 21.]);
    }

    #[test]
    fn concat_rejects_mismatch() {
        let a = t(&[2, 2], &[0.; 4]);
        let b = t(&[3, 3], &[0.; 9]);
        assert!(Tensor::concat(&[&a, &b], 0).is_err());
        assert!(Tensor::concat(&[&a], 5).is_err());
        assert!(Tensor::concat(&[], 0).is_err());
    }

    #[test]
    fn split_inverts_concat() {
        let a = t(&[1, 2, 2], &[1., 2., 3., 4.]);
        let b = t(&[1, 2, 2], &[5., 6., 7., 8.]);
        let c = Tensor::concat(&[&a, &b], 1).unwrap(); // channel-ish axis
        let parts = c.split(2, 1).unwrap();
        assert_eq!(parts[0], a);
        assert_eq!(parts[1], b);
    }

    #[test]
    fn stack_and_index0() {
        let a = t(&[2], &[1., 2.]);
        let b = t(&[2], &[3., 4.]);
        let s = Tensor::stack(&[&a, &b]).unwrap();
        assert_eq!(s.shape(), &[2, 2]);
        assert_eq!(s.index0(1).unwrap(), b);
        assert!(s.index0(2).is_err());
    }

    #[test]
    fn swap01_roundtrip() {
        let a = t(&[2, 3, 2], &(0..12).map(|x| x as f32).collect::<Vec<_>>());
        let b = a.swap01().unwrap();
        assert_eq!(b.shape(), &[3, 2, 2]);
        assert_eq!(b.swap01().unwrap(), a);
        // spot value: a[1,2,:] == b[2,1,:]
        assert_eq!(&b.data()[(2 * 2 + 1) * 2..(2 * 2 + 1) * 2 + 2], &[10., 11.]);
    }

    #[test]
    fn view0_is_zero_copy_window() {
        let a = t(&[2], &[1., 2.]);
        let b = t(&[2], &[3., 4.]);
        let s = Tensor::stack(&[&a, &b]).unwrap();
        let v = s.view0(1).unwrap();
        assert_eq!(v.shape(), &[2]);
        assert_eq!(v.data(), &[3., 4.]);
        // the view's slice aliases the stacked buffer (no copy)
        assert_eq!(v.data().as_ptr(), s.data()[2..].as_ptr());
        assert_eq!(v.to_owned(), b);
        assert!(v == b);
        assert!(s.view0(2).is_err());
    }

    #[test]
    fn views_compare_like_tensors() {
        let a = t(&[2, 2], &[1., 2., 3., 4.]);
        let b = t(&[2, 2], &[1. + 1e-7, 2., 3., 4.]);
        assert!(a.view().allclose(&b.view(), 1e-5, 1e-6));
        assert!(a.view().max_abs_diff(&b.view()).unwrap() < 1e-6);
        let c = t(&[4], &[1., 2., 3., 4.]);
        assert!(a.view().max_abs_diff(&c.view()).is_err());
        assert!(TensorView::new(&[3], &[0.0; 2]).is_err());
    }

    #[test]
    fn allclose_tolerances() {
        let a = t(&[2], &[1.0, 2.0]);
        let b = t(&[2], &[1.0 + 1e-7, 2.0 - 1e-7]);
        assert!(a.allclose(&b, 1e-5, 1e-6));
        let c = t(&[2], &[1.1, 2.0]);
        assert!(!a.allclose(&c, 1e-5, 1e-6));
    }

    #[test]
    fn reshape_checks() {
        let a = t(&[2, 3], &[0.; 6]);
        assert!(a.clone().reshape(&[3, 2]).is_ok());
        assert!(a.reshape(&[4, 2]).is_err());
    }
}
