//! Per-thread sharded accumulators with merge-on-read.
//!
//! The N-thread `ParallelDispatcher` (PR 5) records metrics on every
//! round and every admitted request; funneling those through one
//! `Mutex` serializes the dispatch threads at exactly the moment they
//! should be independent. A [`Sharded<T>`] gives each recording thread
//! its own cache-line-padded shard — the lock it takes is private to
//! it, so the fast path is an uncontended lock/unlock (no cross-core
//! line bouncing) — and readers fold all shards into one `T` through
//! the [`Shardable`] merge. This generalizes the `IngressStats::merge`
//! idiom that `run_dispatch_parallel` already used at join time, but
//! lets the merged view be taken *while* the threads are still
//! recording.
//!
//! Shard count is fixed at construction (one per expected recording
//! thread). Registration is round-robin and wraps: over-registering
//! shares shards, which is safe (each shard is a `Mutex`), merely less
//! parallel.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::util::lock::{LockGuard, LockRank, OrderedMutex};

/// An accumulator whose per-shard states can be folded into one.
/// Merging must commute with recording: merging shards A and B must
/// equal a single accumulator that saw both record streams (in any
/// interleaving) — that is what makes merge-on-read exact.
pub trait Shardable: Default {
    /// Lock rank every shard of this accumulator type acquires at
    /// (ADR-008). Distinct `Sharded` instance types that nest — the
    /// admit path folds tracer and recorder shards while its
    /// stats-shard guard is held — override this so the lock tracker
    /// sees the real hierarchy instead of a same-rank double-acquire.
    const RANK: LockRank = LockRank::StatsShard;

    fn merge_from(&mut self, other: &Self);
}

/// Pad each shard to its own cache line so two threads recording into
/// adjacent shards never false-share.
#[repr(align(64))]
struct CacheLine<T>(OrderedMutex<T>);

/// A fixed set of cache-line-padded shards of `T`.
pub struct Sharded<T> {
    shards: Vec<CacheLine<T>>,
    next: AtomicUsize,
}

impl<T: Shardable> Sharded<T> {
    /// `shards` is clamped to at least 1.
    pub fn new(shards: usize) -> Self {
        let n = shards.max(1);
        let shards = (0..n).map(|_| CacheLine(OrderedMutex::new(T::RANK, T::default()))).collect();
        Sharded { shards, next: AtomicUsize::new(0) }
    }

    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Claim the next shard round-robin (associated fn: the handle
    /// keeps the `Arc` alive). Wraps when more handles are registered
    /// than shards exist (those handles then share a lock).
    pub fn register(this: &Arc<Self>) -> ShardHandle<T> {
        let index = this.next.fetch_add(1, Ordering::Relaxed) % this.shards.len();
        ShardHandle { shared: Arc::clone(this), index }
    }

    /// Fold every shard into a fresh `T`. Safe to call while writers
    /// are live — each shard is locked only long enough to merge it.
    pub fn read(&self) -> T {
        let mut out = T::default();
        for s in &self.shards {
            out.merge_from(&s.0.lock());
        }
        out
    }
}

/// A recording thread's claim on one shard.
pub struct ShardHandle<T> {
    shared: Arc<Sharded<T>>,
    index: usize,
}

impl<T> ShardHandle<T> {
    /// Lock this handle's shard. Uncontended unless handles share a
    /// shard (registration wrapped) or a reader is mid-merge on it.
    pub fn lock(&self) -> LockGuard<'_, T> {
        self.shared.shards[self.index].0.lock()
    }

    pub fn index(&self) -> usize {
        self.index
    }
}

impl<T: Shardable> ShardHandle<T> {
    /// Fold every shard of the underlying [`Sharded`] into a fresh `T`
    /// — the merged view, readable from any thread that only holds a
    /// handle (the dispatch loops answer `ObsQuery` snapshots this
    /// way without threading the `Arc` through their signatures).
    pub fn merged(&self) -> T {
        self.shared.read()
    }
}

// manual impl: derive(Clone) would demand T: Clone
impl<T> Clone for ShardHandle<T> {
    fn clone(&self) -> Self {
        ShardHandle { shared: Arc::clone(&self.shared), index: self.index }
    }
}

// manual impl so holders (e.g. `Metrics`) can stay derive(Debug)
// without locking the shard to format it
impl<T> std::fmt::Debug for ShardHandle<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShardHandle").field("index", &self.index).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Default)]
    struct Count(u64);
    impl Shardable for Count {
        fn merge_from(&mut self, other: &Self) {
            self.0 += other.0;
        }
    }

    #[test]
    fn registration_is_round_robin_and_wraps() {
        let s = Arc::new(Sharded::<Count>::new(2));
        let (a, b, c) = (Sharded::register(&s), Sharded::register(&s), Sharded::register(&s));
        assert_eq!(a.index(), 0);
        assert_eq!(b.index(), 1);
        assert_eq!(c.index(), 0, "third handle wraps onto shard 0");
    }

    #[test]
    fn read_merges_all_shards() {
        let s = Arc::new(Sharded::<Count>::new(3));
        for add in [5u64, 7, 11] {
            Sharded::register(&s).lock().0 += add;
        }
        assert_eq!(s.read().0, 23);
        let h = Sharded::register(&s);
        assert_eq!(h.merged().0, 23, "a handle's merged view folds every shard");
    }

    #[test]
    fn clones_share_the_shard() {
        let s = Arc::new(Sharded::<Count>::new(4));
        let h = Sharded::register(&s);
        let h2 = h.clone();
        h.lock().0 += 1;
        h2.lock().0 += 1;
        assert_eq!(h.lock().0, 2);
        assert_eq!(s.read().0, 2);
    }

    #[test]
    fn zero_shards_clamps_to_one() {
        let s = Arc::new(Sharded::<Count>::new(0));
        assert_eq!(s.shards(), 1);
        Sharded::register(&s).lock().0 = 9;
        assert_eq!(s.read().0, 9);
    }

    #[test]
    fn concurrent_recording_sums_exactly() {
        let s = Arc::new(Sharded::<Count>::new(4));
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let h = Sharded::register(&s);
                scope.spawn(move || {
                    for _ in 0..10_000 {
                        h.lock().0 += 1;
                    }
                });
            }
        });
        assert_eq!(s.read().0, 40_000);
    }
}
