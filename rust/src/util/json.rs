//! Minimal JSON parser/serializer (serde_json is not available offline).
//!
//! Spec-complete for the subset this system exchanges with the Python
//! build path: objects, arrays, strings (with escapes + `\uXXXX`),
//! numbers, booleans, null. Parsing is recursive-descent with a depth
//! limit; serialization is deterministic (object keys keep insertion
//! order). Fuzz/property tests live in `tests/util_tests.rs`.

use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap keeps key order deterministic for round-trip tests.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; `Json::Null` for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    /// Compact serialization. Round-trips through `parse`.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub at: usize,
    pub msg: String,
}

// Manual impls: `thiserror`'s derive is unavailable offline.
impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { at: self.i, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        if self.depth >= MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        self.depth += 1;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        self.depth += 1;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            // fast path (§Perf iteration 5): bulk-copy the run of plain
            // bytes up to the next quote/escape/control instead of
            // pushing char by char — manifest parse is dominated by
            // large escape-free strings (HLO names, shape lists).
            let start = self.i;
            while let Some(c) = self.peek() {
                if c == b'"' || c == b'\\' || c < 0x20 || c >= 0x80 {
                    break;
                }
                self.i += 1;
            }
            if self.i > start {
                // the run is pure ASCII: always valid UTF-8
                out.push_str(std::str::from_utf8(&self.b[start..self.i]).unwrap());
            }
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            self.i += 1;
                            let cp = self.hex4()?;
                            // surrogate pairs
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    let combined = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                            continue; // hex4 advanced i already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(_) => {
                    // decode one utf-8 char
                    let s = &self.b[self.i..];
                    let len = utf8_len(s[0]);
                    if s.len() < len {
                        return Err(self.err("bad utf-8"));
                    }
                    let chunk = std::str::from_utf8(&s[..len])
                        .map_err(|_| self.err("bad utf-8"))?;
                    out.push_str(chunk);
                    self.i += len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.b.len() < self.i + 4 {
            return Err(self.err("short \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Convenience constructors used by the manifest writers.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        // surrogate pair (😀)
        assert_eq!(
            Json::parse(r#""😀""#).unwrap(),
            Json::Str("😀".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,]", "{\"a\":}", "truex", "1 2", "\"\\q\""] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"m":1,"arr":[1.5,"x",true,null],"nested":{"k":[{}]}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn depth_limit() {
        let s = "[".repeat(200) + &"]".repeat(200);
        assert!(Json::parse(&s).is_err());
    }
}
