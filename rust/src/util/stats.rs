//! Streaming statistics + latency recorder (percentiles, histograms).

/// Welford online mean/variance plus min/max.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Fold another summary in (Chan et al. parallel Welford merge):
    /// the result is exactly what one summary over both sample streams
    /// would hold, so per-thread shards can merge on read.
    pub fn merge_from(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let (n1, n2) = (self.n as f64, other.n as f64);
        let d = other.mean - self.mean;
        self.mean += d * n2 / (n1 + n2);
        self.m2 += other.m2 + d * d * n1 * n2 / (n1 + n2);
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Latency recorder keeping raw samples (bounded) for exact percentiles.
#[derive(Debug, Clone, Default)]
pub struct Latencies {
    samples: Vec<f64>,
    summary: Summary,
}

impl Latencies {
    pub fn new() -> Self {
        Latencies { samples: Vec::new(), summary: Summary::new() }
    }

    pub fn record(&mut self, seconds: f64) {
        self.samples.push(seconds);
        self.summary.add(seconds);
    }

    pub fn summary(&self) -> &Summary {
        &self.summary
    }

    /// Absorb another recorder's samples. Because percentiles are
    /// computed from the raw sample multiset (see [`percentile`]) and
    /// multiset union is order-independent, merged shards report
    /// *identical* percentiles to one recorder that saw every sample —
    /// merge-on-read is exact, not approximate.
    ///
    /// [`percentile`]: Latencies::percentile
    pub fn merge_from(&mut self, other: &Latencies) {
        self.samples.extend_from_slice(&other.samples);
        self.summary.merge_from(&other.summary);
    }

    /// Exact percentile over the raw samples, pinned to the
    /// **nearest-rank** convention (q in [0,1]): sort ascending, take
    /// the 1-indexed element at `ceil(q * n)` clamped to `[1, n]`. No
    /// interpolation — the result is always an observed sample, and it
    /// depends only on the sample multiset (not on recording order).
    pub fn percentile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut v = self.samples.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((q * v.len() as f64).ceil() as usize).clamp(1, v.len());
        v[rank - 1]
    }

    pub fn p50(&self) -> f64 {
        self.percentile(0.50)
    }
    pub fn p95(&self) -> f64 {
        self.percentile(0.95)
    }
    pub fn p99(&self) -> f64 {
        self.percentile(0.99)
    }
    pub fn count(&self) -> usize {
        self.samples.len()
    }
}

impl crate::util::shard::Shardable for Latencies {
    fn merge_from(&mut self, other: &Self) {
        Latencies::merge_from(self, other);
    }
}

/// Pretty time formatting for reports.
pub fn fmt_secs(s: f64) -> String {
    if s.is_nan() {
        "n/a".into()
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

/// Pretty byte formatting for memory reports.
pub fn fmt_bytes(b: u64) -> String {
    const G: f64 = 1024.0 * 1024.0 * 1024.0;
    const M: f64 = 1024.0 * 1024.0;
    let f = b as f64;
    if f >= G {
        format!("{:.2}GB", f / G)
    } else if f >= M {
        format!("{:.1}MB", f / M)
    } else {
        format!("{:.1}KB", f / 1024.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-9);
        assert!((s.var() - 32.0 / 7.0).abs() < 1e-9);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentiles() {
        let mut l = Latencies::new();
        for i in 1..=100 {
            l.record(i as f64);
        }
        assert_eq!(l.p50(), 50.0);
        assert_eq!(l.p95(), 95.0);
        assert_eq!(l.p99(), 99.0);
        assert_eq!(l.percentile(1.0), 100.0);
    }

    #[test]
    fn empty_latencies_nan() {
        assert!(Latencies::new().p50().is_nan());
    }

    #[test]
    fn summary_merge_matches_single_stream() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0, 1.5, 3.25];
        let mut whole = Summary::new();
        for &x in &xs {
            whole.add(x);
        }
        let (mut a, mut b) = (Summary::new(), Summary::new());
        for &x in &xs[..3] {
            a.add(x);
        }
        for &x in &xs[3..] {
            b.add(x);
        }
        a.merge_from(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        assert!((a.var() - whole.var()).abs() < 1e-12);
        assert_eq!(a.min(), whole.min());
        assert_eq!(a.max(), whole.max());
    }

    #[test]
    fn summary_merge_with_empty_sides() {
        let mut a = Summary::new();
        a.merge_from(&Summary::new());
        assert_eq!(a.count(), 0);
        let mut b = Summary::new();
        b.add(3.0);
        a.merge_from(&b);
        assert_eq!(a.count(), 1);
        assert_eq!(a.mean(), 3.0);
        b.merge_from(&Summary::new());
        assert_eq!(b.count(), 1);
    }

    #[test]
    fn latency_merge_percentiles_are_exact() {
        // split 1..=100 across three recorders in a scrambled order:
        // merged percentiles must equal the single-recorder ones.
        let mut whole = Latencies::new();
        let mut parts = [Latencies::new(), Latencies::new(), Latencies::new()];
        for i in 1..=100u64 {
            whole.record(i as f64);
            parts[(i * 7 % 3) as usize].record(i as f64);
        }
        let mut merged = Latencies::new();
        for p in &parts {
            merged.merge_from(p);
        }
        assert_eq!(merged.count(), whole.count());
        assert_eq!(merged.p50(), whole.p50());
        assert_eq!(merged.p95(), whole.p95());
        assert_eq!(merged.p99(), whole.p99());
        assert_eq!(merged.percentile(1.0), whole.percentile(1.0));
        assert!((merged.summary().mean() - whole.summary().mean()).abs() < 1e-12);
    }

    #[test]
    fn formats() {
        assert_eq!(fmt_secs(0.00005), "50.0µs");
        assert_eq!(fmt_secs(0.0123), "12.30ms");
        assert_eq!(fmt_bytes(1536), "1.5KB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024 * 1024), "3.00GB");
    }
}
