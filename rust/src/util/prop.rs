//! Mini property-based-testing framework (proptest is not available
//! offline). Generators are plain closures over [`Rng`]; failures are
//! shrunk by retrying with smaller size parameters.
//!
//! Used by `rust/tests/` for the fuse/tensor/json invariants.

use super::rng::Rng;

/// Run `prop` against `cases` random inputs produced by `gen` at growing
/// sizes. On failure, retry smaller sizes to report a minimal-ish case.
pub fn check<T: std::fmt::Debug, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng, usize) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(0xC0FFEE ^ hash(name));
    for case in 0..cases {
        // size ramps up with the case index, like proptest
        let size = 1 + case * 16 / cases.max(1);
        let input = gen(&mut rng, size);
        if let Err(msg) = prop(&input) {
            // shrink: try progressively smaller sizes with fresh values
            let mut minimal: Option<(T, String)> = None;
            for s in (1..size).rev() {
                for _ in 0..20 {
                    let cand = gen(&mut rng, s);
                    if let Err(m) = prop(&cand) {
                        minimal = Some((cand, m));
                    }
                }
            }
            match minimal {
                Some((m, mm)) => panic!(
                    "property {name:?} failed (case {case}):\n  \
                     original: {input:?}\n  error: {msg}\n  \
                     shrunk: {m:?}\n  error: {mm}"
                ),
                None => panic!(
                    "property {name:?} failed (case {case}):\n  \
                     input: {input:?}\n  error: {msg}"
                ),
            }
        }
    }
}

/// Assertion helper for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

fn hash(s: &str) -> u64 {
    // FNV-1a
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs() {
        check("reverse-involutive", 50, |r, size| {
            (0..size).map(|_| r.below(100)).collect::<Vec<_>>()
        }, |v| {
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            if w == *v { Ok(()) } else { Err("not involutive".into()) }
        });
    }

    #[test]
    #[should_panic(expected = "sorted-is-identity")]
    fn failing_property_panics() {
        check("sorted-is-identity", 100, |r, size| {
            (0..size + 2).map(|_| r.below(100)).collect::<Vec<_>>()
        }, |v| {
            let mut w = v.clone();
            w.sort();
            if w == *v { Ok(()) } else { Err("differs".into()) }
        });
    }
}
