//! In-repo invariant lint (ADR-008): the engine behind `pallas-lint`.
//!
//! Four rules, each encoding a repo-wide invariant the compiler cannot
//! check, run over every `.rs` file under `rust/src` by the
//! `pallas-lint` binary (a required CI step before the build):
//!
//! 1. **`unsafe-needs-safety-comment`** — every line containing the
//!    `unsafe` keyword must have a `SAFETY` note in the contiguous
//!    comment/attribute block directly above it (doc comments count).
//! 2. **`target-feature-call-outside-simd`** — functions declared with
//!    `#[target_feature]` may only be called from `util/simd.rs`, the
//!    one place with the runtime CPU-feature dispatch; a direct call
//!    anywhere else can execute illegal instructions on older CPUs.
//! 3. **`raw-lock-outside-util-lock`** — `std::sync::Mutex`/`RwLock`
//!    may only be named inside `util/lock.rs`: everything else takes
//!    rank-checked `OrderedMutex`/`OrderedRwLock` wrappers, which is
//!    what makes the lockdep tracker's coverage total. (`Condvar` stays
//!    raw — it carries no ordering of its own.)
//! 4. **`hot-path-panic`** — in the hot-path modules (the dispatch
//!    loop's per-round code: `coordinator/multi.rs`,
//!    `ingress/bridge.rs`, `ingress/qos.rs`, `coordinator/arena.rs`),
//!    `.unwrap()`, `.expect(...)` and slice indexing `x[i]` are banned:
//!    a panic there kills a dispatch thread and strands every queued
//!    request. `#[cfg(test)] mod` bodies are exempt.
//!
//! Suppression is explicit and audited: a comment
//! `// LINT-ALLOW(reason)` — the reason is mandatory — exempts the
//! next item (the whole body, brace-matched, when that item is a
//! `fn`), or only its own line when it trails code. The lexer is
//! hand-rolled (the offline registry has no syn/proc-macro stack): it
//! tracks line/block comments (nested), string/char/raw-string
//! literals, attributes and brace depth, which is exactly enough
//! syntax for these four token-level rules.

use std::fs;
use std::path::{Path, PathBuf};

/// Rule 1: `unsafe` without a `SAFETY` comment directly above.
pub const RULE_SAFETY: &str = "unsafe-needs-safety-comment";
/// Rule 2: direct `#[target_feature]` kernel call outside `util/simd.rs`.
pub const RULE_KERNEL: &str = "target-feature-call-outside-simd";
/// Rule 3: raw `std::sync` lock named outside `util/lock.rs`.
pub const RULE_RAW_LOCK: &str = "raw-lock-outside-util-lock";
/// Rule 4: panic-capable construct in a hot-path module.
pub const RULE_HOT_PANIC: &str = "hot-path-panic";

/// Modules where rule 4 applies (path suffix match): the code a
/// dispatch thread runs per round or per admitted request.
pub const HOT_PATH_SUFFIXES: &[&str] = &[
    "coordinator/multi.rs",
    "ingress/bridge.rs",
    "ingress/qos.rs",
    "coordinator/arena.rs",
];

const KERNEL_HOME_SUFFIX: &str = "util/simd.rs";
const LOCK_HOME_SUFFIX: &str = "util/lock.rs";

/// One lint violation.
#[derive(Debug, Clone)]
pub struct Finding {
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: &'static str,
    pub msg: String,
}

impl Finding {
    pub fn render(&self) -> String {
        format!("{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Lint every `.rs` file under `root` (recursively, sorted for
/// deterministic output).
pub fn lint_tree(root: &Path) -> std::io::Result<Vec<Finding>> {
    let mut paths = Vec::new();
    collect_rs(root, &mut paths)?;
    paths.sort();
    let mut files = Vec::with_capacity(paths.len());
    for p in paths {
        let text = fs::read_to_string(&p)?;
        files.push((p.to_string_lossy().replace('\\', "/"), text));
    }
    Ok(lint_sources(&files))
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let p = entry?.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint a set of `(path, source)` pairs. Paths only matter as
/// suffixes (hot-path membership, `util/simd.rs`, `util/lock.rs`), so
/// tests can lint fixtures under any logical path they choose.
pub fn lint_sources(files: &[(String, String)]) -> Vec<Finding> {
    let scrubbed: Vec<(&str, Vec<Line>)> =
        files.iter().map(|(p, s)| (p.as_str(), scrub(s))).collect();
    let kernels = collect_kernels(&scrubbed);
    let mut out = Vec::new();
    for (path, lines) in &scrubbed {
        check_file(path, lines, &kernels, &mut out);
    }
    out
}

// ---------------------------------------------------------------------------
// lexer: one source file -> per-line (code, comment) with literals blanked
// ---------------------------------------------------------------------------

struct Line {
    /// Source text with comments removed and string/char literal
    /// contents blanked (delimiters kept).
    code: String,
    /// Comment text on this line (line, doc, and block comments).
    comment: String,
}

#[derive(Clone, Copy)]
enum St {
    Code,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(usize),
    Char,
}

fn scrub(src: &str) -> Vec<Line> {
    let chars: Vec<char> = src.chars().collect();
    let mut lines = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut st = St::Code;
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if matches!(st, St::LineComment) {
                st = St::Code;
            }
            lines.push(Line {
                code: std::mem::take(&mut code),
                comment: std::mem::take(&mut comment),
            });
            i += 1;
            continue;
        }
        match st {
            St::Code => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    st = St::LineComment;
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    st = St::BlockComment(1);
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    st = St::Str;
                    i += 1;
                } else if c == 'b' && next == Some('"') && !prev_is_ident(&code) {
                    code.push('"');
                    st = St::Str;
                    i += 2;
                } else if (c == 'r' || (c == 'b' && next == Some('r'))) && !prev_is_ident(&code) {
                    let mut j = if c == 'b' { i + 2 } else { i + 1 };
                    let mut hashes = 0usize;
                    while chars.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if chars.get(j) == Some(&'"') {
                        code.push('"');
                        st = St::RawStr(hashes);
                        i = j + 1;
                    } else {
                        code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    if next == Some('\\') {
                        code.push('\'');
                        st = St::Char;
                        i += 1;
                    } else if chars.get(i + 2) == Some(&'\'') && next != Some('\'') {
                        code.push('\'');
                        code.push('\'');
                        i += 3; // 'x'
                    } else {
                        code.push('\''); // lifetime
                        i += 1;
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            St::LineComment => {
                comment.push(c);
                i += 1;
            }
            St::BlockComment(d) => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('*') {
                    st = St::BlockComment(d + 1);
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    st = if d == 1 { St::Code } else { St::BlockComment(d - 1) };
                    i += 2;
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    i += if chars.get(i + 1) == Some(&'\n') { 1 } else { 2 };
                } else if c == '"' {
                    code.push('"');
                    st = St::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            St::RawStr(h) => {
                if c == '"' && (0..h).all(|k| chars.get(i + 1 + k) == Some(&'#')) {
                    code.push('"');
                    st = St::Code;
                    i += 1 + h;
                } else {
                    i += 1;
                }
            }
            St::Char => {
                if c == '\\' {
                    i += 2;
                } else if c == '\'' {
                    code.push('\'');
                    st = St::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
        }
    }
    lines.push(Line { code, comment });
    lines
}

/// Whether the last pushed code character continues an identifier —
/// distinguishes the `r`/`b` of a raw/byte string prefix from the
/// trailing letter of a plain ident (`for`, `attr`, ...).
fn prev_is_ident(code: &str) -> bool {
    code.chars().next_back().is_some_and(|c| c.is_alphanumeric() || c == '_')
}

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Byte ranges of the identifiers in a scrubbed code line.
fn idents(code: &str) -> Vec<(usize, usize)> {
    let bytes = code.as_bytes();
    let mut out = Vec::new();
    let mut i = 0;
    while i < bytes.len() {
        let c = bytes[i] as char;
        if is_ident_char(c) && !c.is_ascii_digit() {
            let start = i;
            while i < bytes.len() && is_ident_char(bytes[i] as char) {
                i += 1;
            }
            out.push((start, i));
        } else {
            i += 1;
        }
    }
    out
}

fn has_ident(code: &str, name: &str) -> bool {
    idents(code).iter().any(|&(s, e)| &code[s..e] == name)
}

// ---------------------------------------------------------------------------
// scopes: #[cfg(test)] mod bodies and LINT-ALLOW ranges
// ---------------------------------------------------------------------------

/// Line index where the brace opened at/after `start` closes; stops at
/// a `;` seen before any `{` (braceless items like `mod tests;`).
fn brace_match(lines: &[Line], start: usize) -> usize {
    let mut depth = 0i64;
    let mut opened = false;
    for (k, line) in lines.iter().enumerate().skip(start) {
        for c in line.code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    opened = true;
                }
                '}' => depth -= 1,
                ';' if !opened => return k,
                _ => {}
            }
            if opened && depth == 0 {
                return k;
            }
        }
    }
    lines.len().saturating_sub(1)
}

/// Index of the next line holding real code (skipping blanks and
/// attribute-only lines), or `None`.
fn next_code_line(lines: &[Line], from: usize) -> Option<usize> {
    (from..lines.len()).find(|&j| {
        let t = lines[j].code.trim();
        !t.is_empty() && !t.starts_with("#[")
    })
}

/// Mark the body of every `#[cfg(test)] mod` (rule 4's exemption; the
/// other rules skip them too — tests are not hot paths and in-file
/// test mods routinely unwrap).
fn test_mod_lines(lines: &[Line]) -> Vec<bool> {
    let mut skip = vec![false; lines.len()];
    let mut i = 0;
    while i < lines.len() {
        if lines[i].code.contains("#[cfg(test)]") {
            if let Some(j) = next_code_line(lines, i + 1) {
                let t = lines[j].code.trim();
                if t.starts_with("mod ") || t.starts_with("pub mod ") {
                    let end = brace_match(lines, j);
                    for s in skip.iter_mut().take(end + 1).skip(i) {
                        *s = true;
                    }
                    i = end + 1;
                    continue;
                }
            }
        }
        i += 1;
    }
    skip
}

/// Mark the lines each `LINT-ALLOW(reason)` comment covers. The reason
/// is mandatory — an empty `LINT-ALLOW()` suppresses nothing.
fn allow_lines(lines: &[Line]) -> Vec<bool> {
    let mut allow = vec![false; lines.len()];
    for i in 0..lines.len() {
        let Some(pos) = lines[i].comment.find("LINT-ALLOW(") else {
            continue;
        };
        let rest = &lines[i].comment[pos + "LINT-ALLOW(".len()..];
        let Some(close) = rest.find(')') else {
            continue;
        };
        if rest[..close].trim().is_empty() {
            continue;
        }
        if !lines[i].code.trim().is_empty() {
            allow[i] = true; // trailing comment: its own line only
            continue;
        }
        let Some(j) = next_code_line(lines, i + 1) else {
            continue;
        };
        let end = if has_ident(&lines[j].code, "fn") {
            brace_match(lines, j)
        } else {
            j
        };
        for a in allow.iter_mut().take(end + 1).skip(i) {
            *a = true;
        }
    }
    allow
}

// ---------------------------------------------------------------------------
// the rules
// ---------------------------------------------------------------------------

/// Names of functions declared under a `#[target_feature]` attribute
/// anywhere in the linted set.
fn collect_kernels(files: &[(&str, Vec<Line>)]) -> Vec<String> {
    let mut names = Vec::new();
    for (_, lines) in files {
        let mut pending = false;
        for line in lines {
            let t = line.code.trim();
            if t.is_empty() {
                continue;
            }
            if t.contains("#[target_feature") {
                pending = true;
                continue;
            }
            if pending {
                if t.starts_with("#[") {
                    continue; // more attributes between
                }
                if let Some(name) = declared_fn_name(&line.code) {
                    names.push(name.to_string());
                }
                pending = false;
            }
        }
    }
    names.sort();
    names.dedup();
    names
}

/// The identifier right after a `fn` keyword, if this line declares one.
fn declared_fn_name(code: &str) -> Option<&str> {
    let ids = idents(code);
    let at = ids.iter().position(|&(s, e)| &code[s..e] == "fn")?;
    let &(s, e) = ids.get(at + 1)?;
    Some(&code[s..e])
}

/// Whether `code` calls `name` directly (ident followed by `(`, not a
/// declaration).
fn calls(code: &str, name: &str) -> bool {
    let ids = idents(code);
    for (k, &(s, e)) in ids.iter().enumerate() {
        if &code[s..e] != name {
            continue;
        }
        if k > 0 {
            let (ps, pe) = ids[k - 1];
            if &code[ps..pe] == "fn" {
                continue; // the declaration itself
            }
        }
        if code[e..].trim_start().starts_with('(') {
            return true;
        }
    }
    false
}

/// Whether the `unsafe` on line `i` has a `SAFETY` note on its own
/// line or in the contiguous comment/attribute block directly above.
fn safety_documented(lines: &[Line], i: usize) -> bool {
    if lines[i].comment.contains("SAFETY") {
        return true;
    }
    for j in (0..i).rev() {
        let t = lines[j].code.trim();
        let is_attr = t.starts_with("#[");
        let is_comment_only = t.is_empty() && !lines[j].comment.is_empty();
        if !is_attr && !is_comment_only {
            return false; // blank line or real code breaks the block
        }
        if lines[j].comment.contains("SAFETY") {
            return true;
        }
    }
    false
}

/// Whether `code` contains a slice/array indexing expression: a `[`
/// whose previous non-space character ends a value (ident, `)`, `]`).
/// Attribute `#[...]`, array types `[T; N]`, `vec![...]`, and slice
/// types after a keyword (`&mut [T]`, `dyn [..]`-style positions) all
/// have a non-value token before the bracket and do not match.
fn has_indexing(code: &str) -> bool {
    const KEYWORDS: &[&[u8]] = &[b"mut", b"dyn", b"in", b"as", b"return", b"else", b"const"];
    let bytes = code.as_bytes();
    for (p, &b) in bytes.iter().enumerate() {
        if b != b'[' {
            continue;
        }
        let mut q = p;
        while q > 0 && bytes[q - 1] == b' ' {
            q -= 1;
        }
        if q == 0 {
            continue;
        }
        let prev = bytes[q - 1] as char;
        if prev == ')' || prev == ']' {
            return true;
        }
        if is_ident_char(prev) {
            let mut s = q;
            while s > 0 && is_ident_char(bytes[s - 1] as char) {
                s -= 1;
            }
            if !KEYWORDS.contains(&&bytes[s..q]) {
                return true;
            }
        }
    }
    false
}

fn check_file(path: &str, lines: &[Line], kernels: &[String], out: &mut Vec<Finding>) {
    let skip = test_mod_lines(lines);
    let allow = allow_lines(lines);
    let hot = HOT_PATH_SUFFIXES.iter().any(|s| path.ends_with(s));
    let kernel_home = path.ends_with(KERNEL_HOME_SUFFIX);
    let lock_home = path.ends_with(LOCK_HOME_SUFFIX);
    let mut push = |line: usize, rule: &'static str, msg: String| {
        out.push(Finding { file: path.to_string(), line: line + 1, rule, msg });
    };
    for (i, line) in lines.iter().enumerate() {
        if skip[i] || allow[i] {
            continue;
        }
        let code = &line.code;
        if has_ident(code, "unsafe") && !safety_documented(lines, i) {
            push(
                i,
                RULE_SAFETY,
                "`unsafe` without a `// SAFETY:` comment directly above".to_string(),
            );
        }
        if !kernel_home {
            for k in kernels {
                if calls(code, k) {
                    push(
                        i,
                        RULE_KERNEL,
                        format!(
                            "direct call to `#[target_feature]` fn `{k}` outside \
                             util/simd.rs dispatch"
                        ),
                    );
                }
            }
        }
        if !lock_home && (has_ident(code, "Mutex") || has_ident(code, "RwLock")) {
            push(
                i,
                RULE_RAW_LOCK,
                "raw std::sync lock outside util/lock.rs; use OrderedMutex/OrderedRwLock"
                    .to_string(),
            );
        }
        if hot {
            if code.contains(".unwrap()") {
                push(i, RULE_HOT_PANIC, "`.unwrap()` in a hot-path module".to_string());
            }
            if code.contains(".expect(") {
                push(i, RULE_HOT_PANIC, "`.expect(...)` in a hot-path module".to_string());
            }
            if has_indexing(code) {
                push(i, RULE_HOT_PANIC, "slice indexing in a hot-path module".to_string());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint_one(path: &str, src: &str) -> Vec<Finding> {
        lint_sources(&[(path.to_string(), src.to_string())])
    }

    #[test]
    fn scrubber_strips_comments_and_literals() {
        let src = "let a = \"unsafe [0] // not code\"; // Mutex in comment\nlet b = 'x';\n";
        let lines = scrub(src);
        assert!(!lines[0].code.contains("unsafe"));
        assert!(!lines[0].code.contains("Mutex"));
        assert!(lines[0].comment.contains("Mutex"));
        assert!(!has_indexing(&lines[0].code));
        assert_eq!(lines[1].code.trim(), "let b = '';");
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let lines = scrub("fn f<'a>(x: &'a str) -> &'a str { x }\n");
        assert!(lines[0].code.contains("fn f<'a>"));
    }

    #[test]
    fn unsafe_without_safety_is_flagged_and_with_is_not() {
        let bad = lint_one("a.rs", "fn f() {\n    unsafe { g() }\n}\n");
        assert_eq!(bad.len(), 1);
        assert_eq!(bad[0].rule, RULE_SAFETY);
        assert_eq!(bad[0].line, 2);
        let good = lint_one(
            "a.rs",
            "fn f() {\n    // SAFETY: g has no preconditions\n    unsafe { g() }\n}\n",
        );
        assert!(good.is_empty(), "{good:?}");
    }

    #[test]
    fn safety_scan_crosses_attributes_but_not_code() {
        let good = "/// SAFETY: caller passes valid pointers\n#[inline]\nunsafe fn f() {}\n";
        assert!(lint_one("a.rs", good).is_empty());
        let bad = "// SAFETY: stale, detached by real code\nlet x = 1;\nunsafe fn f() {}\n";
        let f = lint_one("a.rs", bad);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, RULE_SAFETY);
    }

    #[test]
    fn kernel_calls_flagged_outside_simd_only() {
        let src = "/// SAFETY: n valid elements\n#[target_feature(enable = \"avx2\")]\n\
                   unsafe fn k(p: *mut f32) {}\nfn call() { k(p) }\n";
        let f = lint_one("src/other.rs", src);
        assert_eq!(f.iter().filter(|f| f.rule == RULE_KERNEL).count(), 1);
        assert!(lint_one("src/util/simd.rs", src).is_empty());
    }

    #[test]
    fn raw_locks_flagged_outside_lock_home_only() {
        let src = "use std::sync::Mutex;\nfn f() { let m = Mutex::new(0); }\n";
        let f = lint_one("src/x.rs", src);
        assert_eq!(f.iter().filter(|f| f.rule == RULE_RAW_LOCK).count(), 2);
        assert!(lint_one("src/util/lock.rs", src).is_empty());
        // OrderedMutex is a different identifier, not a match
        assert!(lint_one("src/x.rs", "fn f(m: &OrderedMutex<u32>) {}\n").is_empty());
    }

    #[test]
    fn hot_path_rules_apply_by_suffix() {
        let src = "fn f(v: &[u32]) -> u32 { v.first().unwrap() + v[0] }\n";
        assert!(lint_one("src/x.rs", src).is_empty());
        let f = lint_one("src/ingress/qos.rs", src);
        assert_eq!(f.iter().filter(|f| f.rule == RULE_HOT_PANIC).count(), 2);
    }

    #[test]
    fn cfg_test_mods_are_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f(v: &[u32]) -> u32 { v[0] }\n}\n";
        assert!(lint_one("src/ingress/qos.rs", src).is_empty());
    }

    #[test]
    fn lint_allow_scopes_one_fn_with_reason() {
        let allowed = "// LINT-ALLOW(index proven in bounds by construction)\n\
                       fn f(v: &[u32]) -> u32 {\n    v[0]\n}\nfn g(v: &[u32]) -> u32 { v[1] }\n";
        let f = lint_one("src/ingress/qos.rs", allowed);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].line, 5, "only the un-allowed fn is flagged");
        // the reason is mandatory
        let bare = "// LINT-ALLOW()\nfn f(v: &[u32]) -> u32 { v[0] }\n";
        assert_eq!(lint_one("src/ingress/qos.rs", bare).len(), 1);
    }

    #[test]
    fn indexing_heuristic_spares_types_attrs_and_macros() {
        for ok in [
            "fn f(x: [f32; 4]) {}",
            "#[derive(Debug)]",
            "let v = vec![1, 2];",
            "let s: &[u8] = b\"x\";",
            "fn g(x: &mut [u32]) -> &mut [u32] { x }",
        ] {
            assert!(!has_indexing(&scrub(ok)[0].code), "{ok}");
        }
        for bad in ["v[0]", "f()[1]", "a[0][1]"] {
            assert!(has_indexing(&scrub(bad)[0].code), "{bad}");
        }
    }
}
