//! Lockdep-style lock-order tracking (ADR-008).
//!
//! Every blocking lock in the serving stack is an [`OrderedMutex`] or
//! [`OrderedRwLock`] carrying a static [`LockRank`] from the single
//! declared hierarchy below. Debug builds (and release builds with the
//! `lockcheck` feature) record the per-thread set of held ranks on
//! every acquisition and panic — with the source locations and
//! backtraces of BOTH acquisitions — the moment any thread acquires a
//! lock whose rank is not strictly greater than everything it already
//! holds. Rank inversion across two threads is how every real deadlock
//! in this codebase would start, so the entire existing test suite
//! doubles as a deadlock detector: a violating interleaving does not
//! need to actually deadlock in CI to be caught, one thread merely has
//! to *attempt* the inverted order once.
//!
//! Release builds without `lockcheck` compile the wrappers to
//! `#[repr(transparent)]` passthroughs over `std::sync` with
//! `#[inline]` methods — zero cost on the hot paths.
//!
//! Two deliberate policy points:
//!
//! - **Same-rank double-acquire panics (for blocking acquisitions).**
//!   Two locks of equal rank blocking-held by one thread is either a
//!   self-deadlock (same lock) or an unordered pair (two instances),
//!   both bugs. Code that needs nested locking declares distinct ranks
//!   — see the `*Shard` ranks, one per `Sharded` instance type,
//!   because a stats-shard guard is held across tracer/recorder shard
//!   folds on the admit path. `try_lock` follows lockdep's trylock
//!   rule instead: exempt from the check (it cannot block, so it
//!   cannot close a cycle) but recorded as held — which is how one
//!   dispatch thread may hold several `ArenaSlot` ring reservations.
//! - **Poison is absorbed, not propagated.** Every historical call
//!   site immediately `unwrap()`ed poison into a panic anyway; the
//!   wrappers recover the inner guard so hot-path modules need no
//!   per-site `unwrap()` (which `pallas-lint` bans there). A panic
//!   while holding a lock still unwinds loudly through its own test.
//!
//! Condvar waits go through [`LockGuard::wait`] /
//! [`LockGuard::wait_timeout`]. The rank stays registered for the
//! whole wait: a parked thread acquires nothing, and on wake it holds
//! the lock again — exactly the invariant the held-set models.

use std::time::Duration;

/// The declared lock hierarchy, lowest first: a thread may only
/// acquire a lock of strictly GREATER rank than everything it holds.
///
/// The edges that force this order (each is a real held-while-acquired
/// nesting on a hot path; the full table with rationale is
/// `docs/ADR-008-correctness-tooling.md`):
///
/// - `ArenaSlot < ArenaRelease`: `RingSlot::drop` notifies waiters
///   under `release_lock` while the slot mutex is still held.
/// - `ArenaSlot < PoolQueue/PoolLatch/PoolHandles`: a NETFUSE round
///   holds its ring slot across pack → stage → execute, and execution
///   fans out through `WorkerPool::scope`.
/// - `ObsMeta < MetricsShard`: `ObsHub::report` reads the merged
///   metrics hub while holding the hub's `metrics` registration slot.
/// - `StatsShard < ObsShard`: `admit`/`route_responses` hold the
///   ingress-stats shard while folding tracer stamps and recording
///   flight-recorder events.
/// - `StatsShard < ReplyQueue`: reject/response frames are pushed to
///   per-connection reply queues under the stats-shard guard.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum LockRank {
    /// `IngressBridge` admission queue (+ its condvar).
    Bridge,
    /// `IngressBridge`'s observability-hub registration slot.
    BridgeObs,
    /// `PartControl` per-partition command queue.
    ControlQueue,
    /// `control::Ticket`/`Ack` one-shot completion cell.
    Ticket,
    /// `multi::Topology` routing tables (RwLock).
    Topology,
    /// `ObsHub` gauges/queries/rings/metrics slots and the
    /// `FlightRecorder` last-dump cell.
    ObsMeta,
    /// One `ArenaRing` slot (`RoundArena` behind it).
    ArenaSlot,
    /// `ArenaRing`'s release wakeup lock (+ condvar).
    ArenaRelease,
    /// `WorkerPool` job queue (+ condvar).
    PoolQueue,
    /// `pool::Latch` completion counter (+ condvar).
    PoolLatch,
    /// `WorkerPool::run_chunked` per-chunk result slots.
    PoolResult,
    /// `WorkerPool` join-handle registry.
    PoolHandles,
    /// `runtime::Runtime` compiled-module cache.
    RuntimeCache,
    /// Mock executor weight-version table (`EchoExecutor`).
    ModelState,
    /// `Sharded<IngressStats>` shards.
    StatsShard,
    /// `Sharded<ObsCore>` / `Sharded<EventRing>` shards (tracer and
    /// flight recorder — folded under a held stats-shard guard).
    ObsShard,
    /// `Sharded<MetricsCore>` shards (read under `ObsMeta`).
    MetricsShard,
    /// `transport::FrameQueue` (reply routing and in-proc transport).
    ReplyQueue,
}

#[cfg(any(debug_assertions, feature = "lockcheck"))]
mod checked {
    use std::backtrace::Backtrace;
    use std::cell::RefCell;
    use std::ops::{Deref, DerefMut};
    use std::panic::Location;
    use std::sync::{Condvar, Mutex, MutexGuard, PoisonError, RwLock};
    use std::time::Duration;

    use super::LockRank;

    struct HeldLock {
        rank: LockRank,
        token: u64,
        location: &'static Location<'static>,
        backtrace: Backtrace,
    }

    thread_local! {
        static HELD: RefCell<(u64, Vec<HeldLock>)> = const { RefCell::new((0, Vec::new())) };
    }

    /// Register an acquisition of `rank`, panicking on any ordering
    /// violation against this thread's currently held set. Returns the
    /// token `release` later removes (0 = thread-local gone, skip).
    ///
    /// `blocking` is false for try-acquisitions: lockdep's trylock
    /// rule. A non-blocking acquire can never complete a deadlock
    /// cycle (it fails instead of waiting), so it is exempt from the
    /// ordering check — but it IS recorded, so every later *blocking*
    /// acquisition is checked against it. This is what lets one thread
    /// legitimately hold several `ArenaSlot` ring reservations (slot
    /// acquisition is try-lock-only by construction).
    fn acquire(rank: LockRank, location: &'static Location<'static>, blocking: bool) -> u64 {
        let mut violation: Option<String> = None;
        let token = HELD
            .try_with(|cell| {
                let mut held = cell.borrow_mut();
                if let Some(prior) =
                    held.1.iter().rev().find(|h| blocking && h.rank >= rank)
                {
                    let kind = if prior.rank == rank {
                        format!("same-rank double-acquire of {rank:?}")
                    } else {
                        format!("acquiring {rank:?} above held {:?}", prior.rank)
                    };
                    let ranks: Vec<LockRank> = held.1.iter().map(|h| h.rank).collect();
                    violation = Some(format!(
                        "lock-order violation: {kind}\n  this acquisition: {location}\n  \
                         conflicting hold: {:?} acquired at {}\n  held ranks: {ranks:?}\n  \
                         backtrace of the conflicting hold:\n{}\n  \
                         backtrace of this acquisition:\n{}\n  \
                         (run with RUST_BACKTRACE=1 for resolved backtraces)",
                        prior.rank,
                        prior.location,
                        prior.backtrace,
                        Backtrace::capture(),
                    ));
                    return 0;
                }
                held.0 += 1;
                let token = held.0;
                held.1.push(HeldLock {
                    rank,
                    token,
                    location,
                    backtrace: Backtrace::capture(),
                });
                token
            })
            .unwrap_or(0);
        if let Some(msg) = violation {
            panic!("{msg}");
        }
        token
    }

    fn release(token: u64) {
        if token == 0 {
            return;
        }
        let _ = HELD.try_with(|cell| {
            let mut held = cell.borrow_mut();
            if let Some(i) = held.1.iter().rposition(|h| h.token == token) {
                held.1.remove(i);
            }
        });
    }

    /// Rank-checked mutex (debug/`lockcheck` form; see module doc).
    pub struct OrderedMutex<T: ?Sized> {
        rank: LockRank,
        inner: Mutex<T>,
    }

    /// Guard of an [`OrderedMutex`]; releases the rank on drop.
    pub struct LockGuard<'a, T: ?Sized> {
        // `Option` so condvar waits can move the std guard out and
        // back without touching the rank registration.
        inner: Option<MutexGuard<'a, T>>,
        token: u64,
    }

    impl<T> OrderedMutex<T> {
        pub fn new(rank: LockRank, value: T) -> OrderedMutex<T> {
            OrderedMutex { rank, inner: Mutex::new(value) }
        }

        pub fn into_inner(self) -> T {
            self.inner.into_inner().unwrap_or_else(PoisonError::into_inner)
        }
    }

    impl<T: ?Sized> OrderedMutex<T> {
        #[track_caller]
        pub fn lock(&self) -> LockGuard<'_, T> {
            let token = acquire(self.rank, Location::caller(), true);
            let inner = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
            LockGuard { inner: Some(inner), token }
        }

        /// Non-blocking acquire. `None` when contended. Per lockdep's
        /// trylock rule this is exempt from the ordering check (it
        /// cannot block, so it cannot close a deadlock cycle) but the
        /// hold is recorded: later blocking acquisitions are checked
        /// against it like any other held rank.
        #[track_caller]
        pub fn try_lock(&self) -> Option<LockGuard<'_, T>> {
            match self.inner.try_lock() {
                Ok(inner) => {
                    let token = acquire(self.rank, Location::caller(), false);
                    Some(LockGuard { inner: Some(inner), token })
                }
                Err(std::sync::TryLockError::Poisoned(p)) => {
                    let token = acquire(self.rank, Location::caller(), false);
                    Some(LockGuard { inner: Some(p.into_inner()), token })
                }
                Err(std::sync::TryLockError::WouldBlock) => None,
            }
        }

        pub fn get_mut(&mut self) -> &mut T {
            self.inner.get_mut().unwrap_or_else(PoisonError::into_inner)
        }
    }

    impl<'a, T: ?Sized> LockGuard<'a, T> {
        fn std(&self) -> &MutexGuard<'a, T> {
            self.inner.as_ref().expect("guard emptied mid-wait")
        }

        fn std_mut(&mut self) -> &mut MutexGuard<'a, T> {
            self.inner.as_mut().expect("guard emptied mid-wait")
        }

        /// Block on `cv`, releasing the mutex while parked and
        /// re-holding it on wake. The rank stays registered across the
        /// wait: a parked thread acquires nothing, so the held-set
        /// stays truthful for everything this thread does next.
        pub fn wait(mut self, cv: &Condvar) -> LockGuard<'a, T> {
            let std = self.inner.take().expect("guard emptied mid-wait");
            let std = cv.wait(std).unwrap_or_else(PoisonError::into_inner);
            self.inner = Some(std);
            self
        }

        /// [`LockGuard::wait`] with a timeout; the bool is "timed out".
        pub fn wait_timeout(mut self, cv: &Condvar, dur: Duration) -> (LockGuard<'a, T>, bool) {
            let std = self.inner.take().expect("guard emptied mid-wait");
            let (std, res) = cv
                .wait_timeout(std, dur)
                .unwrap_or_else(PoisonError::into_inner);
            self.inner = Some(std);
            (self, res.timed_out())
        }
    }

    impl<T: ?Sized> Deref for LockGuard<'_, T> {
        type Target = T;
        fn deref(&self) -> &T {
            self.std()
        }
    }

    impl<T: ?Sized> DerefMut for LockGuard<'_, T> {
        fn deref_mut(&mut self) -> &mut T {
            self.std_mut()
        }
    }

    impl<T: ?Sized> Drop for LockGuard<'_, T> {
        fn drop(&mut self) {
            release(self.token);
        }
    }

    /// Rank-checked RwLock (debug/`lockcheck` form). Reads are tracked
    /// with the same strictness as writes: a read held while another
    /// same-or-lower rank is acquired is still an ordering bug (a
    /// writer queued between two readers deadlocks them).
    pub struct OrderedRwLock<T: ?Sized> {
        rank: LockRank,
        inner: RwLock<T>,
    }

    pub struct ReadGuard<'a, T: ?Sized> {
        inner: std::sync::RwLockReadGuard<'a, T>,
        token: u64,
    }

    pub struct WriteGuard<'a, T: ?Sized> {
        inner: std::sync::RwLockWriteGuard<'a, T>,
        token: u64,
    }

    impl<T> OrderedRwLock<T> {
        pub fn new(rank: LockRank, value: T) -> OrderedRwLock<T> {
            OrderedRwLock { rank, inner: RwLock::new(value) }
        }
    }

    impl<T: ?Sized> OrderedRwLock<T> {
        #[track_caller]
        pub fn read(&self) -> ReadGuard<'_, T> {
            let token = acquire(self.rank, Location::caller(), true);
            let inner = self.inner.read().unwrap_or_else(PoisonError::into_inner);
            ReadGuard { inner, token }
        }

        #[track_caller]
        pub fn write(&self) -> WriteGuard<'_, T> {
            let token = acquire(self.rank, Location::caller(), true);
            let inner = self.inner.write().unwrap_or_else(PoisonError::into_inner);
            WriteGuard { inner, token }
        }
    }

    impl<T: ?Sized> Deref for ReadGuard<'_, T> {
        type Target = T;
        fn deref(&self) -> &T {
            &self.inner
        }
    }

    impl<T: ?Sized> Drop for ReadGuard<'_, T> {
        fn drop(&mut self) {
            release(self.token);
        }
    }

    impl<T: ?Sized> Deref for WriteGuard<'_, T> {
        type Target = T;
        fn deref(&self) -> &T {
            &self.inner
        }
    }

    impl<T: ?Sized> DerefMut for WriteGuard<'_, T> {
        fn deref_mut(&mut self) -> &mut T {
            &mut self.inner
        }
    }

    impl<T: ?Sized> Drop for WriteGuard<'_, T> {
        fn drop(&mut self) {
            release(self.token);
        }
    }
}

#[cfg(not(any(debug_assertions, feature = "lockcheck")))]
mod passthrough {
    use std::ops::{Deref, DerefMut};
    use std::sync::{Condvar, Mutex, MutexGuard, PoisonError, RwLock};
    use std::time::Duration;

    use super::LockRank;

    /// Release passthrough: the rank is compile-time documentation
    /// only, the layout and codegen are `std::sync::Mutex`'s.
    #[repr(transparent)]
    pub struct OrderedMutex<T: ?Sized> {
        inner: Mutex<T>,
    }

    pub struct LockGuard<'a, T: ?Sized> {
        inner: MutexGuard<'a, T>,
    }

    impl<T> OrderedMutex<T> {
        #[inline]
        pub fn new(rank: LockRank, value: T) -> OrderedMutex<T> {
            let _ = rank;
            OrderedMutex { inner: Mutex::new(value) }
        }

        #[inline]
        pub fn into_inner(self) -> T {
            self.inner.into_inner().unwrap_or_else(PoisonError::into_inner)
        }
    }

    impl<T: ?Sized> OrderedMutex<T> {
        #[inline]
        pub fn lock(&self) -> LockGuard<'_, T> {
            LockGuard { inner: self.inner.lock().unwrap_or_else(PoisonError::into_inner) }
        }

        #[inline]
        pub fn try_lock(&self) -> Option<LockGuard<'_, T>> {
            match self.inner.try_lock() {
                Ok(inner) => Some(LockGuard { inner }),
                Err(std::sync::TryLockError::Poisoned(p)) => {
                    Some(LockGuard { inner: p.into_inner() })
                }
                Err(std::sync::TryLockError::WouldBlock) => None,
            }
        }

        #[inline]
        pub fn get_mut(&mut self) -> &mut T {
            self.inner.get_mut().unwrap_or_else(PoisonError::into_inner)
        }
    }

    impl<'a, T: ?Sized> LockGuard<'a, T> {
        #[inline]
        pub fn wait(self, cv: &Condvar) -> LockGuard<'a, T> {
            LockGuard { inner: cv.wait(self.inner).unwrap_or_else(PoisonError::into_inner) }
        }

        #[inline]
        pub fn wait_timeout(self, cv: &Condvar, dur: Duration) -> (LockGuard<'a, T>, bool) {
            let (inner, res) = cv
                .wait_timeout(self.inner, dur)
                .unwrap_or_else(PoisonError::into_inner);
            (LockGuard { inner }, res.timed_out())
        }
    }

    impl<T: ?Sized> Deref for LockGuard<'_, T> {
        type Target = T;
        #[inline]
        fn deref(&self) -> &T {
            &self.inner
        }
    }

    impl<T: ?Sized> DerefMut for LockGuard<'_, T> {
        #[inline]
        fn deref_mut(&mut self) -> &mut T {
            &mut self.inner
        }
    }

    /// Release passthrough over `std::sync::RwLock`.
    #[repr(transparent)]
    pub struct OrderedRwLock<T: ?Sized> {
        inner: RwLock<T>,
    }

    pub struct ReadGuard<'a, T: ?Sized> {
        inner: std::sync::RwLockReadGuard<'a, T>,
    }

    pub struct WriteGuard<'a, T: ?Sized> {
        inner: std::sync::RwLockWriteGuard<'a, T>,
    }

    impl<T> OrderedRwLock<T> {
        #[inline]
        pub fn new(rank: LockRank, value: T) -> OrderedRwLock<T> {
            let _ = rank;
            OrderedRwLock { inner: RwLock::new(value) }
        }
    }

    impl<T: ?Sized> OrderedRwLock<T> {
        #[inline]
        pub fn read(&self) -> ReadGuard<'_, T> {
            ReadGuard { inner: self.inner.read().unwrap_or_else(PoisonError::into_inner) }
        }

        #[inline]
        pub fn write(&self) -> WriteGuard<'_, T> {
            WriteGuard { inner: self.inner.write().unwrap_or_else(PoisonError::into_inner) }
        }
    }

    impl<T: ?Sized> Deref for ReadGuard<'_, T> {
        type Target = T;
        #[inline]
        fn deref(&self) -> &T {
            &self.inner
        }
    }

    impl<T: ?Sized> Deref for WriteGuard<'_, T> {
        type Target = T;
        #[inline]
        fn deref(&self) -> &T {
            &self.inner
        }
    }

    impl<T: ?Sized> DerefMut for WriteGuard<'_, T> {
        #[inline]
        fn deref_mut(&mut self) -> &mut T {
            &mut self.inner
        }
    }
}

#[cfg(any(debug_assertions, feature = "lockcheck"))]
pub use checked::{LockGuard, OrderedMutex, OrderedRwLock, ReadGuard, WriteGuard};
#[cfg(not(any(debug_assertions, feature = "lockcheck")))]
pub use passthrough::{LockGuard, OrderedMutex, OrderedRwLock, ReadGuard, WriteGuard};

/// Compile-time check that the passthrough really is transparent: the
/// release wrapper must add nothing to `std::sync::Mutex`'s layout.
#[cfg(not(any(debug_assertions, feature = "lockcheck")))]
const _: () = {
    assert!(
        std::mem::size_of::<OrderedMutex<u64>>()
            == std::mem::size_of::<std::sync::Mutex<u64>>()
    );
    assert!(
        std::mem::size_of::<OrderedRwLock<u64>>()
            == std::mem::size_of::<std::sync::RwLock<u64>>()
    );
};

#[cfg(test)]
mod tests {
    use std::sync::Condvar;
    use std::time::Duration;

    use super::{LockRank, OrderedMutex, OrderedRwLock};

    #[test]
    fn in_order_acquisition_is_clean() {
        let low = OrderedMutex::new(LockRank::Bridge, 1u32);
        let high = OrderedMutex::new(LockRank::StatsShard, 2u32);
        let a = low.lock();
        let b = high.lock();
        assert_eq!(*a + *b, 3);
        drop(b);
        drop(a);
        // and again, proving release really clears the held set
        let b = high.lock();
        drop(b);
        let a = low.lock();
        drop(a);
    }

    #[test]
    fn lower_rank_is_fine_once_the_higher_guard_dropped() {
        let low = OrderedMutex::new(LockRank::Bridge, ());
        let high = OrderedMutex::new(LockRank::ReplyQueue, ());
        drop(high.lock());
        drop(low.lock()); // no longer held: not an inversion
    }

    #[test]
    fn guards_may_release_out_of_order() {
        let a = OrderedMutex::new(LockRank::Bridge, ());
        let b = OrderedMutex::new(LockRank::Topology, ());
        let c = OrderedMutex::new(LockRank::ReplyQueue, ());
        let ga = a.lock();
        let gb = b.lock();
        let gc = c.lock();
        drop(gb); // middle first: the held-set removal is by token
        drop(ga);
        drop(gc);
        drop(a.lock());
    }

    #[test]
    fn try_lock_contended_returns_none() {
        let m = OrderedMutex::new(LockRank::ArenaSlot, 7u32);
        let held = m.lock();
        std::thread::scope(|s| {
            s.spawn(|| {
                assert!(m.try_lock().is_none(), "contended try_lock must not block");
            });
        });
        drop(held);
        let g = m.try_lock().expect("uncontended try_lock succeeds");
        assert_eq!(*g, 7);
    }

    #[test]
    fn try_lock_may_stack_same_rank_holds() {
        // lockdep trylock rule: a non-blocking acquire cannot close a
        // deadlock cycle, so stacking ring-slot reservations is legal
        let a = OrderedMutex::new(LockRank::ArenaSlot, ());
        let b = OrderedMutex::new(LockRank::ArenaSlot, ());
        let ga = a.try_lock().expect("uncontended");
        let gb = b.try_lock().expect("uncontended");
        drop(ga);
        drop(gb);
    }

    #[test]
    fn threads_have_independent_held_sets() {
        let high = OrderedMutex::new(LockRank::ReplyQueue, ());
        let low = OrderedMutex::new(LockRank::Bridge, ());
        let g = high.lock();
        std::thread::scope(|s| {
            s.spawn(|| {
                // this thread holds nothing: low rank is fine here
                drop(low.lock());
            });
        });
        drop(g);
    }

    #[test]
    fn condvar_wait_timeout_returns_a_live_guard() {
        let m = OrderedMutex::new(LockRank::PoolQueue, 5u32);
        let cv = Condvar::new();
        let g = m.lock();
        let (mut g, timed_out) = g.wait_timeout(&cv, Duration::from_millis(1));
        assert!(timed_out);
        *g += 1;
        assert_eq!(*g, 6);
        drop(g);
        // the rank released cleanly after the round-trip through wait
        drop(m.lock());
    }

    #[test]
    fn rwlock_read_and_write_are_tracked_in_order() {
        let topo = OrderedRwLock::new(LockRank::Topology, 1u32);
        let shard = OrderedMutex::new(LockRank::StatsShard, ());
        {
            let r = topo.read();
            let _s = shard.lock(); // Topology < StatsShard: fine
            assert_eq!(*r, 1);
        }
        {
            let mut w = topo.write();
            *w = 2;
        }
        assert_eq!(*topo.read(), 2);
    }

    #[test]
    fn into_inner_and_get_mut_bypass_locking() {
        let mut m = OrderedMutex::new(LockRank::PoolResult, 3u32);
        *m.get_mut() += 1;
        assert_eq!(m.into_inner(), 4);
    }

    // The negative tests only exist where the checker is compiled in:
    // a release build without `lockcheck` is a pure passthrough and
    // must NOT panic (that is the point of the cfg split).
    #[cfg(any(debug_assertions, feature = "lockcheck"))]
    #[test]
    #[should_panic(expected = "lock-order violation")]
    fn detects_two_lock_rank_inversion() {
        let low = OrderedMutex::new(LockRank::Bridge, ());
        let high = OrderedMutex::new(LockRank::ArenaSlot, ());
        let _g = high.lock();
        let _bad = low.lock(); // Bridge under ArenaSlot: inverted
    }

    #[cfg(any(debug_assertions, feature = "lockcheck"))]
    #[test]
    #[should_panic(expected = "same-rank double-acquire")]
    fn detects_same_rank_double_acquire() {
        let a = OrderedMutex::new(LockRank::ArenaSlot, ());
        let b = OrderedMutex::new(LockRank::ArenaSlot, ());
        let _g = a.lock();
        let _bad = b.lock(); // two ArenaSlot holds on one thread
    }

    #[cfg(any(debug_assertions, feature = "lockcheck"))]
    #[test]
    #[should_panic(expected = "lock-order violation")]
    fn blocking_acquires_are_checked_against_try_holds() {
        let slot = OrderedMutex::new(LockRank::ArenaSlot, ());
        let bridge = OrderedMutex::new(LockRank::Bridge, ());
        let _g = slot.try_lock().expect("uncontended");
        let _bad = bridge.lock(); // Bridge under a try-held ArenaSlot
    }

    #[cfg(any(debug_assertions, feature = "lockcheck"))]
    #[test]
    #[should_panic(expected = "lock-order violation")]
    fn detects_inversion_through_rwlock_reads() {
        let topo = OrderedRwLock::new(LockRank::Topology, ());
        let ctrl = OrderedMutex::new(LockRank::ControlQueue, ());
        let _r = topo.read();
        let _bad = ctrl.lock(); // ControlQueue under Topology: inverted
    }
}
