//! Criterion-style measurement harness (criterion is not available
//! offline). Bench targets are `harness = false` binaries that call
//! [`Bench::run`]; results print as aligned tables and can be captured by
//! the figure generators.

use std::time::Instant;

use super::stats::{fmt_secs, Summary};

/// Allocation-counting global allocator for the zero-allocation
/// assertions in `benches/round_pipeline.rs`.
///
/// A bench binary installs it with
/// `#[global_allocator] static A: CountingAlloc = CountingAlloc;` and
/// brackets the measured region with [`counting_alloc::allocations`]
/// reads; the steady-state round pipeline must show a zero delta.
pub mod counting_alloc {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::sync::atomic::{AtomicU64, Ordering};

    static ALLOCS: AtomicU64 = AtomicU64::new(0);
    static BYTES: AtomicU64 = AtomicU64::new(0);

    /// Forwards to the system allocator, counting every allocation
    /// (including growth via `realloc`).
    pub struct CountingAlloc;

    // SAFETY: every method delegates verbatim to `System`, which
    // upholds the `GlobalAlloc` contract; the only additions are
    // relaxed atomic counter bumps, which allocate nothing and cannot
    // unwind.
    unsafe impl GlobalAlloc for CountingAlloc {
        // SAFETY: caller contract (valid `layout`) is forwarded
        // unchanged to `System.alloc`.
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
            System.alloc(layout)
        }

        // SAFETY: caller contract is forwarded unchanged to
        // `System.alloc_zeroed`.
        unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
            System.alloc_zeroed(layout)
        }

        // SAFETY: caller contract (ptr from this allocator, matching
        // `layout`) is forwarded unchanged to `System.realloc`.
        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
            System.realloc(ptr, layout, new_size)
        }

        // SAFETY: caller contract (ptr from this allocator, matching
        // `layout`) is forwarded unchanged to `System.dealloc`.
        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            System.dealloc(ptr, layout)
        }
    }

    /// Total allocation events since process start (monotonic; diff two
    /// reads to measure a region).
    pub fn allocations() -> u64 {
        ALLOCS.load(Ordering::Relaxed)
    }

    /// Total bytes requested since process start.
    pub fn bytes_allocated() -> u64 {
        BYTES.load(Ordering::Relaxed)
    }
}

/// Measurement configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Warm-up wall time budget (seconds).
    pub warmup_s: f64,
    /// Number of recorded samples.
    pub samples: usize,
    /// Per-sample minimum wall time; iterations scale to reach it.
    pub min_sample_s: f64,
}

impl Default for Config {
    fn default() -> Self {
        Config { warmup_s: 0.3, samples: 20, min_sample_s: 0.01 }
    }
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    /// Mean seconds per iteration.
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub iters_per_sample: u64,
    pub samples: usize,
}

impl Measurement {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} ± {:>9}  (min {:>10}, {} samples x {} iters)",
            self.name,
            fmt_secs(self.mean),
            fmt_secs(self.std),
            fmt_secs(self.min),
            self.samples,
            self.iters_per_sample,
        )
    }
}

/// Bench runner. Keeps all measurements for a final summary table.
#[derive(Default)]
pub struct Bench {
    pub config: Config,
    pub results: Vec<Measurement>,
    quiet: bool,
}

impl Bench {
    pub fn new() -> Self {
        Bench::default()
    }

    /// Quick preset used by `cargo test`-adjacent smoke benches.
    pub fn quick() -> Self {
        Bench {
            config: Config { warmup_s: 0.05, samples: 5, min_sample_s: 0.002 },
            ..Default::default()
        }
    }

    pub fn quiet(mut self) -> Self {
        self.quiet = true;
        self
    }

    /// Measure `f`, auto-scaling iterations so each sample runs at least
    /// `min_sample_s`. Returns mean seconds per iteration.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> Measurement {
        // warm-up + iteration calibration
        let start = Instant::now();
        let mut calib_iters: u64 = 0;
        while start.elapsed().as_secs_f64() < self.config.warmup_s {
            f();
            calib_iters += 1;
        }
        let per_iter = start.elapsed().as_secs_f64() / calib_iters.max(1) as f64;
        let iters = ((self.config.min_sample_s / per_iter).ceil() as u64).max(1);

        let mut s = Summary::new();
        for _ in 0..self.config.samples {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            s.add(t.elapsed().as_secs_f64() / iters as f64);
        }
        let m = Measurement {
            name: name.to_string(),
            mean: s.mean(),
            std: s.std(),
            min: s.min(),
            max: s.max(),
            iters_per_sample: iters,
            samples: self.config.samples,
        };
        if !self.quiet {
            println!("{}", m.report());
        }
        self.results.push(m.clone());
        m
    }

    /// Retrieve a previous measurement by name.
    pub fn get(&self, name: &str) -> Option<&Measurement> {
        self.results.iter().find(|m| m.name == name)
    }
}

/// One-shot timing of a closure (used for merge-overhead style
/// measurements where a single run is the quantity of interest).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed().as_secs_f64())
}

/// Shared `BENCH_<name>.json` emitter, so every bench binary writes the
/// same report shape instead of hand-rolling a `BTreeMap` each time.
///
/// Every report carries `bench` (the name) and `smoke` keys; arbitrary
/// gate numbers go in via [`set`]/[`num`], and per-op normalized costs
/// via [`ns_per_slot`], which collects under one `"ns_per_slot"` object
/// so the figure generators can diff op costs across PRs uniformly.
///
/// [`set`]: report::BenchReport::set
/// [`num`]: report::BenchReport::num
/// [`ns_per_slot`]: report::BenchReport::ns_per_slot
pub mod report {
    use std::collections::BTreeMap;

    use crate::util::json::Json;

    pub struct BenchReport {
        name: String,
        root: BTreeMap<String, Json>,
        ns_per_slot: BTreeMap<String, Json>,
    }

    impl BenchReport {
        pub fn new(name: &str, smoke: bool) -> Self {
            let mut root = BTreeMap::new();
            root.insert("bench".to_string(), Json::Str(name.to_string()));
            root.insert("smoke".to_string(), Json::Bool(smoke));
            BenchReport { name: name.to_string(), root, ns_per_slot: BTreeMap::new() }
        }

        /// Set a numeric top-level field.
        pub fn num(&mut self, key: &str, v: f64) -> &mut Self {
            self.set(key, Json::Num(v))
        }

        /// Set an arbitrary top-level field (nested objects included).
        pub fn set(&mut self, key: &str, v: Json) -> &mut Self {
            self.root.insert(key.to_string(), v);
            self
        }

        /// Record one op's normalized cost under the shared
        /// `"ns_per_slot"` object (nanoseconds per slot/element).
        pub fn ns_per_slot(&mut self, op: &str, ns: f64) -> &mut Self {
            self.ns_per_slot.insert(op.to_string(), Json::Num(ns));
            self
        }

        /// Write `BENCH_<name>.json` to the working directory and
        /// return its path. Call BEFORE asserting gates so a failing
        /// run still leaves its numbers behind.
        pub fn write(&mut self) -> std::io::Result<String> {
            if !self.ns_per_slot.is_empty() {
                self.root
                    .insert("ns_per_slot".to_string(), Json::Obj(self.ns_per_slot.clone()));
            }
            let path = format!("BENCH_{}.json", self.name);
            std::fs::write(&path, Json::Obj(self.root.clone()).dump())?;
            println!("report written to {path}");
            Ok(path)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::quick().quiet();
        let m = b.run("noop-ish", || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(m.mean > 0.0 && m.mean < 0.01);
        assert!(b.get("noop-ish").is_some());
    }

    #[test]
    fn time_once_returns_value() {
        let (v, secs) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
