//! Fixed-log-bucket latency histograms with **exact** merge-on-read
//! (ADR-006).
//!
//! A [`Hist`] maps a nanosecond value to one of [`N_BUCKETS`] buckets:
//! values below 16 get their own bucket (exact), and every octave above
//! that is split into 16 sub-buckets, so the relative quantization
//! error is bounded by 1/16 (~6.25%) everywhere. Bucketization is a
//! pure function of the value, applied BEFORE sharding — so summing two
//! histograms element-wise yields byte-for-byte the counts a single
//! histogram fed the union would hold, and every rank statistic
//! (nearest-rank percentiles included) computed from the merged counts
//! equals the single-histogram answer exactly. That extends the
//! `MetricsCore` merge-exactness proof (ADR-004) to stage timings
//! without shipping raw samples around.
//!
//! The running `sum_ns` is kept exactly (not reconstructed from bucket
//! midpoints), so means — and the "stages sum to end-to-end latency"
//! acceptance check — are not subject to bucket resolution at all.
//! Percentiles return the bucket's **lower bound**: the true value `v`
//! satisfies `floor <= v < floor + floor/16` (exact below 16).

use std::time::Duration;

use super::shard::Shardable;

/// Sub-buckets per octave (a power of two; 16 → ~6.25% resolution).
const SUB: u64 = 16;
/// log2(SUB)
const SUB_BITS: u32 = 4;

/// Total buckets: 16 exact low buckets + 16 per octave for exponents
/// 4..=63, with the top octave's sub-buckets covering up to `u64::MAX`.
pub const N_BUCKETS: usize = (64 - SUB_BITS as usize) * SUB as usize + SUB as usize;

/// The bucket index of `v` nanoseconds (monotone in `v`).
#[inline]
pub fn bucket_of(v: u64) -> usize {
    if v < SUB {
        v as usize
    } else {
        let exp = 63 - v.leading_zeros();
        let sub = ((v >> (exp - SUB_BITS)) & (SUB - 1)) as usize;
        (exp as usize - SUB_BITS as usize + 1) * SUB as usize + sub
    }
}

/// The smallest value mapping to bucket `i` (inverse of [`bucket_of`]).
#[inline]
pub fn bucket_floor(i: usize) -> u64 {
    if i < SUB as usize {
        i as u64
    } else {
        let exp = (i / SUB as usize) as u32 + SUB_BITS - 1;
        let sub = (i % SUB as usize) as u64;
        (1u64 << exp) | (sub << (exp - SUB_BITS))
    }
}

/// A fixed-log-bucket histogram of nanosecond durations. `Default` is
/// empty; element-wise [`Hist::merge_from`] makes it [`Shardable`].
#[derive(Clone, Debug)]
pub struct Hist {
    counts: Vec<u64>,
    count: u64,
    sum_ns: u64,
}

impl Default for Hist {
    fn default() -> Self {
        Hist { counts: vec![0; N_BUCKETS], count: 0, sum_ns: 0 }
    }
}

impl Hist {
    pub fn new() -> Hist {
        Hist::default()
    }

    /// Record one sample of `ns` nanoseconds.
    #[inline]
    pub fn record_ns(&mut self, ns: u64) {
        self.counts[bucket_of(ns)] += 1;
        self.count += 1;
        self.sum_ns = self.sum_ns.saturating_add(ns);
    }

    /// Record one sample (saturating at `u64::MAX` ns ≈ 584 years).
    #[inline]
    pub fn record(&mut self, d: Duration) {
        self.record_ns(u64::try_from(d.as_nanos()).unwrap_or(u64::MAX));
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact sum of all recorded samples, in nanoseconds.
    pub fn sum_ns(&self) -> u64 {
        self.sum_ns
    }

    /// Exact mean in nanoseconds (`None` when empty).
    pub fn mean_ns(&self) -> Option<f64> {
        (self.count > 0).then(|| self.sum_ns as f64 / self.count as f64)
    }

    /// Nearest-rank percentile (`q` in (0, 1]): the lower bound of the
    /// bucket holding the rank-`ceil(q * count)` sample. `None` when
    /// empty. Same rank convention as `util::stats::Latencies`, so the
    /// merged-equals-single exactness proof carries over unchanged.
    pub fn percentile_ns(&self, q: f64) -> Option<u64> {
        if self.count == 0 {
            return None;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Some(bucket_floor(i));
            }
        }
        unreachable!("cumulative count covers every rank")
    }

    pub fn p50_ns(&self) -> Option<u64> {
        self.percentile_ns(0.50)
    }

    pub fn p95_ns(&self) -> Option<u64> {
        self.percentile_ns(0.95)
    }

    pub fn p99_ns(&self) -> Option<u64> {
        self.percentile_ns(0.99)
    }

    /// Element-wise merge: after merging, every count (and therefore
    /// every rank statistic) equals what a single histogram fed both
    /// sample streams would report.
    pub fn merge_from(&mut self, other: &Hist) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns = self.sum_ns.saturating_add(other.sum_ns);
    }
}

impl Shardable for Hist {
    fn merge_from(&mut self, other: &Self) {
        Hist::merge_from(self, other);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_is_monotone_and_inverse_of_floor() {
        // every bucket's floor maps back to that bucket, floors are
        // strictly increasing, and the low range is exact
        let mut prev = None;
        for i in 0..N_BUCKETS {
            let f = bucket_floor(i);
            assert_eq!(bucket_of(f), i, "floor({i}) = {f} does not map back");
            if let Some(p) = prev {
                assert!(f > p, "bucket floors must be strictly increasing at {i}");
            }
            prev = Some(f);
        }
        for v in 0..16u64 {
            assert_eq!(bucket_of(v), v as usize, "low range must be exact");
        }
        // continuity across the exact/log boundary and octave edges
        assert_eq!(bucket_of(16), 16);
        assert_eq!(bucket_of(31), 31);
        assert_eq!(bucket_of(32), 32);
        assert_eq!(bucket_of(u64::MAX), N_BUCKETS - 1);
    }

    #[test]
    fn resolution_bound_holds() {
        // floor <= v, and v < floor + floor/16 for v >= 16
        for v in [17u64, 100, 999, 12_345, 7_654_321, u64::MAX / 3] {
            let f = bucket_floor(bucket_of(v));
            assert!(f <= v);
            assert!(v - f <= f / 16, "bucket {f} too coarse for {v}");
        }
    }

    #[test]
    fn pinned_percentiles_on_a_hand_built_distribution() {
        // 1..=1000 ns, uniform: nearest-rank p50 is sample #500, which
        // lands in the bucket whose floor is 496 (octave 256..512,
        // sub-bucket 15); p99 is sample #990 -> floor 960.
        let mut h = Hist::new();
        for v in 1..=1000u64 {
            h.record_ns(v);
        }
        assert_eq!(h.count(), 1000);
        assert_eq!(h.sum_ns(), 500_500);
        assert_eq!(h.mean_ns(), Some(500.5));
        assert_eq!(h.p50_ns(), Some(496));
        assert_eq!(h.p95_ns(), Some(928));
        assert_eq!(h.p99_ns(), Some(960));
        assert_eq!(h.percentile_ns(1.0), Some(bucket_floor(bucket_of(1000))));
        assert_eq!(Hist::new().p99_ns(), None, "empty histogram has no percentile");
    }

    #[test]
    fn merged_shards_equal_a_single_histogram_exactly() {
        // the ADR-004 exactness contract extended to hists: feed the
        // same deterministic stream round-robin into 4 shards, merge,
        // and every statistic must equal the single-fed histogram's
        let mut single = Hist::new();
        let mut shards: Vec<Hist> = (0..4).map(|_| Hist::new()).collect();
        let mut x = 0x2545F491_4F6CDD1Du64;
        for i in 0..10_000usize {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            let v = x % 50_000_000; // up to 50 ms
            single.record_ns(v);
            shards[i % 4].record_ns(v);
        }
        let mut merged = Hist::new();
        for s in &shards {
            Shardable::merge_from(&mut merged, s);
        }
        assert_eq!(merged.count(), single.count());
        assert_eq!(merged.sum_ns(), single.sum_ns());
        assert_eq!(merged.counts, single.counts, "bucket counts must match exactly");
        for q in [0.01, 0.25, 0.50, 0.90, 0.95, 0.99, 0.999, 1.0] {
            assert_eq!(merged.percentile_ns(q), single.percentile_ns(q), "q = {q}");
        }
    }
}
