//! Deterministic PRNG (splitmix64 + xoshiro256**) — `rand` is not
//! available offline. Used by the workload generator, property tests and
//! synthetic inputs. Not cryptographic, intentionally.

/// xoshiro256** seeded via splitmix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm),
                  splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`. Rejection-sampled to avoid modulo bias.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [lo, hi).
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f64() as f32
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (Poisson inter-arrival times for the
    /// workload generator).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-12).ln() / lambda
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Fresh generator split off this one (for per-thread streams).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(2);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s1 += v;
            s2 += v * v;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn splits_diverge() {
        let mut a = Rng::new(5);
        let mut b = a.split();
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
