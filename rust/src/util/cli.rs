//! Tiny declarative CLI parser (clap is not available offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args;
//! generates usage text; unknown flags are hard errors.

use std::collections::BTreeMap;

/// Parsed arguments for one (sub)command.
#[derive(Debug, Clone, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse `argv` against the set of known option names (without the
    /// leading `--`). `bools` take no value.
    pub fn parse(
        argv: &[String],
        known: &[&str],
        bools: &[&str],
    ) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                if bools.contains(&key.as_str()) {
                    out.flags.insert(key, "true".into());
                } else if known.contains(&key.as_str()) {
                    let v = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("--{key} needs a value"))?
                            .clone(),
                    };
                    out.flags.insert(key, v);
                } else {
                    return Err(format!("unknown option --{key}"));
                }
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key}: expected integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key}: expected number, got {v:?}")),
        }
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Comma-separated list helper: `--models resnet,bert`.
    pub fn get_list(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(
            &argv(&["serve", "--m", "8", "--fast", "--name=x"]),
            &["m", "name"],
            &["fast"],
        )
        .unwrap();
        assert_eq!(a.positional(), &["serve"]);
        assert_eq!(a.get_usize("m", 1).unwrap(), 8);
        assert!(a.has("fast"));
        assert_eq!(a.get("name"), Some("x"));
    }

    #[test]
    fn rejects_unknown() {
        assert!(Args::parse(&argv(&["--nope"]), &[], &[]).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&argv(&["--m"]), &["m"], &[]).is_err());
    }

    #[test]
    fn bad_int_is_error() {
        let a = Args::parse(&argv(&["--m", "xyz"]), &["m"], &[]).unwrap();
        assert!(a.get_usize("m", 1).is_err());
    }

    #[test]
    fn list_parsing() {
        let a = Args::parse(&argv(&["--models", "a, b,c"]), &["models"], &[]).unwrap();
        assert_eq!(a.get_list("models", &[]), vec!["a", "b", "c"]);
        assert_eq!(a.get_list("other", &["d"]), vec!["d"]);
    }
}
