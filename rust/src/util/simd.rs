//! Runtime feature-detected SIMD kernels for the round hot paths.
//!
//! The serving stack's per-round cost floor is a handful of wide
//! copies: `RoundArena::pack_with` scatters each instance's payload
//! into its strided megabatch windows, the unpack path gathers merged
//! output windows back out (`TensorView::to_owned`), and the ingress
//! frame codec moves tensor payloads between f32 slices and the wire.
//! This module is the one arch-dispatch layer behind all of them:
//!
//! - **x86_64** — AVX2 when `is_x86_feature_detected!("avx2")` says so,
//!   otherwise SSE2 (the x86_64 baseline, always present);
//! - **aarch64** — NEON (detected, but mandatory on aarch64 in
//!   practice);
//! - **everywhere else** — a portable scalar path the compiler is free
//!   to auto-vectorize (`ptr::copy_nonoverlapping` / `write_bytes`).
//!
//! Setting `RUST_PALLAS_FORCE_SCALAR` (to anything but `""`/`"0"`)
//! pins the scalar path regardless of detection — CI runs the whole
//! test suite under it so the fallback stays green forever. The choice
//! is made once per process and cached ([`backend`]).
//!
//! [`reference`] holds the strict per-element scalar kernels: the
//! semantics oracle the property tests (`rust/tests/simd_tests.rs`)
//! compare every dispatched kernel against byte-for-byte, and the
//! baseline `benches/hot_paths.rs` measures speedups over. Each
//! element access is pinned with `std::hint::black_box` so LLVM's
//! loop-idiom recognition cannot collapse the loop into the very
//! memcpy/SIMD it is supposed to be a scalar baseline for (`black_box`
//! does not change values, so the oracle stays exact).
//!
//! # Safety
//!
//! Every `unsafe` intrinsic block lives behind ONE call boundary: the
//! safe public functions prove bounds/overlap with [`check_windows`]
//! (checked arithmetic, so hostile sizes cannot wrap the bounds check)
//! and slice-length asserts, then hand raw pointers to kernels that
//! only ever touch `[ptr, ptr + n)`. All wide loads/stores are the
//! unaligned variants (`loadu`/`storeu`/`vld1q`/`vst1q`), so no
//! alignment invariant exists to violate. Full argument in
//! `docs/ADR-004-simd-sharded-metrics.md`.

use std::sync::OnceLock;

/// Which kernel family [`backend`] selected for this process.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// portable fallback (also the `RUST_PALLAS_FORCE_SCALAR` pin)
    Scalar,
    /// x86_64 baseline, 4 f32 lanes
    Sse2,
    /// x86_64 detected, 8 f32 lanes
    Avx2,
    /// aarch64, 4 f32 lanes
    Neon,
}

/// `true` when `RUST_PALLAS_FORCE_SCALAR` pins the scalar path
/// (set and neither empty nor `"0"`).
pub fn scalar_forced() -> bool {
    match std::env::var("RUST_PALLAS_FORCE_SCALAR") {
        Ok(v) => !v.is_empty() && v != "0",
        Err(_) => false,
    }
}

static BACKEND: OnceLock<Backend> = OnceLock::new();

/// The kernel family every dispatched primitive uses — detected once
/// per process (env override first, then CPU features).
pub fn backend() -> Backend {
    *BACKEND.get_or_init(|| if scalar_forced() { Backend::Scalar } else { detect() })
}

#[cfg(target_arch = "x86_64")]
fn detect() -> Backend {
    if is_x86_feature_detected!("avx2") {
        Backend::Avx2
    } else {
        Backend::Sse2
    }
}

#[cfg(target_arch = "aarch64")]
fn detect() -> Backend {
    if std::arch::is_aarch64_feature_detected!("neon") {
        Backend::Neon
    } else {
        Backend::Scalar
    }
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect() -> Backend {
    Backend::Scalar
}

// ---------------------------------------------------------------------------
// safe public API
// ---------------------------------------------------------------------------

/// A batch of equal-length row copies between strided window layouts:
/// row `r` copies `row_len` f32 from `src[src_offset + r*src_stride..]`
/// to `dst[dst_offset + r*dst_stride..]`. Strides must cover `row_len`
/// (windows within each buffer are overlap-free) — the shape of every
/// slot-window scatter/gather in the round pipeline.
#[derive(Debug, Clone, Copy)]
pub struct Windows {
    pub rows: usize,
    pub row_len: usize,
    pub dst_offset: usize,
    pub dst_stride: usize,
    pub src_offset: usize,
    pub src_stride: usize,
}

/// Copy `src` into `dst` (equal lengths) on the dispatched path.
pub fn copy(dst: &mut [f32], src: &[f32]) {
    assert_eq!(dst.len(), src.len(), "simd::copy length mismatch");
    // SAFETY: lengths asserted equal; &mut rules out dst/src aliasing.
    unsafe { copy_raw(backend(), dst.as_mut_ptr(), src.as_ptr(), dst.len()) }
}

/// Copy `src` into a fresh `Vec` on the dispatched path (the
/// `TensorView::to_owned` unpack step).
pub fn to_vec(src: &[f32]) -> Vec<f32> {
    let mut v = Vec::with_capacity(src.len());
    // SAFETY: capacity reserved for exactly src.len() elements, the
    // kernel writes [ptr, ptr+len), set_len publishes initialized data.
    unsafe {
        copy_raw(backend(), v.as_mut_ptr(), src.as_ptr(), src.len());
        v.set_len(src.len());
    }
    v
}

/// Zero `dst` on the dispatched path.
pub fn fill_zero(dst: &mut [f32]) {
    // SAFETY: the kernel writes exactly [ptr, ptr + dst.len()).
    unsafe { fill_raw(backend(), dst.as_mut_ptr(), dst.len()) }
}

/// Copy a strided window layout (see [`Windows`]) on the dispatched
/// path. Bounds and overlap-freedom are proven up front with checked
/// arithmetic; `rows == 0` or `row_len == 0` is a no-op.
pub fn copy_windows(dst: &mut [f32], src: &[f32], w: Windows) {
    check_windows(dst.len(), Some(src.len()), &w);
    if w.rows == 0 || w.row_len == 0 {
        return;
    }
    let be = backend();
    let (d, s) = (dst.as_mut_ptr(), src.as_ptr());
    // SAFETY: check_windows proved every row's [offset + r*stride,
    // .. + row_len) lies inside its slice; &mut rules out aliasing.
    unsafe {
        for r in 0..w.rows {
            copy_raw(
                be,
                d.add(w.dst_offset + r * w.dst_stride),
                s.add(w.src_offset + r * w.src_stride),
                w.row_len,
            );
        }
    }
}

/// Scatter `rows` contiguous rows of `src` into strided windows of
/// `dst` — the megabatch pack direction (`RoundArena::pack_with`).
pub fn scatter_rows(
    dst: &mut [f32],
    dst_offset: usize,
    dst_stride: usize,
    src: &[f32],
    rows: usize,
    row_len: usize,
) {
    copy_windows(
        dst,
        src,
        Windows { rows, row_len, dst_offset, dst_stride, src_offset: 0, src_stride: row_len },
    );
}

/// Gather strided windows of `src` into `rows` contiguous rows of
/// `dst` — the megabatch unpack direction.
pub fn gather_rows(
    dst: &mut [f32],
    src: &[f32],
    src_offset: usize,
    src_stride: usize,
    rows: usize,
    row_len: usize,
) {
    copy_windows(
        dst,
        src,
        Windows { rows, row_len, dst_offset: 0, dst_stride: row_len, src_offset, src_stride },
    );
}

/// Zero `rows` strided windows of `dst` — pad re-zeroing for absent
/// megabatch slots, without reading a pad source block.
pub fn fill_rows_zero(dst: &mut [f32], offset: usize, stride: usize, rows: usize, row_len: usize) {
    let w = Windows {
        rows,
        row_len,
        dst_offset: offset,
        dst_stride: stride,
        src_offset: 0,
        src_stride: row_len,
    };
    check_windows(dst.len(), None, &w);
    if rows == 0 || row_len == 0 {
        return;
    }
    let be = backend();
    let d = dst.as_mut_ptr();
    // SAFETY: bounds proven by check_windows, same shape as copy_windows.
    unsafe {
        for r in 0..rows {
            fill_raw(be, d.add(offset + r * stride), row_len);
        }
    }
}

/// Append `src` to `out` as little-endian f32 bytes (frame encode).
pub fn extend_f32_le(out: &mut Vec<u8>, src: &[f32]) {
    if cfg!(target_endian = "big") {
        reference::extend_f32_le(out, src);
        return;
    }
    // an f32 slice occupies len*4 <= isize::MAX bytes, so no overflow
    let n = src.len() * 4;
    out.reserve(n);
    let at = out.len();
    // SAFETY: little-endian target, so the in-memory f32 bytes ARE the
    // wire bytes; n bytes reserved past `at`; set_len publishes them.
    unsafe {
        copy_bytes_raw(backend(), out.as_mut_ptr().add(at), src.as_ptr().cast::<u8>(), n);
        out.set_len(at + n);
    }
}

/// Append the f32s encoded little-endian in `src` (length a multiple
/// of 4) to `out` (frame decode).
pub fn extend_le_f32(out: &mut Vec<f32>, src: &[u8]) {
    assert!(src.len() % 4 == 0, "LE f32 stream of {} bytes is not a multiple of 4", src.len());
    if cfg!(target_endian = "big") {
        reference::extend_le_f32(out, src);
        return;
    }
    let n = src.len() / 4;
    out.reserve(n);
    let at = out.len();
    // SAFETY: every bit pattern is a valid f32; n elements reserved
    // past `at`; the byte kernel tolerates any (mis)alignment.
    unsafe {
        copy_bytes_raw(backend(), out.as_mut_ptr().add(at).cast::<u8>(), src.as_ptr(), src.len());
        out.set_len(at + n);
    }
}

/// Prove a [`Windows`] layout stays inside both buffers and its rows
/// cannot overlap (stride >= row_len), with checked arithmetic so
/// degenerate sizes fail the assert instead of wrapping past it.
/// `src_len = None` skips the source-side check (fill kernels).
fn check_windows(dst_len: usize, src_len: Option<usize>, w: &Windows) {
    if w.rows == 0 || w.row_len == 0 {
        return;
    }
    assert!(
        w.dst_stride >= w.row_len && w.src_stride >= w.row_len,
        "window stride (dst {}, src {}) must cover row_len {}",
        w.dst_stride,
        w.src_stride,
        w.row_len
    );
    let end = |offset: usize, stride: usize| {
        (w.rows - 1)
            .checked_mul(stride)
            .and_then(|x| x.checked_add(offset))
            .and_then(|x| x.checked_add(w.row_len))
            .expect("window bounds overflow")
    };
    let dst_end = end(w.dst_offset, w.dst_stride);
    assert!(dst_end <= dst_len, "windows end at {dst_end} but dst holds {dst_len}");
    if let Some(src_len) = src_len {
        let src_end = end(w.src_offset, w.src_stride);
        assert!(src_end <= src_len, "windows end at {src_end} but src holds {src_len}");
    }
}

// ---------------------------------------------------------------------------
// strict scalar reference kernels (test oracle + bench baseline)
// ---------------------------------------------------------------------------

/// Strict per-element scalar kernels: the portable semantics every
/// dispatched kernel must match byte-for-byte, and the ns/slot baseline
/// of `benches/hot_paths.rs`. `black_box` pins each element so the
/// compiler cannot rewrite the loop into memcpy or auto-vectorize it —
/// a *scalar* baseline stays scalar (values are unchanged, so these
/// remain exact oracles).
pub mod reference {
    use std::hint::black_box;

    use super::{check_windows, Windows};

    pub fn copy(dst: &mut [f32], src: &[f32]) {
        assert_eq!(dst.len(), src.len(), "reference::copy length mismatch");
        for (d, s) in dst.iter_mut().zip(src) {
            *d = black_box(*s);
        }
    }

    pub fn fill_zero(dst: &mut [f32]) {
        for d in dst.iter_mut() {
            *d = black_box(0.0);
        }
    }

    pub fn copy_windows(dst: &mut [f32], src: &[f32], w: Windows) {
        check_windows(dst.len(), Some(src.len()), &w);
        if w.rows == 0 || w.row_len == 0 {
            return;
        }
        for r in 0..w.rows {
            let d = w.dst_offset + r * w.dst_stride;
            let s = w.src_offset + r * w.src_stride;
            copy(&mut dst[d..d + w.row_len], &src[s..s + w.row_len]);
        }
    }

    pub fn fill_rows_zero(
        dst: &mut [f32],
        offset: usize,
        stride: usize,
        rows: usize,
        row_len: usize,
    ) {
        let w = Windows {
            rows,
            row_len,
            dst_offset: offset,
            dst_stride: stride,
            src_offset: 0,
            src_stride: row_len,
        };
        check_windows(dst.len(), None, &w);
        if rows == 0 || row_len == 0 {
            return;
        }
        for r in 0..rows {
            let d = offset + r * stride;
            fill_zero(&mut dst[d..d + row_len]);
        }
    }

    pub fn extend_f32_le(out: &mut Vec<u8>, src: &[f32]) {
        for &v in src {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }

    pub fn extend_le_f32(out: &mut Vec<f32>, src: &[u8]) {
        assert!(src.len() % 4 == 0, "LE f32 stream of {} bytes is not a multiple of 4", src.len());
        out.extend(src.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())));
    }
}

// ---------------------------------------------------------------------------
// raw kernels — dispatch + per-arch implementations
// ---------------------------------------------------------------------------

/// SAFETY: `dst` and `src` must be valid for `n` f32 reads/writes and
/// must not overlap. Any alignment is fine (unaligned ops throughout).
unsafe fn copy_raw(be: Backend, dst: *mut f32, src: *const f32, n: usize) {
    match be {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => copy_avx2(dst, src, n),
        #[cfg(target_arch = "x86_64")]
        Backend::Sse2 => copy_sse2(dst, src, n),
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => copy_neon(dst, src, n),
        _ => std::ptr::copy_nonoverlapping(src, dst, n),
    }
}

/// SAFETY: `dst` must be valid for `n` f32 writes; any alignment.
unsafe fn fill_raw(be: Backend, dst: *mut f32, n: usize) {
    match be {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => fill_avx2(dst, n),
        #[cfg(target_arch = "x86_64")]
        Backend::Sse2 => fill_sse2(dst, n),
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => fill_neon(dst, n),
        // all-zero bytes are f32 0.0
        _ => std::ptr::write_bytes(dst, 0, n),
    }
}

/// SAFETY: `dst` and `src` must be valid for `n` byte reads/writes and
/// must not overlap; any alignment.
unsafe fn copy_bytes_raw(be: Backend, dst: *mut u8, src: *const u8, n: usize) {
    match be {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => copy_bytes_avx2(dst, src, n),
        #[cfg(target_arch = "x86_64")]
        Backend::Sse2 => copy_bytes_sse2(dst, src, n),
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => copy_bytes_neon(dst, src, n),
        _ => std::ptr::copy_nonoverlapping(src, dst, n),
    }
}

/// SAFETY: valid for elements `i..n`; the ragged-tail finisher every
/// wide kernel ends with.
#[inline(always)]
unsafe fn copy_tail(dst: *mut f32, src: *const f32, mut i: usize, n: usize) {
    while i < n {
        *dst.add(i) = *src.add(i);
        i += 1;
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::{
        __m256i, _mm256_loadu_ps, _mm256_loadu_si256, _mm256_setzero_ps, _mm256_storeu_ps,
        _mm256_storeu_si256, _mm_loadu_ps, _mm_loadu_si128, _mm_setzero_ps, _mm_storeu_ps,
        _mm_storeu_si128,
    };

    use super::copy_tail;

    /// SAFETY (all kernels here): caller guarantees `n` valid elements
    /// behind each pointer and no overlap; unaligned ops throughout.
    #[target_feature(enable = "avx2")]
    pub unsafe fn copy_avx2(dst: *mut f32, src: *const f32, n: usize) {
        let mut i = 0usize;
        while i + 16 <= n {
            let a = _mm256_loadu_ps(src.add(i));
            let b = _mm256_loadu_ps(src.add(i + 8));
            _mm256_storeu_ps(dst.add(i), a);
            _mm256_storeu_ps(dst.add(i + 8), b);
            i += 16;
        }
        if i + 8 <= n {
            _mm256_storeu_ps(dst.add(i), _mm256_loadu_ps(src.add(i)));
            i += 8;
        }
        if i + 4 <= n {
            _mm_storeu_ps(dst.add(i), _mm_loadu_ps(src.add(i)));
            i += 4;
        }
        copy_tail(dst, src, i, n);
    }

    /// SAFETY: as [`copy_avx2`] — `n` valid elements, no overlap.
    #[target_feature(enable = "sse2")]
    pub unsafe fn copy_sse2(dst: *mut f32, src: *const f32, n: usize) {
        let mut i = 0usize;
        while i + 8 <= n {
            let a = _mm_loadu_ps(src.add(i));
            let b = _mm_loadu_ps(src.add(i + 4));
            _mm_storeu_ps(dst.add(i), a);
            _mm_storeu_ps(dst.add(i + 4), b);
            i += 8;
        }
        if i + 4 <= n {
            _mm_storeu_ps(dst.add(i), _mm_loadu_ps(src.add(i)));
            i += 4;
        }
        copy_tail(dst, src, i, n);
    }

    /// SAFETY: `dst` valid for `n` f32 writes; any alignment.
    #[target_feature(enable = "avx2")]
    pub unsafe fn fill_avx2(dst: *mut f32, n: usize) {
        let z = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= n {
            _mm256_storeu_ps(dst.add(i), z);
            i += 8;
        }
        while i < n {
            *dst.add(i) = 0.0;
            i += 1;
        }
    }

    /// SAFETY: `dst` valid for `n` f32 writes; any alignment.
    #[target_feature(enable = "sse2")]
    pub unsafe fn fill_sse2(dst: *mut f32, n: usize) {
        let z = _mm_setzero_ps();
        let mut i = 0usize;
        while i + 4 <= n {
            _mm_storeu_ps(dst.add(i), z);
            i += 4;
        }
        while i < n {
            *dst.add(i) = 0.0;
            i += 1;
        }
    }

    /// SAFETY: `n` valid bytes behind each pointer, no overlap.
    #[target_feature(enable = "avx2")]
    pub unsafe fn copy_bytes_avx2(dst: *mut u8, src: *const u8, n: usize) {
        let mut i = 0usize;
        while i + 32 <= n {
            let v = _mm256_loadu_si256(src.add(i).cast::<__m256i>());
            _mm256_storeu_si256(dst.add(i).cast::<__m256i>(), v);
            i += 32;
        }
        while i < n {
            *dst.add(i) = *src.add(i);
            i += 1;
        }
    }

    /// SAFETY: `n` valid bytes behind each pointer, no overlap.
    #[target_feature(enable = "sse2")]
    pub unsafe fn copy_bytes_sse2(dst: *mut u8, src: *const u8, n: usize) {
        let mut i = 0usize;
        while i + 16 <= n {
            let v = _mm_loadu_si128(src.add(i).cast());
            _mm_storeu_si128(dst.add(i).cast(), v);
            i += 16;
        }
        while i < n {
            *dst.add(i) = *src.add(i);
            i += 1;
        }
    }
}

#[cfg(target_arch = "x86_64")]
use x86::{copy_avx2, copy_bytes_avx2, copy_bytes_sse2, copy_sse2, fill_avx2, fill_sse2};

#[cfg(target_arch = "aarch64")]
mod arm {
    use std::arch::aarch64::{vdupq_n_f32, vld1q_f32, vld1q_u8, vst1q_f32, vst1q_u8};

    use super::copy_tail;

    /// SAFETY (all kernels here): caller guarantees `n` valid elements
    /// behind each pointer and no overlap; unaligned ops throughout.
    #[target_feature(enable = "neon")]
    pub unsafe fn copy_neon(dst: *mut f32, src: *const f32, n: usize) {
        let mut i = 0usize;
        while i + 8 <= n {
            let a = vld1q_f32(src.add(i));
            let b = vld1q_f32(src.add(i + 4));
            vst1q_f32(dst.add(i), a);
            vst1q_f32(dst.add(i + 4), b);
            i += 8;
        }
        if i + 4 <= n {
            vst1q_f32(dst.add(i), vld1q_f32(src.add(i)));
            i += 4;
        }
        copy_tail(dst, src, i, n);
    }

    /// SAFETY: `dst` valid for `n` f32 writes; any alignment.
    #[target_feature(enable = "neon")]
    pub unsafe fn fill_neon(dst: *mut f32, n: usize) {
        let z = vdupq_n_f32(0.0);
        let mut i = 0usize;
        while i + 4 <= n {
            vst1q_f32(dst.add(i), z);
            i += 4;
        }
        while i < n {
            *dst.add(i) = 0.0;
            i += 1;
        }
    }

    /// SAFETY: `n` valid bytes behind each pointer, no overlap.
    #[target_feature(enable = "neon")]
    pub unsafe fn copy_bytes_neon(dst: *mut u8, src: *const u8, n: usize) {
        let mut i = 0usize;
        while i + 16 <= n {
            vst1q_u8(dst.add(i), vld1q_u8(src.add(i)));
            i += 16;
        }
        while i < n {
            *dst.add(i) = *src.add(i);
            i += 1;
        }
    }
}

#[cfg(target_arch = "aarch64")]
use arm::{copy_bytes_neon, copy_neon, fill_neon};

#[cfg(test)]
mod tests {
    use super::*;

    fn ramp(n: usize) -> Vec<f32> {
        (0..n).map(|i| i as f32 * 0.5 - 3.0).collect()
    }

    #[test]
    fn backend_is_stable_and_respects_the_env_pin() {
        assert_eq!(backend(), backend());
        if scalar_forced() {
            assert_eq!(backend(), Backend::Scalar);
        }
    }

    #[test]
    fn copy_and_fill_match_reference_across_tails() {
        // every ragged tail 0..64 plus a couple of wide bodies
        for n in (0..64).chain([128, 1000]) {
            let src = ramp(n);
            let mut got = vec![f32::NAN; n];
            let mut want = vec![f32::NAN; n];
            copy(&mut got, &src);
            reference::copy(&mut want, &src);
            assert_eq!(got, want, "copy n={n}");

            fill_zero(&mut got);
            reference::fill_zero(&mut want);
            assert_eq!(got, want, "fill n={n}");
        }
    }

    #[test]
    fn to_vec_is_copy() {
        let src = ramp(77);
        assert_eq!(to_vec(&src), src);
        assert!(to_vec(&[]).is_empty());
    }

    #[test]
    fn windows_scatter_gather_roundtrip() {
        let (rows, row_len, stride) = (5usize, 7usize, 11usize);
        let src = ramp(rows * row_len);
        let mut mega = vec![-1.0f32; 3 + (rows - 1) * stride + row_len];
        scatter_rows(&mut mega, 3, stride, &src, rows, row_len);
        // gaps between windows stay untouched
        assert_eq!(mega[0], -1.0);
        assert_eq!(mega[3 + row_len], -1.0);
        let mut back = vec![0.0f32; rows * row_len];
        gather_rows(&mut back, &mega, 3, stride, rows, row_len);
        assert_eq!(back, src);

        fill_rows_zero(&mut mega, 3, stride, rows, row_len);
        let mut want = vec![0.0f32; rows * row_len];
        gather_rows(&mut want, &mega, 3, stride, rows, row_len);
        assert_eq!(want, vec![0.0f32; rows * row_len]);
        assert_eq!(mega[3 + row_len], -1.0, "gap survived the zero fill");
    }

    #[test]
    fn le_bytes_roundtrip_matches_reference() {
        let src = ramp(33);
        let (mut got, mut want) = (vec![0xAAu8], vec![0xAAu8]);
        extend_f32_le(&mut got, &src);
        reference::extend_f32_le(&mut want, &src);
        assert_eq!(got, want);

        let (mut back, mut back_ref) = (Vec::new(), Vec::new());
        extend_le_f32(&mut back, &got[1..]);
        reference::extend_le_f32(&mut back_ref, &want[1..]);
        assert_eq!(back, src);
        assert_eq!(back_ref, src);
    }

    #[test]
    #[should_panic(expected = "stride")]
    fn overlapping_windows_are_rejected() {
        let mut dst = vec![0.0f32; 32];
        let src = ramp(16);
        scatter_rows(&mut dst, 0, 3, &src, 4, 4); // stride 3 < row_len 4
    }

    #[test]
    #[should_panic(expected = "windows end")]
    fn out_of_bounds_windows_are_rejected() {
        let mut dst = vec![0.0f32; 10];
        let src = ramp(8);
        scatter_rows(&mut dst, 0, 8, &src, 2, 4); // ends at 12 > 10
    }
}
