//! Substrates: JSON, RNG, CLI parsing, statistics, property testing and a
//! criterion-style bench harness.
//!
//! The offline registry only carries the `xla` crate's dependency closure
//! (DESIGN.md §7), so `serde_json`, `rand`, `clap`, `criterion` and
//! `proptest` are re-implemented here at the scale this system needs.

pub mod json;
pub mod rng;
pub mod cli;
pub mod stats;
pub mod prop;
pub mod bench;
pub mod simd;
pub mod shard;
pub mod hist;
pub mod lint;
pub mod lock;
