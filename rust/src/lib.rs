//! # netfuse
//!
//! Rust + JAX + Pallas reproduction of **"Accelerating Multi-Model
//! Inference by Merging DNNs of Different Weights"** (Jeong et al., 2020).
//!
//! NETFUSE merges M DNN instances that share an architecture but carry
//! different weights and serve different inputs into one large model, by
//! replacing each op with a counterpart that admits *input-weight local
//! computations* (matmul → batch matmul, conv → grouped conv, layer norm
//! → group norm). The merged network is numerically equivalent to running
//! the M networks separately, but executes as a single program.
//!
//! This crate is Layer 3 of the stack (see `DESIGN.md`): the serving
//! coordinator. It loads HLO modules AOT-compiled by the Python side
//! (`python/compile/aot.py`), owns per-instance weight banks, and serves
//! multi-model inference under four execution strategies — the paper's
//! `Sequential`, `Concurrent`, `Hybrid` baselines and `NetFuse` itself.
//!
//! Module map:
//! - [`util`] — substrates: JSON, RNG, CLI, stats, property testing, bench
//!   harness + counting allocator (no external crates offline; the error
//!   API is the vendored `anyhow` shim under `vendor/`).
//! - [`tensor`] — dense tensor library + `.nft` container IO. `Tensor` is
//!   the owned type; `TensorView` is the zero-copy borrowed window the
//!   round pipeline trades in (`view0` replaces copying `index0`/`split`
//!   on the unpack path).
//! - [`graph`] — the graph IR shared with Python (JSON round-trip).
//! - [`fuse`] — Algorithm 1 as the serving-side merge planner.
//! - [`runtime`] — PJRT client wrapper: load / compile / execute HLO.
//!   `Bound::run_raw` executes straight from a staging slice; the
//!   default build uses the offline stub backend (`xla` feature gates
//!   the real bindings).
//! - [`coordinator`] — router, batcher, strategies, memory accounting,
//!   metrics, workload generation, serving loop. The round data plane:
//!   `coordinator::arena::RoundArena` owns the reusable megabatch + pad
//!   block (packing is one in-place copy per round, zero allocations,
//!   and windows already zeroed by a previous padded round skip even
//!   that); `coordinator::arena::ArenaRing` multi-buffers it so up to
//!   `depth` threads pack later rounds while round N's staged
//!   megabatch is still in flight; `coordinator::pool::WorkerPool` owns the persistent
//!   Concurrent/Hybrid workers (created lazily per `Fleet`, or ONE
//!   machine-sized pool shared by many fleets via
//!   `Fleet::load_with_pool`, fed borrowed round-scoped jobs);
//!   `Fleet::unpack` hands out `TensorView`s into the merged output,
//!   promoted to owned tensors only for occupied response slots.
//!   Serving front ends: `coordinator::server::Server` (single fleet)
//!   and `coordinator::multi::MultiServer` (several fleets as tenants
//!   of one machine — per-fleet lanes, fair round-ready dispatch
//!   across lanes, one shared worker pool). Both are generic over
//!   `coordinator::RoundExecutor`, so the batching/requeue/scheduling
//!   logic runs under test without AOT artifacts. The `max_wait`
//!   batching deadline is derived per request from its arrival time
//!   (never reset by a dispatch).
//! - [`ingress`] — the open-loop serving front door: length-prefixed
//!   frame wire format, a `Transport` trait (TCP + in-proc channel),
//!   the bounded `IngressBridge` MPSC through which N producer threads
//!   feed the one dispatch thread owning a `MultiServer`, per-lane QoS
//!   (`LaneQos` weight + SLO; weighted deficit round-robin with an
//!   SLO-deadline boost in `QosScheduler`), and an open-loop Poisson /
//!   bursty / skewed-lane load generator. Requests are re-stamped at
//!   admission (`Request::arrived_now`) so producer-side clock reuse
//!   cannot skew queue-wait math. Since ADR-005 the lane topology is
//!   **elastic**: `coordinator::control::TopologyController` adds,
//!   removes, and hot-swaps lanes on a live `ParallelDispatcher`
//!   (`ingress::run_dispatch_elastic`) without disturbing sibling
//!   lanes' in-flight rounds.
//! - [`devmodel`] — analytical V100 / TITAN Xp device model (reproduces
//!   the paper's GPU-shaped figures; we have no GPU).
//! - [`rewriter`] — miniature TASO-like greedy graph rewriter (the §2.2
//!   baseline that cannot find cross-model merges).

pub mod util;
pub mod tensor;
pub mod graph;
pub mod fuse;
pub mod runtime;
pub mod coordinator;
pub mod ingress;
pub mod devmodel;
pub mod figures;
pub mod rewriter;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
