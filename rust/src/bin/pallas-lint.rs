//! `pallas-lint`: run the in-repo invariant lint (ADR-008) over
//! `rust/src` and exit nonzero on any finding. Wired into CI as a
//! required step *before* the build, so invariant violations fail fast.
//!
//! Usage: `pallas-lint [ROOT]` — ROOT defaults to this crate's `src/`.

use std::path::PathBuf;
use std::process::ExitCode;

use netfuse::util::lint;

fn main() -> ExitCode {
    let root = std::env::args()
        .nth(1)
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src"));
    let findings = match lint::lint_tree(&root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("pallas-lint: cannot lint {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    if findings.is_empty() {
        println!("pallas-lint: {} clean", root.display());
        return ExitCode::SUCCESS;
    }
    for f in &findings {
        eprintln!("{}", f.render());
    }
    eprintln!("pallas-lint: {} finding(s)", findings.len());
    ExitCode::FAILURE
}
