//! Open-loop load generation for the ingress subsystem.
//!
//! A closed-loop driver (every driver so far) waits for round N before
//! offering round N+1, so the server can never be overloaded and
//! queue-wait behavior is never exercised. The [`LoadGen`] here is
//! **open loop**: arrivals follow the traffic process regardless of
//! completions, which is what makes the QoS scheduler's choices (and
//! SLO violations) observable at all.
//!
//! Traffic shapes ([`TrafficShape`]):
//! - `Poisson` — homogeneous arrivals at `rate` req/s (exponential
//!   inter-arrival times, as in `coordinator::workload`);
//! - `Bursty` — on/off modulated Poisson: `rate` during each `on`
//!   window, silence during each `off` window (arrivals are generated
//!   in "active time" and mapped through the gaps).
//!
//! Lane skew is orthogonal to the shape: each arrival picks a lane with
//! probability proportional to its weight, then a model uniformly
//! within the lane — `&[(2, 9.0), (2, 1.0)]` sends 90% of traffic to
//! lane 0.
//!
//! [`LoadGen::shards`] splits one stream across N producer threads by
//! rate-thinning (N independent generators at `rate/N`; the
//! superposition of independent Poisson processes is Poisson at the
//! original rate), with ids striped so no two shards collide.

use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::util::rng::Rng;

/// Arrival process shape.
#[derive(Debug, Clone, Copy)]
pub enum TrafficShape {
    /// homogeneous Poisson at `rate` requests/sec
    Poisson { rate: f64 },
    /// Poisson at `rate` during each `on` window, silent during `off`
    Bursty { rate: f64, on: Duration, off: Duration },
}

/// One scheduled request arrival.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Arrival {
    /// offset from stream start
    pub at: Duration,
    pub lane: usize,
    pub model_idx: usize,
    pub id: u64,
}

/// Deterministic open-loop arrival generator.
pub struct LoadGen {
    shape: TrafficShape,
    /// per-lane (models, weight)
    lanes: Vec<(usize, f64)>,
    total_weight: f64,
    rng: Rng,
    /// active-time clock (seconds of "rate on" time)
    active_t: f64,
    next_id: u64,
    id_stride: u64,
}

impl LoadGen {
    /// `lanes` is one `(models, weight)` per lane: arrivals pick a lane
    /// proportionally to `weight` and a model uniformly within it.
    pub fn new(shape: TrafficShape, lanes: &[(usize, f64)], seed: u64) -> Result<LoadGen> {
        let rate = match shape {
            TrafficShape::Poisson { rate } => rate,
            TrafficShape::Bursty { rate, on, off } => {
                if on.is_zero() {
                    bail!("bursty traffic needs a nonzero on-window");
                }
                if off.is_zero() {
                    bail!("bursty traffic with a zero off-window is just Poisson");
                }
                rate
            }
        };
        if !rate.is_finite() || rate <= 0.0 {
            bail!("arrival rate must be positive, got {rate}");
        }
        if lanes.is_empty() {
            bail!("loadgen needs at least one lane");
        }
        let mut total_weight = 0.0;
        for &(models, weight) in lanes {
            if models == 0 {
                bail!("every lane needs at least one model");
            }
            if !weight.is_finite() || weight <= 0.0 {
                bail!("lane weights must be positive, got {weight}");
            }
            total_weight += weight;
        }
        Ok(LoadGen {
            shape,
            lanes: lanes.to_vec(),
            total_weight,
            rng: Rng::new(seed),
            active_t: 0.0,
            next_id: 0,
            id_stride: 1,
        })
    }

    /// The next arrival in time order (the `at` clock only moves
    /// forward; for bursty shapes it skips the off windows).
    pub fn next(&mut self) -> Arrival {
        let rate = match self.shape {
            TrafficShape::Poisson { rate } | TrafficShape::Bursty { rate, .. } => rate,
        };
        self.active_t += self.rng.exp(rate);
        let at = match self.shape {
            TrafficShape::Poisson { .. } => self.active_t,
            TrafficShape::Bursty { on, off, .. } => {
                // map active time through the on/off cycle: the k-th
                // on-window's worth of active time lands after k off-gaps
                let on_s = on.as_secs_f64();
                let cycle = on_s + off.as_secs_f64();
                let k = (self.active_t / on_s).floor();
                k * cycle + (self.active_t - k * on_s)
            }
        };
        let lane = self.pick_lane();
        let model_idx = self.rng.usize_below(self.lanes[lane].0);
        let id = self.next_id;
        self.next_id += self.id_stride;
        Arrival { at: Duration::from_secs_f64(at), lane, model_idx, id }
    }

    fn pick_lane(&mut self) -> usize {
        let mut x = self.rng.f64() * self.total_weight;
        for (i, &(_, w)) in self.lanes.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return i;
            }
        }
        self.lanes.len() - 1 // fp rounding fell off the end
    }

    /// Split into `n` independent shards for `n` producer threads: each
    /// runs the same shape at `rate / n` (thinned Poisson — their
    /// superposition matches the original process), with ids striped
    /// `shard, shard+n, shard+2n, ...` so shards never collide.
    pub fn shards(mut self, n: usize) -> Vec<LoadGen> {
        assert!(n >= 1, "need at least one shard");
        let shape = match self.shape {
            TrafficShape::Poisson { rate } => TrafficShape::Poisson { rate: rate / n as f64 },
            TrafficShape::Bursty { rate, on, off } => {
                TrafficShape::Bursty { rate: rate / n as f64, on, off }
            }
        };
        (0..n as u64)
            .map(|i| LoadGen {
                shape,
                lanes: self.lanes.clone(),
                total_weight: self.total_weight,
                rng: self.rng.split(),
                active_t: 0.0,
                next_id: i,
                id_stride: n as u64,
            })
            .collect()
    }

    /// Replay arrivals in real time for `horizon`, calling `send` for
    /// each. Open loop: the clock never waits for completions — if the
    /// server falls behind, arrivals keep coming (that is the point).
    /// Returns the number of arrivals sent.
    pub fn drive(mut self, horizon: Duration, mut send: impl FnMut(Arrival)) -> u64 {
        let start = Instant::now();
        let mut sent = 0;
        loop {
            let a = self.next();
            if a.at >= horizon {
                return sent;
            }
            let elapsed = start.elapsed();
            if a.at > elapsed {
                std::thread::sleep(a.at - elapsed);
            }
            send(a);
            sent += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn poisson(rate: f64, seed: u64) -> LoadGen {
        LoadGen::new(TrafficShape::Poisson { rate }, &[(2, 1.0)], seed).unwrap()
    }

    #[test]
    fn poisson_rate_is_respected() {
        let mut g = poisson(1000.0, 7);
        let n = 20_000;
        let mut last = Duration::ZERO;
        for _ in 0..n {
            let a = g.next();
            assert!(a.at >= last, "arrivals must be time-ordered");
            last = a.at;
        }
        let measured = n as f64 / last.as_secs_f64();
        assert!(
            (measured - 1000.0).abs() < 50.0,
            "empirical rate {measured:.0} req/s should be ~1000"
        );
    }

    #[test]
    fn bursty_arrivals_avoid_off_windows() {
        let on = Duration::from_millis(20);
        let off = Duration::from_millis(80);
        let shape = TrafficShape::Bursty { rate: 2000.0, on, off };
        let mut g = LoadGen::new(shape, &[(1, 1.0)], 3).unwrap();
        let cycle = (on + off).as_secs_f64();
        for _ in 0..5000 {
            let a = g.next();
            let phase = a.at.as_secs_f64() % cycle;
            assert!(
                phase <= on.as_secs_f64() + 1e-9,
                "arrival at {:?} lands in an off window (phase {phase:.4})",
                a.at
            );
        }
    }

    #[test]
    fn lane_skew_follows_weights() {
        let shape = TrafficShape::Poisson { rate: 100.0 };
        let mut g = LoadGen::new(shape, &[(2, 9.0), (2, 1.0)], 11).unwrap();
        let n = 20_000;
        let mut lane0 = 0usize;
        for _ in 0..n {
            let a = g.next();
            assert!(a.lane < 2 && a.model_idx < 2);
            if a.lane == 0 {
                lane0 += 1;
            }
        }
        let frac = lane0 as f64 / n as f64;
        assert!((frac - 0.9).abs() < 0.02, "lane-0 share {frac:.3} should be ~0.9");
    }

    #[test]
    fn shards_thin_the_rate_and_stripe_ids() {
        let g = LoadGen::new(TrafficShape::Poisson { rate: 400.0 }, &[(1, 1.0)], 5).unwrap();
        let shards = g.shards(4);
        assert_eq!(shards.len(), 4);
        let mut ids = std::collections::BTreeSet::new();
        let mut total = 0usize;
        let horizon = 5.0; // virtual seconds
        for mut s in shards {
            loop {
                let a = s.next();
                if a.at.as_secs_f64() > horizon {
                    break;
                }
                assert!(ids.insert(a.id), "shard ids must not collide");
                total += 1;
            }
        }
        let rate = total as f64 / horizon;
        assert!(
            (rate - 400.0).abs() < 60.0,
            "superposed shard rate {rate:.0} should be ~400"
        );
    }

    #[test]
    fn deterministic_for_seed_and_validates_config() {
        let mut a = poisson(50.0, 42);
        let mut b = poisson(50.0, 42);
        for _ in 0..100 {
            assert_eq!(a.next(), b.next());
        }
        assert!(LoadGen::new(TrafficShape::Poisson { rate: 0.0 }, &[(1, 1.0)], 0).is_err());
        assert!(LoadGen::new(TrafficShape::Poisson { rate: 1.0 }, &[], 0).is_err());
        assert!(LoadGen::new(TrafficShape::Poisson { rate: 1.0 }, &[(0, 1.0)], 0).is_err());
        assert!(LoadGen::new(TrafficShape::Poisson { rate: 1.0 }, &[(1, -1.0)], 0).is_err());
        let bad = TrafficShape::Bursty {
            rate: 1.0,
            on: Duration::ZERO,
            off: Duration::from_millis(1),
        };
        assert!(LoadGen::new(bad, &[(1, 1.0)], 0).is_err());
    }
}
