//! Per-lane QoS: weighted deficit round-robin with an SLO-deadline
//! boost. Replaces `MultiServer`'s pure round-robin `ready_lane` scan.
//!
//! Each lane carries a [`LaneQos`]: a WDRR `weight` (its share of
//! dispatched rounds when several lanes are backlogged) and an `slo`
//! (the end-to-end latency target its requests are supposed to meet).
//!
//! Scheduling is two-tier:
//! 1. **SLO boost** — a lane whose oldest queued request has waited to
//!    within ε of its `slo` preempts the WDRR order outright, even if
//!    its round is not yet due (the dispatch pads the missing slots):
//!    better a padded round now than a full round after the deadline.
//!    Among urgent lanes, least slack wins. ε is per lane
//!    ([`LaneQos::with_boost_margin`]), defaulting to the scheduler-wide
//!    [`QosScheduler::boost_margin`]; a zero-margin lane never pads
//!    early.
//! 2. **WDRR** — otherwise, lanes whose rounds are due are served in
//!    deficit round-robin: every replenish cycle grants each backlogged
//!    lane `weight` round credits (capped at two cycles so an idle
//!    spell cannot bank unbounded priority; a drained lane's unspent
//!    credit resets, per classic DRR); the scan starts after the last
//!    dispatched lane, so equal weights degenerate to exactly the old
//!    fair round-robin.
//!
//! Deficits are **fractional** (fixed-point, [`CHARGE_UNIT`] = one full
//! round of the lane's own capacity): a dispatched round charges every
//! lane it served in proportion to the slots that lane consumed —
//! [`QosScheduler::commit_served`] takes one [`LaneCharge`] per served
//! lane. This is the merged-round fairness fix: a coalesced group round
//! serves *rider* lanes beyond the picked one, and charging only the
//! pick (the pre-fix behavior) let riders accumulate service for free,
//! so strict weighted shares drifted as lane counts grew. A rider
//! served beyond its remaining credit goes into bounded debt (two
//! cycles' worth, mirroring the credit cap) and pays it off before
//! being picked again.
//!
//! Lanes have a **lifecycle** (elastic topology, ADR-005): the control
//! plane retires a removed lane with [`QosScheduler::remove_lane`] —
//! which clears its deficit/debt/boost state completely, so a later
//! tenant reusing the id ([`QosScheduler::restore_lane`]) starts from
//! zero credit — and a lane migrated to another partition carries its
//! deficit with it ([`QosScheduler::add_lane_carrying`]), so weighted
//! shares hold across a partition rebalance.
//!
//! The scheduler is deliberately decoupled from `Server` internals: it
//! sees lanes only through [`LaneSnapshot`]s produced by a caller-owned
//! closure, so it is unit-testable with plain structs and usable by any
//! front end. [`QosScheduler::select`] is pure (usable from `&self`
//! readiness probes); [`QosScheduler::commit_served`] applies the
//! deficit charges and cursor advance for a pick that was actually
//! dispatched ([`QosScheduler::commit`] is the whole-round shorthand).

use std::time::Duration;

/// Per-lane scheduling contract.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LaneQos {
    /// WDRR share: rounds granted per replenish cycle (clamped >= 1).
    pub weight: u32,
    /// End-to-end latency target for the lane's requests. Lanes that
    /// get within their boost margin of it preempt WDRR.
    pub slo: Duration,
    /// Per-lane SLO boost margin ε: how close to `slo` the lane's
    /// oldest wait may get before the lane preempts the WDRR order
    /// (dispatching a padded round early). `None` inherits the
    /// scheduler-wide default ([`QosScheduler::boost_margin`]); an
    /// explicit `Duration::ZERO` means the lane never pads early — it
    /// boosts only once the deadline has actually been reached.
    pub boost_margin: Option<Duration>,
}

impl LaneQos {
    pub fn new(weight: u32, slo: Duration) -> LaneQos {
        LaneQos { weight, slo, boost_margin: None }
    }

    /// Set this lane's own SLO boost margin ε instead of inheriting the
    /// scheduler default. Plumbed uniformly through every
    /// `MultiServer::add_lane_qos` path — before this, ε was fixed for
    /// ALL lanes at `MultiServer` construction, so a single latency
    /// tier's margin was un-tunable per lane.
    pub fn with_boost_margin(mut self, eps: Duration) -> LaneQos {
        self.boost_margin = Some(eps);
        self
    }
}

impl Default for LaneQos {
    /// Weight 1 and an SLO far beyond any real deadline: scheduling
    /// degenerates to the plain fair round-robin `MultiServer` had.
    fn default() -> LaneQos {
        LaneQos { weight: 1, slo: Duration::from_secs(3600), boost_margin: None }
    }
}

/// What the scheduler sees of one lane at selection time.
#[derive(Debug, Clone, Copy)]
pub struct LaneSnapshot {
    /// the lane's round is due (full, or past its batching deadline)
    pub ready: bool,
    /// queued requests
    pub pending: usize,
    /// how long the lane's oldest queued request has waited
    pub oldest_wait: Option<Duration>,
}

/// A scheduling decision from [`QosScheduler::select`].
#[derive(Debug, Clone, Copy)]
pub struct Pick {
    pub lane: usize,
    /// chosen by the SLO boost (the round may need padding)
    pub urgent: bool,
    /// how many deficit replenish cycles selection assumed (0 = none;
    /// more than one only when rider debt must be worked off first);
    /// `commit_served` applies them
    replenish: u8,
}

/// Fixed-point scale of the WDRR deficit counters: one full round of a
/// lane's own capacity. Fractions arise from partial occupancy (a
/// padded round consuming `slots < round_slots`) and from merged-round
/// rider charges — see [`QosScheduler::commit_served`].
pub const CHARGE_UNIT: i64 = 1 << 16;

/// One lane's share of a dispatched round, as consumed slots: lane
/// `lane` had `slots` of its `round_slots` instance slots served. The
/// deficit charge is `CHARGE_UNIT * slots / round_slots` — a full
/// round costs one credit, a half-occupied rider half a credit.
#[derive(Debug, Clone, Copy)]
pub struct LaneCharge {
    pub lane: usize,
    /// occupied slots this round served for the lane
    pub slots: usize,
    /// the lane's full-round slot capacity (its executor's `m`)
    pub round_slots: usize,
}

impl LaneCharge {
    /// A whole-round charge (the solo-dispatch shorthand).
    pub fn full(lane: usize) -> LaneCharge {
        LaneCharge { lane, slots: 1, round_slots: 1 }
    }

    /// The fixed-point deficit debit this charge applies.
    fn debit(&self) -> i64 {
        let den = self.round_slots.max(1) as i64;
        // clamp: a misreported over-full round never charges more than
        // one whole round
        (CHARGE_UNIT * (self.slots as i64).min(den) / den).max(0)
    }
}

struct LaneState {
    qos: LaneQos,
    /// WDRR credits remaining this cycle, in [`CHARGE_UNIT`] fixed
    /// point. Negative = rider debt (service received beyond credit by
    /// merged rounds), bounded at two cycles' worth.
    deficit: i64,
    /// `false` once the lane is retired by the control plane
    /// ([`QosScheduler::remove_lane`]): never selected, never
    /// replenished, never charged. The slot itself is kept — lane ids
    /// are positional across `MultiServer` — and waits for reuse via
    /// [`QosScheduler::restore_lane`].
    live: bool,
    /// ε derived from the lane's *observed* round-time tail (ADR-007):
    /// the dispatch loop feeds EWMA-smoothed round p99 through
    /// [`QosScheduler::set_adaptive_margin`] between rounds. Resolution
    /// order in [`QosScheduler::lane_boost_margin`] is
    /// pin (`qos.boost_margin`) > adaptive > scheduler default, so an
    /// operator pin always wins and lanes with no observations yet fall
    /// back to the static default.
    adaptive_eps: Option<Duration>,
}

/// Weighted-deficit round-robin + SLO-boost lane scheduler.
pub struct QosScheduler {
    lanes: Vec<LaneState>,
    /// the lane AFTER the last dispatched one is scanned first
    cursor: usize,
    /// ε: how close to its SLO a lane's oldest wait may get before the
    /// lane preempts the WDRR order
    eps: Duration,
}

impl QosScheduler {
    pub const DEFAULT_BOOST_MARGIN: Duration = Duration::from_millis(1);

    pub fn new(boost_margin: Duration) -> QosScheduler {
        QosScheduler { lanes: Vec::new(), cursor: 0, eps: boost_margin }
    }

    /// The scheduler-wide default ε (lanes without an explicit
    /// [`LaneQos::boost_margin`] inherit it).
    pub fn boost_margin(&self) -> Duration {
        self.eps
    }

    /// The effective ε for one lane: its pinned margin if set, else the
    /// adaptive margin the dispatch loop derived from observed round
    /// tails, else the scheduler default. Deadline math
    /// (`MultiServer::next_due_in`) must use this, not
    /// [`QosScheduler::boost_margin`], or a per-lane margin would nap
    /// the dispatch thread past its boost window.
    // LINT-ALLOW(lane ids are issued by add_lane; callers pass back what we issued)
    pub fn lane_boost_margin(&self, lane: usize) -> Duration {
        let st = &self.lanes[lane];
        st.qos.boost_margin.or(st.adaptive_eps).unwrap_or(self.eps)
    }

    /// Install (or clear, with `None`) the adaptive ε for one lane —
    /// the control-loop write (ADR-007): the dispatch loop smooths the
    /// lane's observed round-time p99 and clamps it to
    /// `[min_eps, slo/2]` before calling this. A pinned
    /// [`LaneQos::boost_margin`] still overrides whatever is installed
    /// here, so operators keep the last word.
    // LINT-ALLOW(lane ids are issued by add_lane; callers pass back what we issued)
    pub fn set_adaptive_margin(&mut self, lane: usize, eps: Option<Duration>) {
        self.lanes[lane].adaptive_eps = eps;
    }

    /// The adaptive ε currently installed for `lane` (observability
    /// read; `None` until the control loop has observed a round tail,
    /// or after the lane was retired).
    // LINT-ALLOW(lane ids are issued by add_lane; callers pass back what we issued)
    pub fn adaptive_margin(&self, lane: usize) -> Option<Duration> {
        self.lanes[lane].adaptive_eps
    }

    /// Register a lane; returns its index. Weight 0 is clamped to 1 (a
    /// zero-share lane would starve forever).
    pub fn add_lane(&mut self, qos: LaneQos) -> usize {
        let qos = LaneQos { weight: qos.weight.max(1), ..qos };
        self.lanes.push(LaneState { qos, deficit: 0, live: true, adaptive_eps: None });
        self.lanes.len() - 1
    }

    /// [`QosScheduler::add_lane`] carrying a migrated deficit: when the
    /// control plane rebalances partitions, the lane's credit/debt moves
    /// with it (clamped to the new weight's ±2-cycle bounds), so
    /// weighted shares hold *across* the rebalance instead of the
    /// migrated lane restarting from zero and jumping the WDRR queue.
    // LINT-ALLOW(indexes the slot this call just pushed)
    pub fn add_lane_carrying(&mut self, qos: LaneQos, deficit: i64) -> usize {
        let lane = self.add_lane(qos);
        let w = self.lanes[lane].qos.weight as i64 * CHARGE_UNIT;
        self.lanes[lane].deficit = deficit.clamp(-w.saturating_mul(2), w.saturating_mul(2));
        lane
    }

    /// Retire a lane from scheduling **entirely**: it is never selected,
    /// replenished, or charged again, and its slot waits for reuse.
    /// Returns the lane's final deficit (positive credit or negative
    /// rider debt) so a *migrating* lane can carry it to its new
    /// partition ([`QosScheduler::add_lane_carrying`] /
    /// [`QosScheduler::restore_lane`]).
    ///
    /// Every piece of the retired lane's state — deficit, rider debt,
    /// boost margin, weight — is cleared HERE, not lazily at reuse: a
    /// later lane reusing the id must start from zero credit, never from
    /// the previous tenant's inherited debt (or banked boost window).
    // LINT-ALLOW(the control plane retires ids it previously added)
    pub fn remove_lane(&mut self, lane: usize) -> i64 {
        let st = &mut self.lanes[lane];
        let carried = st.deficit;
        st.live = false;
        st.deficit = 0;
        st.qos = LaneQos::default();
        st.adaptive_eps = None; // a reused id must not inherit a tail estimate
        carried
    }

    /// Re-register a retired lane slot under a (possibly new) tenant.
    /// `deficit` is 0 for a fresh lane, or the value
    /// [`QosScheduler::remove_lane`] returned when the same tenant is
    /// migrating in from another partition (clamped to the new weight's
    /// ±2-cycle bounds, mirroring the credit cap and debt floor).
    // LINT-ALLOW(the control plane restores ids it previously retired)
    pub fn restore_lane(&mut self, lane: usize, qos: LaneQos, deficit: i64) {
        let qos = LaneQos { weight: qos.weight.max(1), ..qos };
        let w = qos.weight as i64 * CHARGE_UNIT;
        self.lanes[lane] = LaneState {
            qos,
            deficit: deficit.clamp(-w.saturating_mul(2), w.saturating_mul(2)),
            live: true,
            adaptive_eps: None,
        };
    }

    /// Whether `lane` is currently schedulable (not retired).
    // LINT-ALLOW(lane ids are issued by add_lane; callers pass back what we issued)
    pub fn is_live(&self, lane: usize) -> bool {
        self.lanes[lane].live
    }

    /// Number of live (non-retired) lanes.
    pub fn live_lanes(&self) -> usize {
        self.lanes.iter().filter(|l| l.live).count()
    }

    // LINT-ALLOW(lane ids are issued by add_lane; callers pass back what we issued)
    pub fn qos(&self, lane: usize) -> LaneQos {
        self.lanes[lane].qos
    }

    /// `lane`'s current WDRR deficit in [`CHARGE_UNIT`] fixed point
    /// (negative = rider debt). Observability read (ADR-006): published
    /// as a gauge and stamped on flight-recorder QoS-pick events; the
    /// scheduling path never consults it from outside.
    // LINT-ALLOW(lane ids are issued by add_lane; callers pass back what we issued)
    pub fn deficit(&self, lane: usize) -> i64 {
        self.lanes[lane].deficit
    }

    pub fn len(&self) -> usize {
        self.lanes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lanes.is_empty()
    }

    pub(crate) fn cursor(&self) -> usize {
        self.cursor
    }

    /// Advance the fair cursor past `lane` without a deficit charge
    /// (used by drain paths that bypass round-readiness).
    pub(crate) fn rotate_after(&mut self, lane: usize) {
        self.cursor = (lane + 1) % self.lanes.len().max(1);
    }

    /// Pick the next lane to dispatch, or `None` when nothing is due.
    /// Pure: charging happens in [`QosScheduler::commit`], so readiness
    /// probes can call this from `&self` without perturbing the WDRR
    /// state.
    // LINT-ALLOW(select iterates 0..lanes.len() over the scheduler's own tables)
    pub fn select(&self, snap: &dyn Fn(usize) -> LaneSnapshot) -> Option<Pick> {
        let n = self.lanes.len();
        if n == 0 {
            return None;
        }
        // tier 1: SLO boost — least slack wins, cursor order breaks ties
        let mut urgent: Option<(usize, Duration)> = None;
        for k in 0..n {
            let i = (self.cursor + k) % n;
            if !self.lanes[i].live {
                continue;
            }
            let s = snap(i);
            if s.pending == 0 {
                continue;
            }
            let Some(wait) = s.oldest_wait else { continue };
            let slo = self.lanes[i].qos.slo;
            if wait >= slo.saturating_sub(self.lane_boost_margin(i)) {
                let slack = slo.saturating_sub(wait);
                let better = match urgent {
                    None => true,
                    Some((_, best)) => slack < best,
                };
                if better {
                    urgent = Some((i, slack));
                }
            }
        }
        if let Some((lane, _)) = urgent {
            return Some(Pick { lane, urgent: true, replenish: 0 });
        }
        // tier 2: WDRR over round-ready lanes — a lane is pickable when
        // it can afford a whole round (fractional remainders and rider
        // debt keep it waiting for a replenish)
        let mut any_ready = false;
        for k in 0..n {
            let i = (self.cursor + k) % n;
            if !self.lanes[i].live {
                continue;
            }
            let s = snap(i);
            if !s.ready {
                continue;
            }
            any_ready = true;
            if self.lanes[i].deficit >= CHARGE_UNIT {
                return Some(Pick { lane: i, urgent: false, replenish: 0 });
            }
        }
        if any_ready {
            // every ready lane is out of credit: replenish cycles until
            // the first ready lane (from the cursor) that can afford a
            // whole round. One cycle suffices for any debt-free lane
            // (weight >= 1 grants >= one round credit); rider debt is
            // floored at two cycles' worth, so three cycles always
            // surface a pick.
            for cycles in 1..=3u8 {
                for k in 0..n {
                    let i = (self.cursor + k) % n;
                    if !self.lanes[i].live {
                        continue;
                    }
                    let after = self.lanes[i].deficit
                        + cycles as i64 * self.lanes[i].qos.weight as i64 * CHARGE_UNIT;
                    if snap(i).ready && after >= CHARGE_UNIT {
                        return Some(Pick { lane: i, urgent: false, replenish: cycles });
                    }
                }
            }
        }
        None
    }

    /// Charge a dispatched round to **every lane it served**: apply the
    /// replenish cycle the pick assumed (if any), debit each
    /// [`LaneCharge`] in proportion to the slots that lane consumed,
    /// advance the fair cursor past the pick.
    ///
    /// This is the merged-round fairness fix: a coalesced group round
    /// serves rider lanes beyond the picked one, and before riders were
    /// charged, their banked credit bought them *extra* rounds — a
    /// grouped lane received up to `group_size` times its weighted
    /// share. A rider served beyond its remaining credit goes negative
    /// (debt), bounded at two cycles' worth like the credit cap, and
    /// works the debt off before its next pick.
    // LINT-ALLOW(charges and picks reference lanes the scheduler itself produced)
    pub fn commit_served(
        &mut self,
        pick: &Pick,
        served: &[LaneCharge],
        snap: &dyn Fn(usize) -> LaneSnapshot,
    ) {
        let n = self.lanes.len();
        if pick.replenish > 0 {
            for i in 0..n {
                if !self.lanes[i].live {
                    continue; // retired slots bank nothing
                }
                let w = self.lanes[i].qos.weight as i64 * CHARGE_UNIT;
                // drained lanes lose unspent credit (classic DRR) but
                // keep rider debt; busy lanes bank at most two cycles.
                // Applying `replenish` cycles in one shot matches the
                // cycle-by-cycle form because the cap is monotone.
                //
                // `snap` runs AFTER the dispatch being committed, so a
                // lane this very round served (or picked) may read
                // pending == 0 merely because the round emptied it —
                // it was backlogged at selection time and has earned
                // its replenish; only lanes the round did NOT touch
                // can have been genuinely idle across the pick.
                let self_drained =
                    i == pick.lane || served.iter().any(|c| c.lane == i);
                self.lanes[i].deficit = if snap(i).pending == 0 && !self_drained {
                    self.lanes[i].deficit.min(0)
                } else {
                    (self.lanes[i].deficit + pick.replenish as i64 * w).min(w.saturating_mul(2))
                };
            }
        }
        for c in served {
            if !self.lanes[c.lane].live {
                continue; // defensive: a committed round never serves a retired lane
            }
            let w = self.lanes[c.lane].qos.weight as i64 * CHARGE_UNIT;
            let floor = -w.saturating_mul(2);
            self.lanes[c.lane].deficit =
                (self.lanes[c.lane].deficit - c.debit()).max(floor);
        }
        self.cursor = (pick.lane + 1) % n;
    }

    /// [`QosScheduler::commit_served`] shorthand charging the picked
    /// lane one whole round (the solo-dispatch and failed-round form —
    /// a failed round still burns the pick's credit and advances the
    /// cursor so a persistently failing lane cannot starve the others).
    pub fn commit(&mut self, pick: &Pick, snap: &dyn Fn(usize) -> LaneSnapshot) {
        self.commit_served(pick, &[LaneCharge::full(pick.lane)], snap);
    }

    /// How long until some lane becomes due — `batch_wait(i)` is lane
    /// `i`'s batching deadline (its server's `max_wait`). Returns
    /// `Duration::ZERO` if a lane is due right now, `None` when every
    /// lane is idle. This is the longest a dispatch thread may nap
    /// without idling next to a due round.
    ///
    /// EVERY backlogged lane contributes its batching deadline and its
    /// SLO boost window — including lanes that would only be served as
    /// *riders* of a coalesced group round (a rider's boost window is a
    /// real dispatch trigger: the rider preempts as SLO-urgent the
    /// moment its window opens, so napping past it would trade a
    /// deadline for a sleep). The caller owns lane->group topology;
    /// this scan is deliberately topology-free so no lane class can be
    /// accidentally excluded from the nap cap.
    // LINT-ALLOW(iterates 0..lanes.len() over the scheduler's own tables)
    pub fn next_due_in(
        &self,
        snap: &dyn Fn(usize) -> LaneSnapshot,
        batch_wait: &dyn Fn(usize) -> Duration,
    ) -> Option<Duration> {
        if self.select(snap).is_some() {
            return Some(Duration::ZERO);
        }
        let mut best: Option<Duration> = None;
        for i in 0..self.lanes.len() {
            if !self.lanes[i].live {
                continue;
            }
            let s = snap(i);
            let Some(wait) = s.oldest_wait else { continue };
            let batch_due = batch_wait(i).saturating_sub(wait);
            let slo_due = self.lanes[i]
                .qos
                .slo
                .saturating_sub(self.lane_boost_margin(i))
                .saturating_sub(wait);
            let due = batch_due.min(slo_due);
            best = Some(match best {
                Some(b) => b.min(due),
                None => due,
            });
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backlogged(n: usize) -> impl Fn(usize) -> LaneSnapshot {
        move |i: usize| {
            assert!(i < n);
            LaneSnapshot { ready: true, pending: 8, oldest_wait: Some(Duration::ZERO) }
        }
    }

    fn dispatch_sequence(
        sched: &mut QosScheduler,
        snap: &dyn Fn(usize) -> LaneSnapshot,
        rounds: usize,
    ) -> Vec<usize> {
        (0..rounds)
            .map(|_| {
                let pick = sched.select(snap).expect("backlogged lanes must be schedulable");
                sched.commit(&pick, snap);
                pick.lane
            })
            .collect()
    }

    #[test]
    fn equal_weights_alternate_like_plain_round_robin() {
        let mut s = QosScheduler::new(QosScheduler::DEFAULT_BOOST_MARGIN);
        s.add_lane(LaneQos::default());
        s.add_lane(LaneQos::default());
        let order = dispatch_sequence(&mut s, &backlogged(2), 6);
        assert_eq!(order, vec![0, 1, 0, 1, 0, 1]);
    }

    #[test]
    fn three_to_one_weights_give_three_to_one_rounds() {
        let mut s = QosScheduler::new(QosScheduler::DEFAULT_BOOST_MARGIN);
        s.add_lane(LaneQos::new(3, Duration::from_secs(3600)));
        s.add_lane(LaneQos::new(1, Duration::from_secs(3600)));
        let order = dispatch_sequence(&mut s, &backlogged(2), 400);
        let a = order.iter().filter(|&&l| l == 0).count();
        let b = order.len() - a;
        assert_eq!(a, 300, "weight-3 lane must get 3/4 of the rounds, got {a}/{}", order.len());
        assert_eq!(b, 100);
    }

    #[test]
    fn slo_boost_preempts_wdrr_order() {
        let mut s = QosScheduler::new(Duration::from_millis(1));
        s.add_lane(LaneQos::new(8, Duration::from_secs(3600)));
        s.add_lane(LaneQos::new(1, Duration::from_millis(10)));
        // lane 1 is NOT round-ready (partial round) but its oldest
        // request is within eps of the 10ms SLO -> it preempts lane 0
        let snap = |i: usize| {
            if i == 0 {
                LaneSnapshot {
                    ready: true,
                    pending: 8,
                    oldest_wait: Some(Duration::from_millis(1)),
                }
            } else {
                LaneSnapshot {
                    ready: false,
                    pending: 1,
                    oldest_wait: Some(Duration::from_micros(9500)),
                }
            }
        };
        let pick = s.select(&snap).unwrap();
        assert_eq!(pick.lane, 1);
        assert!(pick.urgent);
    }

    #[test]
    fn idle_lanes_do_not_bank_unbounded_credit() {
        let mut s = QosScheduler::new(QosScheduler::DEFAULT_BOOST_MARGIN);
        s.add_lane(LaneQos::new(1, Duration::from_secs(3600)));
        s.add_lane(LaneQos::new(1, Duration::from_secs(3600)));
        // lane 1 idle through many replenish cycles
        let only0 = |i: usize| LaneSnapshot {
            ready: i == 0,
            pending: if i == 0 { 4 } else { 0 },
            oldest_wait: if i == 0 { Some(Duration::ZERO) } else { None },
        };
        for _ in 0..50 {
            let pick = s.select(&only0).unwrap();
            assert_eq!(pick.lane, 0);
            s.commit(&pick, &only0);
        }
        // when lane 1 wakes, it gets its fair share, not 50 banked rounds
        let order = dispatch_sequence(&mut s, &backlogged(2), 8);
        let ones = order.iter().filter(|&&l| l == 1).count();
        assert!(
            (3..=5).contains(&ones),
            "woken lane must get ~half the rounds, got {ones}/8 ({order:?})"
        );
    }

    #[test]
    fn zero_boost_margin_never_pads_early() {
        // REGRESSION: ε used to be fixed for every lane at scheduler
        // construction; now it is per-lane, and ZERO must mean "boost
        // exactly at the deadline, never before" — no early padded
        // dispatch for a lane that is within the old default 1ms window
        let mut s = QosScheduler::new(QosScheduler::DEFAULT_BOOST_MARGIN);
        let slo = Duration::from_millis(50);
        s.add_lane(LaneQos::new(1, slo).with_boost_margin(Duration::ZERO));
        let at = |wait: Duration| {
            move |_: usize| LaneSnapshot { ready: false, pending: 1, oldest_wait: Some(wait) }
        };
        // inside the scheduler-default window but before the SLO: a
        // zero-margin lane must NOT be selected (the default-ε scheduler
        // would have padded early here)
        assert!(
            s.select(&at(slo - Duration::from_micros(500))).is_none(),
            "zero-margin lane padded early"
        );
        // exactly at (and past) the SLO it boosts
        let pick = s.select(&at(slo)).expect("deadline reached must boost");
        assert!(pick.urgent);

        // and the per-lane margin can also WIDEN the window past the
        // scheduler default — plumbed per lane, not per scheduler
        let mut s = QosScheduler::new(Duration::ZERO);
        s.add_lane(LaneQos::new(1, slo).with_boost_margin(Duration::from_millis(20)));
        assert_eq!(s.lane_boost_margin(0), Duration::from_millis(20));
        let pick = s.select(&at(slo - Duration::from_millis(10))).unwrap();
        assert!(pick.urgent, "20ms margin must boost 10ms before the SLO");
    }

    #[test]
    fn adaptive_margin_resolution_order_is_pin_adaptive_default() {
        // ADR-007: pin (`with_boost_margin`) > adaptive > scheduler
        // default, and the adaptive slot is live — it both widens the
        // boost window (select) and clears on lane retirement.
        let slo = Duration::from_millis(50);
        let mut s = QosScheduler::new(Duration::from_millis(1));
        s.add_lane(LaneQos::new(1, slo)); // unpinned: adaptive applies
        s.add_lane(LaneQos::new(1, slo).with_boost_margin(Duration::from_millis(2))); // pinned

        // before any observation, both resolve statically
        assert_eq!(s.lane_boost_margin(0), Duration::from_millis(1));
        assert_eq!(s.lane_boost_margin(1), Duration::from_millis(2));

        s.set_adaptive_margin(0, Some(Duration::from_millis(10)));
        s.set_adaptive_margin(1, Some(Duration::from_millis(10)));
        assert_eq!(s.lane_boost_margin(0), Duration::from_millis(10), "adaptive beats default");
        assert_eq!(s.lane_boost_margin(1), Duration::from_millis(2), "pin beats adaptive");
        assert_eq!(s.adaptive_margin(0), Some(Duration::from_millis(10)));

        // the widened window is a real dispatch trigger: 8ms from the
        // SLO is outside the 1ms default but inside the 10ms adaptive ε
        let at = |wait: Duration| {
            move |i: usize| LaneSnapshot {
                ready: false,
                pending: if i == 0 { 1 } else { 0 },
                oldest_wait: if i == 0 { Some(wait) } else { None },
            }
        };
        let pick = s.select(&at(slo - Duration::from_millis(8))).expect("adaptive ε boosts");
        assert_eq!(pick.lane, 0);
        assert!(pick.urgent);

        // retirement clears the estimate; a new tenant starts static
        s.remove_lane(0);
        s.restore_lane(0, LaneQos::new(1, slo), 0);
        assert_eq!(s.adaptive_margin(0), None, "retired tenant's tail must not leak");
        assert_eq!(s.lane_boost_margin(0), Duration::from_millis(1));
    }

    #[test]
    fn empty_or_idle_schedulers_select_nothing() {
        let s = QosScheduler::new(QosScheduler::DEFAULT_BOOST_MARGIN);
        assert!(s.select(&|_| unreachable!()).is_none());

        let mut s = QosScheduler::new(QosScheduler::DEFAULT_BOOST_MARGIN);
        s.add_lane(LaneQos::default());
        let idle = |_: usize| LaneSnapshot { ready: false, pending: 0, oldest_wait: None };
        assert!(s.select(&idle).is_none());
    }

    #[test]
    fn rider_charges_split_service_to_weighted_shares() {
        // REGRESSION (merged-round fairness): lane 0 standalone with
        // weight 3; lanes 1 and 2 form a coalesce group with weight 1
        // each, so every round picked on one of them also serves the
        // other as a rider. Charging ONLY the pick (the old behavior)
        // let each member's credit buy a round that served both — the
        // grouped lanes received double their weighted share. With
        // commit_served charging every served lane, rounds-served per
        // lane must track 3:1:1.
        let snap = backlogged(3);
        let mut s = QosScheduler::new(QosScheduler::DEFAULT_BOOST_MARGIN);
        s.add_lane(LaneQos::new(3, Duration::from_secs(3600)));
        s.add_lane(LaneQos::new(1, Duration::from_secs(3600)));
        s.add_lane(LaneQos::new(1, Duration::from_secs(3600)));

        let mut served = [0u64; 3];
        for _ in 0..500 {
            let pick = s.select(&snap).expect("backlogged lanes must be schedulable");
            match pick.lane {
                0 => {
                    served[0] += 1;
                    let charge = [LaneCharge { lane: 0, slots: 4, round_slots: 4 }];
                    s.commit_served(&pick, &charge, &snap);
                }
                l => {
                    // a merged round: the pick AND the other group
                    // member are served a full round of slots each
                    let rider = if l == 1 { 2 } else { 1 };
                    served[l] += 1;
                    served[rider] += 1;
                    s.commit_served(
                        &pick,
                        &[
                            LaneCharge { lane: l, slots: 4, round_slots: 4 },
                            LaneCharge { lane: rider, slots: 4, round_slots: 4 },
                        ],
                        &snap,
                    );
                }
            }
        }
        let total: u64 = served.iter().sum();
        let share0 = served[0] as f64 / total as f64;
        // weights 3:1:1 -> lane 0 should receive 3/5 of served rounds
        assert!(
            (share0 - 0.6).abs() < 0.03,
            "standalone weight-3 lane must hold a 0.6 share, got {share0:.3} ({served:?})"
        );
        let drift = (served[1] as f64 - served[2] as f64).abs() / total as f64;
        assert!(drift < 0.03, "group members with equal weight drifted: {served:?}");
    }

    #[test]
    fn partial_rounds_charge_fractionally() {
        // a lane whose rounds are half-occupied pays half a credit per
        // round: over a cycle it affords twice the rounds of an
        // identically weighted full-round lane (equal SLOT shares)
        let snap = backlogged(2);
        let mut s = QosScheduler::new(QosScheduler::DEFAULT_BOOST_MARGIN);
        s.add_lane(LaneQos::new(1, Duration::from_secs(3600)));
        s.add_lane(LaneQos::new(1, Duration::from_secs(3600)));
        let mut rounds = [0u64; 2];
        for _ in 0..300 {
            let pick = s.select(&snap).unwrap();
            rounds[pick.lane] += 1;
            let slots = if pick.lane == 0 { 2 } else { 4 }; // lane 0 half-full
            s.commit_served(
                &pick,
                &[LaneCharge { lane: pick.lane, slots, round_slots: 4 }],
                &snap,
            );
        }
        let ratio = rounds[0] as f64 / rounds[1] as f64;
        assert!(
            (ratio - 2.0).abs() < 0.2,
            "half-occupied rounds must come twice as often, got {ratio:.2} ({rounds:?})"
        );
    }

    #[test]
    fn rider_debt_is_bounded_and_paid_off() {
        // a zero-credit rider served by merged rounds goes into debt,
        // but never beyond two cycles' worth — and a debt-laden lane is
        // not pickable until replenishes cover the debt
        let snap = backlogged(2);
        let mut s = QosScheduler::new(QosScheduler::DEFAULT_BOOST_MARGIN);
        s.add_lane(LaneQos::new(1, Duration::from_secs(3600)));
        s.add_lane(LaneQos::new(1, Duration::from_secs(3600)));
        // hammer lane 1 with rider charges far beyond its credit
        for _ in 0..10 {
            let pick = s.select(&snap).unwrap();
            s.commit_served(
                &pick,
                &[
                    LaneCharge::full(pick.lane),
                    LaneCharge { lane: 1, slots: 4, round_slots: 4 },
                ],
                &snap,
            );
        }
        // debt is capped at 2 cycles (weight 1), so at most two extra
        // replenishes are needed before lane 1 is schedulable again;
        // the WDRR order must recover rather than starve lane 1 forever
        let order = dispatch_sequence(&mut s, &snap, 12);
        assert!(
            order.iter().filter(|&&l| l == 1).count() >= 3,
            "debt-bounded rider must recover its share, got {order:?}"
        );
    }

    #[test]
    fn self_drained_pick_keeps_its_replenish_credit() {
        // REGRESSION: commit_served runs after the dispatch, so the
        // replenish snapshot can see the picked lane's queue EMPTY only
        // because the committed round drained it. That lane earned its
        // replenish at selection time — resetting it like an idle lane
        // and then debiting the round would manufacture spurious debt
        // for every bursty (drain-to-empty) lane.
        let mut s = QosScheduler::new(QosScheduler::DEFAULT_BOOST_MARGIN);
        s.add_lane(LaneQos::new(1, Duration::from_secs(3600)));
        s.add_lane(LaneQos::new(1, Duration::from_secs(3600)));
        let at_select = |i: usize| LaneSnapshot {
            ready: i == 0,
            pending: if i == 0 { 1 } else { 0 },
            oldest_wait: if i == 0 { Some(Duration::ZERO) } else { None },
        };
        let pick = s.select(&at_select).expect("lane 0 is ready");
        assert_eq!(pick.lane, 0);
        // the round drains lane 0: the commit-time snapshot is empty
        let after = |_: usize| LaneSnapshot { ready: false, pending: 0, oldest_wait: None };
        s.commit(&pick, &after);
        // a fresh burst arrives: the lane must be dispatchable on ONE
        // replenish cycle, exactly as before the burst (no carried debt)
        let pick = s.select(&at_select).expect("new burst is schedulable");
        assert_eq!(pick.lane, 0);
        assert_eq!(pick.replenish, 1, "self-drained lane must not carry debt");
    }

    #[test]
    fn removed_lane_state_fully_retires() {
        // REGRESSION (elastic topology, satellite of ADR-005): removing
        // a lane must clear its deficit/debt/boost state completely. A
        // later lane REUSING the id starts from zero credit — one
        // replenish cycle away from dispatch, exactly like a brand-new
        // lane — never from the previous tenant's inherited rider debt.
        // (Companion to rider_charges_split_service_to_weighted_shares,
        // the PR 5 rider-charging regression.)
        let snap = backlogged(2);
        let mut s = QosScheduler::new(QosScheduler::DEFAULT_BOOST_MARGIN);
        s.add_lane(LaneQos::new(1, Duration::from_secs(3600)));
        s.add_lane(LaneQos::new(1, Duration::from_secs(3600)));
        // bury lane 1 in rider debt (served by merged rounds it never
        // had credit for), down to the -2-cycle floor
        for _ in 0..10 {
            let pick = s.select(&snap).unwrap();
            s.commit_served(
                &pick,
                &[
                    LaneCharge::full(pick.lane),
                    LaneCharge { lane: 1, slots: 4, round_slots: 4 },
                ],
                &snap,
            );
        }
        let carried = s.remove_lane(1);
        assert!(carried < 0, "the hammered rider must retire in debt, got {carried}");
        assert!(!s.is_live(1));
        assert_eq!(s.live_lanes(), 1);
        // while retired the slot is unschedulable even though its
        // snapshot claims a backlog
        for _ in 0..4 {
            let pick = s.select(&snap).unwrap();
            assert_eq!(pick.lane, 0, "retired lane must never be selected");
            s.commit(&pick, &snap);
        }
        // a new tenant reuses the id: zero inherited debt — its very
        // first pick needs only the single replenish a fresh lane needs
        s.restore_lane(1, LaneQos::new(1, Duration::from_secs(3600)), 0);
        let order = dispatch_sequence(&mut s, &snap, 8);
        let ones = order.iter().filter(|&&l| l == 1).count();
        assert!(
            (3..=5).contains(&ones),
            "reused lane id must get a fresh fair share, got {ones}/8 ({order:?})"
        );
    }

    #[test]
    fn carried_deficit_holds_shares_across_migration() {
        // cross-partition WDRR (ADR-005, folds the ADR-003 residual):
        // a lane migrated between partitions carries its deficit, so a
        // debt-laden lane cannot launder its debt by moving. Partition
        // P: lane 0 rides merged rounds into debt; migrate it to
        // partition Q (a fresh scheduler) carrying the returned deficit.
        // In Q, the fresh sibling must win the first TWO rounds while
        // the migrant pays off its two-cycle debt; with the carry
        // dropped (deficit 0), the migrant — sitting first in cursor
        // order — would win round one instead.
        let snap = backlogged(2);
        let mut p = QosScheduler::new(QosScheduler::DEFAULT_BOOST_MARGIN);
        p.add_lane(LaneQos::new(1, Duration::from_secs(3600)));
        p.add_lane(LaneQos::new(1, Duration::from_secs(3600)));
        for _ in 0..10 {
            let pick = p.select(&snap).unwrap();
            s_commit_with_rider(&mut p, &pick, 0, &snap);
        }
        let carried = p.remove_lane(0);
        assert_eq!(carried, -2 * CHARGE_UNIT, "weight-1 debt floors at two cycles");

        let mut q = QosScheduler::new(QosScheduler::DEFAULT_BOOST_MARGIN);
        q.add_lane_carrying(LaneQos::new(1, Duration::from_secs(3600)), carried);
        q.add_lane(LaneQos::new(1, Duration::from_secs(3600)));
        let order = dispatch_sequence(&mut q, &snap, 6);
        assert_eq!(
            &order[..2],
            &[1, 1],
            "migrant must pay its carried debt before its first pick, got {order:?}"
        );
        assert!(
            order[2..].contains(&0),
            "debt paid, the migrant recovers its share: {order:?}"
        );

        // control: the same migration WITHOUT the carry — the migrant
        // jumps straight back into the rotation (the unfair behavior
        // the carry exists to prevent)
        let mut q0 = QosScheduler::new(QosScheduler::DEFAULT_BOOST_MARGIN);
        q0.add_lane(LaneQos::new(1, Duration::from_secs(3600)));
        q0.add_lane(LaneQos::new(1, Duration::from_secs(3600)));
        assert_eq!(dispatch_sequence(&mut q0, &snap, 1), vec![0]);
    }

    /// Commit `pick` charging both the pick and `rider` a full round
    /// (the merged-round shape the migration test hammers with).
    fn s_commit_with_rider(
        s: &mut QosScheduler,
        pick: &Pick,
        rider: usize,
        snap: &dyn Fn(usize) -> LaneSnapshot,
    ) {
        s.commit_served(
            pick,
            &[
                LaneCharge::full(pick.lane),
                LaneCharge { lane: rider, slots: 4, round_slots: 4 },
            ],
            snap,
        );
    }

    #[test]
    fn next_due_in_considers_rider_boost_windows() {
        // REGRESSION (nap cap): lane 0 is a group member with plenty of
        // deadline slack; lane 1 — servable only as a rider of lane 0's
        // group — is near ITS boost window. The nap cap must be bounded
        // by the rider's window, not just the (far) pick candidates'.
        let slo = Duration::from_millis(20);
        let mut s = QosScheduler::new(Duration::from_millis(1));
        s.add_lane(LaneQos::new(4, Duration::from_secs(3600)));
        s.add_lane(LaneQos::new(1, slo));
        let snap = |i: usize| LaneSnapshot {
            ready: false,
            pending: 1,
            oldest_wait: Some(if i == 0 {
                Duration::from_millis(1)
            } else {
                slo - Duration::from_millis(5) // 5ms from the SLO, 4ms from boost
            }),
        };
        let batch = |_: usize| Duration::from_secs(3600);
        let due = s.next_due_in(&snap, &batch).expect("backlogged lanes have a due time");
        assert!(
            due <= Duration::from_millis(4),
            "nap must not run past the rider's boost window, got {due:?}"
        );
        assert!(due > Duration::ZERO, "nothing is due yet");

        // inside the boost window the scheduler is due immediately
        let snap_hot = |i: usize| LaneSnapshot {
            ready: false,
            pending: 1,
            oldest_wait: Some(if i == 0 { Duration::from_millis(1) } else { slo }),
        };
        assert_eq!(s.next_due_in(&snap_hot, &batch), Some(Duration::ZERO));

        // all idle -> no deadline at all
        let idle = |_: usize| LaneSnapshot { ready: false, pending: 0, oldest_wait: None };
        assert_eq!(s.next_due_in(&idle, &batch), None);
    }
}
