//! Per-lane QoS: weighted deficit round-robin with an SLO-deadline
//! boost. Replaces `MultiServer`'s pure round-robin `ready_lane` scan.
//!
//! Each lane carries a [`LaneQos`]: a WDRR `weight` (its share of
//! dispatched rounds when several lanes are backlogged) and an `slo`
//! (the end-to-end latency target its requests are supposed to meet).
//!
//! Scheduling is two-tier:
//! 1. **SLO boost** — a lane whose oldest queued request has waited to
//!    within ε of its `slo` preempts the WDRR order outright, even if
//!    its round is not yet due (the dispatch pads the missing slots):
//!    better a padded round now than a full round after the deadline.
//!    Among urgent lanes, least slack wins. ε is per lane
//!    ([`LaneQos::with_boost_margin`]), defaulting to the scheduler-wide
//!    [`QosScheduler::boost_margin`]; a zero-margin lane never pads
//!    early.
//! 2. **WDRR** — otherwise, lanes whose rounds are due are served in
//!    deficit round-robin: every replenish cycle grants each backlogged
//!    lane `weight` round credits (capped at two cycles so an idle
//!    spell cannot bank unbounded priority; a drained lane's credit
//!    resets, per classic DRR); the scan starts after the last
//!    dispatched lane, so equal weights degenerate to exactly the old
//!    fair round-robin.
//!
//! The scheduler is deliberately decoupled from `Server` internals: it
//! sees lanes only through [`LaneSnapshot`]s produced by a caller-owned
//! closure, so it is unit-testable with plain structs and usable by any
//! front end. [`QosScheduler::select`] is pure (usable from `&self`
//! readiness probes); [`QosScheduler::commit`] applies the deficit
//! charge and cursor advance for a pick that was actually dispatched.

use std::time::Duration;

/// Per-lane scheduling contract.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LaneQos {
    /// WDRR share: rounds granted per replenish cycle (clamped >= 1).
    pub weight: u32,
    /// End-to-end latency target for the lane's requests. Lanes that
    /// get within their boost margin of it preempt WDRR.
    pub slo: Duration,
    /// Per-lane SLO boost margin ε: how close to `slo` the lane's
    /// oldest wait may get before the lane preempts the WDRR order
    /// (dispatching a padded round early). `None` inherits the
    /// scheduler-wide default ([`QosScheduler::boost_margin`]); an
    /// explicit `Duration::ZERO` means the lane never pads early — it
    /// boosts only once the deadline has actually been reached.
    pub boost_margin: Option<Duration>,
}

impl LaneQos {
    pub fn new(weight: u32, slo: Duration) -> LaneQos {
        LaneQos { weight, slo, boost_margin: None }
    }

    /// Set this lane's own SLO boost margin ε instead of inheriting the
    /// scheduler default. Plumbed uniformly through every
    /// `MultiServer::add_lane_qos` path — before this, ε was fixed for
    /// ALL lanes at `MultiServer` construction, so a single latency
    /// tier's margin was un-tunable per lane.
    pub fn with_boost_margin(mut self, eps: Duration) -> LaneQos {
        self.boost_margin = Some(eps);
        self
    }
}

impl Default for LaneQos {
    /// Weight 1 and an SLO far beyond any real deadline: scheduling
    /// degenerates to the plain fair round-robin `MultiServer` had.
    fn default() -> LaneQos {
        LaneQos { weight: 1, slo: Duration::from_secs(3600), boost_margin: None }
    }
}

/// What the scheduler sees of one lane at selection time.
#[derive(Debug, Clone, Copy)]
pub struct LaneSnapshot {
    /// the lane's round is due (full, or past its batching deadline)
    pub ready: bool,
    /// queued requests
    pub pending: usize,
    /// how long the lane's oldest queued request has waited
    pub oldest_wait: Option<Duration>,
}

/// A scheduling decision from [`QosScheduler::select`].
#[derive(Debug, Clone, Copy)]
pub struct Pick {
    pub lane: usize,
    /// chosen by the SLO boost (the round may need padding)
    pub urgent: bool,
    /// selection assumed a deficit replenish; `commit` applies it
    replenish: bool,
}

struct LaneState {
    qos: LaneQos,
    /// WDRR round credits remaining this cycle
    deficit: u64,
}

/// Weighted-deficit round-robin + SLO-boost lane scheduler.
pub struct QosScheduler {
    lanes: Vec<LaneState>,
    /// the lane AFTER the last dispatched one is scanned first
    cursor: usize,
    /// ε: how close to its SLO a lane's oldest wait may get before the
    /// lane preempts the WDRR order
    eps: Duration,
}

impl QosScheduler {
    pub const DEFAULT_BOOST_MARGIN: Duration = Duration::from_millis(1);

    pub fn new(boost_margin: Duration) -> QosScheduler {
        QosScheduler { lanes: Vec::new(), cursor: 0, eps: boost_margin }
    }

    /// The scheduler-wide default ε (lanes without an explicit
    /// [`LaneQos::boost_margin`] inherit it).
    pub fn boost_margin(&self) -> Duration {
        self.eps
    }

    /// The effective ε for one lane: its own margin if set, else the
    /// scheduler default. Deadline math (`MultiServer::next_due_in`)
    /// must use this, not [`QosScheduler::boost_margin`], or a per-lane
    /// margin would nap the dispatch thread past its boost window.
    pub fn lane_boost_margin(&self, lane: usize) -> Duration {
        self.lanes[lane].qos.boost_margin.unwrap_or(self.eps)
    }

    /// Register a lane; returns its index. Weight 0 is clamped to 1 (a
    /// zero-share lane would starve forever).
    pub fn add_lane(&mut self, qos: LaneQos) -> usize {
        let qos = LaneQos { weight: qos.weight.max(1), ..qos };
        self.lanes.push(LaneState { qos, deficit: 0 });
        self.lanes.len() - 1
    }

    pub fn qos(&self, lane: usize) -> LaneQos {
        self.lanes[lane].qos
    }

    pub fn len(&self) -> usize {
        self.lanes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lanes.is_empty()
    }

    pub(crate) fn cursor(&self) -> usize {
        self.cursor
    }

    /// Advance the fair cursor past `lane` without a deficit charge
    /// (used by drain paths that bypass round-readiness).
    pub(crate) fn rotate_after(&mut self, lane: usize) {
        self.cursor = (lane + 1) % self.lanes.len().max(1);
    }

    /// Pick the next lane to dispatch, or `None` when nothing is due.
    /// Pure: charging happens in [`QosScheduler::commit`], so readiness
    /// probes can call this from `&self` without perturbing the WDRR
    /// state.
    pub fn select(&self, snap: &dyn Fn(usize) -> LaneSnapshot) -> Option<Pick> {
        let n = self.lanes.len();
        if n == 0 {
            return None;
        }
        // tier 1: SLO boost — least slack wins, cursor order breaks ties
        let mut urgent: Option<(usize, Duration)> = None;
        for k in 0..n {
            let i = (self.cursor + k) % n;
            let s = snap(i);
            if s.pending == 0 {
                continue;
            }
            let Some(wait) = s.oldest_wait else { continue };
            let slo = self.lanes[i].qos.slo;
            if wait >= slo.saturating_sub(self.lane_boost_margin(i)) {
                let slack = slo.saturating_sub(wait);
                let better = match urgent {
                    None => true,
                    Some((_, best)) => slack < best,
                };
                if better {
                    urgent = Some((i, slack));
                }
            }
        }
        if let Some((lane, _)) = urgent {
            return Some(Pick { lane, urgent: true, replenish: false });
        }
        // tier 2: WDRR over round-ready lanes
        let mut any_ready = false;
        for k in 0..n {
            let i = (self.cursor + k) % n;
            let s = snap(i);
            if !s.ready {
                continue;
            }
            any_ready = true;
            if self.lanes[i].deficit >= 1 {
                return Some(Pick { lane: i, urgent: false, replenish: false });
            }
        }
        if any_ready {
            // every ready lane is out of credit: after a replenish the
            // first ready lane from the cursor has weight >= 1 credits
            for k in 0..n {
                let i = (self.cursor + k) % n;
                if snap(i).ready {
                    return Some(Pick { lane: i, urgent: false, replenish: true });
                }
            }
        }
        None
    }

    /// Charge a dispatched pick: apply the replenish cycle it assumed
    /// (if any), deduct one round credit, advance the fair cursor.
    pub fn commit(&mut self, pick: &Pick, snap: &dyn Fn(usize) -> LaneSnapshot) {
        let n = self.lanes.len();
        if pick.replenish {
            for i in 0..n {
                let w = self.lanes[i].qos.weight as u64;
                // drained lanes lose their credit (classic DRR); busy
                // lanes bank at most two cycles' worth
                self.lanes[i].deficit = if snap(i).pending == 0 {
                    0
                } else {
                    (self.lanes[i].deficit + w).min(w.saturating_mul(2))
                };
            }
        }
        self.lanes[pick.lane].deficit = self.lanes[pick.lane].deficit.saturating_sub(1);
        self.cursor = (pick.lane + 1) % n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backlogged(n: usize) -> impl Fn(usize) -> LaneSnapshot {
        move |i: usize| {
            assert!(i < n);
            LaneSnapshot { ready: true, pending: 8, oldest_wait: Some(Duration::ZERO) }
        }
    }

    fn dispatch_sequence(
        sched: &mut QosScheduler,
        snap: &dyn Fn(usize) -> LaneSnapshot,
        rounds: usize,
    ) -> Vec<usize> {
        (0..rounds)
            .map(|_| {
                let pick = sched.select(snap).expect("backlogged lanes must be schedulable");
                sched.commit(&pick, snap);
                pick.lane
            })
            .collect()
    }

    #[test]
    fn equal_weights_alternate_like_plain_round_robin() {
        let mut s = QosScheduler::new(QosScheduler::DEFAULT_BOOST_MARGIN);
        s.add_lane(LaneQos::default());
        s.add_lane(LaneQos::default());
        let order = dispatch_sequence(&mut s, &backlogged(2), 6);
        assert_eq!(order, vec![0, 1, 0, 1, 0, 1]);
    }

    #[test]
    fn three_to_one_weights_give_three_to_one_rounds() {
        let mut s = QosScheduler::new(QosScheduler::DEFAULT_BOOST_MARGIN);
        s.add_lane(LaneQos::new(3, Duration::from_secs(3600)));
        s.add_lane(LaneQos::new(1, Duration::from_secs(3600)));
        let order = dispatch_sequence(&mut s, &backlogged(2), 400);
        let a = order.iter().filter(|&&l| l == 0).count();
        let b = order.len() - a;
        assert_eq!(a, 300, "weight-3 lane must get 3/4 of the rounds, got {a}/{}", order.len());
        assert_eq!(b, 100);
    }

    #[test]
    fn slo_boost_preempts_wdrr_order() {
        let mut s = QosScheduler::new(Duration::from_millis(1));
        s.add_lane(LaneQos::new(8, Duration::from_secs(3600)));
        s.add_lane(LaneQos::new(1, Duration::from_millis(10)));
        // lane 1 is NOT round-ready (partial round) but its oldest
        // request is within eps of the 10ms SLO -> it preempts lane 0
        let snap = |i: usize| {
            if i == 0 {
                LaneSnapshot {
                    ready: true,
                    pending: 8,
                    oldest_wait: Some(Duration::from_millis(1)),
                }
            } else {
                LaneSnapshot {
                    ready: false,
                    pending: 1,
                    oldest_wait: Some(Duration::from_micros(9500)),
                }
            }
        };
        let pick = s.select(&snap).unwrap();
        assert_eq!(pick.lane, 1);
        assert!(pick.urgent);
    }

    #[test]
    fn idle_lanes_do_not_bank_unbounded_credit() {
        let mut s = QosScheduler::new(QosScheduler::DEFAULT_BOOST_MARGIN);
        s.add_lane(LaneQos::new(1, Duration::from_secs(3600)));
        s.add_lane(LaneQos::new(1, Duration::from_secs(3600)));
        // lane 1 idle through many replenish cycles
        let only0 = |i: usize| LaneSnapshot {
            ready: i == 0,
            pending: if i == 0 { 4 } else { 0 },
            oldest_wait: if i == 0 { Some(Duration::ZERO) } else { None },
        };
        for _ in 0..50 {
            let pick = s.select(&only0).unwrap();
            assert_eq!(pick.lane, 0);
            s.commit(&pick, &only0);
        }
        // when lane 1 wakes, it gets its fair share, not 50 banked rounds
        let order = dispatch_sequence(&mut s, &backlogged(2), 8);
        let ones = order.iter().filter(|&&l| l == 1).count();
        assert!(
            (3..=5).contains(&ones),
            "woken lane must get ~half the rounds, got {ones}/8 ({order:?})"
        );
    }

    #[test]
    fn zero_boost_margin_never_pads_early() {
        // REGRESSION: ε used to be fixed for every lane at scheduler
        // construction; now it is per-lane, and ZERO must mean "boost
        // exactly at the deadline, never before" — no early padded
        // dispatch for a lane that is within the old default 1ms window
        let mut s = QosScheduler::new(QosScheduler::DEFAULT_BOOST_MARGIN);
        let slo = Duration::from_millis(50);
        s.add_lane(LaneQos::new(1, slo).with_boost_margin(Duration::ZERO));
        let at = |wait: Duration| {
            move |_: usize| LaneSnapshot { ready: false, pending: 1, oldest_wait: Some(wait) }
        };
        // inside the scheduler-default window but before the SLO: a
        // zero-margin lane must NOT be selected (the default-ε scheduler
        // would have padded early here)
        assert!(
            s.select(&at(slo - Duration::from_micros(500))).is_none(),
            "zero-margin lane padded early"
        );
        // exactly at (and past) the SLO it boosts
        let pick = s.select(&at(slo)).expect("deadline reached must boost");
        assert!(pick.urgent);

        // and the per-lane margin can also WIDEN the window past the
        // scheduler default — plumbed per lane, not per scheduler
        let mut s = QosScheduler::new(Duration::ZERO);
        s.add_lane(LaneQos::new(1, slo).with_boost_margin(Duration::from_millis(20)));
        assert_eq!(s.lane_boost_margin(0), Duration::from_millis(20));
        let pick = s.select(&at(slo - Duration::from_millis(10))).unwrap();
        assert!(pick.urgent, "20ms margin must boost 10ms before the SLO");
    }

    #[test]
    fn empty_or_idle_schedulers_select_nothing() {
        let s = QosScheduler::new(QosScheduler::DEFAULT_BOOST_MARGIN);
        assert!(s.select(&|_| unreachable!()).is_none());

        let mut s = QosScheduler::new(QosScheduler::DEFAULT_BOOST_MARGIN);
        s.add_lane(LaneQos::default());
        let idle = |_: usize| LaneSnapshot { ready: false, pending: 0, oldest_wait: None };
        assert!(s.select(&idle).is_none());
    }
}
