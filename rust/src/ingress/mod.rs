//! Open-loop ingress + SLO-aware QoS scheduling — the serving front
//! door.
//!
//! Everything below `coordinator` assumed a closed loop: the thread
//! that drives rounds also fabricates the traffic, so the merged-round
//! speedups (paper §5) never met real concurrent arrivals. This module
//! decouples request arrival from round dispatch:
//!
//! ```text
//!  clients ── Frame wire format ── Transport (TCP | in-proc chan)
//!      │  serve_conn: reader thread per connection
//!      ▼
//!  IngressBridge (bounded MPSC, mutex+condvar; full => Reject{Busy})
//!      │  run_dispatch: THE dispatch thread (owns MultiServer)
//!      ▼
//!  QosScheduler (WDRR weights + SLO-deadline boost) picks the lane,
//!  responses route back through per-connection reply queues
//! ```
//!
//! - [`frame`] — length-prefixed, fully validated wire format
//!   ([`Frame`]): requests, responses, typed rejections, end-of-stream;
//! - [`transport`] — one [`Transport`] trait, two implementations
//!   ([`TcpTransport`], in-proc [`ChanTransport`]), splittable into
//!   reader/writer halves;
//! - [`bridge`] — the bounded producer→dispatch handoff
//!   ([`IngressBridge`]), per-connection glue ([`serve_conn`]) and the
//!   dispatch loop ([`run_dispatch`]) that admits (re-stamping arrival
//!   at the boundary), dispatches, and routes responses;
//! - [`qos`] — [`LaneQos`] (weight + SLO) and the [`QosScheduler`]
//!   (weighted deficit round-robin with SLO-deadline preemption) that
//!   `MultiServer` now schedules lanes with;
//! - [`loadgen`] — open-loop Poisson / bursty / lane-skewed arrival
//!   generation ([`LoadGen`]) for benches and examples.
//!
//! End-to-end demo: `examples/serve_ingress.rs` (TCP, 4 producers, two
//! QoS classes); measured: `benches/ingress_qos.rs`.

pub mod bridge;
pub mod frame;
pub mod loadgen;
pub mod qos;
pub mod transport;

pub use bridge::{
    run_dispatch, run_dispatch_elastic, run_dispatch_parallel, run_dispatch_parallel_observed,
    serve_conn, ConnHandle, Envelope, IngressBridge, IngressStats, LaneRejects, SubmitError,
};
pub use frame::{Frame, RejectCode};
pub use loadgen::{Arrival, LoadGen, TrafficShape};
pub use qos::{LaneCharge, LaneQos, LaneSnapshot, Pick, QosScheduler, CHARGE_UNIT};
pub use transport::{ChanTransport, FrameQueue, TcpTransport, Transport, TransportRx, TransportTx};
