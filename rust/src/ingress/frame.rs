//! Ingress wire format: length-prefixed frames.
//!
//! Every message on an ingress connection is one frame:
//!
//! ```text
//! [u32 LE payload_len][payload]
//! payload := [u8 tag][tag-specific fields]   (all integers little-endian)
//!   tag 1 Request : u64 id, u32 lane, u32 model_idx,
//!                   u8 rank, rank x u32 dims, n x f32 data
//!   tag 2 Response: u64 id, u32 lane, u32 model_idx, u64 latency_bits,
//!                   u8 rank, rank x u32 dims, n x f32 data
//!   tag 3 Reject  : u64 id, u32 lane, u8 code, u32 msg_len, msg (utf8)
//!   tag 4 Eos     : (empty) — client is done sending; the server keeps
//!                   the connection open until queued responses flush
//!   tag 5 ObsQuery : u64 id — ask the server for an introspection
//!                    snapshot (ADR-006); answered out of band with the
//!                    matching ObsReport
//!   tag 6 ObsReport: u64 id, u32 json_len, json (utf8) — the merged
//!                    metrics / stage histograms / topology / gauges
//!                    snapshot for query `id`
//! ```
//!
//! Decoding is fully validated BEFORE the payload buffer is reserved:
//! the length prefix is capped at [`MAX_FRAME`], and `read_from` then
//! reads only the first [`HEADER_MAX`] payload bytes and cross-checks
//! the length the header itself implies (tag, rank — capped at
//! [`MAX_RANK`] — dims, message length) against the declared prefix.
//! A hostile 64MiB-claiming prefix on a 1-byte frame is rejected after
//! a 64-byte read, not after a 64MiB allocation. The full decode then
//! re-validates everything (dim product must equal the remaining f32
//! count; no trailing bytes), so a malformed frame fails as one `Err`,
//! never as a huge allocation or a panic. `read_from` distinguishes
//! clean EOF at a frame boundary (`Ok(None)`) from a connection dying
//! mid-frame (`Err`).
//!
//! Tensor payload bytes move through the feature-detected wide kernels
//! (`util::simd::extend_f32_le` / `extend_le_f32`) on both encode and
//! decode — on little-endian targets the in-memory f32 bytes are the
//! wire bytes, so both directions are single wide copies.

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

/// Upper bound on one frame's payload (64 MiB) — rejects hostile length
/// prefixes before allocating.
pub const MAX_FRAME: usize = 1 << 26;
/// Upper bound on a payload tensor's rank.
pub const MAX_RANK: usize = 8;
/// Every length-determining header field lives within this many payload
/// bytes (worst case: a Response header with [`MAX_RANK`] dims — 1 tag
/// + 24 fixed + 1 rank + 32 dim bytes), so `read_from` can validate the
/// declared length against the header before allocating the payload.
pub const HEADER_MAX: usize = 64;

const TAG_REQUEST: u8 = 1;
const TAG_RESPONSE: u8 = 2;
const TAG_REJECT: u8 = 3;
const TAG_EOS: u8 = 4;
const TAG_OBS_QUERY: u8 = 5;
const TAG_OBS_REPORT: u8 = 6;

/// Why an ingress request was refused (mirrors `coordinator::server::Admit`
/// plus the bridge- and routing-level causes the wire adds).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectCode {
    /// backpressure: the bridge or the lane queue is full — retry later
    Busy,
    /// malformed request (shape/routing) — never admissible
    Invalid,
    /// the addressed lane does not exist
    NoLane,
    /// the server is shutting down
    Shutdown,
    /// admission control: the lane's projected queue wait already
    /// exceeds its SLO, so serving this request would only produce a
    /// late answer — shed now rather than waste a slot. Unlike `Busy`
    /// (a transient capacity signal: retry soon), `Shed` says the lane
    /// is over its knee: back off harder or try another lane.
    Shed,
}

impl RejectCode {
    fn to_u8(self) -> u8 {
        match self {
            RejectCode::Busy => 1,
            RejectCode::Invalid => 2,
            RejectCode::NoLane => 3,
            RejectCode::Shutdown => 4,
            RejectCode::Shed => 5,
        }
    }

    fn from_u8(b: u8) -> Result<RejectCode> {
        Ok(match b {
            1 => RejectCode::Busy,
            2 => RejectCode::Invalid,
            3 => RejectCode::NoLane,
            4 => RejectCode::Shutdown,
            5 => RejectCode::Shed,
            _ => bail!("bad reject code {b}"),
        })
    }
}

/// One ingress wire message.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// client -> server: one inference request for `lane` / `model_idx`
    Request {
        id: u64,
        lane: u32,
        model_idx: u32,
        shape: Vec<usize>,
        data: Vec<f32>,
    },
    /// server -> client: the completion for request `id`
    Response {
        id: u64,
        lane: u32,
        model_idx: u32,
        /// end-to-end seconds (admission -> completion)
        latency: f64,
        shape: Vec<usize>,
        data: Vec<f32>,
    },
    /// server -> client: request `id` was refused
    Reject {
        id: u64,
        lane: u32,
        code: RejectCode,
        msg: String,
    },
    /// client -> server: end of request stream (graceful half-close)
    Eos,
    /// client -> server: ask for an introspection snapshot (ADR-006).
    /// Answered out of band by the next dispatch-loop poll; responses
    /// and rejects for in-flight requests may interleave before it.
    ObsQuery { id: u64 },
    /// server -> client: the introspection snapshot for query `id` —
    /// one JSON document (merged stats, per-lane stage histograms,
    /// topology epoch, QoS gauges, arena in-flight, recorder state)
    ObsReport { id: u64, json: String },
}

impl Frame {
    pub fn reject(id: u64, lane: u32, code: RejectCode, msg: &str) -> Frame {
        Frame::Reject { id, lane, code, msg: msg.to_string() }
    }

    /// Append the full framed encoding (length prefix + payload) to `out`.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        let at = out.len();
        out.extend_from_slice(&[0u8; 4]); // length backpatched below
        match self {
            Frame::Request { id, lane, model_idx, shape, data } => {
                out.push(TAG_REQUEST);
                out.extend_from_slice(&id.to_le_bytes());
                out.extend_from_slice(&lane.to_le_bytes());
                out.extend_from_slice(&model_idx.to_le_bytes());
                put_tensor(out, shape, data);
            }
            Frame::Response { id, lane, model_idx, latency, shape, data } => {
                out.push(TAG_RESPONSE);
                out.extend_from_slice(&id.to_le_bytes());
                out.extend_from_slice(&lane.to_le_bytes());
                out.extend_from_slice(&model_idx.to_le_bytes());
                out.extend_from_slice(&latency.to_bits().to_le_bytes());
                put_tensor(out, shape, data);
            }
            Frame::Reject { id, lane, code, msg } => {
                out.push(TAG_REJECT);
                out.extend_from_slice(&id.to_le_bytes());
                out.extend_from_slice(&lane.to_le_bytes());
                out.push(code.to_u8());
                out.extend_from_slice(&(msg.len() as u32).to_le_bytes());
                out.extend_from_slice(msg.as_bytes());
            }
            Frame::Eos => out.push(TAG_EOS),
            Frame::ObsQuery { id } => {
                out.push(TAG_OBS_QUERY);
                out.extend_from_slice(&id.to_le_bytes());
            }
            Frame::ObsReport { id, json } => {
                out.push(TAG_OBS_REPORT);
                out.extend_from_slice(&id.to_le_bytes());
                out.extend_from_slice(&(json.len() as u32).to_le_bytes());
                out.extend_from_slice(json.as_bytes());
            }
        }
        let len = (out.len() - at - 4) as u32;
        out[at..at + 4].copy_from_slice(&len.to_le_bytes());
    }

    /// Write one frame (length prefix + payload) to `w`. Callers that
    /// batch writes should wrap `w` in a `BufWriter` and flush.
    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        let mut buf = Vec::new();
        self.encode_into(&mut buf);
        w.write_all(&buf).context("frame write")
    }

    /// Read one frame. `Ok(None)` on clean EOF at a frame boundary; a
    /// connection dying mid-frame is an error.
    pub fn read_from(r: &mut impl Read) -> Result<Option<Frame>> {
        let mut len4 = [0u8; 4];
        let mut got = 0;
        while got < 4 {
            let n = r.read(&mut len4[got..]).context("frame length read")?;
            if n == 0 {
                if got == 0 {
                    return Ok(None); // clean EOF between frames
                }
                bail!("connection closed mid frame-length");
            }
            got += n;
        }
        let len = u32::from_le_bytes(len4) as usize;
        if len == 0 || len > MAX_FRAME {
            bail!("bad frame length {len} (max {MAX_FRAME})");
        }
        // read only the header first and cross-check the length the
        // header implies against the declared prefix, so a hostile
        // length claim cannot force a large allocation for a frame
        // that will be rejected anyway
        let head = len.min(HEADER_MAX);
        let mut payload = vec![0u8; head];
        r.read_exact(&mut payload).context("frame header read")?;
        Self::validate_header(&payload, len)?;
        if len > head {
            payload.resize(len, 0);
            r.read_exact(&mut payload[head..]).context("frame payload read")?;
        }
        Self::decode_payload(&payload).map(Some)
    }

    /// Cross-check the payload length the header's own fields imply
    /// against the declared length prefix. `head` is the first
    /// `min(declared_len, HEADER_MAX)` payload bytes; every
    /// length-determining field fits in them by construction, so a
    /// truncated read here means the frame itself is short.
    fn validate_header(head: &[u8], declared_len: usize) -> Result<()> {
        let mut rd = Rd { b: head, i: 0 };
        let expected = match rd.u8()? {
            TAG_REQUEST => {
                rd.take(16)?; // id + lane + model_idx
                let (shape, n) = rd.shape()?;
                17 + 1 + shape.len() * 4 + n * 4
            }
            TAG_RESPONSE => {
                rd.take(24)?; // id + lane + model_idx + latency bits
                let (shape, n) = rd.shape()?;
                25 + 1 + shape.len() * 4 + n * 4
            }
            TAG_REJECT => {
                rd.take(13)?; // id + lane + code
                let msg_len = rd.u32()? as usize;
                18usize.checked_add(msg_len).context("reject message length overflows")?
            }
            TAG_EOS => 1,
            TAG_OBS_QUERY => 9, // tag + id
            TAG_OBS_REPORT => {
                rd.take(8)?; // id
                let json_len = rd.u32()? as usize;
                13usize.checked_add(json_len).context("obs report length overflows")?
            }
            t => bail!("unknown frame tag {t}"),
        };
        if expected != declared_len {
            bail!(
                "frame header implies {expected} payload bytes, \
                 length prefix declares {declared_len}"
            );
        }
        Ok(())
    }

    /// Decode one payload (the bytes AFTER the length prefix).
    pub fn decode_payload(b: &[u8]) -> Result<Frame> {
        let mut rd = Rd { b, i: 0 };
        let frame = match rd.u8()? {
            TAG_REQUEST => {
                let id = rd.u64()?;
                let lane = rd.u32()?;
                let model_idx = rd.u32()?;
                let (shape, data) = rd.tensor()?;
                Frame::Request { id, lane, model_idx, shape, data }
            }
            TAG_RESPONSE => {
                let id = rd.u64()?;
                let lane = rd.u32()?;
                let model_idx = rd.u32()?;
                let latency = f64::from_bits(rd.u64()?);
                let (shape, data) = rd.tensor()?;
                Frame::Response { id, lane, model_idx, latency, shape, data }
            }
            TAG_REJECT => {
                let id = rd.u64()?;
                let lane = rd.u32()?;
                let code = RejectCode::from_u8(rd.u8()?)?;
                let n = rd.u32()? as usize;
                let msg = String::from_utf8(rd.take(n)?.to_vec())
                    .context("reject message is not utf8")?;
                Frame::Reject { id, lane, code, msg }
            }
            TAG_EOS => Frame::Eos,
            TAG_OBS_QUERY => Frame::ObsQuery { id: rd.u64()? },
            TAG_OBS_REPORT => {
                let id = rd.u64()?;
                let n = rd.u32()? as usize;
                let json = String::from_utf8(rd.take(n)?.to_vec())
                    .context("obs report is not utf8")?;
                Frame::ObsReport { id, json }
            }
            t => bail!("unknown frame tag {t}"),
        };
        rd.done()?;
        Ok(frame)
    }
}

fn put_tensor(out: &mut Vec<u8>, shape: &[usize], data: &[f32]) {
    // encode-side guard mirroring the decoder's caps: a frame this side
    // emits must be one the peer will accept, or a server-side success
    // would read as a dead connection over there. (Payloads here are
    // request/response tensors, orders of magnitude under the caps;
    // violating them is a programming error, not a traffic condition.)
    assert!(shape.len() <= MAX_RANK, "tensor rank {} exceeds the wire cap", shape.len());
    assert!(
        data.len() <= MAX_FRAME / 4,
        "tensor of {} elements exceeds the {MAX_FRAME}-byte frame cap",
        data.len()
    );
    out.push(shape.len() as u8);
    for &d in shape {
        out.extend_from_slice(&(d as u32).to_le_bytes());
    }
    crate::util::simd::extend_f32_le(out, data);
}

/// Bounds-checked little-endian payload reader.
struct Rd<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.b.len() - self.i < n {
            bail!("truncated frame: wanted {n} bytes, have {}", self.b.len() - self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// `u8 rank, rank x u32 dims` with the rank and element-count caps
    /// applied — shared by the pre-allocation header check
    /// (`Frame::validate_header`) and the full tensor decode, so the
    /// two can never disagree on what a header implies.
    fn shape(&mut self) -> Result<(Vec<usize>, usize)> {
        let rank = self.u8()? as usize;
        if rank > MAX_RANK {
            bail!("tensor rank {rank} exceeds max {MAX_RANK}");
        }
        let mut shape = Vec::with_capacity(rank);
        let mut n: usize = 1;
        for _ in 0..rank {
            let d = self.u32()? as usize;
            n = n
                .checked_mul(d)
                .with_context(|| format!("tensor shape {shape:?} x {d} overflows"))?;
            shape.push(d);
        }
        if n > MAX_FRAME / 4 {
            bail!("tensor of {n} elements exceeds the frame cap");
        }
        Ok((shape, n))
    }

    /// `u8 rank, rank x u32 dims, (prod dims) x f32` — the dim product
    /// must equal the f32 count left in the payload.
    fn tensor(&mut self) -> Result<(Vec<usize>, Vec<f32>)> {
        let (shape, n) = self.shape()?;
        let bytes = self.take(n * 4)?;
        let mut data = Vec::new();
        crate::util::simd::extend_le_f32(&mut data, bytes);
        Ok((shape, data))
    }

    /// Every payload byte must be consumed — trailing garbage is a
    /// malformed frame, not an extension point.
    fn done(&self) -> Result<()> {
        if self.i != self.b.len() {
            bail!("frame has {} trailing bytes", self.b.len() - self.i);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(f: &Frame) -> Frame {
        let mut buf = Vec::new();
        f.encode_into(&mut buf);
        let mut r = &buf[..];
        let got = Frame::read_from(&mut r).unwrap().unwrap();
        assert!(r.is_empty(), "reader must consume the whole frame");
        got
    }

    #[test]
    fn request_roundtrips() {
        let f = Frame::Request {
            id: 7,
            lane: 1,
            model_idx: 3,
            shape: vec![1, 4],
            data: vec![0.5, -1.25, 3.0, f32::MIN_POSITIVE],
        };
        assert_eq!(roundtrip(&f), f);
    }

    #[test]
    fn response_and_reject_and_eos_roundtrip() {
        let r = Frame::Response {
            id: u64::MAX,
            lane: 0,
            model_idx: 0,
            latency: 0.012345,
            shape: vec![2, 2],
            data: vec![1.0, 2.0, 3.0, 4.0],
        };
        assert_eq!(roundtrip(&r), r);
        let j = Frame::reject(9, 2, RejectCode::Busy, "lane queue full");
        assert_eq!(roundtrip(&j), j);
        assert_eq!(roundtrip(&Frame::Eos), Frame::Eos);
    }

    #[test]
    fn every_reject_code_roundtrips() {
        for code in [
            RejectCode::Busy,
            RejectCode::Invalid,
            RejectCode::NoLane,
            RejectCode::Shutdown,
            RejectCode::Shed,
        ] {
            let f = Frame::reject(1, 0, code, "x");
            assert_eq!(roundtrip(&f), f);
        }
        // unknown wire codes stay errors, not silent remaps
        let mut payload = vec![TAG_REJECT];
        payload.extend_from_slice(&1u64.to_le_bytes());
        payload.extend_from_slice(&0u32.to_le_bytes());
        payload.push(99);
        payload.extend_from_slice(&0u32.to_le_bytes());
        assert!(Frame::decode_payload(&payload).is_err());
    }

    #[test]
    fn obs_frames_roundtrip() {
        let q = Frame::ObsQuery { id: 42 };
        assert_eq!(roundtrip(&q), q);
        // a report whose JSON body crosses the HEADER_MAX window, so
        // the split header-read path is exercised too
        let r = Frame::ObsReport {
            id: u64::MAX,
            json: format!("{{\"lanes\":[{}]}}", "1,".repeat(60) + "1"),
        };
        assert_eq!(roundtrip(&r), r);
        let empty = Frame::ObsReport { id: 0, json: String::new() };
        assert_eq!(roundtrip(&empty), empty);
    }

    #[test]
    fn inflated_obs_report_prefix_is_rejected_before_allocation() {
        // an ObsReport claiming a huge declared length but whose header
        // json_len field implies a small frame: the cross-check must
        // catch the mismatch from the header window alone
        let f = Frame::ObsReport { id: 1, json: "{}".to_string() };
        let mut buf = Vec::new();
        f.encode_into(&mut buf);
        buf[..4].copy_from_slice(&((MAX_FRAME - 1) as u32).to_le_bytes());
        buf.resize(4 + HEADER_MAX, 0);
        let mut r = &buf[..];
        let err = Frame::read_from(&mut r).unwrap_err().to_string();
        assert!(err.contains("implies"), "want the header cross-check, got: {err}");

        // and an ObsQuery with trailing bytes is malformed
        let mut payload = vec![TAG_OBS_QUERY];
        payload.extend_from_slice(&7u64.to_le_bytes());
        payload.push(0xFF);
        assert!(Frame::decode_payload(&payload).is_err());
    }

    #[test]
    fn empty_stream_is_clean_eof() {
        let mut r: &[u8] = &[];
        assert!(Frame::read_from(&mut r).unwrap().is_none());
    }

    #[test]
    fn mid_frame_eof_is_an_error() {
        let mut buf = Vec::new();
        Frame::Eos.encode_into(&mut buf);
        let mut r = &buf[..3]; // cut inside the length prefix
        assert!(Frame::read_from(&mut r).is_err());
        let mut r = &buf[..4]; // length present, payload missing
        assert!(Frame::read_from(&mut r).is_err());
    }

    #[test]
    fn hostile_length_and_rank_are_rejected() {
        let mut r: &[u8] = &(u32::MAX).to_le_bytes()[..];
        assert!(Frame::read_from(&mut r).is_err(), "oversized length prefix");

        // rank 9 tensor
        let mut payload = vec![TAG_REQUEST];
        payload.extend_from_slice(&0u64.to_le_bytes());
        payload.extend_from_slice(&0u32.to_le_bytes());
        payload.extend_from_slice(&0u32.to_le_bytes());
        payload.push(9);
        assert!(Frame::decode_payload(&payload).is_err(), "rank over cap");
    }

    #[test]
    fn hostile_length_claim_is_rejected_before_the_payload_allocation() {
        // a 64MiB-claiming prefix over what is actually a 1-byte Eos
        // payload: the header check must reject it after HEADER_MAX
        // bytes, without trusting (or waiting for) the claimed length
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME as u32).to_le_bytes());
        buf.push(TAG_EOS);
        buf.resize(4 + HEADER_MAX, 0); // enough bytes for the header read
        let mut r = &buf[..];
        let err = Frame::read_from(&mut r).unwrap_err().to_string();
        assert!(err.contains("implies"), "want the header cross-check, got: {err}");
    }

    #[test]
    fn inflated_length_prefix_on_a_valid_request_is_rejected() {
        let f = Frame::Request {
            id: 3,
            lane: 1,
            model_idx: 0,
            shape: vec![2],
            data: vec![1.0, 2.0],
        };
        let mut buf = Vec::new();
        f.encode_into(&mut buf);
        let honest = u32::from_le_bytes(buf[..4].try_into().unwrap());
        buf[..4].copy_from_slice(&(honest + 4).to_le_bytes());
        buf.extend_from_slice(&[0u8; 4]); // pad so the reads succeed
        let mut r = &buf[..];
        let err = Frame::read_from(&mut r).unwrap_err().to_string();
        assert!(err.contains("implies"), "want the header cross-check, got: {err}");
    }

    #[test]
    fn payloads_longer_than_the_header_window_roundtrip() {
        // 64 f32s => 290 payload bytes, well past HEADER_MAX: exercises
        // the header-read + remainder-read split in read_from
        let f = Frame::Response {
            id: 11,
            lane: 2,
            model_idx: 1,
            latency: 0.25,
            shape: vec![1, 64],
            data: (0..64).map(|i| i as f32 * 0.75 - 8.0).collect(),
        };
        assert_eq!(roundtrip(&f), f);
    }

    #[test]
    fn shape_data_mismatch_and_trailing_bytes_fail() {
        let f = Frame::Request {
            id: 1,
            lane: 0,
            model_idx: 0,
            shape: vec![2],
            data: vec![1.0, 2.0],
        };
        let mut buf = Vec::new();
        f.encode_into(&mut buf);
        // corrupt the encoded dim (2 -> 3): data is now one f32 short
        let dim_at = 4 + 1 + 8 + 4 + 4 + 1;
        buf[dim_at] = 3;
        let mut r = &buf[..];
        assert!(Frame::read_from(&mut r).is_err());

        // trailing garbage after a valid Eos payload
        assert!(Frame::decode_payload(&[TAG_EOS, 0xFF]).is_err());
    }
}
