//! `IngressBridge`: the handoff between N producer threads and the one
//! dispatch thread that owns a `MultiServer`.
//!
//! Producers (connection reader threads, in-proc load generators) parse
//! request frames and [`IngressBridge::submit`] an [`Envelope`] each.
//! The bridge is a **bounded** mutex+condvar MPSC queue: a full bridge
//! rejects at submit time (`SubmitError::Busy`) and the producer sends a
//! `Reject { Busy }` frame back — open-loop arrivals are never parked on
//! a lock, so backpressure reaches the client instead of silently
//! queueing unbounded memory. Lane-level backpressure (`Admit::Rejected`
//! / `Admit::Invalid` from `Server::offer`) is mapped to the same frame
//! type by the dispatch loop.
//!
//! [`run_dispatch`] is the single consumer. Its loop keeps a strict
//! priority: (1) drain arrivals without blocking, (2) dispatch the lane
//! the [`QosScheduler`] picks, (3) only when nothing is due, block for
//! the next arrival — capped at the soonest batching/SLO deadline — so
//! the dispatch thread never idles while any lane is round-ready.
//! Dispatch prefers **group-ready over lane-ready**: when the QoS pick
//! lands on a coalesce-group member and other members hold work,
//! `MultiServer::dispatch_next` runs ONE merged round for the whole
//! group, and the responses the loop routes back span several lanes
//! (the per-request `Route` carries the authoritative lane, so the
//! scatter needs no lane hint).
//!
//! Requests are re-stamped (`Request::arrived_now`) at admission: the
//! queue-wait clock starts when the server accepts the request, not
//! when some producer happened to construct (or clone) it.
//!
//! Observability (ADR-006) is opt-in per bridge: after
//! [`IngressBridge::attach_obs`], connection readers enqueue `ObsQuery`
//! frames on the hub, every dispatch loop folds response stage stamps
//! into the hub's per-lane histograms, records its decisions on a
//! flight-recorder ring (dumped automatically when rounds fail
//! persistently or control tickets die unresolved), publishes lane
//! gauges between rounds, and answers pending queries with one merged
//! `ObsReport`. With no hub attached none of these paths run.
//!
//! [`QosScheduler`]: super::qos::QosScheduler

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::control::{Ack, AddOutcome, ControlPlane, LaneCmd, PartControl, RemoveOutcome};
use crate::coordinator::multi::{LaneLife, MultiServer, ParallelDispatcher, Topology};
use crate::coordinator::obs::{CtrlKind, EventKind, LaneGauge, ObsHub, RecHandle, StageTracer};
use crate::coordinator::request::{Request, Response};
use crate::coordinator::server::Admit;
use crate::coordinator::service::RoundExecutor;
use crate::tensor::Tensor;
use crate::util::lock::{LockRank, OrderedMutex};
use crate::util::shard::{ShardHandle, Shardable, Sharded};

use super::frame::{Frame, RejectCode};
use super::transport::{FrameQueue, Transport};

/// One admitted-or-not unit of work crossing the bridge.
pub struct Envelope {
    /// target `MultiServer` lane
    pub lane: usize,
    /// the client's request id (echoed back on the wire; the dispatch
    /// loop re-keys requests internally so ids from different
    /// connections cannot collide)
    pub client_id: u64,
    pub req: Request,
    /// where this connection's responses and rejections go
    pub reply: FrameQueue,
}

/// Why a submit did not enqueue. The envelope is handed back so the
/// producer can answer the client without re-parsing anything.
pub enum SubmitError {
    /// bridge full — backpressure, retry later
    Busy(Envelope),
    /// bridge closed — server shutting down
    Closed(Envelope),
}

struct BridgeState {
    q: VecDeque<Envelope>,
    closed: bool,
}

struct BridgeInner {
    // Bridge sits at the bottom of the lock hierarchy (ADR-008):
    // producers and the dispatch thread take it with nothing else held.
    state: OrderedMutex<BridgeState>,
    cap: usize,
    ready: Condvar,
    /// observability plane (ADR-006) — attach BEFORE dispatch starts:
    /// the dispatch loops read it once at entry
    obs: OrderedMutex<Option<Arc<ObsHub>>>,
}

/// Bounded MPSC handoff: many producers, one dispatch thread.
#[derive(Clone)]
pub struct IngressBridge {
    inner: Arc<BridgeInner>,
}

impl IngressBridge {
    /// `cap` bounds queued envelopes (clamped >= 1): beyond it, submits
    /// fail with [`SubmitError::Busy`] until the dispatch thread drains.
    pub fn new(cap: usize) -> IngressBridge {
        IngressBridge {
            inner: Arc::new(BridgeInner {
                state: OrderedMutex::new(
                    LockRank::Bridge,
                    BridgeState { q: VecDeque::new(), closed: false },
                ),
                cap: cap.max(1),
                ready: Condvar::new(),
                obs: OrderedMutex::new(LockRank::BridgeObs, None),
            }),
        }
    }

    /// Attach the observability plane (ADR-006). Must happen BEFORE the
    /// dispatch loops start: each loop reads the hub exactly once at
    /// entry (attaching later silently observes nothing). Size the hub
    /// to the dispatch thread count (`parts + 1` for parallel runs).
    pub fn attach_obs(&self, hub: Arc<ObsHub>) {
        *self.inner.obs.lock() = Some(hub);
    }

    /// The attached observability hub, if any.
    pub fn obs(&self) -> Option<Arc<ObsHub>> {
        self.inner.obs.lock().clone()
    }

    /// Non-blocking submit (producer side). Never parks the caller: a
    /// full or closed bridge returns the envelope for a rejection frame.
    pub fn submit(&self, env: Envelope) -> std::result::Result<(), SubmitError> {
        let mut st = self.inner.state.lock();
        if st.closed {
            return Err(SubmitError::Closed(env));
        }
        if st.q.len() >= self.inner.cap {
            return Err(SubmitError::Busy(env));
        }
        st.q.push_back(env);
        self.inner.ready.notify_one();
        Ok(())
    }

    /// Non-blocking pop (dispatch side).
    pub fn try_pop(&self) -> Option<Envelope> {
        self.inner.state.lock().q.pop_front()
    }

    /// Pop, blocking up to `timeout` for an arrival. `None` on timeout
    /// or when the bridge is closed and drained.
    pub fn pop_timeout(&self, timeout: Duration) -> Option<Envelope> {
        let deadline = std::time::Instant::now() + timeout;
        let mut st = self.inner.state.lock();
        loop {
            if let Some(env) = st.q.pop_front() {
                return Some(env);
            }
            if st.closed {
                return None;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            let (next, timed_out) = st.wait_timeout(&self.inner.ready, deadline - now);
            st = next;
            if timed_out && st.q.is_empty() {
                return None;
            }
        }
    }

    /// Close the bridge: new submits fail `Closed`, queued envelopes
    /// remain poppable, blocked pops wake.
    pub fn close(&self) {
        self.inner.state.lock().closed = true;
        self.inner.ready.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.state.lock().closed
    }

    pub fn len(&self) -> usize {
        self.inner.state.lock().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// ---------------------------------------------------------------------------
// per-connection glue: transport <-> bridge
// ---------------------------------------------------------------------------

/// Threads serving one client connection, plus the reply queue the
/// dispatch loop routes this connection's responses into.
pub struct ConnHandle {
    pub reader: JoinHandle<()>,
    pub writer: JoinHandle<()>,
    /// Close after dispatch has fully drained to flush-and-release the
    /// writer; until then it stays open so late responses still flow.
    pub reply: FrameQueue,
}

impl ConnHandle {
    /// Flush remaining replies and join both threads (orchestrator
    /// shutdown path, after dispatch has drained). The reader is joined
    /// BEFORE the reply queue closes: it may still be answering frames
    /// that were in flight when the bridge closed (Shutdown rejects),
    /// and closing first would drop those outcomes. The reader exits on
    /// `Eos`/EOF, which every client sends when it stops producing.
    pub fn shutdown(self) {
        let _ = self.reader.join();
        self.reply.close();
        let _ = self.writer.join();
    }
}

/// Serve one client connection: a reader thread parses `Request` frames
/// into envelopes, a writer thread drains the connection's reply queue.
/// The reader stops at `Eos` or EOF **without** closing the reply queue
/// (responses for still-queued requests must flush first); the
/// orchestrator closes it via [`ConnHandle::shutdown`] once dispatch has
/// drained. A vanished client unblocks the writer through send errors.
pub fn serve_conn(bridge: IngressBridge, transport: Box<dyn Transport>) -> Result<ConnHandle> {
    let (mut tx, mut rx) = transport.split()?;
    let reply = FrameQueue::new();

    let wq = reply.clone();
    let writer = std::thread::spawn(move || {
        while let Some(frame) = wq.pop() {
            if tx.send(&frame).is_err() {
                // client gone: stop delivering, let late pushes drop
                wq.close();
                break;
            }
        }
    });

    let rq = reply.clone();
    let reader = std::thread::spawn(move || {
        loop {
            let frame = match rx.recv() {
                Ok(Some(f)) => f,
                Ok(None) | Err(_) => break, // EOF or dead connection
            };
            match frame {
                Frame::Request { id, lane, model_idx, shape, data } => {
                    let input = match Tensor::new(shape, data) {
                        Ok(t) => t,
                        Err(e) => {
                            rq.push(Frame::reject(
                                id,
                                lane,
                                RejectCode::Invalid,
                                &format!("bad payload: {e}"),
                            ));
                            continue;
                        }
                    };
                    let req = Request::new(id, model_idx as usize, input);
                    let env =
                        Envelope { lane: lane as usize, client_id: id, req, reply: rq.clone() };
                    match bridge.submit(env) {
                        Ok(()) => {}
                        Err(SubmitError::Busy(env)) => {
                            env.reply.push(Frame::reject(
                                env.client_id,
                                lane,
                                RejectCode::Busy,
                                "ingress bridge full",
                            ));
                        }
                        // keep reading after Closed: frames already in
                        // flight each still get their outcome frame (a
                        // typed Shutdown reject), instead of being
                        // orphaned with no reply at all
                        Err(SubmitError::Closed(env)) => {
                            env.reply.push(Frame::reject(
                                env.client_id,
                                lane,
                                RejectCode::Shutdown,
                                "server shutting down",
                            ));
                        }
                    }
                }
                Frame::Eos => break,
                // introspection (ADR-006): park the query on the hub;
                // the next dispatch-loop poll answers it out of band on
                // this connection's reply queue
                Frame::ObsQuery { id } => match bridge.obs() {
                    Some(hub) => hub.enqueue_query(id, rq.clone()),
                    None => rq.push(Frame::reject(
                        id,
                        0,
                        RejectCode::Invalid,
                        "observability not enabled",
                    )),
                },
                // clients only send requests; anything else is a
                // protocol violation answered in-band
                _ => {
                    rq.push(Frame::reject(0, 0, RejectCode::Invalid, "unexpected frame"));
                }
            }
        }
    });

    Ok(ConnHandle { reader, writer, reply })
}

// ---------------------------------------------------------------------------
// the dispatch loop (single consumer)
// ---------------------------------------------------------------------------

/// Per-lane typed-reject attribution (ADR-007, keyed by the client's
/// wire lane id). Without this, shed load was invisible exactly when
/// admission control acted: the scalar totals said HOW MUCH was
/// refused, but not WHICH tenant was over its knee.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct LaneRejects {
    /// `Reject{Busy}` frames: lane queue or dispatch-group queue full
    pub busy: u64,
    /// `Reject{Shed}` frames: admission control projected an SLO miss
    pub shed: u64,
}

/// Counters from one [`run_dispatch`] run.
#[derive(Debug, Default, Clone)]
pub struct IngressStats {
    /// envelopes admitted into lane queues
    pub admitted: u64,
    /// envelopes refused with `Admit::Rejected` (lane queue full)
    pub lane_busy: u64,
    /// envelopes refused because the owning dispatch group's queue was
    /// full (parallel dispatch only — the router's backpressure)
    pub group_busy: u64,
    /// envelopes refused with `Admit::Invalid`
    pub invalid: u64,
    /// envelopes addressed to a lane that does not exist
    pub no_lane: u64,
    /// envelopes shed by admission control: the lane's projected queue
    /// wait already exceeded its SLO (ADR-007) — refused with a typed
    /// `Reject{Shed}` before consuming a queue slot or QoS credit
    pub shed: u64,
    /// responses routed back to connections
    pub responses: u64,
    /// rounds dispatched
    pub rounds: u64,
    /// rounds that were coalesced group rounds (one merged execution
    /// serving >= 2 lanes); included in `rounds`
    pub coalesced_rounds: u64,
    /// failed rounds that were retried (requests requeued by the lane)
    pub round_errors: u64,
    /// times the pre-block recheck found a lane due (a deadline expired
    /// in the gap since `dispatch_next` said "nothing due") — the loop
    /// dispatches instead of napping, so nonzero means races were
    /// *caught*, never that the thread idled while work was ready
    pub idle_naps_avoided: u64,
    /// control-plane commands applied between rounds (lane add /
    /// remove / swap — elastic dispatch only)
    pub ctrl_ops: u64,
    /// per-wire-lane reject attribution (Busy + Shed). Merged exactly
    /// across shards like every scalar above: lane totals over the
    /// merged read equal the sum of every thread's local counts.
    pub lane_rejects: HashMap<usize, LaneRejects>,
}

impl IngressStats {
    /// Fold another run's counters into this one (the parallel runner
    /// keeps one shard per thread and merges them on read).
    pub fn merge(&mut self, o: &IngressStats) {
        self.admitted += o.admitted;
        self.lane_busy += o.lane_busy;
        self.group_busy += o.group_busy;
        self.invalid += o.invalid;
        self.no_lane += o.no_lane;
        self.shed += o.shed;
        self.responses += o.responses;
        self.rounds += o.rounds;
        self.coalesced_rounds += o.coalesced_rounds;
        self.round_errors += o.round_errors;
        self.idle_naps_avoided += o.idle_naps_avoided;
        self.ctrl_ops += o.ctrl_ops;
        for (&lane, r) in &o.lane_rejects {
            let e = self.lane_rejects.entry(lane).or_default();
            e.busy += r.busy;
            e.shed += r.shed;
        }
    }

    /// Record one Busy reject against `lane` (wire lane id).
    pub fn note_busy(&mut self, lane: usize) {
        self.lane_rejects.entry(lane).or_default().busy += 1;
    }

    /// Record one Shed reject against `lane` (wire lane id).
    pub fn note_shed(&mut self, lane: usize) {
        self.lane_rejects.entry(lane).or_default().shed += 1;
    }

    /// Per-lane reject rows sorted by wire lane id — the deterministic
    /// order report lines and `ObsReport` JSON emit.
    pub fn lane_reject_rows(&self) -> Vec<(usize, LaneRejects)> {
        let mut rows: Vec<(usize, LaneRejects)> =
            self.lane_rejects.iter().map(|(&l, &r)| (l, r)).collect();
        rows.sort_unstable_by_key(|&(l, _)| l);
        rows
    }
}

impl Shardable for IngressStats {
    // StatsShard is held while the dispatch loop folds tracer stamps /
    // recorder events (ObsShard) and pushes frames (ReplyQueue) — both
    // rank above it (ADR-008 edges StatsShard < ObsShard, < ReplyQueue).
    const RANK: LockRank = LockRank::StatsShard;

    fn merge_from(&mut self, other: &Self) {
        self.merge(other);
    }
}

/// Response routing entry: which connection gets server-keyed request id.
struct Route {
    client_id: u64,
    lane: usize,
    reply: FrameQueue,
}

/// Upper bound on one idle nap — even with no deadline in sight the
/// loop re-checks arrivals and shutdown at this cadence.
const IDLE_POLL: Duration = Duration::from_millis(5);
/// Consecutive failed rounds tolerated (requests are requeued by the
/// lane each time) before the loop gives up and surfaces the error.
const MAX_CONSECUTIVE_ROUND_ERRORS: u32 = 3;
/// How long a lane that just failed a round is skipped by selection
/// (ADR-007). Before this, a failed round was re-picked immediately:
/// three consecutive failures burned in microseconds — the loop died
/// before a sibling's deadline could interleave a healthy round — and
/// the doomed lane's WDRR credit was destroyed while healthy lanes
/// waited. Kept under [`IDLE_POLL`] so a cooldown never outlives the
/// loop's own worst-case reaction time.
const FAILURE_COOLDOWN: Duration = Duration::from_millis(2);
/// Floor for the adaptive SLO boost margin ε (ADR-007): even a lane
/// with microsecond round tails keeps a margin above scheduling noise.
const ADAPTIVE_EPS_FLOOR: Duration = Duration::from_micros(200);
/// Arrivals admitted per loop iteration before dispatch gets a turn. A
/// saturating producer used to pin the loop in the drain-arrivals
/// phase indefinitely — rounds, gauges, and the ε refresh all starved
/// exactly when an operator most needs them (ADR-007 satellite).
const MAX_ARRIVALS_PER_ITER: usize = 256;

/// Run the dispatch side of the bridge to completion: admit arrivals,
/// dispatch QoS-picked rounds, route responses, and return once the
/// bridge is closed AND every queue is drained. The loop never blocks
/// while a lane is due (arrival drains are non-blocking and idle naps
/// are capped at the soonest batching/SLO deadline — a deadline scan
/// that covers every backlogged lane, coalesce-group riders included).
pub fn run_dispatch<E: RoundExecutor>(
    multi: &mut MultiServer<E>,
    bridge: &IngressBridge,
) -> Result<IngressStats> {
    let stats: Arc<Sharded<IngressStats>> = Arc::new(Sharded::new(1));
    let handle = Sharded::register(&stats);
    dispatch_loop(multi, bridge, None, None, &handle)?;
    Ok(stats.read())
}

/// The single-consumer loop behind [`run_dispatch`], parameterized over
/// the lane id space: `part = None` serves every envelope on `multi`
/// with wire lane ids = `multi` lane ids; `part = Some((topo, p))` is
/// one partition of a [`ParallelDispatcher`] — envelopes carry
/// **global** lane ids, which translate to partition-local ids at
/// admission and back at response routing (response frames must quote
/// the client's own lane id regardless of which thread served it).
///
/// `ctrl = Some(queue)` makes the loop this partition's control-plane
/// executor (ADR-005): once per iteration — which is strictly between
/// rounds, since an iteration dispatches at most one round — it applies
/// queued [`LaneCmd`]s (install/publish, begin-quiesce, hot-swap) and
/// excises any quiescing lane that has fully drained. Every command is
/// acknowledged exactly once on every exit path, including shutdown and
/// round-failure, so controller waits never hang.
///
/// Counters go to `stats` — the caller's shard of a [`Sharded`]
/// accumulator. One loop is one shard's only writer, so every bump is
/// an uncontended lock, while an observer can merge-read the live
/// totals across all loops at any time.
fn dispatch_loop<'f, E: RoundExecutor>(
    multi: &mut MultiServer<'f, E>,
    bridge: &IngressBridge,
    part: Option<(&Topology, usize)>,
    ctrl: Option<&PartControl<'f, E>>,
    stats: &ShardHandle<IngressStats>,
) -> Result<()> {
    let mut retiring: Vec<(usize, usize, Ack<RemoveOutcome>)> = Vec::new();
    let result = dispatch_core(multi, bridge, part, ctrl, stats, &mut retiring);
    // exactly-once acknowledgement on every exit path: quiescing lanes
    // that finished draining during the final flush excise here; the
    // rest — and any commands still queued — fail their waiters rather
    // than hanging them
    if let Some(ctrl) = ctrl {
        // a control ticket about to fail is exactly the moment an
        // operator wants the recent decision history (ADR-006); retires
        // that finished draining during the final flush resolve cleanly
        // below and are not failures
        let failing_retires = retiring.iter().filter(|(l, _, _)| !multi.retire_ready(*l)).count();
        if failing_retires > 0 || !ctrl.is_empty() {
            if let Some(hub) = bridge.obs() {
                hub.recorder.dump_now(&format!(
                    "dispatch loop exiting with {failing_retires} undrained retire(s) \
                     and {} queued command(s)",
                    ctrl.len(),
                ));
            }
        }
        let epoch = part.map(|(topo, _)| topo.epoch()).unwrap_or(0);
        for (local, global, ack) in retiring.drain(..) {
            if multi.retire_ready(local) {
                match multi.finish_retire(local) {
                    Ok(deficit) => ack.complete(Ok(RemoveOutcome { deficit, epoch })),
                    Err(e) => ack.complete(Err(e.to_string())),
                }
            } else {
                ack.complete(Err(format!(
                    "dispatch loop exited before lane {global} drained"
                )));
            }
        }
        while let Some(cmd) = ctrl.pop() {
            cmd.fail("dispatch loop shut down");
        }
    }
    result
}

/// The loop body of [`dispatch_loop`]; `retiring` is owned by the
/// wrapper so outstanding quiesces survive an early return and get
/// resolved there.
// LINT-ALLOW(retiring[k] iterates indices of the local retiring vec)
fn dispatch_core<'f, E: RoundExecutor>(
    multi: &mut MultiServer<'f, E>,
    bridge: &IngressBridge,
    part: Option<(&Topology, usize)>,
    ctrl: Option<&PartControl<'f, E>>,
    stats: &ShardHandle<IngressStats>,
    retiring: &mut Vec<(usize, usize, Ack<RemoveOutcome>)>,
) -> Result<()> {
    let to_local = |lane: usize| -> Option<usize> {
        match part {
            None => Some(lane),
            Some((topo, p)) => match topo.locate(lane) {
                Some((owner, local)) if owner == p => Some(local),
                _ => None,
            },
        }
    };
    let to_global = |local: usize| -> usize {
        match part {
            None => local,
            Some((topo, p)) => topo.global(p, local),
        }
    };
    let mut routes: HashMap<u64, Route> = HashMap::new();
    let mut seq: u64 = 0;
    let mut responses: Vec<Response> = Vec::new();
    let mut consecutive_errors: u32 = 0;

    // observability claims (ADR-006): read once — attach_obs after the
    // loop starts is a documented no-op for this thread
    let hub = bridge.obs();
    let tracer = hub.as_ref().map(|h| h.tracer());
    let rec = hub.as_ref().map(|h| h.rec_handle());
    let mut last_gauges: Option<Instant> = None;
    let mut last_eps: Option<Instant> = None;

    loop {
        // 0) control plane: apply queued lane commands strictly BETWEEN
        // rounds (an iteration dispatches at most one round), then
        // excise any quiescing lane that has fully drained. Sibling
        // lanes' queues and any merged rounds in flight on OTHER
        // partitions' ArenaRing slots are untouched by construction —
        // this thread owns everything it mutates here.
        if let Some(ctrl) = ctrl {
            while let Some(cmd) = ctrl.pop() {
                stats.lock().ctrl_ops += 1;
                // capture (kind, global) before the match consumes the
                // command; record after, so the event's epoch reflects
                // the applied mutation
                let ev = rec.as_ref().map(|_| match &cmd {
                    LaneCmd::Add { global, .. } => (CtrlKind::Add, *global),
                    LaneCmd::Remove { global, .. } => (CtrlKind::Remove, *global),
                    LaneCmd::Swap { local, .. } => (CtrlKind::Swap, to_global(*local)),
                });
                match cmd {
                    LaneCmd::Add { global, spec, deficit, ack } => {
                        let Some((topo, p)) = part else {
                            ack.complete(Err(
                                "elastic add needs a partitioned run".to_string()
                            ));
                            continue;
                        };
                        match multi.install_lane(spec.exec, spec.cfg, spec.qos, deficit) {
                            Ok((local, group)) => {
                                // publish AFTER install: the reserved
                                // global id answered NoLane until the
                                // lane could actually serve
                                topo.map_lane(global, p, local);
                                ack.complete(Ok(AddOutcome {
                                    global,
                                    local,
                                    group,
                                    epoch: topo.epoch(),
                                }));
                            }
                            Err(e) => ack.complete(Err(e.to_string())),
                        }
                    }
                    LaneCmd::Remove { local, global, ack } => {
                        // the controller unmapped the global id before
                        // queueing this, so no new arrivals can reach
                        // the lane; admitted work drains through normal
                        // dispatch until retire_ready
                        match multi.begin_retire(local) {
                            Ok(()) => retiring.push((local, global, ack)),
                            Err(e) => ack.complete(Err(e.to_string())),
                        }
                    }
                    LaneCmd::Swap { local, tag, ack } => {
                        let res = multi.swap_lane_model(local, tag).map_err(|e| e.to_string());
                        if res.is_ok() {
                            if let Some((topo, _)) = part {
                                topo.note_change();
                            }
                        }
                        ack.complete(res);
                    }
                }
                if let (Some(r), Some((op, global))) = (&rec, ev) {
                    let epoch = part.map(|(topo, _)| topo.epoch()).unwrap_or(0);
                    r.record(EventKind::CtrlOp { op, global, epoch });
                }
            }
            let mut k = 0;
            while k < retiring.len() {
                if multi.retire_ready(retiring[k].0) {
                    let (local, _global, ack) = retiring.remove(k);
                    match multi.finish_retire(local) {
                        Ok(deficit) => {
                            let epoch = part.map(|(topo, _)| topo.epoch()).unwrap_or(0);
                            ack.complete(Ok(RemoveOutcome { deficit, epoch }));
                        }
                        Err(e) => ack.complete(Err(e.to_string())),
                    }
                } else {
                    k += 1;
                }
            }
        }

        // 0.4) ε control loop (ADR-007): refresh each lane's adaptive
        // SLO boost margin from its observed round-time tail
        // (EWMA-smoothed p99, clamped to [floor, slo/2]) on the same
        // time budget as the gauges — between rounds, never inside one.
        // Runs hub or no hub: the margin is a scheduling input, not an
        // observability nicety.
        if last_eps.is_none_or(|t| t.elapsed() >= IDLE_POLL) {
            multi.refresh_adaptive_eps(ADAPTIVE_EPS_FLOOR);
            last_eps = Some(Instant::now());
        }

        // 0.5) observability (ADR-006): refresh this partition's lane
        // gauges on a time budget (the p99 read sorts a sample clone —
        // cheap at this rate, not per round). The budget is re-checked
        // in the round path too, and the arrival drain below is
        // bounded, so a saturated loop — the exact moment an operator
        // queries — still republishes within one cadence instead of
        // only at idle polls. Then answer any pending introspection
        // queries with the exactly merged counters. Whichever thread
        // polls first answers ALL pending queries; other partitions'
        // gauges are at most one gauge cadence plus one round stale
        // (documented bound).
        if let Some(hub) = &hub {
            refresh_gauges_if_stale(hub, multi, part, &mut last_gauges, hub.has_queries());
            if hub.has_queries() {
                let snap = part.map(|(topo, _)| topo.snapshot());
                hub.answer(&stats.merged(), snap.as_ref());
            }
        }

        // 1) drain arrivals without blocking — bounded per iteration so
        // a saturating producer cannot pin the loop in this phase while
        // dispatch, gauges, and the ε refresh starve
        let mut drained = 0usize;
        while drained < MAX_ARRIVALS_PER_ITER {
            let Some(env) = bridge.try_pop() else { break };
            let local = to_local(env.lane);
            admit(multi, env, local, &mut routes, &mut seq, &mut stats.lock(), rec.as_ref());
            drained += 1;
        }

        // 2) dispatch whatever the QoS scheduler says is due — a
        // coalesced group round when the pick's group has work on
        // several lanes, a solo lane round otherwise
        if let Some(r) = &rec {
            // guard on a ready lane so idle iterations don't flood the
            // ring with starts that never became rounds
            if multi.ready_lane().is_some() {
                r.record(EventKind::RoundStart { part: part.map(|(_, p)| p).unwrap_or(0) });
            }
        }
        match multi.dispatch_next(&mut responses) {
            Ok(Some(d)) => {
                consecutive_errors = 0;
                if let Some(r) = &rec {
                    let lane = to_global(d.lane);
                    // deficit is post-charge: what the lane has LEFT
                    // after paying for this round (ADR-006)
                    r.record(EventKind::QosPick {
                        lane,
                        deficit: multi.lane_deficit(d.lane),
                        urgent: d.urgent,
                    });
                    if d.lanes_served > 1 {
                        r.record(EventKind::Coalesce { lane, members: d.lanes_served });
                    }
                    r.record(EventKind::RoundEnd {
                        lane,
                        lanes_served: d.lanes_served,
                        responses: d.responses,
                    });
                }
                // a merged round's responses span lanes; only a solo
                // round's batch can be pinned to the picked lane. The
                // hint (a Topology read) is computed BEFORE the stats
                // guard: StatsShard ranks above Topology (ADR-008).
                let hint = if d.lanes_served > 1 {
                    usize::MAX
                } else {
                    to_global(d.lane)
                };
                let mut st = stats.lock();
                st.rounds += 1;
                if d.lanes_served > 1 {
                    st.coalesced_rounds += 1;
                }
                route_responses(&mut responses, &mut routes, hint, &mut st, tracer.as_ref());
                drop(st);
                // the stale-gauge fix (ADR-007 satellite): the gauge
                // time budget is checked in the round path as well, so
                // back-to-back rounds cannot outrun the refresh cadence
                if let Some(hub) = &hub {
                    refresh_gauges_if_stale(hub, multi, part, &mut last_gauges, false);
                }
                continue;
            }
            Ok(None) => {}
            Err(e) => {
                // the lane requeued its requests; retry — after a
                // bounded cooldown — a few times before surfacing (a
                // persistently failing fleet)
                stats.lock().round_errors += 1;
                consecutive_errors += 1;
                if let Some(r) = &rec {
                    r.record(EventKind::RoundError { consecutive: consecutive_errors });
                }
                if consecutive_errors >= MAX_CONSECUTIVE_ROUND_ERRORS {
                    // the failing rounds are the newest events on the
                    // ring — dump them before the loop dies (ADR-006)
                    if let Some(hub) = &hub {
                        hub.recorder.dump_now(&format!(
                            "giving up after {consecutive_errors} consecutive round failures: {e}"
                        ));
                    }
                    // every admitted-but-unanswered request and every
                    // still-queued arrival gets its outcome frame
                    // before the loop dies — the one-outcome-per-
                    // arrival contract holds on the error path too
                    for (_, route) in routes.drain() {
                        route.reply.push(Frame::reject(
                            route.client_id,
                            route.lane as u32,
                            RejectCode::Shutdown,
                            "dispatch loop failed",
                        ));
                    }
                    while let Some(env) = bridge.try_pop() {
                        env.reply.push(Frame::reject(
                            env.client_id,
                            env.lane as u32,
                            RejectCode::Shutdown,
                            "dispatch loop failed",
                        ));
                    }
                    return Err(e).context("dispatch loop: rounds failing persistently");
                }
                // failure cooldown (ADR-007 satellite): before this,
                // the failed lane was re-picked IMMEDIATELY — three
                // failures burned in microseconds (the loop died before
                // any sibling deadline could interleave a healthy
                // round) and the doomed lane's WDRR credit was shredded
                // while healthy lanes waited. Cooling the lane drops it
                // out of selection AND the deadline scan, so the
                // next_due_in below reflects the SIBLINGS' deadlines.
                if let Some(lane) = multi.take_failed_lane() {
                    multi.set_lane_cooldown(lane, Instant::now() + FAILURE_COOLDOWN);
                }
                // short back-off capped by the next real deadline: a
                // due sibling dispatches immediately; a sole failing
                // lane naps instead of busy-spinning its requeue.
                // Arrivals still wake the nap early.
                let nap = match multi.next_due_in() {
                    Some(d) if d.is_zero() => continue,
                    Some(d) => d.min(FAILURE_COOLDOWN),
                    None => FAILURE_COOLDOWN,
                };
                if let Some(env) = bridge.pop_timeout(nap) {
                    let local = to_local(env.lane);
                    admit(multi, env, local, &mut routes, &mut seq, &mut stats.lock(), rec.as_ref());
                }
                continue;
            }
        }

        // 3) nothing due: shutdown flush, or a deadline-capped nap
        if bridge.is_closed() && bridge.is_empty() {
            if multi.pending() == 0 {
                break;
            }
            // flush leftovers (partial rounds before their deadline)
            let flushed = multi.drain(&mut responses)?;
            let mut st = stats.lock();
            st.rounds += 1; // at least one; exact count is in metrics
            route_responses(&mut responses, &mut routes, usize::MAX, &mut st, tracer.as_ref());
            drop(st);
            debug_assert!(flushed > 0);
            continue;
        }
        // one scan decides both "due right now?" (a deadline expired in
        // the microseconds since dispatch_next said nothing was) and
        // how long the nap may be
        let nap = match multi.next_due_in() {
            Some(d) if d.is_zero() => {
                stats.lock().idle_naps_avoided += 1;
                continue;
            }
            Some(d) => d.min(IDLE_POLL),
            None => IDLE_POLL,
        };
        if let Some(env) = bridge.pop_timeout(nap) {
            let local = to_local(env.lane);
            admit(multi, env, local, &mut routes, &mut seq, &mut stats.lock(), rec.as_ref());
        }
    }
    Ok(())
}

/// Republish lane gauges when the time budget (`IDLE_POLL`) has
/// elapsed since the last publish, or unconditionally with `force`
/// (a pending query must never read stale gauges). Returns whether a
/// publish happened. Called both at the loop top AND on the
/// round-dispatch path (ADR-007 satellite): a saturated loop that
/// never reaches an idle poll still refreshes within one cadence.
fn refresh_gauges_if_stale<E: RoundExecutor>(
    hub: &ObsHub,
    multi: &MultiServer<E>,
    part: Option<(&Topology, usize)>,
    last: &mut Option<Instant>,
    force: bool,
) -> bool {
    if force || last.is_none_or(|t| t.elapsed() >= IDLE_POLL) {
        publish_lane_gauges(hub, multi, part);
        *last = Some(Instant::now());
        return true;
    }
    false
}

/// Publish every non-retired lane's point-in-time gauge to the hub
/// (retired slots drop theirs — a stale "draining" gauge would outlive
/// the lane). Runs between rounds on the owning dispatch thread, so all
/// fields of one gauge are mutually coherent.
fn publish_lane_gauges<E: RoundExecutor>(
    hub: &ObsHub,
    multi: &MultiServer<E>,
    part: Option<(&Topology, usize)>,
) {
    for local in 0..multi.lanes() {
        let global = match part {
            None => local,
            Some((topo, p)) => topo.global(p, local),
        };
        let life = match multi.lane_life(local) {
            LaneLife::Retired => {
                hub.drop_gauge(global);
                continue;
            }
            LaneLife::Live => "live",
            LaneLife::Draining => "draining",
        };
        let lane = multi.lane(local);
        hub.publish_gauge(LaneGauge {
            global,
            part: part.map(|(_, p)| p).unwrap_or(0),
            local,
            life,
            weight: multi.qos(local).weight,
            deficit: multi.lane_deficit(local),
            boost_ns: u64::try_from(multi.lane_boost_margin(local).as_nanos())
                .unwrap_or(u64::MAX),
            pending: lane.pending(),
            round_p99_s: lane.metrics.round_p99(),
        });
    }
}

/// Run a [`ParallelDispatcher`] to completion over the bridge: the
/// calling thread becomes the **router** (the main bridge's single
/// consumer — producer-facing semantics are identical to
/// [`run_dispatch`]), and one dispatch thread per lane partition runs
/// the same single-consumer loop over a partition-private sub-bridge.
///
/// ```text
///  producers ── IngressBridge (bounded MPSC, unchanged)
///      │  router thread: global lane -> owning partition
///      ▼
///  sub-bridge[p] (bounded, cap = group_queue_cap)
///      │  dispatch thread p: THE consumer of partition p
///      ▼
///  partition p's MultiServer — own queues + QosScheduler; merged
///  rounds never cross partitions, responses flow per connection
/// ```
///
/// Backpressure composes: a full main bridge rejects at `submit` (as
/// before), and a full sub-bridge makes the router answer `Busy`
/// (`group_busy` in the stats) rather than ever parking — so arrivals
/// for a slow partition cannot wedge the router, and every arrival
/// still receives exactly one outcome frame. Envelopes keep global
/// lane ids end to end; partition threads translate at admission and
/// back at response routing, so the wire protocol is byte-identical to
/// single-thread dispatch.
///
/// Returns the merged [`IngressStats`] of the router and every
/// partition once the bridge is closed and every queue has drained.
/// If any partition fails persistently, its error surfaces after all
/// threads have been joined (the other partitions still drain; the
/// dead partition's arrivals get Busy rejections once its sub-bridge
/// fills).
pub fn run_dispatch_parallel<E: RoundExecutor>(
    dispatcher: &mut ParallelDispatcher<'_, E>,
    bridge: &IngressBridge,
    group_queue_cap: usize,
) -> Result<IngressStats> {
    let stats: Arc<Sharded<IngressStats>> = Arc::new(Sharded::new(dispatcher.parts() + 1));
    run_dispatch_parallel_observed(dispatcher, bridge, group_queue_cap, &stats)?;
    Ok(stats.read())
}

/// [`run_dispatch_parallel`] with the counters externalized: the caller
/// provides the [`Sharded`] accumulator (size it `parts + 1` — one
/// shard per dispatch thread plus the router — so every recording
/// thread gets a private shard) and can `stats.read()` a live,
/// exactly merged snapshot at ANY point while the run is in flight —
/// the monitoring surface the single merged return value cannot offer.
pub fn run_dispatch_parallel_observed<E: RoundExecutor>(
    dispatcher: &mut ParallelDispatcher<'_, E>,
    bridge: &IngressBridge,
    group_queue_cap: usize,
    stats: &Arc<Sharded<IngressStats>>,
) -> Result<()> {
    run_parallel_inner(dispatcher, bridge, group_queue_cap, stats, None)
}

/// [`run_dispatch_parallel_observed`] with a live control plane
/// (ADR-005): each partition's dispatch thread doubles as the executor
/// of that partition's [`ControlPlane`] command queue, applying lane
/// add / remove / hot-swap strictly between its rounds while a
/// [`TopologyController`](crate::coordinator::control::TopologyController)
/// — on any other thread — issues commands against the same plane and
/// the dispatcher's shared [`Topology`] handle.
///
/// Size the plane AFTER pre-provisioning spare partitions
/// ([`ParallelDispatcher::add_spare_part`]): dispatch threads are
/// pinned at run start, so `plane.parts()` must cover every partition.
/// Command apply latency is bounded by one round plus the loop's idle
/// poll; a removed lane's already-admitted requests drain through
/// normal dispatch before its ticket resolves.
pub fn run_dispatch_elastic<'f, E: RoundExecutor>(
    dispatcher: &mut ParallelDispatcher<'f, E>,
    bridge: &IngressBridge,
    group_queue_cap: usize,
    stats: &Arc<Sharded<IngressStats>>,
    plane: &ControlPlane<'f, E>,
) -> Result<()> {
    if plane.parts() < dispatcher.parts() {
        bail!(
            "control plane covers {} partitions, dispatcher has {} \
             (size the plane after add_spare_part)",
            plane.parts(),
            dispatcher.parts()
        );
    }
    run_parallel_inner(dispatcher, bridge, group_queue_cap, stats, Some(plane))
}

// LINT-ALLOW(partition ids come from the topology this fn built; join propagates worker panics deliberately)
fn run_parallel_inner<'f, E: RoundExecutor>(
    dispatcher: &mut ParallelDispatcher<'f, E>,
    bridge: &IngressBridge,
    group_queue_cap: usize,
    stats: &Arc<Sharded<IngressStats>>,
    plane: Option<&ControlPlane<'f, E>>,
) -> Result<()> {
    let router_stats = Sharded::register(stats);
    let (parts, topo) = dispatcher.split_mut();
    let subs: Vec<IngressBridge> =
        (0..parts.len()).map(|_| IngressBridge::new(group_queue_cap)).collect();
    // propagate the observability hub (ADR-006) to every partition's
    // sub-bridge BEFORE the threads spawn — dispatch_core reads it once
    // at entry; the router records its own reject decisions too
    let router_rec = bridge.obs().map(|hub| {
        for sub in &subs {
            sub.attach_obs(Arc::clone(&hub));
        }
        hub.rec_handle()
    });

    let results: Vec<Result<()>> = std::thread::scope(|s| {
        let mut threads = Vec::with_capacity(parts.len());
        for (p, multi) in parts.iter_mut().enumerate() {
            let sub = &subs[p];
            let shard = Sharded::register(stats);
            let ctrl = plane.map(|pl| pl.part(p));
            threads.push(
                s.spawn(move || dispatch_loop(multi, sub, Some((topo, p)), ctrl, &shard)),
            );
        }

        // the router: drain the main bridge into the owning partitions'
        // sub-bridges until it is closed and empty, never blocking on a
        // full sub-bridge (Busy goes back to the client instead)
        loop {
            match bridge.pop_timeout(IDLE_POLL) {
                Some(env) => match topo.locate(env.lane) {
                    // unmapped — including lanes the control plane has
                    // removed or reserved-but-not-yet-installed — and,
                    // defensively, anything mapped beyond the
                    // partitions this run actually spawned
                    None => {
                        router_stats.lock().no_lane += 1;
                        if let Some(r) = &router_rec {
                            r.record(EventKind::Reject {
                                code: RejectCode::NoLane,
                                lane: env.lane,
                            });
                        }
                        env.reply.push(Frame::reject(
                            env.client_id,
                            env.lane as u32,
                            RejectCode::NoLane,
                            "no such lane",
                        ));
                    }
                    Some((p, _)) if p >= subs.len() => {
                        router_stats.lock().no_lane += 1;
                        if let Some(r) = &router_rec {
                            r.record(EventKind::Reject {
                                code: RejectCode::NoLane,
                                lane: env.lane,
                            });
                        }
                        env.reply.push(Frame::reject(
                            env.client_id,
                            env.lane as u32,
                            RejectCode::NoLane,
                            "no such lane",
                        ));
                    }
                    Some((p, _)) => match subs[p].submit(env) {
                        Ok(()) => {}
                        Err(SubmitError::Busy(env)) => {
                            {
                                let mut st = router_stats.lock();
                                st.group_busy += 1;
                                st.note_busy(env.lane);
                            }
                            if let Some(r) = &router_rec {
                                r.record(EventKind::Reject {
                                    code: RejectCode::Busy,
                                    lane: env.lane,
                                });
                            }
                            env.reply.push(Frame::reject(
                                env.client_id,
                                env.lane as u32,
                                RejectCode::Busy,
                                "dispatch group queue full",
                            ));
                        }
                        // unreachable before the close below, kept for
                        // the same in-band guarantee anyway
                        Err(SubmitError::Closed(env)) => {
                            env.reply.push(Frame::reject(
                                env.client_id,
                                env.lane as u32,
                                RejectCode::Shutdown,
                                "server shutting down",
                            ));
                        }
                    },
                },
                None => {
                    if bridge.is_closed() && bridge.is_empty() {
                        break;
                    }
                }
            }
        }
        // propagate shutdown: each partition loop exits once its
        // sub-bridge is closed AND drained AND its lanes are empty
        for sub in &subs {
            sub.close();
        }
        let results: Vec<Result<()>> =
            threads.into_iter().map(|t| t.join().expect("dispatch thread panicked")).collect();
        // a partition that died with an error stopped consuming its
        // sub-bridge; whatever the router put there afterwards still
        // needs an outcome frame (a no-op on success paths — a healthy
        // partition only exits with its sub-bridge drained)
        for sub in &subs {
            while let Some(env) = sub.try_pop() {
                env.reply.push(Frame::reject(
                    env.client_id,
                    env.lane as u32,
                    RejectCode::Shutdown,
                    "dispatch thread unavailable",
                ));
            }
        }
        results
    });

    for r in results {
        r?;
    }
    Ok(())
}

/// Admit one envelope: re-stamp arrival at the boundary, re-key the id,
/// offer to the (pre-translated) local lane, and answer rejections
/// in-band. `env.lane` stays the client's wire lane id — it is what
/// rejection and response frames must quote.
fn admit<E: RoundExecutor>(
    multi: &mut MultiServer<E>,
    env: Envelope,
    local: Option<usize>,
    routes: &mut HashMap<u64, Route>,
    seq: &mut u64,
    stats: &mut IngressStats,
    rec: Option<&RecHandle>,
) {
    let reject_ev = |code: RejectCode, lane: usize| {
        if let Some(r) = rec {
            r.record(EventKind::Reject { code, lane });
        }
    };
    let Envelope { lane, client_id, req, reply } = env;
    let Some(local) = local else {
        // unmapped wire lane (or an envelope misrouted to the wrong
        // partition): never offer, answer in-band
        stats.no_lane += 1;
        reject_ev(RejectCode::NoLane, lane);
        reply.push(Frame::reject(client_id, lane as u32, RejectCode::NoLane, "no such lane"));
        return;
    };
    // admission control (ADR-007): when the lane's projected queue
    // wait — backlog rounds times observed round p99 — already exceeds
    // its SLO, serving this request can only produce a late answer.
    // Shed NOW with a typed Reject{Shed}, before the request consumes
    // a queue slot, a server id, or QoS credit. Distinct from Busy: a
    // Busy lane wants a quick retry, a shedding lane is past its knee.
    if multi.should_shed(local) {
        stats.shed += 1;
        stats.note_shed(lane);
        reject_ev(RejectCode::Shed, lane);
        reply.push(Frame::reject(
            client_id,
            lane as u32,
            RejectCode::Shed,
            "projected queue wait exceeds lane SLO",
        ));
        return;
    }
    // admission-boundary stamp: queue-wait math must not inherit the
    // producer's construction time (or a cloned request's stale stamp)
    let mut req = req.arrived_now();
    let sid = *seq;
    *seq += 1;
    req.id = sid;
    match multi.offer(local, req) {
        Err(_) => {
            stats.no_lane += 1;
            reject_ev(RejectCode::NoLane, lane);
            reply.push(Frame::reject(client_id, lane as u32, RejectCode::NoLane, "no such lane"));
        }
        Ok(Admit::Queued) => {
            stats.admitted += 1;
            routes.insert(sid, Route { client_id, lane, reply });
        }
        Ok(Admit::Rejected) => {
            stats.lane_busy += 1;
            stats.note_busy(lane);
            reject_ev(RejectCode::Busy, lane);
            reply.push(Frame::reject(client_id, lane as u32, RejectCode::Busy, "lane queue full"));
        }
        Ok(Admit::Invalid) => {
            stats.invalid += 1;
            reject_ev(RejectCode::Invalid, lane);
            reply.push(Frame::reject(
                client_id,
                lane as u32,
                RejectCode::Invalid,
                "payload does not match lane fleet",
            ));
        }
    }
}

/// Send a batch of responses back to their connections. `lane` is a
/// hint for the common case; the authoritative lane is in the route
/// (drain and coalesced-round batches mix lanes — they pass
/// `usize::MAX`).
fn route_responses(
    responses: &mut Vec<Response>,
    routes: &mut HashMap<u64, Route>,
    lane: usize,
    stats: &mut IngressStats,
    tracer: Option<&StageTracer>,
) {
    // one clock read per batch: the write seam's end stamp (the whole
    // batch hands to reply queues "now", within stamp granularity)
    let write_end = tracer.map(|_| Instant::now());
    for resp in responses.drain(..) {
        let Some(route) = routes.remove(&resp.id) else {
            // a request admitted outside this loop (foreign offer) has
            // no connection to answer; drop silently
            continue;
        };
        debug_assert!(lane == usize::MAX || route.lane == lane);
        stats.responses += 1;
        if let (Some(t), Some(end)) = (tracer, write_end) {
            // folded under the route's GLOBAL lane id — the same id
            // space the gauges and the wire use
            t.fold_stamps(route.lane, &resp.stamps, end);
        }
        let (shape, data) = resp.output.into_parts();
        // a closed reply queue (client gone) drops the frame, which is
        // the correct delivery semantics for a vanished connection
        route.reply.push(Frame::Response {
            id: route.client_id,
            lane: route.lane as u32,
            model_idx: resp.model_idx as u32,
            latency: resp.latency,
            shape,
            data,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Request;
    use crate::tensor::Tensor;

    fn env(id: u64) -> Envelope {
        Envelope {
            lane: 0,
            client_id: id,
            req: Request::new(id, 0, Tensor::zeros(&[1, 4])),
            reply: FrameQueue::new(),
        }
    }

    #[test]
    fn bridge_bounds_and_backpressure() {
        let b = IngressBridge::new(2);
        assert!(b.submit(env(0)).is_ok());
        assert!(b.submit(env(1)).is_ok());
        match b.submit(env(2)) {
            Err(SubmitError::Busy(e)) => assert_eq!(e.client_id, 2),
            _ => panic!("third submit must hit the bound"),
        }
        assert_eq!(b.len(), 2);
        assert_eq!(b.try_pop().unwrap().client_id, 0);
        assert!(b.submit(env(3)).is_ok(), "pop frees a slot");
    }

    #[test]
    fn closed_bridge_rejects_submits_but_drains_pops() {
        let b = IngressBridge::new(4);
        assert!(b.submit(env(0)).is_ok());
        b.close();
        match b.submit(env(1)) {
            Err(SubmitError::Closed(e)) => assert_eq!(e.client_id, 1),
            _ => panic!("closed bridge must refuse submits"),
        }
        assert_eq!(b.pop_timeout(Duration::from_millis(1)).unwrap().client_id, 0);
        assert!(b.pop_timeout(Duration::from_millis(1)).is_none());
    }

    #[test]
    fn pop_timeout_wakes_on_submit() {
        let b = IngressBridge::new(4);
        let b2 = b.clone();
        let t = std::thread::spawn(move || b2.pop_timeout(Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(10));
        assert!(b.submit(env(7)).is_ok());
        let got = t.join().unwrap().expect("blocked pop must wake on submit");
        assert_eq!(got.client_id, 7);
    }
}
