//! Ingress transports: one [`Transport`] trait, two implementations.
//!
//! - [`TcpTransport`] — length-prefixed [`Frame`]s over a `TcpStream`
//!   (NODELAY, buffered writes flushed per frame);
//! - [`ChanTransport`] — an in-proc pair of [`FrameQueue`]s with the same
//!   frame semantics, for tests and single-process benches where socket
//!   jitter would drown the measurement.
//!
//! A transport is full duplex: [`Transport::split`] yields independently
//! usable send/receive halves so a connection can run one reader thread
//! and one writer thread (the shape `ingress::bridge::serve_conn` and
//! every open-loop client use). Dropping a half closes its direction:
//! the peer's `recv` drains what was already queued, then returns
//! `Ok(None)` — the same clean-EOF signal a closed socket produces.

use std::collections::VecDeque;
use std::io::{BufReader, BufWriter, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::{Arc, Condvar};

use anyhow::{bail, Context, Result};

use crate::util::lock::{LockRank, OrderedMutex};

use super::frame::Frame;

/// Send half of a connection.
pub trait TransportTx: Send {
    fn send(&mut self, frame: &Frame) -> Result<()>;
}

/// Receive half of a connection. `Ok(None)` = peer closed cleanly.
pub trait TransportRx: Send {
    fn recv(&mut self) -> Result<Option<Frame>>;
}

/// A full-duplex framed connection. Use the inherited `send`/`recv`
/// directly from one thread, or [`Transport::split`] for one reader
/// thread plus one writer thread.
pub trait Transport: TransportTx + TransportRx {
    #[allow(clippy::type_complexity)]
    fn split(self: Box<Self>) -> Result<(Box<dyn TransportTx>, Box<dyn TransportRx>)>;
}

// ---------------------------------------------------------------------------
// FrameQueue: the shared frame mailbox (in-proc transport + reply routing)
// ---------------------------------------------------------------------------

/// An unbounded MPMC frame mailbox (mutex + condvar). One direction of a
/// [`ChanTransport`], and the per-connection reply queue the dispatch
/// thread routes responses into. Unbounded by design: admission
/// backpressure lives at the ingress bridge, not on the reply path — a
/// response that was already computed must never block the dispatch
/// thread behind a slow client connection.
#[derive(Clone)]
pub struct FrameQueue {
    inner: Arc<Fq>,
}

struct Fq {
    // ReplyQueue is the top of the lock hierarchy: the dispatch thread
    // pushes responses here while still holding its stats-shard guard
    // (ADR-008 edge StatsShard < ReplyQueue).
    state: OrderedMutex<FqState>,
    ready: Condvar,
}

struct FqState {
    q: VecDeque<Frame>,
    closed: bool,
}

impl Default for FrameQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl FrameQueue {
    pub fn new() -> FrameQueue {
        FrameQueue {
            inner: Arc::new(Fq {
                state: OrderedMutex::new(
                    LockRank::ReplyQueue,
                    FqState { q: VecDeque::new(), closed: false },
                ),
                ready: Condvar::new(),
            }),
        }
    }

    /// Enqueue a frame. Returns `false` (frame dropped) if the queue is
    /// closed — the receiver is gone, so there is nobody to deliver to.
    pub fn push(&self, frame: Frame) -> bool {
        let mut st = self.inner.state.lock();
        if st.closed {
            return false;
        }
        st.q.push_back(frame);
        self.inner.ready.notify_one();
        true
    }

    /// Blocking pop: the next frame, or `None` once the queue is closed
    /// AND drained (frames queued before `close` are still delivered).
    pub fn pop(&self) -> Option<Frame> {
        let mut st = self.inner.state.lock();
        loop {
            if let Some(f) = st.q.pop_front() {
                return Some(f);
            }
            if st.closed {
                return None;
            }
            st = st.wait(&self.inner.ready);
        }
    }

    pub fn try_pop(&self) -> Option<Frame> {
        self.inner.state.lock().q.pop_front()
    }

    /// Close the queue: pending frames stay deliverable, new pushes are
    /// dropped, and blocked poppers wake.
    pub fn close(&self) {
        self.inner.state.lock().closed = true;
        self.inner.ready.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.state.lock().closed
    }

    pub fn len(&self) -> usize {
        self.inner.state.lock().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// ---------------------------------------------------------------------------
// ChanTransport: in-proc transport over a FrameQueue pair
// ---------------------------------------------------------------------------

/// Send half of a [`ChanTransport`]. Dropping it closes the direction.
pub struct ChanTx {
    q: FrameQueue,
}

impl TransportTx for ChanTx {
    fn send(&mut self, frame: &Frame) -> Result<()> {
        if !self.q.push(frame.clone()) {
            bail!("peer closed");
        }
        Ok(())
    }
}

impl Drop for ChanTx {
    fn drop(&mut self) {
        self.q.close();
    }
}

/// Receive half of a [`ChanTransport`]. Dropping it closes the
/// direction, so a vanished receiver turns the peer's sends into errors
/// instead of unbounded queue growth.
pub struct ChanRx {
    q: FrameQueue,
}

impl TransportRx for ChanRx {
    fn recv(&mut self) -> Result<Option<Frame>> {
        Ok(self.q.pop())
    }
}

impl Drop for ChanRx {
    fn drop(&mut self) {
        self.q.close();
    }
}

/// In-proc transport: a connected pair of frame queues.
pub struct ChanTransport {
    tx: ChanTx,
    rx: ChanRx,
}

impl ChanTransport {
    /// A connected (client, server) pair.
    pub fn pair() -> (ChanTransport, ChanTransport) {
        let ab = FrameQueue::new(); // a -> b
        let ba = FrameQueue::new(); // b -> a
        let a = ChanTransport { tx: ChanTx { q: ab.clone() }, rx: ChanRx { q: ba.clone() } };
        let b = ChanTransport { tx: ChanTx { q: ba }, rx: ChanRx { q: ab } };
        (a, b)
    }
}

impl TransportTx for ChanTransport {
    fn send(&mut self, frame: &Frame) -> Result<()> {
        self.tx.send(frame)
    }
}

impl TransportRx for ChanTransport {
    fn recv(&mut self) -> Result<Option<Frame>> {
        self.rx.recv()
    }
}

impl Transport for ChanTransport {
    fn split(self: Box<Self>) -> Result<(Box<dyn TransportTx>, Box<dyn TransportRx>)> {
        Ok((Box::new(self.tx), Box::new(self.rx)))
    }
}

// ---------------------------------------------------------------------------
// TcpTransport: frames over a TcpStream
// ---------------------------------------------------------------------------

/// Send half of a [`TcpTransport`] (buffered, flushed per frame).
pub struct TcpTx {
    w: BufWriter<TcpStream>,
    scratch: Vec<u8>,
}

impl TransportTx for TcpTx {
    fn send(&mut self, frame: &Frame) -> Result<()> {
        self.scratch.clear();
        frame.encode_into(&mut self.scratch);
        self.w.write_all(&self.scratch).context("tcp frame write")?;
        self.w.flush().context("tcp frame flush")
    }
}

/// Receive half of a [`TcpTransport`].
pub struct TcpRx {
    r: BufReader<TcpStream>,
}

impl TransportRx for TcpRx {
    fn recv(&mut self) -> Result<Option<Frame>> {
        Frame::read_from(&mut self.r)
    }
}

/// Framed TCP connection (NODELAY — rounds are latency-sensitive and
/// frames are already batched writes).
pub struct TcpTransport {
    tx: TcpTx,
    rx: TcpRx,
}

impl TcpTransport {
    pub fn connect(addr: impl ToSocketAddrs) -> Result<TcpTransport> {
        let stream = TcpStream::connect(addr).context("tcp connect")?;
        Self::from_stream(stream)
    }

    /// Wrap an accepted (or connected) stream.
    pub fn from_stream(stream: TcpStream) -> Result<TcpTransport> {
        stream.set_nodelay(true).context("tcp nodelay")?;
        let rstream = stream.try_clone().context("tcp stream clone")?;
        Ok(TcpTransport {
            tx: TcpTx { w: BufWriter::new(stream), scratch: Vec::new() },
            rx: TcpRx { r: BufReader::new(rstream) },
        })
    }
}

impl TransportTx for TcpTransport {
    fn send(&mut self, frame: &Frame) -> Result<()> {
        self.tx.send(frame)
    }
}

impl TransportRx for TcpTransport {
    fn recv(&mut self) -> Result<Option<Frame>> {
        self.rx.recv()
    }
}

impl Transport for TcpTransport {
    fn split(self: Box<Self>) -> Result<(Box<dyn TransportTx>, Box<dyn TransportRx>)> {
        Ok((Box::new(self.tx), Box::new(self.rx)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chan_pair_roundtrips_frames_both_ways() {
        let (mut a, mut b) = ChanTransport::pair();
        a.send(&Frame::Eos).unwrap();
        assert_eq!(b.recv().unwrap(), Some(Frame::Eos));
        let f = Frame::reject(1, 0, super::super::frame::RejectCode::Busy, "x");
        b.send(&f).unwrap();
        assert_eq!(a.recv().unwrap(), Some(f));
    }

    #[test]
    fn dropping_a_half_is_clean_eof_after_drain() {
        let (a, mut b) = ChanTransport::pair();
        let (mut atx, arx) = (Box::new(a) as Box<dyn Transport>).split().unwrap();
        atx.send(&Frame::Eos).unwrap();
        drop(atx);
        // the frame sent before the close still arrives, then EOF
        assert_eq!(b.recv().unwrap(), Some(Frame::Eos));
        assert_eq!(b.recv().unwrap(), None);
        // and once the peer's receive half is gone, sends fail
        drop(arx);
        assert!(b.send(&Frame::Eos).is_err());
    }

    #[test]
    fn frame_queue_close_drains_then_ends() {
        let q = FrameQueue::new();
        assert!(q.push(Frame::Eos));
        q.close();
        assert!(!q.push(Frame::Eos), "pushes after close are dropped");
        assert_eq!(q.pop(), Some(Frame::Eos));
        assert_eq!(q.pop(), None);
    }
}
